"""Minimal ``hypothesis`` fallback for environments without the package.

The test-suite uses a small, closed subset of hypothesis — ``@given`` with
keyword strategies, ``@settings(max_examples=..., deadline=...)``, and the
``integers`` / ``sampled_from`` / ``booleans`` / ``floats`` strategies.
When the real package is unavailable (this repo installs no extra deps),
``tests/conftest.py`` installs this stub, which replays each property test
over ``max_examples`` deterministic pseudo-random draws seeded from the
test's qualified name.  No shrinking, no database — a failing example's
kwargs are in the assertion traceback.
"""

from __future__ import annotations

import inspect
import random
import sys
import types


class _Strategy:
    def __init__(self, draw):
        self.draw = draw


def integers(min_value: int, max_value: int) -> _Strategy:
    return _Strategy(lambda rng: rng.randint(min_value, max_value))


def sampled_from(options) -> _Strategy:
    options = list(options)
    return _Strategy(lambda rng: rng.choice(options))


def booleans() -> _Strategy:
    return _Strategy(lambda rng: rng.random() < 0.5)


def floats(min_value: float = 0.0, max_value: float = 1.0, **_kw) -> _Strategy:
    return _Strategy(lambda rng: rng.uniform(min_value, max_value))


def given(**strategies):
    def deco(fn):
        def wrapper(*args, **kwargs):
            n = getattr(fn, "_stub_max_examples", 10)
            rng = random.Random(f"{fn.__module__}.{fn.__qualname__}")
            for _ in range(n):
                drawn = {k: s.draw(rng) for k, s in strategies.items()}
                fn(*args, **drawn, **kwargs)

        wrapper.__name__ = fn.__name__
        wrapper.__qualname__ = fn.__qualname__
        wrapper.__doc__ = fn.__doc__
        wrapper.__module__ = fn.__module__
        # hide the strategy-drawn params so pytest doesn't treat them as
        # fixtures (the real hypothesis does the same)
        sig = inspect.signature(fn)
        wrapper.__signature__ = sig.replace(parameters=[
            p for name, p in sig.parameters.items() if name not in strategies])
        wrapper._stub_target = fn
        return wrapper
    return deco


def settings(max_examples: int = 10, deadline=None, **_kw):
    def deco(fn):
        # works in either decorator order: reach through a @given wrapper
        getattr(fn, "_stub_target", fn)._stub_max_examples = max_examples
        return fn
    return deco


def install() -> None:
    """Register stub ``hypothesis`` / ``hypothesis.strategies`` modules."""
    strategies = types.ModuleType("hypothesis.strategies")
    for name in ("integers", "sampled_from", "booleans", "floats"):
        setattr(strategies, name, globals()[name])
    mod = types.ModuleType("hypothesis")
    mod.given = given
    mod.settings = settings
    mod.strategies = strategies
    mod.__stub__ = True
    sys.modules["hypothesis"] = mod
    sys.modules["hypothesis.strategies"] = strategies
