"""Plan (de)serialization: JSON round-trips + the schema-drift guard.

A :class:`~repro.plan.plan.ServingPlan` is designed to round-trip
losslessly: ``from_dict(to_dict(plan)) == plan`` for every valid plan
(the dataclass canonicalizes nested containers to JSON types at
construction), and the committed BENCH files embed ``to_dict(resolve())``
so any recorded cell can be re-served from its plan alone.

``check_schema()`` is the CI guard (run by ``benchmarks/run.py --smoke``):
it fails loudly when the JSON schema drifts from the dataclass fields, so
a field added to one surface but not the other breaks the build instead
of silently dropping design parameters from the trajectory.
"""

from __future__ import annotations

import dataclasses
import json
from typing import Dict, Mapping

from repro.plan.plan import FleetPlan, ServingPlan, WorkloadProfile

PLAN_SCHEMA = "serving_plan/v1"
FLEET_SCHEMA = "fleet_plan/v1"


# Fields omitted from the JSON when at their default value: the fault-
# tolerance knobs postdate the committed BENCH cells, and emitting them
# unconditionally would perturb every embedded plan dict byte-for-byte.
# ``from_dict`` fills the defaults back in, so round-tripping is lossless.
_OMIT_AT_DEFAULT = ("retry_budget", "watchdog_ticks")


def to_dict(plan: ServingPlan) -> Dict[str, object]:
    """Plain-JSON dict of a plan, tagged with the schema id."""
    d = dataclasses.asdict(plan)
    if d["buckets"] is not None:
        d["buckets"] = list(d["buckets"])
    defaults = {f.name: f.default for f in dataclasses.fields(ServingPlan)}
    for name in _OMIT_AT_DEFAULT:
        if d[name] == defaults[name]:
            del d[name]
    return {"schema": PLAN_SCHEMA, **d}


def from_dict(d: Mapping[str, object]) -> ServingPlan:
    """Inverse of :func:`to_dict`; tolerant of a missing schema tag (plan
    dicts embedded in BENCH cells) but loud on a wrong one."""
    d = dict(d)
    schema = d.pop("schema", PLAN_SCHEMA)
    if schema != PLAN_SCHEMA:
        raise ValueError(f"unsupported plan schema {schema!r}; "
                         f"this build reads {PLAN_SCHEMA!r}")
    known = {f.name for f in dataclasses.fields(ServingPlan)}
    unknown = set(d) - known
    if unknown:
        raise ValueError(f"unknown plan fields {sorted(unknown)}; "
                         f"known: {sorted(known)}")
    # list -> tuple coercion happens in ServingPlan.__post_init__
    return ServingPlan(**d)


def save_plan(plan: ServingPlan, path: str) -> None:
    with open(path, "w") as f:
        json.dump(to_dict(plan), f, indent=1)
        f.write("\n")


def load_plan(path: str) -> ServingPlan:
    with open(path) as f:
        return from_dict(json.load(f)).validate()


def fleet_to_dict(fleet: FleetPlan) -> Dict[str, object]:
    """Plain-JSON dict of a fleet plan: per-replica plans serialize
    through :func:`to_dict` (sharing its omit-at-default rules), the
    fleet-level knobs ride alongside under the fleet schema tag."""
    d = {f.name: getattr(fleet, f.name)
         for f in dataclasses.fields(FleetPlan)}
    d["replicas"] = [to_dict(p) for p in fleet.replicas]
    d["provenance"] = dict(fleet.provenance)
    return {"schema": FLEET_SCHEMA, **d}


def fleet_from_dict(d: Mapping[str, object]) -> FleetPlan:
    """Inverse of :func:`fleet_to_dict`; tolerant of a missing schema tag
    (fleet dicts embedded in BENCH cells) but loud on a wrong one."""
    d = dict(d)
    schema = d.pop("schema", FLEET_SCHEMA)
    if schema != FLEET_SCHEMA:
        raise ValueError(f"unsupported fleet schema {schema!r}; "
                         f"this build reads {FLEET_SCHEMA!r}")
    known = {f.name for f in dataclasses.fields(FleetPlan)}
    unknown = set(d) - known
    if unknown:
        raise ValueError(f"unknown fleet fields {sorted(unknown)}; "
                         f"known: {sorted(known)}")
    if "replicas" in d:
        d["replicas"] = tuple(from_dict(p) for p in d["replicas"])
    return FleetPlan(**d)


def save_fleet_plan(fleet: FleetPlan, path: str) -> None:
    with open(path, "w") as f:
        json.dump(fleet_to_dict(fleet), f, indent=1)
        f.write("\n")


def load_fleet_plan(path: str) -> FleetPlan:
    with open(path) as f:
        return fleet_from_dict(json.load(f)).validate()


def check_schema() -> None:
    """Fail loudly when the plan JSON schema and the dataclass fields
    drift apart, or when a default plan stops round-tripping exactly."""
    fields = {f.name for f in dataclasses.fields(ServingPlan)}
    probe = ServingPlan(arch="rwkv6-1.6b",
                        buckets=(8, 16, 63), max_len=64,
                        cache_layout="paged:16",
                        retry_budget=5, watchdog_ticks=6,
                        tile_plans={
                            "rwkv": {"bh": 64, "persistent": True,
                                     "resident": True, "impl": "auto"},
                            "attn": {"bq": 128, "bk": 512},
                            "matmul_int8": {"bm": 256, "bn": 256, "bk": 512},
                        },
                        provenance={"source": "schema-probe"}).validate()
    d = to_dict(probe)
    keys = set(d) - {"schema"}
    if keys != fields:
        raise RuntimeError(
            f"plan JSON schema drifted from the ServingPlan dataclass: "
            f"json-only={sorted(keys - fields)} "
            f"dataclass-only={sorted(fields - keys)}")
    rt = from_dict(json.loads(json.dumps(d)))
    if rt != probe:
        raise RuntimeError("ServingPlan no longer round-trips through "
                           "JSON byte-exactly; fix plan.io coercions")
    # tile_plans validation must stay loud: an unknown kernel kind or a
    # non-positive tile must never reach a BlockSpec
    for bad in ({"bogus_kernel": {"bh": 8}}, {"rwkv": {"bh": 0}},
                {"rwkv": {"impl": "cuda"}}):
        try:
            dataclasses.replace(probe, tile_plans=bad).validate()
        except ValueError:
            pass
        else:
            raise RuntimeError(
                f"plan.validate() accepted malformed tile_plans {bad}")
    wp = WorkloadProfile(heavy_decode=(0.03, 32, 48))
    if WorkloadProfile.from_json(json.loads(json.dumps(wp.to_json()))) != wp:
        raise RuntimeError("WorkloadProfile no longer round-trips through "
                           "JSON; fix plan.io coercions")
    # fleet schema: same drift + round-trip contract one level up
    ffields = {f.name for f in dataclasses.fields(FleetPlan)}
    fprobe = FleetPlan(
        replicas=(probe, dataclasses.replace(probe, max_batch=8),
                  dataclasses.replace(probe, cache_layout="dense")),
        routing="least_queue", n_prefill=1,
        transit_bytes_per_tick=1e6,
        provenance={"source": "schema-probe"}).validate()
    fd = fleet_to_dict(fprobe)
    fkeys = set(fd) - {"schema"}
    if fkeys != ffields:
        raise RuntimeError(
            f"fleet JSON schema drifted from the FleetPlan dataclass: "
            f"json-only={sorted(fkeys - ffields)} "
            f"dataclass-only={sorted(ffields - fkeys)}")
    frt = fleet_from_dict(json.loads(json.dumps(fd)))
    if frt != fprobe:
        raise RuntimeError("FleetPlan no longer round-trips through "
                           "JSON byte-exactly; fix plan.io coercions")
    # fleet validation must stay loud on the invariants the router relies
    # on: a known routing policy and a snapshot-compatible disaggregation
    for bad in (dataclasses.replace(fprobe, routing="bogus"),
                dataclasses.replace(fprobe, n_prefill=3),
                dataclasses.replace(fprobe, replicas=(
                    probe, dataclasses.replace(probe, max_len=128)),
                    n_prefill=1)):
        try:
            bad.validate()
        except ValueError:
            pass
        else:
            raise RuntimeError(
                f"FleetPlan.validate() accepted a malformed fleet: "
                f"{bad.summary()}")


__all__ = ["PLAN_SCHEMA", "FLEET_SCHEMA", "to_dict", "from_dict",
           "save_plan", "load_plan", "fleet_to_dict", "fleet_from_dict",
           "save_fleet_plan", "load_fleet_plan", "check_schema"]
