"""Plan-centric serving API: one object per design point.

``ServingPlan`` captures every serving design parameter (capacity, bucket
set, hot-path chunking, scheduling policy, sampling, sharding mode,
per-kernel tile plans); ``WorkloadProfile`` captures the workload it is
tuned for; ``planner.autotune`` searches the plan space per (arch,
workload) the way the paper's DSE searches tile geometry per problem
size.  ``io`` round-trips plans through JSON for the CLI (`--plan`) and
the committed BENCH trajectory files.

`planner` is imported lazily (it drags in jax and the model stack);
``from repro.plan import planner`` when you need it.
"""

from repro.plan.io import (  # noqa: F401
    PLAN_SCHEMA,
    from_dict,
    load_plan,
    save_plan,
    to_dict,
)
from repro.plan.plan import (  # noqa: F401
    MIN_BUCKET,
    ServingPlan,
    WorkloadProfile,
    default_buckets,
)

__all__ = ["ServingPlan", "WorkloadProfile", "MIN_BUCKET",
           "default_buckets", "PLAN_SCHEMA", "to_dict", "from_dict",
           "save_plan", "load_plan"]
