"""Plan-centric serving API: one object per design point.

``ServingPlan`` captures every serving design parameter (capacity, bucket
set, hot-path chunking, scheduling policy, sampling, sharding mode,
per-kernel tile plans); ``WorkloadProfile`` captures the workload it is
tuned for; ``planner.autotune`` searches the plan space per (arch,
workload) the way the paper's DSE searches tile geometry per problem
size.  ``io`` round-trips plans through JSON for the CLI (`--plan`) and
the committed BENCH trajectory files.

`planner` is imported lazily (it drags in jax and the model stack);
``from repro.plan import planner`` when you need it.
"""

from repro.plan.io import (  # noqa: F401
    FLEET_SCHEMA,
    PLAN_SCHEMA,
    fleet_from_dict,
    fleet_to_dict,
    from_dict,
    load_fleet_plan,
    load_plan,
    save_fleet_plan,
    save_plan,
    to_dict,
)
from repro.plan.plan import (  # noqa: F401
    MIN_BUCKET,
    FleetPlan,
    ServingPlan,
    WorkloadProfile,
    default_buckets,
)

__all__ = ["ServingPlan", "FleetPlan", "WorkloadProfile", "MIN_BUCKET",
           "default_buckets", "PLAN_SCHEMA", "FLEET_SCHEMA", "to_dict",
           "from_dict", "save_plan", "load_plan", "fleet_to_dict",
           "fleet_from_dict", "save_fleet_plan", "load_fleet_plan"]
