"""`ServingPlan`: every serving design parameter behind one frozen object.

The paper's central claim is that a spatial accelerator stays efficient
across problem sizes because the design parameters (tiling, unrolling,
residency) live behind a general abstraction that a per-problem-size
search can optimize — `repro.core.dse` already does this at the kernel
level (`Plan`, `search`, `best_plan`).  The serving layer had grown the
opposite way: the same ~10 knobs (max_batch, bucket set, sync_every,
policy, preemption, overlap, sampler, sharding mode) threaded by hand
through `ServingEngine.__init__`, 20+ `launch/serve.py` flags, and the
benchmark's `ServingLoadCell`.  This module promotes the design-space
idea to the whole serving stack:

* :class:`ServingPlan` — a frozen, JSON-serializable dataclass that is
  the *single source of truth* for every serving design parameter.  The
  engine is constructed from it (`ServingEngine.from_plan`), the CLI
  loads/saves it (`--plan` / `--save-plan`), and every committed BENCH
  cell embeds the resolved plan dict so the perf trajectory records
  *which* design point produced each number.
* :class:`WorkloadProfile` — the workload half of a serving cell (arrival
  process, prompt/decode length distributions, deadlines): the "problem
  size" the planner searches against.
* :func:`repro.plan.planner.autotune` — the serving-level analogue of
  `core.dse.best_plan`: searches (bucket set x sync_every x max_batch x
  policy) against the `repro.hw` cost model plus a short virtual-clock
  probe run and returns the best plan per (arch, workload).

Defaults resolve to the engine's historical behavior exactly: a default
plan produces a bit-identical schedule to the pre-plan engine, which is
what keeps the committed ``BENCH_serving.json`` metrics blocks stable.

This module is dependency-light on purpose (stdlib only): it is imported
by ``repro.configs`` (cells embed plans) and ``repro.serving.engine``
without dragging jax or the model stack in.
"""

from __future__ import annotations

import dataclasses
from typing import Dict, List, Mapping, Optional, Tuple

MIN_BUCKET = 8   # smallest prefill length bucket (pow2 upward, cap max_len-1)


def parse_cache_layout(layout: str) -> Optional[int]:
    """Parse a ``ServingPlan.cache_layout`` string.

    ``"dense"`` → None (fixed per-slot cache columns);
    ``"paged:<block_size>"`` → the positive int block size (block-table
    pool, KV rings paged along the length axis).  Raises ``ValueError``
    on anything else — this is the single validation point shared by
    ``ServingPlan.validate`` and the slot-manager factory."""
    if layout == "dense":
        return None
    if isinstance(layout, str) and layout.startswith("paged:"):
        tail = layout[len("paged:"):]
        try:
            block = int(tail)
        except ValueError:
            block = 0
        if block >= 1 and str(block) == tail:
            return block
    raise ValueError(
        f"cache_layout must be 'dense' or 'paged:<block_size>' with a "
        f"positive integer block size, got {layout!r}")


def default_buckets(max_len: int) -> Tuple[int, ...]:
    """The historical pow2 bucket set: MIN_BUCKET doubling up to, and
    capped at, ``max_len - 1`` (the engine's prefill compile ceiling)."""
    limit = max_len - 1
    out: List[int] = []
    b = MIN_BUCKET
    while b < limit:
        out.append(b)
        b *= 2
    out.append(limit)
    return tuple(out)


def _jsonify(x):
    """Canonicalize nested containers to plain JSON types so a plan that
    round-trips through JSON compares equal to the original."""
    if isinstance(x, Mapping):
        return {str(k): _jsonify(v) for k, v in x.items()}
    if isinstance(x, (list, tuple)):
        return [_jsonify(v) for v in x]
    if isinstance(x, bool) or x is None or isinstance(x, (int, float, str)):
        return x
    return str(x)


@dataclasses.dataclass(frozen=True)
class WorkloadProfile:
    """The workload side of a serving cell: what arrives, how long it is,
    and what SLO it carries.  A pure description — materialize it with
    :func:`repro.serving.workload.profile_items` (seeded, deterministic).

    ``duration=None`` means "caller decides" (the benchmark sweep's fast /
    full switch); every other field mirrors the corresponding
    :func:`repro.serving.workload.make_workload` argument.
    """

    kind: str = "poisson"                    # workload.ARRIVAL_KINDS
    rate: float = 0.5                        # requests per clock unit
    duration: Optional[float] = None         # span in clock units
    prompt_len: Tuple[int, int] = (4, 12)
    max_new_tokens: Tuple[int, int] = (8, 16)
    prompt_dist: str = "uniform"             # workload.PROMPT_DISTS
    prompt_len_long: Optional[int] = None    # long-tail cap
    heavy_decode: Optional[Tuple[float, int, int]] = None
    deadline_slack: Optional[float] = None   # decode-proportional SLO
    deadline_frac: float = 1.0
    burst_factor: float = 4.0                # mmpp only
    dwell: Tuple[float, float] = (16.0, 4.0)  # mmpp only
    trace_path: Optional[str] = None         # kind == "trace"

    def __post_init__(self):
        object.__setattr__(self, "prompt_len", tuple(self.prompt_len))
        object.__setattr__(self, "max_new_tokens",
                           tuple(self.max_new_tokens))
        object.__setattr__(self, "dwell", tuple(self.dwell))
        if self.heavy_decode is not None:
            f, lo, hi = self.heavy_decode
            object.__setattr__(self, "heavy_decode",
                               (float(f), int(lo), int(hi)))

    @property
    def has_deadlines(self) -> bool:
        return self.deadline_slack is not None and self.deadline_frac > 0

    def mean_decode(self) -> float:
        """Expected decode length per request (slot-occupancy ticks on the
        virtual clock) — the planner's service-time estimate."""
        lo, hi = self.max_new_tokens
        mean = (lo + hi) / 2.0
        if self.heavy_decode is not None:
            f, hlo, hhi = self.heavy_decode
            mean = (1 - f) * mean + f * (hlo + hhi) / 2.0
        return mean

    def to_json(self) -> Dict[str, object]:
        return _jsonify(dataclasses.asdict(self))

    @staticmethod
    def from_json(d: Mapping[str, object]) -> "WorkloadProfile":
        # list -> tuple coercion happens in __post_init__
        return WorkloadProfile(**dict(d))

    @staticmethod
    def from_trace(trace, *, kind: str = "poisson",
                   duration: Optional[float] = None) -> "WorkloadProfile":
        """Fit a profile from *observed* traffic: a recorded
        :class:`repro.obs.Tracer` (live object, exported Chrome-trace
        document, or file path).  See :func:`repro.obs.observe.fit_profile`
        for the estimators; ``autotune(WorkloadProfile.from_trace(t))``
        replans from what actually arrived instead of what was declared."""
        from repro.obs.observe import fit_profile

        return fit_profile(trace, kind=kind, duration=duration)


@dataclasses.dataclass(frozen=True)
class ServingPlan:
    """One serving design point: everything the stack needs to decide *how*
    to serve, in one frozen, JSON-round-trippable object.

    Field groups, in order:

    * model identity — ``arch`` (the ``repro.configs`` id), ``reduced``
      (CPU-sized config), ``shard_mode`` (the ``repro.dist`` rules key);
    * capacity — ``max_batch`` decode slots over a ``max_len`` cache,
      backed dense (fixed per-slot columns) or paged (``cache_layout =
      "paged:<block_size>"``: a block-table pool, see
      :mod:`repro.serving.paged`);
    * admission — ``bucketed_prefill`` plus the explicit ``buckets`` set
      (``None`` = the historical pow2 set, see :func:`default_buckets`);
    * decode hot path — ``sync_every`` on-device ticks per host sync,
      ``overlap_prefill`` admission/decode overlap;
    * scheduling — ``policy`` (scheduler-registry key), ``preempt``,
      ``shed_late`` (deadline-aware admission control: reject provably-
      late requests at submit);
    * sampling — ``temperature`` / ``top_k``
      (= :class:`repro.serving.sampler.SamplerConfig`);
    * ``tile_plans`` — per-kernel tile plans: one embedded
      ``core.dse.Plan`` dict per recurrent layer kind, scored at this
      plan's ``max_batch`` (the kernel-level half of the design point);
    * ``provenance`` — where the plan came from (CLI overrides, autotune
      search record); never affects behavior, always recorded.
    """

    # --- model identity --------------------------------------------------
    arch: str
    reduced: bool = True
    shard_mode: str = "decode"
    # --- capacity --------------------------------------------------------
    max_batch: int = 4
    max_len: int = 128
    cache_layout: str = "dense"   # or "paged:<block_size>"
    # --- admission -------------------------------------------------------
    bucketed_prefill: bool = True
    buckets: Optional[Tuple[int, ...]] = None
    # --- decode hot path -------------------------------------------------
    sync_every: int = 1
    overlap_prefill: bool = True
    # --- scheduling ------------------------------------------------------
    policy: str = "fcfs"
    preempt: bool = False
    shed_late: bool = False
    # --- sampling --------------------------------------------------------
    temperature: float = 0.0
    top_k: int = 0
    # --- misc engine behavior -------------------------------------------
    truncate_prompts: bool = False
    # --- fault tolerance -------------------------------------------------
    # retry_budget: recoveries (rollback / re-prefill) a request may
    # consume before it is shed; watchdog_ticks: evict a slot that made no
    # progress for this many ticks (0 = watchdog off).  Both only matter
    # when faults fire — serialization omits them at their defaults, so
    # existing plan dicts and BENCH cells are unchanged (see plan.io).
    retry_budget: int = 3
    watchdog_ticks: int = 0
    # --- per-kernel tile plans + provenance ------------------------------
    tile_plans: Mapping[str, Mapping[str, object]] = dataclasses.field(
        default_factory=dict)
    provenance: Mapping[str, object] = dataclasses.field(
        default_factory=dict)

    def __post_init__(self):
        if self.buckets is not None:
            object.__setattr__(self, "buckets",
                               tuple(int(b) for b in self.buckets))
        object.__setattr__(self, "tile_plans", _jsonify(self.tile_plans))
        object.__setattr__(self, "provenance", _jsonify(self.provenance))

    # ------------------------------------------------------------ validation
    def validate(self) -> "ServingPlan":
        """Structural validation; raises ``ValueError`` on the first
        problem, returns ``self`` so construction can chain.  Policy names
        are checked against the live scheduler registry so a plan can
        never name a policy the engine does not implement."""
        if not self.arch or not isinstance(self.arch, str):
            raise ValueError(f"plan.arch must be a non-empty string, "
                             f"got {self.arch!r}")
        if self.max_batch < 1:
            raise ValueError(f"plan.max_batch must be >= 1, "
                             f"got {self.max_batch}")
        if self.max_len < 2:
            raise ValueError(f"plan.max_len must be >= 2 (one prompt token "
                             f"+ one generated), got {self.max_len}")
        block = parse_cache_layout(self.cache_layout)  # raises on bad form
        if block is not None and block > self.max_len:
            raise ValueError(
                f"plan.cache_layout block size {block} exceeds max_len "
                f"{self.max_len}: a block never covers more than one ring")
        if self.sync_every < 1:
            raise ValueError(f"plan.sync_every must be >= 1, "
                             f"got {self.sync_every}")
        if self.temperature < 0:
            raise ValueError(f"plan.temperature must be >= 0, "
                             f"got {self.temperature}")
        if self.top_k < 0:
            raise ValueError(f"plan.top_k must be >= 0, got {self.top_k}")
        if self.retry_budget < 0:
            raise ValueError(f"plan.retry_budget must be >= 0, "
                             f"got {self.retry_budget}")
        if self.watchdog_ticks < 0:
            raise ValueError(f"plan.watchdog_ticks must be >= 0 "
                             f"(0 disables the watchdog), "
                             f"got {self.watchdog_ticks}")
        from repro.serving.scheduler import SCHEDULERS, make_scheduler
        if self.policy not in SCHEDULERS:
            raise ValueError(f"plan.policy {self.policy!r} is not in the "
                             f"scheduler registry {sorted(SCHEDULERS)}")
        make_scheduler(self.policy, preempt=self.preempt)  # preempt support
        if self.buckets is not None:
            bs = self.buckets
            if not bs:
                raise ValueError("plan.buckets must be non-empty or None")
            if list(bs) != sorted(set(bs)):
                raise ValueError(f"plan.buckets must be strictly "
                                 f"increasing, got {bs}")
            if bs[0] < 1:
                raise ValueError(f"plan.buckets must be >= 1, got {bs}")
            if bs[-1] != self.max_len - 1:
                raise ValueError(
                    f"plan.buckets must end at max_len-1 = "
                    f"{self.max_len - 1} so every admissible prompt has a "
                    f"bucket, got {bs}")
        _validate_tile_plans(self.tile_plans)
        return self

    # ------------------------------------------------------------ resolution
    def resolved_buckets(self) -> Tuple[int, ...]:
        """The explicit bucket set this plan serves with (the pow2 default
        when ``buckets`` is None).  In non-bucketed mode prefill pads to
        the exact prompt length; the returned set is then only the
        compile-ceiling bound of bucketed mode."""
        if self.buckets is not None:
            return self.buckets
        return default_buckets(self.max_len)

    def resolve(self) -> "ServingPlan":
        """A copy with every defaulted design choice made explicit
        (currently: the bucket set) — what the BENCH files embed, so a
        committed cell is re-runnable without knowing the defaults of the
        code that produced it."""
        if not self.bucketed_prefill or self.buckets is not None:
            return self
        return dataclasses.replace(self, buckets=self.resolved_buckets())

    def summary(self) -> str:
        """One-line human identity for CLI banners and logs."""
        b = ("exact" if not self.bucketed_prefill
             else "pow2" if self.buckets is None
             else ",".join(map(str, self.buckets)))
        bits = [self.arch + ("(reduced)" if self.reduced else ""),
                f"b{self.max_batch}", f"len{self.max_len}",
                f"sync{self.sync_every}",
                self.policy + ("+p" if self.preempt else ""),
                f"buckets={b}"]
        if self.cache_layout != "dense":
            bits.append(self.cache_layout)
        if self.shed_late:
            bits.append("shed")
        if not self.overlap_prefill:
            bits.append("no-overlap")
        if self.temperature > 0:
            bits.append(f"T={self.temperature:g}")
        if self.retry_budget != 3:
            bits.append(f"retry{self.retry_budget}")
        if self.watchdog_ticks > 0:
            bits.append(f"wd{self.watchdog_ticks}")
        return " ".join(bits)


@dataclasses.dataclass(frozen=True)
class FleetPlan:
    """One multi-replica serving design point: N per-replica
    :class:`ServingPlan`\\ s (possibly heterogeneous), a routing policy
    from the router registry, and the prefill/decode disaggregation
    split.  The fleet-level analogue of :class:`ServingPlan` — the router
    is constructed from it (``Router.from_plan``), ``planner.
    autotune_fleet`` searches over it coarsely, and fleet BENCH cells
    embed the resolved dict.

    ``n_prefill = 0`` is the colocated mode: every replica admits,
    prefills and decodes.  ``n_prefill = k > 0`` disaggregates: the first
    ``k`` replicas run admission/prefill only and stream finished slot
    state into the remaining decode replicas over a modeled DCN transit
    (cost per snapshot byte from :mod:`repro.hw` — ``hw`` names the
    spec; ``transit_bytes_per_tick`` overrides the derived rate, mostly
    for tests).
    """

    replicas: Tuple[ServingPlan, ...]
    routing: str = "round_robin"
    n_prefill: int = 0
    transit_bytes_per_tick: Optional[float] = None
    hw: str = "tpu-v5e"
    provenance: Mapping[str, object] = dataclasses.field(
        default_factory=dict)

    def __post_init__(self):
        object.__setattr__(self, "replicas", tuple(self.replicas))
        object.__setattr__(self, "provenance", _jsonify(self.provenance))

    @staticmethod
    def replicated(plan: ServingPlan, n: int, *,
                   routing: str = "round_robin", n_prefill: int = 0,
                   **kw) -> "FleetPlan":
        """Homogeneous fleet: ``n`` copies of one replica plan."""
        return FleetPlan(replicas=(plan,) * int(n), routing=routing,
                         n_prefill=n_prefill, **kw)

    @property
    def n_replicas(self) -> int:
        return len(self.replicas)

    def validate(self) -> "FleetPlan":
        """Structural validation; raises ``ValueError`` on the first
        problem, returns ``self``.  Routing names are checked against the
        live router registry (lazy import, mirroring how per-replica
        plans check the scheduler registry); disaggregation additionally
        pins the snapshot-compat invariants — every replica must share
        arch/reduced/max_len or a prefill→decode transit could never
        restore (``SlotManager.check_snapshot_compat`` would reject it)."""
        if not self.replicas:
            raise ValueError("fleet.replicas must name at least one replica")
        if not (0 <= self.n_prefill < len(self.replicas)):
            raise ValueError(
                f"fleet.n_prefill must leave at least one decode replica: "
                f"got n_prefill={self.n_prefill} of "
                f"{len(self.replicas)} replicas")
        if self.transit_bytes_per_tick is not None \
                and self.transit_bytes_per_tick <= 0:
            raise ValueError(
                f"fleet.transit_bytes_per_tick must be > 0 when set, "
                f"got {self.transit_bytes_per_tick}")
        from repro import hw
        if self.hw not in hw.SPECS:
            raise ValueError(f"fleet.hw {self.hw!r} is not a known "
                             f"hardware spec {sorted(hw.SPECS)}")
        from repro.serving.router import ROUTER_POLICIES
        if self.routing not in ROUTER_POLICIES:
            raise ValueError(
                f"fleet.routing {self.routing!r} is not in the router "
                f"registry {sorted(ROUTER_POLICIES)}")
        for i, plan in enumerate(self.replicas):
            if not isinstance(plan, ServingPlan):
                raise ValueError(f"fleet.replicas[{i}] must be a "
                                 f"ServingPlan, got {type(plan).__name__}")
            try:
                plan.validate()
            except ValueError as e:
                raise ValueError(f"fleet.replicas[{i}]: {e}") from e
        if self.n_prefill > 0:
            ref = self.replicas[0]
            for i, plan in enumerate(self.replicas):
                for field in ("arch", "reduced", "max_len"):
                    if getattr(plan, field) != getattr(ref, field):
                        raise ValueError(
                            f"disaggregated fleets need snapshot-compatible "
                            f"replicas: replicas[{i}].{field}="
                            f"{getattr(plan, field)!r} differs from "
                            f"replicas[0].{field}={getattr(ref, field)!r}")
        return self

    def resolve(self) -> "FleetPlan":
        """A copy with every replica plan resolved (explicit buckets) —
        what fleet BENCH cells embed."""
        return dataclasses.replace(
            self, replicas=tuple(p.resolve() for p in self.replicas))

    def summary(self) -> str:
        # plans hold dict fields (tile_plans, provenance) so they are not
        # hashable; collapse homogeneous fleets by equality instead
        homogeneous = all(p == self.replicas[0] for p in self.replicas[1:])
        parts = [f"{len(self.replicas)}x[{self.replicas[0].summary()}]"
                 if homogeneous else
                 " | ".join(p.summary() for p in self.replicas),
                 f"routing={self.routing}"]
        if self.n_prefill:
            parts.append(f"prefill={self.n_prefill}/"
                         f"{len(self.replicas)}")
        return " ".join(parts)


# ---------------------------------------------------------------------------
# tile_plans validation
# ---------------------------------------------------------------------------

# kernel kinds a tile_plans entry may target: the model's layer kinds plus
# the two standalone kernels (fused_rnn cell serving, W8A16 matmul)
TILE_PLAN_KINDS = ("rwkv", "swa_ssm", "attn", "local",
                   "fused_rnn", "matmul_int8")
_TILE_FIELDS = ("bh", "bq", "bk", "bm", "bn")
_META_FIELDS = ("n_tiles", "vmem_bytes", "resident", "step_latency_s",
                "util", "bound")


def _validate_tile_plans(tile_plans) -> None:
    """Structural validation of ``ServingPlan.tile_plans`` — these dicts
    parameterize real Pallas BlockSpecs, so a malformed entry must fail at
    plan time, not as a Mosaic error mid-serving."""
    from repro.kernels.dispatch import VALID_IMPLS

    for kind, entry in (tile_plans or {}).items():
        if kind not in TILE_PLAN_KINDS:
            raise ValueError(
                f"plan.tile_plans[{kind!r}]: unknown kernel kind "
                f"(known: {sorted(TILE_PLAN_KINDS)})")
        if not isinstance(entry, Mapping):
            raise ValueError(
                f"plan.tile_plans[{kind!r}] must be a dict, got "
                f"{type(entry).__name__}")
        for field, value in entry.items():
            if field in _TILE_FIELDS:
                if isinstance(value, bool) or not isinstance(value, int) \
                        or value < 1:
                    raise ValueError(
                        f"plan.tile_plans[{kind!r}][{field!r}] must be a "
                        f"positive int tile size, got {value!r}")
            elif field == "persistent":
                if not isinstance(value, bool):
                    raise ValueError(
                        f"plan.tile_plans[{kind!r}]['persistent'] must be "
                        f"a bool, got {value!r}")
            elif field == "impl":
                if value not in VALID_IMPLS:
                    raise ValueError(
                        f"plan.tile_plans[{kind!r}]['impl'] must be one of "
                        f"{VALID_IMPLS}, got {value!r}")
            elif field not in _META_FIELDS:
                raise ValueError(
                    f"plan.tile_plans[{kind!r}][{field!r}]: unknown field "
                    f"(tiles: {_TILE_FIELDS}; metadata: {_META_FIELDS}; "
                    f"plus 'persistent'/'impl')")
        if entry.get("persistent"):
            # persistent pins the whole weight set in VMEM for the entire
            # token loop — only admissible with recorded DSE residency
            # evidence, and never past the VMEM budget
            if not entry.get("resident"):
                raise ValueError(
                    f"plan.tile_plans[{kind!r}]: persistent=true requires "
                    f"resident=true (DSE evidence the weights fit in VMEM)")
            vmem = entry.get("vmem_bytes")
            if vmem is not None:
                from repro import hw
                budget = hw.vmem_budget()
                if int(vmem) > budget:
                    raise ValueError(
                        f"plan.tile_plans[{kind!r}]: persistent=true but "
                        f"vmem_bytes={vmem} exceeds the VMEM budget "
                        f"{budget}")


def tiles_summary(tile_plans) -> str:
    """Compact hot-path banner fragment: ``rwkv[bh512] attn[bq256,bk1024]``."""
    bits = []
    for kind in sorted(tile_plans or {}):
        entry = tile_plans[kind]
        tiles = [f"{f}{entry[f]}" for f in _TILE_FIELDS if entry.get(f)]
        if entry.get("persistent"):
            tiles.append("persist")
        if entry.get("impl"):
            tiles.append(str(entry["impl"]))
        bits.append(f"{kind}[{','.join(tiles)}]" if tiles else kind)
    return " ".join(bits)


__all__ = ["ServingPlan", "FleetPlan", "WorkloadProfile", "MIN_BUCKET",
           "TILE_PLAN_KINDS", "default_buckets", "parse_cache_layout",
           "tiles_summary"]
