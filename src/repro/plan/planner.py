"""Serving-level design-space search: `autotune(arch, workload, hw_spec)`.

The serving analogue of :func:`repro.core.dse.best_plan`.  The kernel DSE
searches tile geometry per problem size against an analytic latency
model; this planner searches the *serving* design space

    bucket set x sync_every x max_batch x (policy, preempt)

per (architecture, workload profile) against two complementary oracles:

* the **roofline cost model** (`repro.hw.HardwareSpec`) scores the
  dimensions the deterministic virtual clock cannot see — host-sync
  amortization (``sync_every``), prefill padding waste and compile count
  (bucket set), and HBM feasibility of the slot count (weights + cache
  must fit, estimated from the *full-size* config's param/cache specs
  even when the probe runs reduced);
* a short seeded **virtual-clock probe run** scores the dimensions the
  cost model cannot see — queueing: for each feasible (max_batch,
  policy, preempt) candidate the workload is replayed through a real
  engine and ranked by (SLO attainment, p95 TTFT, p95 queue-wait,
  tokens/tick).

Everything is deterministic for a fixed (hw_spec, seed): the probe uses
the virtual clock and seeded workloads, candidate enumeration order is
fixed, and ties break toward the earlier candidate — so `autotune` is a
pure function, and the winning plan's ``provenance`` records the search.
"""

from __future__ import annotations

import dataclasses
import functools
import logging
import math
from typing import Dict, List, Optional, Sequence, Tuple

import numpy as np

from repro import hw
from repro.plan.plan import (
    MIN_BUCKET,
    ServingPlan,
    WorkloadProfile,
    default_buckets,
    parse_cache_layout,
)

log = logging.getLogger("repro.plan")

# cost-model constants: one blocking host<->device round-trip, and one XLA
# prefill compile (amortized over the workload's admissions).  Order of
# magnitude only — they steer *relative* choices, never absolute claims.
HOST_SYNC_S = 50e-6
COMPILE_S = 2.0
HBM_FRACTION = 0.9        # usable HBM after runtime/fragmentation slack
SYNC_GAIN_MIN = 0.01      # keep growing the chunk while gain >= 1%
# paged-layout gather/launch overhead, expressed as extra tokens' worth of
# bytes per allocated page: smaller blocks fragment less but cost more
# table indirection, so the layout search has a real block-size trade-off
# instead of degenerating to "smallest block always wins"
PAGE_OVERHEAD_TOKENS = 2.0

# recurrent layer kinds that map onto the paper's RNN-cell tile search
_RECURRENT_KINDS = ("rwkv", "swa_ssm")


# ---------------------------------------------------------------------------
# Memory + per-tick cost model (full-size config: the deployment target)
# ---------------------------------------------------------------------------


def _spec_bytes(specs) -> int:
    import jax

    from repro.models.params import is_spec

    return int(sum(
        s.size * np.dtype(s.dtype).itemsize
        for s in jax.tree.leaves(specs, is_leaf=is_spec) if is_spec(s)))


@functools.lru_cache(maxsize=None)
def _full_model(arch: str):
    """The full-size (deployment-target) model wrapper — cached: the cost
    model consults it once per max_batch candidate plus per bucket-set
    candidate within a single autotune call."""
    from repro.configs import get_config
    from repro.models.lm import build_model

    return build_model(get_config(arch))


@functools.lru_cache(maxsize=None)
def serving_memory_bytes(arch: str, max_batch: int,
                         max_len: int) -> Tuple[int, int]:
    """(weight_bytes, cache_bytes) of the *full-size* config at the given
    slot count — from the parameter/cache spec trees, no allocation."""
    model = _full_model(arch)
    weights = _spec_bytes(model.param_specs())
    cache = _spec_bytes(model.cache_specs(max_batch, max_len))
    return weights, cache


@functools.lru_cache(maxsize=None)
def _full_param_count(arch: str) -> int:
    import jax

    from repro.models.params import is_spec

    specs = _full_model(arch).param_specs()
    return int(sum(s.size for s in jax.tree.leaves(specs, is_leaf=is_spec)
                   if is_spec(s)))


def modeled_tick_seconds(arch: str, max_batch: int,
                         spec: hw.HardwareSpec) -> float:
    """Roofline cost of one batched decode tick on the target chip: a
    decode step touches every weight once (the paper's compute:memory
    argument — small-batch decode is weight-streaming-bound) and does
    ~2 FLOPs per (param, slot)."""
    n_params = _full_param_count(arch)
    weight_bytes = 2 * n_params  # bf16 deployment weights
    t_compute = spec.matmul_time(2.0 * n_params * max_batch)
    t_stream = spec.hbm_time(weight_bytes)
    return max(t_compute, t_stream)


def pick_sync_every(arch: str, max_batch: int, spec: hw.HardwareSpec,
                    candidates: Sequence[int], preempt: bool) -> int:
    """Largest chunk whose modeled throughput still gains >= 1% over the
    previous candidate.  Preemptive plans pin ``sync_every=1``: eviction
    happens at host syncs, so a victim would wait out the whole chunk
    (in-chunk preemption is a ROADMAP item, not a current mechanism)."""
    if preempt:
        return 1
    t_tick = modeled_tick_seconds(arch, max_batch, spec)
    cands = sorted(set(int(c) for c in candidates))
    best = cands[0]
    best_thr = 1.0 / (t_tick + HOST_SYNC_S / best)
    for c in cands[1:]:
        thr = 1.0 / (t_tick + HOST_SYNC_S / c)
        if thr < best_thr * (1.0 + SYNC_GAIN_MIN):
            break
        best, best_thr = c, thr
    return best


def _pad_bucket(n: int, limit: int) -> int:
    return min(limit, -(-n // MIN_BUCKET) * MIN_BUCKET)


def candidate_bucket_sets(prompt_lengths: Sequence[int], max_len: int
                          ) -> List[Optional[Tuple[int, ...]]]:
    """Bucket-set candidates: the historical pow2 default plus a quantile
    set fitted to the workload's observed prompt lengths (p50/p90/max,
    padded to MIN_BUCKET granularity, always ending at max_len-1)."""
    limit = max_len - 1
    out: List[Optional[Tuple[int, ...]]] = [None]
    if prompt_lengths:
        ls = sorted(prompt_lengths)
        qs = {ls[min(len(ls) - 1, math.ceil(q * len(ls)) - 1)]
              for q in (0.5, 0.9, 1.0)}
        fitted = tuple(sorted({_pad_bucket(q, limit) for q in qs} | {limit}))
        if fitted != default_buckets(max_len):
            out.append(fitted)
    return out


def bucket_set_cost(buckets: Optional[Tuple[int, ...]],
                    prompt_lengths: Sequence[int], max_len: int,
                    arch: str, spec: hw.HardwareSpec) -> float:
    """Modeled prefill seconds per admitted request: padded-token compute
    plus the XLA compile bill amortized over the workload's admissions."""
    bs = buckets if buckets is not None else default_buckets(max_len)
    limit = max_len - 1

    def pad(n: int) -> int:
        for b in bs:
            if b >= n:
                return b
        return bs[-1]

    n_params = _full_param_count(arch)
    t_tok = spec.matmul_time(2.0 * n_params)
    mean_padded = (sum(pad(min(n, limit)) for n in prompt_lengths)
                   / max(1, len(prompt_lengths)))
    return mean_padded * t_tok + COMPILE_S * len(bs) / max(
        1, len(prompt_lengths))


# ---------------------------------------------------------------------------
# Cache layout (dense vs. paged block pool)
# ---------------------------------------------------------------------------


def expected_tokens_per_slot(items, max_len: int) -> float:
    """Conservative resident-token estimate per occupied slot: the p95 of
    each request's full footprint (prompt + decode budget, capped at the
    cache length).  p95 rather than the mean because a paged pool is
    provisioned for the tokens actually in flight — undershooting the
    tail is what fragmentation-free layouts must *not* do."""
    if not items:
        return float(max_len)
    toks = sorted(min(max_len, len(it.prompt) + it.max_new_tokens)
                  for it in items)
    return float(toks[min(len(toks) - 1, math.ceil(0.95 * len(toks)) - 1)])


@functools.lru_cache(maxsize=None)
def cache_layout_bytes(arch: str, max_batch: int, max_len: int,
                       layout: str, tokens_per_slot: float) -> int:
    """Modeled resident cache bytes of the *full-size* config under a
    cache layout at the expected per-slot token load.  Dense commits the
    whole ``max_batch x max_len`` cache; paged commits per-slot state
    plus expected tokens rounded up to block granularity (see
    :func:`repro.serving.paged.paged_cache_bytes`) plus a per-page
    overhead charge (:data:`PAGE_OVERHEAD_TOKENS`) standing in for the
    block-table gather cost."""
    block = parse_cache_layout(layout)
    if block is None:
        return serving_memory_bytes(arch, max_batch, max_len)[1]
    from repro.serving.paged import paged_cache_bytes

    model = _full_model(arch)
    base = paged_cache_bytes(model, max_batch, max_len, block,
                             tokens_per_slot)
    n_pages = math.ceil(min(max_len, tokens_per_slot) / block)
    # ring bytes per covered token (per-slot recurrent state excluded:
    # paging it costs nothing, so a pool-less arch carries no overhead
    # and ties with dense)
    floor = paged_cache_bytes(model, max_batch, max_len, block, 0.0)
    one_page = paged_cache_bytes(model, max_batch, max_len, block,
                                 float(block))
    per_tok = (one_page - floor) // max(1, max_batch * block)
    overhead = int(PAGE_OVERHEAD_TOKENS * per_tok * n_pages * max_batch)
    return base + overhead


def candidate_cache_layouts(max_len: int,
                            block_sizes: Sequence[int]) -> List[str]:
    """Layout candidates: dense first (the tie-break winner), then one
    paged candidate per admissible block size."""
    return ["dense"] + [f"paged:{b}" for b in sorted(set(int(b)
                        for b in block_sizes)) if 1 <= b <= max_len]


# ---------------------------------------------------------------------------
# Per-kernel tile plans
# ---------------------------------------------------------------------------


def tile_plans_for(arch: str, max_batch: int, spec: hw.HardwareSpec,
                   max_len: int = 2048) -> Dict[str, Dict[str, object]]:
    """Embed a ``core.dse`` tile plan per layer kind, scored at the
    serving batch (the kernel-level half of the design point).

    Recurrent kinds run the paper's RNN-cell tile search (3-gate cell at
    the model width); a plan whose chosen tile keeps the weights VMEM-
    resident in a single tile is additionally marked ``persistent`` — the
    fused decode kernel then pins w_h/w_x in VMEM across the whole token
    loop.  Attention kinds (attn/local) get a bq/bk flash tile plan scored
    at the config's max sequence.  Every dict is the compact
    ``dse.plan_dict`` form so unset tile fields never reach the plan
    (keeps committed plans/BENCH rows byte-stable)."""
    from repro.core import dse
    from repro.core.cells import RNNCellConfig

    cfg = _full_model(arch).cfg
    out: Dict[str, Dict[str, object]] = {}
    for kind in sorted(set(cfg.layer_pattern)):
        if kind in _RECURRENT_KINDS:
            cell = RNNCellConfig("gru", hidden=cfg.d_model,
                                 features=cfg.d_model,
                                 batch=1, precision="bf16")
            best = dse.best_plan(cell, spec, max_batch=max_batch)
            entry = dse.plan_dict(best)
            if best.resident and best.n_tiles == 1:
                entry["persistent"] = True
            out[kind] = entry
        elif kind in ("attn", "local"):
            seq = max(int(max_len), dse.SUBLANE)
            window = cfg.local_window if kind == "local" else 0
            seq_kv = min(seq, window) if window else seq
            best = dse.best_attn_plan(seq, seq_kv, cfg.head_dim_, spec,
                                      n_heads=cfg.n_heads, batch=max_batch)
            out[kind] = dse.plan_dict(best)
    return out


# ---------------------------------------------------------------------------
# The probe + search
# ---------------------------------------------------------------------------


def _probe_metrics(plan: ServingPlan, model, params, sharder,
                   items, seed: int) -> Dict[str, object]:
    from repro.serving import metrics as smetrics
    from repro.serving.engine import ServingEngine
    from repro.serving.workload import drive

    engine = ServingEngine.from_plan(plan, params, model=model,
                                     sharder=sharder, seed=seed)
    reqs = drive(engine, items)
    return smetrics.aggregate(reqs, ticks=engine.ticks,
                              util_history=engine.util_history)


def _score(agg: Dict[str, object]) -> Tuple[float, float, float, float]:
    """Rank key, larger is better: (SLO attainment, -p95 TTFT,
    -p95 queue-wait, tokens/tick).  NaN percentiles (nothing completed)
    rank worst."""

    def neg(x: float) -> float:
        return -1e18 if (x is None or math.isnan(x)) else -float(x)

    slo = agg.get("slo", {}).get("attainment", 0.0)
    return (float(slo), neg(agg["ttft"]["p95"]),
            neg(agg["queue_wait"]["p95"]), float(agg["tokens_per_sec"]))


def autotune(arch: str, workload: WorkloadProfile,
             hw_spec: hw.HardwareSpec = hw.DEFAULT, *,
             seed: int = 0, reduced: bool = True, max_len: int = 64,
             max_batches: Sequence[int] = (2, 4, 8),
             sync_everys: Sequence[int] = (1, 2, 4, 8),
             block_sizes: Sequence[int] = (8, 16, 32),
             probe_duration: float = 32.0) -> ServingPlan:
    """Search the serving design space for one (arch, workload) cell.

    Returns the winning validated :class:`ServingPlan` with the search
    recorded under ``provenance["autotune"]``.  Deterministic for a fixed
    (hw_spec, seed): same inputs, same plan.

    The cache layout (dense vs. ``paged:<block_size>``) is chosen *after*
    the scheduling probe: virtual-clock schedules are layout-invariant by
    construction (the paged manager is bit-exact behind the SlotManager
    seam), so the probe plane does not grow — only the HBM feasibility
    check and the final bytes-resident comparison see the layouts.  A
    slot count is feasible when *any* candidate layout fits, which is how
    paging raises admission capacity under heavy-tail workloads: the
    expected tokens in flight, not ``max_batch x max_len``, is what has
    to fit."""
    import jax

    from repro.configs import get_config
    from repro.dist.sharding import make_sharder
    from repro.models.lm import build_model
    from repro.serving.workload import profile_items
    from repro.testing import reduced_config

    cfg = reduced_config(arch) if reduced else get_config(arch)
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    sharder = make_sharder(cfg, None, "decode")

    span = workload.duration if workload.duration is not None \
        else probe_duration
    # probe on a capped span: replace the profile's own duration, because
    # profile_items prefers it over the duration argument
    probe_span = min(span, probe_duration)
    probe_wl = dataclasses.replace(workload, duration=probe_span)
    items = profile_items(probe_wl, vocab_size=cfg.vocab_size, seed=seed)
    deadlines = any(it.deadline is not None for it in items)

    # --- candidate slot counts: HBM feasibility on the full-size config.
    # A slot count qualifies when its cheapest candidate layout fits, so
    # paged layouts can admit batch sizes the dense cache could not.
    budget = hw_spec.hbm_bytes * HBM_FRACTION
    layouts = candidate_cache_layouts(max_len, block_sizes)
    t_slot = expected_tokens_per_slot(items, max_len)
    feasible, overcommitted = [], False
    for mb in sorted(set(int(b) for b in max_batches)):
        weights, _ = serving_memory_bytes(arch, mb, max_len)
        cheapest = min(cache_layout_bytes(arch, mb, max_len, lay, t_slot)
                       for lay in layouts)
        if weights + cheapest <= budget:
            feasible.append(mb)
    if not feasible:   # weights alone exceed one chip: rank anyway, flag it
        overcommitted = True
        feasible = sorted(set(int(b) for b in max_batches))

    policies = ([("fcfs", False), ("edf", False), ("edf", True)]
                if deadlines else [("fcfs", False), ("spf", False)])

    # --- probe: queueing behavior per (max_batch, policy) on the virtual
    # clock (sync_every / buckets do not move virtual-clock schedules, so
    # one probe per scheduling candidate covers the whole plane)
    best_key, best, probed = None, None, []
    for mb in feasible:
        for policy, preempt in policies:
            cand = ServingPlan(arch=arch, reduced=reduced, max_len=max_len,
                               max_batch=mb, policy=policy, preempt=preempt)
            agg = _probe_metrics(cand, model, params, sharder, items, seed)
            key = _score(agg)
            probed.append({"max_batch": mb, "policy": policy,
                           "preempt": preempt, "score": list(key)})
            log.debug("probe b%d %s%s -> %s", mb, policy,
                      "+p" if preempt else "", key)
            if best_key is None or key > best_key:
                best_key, best = key, cand

    # --- cost-model dimensions the virtual clock cannot see
    sync = pick_sync_every(arch, best.max_batch, hw_spec, sync_everys,
                           best.preempt)
    lengths = [len(it.prompt) for it in items]
    bsets = candidate_bucket_sets(lengths, max_len)
    costs = [bucket_set_cost(bs, lengths, max_len, arch, hw_spec)
             for bs in bsets]
    buckets = bsets[int(np.argmin(costs))]

    # --- cache layout: schedules are layout-invariant, so pick by modeled
    # resident bytes at the winning slot count; dense is enumerated first
    # and wins ties, so paging has to actually save memory to be chosen
    layout_bytes = [(lay, cache_layout_bytes(arch, best.max_batch, max_len,
                                             lay, t_slot))
                    for lay in layouts]
    cache_layout = min(layout_bytes, key=lambda kv: kv[1])[0]

    plan = dataclasses.replace(
        best, sync_every=sync, buckets=buckets, cache_layout=cache_layout,
        tile_plans=tile_plans_for(arch, best.max_batch, hw_spec,
                                  max_len=max_len),
        provenance={"autotune": {
            "hw": hw_spec.name, "seed": seed,
            "probe_duration": probe_span,
            "workload": workload.to_json(),
            "memory_overcommitted": overcommitted,
            "probes": probed,
            "best_score": list(best_key),
            "expected_tokens_per_slot": t_slot,
            "cache_layouts": [
                {"layout": lay, "modeled_bytes": b}
                for lay, b in layout_bytes],
            "bucket_costs": [
                {"buckets": None if b is None else list(b), "cost_s": c}
                for b, c in zip(bsets, costs)],
        }})
    return plan.validate()


# ---------------------------------------------------------------------------
# fleet-level search
# ---------------------------------------------------------------------------

BENCH_COLLECTIVES = "BENCH_collectives.json"
# the dry-run grid records these serve shapes; keys into the trajectory
_PREFILL_SHAPE = "prefill_32k"
_DECODE_SHAPE = "decode_32k"


def load_collectives(path: str = BENCH_COLLECTIVES
                     ) -> Dict[Tuple[str, str], Dict[str, object]]:
    """Read the committed collective-volume trajectory
    (``benchmarks/collectives.py`` → ``BENCH_collectives.json``):
    ``{(arch, shape): collectives-summary}`` with the summary carrying
    ``n_ops`` / ``operand_bytes`` / ``ici_bytes`` / ``by_kind`` exactly as
    ``repro.launch.hlo.collective_summary`` emits them.  Returns ``{}``
    when the file is absent — the planner then falls back to defaults
    and records that no evidence was consulted."""
    import json
    import os

    if not os.path.exists(path):
        return {}
    with open(path) as f:
        doc = json.load(f)
    out: Dict[Tuple[str, str], Dict[str, object]] = {}
    for cell in doc.get("cells", []):
        out[(str(cell["arch"]), str(cell["shape"]))] = cell["collectives"]
    return out


def fleet_shard_modes(arch: str, n_replicas: int, n_prefill: int,
                      collectives: Dict[Tuple[str, str], Dict[str, object]]
                      ) -> Tuple[List[str], Dict[str, object]]:
    """Per-replica ``shard_mode`` choices scored against the recorded
    collective volumes.  Decode (and colocated) replicas keep the serving
    default ``"decode"``; a dedicated prefill replica switches to
    ``"prefill"`` sharding only when the trajectory actually recorded the
    arch's prefill-shape program (evidence the sharded compile exists and
    what it moves over ICI) — with no evidence the planner refuses to
    guess and leaves the default.  Returns the mode list plus a
    provenance record of exactly what was consulted."""
    dec = collectives.get((arch, _DECODE_SHAPE))
    pre = collectives.get((arch, _PREFILL_SHAPE))
    record: Dict[str, object] = {
        "source": BENCH_COLLECTIVES,
        "decode_ici_bytes": None if dec is None else dec.get("ici_bytes"),
        "prefill_ici_bytes": None if pre is None else pre.get("ici_bytes"),
        "consulted": dec is not None or pre is not None,
    }
    modes = []
    for i in range(n_replicas):
        if i < n_prefill and pre is not None:
            modes.append("prefill")
        else:
            modes.append("decode")
    record["modes"] = list(modes)
    return modes, record


def autotune_fleet(arch: str, workload: WorkloadProfile,
                   hw_spec: hw.HardwareSpec = hw.DEFAULT, *,
                   seed: int = 0, reduced: bool = True, max_len: int = 64,
                   replica_counts: Sequence[int] = (1, 2, 4),
                   routings: Sequence[str] = ("round_robin", "least_queue"),
                   prefill_splits: Sequence[int] = (0, 1),
                   base_plan: Optional[ServingPlan] = None,
                   probe_duration: float = 32.0,
                   collectives: Optional[Dict] = None,
                   collectives_path: str = BENCH_COLLECTIVES) -> "FleetPlan":
    """Coarse fleet-level design-space search: replica count × routing
    policy × prefill:decode split, each candidate ranked by a seeded
    fleet probe (``drive_fleet`` on the capped workload, scored by the
    same (SLO, p95 TTFT, p95 queue-wait, tokens/tick) key as the
    per-engine :func:`autotune`, ties toward the smaller fleet).  The
    replica design point itself is not re-searched here — pass
    ``base_plan`` (e.g. an :func:`autotune` winner) to fleet-ify a tuned
    replica; the default replica is the plan's defaults at this arch.

    Per-replica ``shard_mode`` is then scored against the committed
    collective-volume trajectory (:func:`load_collectives` — the
    ``BENCH_collectives.json`` file the tier2 dry-run grid maintains):
    dedicated prefill replicas get ``"prefill"`` sharding when the
    trajectory holds evidence for this arch, everything else keeps
    ``"decode"``.  What was consulted is recorded under
    ``provenance["autotune_fleet"]["collectives"]``.

    Deterministic for fixed (hw_spec, seed): seeded probes on the virtual
    clock, fixed enumeration order, ties to the earlier candidate."""
    from repro.plan.plan import FleetPlan
    from repro.serving.router import Router, drive_fleet
    from repro.serving.workload import profile_items
    from repro.testing import reduced_config

    from repro.configs import get_config

    base = base_plan if base_plan is not None else ServingPlan(
        arch=arch, reduced=reduced, max_len=max_len)
    base.validate()

    cfg = reduced_config(arch) if reduced else get_config(arch)
    span = workload.duration if workload.duration is not None \
        else probe_duration
    probe_wl = dataclasses.replace(workload,
                                   duration=min(span, probe_duration))
    items = profile_items(probe_wl, vocab_size=cfg.vocab_size, seed=seed)

    built: Dict = {}
    best_key, best_cand, probed = None, None, []
    for n in sorted(set(int(n) for n in replica_counts)):
        for routing in routings:
            for split in sorted(set(int(s) for s in prefill_splits)):
                if not 0 <= split < n:
                    continue
                cand = FleetPlan(replicas=(base,) * n, routing=routing,
                                 n_prefill=split, hw=hw_spec.name)
                router = Router.from_plan(cand, seed=seed, _built=built)
                drive_fleet(router, items)
                agg = router.fleet_aggregate()
                key = (_score(agg), -n)
                probed.append({"replicas": n, "routing": routing,
                               "n_prefill": split, "score": list(key[0]),
                               "completed": agg["completed"]})
                log.debug("fleet probe n%d %s split%d -> %s", n, routing,
                          split, key)
                if best_key is None or key > best_key:
                    best_key, best_cand = key, cand

    coll = collectives if collectives is not None \
        else load_collectives(collectives_path)
    modes, coll_record = fleet_shard_modes(
        arch, best_cand.n_replicas, best_cand.n_prefill, coll)
    replicas = tuple(
        dataclasses.replace(p, shard_mode=mode)
        for p, mode in zip(best_cand.replicas, modes))
    fleet = dataclasses.replace(
        best_cand, replicas=replicas,
        provenance={"autotune_fleet": {
            "hw": hw_spec.name, "seed": seed,
            "probe_duration": probe_wl.duration,
            "workload": workload.to_json(),
            "probes": probed,
            "best_score": list(best_key[0]),
            "collectives": coll_record,
        }})
    return fleet.validate()


def autotune_from_trace(arch: str, trace,
                        hw_spec: hw.HardwareSpec = hw.DEFAULT, *,
                        duration: Optional[float] = None,
                        **kwargs) -> ServingPlan:
    """Re-autotune from *observed* traffic: fit a
    :class:`WorkloadProfile` from a recorded :class:`repro.obs.Tracer`
    trace (live object, Chrome-trace document, or file path) and search
    the design space against it.  This is the drift-recovery loop — when
    traffic no longer matches the profile a deployed plan was tuned on,
    replan from what actually arrived instead of the stale declaration.

    Accepts every :func:`autotune` keyword; the fit's inputs and result
    are recorded under ``provenance["observed_traffic"]`` alongside the
    usual ``provenance["autotune"]`` search record.
    """
    from repro.obs.observe import fit_profile, summarize

    profile = fit_profile(trace, duration=duration)
    plan = autotune(arch, profile, hw_spec, **kwargs)
    prov = dict(plan.provenance)
    prov["observed_traffic"] = {
        "fitted_profile": profile.to_json(),
        "trace_summary": summarize(trace),
    }
    return dataclasses.replace(plan, provenance=prov)


__all__ = ["autotune", "autotune_fleet", "autotune_from_trace",
           "load_collectives", "fleet_shard_modes", "BENCH_COLLECTIVES",
           "serving_memory_bytes",
           "modeled_tick_seconds", "pick_sync_every",
           "candidate_bucket_sets", "bucket_set_cost",
           "cache_layout_bytes", "candidate_cache_layouts",
           "expected_tokens_per_slot",
           "tile_plans_for", "HOST_SYNC_S", "COMPILE_S",
           "PAGE_OVERHEAD_TOKENS"]
