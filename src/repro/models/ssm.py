"""SSD-form selective state-space block (hymba's mamba heads).

Hardware adaptation (DESIGN.md §Hardware-adaptation): Mamba1's per-(channel,
state) decay matrix A[d, n] admits no TPU-friendly parallel form without
materializing a (T, d_inner, d_state) tensor.  We use the Mamba2/SSD
restriction — scalar decay per head, state (head_dim x d_state) — which
reduces exactly to scalar-decay chunked linear attention with
q = C_t, k = B_t, v = dt_t * x_t.  hymba's ssm_state=16 is preserved.
"""

from __future__ import annotations

from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models.layers import dot, groupnorm_heads
from repro.models.params import ParamSpec
from repro.models.recurrence import chunked_linear_attention, linear_attention_step

F32 = jnp.float32


def _d_inner(cfg: ModelConfig) -> int:
    return cfg.d_model * cfg.ssm.expand


def _n_ssm_heads(cfg: ModelConfig) -> int:
    return _d_inner(cfg) // cfg.ssm.head_dim


def ssm_specs(cfg: ModelConfig) -> Dict[str, ParamSpec]:
    d, s = cfg.d_model, cfg.ssm
    di, nh = _d_inner(cfg), _n_ssm_heads(cfg)
    return {
        "w_in": ParamSpec((d, 2 * di), jnp.float32, ("embed", "ssm_inner")),
        "conv_kernel": ParamSpec((s.conv_width, di), jnp.float32,
                                 (None, "ssm_inner"), scale=0.5),
        "conv_bias": ParamSpec((di,), jnp.float32, ("ssm_inner",), init="zeros"),
        "w_bc": ParamSpec((di, 2 * s.d_state), jnp.float32, ("ssm_inner", None)),
        "w_dt": ParamSpec((d, nh), jnp.float32, ("embed", None)),
        "dt_bias": ParamSpec((nh,), jnp.float32, (None,), init="custom",
                             custom_init=_dt_bias_init),
        "a_log": ParamSpec((nh,), jnp.float32, (None,), init="custom",
                           custom_init=_a_log_init),
        "d_skip": ParamSpec((nh,), jnp.float32, (None,), init="ones"),
        "ssm_norm": ParamSpec((di,), jnp.float32, ("ssm_inner",), init="zeros"),
        "w_out": ParamSpec((di, d), jnp.float32, ("ssm_inner", "embed")),
    }


def _dt_bias_init(key, spec):
    # softplus^-1 of dt in [1e-3, 1e-1], log-spaced (mamba init)
    n = spec.shape[0]
    dt = jnp.exp(jnp.linspace(jnp.log(1e-3), jnp.log(1e-1), n))
    return jnp.log(jnp.expm1(dt)).astype(spec.dtype)


def _a_log_init(key, spec):
    n = spec.shape[0]
    return jnp.log(jnp.linspace(1.0, 16.0, n)).astype(spec.dtype)


def _causal_depthwise_conv(x: jax.Array, kernel: jax.Array, bias: jax.Array,
                           tail: Optional[jax.Array]) -> jax.Array:
    """Depthwise causal conv along time via shifted adds (no conv primitive).

    x: (B, T, di); kernel: (W, di); tail: (B, W-1, di) previous inputs."""
    W = kernel.shape[0]
    pad = (jnp.zeros((x.shape[0], W - 1, x.shape[2]), x.dtype)
           if tail is None else tail.astype(x.dtype))
    xp = jnp.concatenate([pad, x], axis=1)                # (B, T+W-1, di)
    out = jnp.zeros_like(x)
    T = x.shape[1]
    for w in range(W):
        out = out + xp[:, w:w + T, :] * kernel[w].astype(x.dtype)
    return out + bias.astype(x.dtype)


def _ssm_inputs(params, x: jax.Array, cfg: ModelConfig, conv_tail,
                lengths: Optional[jax.Array] = None):
    """Shared train/decode input computation.

    Returns (q, k, v, log_decay, x_heads, z, new_conv_tail).

    ``lengths`` (B,) marks true per-example lengths in a right-padded
    prefill batch; the conv tail is then gathered at the last valid
    positions (zeros before t=0, matching the causal-conv zero padding).
    Only supported for fresh prefills (conv_tail None)."""
    s = cfg.ssm
    di, nh = _d_inner(cfg), _n_ssm_heads(cfg)
    B, T, _ = x.shape
    xz = dot(x, params["w_in"])
    xi, z = jnp.split(xz, 2, axis=-1)
    xc = _causal_depthwise_conv(xi, params["conv_kernel"], params["conv_bias"],
                                conv_tail)
    if lengths is not None:
        w1 = s.conv_width - 1
        src = (lengths[:, None] - w1
               + jnp.arange(w1, dtype=jnp.int32)[None, :])       # (B, W-1)
        tail = jnp.take_along_axis(xi, jnp.maximum(src, 0)[:, :, None],
                                   axis=1)
        new_tail = jnp.where((src >= 0)[:, :, None], tail, 0.0)
    else:
        new_tail = (jnp.concatenate([conv_tail.astype(x.dtype), xi], axis=1)
                    [:, -(s.conv_width - 1):, :]
                    if conv_tail is not None
                    else xi[:, -(s.conv_width - 1):, :])
    xc = jax.nn.silu(xc)
    bc = dot(xc, params["w_bc"]).astype(F32)
    b_t, c_t = jnp.split(bc, 2, axis=-1)                  # (B,T,N) each
    dt = jax.nn.softplus(
        jax.lax.dot_general(x.astype(F32), params["w_dt"].astype(F32),
                            (((2,), (0,)), ((), ()))) +
        params["dt_bias"].astype(F32))                     # (B,T,nh)
    log_decay = -jnp.exp(params["a_log"].astype(F32)) * dt  # (B,T,nh) <= 0
    xh = xc.reshape(B, T, nh, s.head_dim)
    v = xh.astype(F32) * dt[..., None]                     # (B,T,nh,hd)
    # broadcast shared B/C across heads: (B, nh, T, N)
    q = jnp.repeat(c_t[:, None], nh, axis=1)              # (B,nh,T,N)
    k = jnp.repeat(b_t[:, None], nh, axis=1)
    vv = v.transpose(0, 2, 1, 3)                           # (B,nh,T,hd)
    ld = log_decay.transpose(0, 2, 1)[..., None]           # (B,nh,T,1)
    return q, k, vv, ld, xh, z, new_tail


def _finish(params, y: jax.Array, xh: jax.Array, z: jax.Array,
            cfg: ModelConfig) -> jax.Array:
    """y: (B,nh,T,hd) -> gated, normed, projected out (B,T,d)."""
    B, nh, T, hd = y.shape
    y = y + params["d_skip"].astype(F32)[None, :, None, None] * \
        xh.transpose(0, 2, 1, 3).astype(F32)
    y = y.transpose(0, 2, 1, 3).reshape(B, T, nh * hd)
    y = groupnorm_heads(y.astype(z.dtype), params["ssm_norm"], nh, cfg.norm_eps)
    y = y * jax.nn.silu(z)
    return dot(y, params["w_out"])


def ssm_mixer(params, x: jax.Array, cfg: ModelConfig, sharder, *,
              mode: str, cache: Optional[Dict] = None,
              lengths: Optional[jax.Array] = None):
    """SSD mixer.  x: (B, T, d).  Returns (out (B,T,d), new_cache).

    ``lengths`` masks padded steps of a right-padded prefill batch: padded
    steps get (decay 1, k 0) so the ssd_state carries through unchanged."""
    s = cfg.ssm
    if mode == "decode":
        conv_tail, state = cache["conv_state"], cache["ssd_state"]
        q, k, v, ld, xh, z, new_tail = _ssm_inputs(params, x, cfg, conv_tail)
        y, new_state = linear_attention_step(
            state, q[:, :, 0], k[:, :, 0], v[:, :, 0], ld[:, :, 0],
            convention="inclusive")
        y = y[:, :, None, :]                               # (B,nh,1,hd)
        out = _finish(params, y, xh, z, cfg)
        return out, {"conv_state": new_tail, "ssd_state": new_state.astype(F32)}

    conv_tail = cache["conv_state"] if cache else None
    state = cache["ssd_state"] if cache else None
    q, k, v, ld, xh, z, new_tail = _ssm_inputs(params, x, cfg, conv_tail,
                                               lengths=lengths)
    if lengths is not None:
        valid = (jnp.arange(x.shape[1], dtype=jnp.int32)[None, None, :, None]
                 < lengths[:, None, None, None])                 # (B,1,T,1)
        k = jnp.where(valid, k, 0.0)
        ld = jnp.where(valid, ld, 0.0)
    y, new_state = chunked_linear_attention(
        q, k, v, ld, chunk=min(s.chunk, x.shape[1]),
        convention="inclusive", initial_state=state)
    out = _finish(params, y, xh, z, cfg)
    new_cache = {"conv_state": new_tail, "ssd_state": new_state.astype(F32)}
    return out, new_cache
