"""Attention: projections, blockwise flash attention, decode attention.

Design notes (see DESIGN.md §Attention):

* **Blockwise flash, loop-free.**  Train/prefill attention is computed with
  an online-softmax over KV blocks using a *python-unrolled* block loop —
  no ``lax.scan`` — for two reasons: XLA's ``cost_analysis`` counts a while
  body only once (which would wreck the roofline accounting), and the
  unrolled chain lets XLA reuse one block-sized buffer instead of ever
  materializing the (S, S) score matrix.  On real TPUs the Pallas kernel in
  ``repro.kernels.flash_attention`` replaces this path.

* **GQA grouped form.**  q is viewed as (B, K, G, S, d) over K kv-heads and
  G = H/K query groups.  In "heads" sharding mode the kv heads are first
  repeated to H (K=H, G=1) so the head dim shards over the model axis; in
  "qseq" mode the grouped form avoids materializing repeated KV and the
  query *sequence* dim shards instead.  One code path serves both; the
  logical-axis rules make the same ``constrain`` calls resolve differently.

* **Decode.**  One query token against a cache whose sequence dim shards
  over the model axis (flash-decode style): the softmax over the sharded
  dim lowers to partial max/sum + all-reduce, and the A·V contraction to a
  partial-sum all-reduce.  This works for every head count, so decode needs
  no head-divisibility at all.
"""

from __future__ import annotations

import math
from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models.layers import apply_m_rope, apply_rope, dot, groupnorm_heads
from repro.models.params import ParamSpec

F32 = jnp.float32
NEG_INF = -1e30

# Maximum number of unrolled KV blocks; the block size grows with sequence
# length so the unrolled HLO stays bounded.
MAX_KV_BLOCKS = 8
MIN_KV_BLOCK = 512


def kv_block_size(skv: int) -> int:
    block = max(MIN_KV_BLOCK, -(-skv // MAX_KV_BLOCKS))
    return -(-block // 128) * 128  # multiple of the MXU edge


# ---------------------------------------------------------------------------
# Parameter specs
# ---------------------------------------------------------------------------


def attention_specs(cfg: ModelConfig, prefix: str = "") -> Dict[str, ParamSpec]:
    d, qd, kvd = cfg.d_model, cfg.q_dim, cfg.kv_dim
    specs = {
        "wq": ParamSpec((d, qd), jnp.float32, ("embed", "q_flat")),
        "wk": ParamSpec((d, kvd), jnp.float32, ("embed", "kv_flat")),
        "wv": ParamSpec((d, kvd), jnp.float32, ("embed", "kv_flat")),
        "wo": ParamSpec((qd, d), jnp.float32, ("q_flat", "embed")),
    }
    if cfg.qkv_bias:
        specs["bq"] = ParamSpec((qd,), jnp.float32, ("q_flat",), init="zeros")
        specs["bk"] = ParamSpec((kvd,), jnp.float32, ("kv_flat",), init="zeros")
        specs["bv"] = ParamSpec((kvd,), jnp.float32, ("kv_flat",), init="zeros")
    if cfg.qk_norm:
        specs["q_norm"] = ParamSpec((cfg.head_dim_,), jnp.float32, (None,),
                                    init="zeros")
        specs["k_norm"] = ParamSpec((cfg.head_dim_,), jnp.float32, (None,),
                                    init="zeros")
    return specs


# ---------------------------------------------------------------------------
# Projections
# ---------------------------------------------------------------------------


def _headnorm(x: jax.Array, scale: jax.Array, eps: float) -> jax.Array:
    dtype = x.dtype
    x = x.astype(F32)
    var = jnp.mean(jnp.square(x), axis=-1, keepdims=True)
    return (x * jax.lax.rsqrt(var + eps) * (1.0 + scale.astype(F32))).astype(dtype)


def project_qkv(params, x: jax.Array, cfg: ModelConfig, sharder,
                positions: jax.Array, rope: bool = True
                ) -> Tuple[jax.Array, jax.Array, jax.Array]:
    """x: (B, S, d) -> q (B, S, H, hd), k/v (B, S, K, hd), rope applied."""
    B, S, _ = x.shape
    hd, H, K = cfg.head_dim_, cfg.n_heads, cfg.n_kv_heads
    q = dot(x, params["wq"])
    k = dot(x, params["wk"])
    v = dot(x, params["wv"])
    if cfg.qkv_bias:
        q = q + params["bq"].astype(x.dtype)
        k = k + params["bk"].astype(x.dtype)
        v = v + params["bv"].astype(x.dtype)
    q = q.reshape(B, S, H, hd)
    k = k.reshape(B, S, K, hd)
    v = v.reshape(B, S, K, hd)
    if cfg.qk_norm:
        q = _headnorm(q, params["q_norm"], cfg.norm_eps)
        k = _headnorm(k, params["k_norm"], cfg.norm_eps)
    if rope:
        if cfg.m_rope_sections and positions.ndim == 3:
            q = apply_m_rope(q, positions, cfg.rope_theta, cfg.m_rope_sections)
            k = apply_m_rope(k, positions, cfg.rope_theta, cfg.m_rope_sections)
        else:
            pos2d = positions if positions.ndim == 2 else positions[:, 0]
            q = apply_rope(q, pos2d, cfg.rope_theta)
            k = apply_rope(k, pos2d, cfg.rope_theta)
    q = sharder.constrain(q, "batch", "qseq", "heads", None)
    # kv is gathered whole-sequence here (one gather per layer under
    # sequence parallelism; free otherwise) for the blockwise flash loop
    k = sharder.constrain(k, "batch", "kv_full_seq", "kv_heads", None)
    v = sharder.constrain(v, "batch", "kv_full_seq", "kv_heads", None)
    return q, k, v


# ---------------------------------------------------------------------------
# Blockwise flash attention (train / prefill)
# ---------------------------------------------------------------------------


def flash_attention(q: jax.Array, k: jax.Array, v: jax.Array,
                    q_pos: jax.Array, kv_pos: jax.Array, *,
                    cfg: ModelConfig, sharder, causal: bool = True,
                    window: int = 0, block: int = 0,
                    tile_plan=None) -> jax.Array:
    """Online-softmax attention over unrolled KV blocks.

    q: (B, Sq, H, hd); k, v: (B, Skv, K, hd); positions are (B, S) int32.
    Returns (B, Sq, H, hd).  An active ``tile_plan`` routes to the Pallas
    flash kernel with the plan's bq/bk BlockSpec geometry (single-device
    path; the jnp fallback below handles sharded execution).
    """
    from repro.kernels.dispatch import pallas_active

    if pallas_active(tile_plan):
        from repro.kernels.flash_attention import ops as flash_ops

        out = flash_ops.attention(
            q, k, v, causal=causal, window=window,
            softcap=cfg.attn_softcap, q_pos=q_pos, kv_pos=kv_pos,
            plan=tile_plan)
        return sharder.constrain(
            out, "batch", "qseq", "heads", None).astype(q.dtype)
    B, Sq, H, hd = q.shape
    Skv, K = k.shape[1], k.shape[2]
    scale = 1.0 / math.sqrt(hd)
    softcap = cfg.attn_softcap

    heads_mode = cfg.attention_sharding != "qseq"
    if heads_mode and K != H:
        # repeat kv to full heads so the head dim shards over the model axis
        rep = H // K
        k = jnp.repeat(k, rep, axis=2)
        v = jnp.repeat(v, rep, axis=2)
        K = H
    G = H // K

    # grouped views: q (B, K, G, Sq, hd); kv (B, K, Skv, hd)
    qg = q.reshape(B, Sq, K, G, hd).transpose(0, 2, 3, 1, 4)
    qg = sharder.constrain(qg, "batch", "heads", None, "qseq", None)
    kg = k.transpose(0, 2, 1, 3)   # (B, K, Skv, hd)
    vg = v.transpose(0, 2, 1, 3)

    block = block or (cfg.attn_block or kv_block_size(Skv))
    n_blocks = -(-Skv // block)

    m = jnp.full((B, K, G, Sq), NEG_INF, F32)
    l = jnp.zeros((B, K, G, Sq), F32)
    acc = jnp.zeros((B, K, G, Sq, hd), F32)
    qf = qg.astype(jnp.bfloat16)

    for i in range(n_blocks):
        s0, s1 = i * block, min((i + 1) * block, Skv)
        kb = jax.lax.slice_in_dim(kg, s0, s1, axis=2).astype(jnp.bfloat16)
        vb = jax.lax.slice_in_dim(vg, s0, s1, axis=2).astype(jnp.bfloat16)
        pb = jax.lax.slice_in_dim(kv_pos, s0, s1, axis=1)      # (B, bk)

        logits = jnp.einsum("bkgqd,bksd->bkgqs", qf, kb,
                            preferred_element_type=F32) * scale
        if softcap > 0.0:
            logits = softcap * jnp.tanh(logits / softcap)
        mask = jnp.ones((B, 1, 1, Sq, s1 - s0), bool)
        if causal:
            mask &= (pb[:, None, None, None, :] <=
                     q_pos[:, None, None, :, None])
        if window > 0:
            mask &= (q_pos[:, None, None, :, None] -
                     pb[:, None, None, None, :]) < window
        mask &= (pb >= 0)[:, None, None, None, :]              # cache validity
        logits = jnp.where(mask, logits, NEG_INF)
        logits = sharder.constrain(
            logits, "batch", "heads", None, "qseq", None)

        m_new = jnp.maximum(m, logits.max(axis=-1))
        alpha = jnp.exp(m - m_new)
        p = jnp.exp(logits - m_new[..., None])
        l = l * alpha + p.sum(axis=-1)
        acc = acc * alpha[..., None] + jnp.einsum(
            "bkgqs,bksd->bkgqd", p.astype(jnp.bfloat16), vb,
            preferred_element_type=F32)
        m = m_new

    out = acc / jnp.maximum(l[..., None], 1e-30)
    out = out.transpose(0, 3, 1, 2, 4).reshape(B, Sq, H, hd)
    return sharder.constrain(out, "batch", "qseq", "heads", None).astype(q.dtype)


# ---------------------------------------------------------------------------
# Decode attention (one token against a cache)
# ---------------------------------------------------------------------------


def decode_attention(q: jax.Array, k_cache: jax.Array, v_cache: jax.Array,
                     kv_pos: jax.Array, q_pos: jax.Array, *,
                     cfg: ModelConfig, sharder, causal: bool = True,
                     window: int = 0, tile_plan=None) -> jax.Array:
    """q: (B, H, hd); caches: (B, S, K, hd); kv_pos: (B, S) absolute
    positions (-1 = empty slot); q_pos: (B,).  Returns (B, H, hd).
    An active ``tile_plan`` routes to the split-KV flash-decoding kernel
    with the plan's bk chunk size."""
    from repro.kernels.dispatch import pallas_active

    if pallas_active(tile_plan):
        from repro.kernels.flash_attention import ops as flash_ops

        return flash_ops.decode(
            q, k_cache, v_cache, kv_pos, q_pos, causal=causal,
            window=window, softcap=cfg.attn_softcap, plan=tile_plan)
    B, H, hd = q.shape
    S, K = k_cache.shape[1], k_cache.shape[2]
    G = H // K
    scale = 1.0 / math.sqrt(hd)

    qg = q.reshape(B, K, G, hd).astype(jnp.bfloat16)
    kc = k_cache.astype(jnp.bfloat16)
    logits = jnp.einsum("bkgd,bskd->bkgs", qg, kc,
                        preferred_element_type=F32) * scale
    if cfg.attn_softcap > 0.0:
        c = cfg.attn_softcap
        logits = c * jnp.tanh(logits / c)

    mask = kv_pos >= 0
    if causal:
        mask &= kv_pos <= q_pos[:, None]
    if window > 0:
        mask &= (q_pos[:, None] - kv_pos) < window
    logits = jnp.where(mask[:, None, None, :], logits, NEG_INF)
    logits = sharder.constrain(logits, "batch", "kv_heads", None, "cache_seq")

    # softmax over the (possibly model-axis-sharded) cache dim
    m = logits.max(axis=-1, keepdims=True)
    p = jnp.exp(logits - m)
    p = p / jnp.maximum(p.sum(axis=-1, keepdims=True), 1e-30)
    out = jnp.einsum("bkgs,bskd->bkgd", p.astype(jnp.bfloat16),
                     v_cache.astype(jnp.bfloat16),
                     preferred_element_type=F32)
    return out.reshape(B, H, hd).astype(jnp.bfloat16)


# ---------------------------------------------------------------------------
# KV-cache helpers
# ---------------------------------------------------------------------------


def cache_slot_count(cfg: ModelConfig, kind: str, max_len: int) -> int:
    if kind == "local" or (kind == "swa_ssm" and cfg.local_window):
        return min(cfg.local_window, max_len)
    return max_len


def update_cache(k_cache, v_cache, kv_pos, k_new, v_new, lengths, *,
                 n_slots: int, ring: bool):
    """Insert one token per sequence.  k_new/v_new: (B, K, hd);
    lengths: (B,) current lengths (the new token's absolute position)."""
    B = k_new.shape[0]
    idx = lengths % n_slots if ring else lengths
    b = jnp.arange(B)
    k_cache = k_cache.at[b, idx].set(k_new.astype(k_cache.dtype))
    v_cache = v_cache.at[b, idx].set(v_new.astype(v_cache.dtype))
    kv_pos = kv_pos.at[b, idx].set(lengths.astype(kv_pos.dtype))
    return k_cache, v_cache, kv_pos


def fill_cache_from_prefill(k, v, positions, n_slots: int):
    """Build (cache, cache_pos) from prefill-computed k/v (B, S, K, hd).

    ``positions`` (B, S) carries each token's absolute position, -1 for
    padding (right-padded bucketed prefill), so examples in one batch may
    have different true lengths.  Per example, the last ``n_slots`` *valid*
    tokens are kept at their ring slots (slot = pos % n_slots); unfilled
    slots get pos -1 — decode attention masks them, and the decode-side
    cache update (`update_cache` semantics, slot = lengths % n_slots)
    overwrites them in the same layout.
    """
    B, S, K, hd = k.shape
    lengths = jnp.sum((positions >= 0).astype(jnp.int32), axis=1)  # (B,)
    s = jnp.arange(n_slots, dtype=jnp.int32)[None, :]              # (1, n)
    last = lengths[:, None] - 1                                    # (B, 1)
    # token position landing in slot s: the largest valid p with
    # p % n_slots == s (>= lengths - n_slots by construction of the mod)
    p = last - ((last - s) % n_slots)                              # (B, n)
    idx = jnp.maximum(p, 0)[:, :, None, None]
    kc = jnp.take_along_axis(k, idx, axis=1)
    vc = jnp.take_along_axis(v, idx, axis=1)
    pos = jnp.where(p >= 0, p, -1).astype(jnp.int32)
    return kc, vc, pos
