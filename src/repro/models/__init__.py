"""Model zoo package.  ``LM`` / ``build_model`` are re-exported lazily to
keep ``repro.models.params`` importable from the sharding layer without a
circular import."""


def __getattr__(name):
    if name in ("LM", "build_model"):
        from repro.models import lm
        return getattr(lm, name)
    raise AttributeError(name)
