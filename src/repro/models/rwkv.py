"""RWKV6 (Finch) block: data-dependent-decay time mix + channel mix.

The closest assigned architecture to the paper's own subject — a recurrent
cell served one token at a time.  Train/prefill use the chunked closed form
(:mod:`repro.models.recurrence`); decode uses the fused single-step
recurrence, which is exactly the paper's loop-based LSTM-1 pattern: per
output element, a fused dot-product -> decay/bonus update -> readout with
no materialized intermediates.
"""

from __future__ import annotations

from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models.layers import dot, groupnorm_heads, rmsnorm
from repro.models.params import ParamSpec
from repro.models.recurrence import (chunked_linear_attention,
                                     linear_attention_step_planned)

F32 = jnp.float32
LORA_RANK = 32
DECAY_RANK = 64
N_MIX = 5  # (w, k, v, r, g)


def rwkv_specs(cfg: ModelConfig) -> Dict[str, ParamSpec]:
    d = cfg.d_model
    ff = cfg.d_ff
    z = lambda *s: ParamSpec(tuple(s), jnp.float32, (None,) * len(s), init="zeros")
    specs = {
        "ln1": z(d),
        "ln2": z(d),
        # time-mix ddlerp
        "mu_base": z(d),
        "mu": z(N_MIX, d),
        "lora_a": ParamSpec((d, N_MIX * LORA_RANK), jnp.float32, ("embed", None)),
        "lora_b": ParamSpec((N_MIX, LORA_RANK, d), jnp.float32, (None, None, "embed"),
                            scale=1e-2),
        # projections
        "wr": ParamSpec((d, d), jnp.float32, ("embed", "q_flat")),
        "wk": ParamSpec((d, d), jnp.float32, ("embed", "q_flat")),
        "wv": ParamSpec((d, d), jnp.float32, ("embed", "q_flat")),
        "wg": ParamSpec((d, d), jnp.float32, ("embed", "q_flat")),
        "wo": ParamSpec((d, d), jnp.float32, ("q_flat", "embed")),
        # data-dependent decay
        "decay_base": ParamSpec((d,), jnp.float32, (None,), init="custom",
                                custom_init=_decay_init),
        "decay_a": ParamSpec((d, DECAY_RANK), jnp.float32, ("embed", None)),
        "decay_b": ParamSpec((DECAY_RANK, d), jnp.float32, (None, "embed"),
                             scale=1e-2),
        "bonus": z(d),
        "wkv_norm": z(d),
        # channel mix
        "mu_ck": z(d),
        "mu_cr": z(d),
        "wk_c": ParamSpec((d, ff), jnp.float32, ("embed", "mlp")),
        "wv_c": ParamSpec((ff, d), jnp.float32, ("mlp", "embed")),
        "wr_c": ParamSpec((d, d), jnp.float32, ("embed", "q_flat")),
    }
    return specs


def _decay_init(key: jax.Array, spec: ParamSpec) -> jax.Array:
    # spread decay half-lives per channel (rwkv-style ratio init)
    d = spec.shape[0]
    ratio = jnp.arange(d, dtype=F32) / max(1, d - 1)
    return (-6.0 + 5.0 * ratio).astype(spec.dtype)  # log(-log w) range


def _shift_seq(x: jax.Array, prev: Optional[jax.Array]) -> jax.Array:
    """Token shift: x_{t-1} (zeros / cached tail at t=0).  x: (B, T, d)."""
    pad = jnp.zeros_like(x[:, :1]) if prev is None else prev[:, None, :]
    return jnp.concatenate([pad, x[:, :-1]], axis=1)


def _ddlerp(params, x: jax.Array, xs: jax.Array):
    """Data-dependent token-shift interpolation -> the 5 mixed streams."""
    dx = xs - x
    xb = x + dx * params["mu_base"].astype(x.dtype)
    lora = jnp.tanh(dot(xb, params["lora_a"]))
    B, T = x.shape[:2]
    lora = lora.reshape(B, T, N_MIX, LORA_RANK)
    mix = params["mu"].astype(F32) + jnp.einsum(
        "btnr,nrd->btnd", lora.astype(F32), params["lora_b"].astype(F32))
    streams = x[:, :, None, :].astype(F32) + dx[:, :, None, :].astype(F32) * mix
    return [s.astype(x.dtype) for s in
            jnp.split(streams, N_MIX, axis=2)]  # each (B,T,1,d)


def _time_mix_inputs(params, x, xs, cfg: ModelConfig):
    xw, xk, xv, xr, xg = [s[:, :, 0, :] for s in _ddlerp(params, x, xs)]
    r = dot(xr, params["wr"])
    k = dot(xk, params["wk"])
    v = dot(xv, params["wv"])
    g = jax.nn.silu(dot(xg, params["wg"]))
    dd = jnp.tanh(dot(xw, params["decay_a"]))
    dd = jax.lax.dot_general(dd.astype(F32), params["decay_b"].astype(F32),
                             (((dd.ndim - 1,), (0,)), ((), ())))
    log_decay = -jnp.exp(
        jnp.clip(params["decay_base"].astype(F32) + dd, -8.0, 3.0))
    return r, k, v, g, log_decay


def _heads(x: jax.Array, hd: int) -> jax.Array:
    B, T, d = x.shape
    return x.reshape(B, T, d // hd, hd).transpose(0, 2, 1, 3)  # (B,H,T,hd)


def _last_valid(x: jax.Array, lengths: Optional[jax.Array]) -> jax.Array:
    """x (B, T, d) -> the last *valid* token per example (B, d): x[:, -1]
    when lengths is None, else x[b, lengths[b]-1] (right-padded batch)."""
    if lengths is None:
        return x[:, -1, :]
    idx = jnp.maximum(lengths - 1, 0)[:, None, None]
    return jnp.take_along_axis(x, idx, axis=1)[:, 0, :]


def time_mix(params, x: jax.Array, cfg: ModelConfig, sharder, *,
             prev: Optional[jax.Array] = None,
             state: Optional[jax.Array] = None,
             lengths: Optional[jax.Array] = None):
    """Full-sequence wkv.  x: (B, T, d).  Returns (out, new_shift, new_state).

    ``lengths`` (B,) marks true per-example lengths in a right-padded
    batch: padded steps are forced to (decay 1, k 0) so they leave the
    recurrent state untouched — the same identity trick
    chunked_linear_attention uses for its own chunk padding."""
    hd = cfg.rwkv.head_dim
    H = cfg.d_model // hd
    xs = _shift_seq(x, prev)
    r, k, v, g, log_decay = _time_mix_inputs(params, x, xs, cfg)
    if lengths is not None:
        valid = (jnp.arange(x.shape[1], dtype=jnp.int32)[None, :]
                 < lengths[:, None])[..., None]                  # (B, T, 1)
        k = jnp.where(valid, k, 0.0)
        log_decay = jnp.where(valid, log_decay, 0.0)
    rh, kh, vh = _heads(r, hd), _heads(k, hd), _heads(v, hd)
    wh = _heads(log_decay, hd)
    u = params["bonus"].astype(F32).reshape(H, hd)
    rh = sharder.constrain(rh, "batch", "rwkv_heads", "seq", None)
    y, new_state = chunked_linear_attention(
        rh, kh, vh, wh, chunk=min(cfg.rwkv.chunk, x.shape[1]),
        convention="exclusive", u=u, initial_state=state)
    y = y.transpose(0, 2, 1, 3).reshape(x.shape)
    y = groupnorm_heads(y.astype(x.dtype), params["wkv_norm"], H, cfg.norm_eps)
    out = dot(y * g, params["wo"])
    return out, _last_valid(x, lengths), new_state


def time_mix_step(params, x: jax.Array, cfg: ModelConfig, sharder, *,
                  prev: jax.Array, state: jax.Array, tile_plan=None):
    """Single-token wkv (decode).  x: (B, 1, d)."""
    hd = cfg.rwkv.head_dim
    H = cfg.d_model // hd
    xs = prev[:, None, :]
    r, k, v, g, log_decay = _time_mix_inputs(params, x, xs, cfg)
    sq = lambda t: t[:, 0, :].reshape(t.shape[0], H, hd)
    u = params["bonus"].astype(F32).reshape(H, hd)
    y, new_state = linear_attention_step_planned(
        state, sq(r), sq(k), sq(v), sq(log_decay),
        u=u, tile_plan=tile_plan)
    y = y.reshape(x.shape[0], 1, cfg.d_model)
    y = groupnorm_heads(y.astype(x.dtype), params["wkv_norm"], H, cfg.norm_eps)
    out = dot(y * g, params["wo"])
    return out, x[:, 0, :], new_state


def channel_mix(params, x: jax.Array, cfg: ModelConfig, sharder, *,
                prev: Optional[jax.Array] = None,
                lengths: Optional[jax.Array] = None):
    """Squared-relu channel mix.  Returns (out, new_shift)."""
    xs = _shift_seq(x, prev)
    dx = xs - x
    xk = x + dx * params["mu_ck"].astype(x.dtype)
    xr = x + dx * params["mu_cr"].astype(x.dtype)
    kk = jnp.square(jax.nn.relu(dot(xk, params["wk_c"])))
    kk = sharder.constrain(kk, "batch", "seq", "mlp")
    r = jax.nn.sigmoid(dot(xr, params["wr_c"]))
    out = r * dot(kk, params["wv_c"])
    return out, _last_valid(x, lengths)


def rwkv_block(params, x: jax.Array, cfg: ModelConfig, sharder, *,
               mode: str, cache: Optional[Dict] = None,
               lengths: Optional[jax.Array] = None, tile_plan=None):
    """Full rwkv block.  Returns (x, new_cache).  ``lengths`` masks padded
    steps of a right-padded prefill batch (see time_mix).  ``tile_plan``
    (a ``tile_plans["rwkv"]`` entry) routes the decode step to the fused
    Pallas kernel with the DSE-chosen head tile."""
    if mode == "decode":
        h, tm_shift, state = time_mix_step(
            params, rmsnorm(x, params["ln1"], cfg.norm_eps), cfg, sharder,
            prev=cache["tm_shift"], state=cache["wkv_state"],
            tile_plan=tile_plan)
        x = x + h
        h, cm_shift = channel_mix(
            params, rmsnorm(x, params["ln2"], cfg.norm_eps), cfg, sharder,
            prev=cache["cm_shift"])
        x = x + h
        return x, {"wkv_state": state.astype(F32), "tm_shift": tm_shift,
                   "cm_shift": cm_shift}
    prev_tm = cache["tm_shift"] if cache else None
    prev_cm = cache["cm_shift"] if cache else None
    state = cache["wkv_state"] if cache else None
    h, tm_shift, state = time_mix(
        params, rmsnorm(x, params["ln1"], cfg.norm_eps), cfg, sharder,
        prev=prev_tm, state=state, lengths=lengths)
    x = x + h
    h, cm_shift = channel_mix(
        params, rmsnorm(x, params["ln2"], cfg.norm_eps), cfg, sharder,
        prev=prev_cm, lengths=lengths)
    x = x + h
    new_cache = {"wkv_state": state.astype(F32), "tm_shift": tm_shift,
                 "cm_shift": cm_shift}
    return x, new_cache
