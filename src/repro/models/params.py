"""Parameter-spec system.

A model describes its parameters as a pytree of :class:`ParamSpec` — shape,
dtype, *logical axis names*, and an initializer.  From the same spec tree we
derive:

  * real initialized parameters (smoke tests / examples),
  * ``jax.ShapeDtypeStruct`` stand-ins (the multi-pod dry-run: no allocation),
  * ``NamedSharding`` trees via the logical-axis rules in
    :mod:`repro.dist.sharding`.

This mirrors what flax/maxtext do with ``nn.with_logical_partitioning`` but
stays dependency-free and explicit.
"""

from __future__ import annotations

import dataclasses
import math
import zlib
from typing import Any, Callable, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np


@dataclasses.dataclass(frozen=True)
class ParamSpec:
    shape: Tuple[int, ...]
    dtype: Any = jnp.float32
    axes: Tuple[Optional[str], ...] = ()
    init: str = "normal"        # normal | zeros | ones | uniform | custom
    scale: Optional[float] = None
    custom_init: Optional[Callable[[jax.Array, "ParamSpec"], jax.Array]] = None

    def __post_init__(self):
        if self.axes and len(self.axes) != len(self.shape):
            raise ValueError(
                f"axes {self.axes} rank != shape {self.shape} rank")

    @property
    def size(self) -> int:
        return int(np.prod(self.shape)) if self.shape else 1

    @property
    def nbytes(self) -> int:
        return self.size * jnp.dtype(self.dtype).itemsize

    def abstract(self) -> jax.ShapeDtypeStruct:
        return jax.ShapeDtypeStruct(self.shape, self.dtype)

    def initialize(self, key: jax.Array) -> jax.Array:
        if self.custom_init is not None:
            return self.custom_init(key, self)
        if self.init == "zeros":
            return jnp.zeros(self.shape, self.dtype)
        if self.init == "ones":
            return jnp.ones(self.shape, self.dtype)
        if self.init == "uniform":
            s = self.scale if self.scale is not None else 1.0
            return jax.random.uniform(
                key, self.shape, jnp.float32, -s, s).astype(self.dtype)
        # default: truncated-normal, fan-in scaled unless overridden
        if self.scale is not None:
            std = self.scale
        else:
            fan_in = self.shape[-2] if len(self.shape) >= 2 else self.shape[-1]
            std = 1.0 / math.sqrt(max(1, fan_in))
        x = jax.random.truncated_normal(key, -3.0, 3.0, self.shape, jnp.float32)
        return (x * std).astype(self.dtype)


def is_spec(x: Any) -> bool:
    return isinstance(x, ParamSpec)


def tree_abstract(specs) -> Any:
    """ShapeDtypeStruct tree for dry-run lowering (no device allocation)."""
    return jax.tree.map(lambda s: s.abstract(), specs, is_leaf=is_spec)


def tree_axes(specs) -> Any:
    return jax.tree.map(lambda s: s.axes, specs, is_leaf=is_spec)


def tree_init(specs, key: jax.Array) -> Any:
    """Initialize every leaf with an independent, path-derived key.

    Keys are derived by folding a stable hash of the tree path into `key`,
    so adding/removing parameters does not reshuffle unrelated leaves —
    useful for checkpoint-compat tests.
    """
    leaves_with_paths = jax.tree_util.tree_flatten_with_path(
        specs, is_leaf=is_spec)[0]
    treedef = jax.tree.structure(specs, is_leaf=is_spec)
    out = []
    for path, spec in leaves_with_paths:
        path_str = jax.tree_util.keystr(path)
        # crc32, not hash(): str hashing is randomized per process, which
        # would reshuffle every init between runs (and break the promise
        # this docstring makes)
        sub = jax.random.fold_in(key, zlib.crc32(path_str.encode()) % (2**31))
        out.append(spec.initialize(sub))
    return jax.tree.unflatten(treedef, out)


def tree_size(specs) -> int:
    return sum(s.size for s in jax.tree.leaves(specs, is_leaf=is_spec))


def tree_bytes(specs) -> int:
    return sum(s.nbytes for s in jax.tree.leaves(specs, is_leaf=is_spec))


def stack_specs(spec: ParamSpec, n: int, axis_name: Optional[str] = "layers") -> ParamSpec:
    """Prepend a stacking dimension (for scan-over-layers parameters)."""
    return dataclasses.replace(
        spec,
        shape=(n,) + tuple(spec.shape),
        axes=(axis_name,) + tuple(spec.axes) if spec.axes else (),
    )


def tree_stack_specs(specs, n: int) -> Any:
    return jax.tree.map(lambda s: stack_specs(s, n), specs, is_leaf=is_spec)
