"""Abstract input construction for every (architecture x shape) cell.

``input_specs`` returns ``jax.ShapeDtypeStruct`` stand-ins (weak-type
correct, shardable, no device allocation) together with their logical axes
— the same pattern the dry-run lowers against.  ``make_batch`` materializes
a concrete random batch of the same structure for smoke tests and
examples.
"""

from __future__ import annotations

from typing import Any, Dict, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig, ShapeSpec


def input_specs(cfg: ModelConfig, shape: ShapeSpec
                ) -> Tuple[Dict[str, jax.ShapeDtypeStruct], Dict[str, Tuple]]:
    """Returns (abstract batch, logical axes per entry) for train/prefill.

    Decode-mode inputs are the (token, lengths) pair plus the cache, whose
    specs come from ``LM.cache_specs``.
    """
    B, S = shape.global_batch, shape.seq_len
    specs: Dict[str, Any] = {}
    axes: Dict[str, Tuple] = {}
    if shape.mode == "train":
        specs["tokens"] = jax.ShapeDtypeStruct((B, S + 1), jnp.int32)
        axes["tokens"] = ("batch", "seq")
    elif shape.mode == "prefill":
        specs["tokens"] = jax.ShapeDtypeStruct((B, S), jnp.int32)
        axes["tokens"] = ("batch", "seq")
    else:  # decode
        specs["tokens"] = jax.ShapeDtypeStruct((B,), jnp.int32)
        axes["tokens"] = ("batch",)

    if cfg.is_encoder_decoder and shape.mode in ("train", "prefill"):
        se = S // cfg.encoder_downsample
        specs["frames"] = jax.ShapeDtypeStruct((B, se, cfg.d_model),
                                               jnp.bfloat16)
        axes["frames"] = ("batch", "seq", None)
    if cfg.m_rope_sections and shape.mode in ("train", "prefill"):
        specs["positions"] = jax.ShapeDtypeStruct((B, 3, S), jnp.int32)
        axes["positions"] = ("batch", None, "seq")
    return specs, axes


def make_batch(cfg: ModelConfig, shape: ShapeSpec, seed: int = 0
               ) -> Dict[str, jax.Array]:
    """Concrete random batch matching ``input_specs`` (host-side numpy)."""
    rng = np.random.default_rng(seed)
    specs, _ = input_specs(cfg, shape)
    batch = {}
    for name, s in specs.items():
        if name in ("tokens",):
            batch[name] = jnp.asarray(
                rng.integers(0, cfg.vocab_size, s.shape), jnp.int32)
        elif name == "positions":
            B, _, S = s.shape
            pos = np.broadcast_to(np.arange(S), (B, 3, S))
            batch[name] = jnp.asarray(pos, jnp.int32)
        elif name == "frames":
            batch[name] = jnp.asarray(
                rng.standard_normal(s.shape, np.float32), jnp.bfloat16)
        else:
            raise KeyError(name)
    return batch
