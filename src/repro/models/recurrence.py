"""Chunked linear-attention / SSM recurrence.

Both recurrent families in the assigned grid reduce to the same affine
state recurrence over a (K x V) state S with per-step decay d_t:

    S_t = diag(d_t) S_{t-1} + k_t v_t^T          y_t = q_t . S_{t'}

RWKV6 reads S_{t-1} plus a "bonus" diagonal term (u), per-channel decay;
the SSD-form SSM (Mamba2-style) reads S_t, scalar-per-head decay.  Both are
evaluated in a *chunked* closed form that never builds a while loop:

  * within a chunk: decays become cumulative log-sums; scores are a masked
    (q*exp(c_i)) @ (k*exp(-c_j))^T matmul.  Cumulative logs are clamped at
    ``-LOG_CLAMP`` — clamping preserves *differences* once both ends are
    clamped, so the only error is in coefficients below exp(-LOG_CLAMP),
    which are numerically zero anyway.
  * across chunks: per-chunk (decay D_c, increment A_c) pairs are combined
    with ``jax.lax.associative_scan`` over the affine monoid
    (D1,A1) o (D2,A2) = (D2*D1, D2*A1 + A2).

This is the TPU-native adaptation of the paper's "keep the recurrent state
in registers" insight: the state chain is the only sequential dependence
and it is log-depth; everything else is dense MXU work (DESIGN.md
§Hardware-adaptation).
"""

from __future__ import annotations

from functools import partial
from typing import Optional, Tuple

import jax
import jax.numpy as jnp

F32 = jnp.float32
LOG_CLAMP = 30.0


def _affine_combine(a, b):
    d1, s1 = a
    d2, s2 = b
    return d1 * d2, d2[..., None] * s1 + s2


def chunked_linear_attention(
    q: jax.Array,                 # (B, H, T, K)
    k: jax.Array,                 # (B, H, T, K)
    v: jax.Array,                 # (B, H, T, V)
    log_decay: jax.Array,         # (B, H, T, K) or (B, H, T, 1); <= 0
    *,
    chunk: int,
    convention: str,              # "exclusive" (rwkv) | "inclusive" (ssd)
    u: Optional[jax.Array] = None,        # (H, K) rwkv bonus
    initial_state: Optional[jax.Array] = None,   # (B, H, K, V)
) -> Tuple[jax.Array, jax.Array]:
    """Returns (y (B, H, T, V), final_state (B, H, K, V))."""
    B, H, T, K = q.shape
    V = v.shape[-1]
    T_real = T
    chunk = max(1, chunk)
    pad = (-T) % chunk
    if pad:
        # zero-pad the tail: padded steps have decay 1 and k = 0, so they
        # leave the state untouched; their outputs are sliced away below.
        zpad = lambda x: jnp.concatenate(
            [x, jnp.zeros(x.shape[:2] + (pad,) + x.shape[3:], x.dtype)], axis=2)
        q, k, v, log_decay = zpad(q), zpad(k), zpad(v), zpad(log_decay)
        T = T + pad
    n_c, n = T // chunk, chunk

    ch = lambda x: x.reshape(B, H, n_c, n, x.shape[-1])
    qc, kc, vc = ch(q.astype(F32)), ch(k.astype(F32)), ch(v.astype(F32))
    lw = ch(log_decay.astype(F32))                       # (B,H,nc,n,Kd)
    lw = jnp.broadcast_to(lw, (B, H, n_c, n, K)) if lw.shape[-1] == 1 else lw

    c_inc = jnp.cumsum(lw, axis=3)                       # inclusive cumsum
    c_exc = c_inc - lw                                   # exclusive
    cq = c_exc if convention == "exclusive" else c_inc
    cqc = jnp.maximum(cq, -LOG_CLAMP)
    ckc = jnp.maximum(c_inc, -LOG_CLAMP)

    qd = qc * jnp.exp(cqc)
    kd = kc * jnp.exp(-ckc)

    # ---- intra-chunk scores -------------------------------------------------
    scores = jnp.einsum("bhcik,bhcjk->bhcij", qd, kd,
                        preferred_element_type=F32)
    i_idx = jnp.arange(n)[:, None]
    j_idx = jnp.arange(n)[None, :]
    mask = (j_idx < i_idx) if convention == "exclusive" else (j_idx <= i_idx)
    scores = jnp.where(mask, scores, 0.0)
    y = jnp.einsum("bhcij,bhcjv->bhciv", scores, vc,
                   preferred_element_type=F32)
    if u is not None:  # rwkv bonus: the diagonal reads (u*k_i) instead of S
        diag = jnp.einsum("bhcik,hk,bhcik->bhci", qc, u.astype(F32), kc)
        y = y + diag[..., None] * vc

    # ---- chunk summaries ----------------------------------------------------
    total = c_inc[:, :, :, -1, :]                        # (B,H,nc,K)
    rc = jnp.maximum(total[:, :, :, None, :] - c_inc, -LOG_CLAMP)
    kt = kc * jnp.exp(rc)
    A = jnp.einsum("bhcjk,bhcjv->bhckv", kt, vc,
                   preferred_element_type=F32)           # (B,H,nc,K,V)
    D = jnp.exp(total)                                   # (B,H,nc,K)

    # ---- inter-chunk state chain (log-depth, no while loop) ----------------
    Dcum, Acum = jax.lax.associative_scan(_affine_combine, (D, A), axis=2)
    S_init = (jnp.zeros((B, H, K, V), F32) if initial_state is None
              else initial_state.astype(F32))
    # state entering chunk c = effect of chunks [0, c) applied to S_init
    S_in = Dcum[..., None] * S_init[:, :, None] + Acum    # state AFTER chunk c
    S_enter = jnp.concatenate(
        [S_init[:, :, None], S_in[:, :, :-1]], axis=2)    # (B,H,nc,K,V)

    y = y + jnp.einsum("bhcik,bhckv->bhciv", qd, S_enter,
                       preferred_element_type=F32)
    final = S_in[:, :, -1]
    y = y.reshape(B, H, T, V)
    if pad:
        y = y[:, :, :T_real]
    return y, final


def linear_attention_step(
    state: jax.Array,             # (B, H, K, V)
    q: jax.Array,                 # (B, H, K)
    k: jax.Array,                 # (B, H, K)
    v: jax.Array,                 # (B, H, V)
    log_decay: jax.Array,         # (B, H, K) or (B, H, 1)
    *,
    convention: str,
    u: Optional[jax.Array] = None,        # (H, K)
) -> Tuple[jax.Array, jax.Array]:
    """Single-token recurrence (decode).  Returns (y (B,H,V), new_state).

    This is the paper's fused serving step: projections feed the state
    update and readout with all intermediates register-resident.
    """
    state = state.astype(F32)
    q, k, v = q.astype(F32), k.astype(F32), v.astype(F32)
    d = jnp.exp(jnp.broadcast_to(log_decay.astype(F32), k.shape))
    kv = k[..., None] * v[..., None, :]                   # (B,H,K,V)
    if convention == "exclusive":
        read = state + (u.astype(F32)[None, :, :, None] * kv
                        if u is not None else 0.0)
        new_state = d[..., None] * state + kv
    else:  # inclusive (ssd)
        new_state = d[..., None] * state + kv
        read = new_state
    y = jnp.einsum("bhk,bhkv->bhv", q, read)
    return y, new_state


def linear_attention_step_planned(
    state: jax.Array,             # (B, H, K, V)
    q: jax.Array,                 # (B, H, K)
    k: jax.Array,                 # (B, H, K)
    v: jax.Array,                 # (B, H, V)
    log_decay: jax.Array,         # (B, H, K)
    *,
    u: Optional[jax.Array] = None,        # (H, K)
    tile_plan=None,
) -> Tuple[jax.Array, jax.Array]:
    """Exclusive-convention single-token step, routed by a tile plan.

    With no plan (or ``impl`` resolving to jnp) this is exactly
    :func:`linear_attention_step`; with an active pallas plan the fused
    RWKV6 step kernel runs instead, its head tile taken from the plan's
    ``bh`` (hidden units -> whole heads)."""
    from repro.kernels.dispatch import interpret_mode, pallas_active

    if not pallas_active(tile_plan):
        return linear_attention_step(state, q, k, v, log_decay,
                                     convention="exclusive", u=u)
    from repro.kernels.rwkv_step.ops import head_tile
    from repro.kernels.rwkv_step.rwkv_step import rwkv6_step

    H, K = q.shape[1], q.shape[2]
    bh = head_tile(H, K, tile_plan)
    y, new_state = rwkv6_step(
        q[None], k[None], v[None], jnp.broadcast_to(
            log_decay.astype(F32), k.shape)[None],
        u.astype(F32) if u is not None else jnp.zeros((H, K), F32),
        state.astype(F32), bh=bh, interpret=interpret_mode())
    return y[0].astype(F32), new_state
