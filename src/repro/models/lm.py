"""The unified LM wrapper: parameters, train loss, prefill, decode.

One class serves all ten assigned architectures; family differences are
entirely expressed through ``ModelConfig.layer_pattern`` and the block
library.  The layer stack is scanned at *period* granularity (stacked
parameters, one period = one iteration) which keeps HLO size and compile
time independent of depth — and the class exposes ``period_apply`` /
``stem_train`` / ``stem_serve`` so the roofline analyzer can lower the
scanned body separately and scale its cost by the trip count
(EXPERIMENTS.md §Methodology).
"""

from __future__ import annotations

import functools
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig, ShapeSpec
from repro.dist.sharding import Sharder
from repro.models import params as pspec
from repro.models.attention import cache_slot_count
from repro.models.blocks import apply_block, attn_cache_entry, block_specs
from repro.models.layers import embed, embed_specs, unembed
from repro.models.params import ParamSpec
from repro.models.ssm import _d_inner, _n_ssm_heads

F32 = jnp.float32


def build_model(cfg: ModelConfig, tile_plans=None) -> "LM":
    return LM(cfg, tile_plans=tile_plans)


class LM:
    def __init__(self, cfg: ModelConfig, tile_plans=None):
        self.cfg = cfg
        # per-kind kernel tile geometry (ServingPlan.tile_plans); entries
        # reach every apply_block call so an autotuned plan provably
        # changes the compiled hot path.
        self.tile_plans = dict(tile_plans or {})

    def with_tile_plans(self, tile_plans) -> "LM":
        """A copy of this model whose blocks run under ``tile_plans``."""
        return type(self)(self.cfg, tile_plans=tile_plans)

    # ------------------------------------------------------------------ specs
    def param_specs(self) -> Dict[str, Any]:
        cfg = self.cfg
        specs: Dict[str, Any] = {}
        specs.update(embed_specs(cfg))
        specs["final_norm"] = ParamSpec((cfg.d_model,), F32, (None,),
                                        init="zeros")
        period = {
            f"p{i}": block_specs(cfg, kind, cross=cfg.is_encoder_decoder)
            for i, kind in enumerate(cfg.layer_pattern)
        }
        specs["blocks"] = pspec.tree_stack_specs(period, cfg.n_periods)
        if cfg.is_encoder_decoder:
            enc_period = {"p0": block_specs(cfg, "attn")}
            specs["enc_blocks"] = pspec.tree_stack_specs(
                enc_period, cfg.n_encoder_layers)
            specs["enc_final_norm"] = ParamSpec((cfg.d_model,), F32, (None,),
                                                init="zeros")
        return specs

    def init(self, key: jax.Array):
        return pspec.tree_init(self.param_specs(), key)

    def abstract_params(self):
        return pspec.tree_abstract(self.param_specs())

    def n_params(self) -> int:
        return pspec.tree_size(self.param_specs())

    # ------------------------------------------------------------- period body
    def period_apply(self, p_params, x, *, positions=None, lengths=None,
                     mode: str, sharder: Sharder, p_cache=None, enc_out=None,
                     causal: bool = True, max_len: int = 0):
        """Apply one scan period (all layers of the pattern).

        Returns (x, new_period_cache_or_None, aux)."""
        cfg = self.cfg
        aux = jnp.zeros((), F32)
        new_cache: Dict[str, Any] = {}
        for i, kind in enumerate(cfg.layer_pattern):
            key = f"p{i}"
            x, c, a = apply_block(
                p_params[key], x, cfg, kind, sharder, positions=positions,
                lengths=lengths, mode=mode, enc_out=enc_out, causal=causal,
                cache=(p_cache or {}).get(key) if p_cache else None,
                max_len=max_len, tile_plan=self.tile_plans.get(kind))
            aux = aux + a
            if c is not None:
                new_cache[key] = c
        return x, (new_cache or None), aux

    def _scan(self, blocks, x, *, positions=None, lengths=None, mode: str,
              sharder: Sharder, cache=None, enc_out=None, causal=True,
              max_len: int = 0, remat: Optional[bool] = None):
        cfg = self.cfg
        collect = mode in ("prefill", "decode")
        remat = (cfg.remat != "none" and mode == "train") \
            if remat is None else remat

        def body(carry, xs):
            x, aux = carry
            p_params, p_cache = xs if collect and cache is not None \
                else (xs, None)
            x, new_c, a = self.period_apply(
                p_params, x, positions=positions, lengths=lengths, mode=mode,
                sharder=sharder, p_cache=p_cache, enc_out=enc_out,
                causal=causal, max_len=max_len)
            if mode == "train":
                # the scan carry is what remat saves; under
                # cfg.shard_residual_seq its seq dim shards over the model
                # axis (re-gathered on recompute) — §Perf lever
                x = sharder.constrain(x, "batch", "res_seq", None)
            return (x, aux + a), (new_c if collect else 0)

        if remat:
            policy = (jax.checkpoint_policies.dots_with_no_batch_dims_saveable
                      if cfg.remat == "dots" else None)
            body = jax.checkpoint(body, policy=policy)
        xs = (blocks, cache) if (collect and cache is not None) else blocks
        (x, aux), caches = jax.lax.scan(body, (x, jnp.zeros((), F32)), xs)
        return x, (caches if collect else None), aux

    # ------------------------------------------------------------------ stems
    def embed_tokens(self, params, tokens, sharder) -> jax.Array:
        return embed(params, tokens, self.cfg, sharder)

    def final_hidden_to_logits(self, params, x, sharder,
                               norm_name="final_norm") -> jax.Array:
        from repro.models.layers import rmsnorm
        x = rmsnorm(x, params[norm_name], self.cfg.norm_eps)
        return unembed(params, x, self.cfg, sharder)

    # --------------------------------------------------------------- encoder
    def encode(self, params, frames, sharder, mode="train"):
        """Whisper encoder over precomputed frame embeddings (stub
        frontend).  frames: (B, S_enc, d_model)."""
        from repro.models.layers import rmsnorm
        B, Se, _ = frames.shape
        pos = jnp.broadcast_to(jnp.arange(Se, dtype=jnp.int32), (B, Se))
        x = frames.astype(jnp.bfloat16)
        x, _, _ = self._scan(params["enc_blocks"], x, positions=pos,
                             mode="train", sharder=sharder, causal=False,
                             remat=(mode == "train" and self.cfg.remat != "none"))
        return rmsnorm(x, params["enc_final_norm"], self.cfg.norm_eps)

    # ------------------------------------------------------------------ train
    def loss(self, params, batch, sharder: Sharder
             ) -> Tuple[jax.Array, Dict[str, jax.Array]]:
        cfg = self.cfg
        tokens = batch["tokens"]
        x_tok, targets = tokens[:, :-1], tokens[:, 1:]
        B, S = x_tok.shape
        positions = batch.get("positions")
        if positions is None:
            positions = jnp.broadcast_to(jnp.arange(S, dtype=jnp.int32),
                                         (B, S))
        enc_out = None
        if cfg.is_encoder_decoder:
            enc_out = self.encode(params, batch["frames"], sharder)
        x = self.embed_tokens(params, x_tok, sharder)
        x, _, aux = self._scan(params["blocks"], x, positions=positions,
                               mode="train", sharder=sharder, enc_out=enc_out)
        logits = self.final_hidden_to_logits(params, x, sharder)
        return self.ce_loss(logits, targets, aux)

    def ce_loss(self, logits, targets, aux=None):
        cfg = self.cfg
        logits = logits.astype(F32)
        lse = jax.nn.logsumexp(logits, axis=-1)
        gold = jnp.take_along_axis(
            logits, targets[..., None].astype(jnp.int32), axis=-1)[..., 0]
        ce = jnp.mean(lse - gold)
        z_loss = 1e-4 * jnp.mean(jnp.square(lse))
        total = ce + z_loss + (aux if aux is not None else 0.0)
        metrics = {"loss": total, "ce": ce, "z_loss": z_loss,
                   "aux": aux if aux is not None else jnp.zeros((), F32)}
        return total, metrics

    # ------------------------------------------------------------------ cache
    def cache_specs(self, batch: int, max_len: int) -> Dict[str, Any]:
        """ParamSpec tree for the serving cache (decode input)."""
        cfg = self.cfg
        period = self.period_cache_specs(batch, max_len)
        blocks = pspec.tree_stack_specs(period, cfg.n_periods)
        return {"blocks": blocks,
                "lengths": ParamSpec((batch,), jnp.int32, ("batch",),
                                     init="zeros")}

    def period_cache_specs(self, batch: int, max_len: int) -> Dict[str, Any]:
        """Cache specs for ONE scan period (pre-stacking); also used by the
        roofline analyzer's per-period decode cost piece."""
        cfg = self.cfg
        period: Dict[str, Any] = {}
        for i, kind in enumerate(cfg.layer_pattern):
            key = f"p{i}"
            if kind == "rwkv":
                H, hd = cfg.d_model // cfg.rwkv.head_dim, cfg.rwkv.head_dim
                period[key] = {
                    "wkv_state": ParamSpec((batch, H, hd, hd), F32,
                                           ("batch", "rwkv_heads", None, None),
                                           init="zeros"),
                    "tm_shift": ParamSpec((batch, cfg.d_model), jnp.bfloat16,
                                          ("batch", None), init="zeros"),
                    "cm_shift": ParamSpec((batch, cfg.d_model), jnp.bfloat16,
                                          ("batch", None), init="zeros"),
                }
                continue
            entry = attn_cache_entry(cfg, kind, batch, max_len)
            if kind == "swa_ssm":
                s = cfg.ssm
                di, nh = _d_inner(cfg), _n_ssm_heads(cfg)
                entry["conv_state"] = ParamSpec(
                    (batch, s.conv_width - 1, di), jnp.bfloat16,
                    ("batch", None, "ssm_inner"), init="zeros")
                entry["ssd_state"] = ParamSpec(
                    (batch, nh, s.d_state, s.head_dim), F32,
                    ("batch", None, None, None), init="zeros")
            if cfg.is_encoder_decoder:
                se = max_len // cfg.encoder_downsample
                entry["xk"] = ParamSpec(
                    (batch, se, cfg.n_kv_heads, cfg.head_dim_), jnp.bfloat16,
                    ("batch", None, "kv_heads", None), init="zeros")
                entry["xv"] = ParamSpec(
                    (batch, se, cfg.n_kv_heads, cfg.head_dim_), jnp.bfloat16,
                    ("batch", None, "kv_heads", None), init="zeros")
            period[key] = entry
        return period

    def init_cache(self, batch: int, max_len: int):
        return pspec.tree_init(self.cache_specs(batch, max_len),
                               jax.random.PRNGKey(0))

    def cache_batch_axes(self, cache) -> Dict[str, Any]:
        """Batch(=slot)-axis index for every cache leaf — the cache pytree
        contract the serving layer's slot-state manager keys on.

        Every leaf under ``blocks`` is period-stacked (axis 0 = scan
        period), so its slot axis is 1; the top-level ``lengths`` vector
        carries slots on axis 0.  Gathering a slot's column across this
        axes tree captures the request's *entire* decode state — KV ring
        (k/v/pos and int8 scales), rwkv wkv/shift, ssd/conv, cross-attn
        keys, and its length counter — which is what makes preempt-to-
        host / resume (repro.serving.slotstate) architecture-agnostic."""
        return {"blocks": jax.tree.map(lambda _: 1, cache["blocks"]),
                "lengths": 0}

    # KV-ring leaves: paged along their length(-ring) axis by the paged
    # slot-state manager.  Everything else — rwkv wkv/shift, ssd/conv,
    # cross-attn keys, lengths — is per-slot state with no length axis
    # (or, for xk/xv, written whole at prefill), i.e. "one block per
    # slot": the cheap recurrent case.
    PAGEABLE_LEAVES = frozenset({"k", "v", "pos", "k_scale", "v_scale"})

    def cache_page_axes(self, cache) -> Dict[str, Any]:
        """Length(-ring)-axis index for every *pageable* cache leaf, None
        for per-slot state — the companion contract to
        :meth:`cache_batch_axes` that lets the paged slot-state manager
        (repro.serving.paged) split the cache into a block pool (KV rings,
        paged along axis 2 after period stacking) and dense per-slot
        leaves.  Accepts either a live cache pytree or a ``cache_specs``
        spec tree (classification is by leaf name, not by value)."""
        def classify(path, _leaf):
            name = path[-1].key if hasattr(path[-1], "key") else None
            return 2 if name in self.PAGEABLE_LEAVES else None

        blocks = jax.tree_util.tree_map_with_path(
            classify, cache["blocks"],
            is_leaf=lambda x: isinstance(x, ParamSpec))
        return {"blocks": blocks, "lengths": None}

    # ---------------------------------------------------------------- prefill
    def prefill(self, params, batch, sharder: Sharder, max_len: int = 0):
        """Full-sequence prefill.  Returns (cache, last_token_logits).

        ``batch["lengths"]`` (B,) int32, when present, marks each example's
        true prompt length within a right-padded batch (bucketed batched
        prefill): padding positions are masked out of attention (position
        -1), recurrent-state updates on padded steps are forced to the
        identity, the returned logits are read at each example's last
        *valid* token, and the cache records the true lengths — so one
        padded batched call is equivalent to per-example exact-length
        prefills.  (MoE routing is the one approximate spot: padded tokens
        still compete for expert capacity.)"""
        cfg = self.cfg
        tokens = batch["tokens"]
        B, S = tokens.shape
        max_len = max_len or S
        lengths = batch.get("lengths")
        positions = batch.get("positions")
        if positions is None:
            positions = jnp.broadcast_to(jnp.arange(S, dtype=jnp.int32),
                                         (B, S))
        if lengths is not None:
            lengths = lengths.astype(jnp.int32)
            valid = (jnp.arange(S, dtype=jnp.int32)[None, :]
                     < lengths[:, None])                          # (B, S)
            vmask = valid if positions.ndim == 2 else valid[:, None, :]
            positions = jnp.where(vmask, positions, -1)
        enc_out = None
        if cfg.is_encoder_decoder:
            enc_out = self.encode(params, batch["frames"], sharder,
                                  mode="prefill")
        x = self.embed_tokens(params, tokens, sharder)
        x, caches, _ = self._scan(params["blocks"], x, positions=positions,
                                  lengths=lengths, mode="prefill",
                                  sharder=sharder, enc_out=enc_out,
                                  max_len=max_len)
        if lengths is None:
            h_last = x[:, -1:, :]
            cache_lengths = jnp.full((B,), S, jnp.int32)
        else:
            idx = jnp.maximum(lengths - 1, 0)[:, None, None]
            h_last = jnp.take_along_axis(x, idx, axis=1)
            cache_lengths = lengths
        logits = self.final_hidden_to_logits(params, h_last, sharder)
        cache = {"blocks": caches, "lengths": cache_lengths}
        return cache, logits[:, 0]

    # ----------------------------------------------------------------- decode
    def decode_step(self, params, cache, tokens, sharder: Sharder):
        """One decode step.  tokens: (B,) int32.  Returns (cache, logits)."""
        cfg = self.cfg
        B = tokens.shape[0]
        lengths = cache["lengths"]
        if cfg.m_rope_sections:
            positions = jnp.broadcast_to(lengths[:, None, None], (B, 3, 1))
        else:
            positions = lengths[:, None]
        x = self.embed_tokens(params, tokens[:, None], sharder)
        x, new_blocks, _ = self._scan(
            params["blocks"], x, positions=positions, lengths=lengths,
            mode="decode", sharder=sharder, cache=cache["blocks"])
        logits = self.final_hidden_to_logits(params, x, sharder)
        new_cache = {"blocks": new_blocks, "lengths": lengths + 1}
        return new_cache, logits[:, 0]

    # ------------------------------------------------ cost pieces (roofline)
    def stem_train(self, params, tokens, h_final, sharder):
        """Embedding + head + loss (the non-scanned part of a train step)."""
        x_tok, targets = tokens[:, :-1], tokens[:, 1:]
        x0 = self.embed_tokens(params, x_tok, sharder)
        logits = self.final_hidden_to_logits(
            params, h_final + 0.0 * x0, sharder)
        total, _ = self.ce_loss(logits, targets)
        return total

    def stem_serve(self, params, tokens, h_final, sharder, last_only=True):
        x0 = self.embed_tokens(params, tokens, sharder)
        h = h_final + 0.0 * x0
        if last_only:
            h = h[:, -1:, :]
        return self.final_hidden_to_logits(params, h, sharder)
