"""Mixture-of-experts layer: top-k routing, GShard dispatch/combine einsums.

Experts shard over the model axis (expert parallelism); the dispatch einsum
contracts tokens against a (group, token, expert, capacity) one-hot, which
GSPMD partitions into the canonical all-to-all exchange.  Capacity is
computed per token *group* so the dispatch tensor stays bounded; overflow
tokens are dropped (their combine weight is zero) as in GShard/Switch, and
the auxiliary load-balance loss keeps the router near-uniform.

The dispatch-einsum overhead relative to useful expert FLOPs is
2*E*C/(k*d_ff)-ish and is reported by the roofline's useful-flops ratio;
replacing it with sort-based ragged dispatch is a recorded hillclimb
candidate (EXPERIMENTS.md §Perf).
"""

from __future__ import annotations

import math
from typing import Dict, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models.params import ParamSpec

F32 = jnp.float32


def moe_specs(cfg: ModelConfig) -> Dict[str, ParamSpec]:
    d, f, m = cfg.d_model, cfg.d_ff, cfg.moe
    specs = {
        "router": ParamSpec((d, m.n_experts), jnp.float32, ("embed", None),
                            scale=0.02),
        "w_up": ParamSpec((m.n_experts, d, f), jnp.float32,
                          ("experts", "embed", "mlp")),
        "w_down": ParamSpec((m.n_experts, f, d), jnp.float32,
                            ("experts", "mlp", "embed")),
    }
    if cfg.mlp_gated:
        specs["w_gate"] = ParamSpec((m.n_experts, d, f), jnp.float32,
                                    ("experts", "embed", "mlp"))
    return specs


def _group_size(cfg: ModelConfig, n_tokens: int, sharder) -> int:
    """Groups must (a) bound the dispatch tensor, (b) outnumber the data
    shards so the group dim shards."""
    n_data = 1
    if sharder.mesh is not None:
        for a in ("pod", "data"):
            if a in sharder.mesh.shape:
                n_data *= sharder.mesh.shape[a]
    gs = min(cfg.moe.group_size, max(1, n_tokens // max(1, n_data)))
    while n_tokens % gs:
        gs -= 1
    return gs


def moe_mlp(params, x: jax.Array, cfg: ModelConfig, sharder
            ) -> Tuple[jax.Array, jax.Array]:
    """x: (B, S, d) -> (y, aux_loss)."""
    m = cfg.moe
    B, S, d = x.shape
    n_tokens = B * S
    gs = _group_size(cfg, n_tokens, sharder)
    G = n_tokens // gs
    E, K = m.n_experts, m.top_k
    C = max(1, int(math.ceil(gs * K * m.capacity_factor / E)))

    xg = x.reshape(G, gs, d)
    xg = sharder.constrain(xg, "expert_group", None, None)

    # ---- routing (f32) ------------------------------------------------------
    logits = jax.lax.dot_general(
        xg.astype(F32), params["router"].astype(F32),
        (((2,), (0,)), ((), ())))                          # (G, gs, E)
    probs = jax.nn.softmax(logits, axis=-1)
    top_p, top_idx = jax.lax.top_k(probs, K)               # (G, gs, K)
    top_p = top_p / jnp.maximum(top_p.sum(-1, keepdims=True), 1e-9)

    # ---- position-in-expert, slot by slot -----------------------------------
    dispatch = jnp.zeros((G, gs, E, C), jnp.bfloat16)
    combine = jnp.zeros((G, gs, E, C), F32)
    counts = jnp.zeros((G, E), F32)
    for j in range(K):
        oh = jax.nn.one_hot(top_idx[..., j], E, dtype=F32)  # (G, gs, E)
        pos = counts[:, None, :] + jnp.cumsum(oh, axis=1) - oh
        keep = (pos < C) * oh
        slot = jax.nn.one_hot(pos.astype(jnp.int32), C, dtype=F32)  # (G,gs,E,C)
        dj = keep[..., None] * slot
        dispatch = dispatch + dj.astype(jnp.bfloat16)
        combine = combine + dj * top_p[..., j][..., None, None]
        counts = counts + oh.sum(axis=1)

    # ---- dispatch -> expert compute -> combine ------------------------------
    expert_in = jnp.einsum("gsec,gsd->egcd", dispatch, xg.astype(jnp.bfloat16),
                           preferred_element_type=jnp.bfloat16)
    expert_in = sharder.constrain(expert_in, "experts", "expert_group",
                                  None, None)
    up = jnp.einsum("egcd,edf->egcf", expert_in,
                    params["w_up"].astype(jnp.bfloat16),
                    preferred_element_type=F32)
    if cfg.mlp_gated:
        gate = jnp.einsum("egcd,edf->egcf", expert_in,
                          params["w_gate"].astype(jnp.bfloat16),
                          preferred_element_type=F32)
        h = jax.nn.silu(gate) * up
    else:
        h = jax.nn.gelu(up)
    h = sharder.constrain(h.astype(jnp.bfloat16), "experts", "expert_group",
                          None, "mlp")
    out_e = jnp.einsum("egcf,efd->egcd", h,
                       params["w_down"].astype(jnp.bfloat16),
                       preferred_element_type=F32)
    y = jnp.einsum("gsec,egcd->gsd", combine.astype(jnp.bfloat16),
                   out_e.astype(jnp.bfloat16), preferred_element_type=F32)
    y = y.reshape(B, S, d).astype(x.dtype)

    # ---- aux losses ----------------------------------------------------------
    # load balance: E * sum_e f_e * P_e  (f from top-1 assignment)
    f_e = jax.nn.one_hot(top_idx[..., 0], E, dtype=F32).mean(axis=(0, 1))
    p_e = probs.mean(axis=(0, 1))
    balance = E * jnp.sum(f_e * p_e)
    router_z = jnp.mean(jnp.square(jax.nn.logsumexp(logits, axis=-1)))
    aux = m.router_aux_coef * balance + 1e-3 * router_z
    return y, aux
