"""Block assembly: one function per layer *kind*.

Kinds (ModelConfig.layer_pattern entries):
  "attn"     — global attention + MLP (or MoE when cfg.moe is set)
  "local"    — sliding-window attention + MLP/MoE; ring-buffer KV cache
  "swa_ssm"  — hymba hybrid: parallel sliding-window attention + SSD heads,
               outputs mean-fused after per-path norm, then MLP
  "rwkv"     — rwkv6 time-mix + channel-mix (handles its own norms)

Every block is a pure function (params, x, cache) -> (x, cache, aux) so the
layer-stack scan, the per-period cost piece of the roofline analyzer, and
the smoke tests all share one implementation.
"""

from __future__ import annotations

from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.core.quant import dequantize_kv, quantize_kv
from repro.models import attention as attn
from repro.models import moe as moe_lib
from repro.models import rwkv as rwkv_lib
from repro.models import ssm as ssm_lib
from repro.models.layers import dot, mlp, mlp_specs, rmsnorm
from repro.models.params import ParamSpec

F32 = jnp.float32


# ---------------------------------------------------------------------------
# Specs
# ---------------------------------------------------------------------------


def block_specs(cfg: ModelConfig, kind: str, cross: bool = False
                ) -> Dict[str, ParamSpec]:
    if kind == "rwkv":
        return rwkv_lib.rwkv_specs(cfg)
    d = cfg.d_model
    norm = lambda: ParamSpec((d,), jnp.float32, (None,), init="zeros")
    specs: Dict[str, object] = {
        "norm1": norm(),
        "norm2": norm(),
        "attn": attn.attention_specs(cfg),
    }
    if cfg.moe is not None:
        specs["moe"] = moe_lib.moe_specs(cfg)
    else:
        specs["mlp"] = mlp_specs(cfg)
    if kind == "swa_ssm":
        specs["ssm"] = ssm_lib.ssm_specs(cfg)
        specs["attn_out_norm"] = norm()
        specs["ssm_out_norm"] = norm()
    if cross:
        specs["norm_cross"] = norm()
        specs["cross"] = attn.attention_specs(cfg)
    return specs


# ---------------------------------------------------------------------------
# KV-cache entry helpers (bf16 or int8 storage)
# ---------------------------------------------------------------------------


def _kv_store_dtype(cfg: ModelConfig):
    return jnp.int8 if cfg.kv_cache_dtype == "int8" else jnp.bfloat16


def _encode_kv(cfg: ModelConfig, k, v):
    """(B,S,K,hd) -> cache arrays (+ scales when int8)."""
    if cfg.kv_cache_dtype == "int8":
        kq, ks = quantize_kv(k)
        vq, vs = quantize_kv(v)
        return {"k": kq, "v": vq, "k_scale": ks[..., 0], "v_scale": vs[..., 0]}
    return {"k": k.astype(jnp.bfloat16), "v": v.astype(jnp.bfloat16)}


def _decode_kv(cfg: ModelConfig, entry):
    if cfg.kv_cache_dtype == "int8":
        k = dequantize_kv(entry["k"], entry["k_scale"][..., None])
        v = dequantize_kv(entry["v"], entry["v_scale"][..., None])
        return k, v
    return entry["k"], entry["v"]


def attn_cache_entry(cfg: ModelConfig, kind: str, batch: int, max_len: int,
                     as_specs: bool = True) -> Dict[str, ParamSpec]:
    """ParamSpec tree for one attention cache entry (pre-stacking)."""
    n = attn.cache_slot_count(cfg, kind, max_len)
    K, hd = cfg.n_kv_heads, cfg.head_dim_
    seq_ax = "window" if n < max_len else "cache_seq"
    dt = _kv_store_dtype(cfg)
    entry = {
        "k": ParamSpec((batch, n, K, hd), dt,
                       ("batch", seq_ax, "kv_heads", None), init="zeros"),
        "v": ParamSpec((batch, n, K, hd), dt,
                       ("batch", seq_ax, "kv_heads", None), init="zeros"),
        "pos": ParamSpec((batch, n), jnp.int32, ("batch", seq_ax),
                         init="custom",
                         custom_init=lambda k, s: -jnp.ones(s.shape, s.dtype)),
    }
    if cfg.kv_cache_dtype == "int8":
        entry["k_scale"] = ParamSpec((batch, n, K), jnp.float32,
                                     ("batch", seq_ax, "kv_heads"), init="ones")
        entry["v_scale"] = ParamSpec((batch, n, K), jnp.float32,
                                     ("batch", seq_ax, "kv_heads"), init="ones")
    return entry


# ---------------------------------------------------------------------------
# Attention sub-block (shared by attn / local / swa_ssm kinds)
# ---------------------------------------------------------------------------


def _attn_seq(params, x, cfg: ModelConfig, sharder, positions, *,
              window: int, mode: str, causal: bool = True, max_len: int = 0,
              tile_plan=None):
    """Full-sequence attention.  Returns (out, cache_entry_or_None)."""
    B, S, _ = x.shape
    q, k, v = attn.project_qkv(params, x, cfg, sharder, positions)
    pos2d = positions if positions.ndim == 2 else positions[:, 0]
    out = attn.flash_attention(
        q, k, v, pos2d, pos2d, cfg=cfg, sharder=sharder, causal=causal,
        window=window, tile_plan=tile_plan)
    out = out.reshape(B, S, cfg.q_dim)
    out = dot(out, params["wo"])
    entry = None
    if mode == "prefill":
        n_slots = min(window, max_len or S) if window else (max_len or S)
        kc, vc, pc = attn.fill_cache_from_prefill(k, v, pos2d, n_slots)
        entry = _encode_kv(cfg, kc, vc)
        entry["pos"] = pc.astype(jnp.int32)
    return out, entry


def _attn_step(params, x, cfg: ModelConfig, sharder, lengths, cache, *,
               window: int, positions=None, tile_plan=None):
    """One-token attention over the cache.  x: (B, 1, d)."""
    B = x.shape[0]
    pos = positions if positions is not None else lengths[:, None]
    q, k, v = attn.project_qkv(params, x, cfg, sharder, pos)
    n_slots = cache["k"].shape[1]
    ring = window > 0 and n_slots <= window
    new_kv = _encode_kv(cfg, k, v)
    idx = lengths % n_slots if ring else jnp.minimum(lengths, n_slots - 1)
    b = jnp.arange(B)
    entry = dict(cache)
    for name in ("k", "v", "k_scale", "v_scale"):
        if name in entry:
            entry[name] = entry[name].at[b, idx].set(new_kv[name][:, 0])
    entry["pos"] = entry["pos"].at[b, idx].set(lengths.astype(jnp.int32))
    kc, vc = _decode_kv(cfg, entry)
    out = attn.decode_attention(
        q[:, 0], kc, vc, entry["pos"], lengths, cfg=cfg, sharder=sharder,
        causal=True, window=window, tile_plan=tile_plan)
    out = out.reshape(B, 1, cfg.q_dim)
    out = dot(out.astype(x.dtype), params["wo"])
    return out, entry


def _cross_attn(params, x, cfg: ModelConfig, sharder, *, enc_out=None,
                cache=None, mode: str):
    """Encoder-decoder cross attention.  Caches projected enc k/v."""
    B, S, _ = x.shape
    if cache is not None and "xk" in cache:
        k, v = cache["xk"], cache["xv"]
    else:
        Se = enc_out.shape[1]
        kf = dot(enc_out, params["wk"])
        vf = dot(enc_out, params["wv"])
        k = kf.reshape(B, Se, cfg.n_kv_heads, cfg.head_dim_)
        v = vf.reshape(B, Se, cfg.n_kv_heads, cfg.head_dim_)
    qf = dot(x, params["wq"])
    q = qf.reshape(B, S, cfg.n_heads, cfg.head_dim_)
    Se = k.shape[1]
    kv_pos = jnp.broadcast_to(jnp.arange(Se, dtype=jnp.int32), (B, Se))
    if mode == "decode":
        out = attn.decode_attention(
            q[:, 0], k.astype(jnp.bfloat16), v.astype(jnp.bfloat16), kv_pos,
            jnp.full((B,), Se, jnp.int32), cfg=cfg, sharder=sharder,
            causal=False, window=0)
        out = out.reshape(B, 1, cfg.q_dim)
    else:
        q_pos = jnp.broadcast_to(jnp.arange(S, dtype=jnp.int32), (B, S))
        out = attn.flash_attention(
            q, k, v, q_pos, kv_pos, cfg=cfg, sharder=sharder, causal=False,
            window=0)
        out = out.reshape(B, S, cfg.q_dim)
    out = dot(out.astype(x.dtype), params["wo"])
    entry = {"xk": k.astype(jnp.bfloat16), "xv": v.astype(jnp.bfloat16)} \
        if mode == "prefill" else None
    return out, entry


# ---------------------------------------------------------------------------
# Full blocks
# ---------------------------------------------------------------------------


def _ffn(params, h, cfg: ModelConfig, sharder):
    if cfg.moe is not None:
        return moe_lib.moe_mlp(params["moe"], h, cfg, sharder)
    return mlp(params["mlp"], h, cfg, sharder), jnp.zeros((), F32)


def apply_block(params, x, cfg: ModelConfig, kind: str, sharder, *,
                positions=None, lengths=None, mode: str = "train",
                cache: Optional[Dict] = None, enc_out=None,
                causal: bool = True, max_len: int = 0, tile_plan=None):
    """Returns (x, new_cache_entry, aux_loss).

    In prefill mode ``lengths`` (when not None) marks each example's true
    prompt length within a right-padded batch: recurrent state updates are
    masked to the identity on padded steps (bucketed batched prefill);
    attention masks padding through the -1 entries of ``positions``.

    ``tile_plan`` is this kind's ``tile_plans`` entry (or None): an active
    pallas entry routes the hot-path math to the Pallas kernels with the
    DSE-chosen BlockSpec geometry.  The swa_ssm attention half stays on
    the jnp path — its plan entry models the SSD recurrence, for which no
    Pallas kernel exists yet."""
    if kind == "rwkv":
        x, new_cache = rwkv_lib.rwkv_block(
            params, x, cfg, sharder, mode=mode, cache=cache,
            lengths=lengths if mode == "prefill" else None,
            tile_plan=tile_plan)
        if mode == "train":
            new_cache = None
        return x, new_cache, jnp.zeros((), F32)

    window = cfg.local_window if kind in ("local", "swa_ssm") else 0
    new_cache: Dict = {}
    h = rmsnorm(x, params["norm1"], cfg.norm_eps)

    if kind == "swa_ssm":
        sub_attn = {k2: cache[k2] for k2 in ("k", "v", "pos", "k_scale",
                                             "v_scale") if cache and k2 in cache} \
            if cache else None
        sub_ssm = {k2: cache[k2] for k2 in ("conv_state", "ssd_state")} \
            if cache else None
        if mode == "decode":
            a_out, a_cache = _attn_step(params["attn"], h, cfg, sharder,
                                        lengths, sub_attn, window=window)
        else:
            a_out, a_cache = _attn_seq(params["attn"], h, cfg, sharder,
                                       positions, window=window, mode=mode,
                                       causal=causal, max_len=max_len)
        s_out, s_cache = ssm_lib.ssm_mixer(
            params["ssm"], h, cfg, sharder, mode=mode, cache=sub_ssm,
            lengths=lengths if mode == "prefill" else None)
        fused = 0.5 * (rmsnorm(a_out, params["attn_out_norm"], cfg.norm_eps)
                       + rmsnorm(s_out, params["ssm_out_norm"], cfg.norm_eps))
        x = x + fused
        if a_cache:
            new_cache.update(a_cache)
        if s_cache and mode != "train":
            new_cache.update(s_cache)
    else:
        if mode == "decode":
            a_out, a_cache = _attn_step(params["attn"], h, cfg, sharder,
                                        lengths, cache, window=window,
                                        positions=positions,
                                        tile_plan=tile_plan)
        else:
            a_out, a_cache = _attn_seq(params["attn"], h, cfg, sharder,
                                       positions, window=window, mode=mode,
                                       causal=causal, max_len=max_len,
                                       tile_plan=tile_plan)
        x = x + a_out
        if a_cache:
            new_cache.update(a_cache)

    if "cross" in params:
        hc = rmsnorm(x, params["norm_cross"], cfg.norm_eps)
        c_out, c_cache = _cross_attn(params["cross"], hc, cfg, sharder,
                                     enc_out=enc_out, cache=cache, mode=mode)
        x = x + c_out
        if c_cache:
            new_cache.update(c_cache)
        elif cache is not None and "xk" in cache:
            new_cache["xk"], new_cache["xv"] = cache["xk"], cache["xv"]

    h = rmsnorm(x, params["norm2"], cfg.norm_eps)
    f_out, aux = _ffn(params, h, cfg, sharder)
    x = x + f_out
    return x, (new_cache if mode != "train" else None), aux
