"""Shared neural-net layers: norms, rotary embeddings (incl. M-RoPE),
gated/non-gated MLPs, embedding / logit head.

Everything is a pure function over an explicit params dict; parameter
shapes/logical axes come from the matching ``*_specs`` function.  Matmuls
accumulate in f32 (``preferred_element_type``) regardless of the bf16
compute dtype, mirroring the paper's narrow-multiply / wide-accumulate
mixed-precision scheme at the XLA level.
"""

from __future__ import annotations

import math
from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models.params import ParamSpec

F32 = jnp.float32


def wcast(w, dtype) -> jax.Array:
    """Weight view: plain array -> cast; int8-quantized dict -> dequantize.

    The paper's mixed-precision scheme at the XLA level: weights may be
    *stored* int8 (HBM reads halve) and are widened right at the consuming
    matmul, where XLA fuses the convert+scale into the operand so the wide
    copy never materializes."""
    if isinstance(w, dict):
        return (w["q"].astype(F32) * w["scale"].astype(F32)).astype(dtype)
    return w.astype(dtype)


def dot(x: jax.Array, w) -> jax.Array:
    """x @ w with f32 accumulation, result cast back to x.dtype."""
    w = wcast(w, x.dtype)
    y = jax.lax.dot_general(
        x, w,
        (((x.ndim - 1,), (0,)), ((), ())),
        preferred_element_type=F32,
    )
    return y.astype(x.dtype)


# ---------------------------------------------------------------------------
# Norms
# ---------------------------------------------------------------------------


def rmsnorm_specs(d: int, name_axes: Tuple = (None,)) -> ParamSpec:
    return ParamSpec((d,), jnp.float32, name_axes, init="zeros")


def rmsnorm(x: jax.Array, scale: jax.Array, eps: float = 1e-6) -> jax.Array:
    """RMSNorm with (1 + scale) parametrization (gemma/llama style)."""
    dtype = x.dtype
    x = x.astype(F32)
    var = jnp.mean(jnp.square(x), axis=-1, keepdims=True)
    y = x * jax.lax.rsqrt(var + eps)
    return (y * (1.0 + scale.astype(F32))).astype(dtype)


def groupnorm_heads(x: jax.Array, scale: jax.Array, n_heads: int,
                    eps: float = 1e-6) -> jax.Array:
    """Per-head RMS normalization of a (..., n_heads * head_dim) tensor
    (RWKV's wkv output GroupNorm / gemma3 qk-norm building block)."""
    dtype = x.dtype
    *lead, d = x.shape
    x = x.reshape(*lead, n_heads, d // n_heads).astype(F32)
    var = jnp.mean(jnp.square(x), axis=-1, keepdims=True)
    y = x * jax.lax.rsqrt(var + eps)
    y = y.reshape(*lead, d)
    return (y * (1.0 + scale.astype(F32))).astype(dtype)


# ---------------------------------------------------------------------------
# Rotary position embeddings
# ---------------------------------------------------------------------------


def rope_frequencies(head_dim: int, theta: float) -> jax.Array:
    half = head_dim // 2
    return 1.0 / (theta ** (jnp.arange(0, half, dtype=F32) / half))


def apply_rope(x: jax.Array, positions: jax.Array, theta: float) -> jax.Array:
    """Standard RoPE.  x: (B, S, H, D), positions: (B, S) int32."""
    freqs = rope_frequencies(x.shape[-1], theta)                  # (D/2,)
    angles = positions.astype(F32)[..., None] * freqs             # (B, S, D/2)
    cos = jnp.cos(angles)[:, :, None, :]
    sin = jnp.sin(angles)[:, :, None, :]
    x1, x2 = jnp.split(x.astype(F32), 2, axis=-1)
    out = jnp.concatenate((x1 * cos - x2 * sin, x2 * cos + x1 * sin), axis=-1)
    return out.astype(x.dtype)


def apply_m_rope(x: jax.Array, positions: jax.Array, theta: float,
                 sections: Tuple[int, ...]) -> jax.Array:
    """Qwen2-VL multimodal RoPE.

    x: (B, S, H, D); positions: (B, 3, S) — (temporal, height, width) streams.
    ``sections`` splits the D/2 rotary pairs across the three streams.
    """
    half = x.shape[-1] // 2
    assert sum(sections) == half, (sections, half)
    freqs = rope_frequencies(x.shape[-1], theta)                  # (D/2,)
    # angle per stream: (B, 3, S, D/2)
    angles = positions.astype(F32)[..., None] * freqs
    # select the stream each rotary-pair section listens to
    stream_id = jnp.repeat(
        jnp.arange(3), jnp.array(sections), total_repeat_length=half)  # (D/2,)
    select = jax.nn.one_hot(stream_id, 3, dtype=F32).T                 # (3, D/2)
    angles = jnp.einsum("bksd,kd->bsd", angles, select)                # (B, S, D/2)
    cos = jnp.cos(angles)[:, :, None, :]
    sin = jnp.sin(angles)[:, :, None, :]
    x1, x2 = jnp.split(x.astype(F32), 2, axis=-1)
    out = jnp.concatenate((x1 * cos - x2 * sin, x2 * cos + x1 * sin), axis=-1)
    return out.astype(x.dtype)


# ---------------------------------------------------------------------------
# MLP
# ---------------------------------------------------------------------------

_ACTS = {
    "silu": jax.nn.silu,
    "gelu": jax.nn.gelu,
    "relu_sq": lambda x: jnp.square(jax.nn.relu(x)),
}


def mlp_specs(cfg: ModelConfig) -> Dict[str, ParamSpec]:
    d, f = cfg.d_model, cfg.d_ff
    specs = {
        "w_up": ParamSpec((d, f), jnp.float32, ("embed", "mlp")),
        "w_down": ParamSpec((f, d), jnp.float32, ("mlp", "embed")),
    }
    if cfg.mlp_gated:
        specs["w_gate"] = ParamSpec((d, f), jnp.float32, ("embed", "mlp"))
    return specs


def mlp(params: Dict[str, jax.Array], x: jax.Array, cfg: ModelConfig,
        sharder) -> jax.Array:
    act = _ACTS[cfg.mlp_act]
    up = dot(x, params["w_up"])
    if cfg.mlp_gated:
        gate = dot(x, params["w_gate"])
        h = act(gate) * up
    else:
        h = act(up)
    h = sharder.constrain(h, "batch", "seq", "mlp")
    return dot(h, params["w_down"])


# ---------------------------------------------------------------------------
# Embedding / head
# ---------------------------------------------------------------------------


def embed_specs(cfg: ModelConfig) -> Dict[str, ParamSpec]:
    v, d = cfg.padded_vocab, cfg.d_model
    specs = {"embedding": ParamSpec((v, d), jnp.float32, ("vocab", "embed"),
                                    scale=1.0)}
    if not cfg.tie_embeddings:
        specs["lm_head"] = ParamSpec((d, v), jnp.float32, ("embed", "vocab"))
    return specs


def embed(params, tokens: jax.Array, cfg: ModelConfig, sharder) -> jax.Array:
    x = params["embedding"].astype(jnp.bfloat16)[tokens]
    if cfg.scale_embeddings:
        x = x * jnp.asarray(math.sqrt(cfg.d_model), x.dtype)
    return sharder.constrain(x, "batch", "seq", None)


def unembed(params, x: jax.Array, cfg: ModelConfig, sharder) -> jax.Array:
    """Final logits (f32)."""
    if cfg.tie_embeddings:
        w = wcast(params["embedding"], x.dtype).T
    else:
        w = wcast(params["lm_head"], x.dtype)
    # logits stay vocab-sharded even under sequence parallelism: gathering
    # the (small) hidden beats all-reducing the (huge) logits in bwd
    x = sharder.constrain(x, "batch", "logit_seq", None)
    logits = jax.lax.dot_general(
        x, w, (((x.ndim - 1,), (0,)), ((), ())), preferred_element_type=F32)
    if cfg.final_softcap > 0.0:
        c = cfg.final_softcap
        logits = c * jnp.tanh(logits / c)
    return sharder.constrain(logits, "batch", "logit_seq", "vocab")
