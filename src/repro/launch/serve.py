"""Serving launcher: batched request serving through the continuous-
batching engine, optionally under an open-loop arrival process.

The CLI is *plan-centric*: every serving design parameter lives in a
:class:`repro.plan.ServingPlan`, and the engine is built from one.

  # legacy closed-loop mode: submit N requests up front, drain
  PYTHONPATH=src python -m repro.launch.serve --arch rwkv6-1.6b --reduced \\
      --requests 8 --max-new 16

  # the paper's real-time scenario: Poisson arrivals, latency percentiles
  PYTHONPATH=src python -m repro.launch.serve --arch rwkv6-1.6b --reduced \\
      --arrival poisson --rate 0.5 --duration 64 --seed 0

  # serve a recorded design point (e.g. one embedded in BENCH_serving.json)
  PYTHONPATH=src python -m repro.launch.serve --plan plan.json \\
      --arrival poisson --rate 0.8 --duration 64

  # search the design space for this workload, save + serve the winner
  PYTHONPATH=src python -m repro.launch.serve --arch rwkv6-1.6b --reduced \\
      --autotune --arrival poisson --rate 2.0 --duration 64 \\
      --deadline-slack 3.0 --save-plan tuned.json

``--plan`` loads a plan JSON (``repro.plan.io``); ``--autotune`` runs the
serving-level design-space search (``repro.plan.planner``) against the
CLI-described workload.  Any knob flag given *in addition* is an explicit
override of the plan and is recorded in ``plan.provenance`` — so a served
plan always says where each of its values came from.  Without either,
the flags resolve to the historical CLI defaults and build a plan
internally: the behavior (and the virtual-clock schedule) is unchanged.

``--arrival {poisson,mmpp,trace}`` replays a workload from
``repro.serving.workload`` and prints the TTFT/TPOT/queue-wait percentile
summary.  ``--clock virtual`` (default) is deterministic — the metrics are
a pure function of (workload, seed); ``--clock wall`` paces arrivals in
real time and additionally reports measured wall tokens/sec.

``--policy`` choices are generated from the scheduler registry
(``repro.serving.scheduler.SCHEDULERS``) so the CLI can never offer a
policy the engine does not implement; the benchmark smoke guard asserts
this stays true.  ``--deadline-slack S`` stamps every generated request
with the absolute deadline ``arrival + S * max_new`` clock units, and
``--shed-late`` turns on deadline-aware admission control (reject
provably-late requests at submit).

Observability (``repro.obs``): ``--trace-out trace.json`` records a
structured event trace — request lifecycle spans and engine events on
the virtual clock — as Chrome ``trace_event`` JSON, viewable at
https://ui.perfetto.dev; ``--live-metrics [N]`` prints a rolling
p95-TTFT/TPOT/SLO/utilization line over the last N ticks while serving.
A recorded trace feeds ``WorkloadProfile.from_trace`` /
``planner.autotune_from_trace`` to replan from observed traffic.
"""

from __future__ import annotations

import argparse
import dataclasses
import logging
import time

import jax
import numpy as np

from repro.configs import get_config
from repro.dist.sharding import make_sharder
from repro.models.lm import build_model
from repro.plan import ServingPlan, WorkloadProfile, io as plan_io
from repro.serving import ServingEngine
from repro.serving import metrics as smetrics
from repro.serving import workload as wl
from repro.serving.router import ROUTING_POLICIES
from repro.serving.scheduler import POLICIES
from repro.testing import reduced_config

# CLI flag -> plan field, for flags that map 1:1 (None = "not given";
# the plan's value stands unless the user typed the flag)
_PLAN_FLAGS = (
    ("arch", "arch"),
    ("reduced", "reduced"),
    ("max_batch", "max_batch"),
    ("max_len", "max_len"),
    ("cache_layout", "cache_layout"),
    ("temperature", "temperature"),
    ("sync_every", "sync_every"),
    ("policy", "policy"),
    ("preempt", "preempt"),
    ("shed_late", "shed_late"),
    ("truncate_prompts", "truncate_prompts"),
    ("retry_budget", "retry_budget"),
    ("watchdog_ticks", "watchdog_ticks"),
)

# the pre-plan CLI defaults, applied only when no plan file is loaded so
# a flagless invocation behaves exactly as it always has
_CLI_DEFAULT_MAX_LEN = 64


def build_parser() -> argparse.ArgumentParser:
    """The CLI surface, as a factory so tools (and the benchmark smoke
    guard) can introspect it without running a model.

    Plan-covered knobs default to ``None`` ("not given"): their effective
    defaults live in :class:`repro.plan.ServingPlan`, and a given flag
    becomes a recorded override of whatever plan is in force."""
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None,
                    help="architecture id (required unless --plan carries "
                         "one)")
    ap.add_argument("--reduced", action="store_true", default=None)
    ap.add_argument("--plan", default=None, metavar="PATH",
                    help="load a ServingPlan JSON (e.g. saved by "
                         "--save-plan, or the 'plan' dict of a committed "
                         "BENCH_serving.json cell); knob flags given as "
                         "well become recorded overrides")
    ap.add_argument("--autotune", action="store_true",
                    help="search the serving design space (bucket set x "
                         "sync_every x max_batch x policy) for the "
                         "CLI-described workload and serve the winning "
                         "plan (repro.plan.planner.autotune)")
    ap.add_argument("--save-plan", default=None, metavar="PATH",
                    help="write the resolved plan (explicit buckets, "
                         "provenance included) as JSON before serving")
    ap.add_argument("--hw-spec", default=None, metavar="NAME",
                    help="hardware spec the kernel tile plans are scored "
                         "against (repro.hw registry, e.g. tpu-v5e / "
                         "plasticine-rnn-variant); giving it recomputes "
                         "tile_plans even when a --plan file carries them")
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--max-new", type=int, default=16)
    ap.add_argument("--max-batch", type=int, default=None,
                    help="decode slots (plan default: 4)")
    ap.add_argument("--max-len", type=int, default=None,
                    help=f"cache length (CLI default: "
                         f"{_CLI_DEFAULT_MAX_LEN})")
    ap.add_argument("--cache-layout", default=None, metavar="LAYOUT",
                    help="cache backing layout: 'dense' (one fixed column "
                         "per slot) or 'paged:<block_size>' (block-table "
                         "pool along the length axis, bit-exact schedules "
                         "either way; plan default dense, autotune searches "
                         "both)")
    ap.add_argument("--temperature", type=float, default=None)
    ap.add_argument("--seed", type=int, default=0,
                    help="workload + sampler seed")
    ap.add_argument("--sync-every", type=int, default=None,
                    help="decode ticks per host sync: the fused on-device "
                         "decode loop runs this many ticks between host "
                         "interventions (admission/retire); plan default 1")
    ap.add_argument("--policy", default=None, choices=POLICIES,
                    help="admission order: FCFS, shortest-prompt-first, or "
                         "earliest-deadline-first (choices come from the "
                         "scheduler registry; plan default fcfs)")
    ap.add_argument("--preempt", action="store_true", default=None,
                    help="allow the scheduler to evict a running request "
                         "to host memory when a strictly tighter deadline "
                         "waits (EDF only); evicted requests resume "
                         "bit-exactly once a slot frees")
    ap.add_argument("--shed-late", action="store_true", default=None,
                    help="deadline-aware admission control: reject "
                         "requests at submit when they provably cannot "
                         "meet their deadline even if admitted instantly")
    ap.add_argument("--no-bucketed-prefill", action="store_true",
                    default=None,
                    help="legacy exact-length batch-1 prefill per request "
                         "(compiles per distinct prompt length) instead of "
                         "length-bucketed batched prefill")
    ap.add_argument("--no-overlap-prefill", action="store_true",
                    default=None,
                    help="serialize admission with decode: block on the "
                         "prefill sample readback before launching the "
                         "decode chunk (the pre-overlap engine behaviour; "
                         "the schedule is identical either way)")
    # multi-replica serving tier (repro.serving.router)
    ap.add_argument("--replicas", type=int, default=None, metavar="N",
                    help="serve through a fleet of N engine replicas "
                         "behind the router (homogeneous: each replica "
                         "gets the resolved plan); arrival process "
                         "required, virtual clock only")
    ap.add_argument("--routing", default=None, choices=ROUTING_POLICIES,
                    help="fleet routing policy (choices come from the "
                         "router registry; default round_robin)")
    ap.add_argument("--prefill-replicas", type=int, default=None,
                    metavar="K",
                    help="disaggregate: the first K replicas run "
                         "admission/prefill only and stream slot state "
                         "into the decode replicas over a modeled DCN "
                         "transit (requires --replicas > K)")
    # open-loop arrival process (the paper's asynchronous-serving scenario)
    ap.add_argument("--arrival", default="batch",
                    choices=("batch",) + wl.ARRIVAL_KINDS,
                    help="'batch' submits --requests up front (legacy); "
                         "poisson/mmpp/trace replay an arrival process")
    ap.add_argument("--rate", type=float, default=0.5,
                    help="arrival rate, requests per clock unit")
    ap.add_argument("--duration", type=float, default=64.0,
                    help="workload span in clock units")
    ap.add_argument("--prompt-dist", default="uniform",
                    choices=wl.PROMPT_DISTS,
                    help="prompt-length distribution for generated "
                         "workloads (bimodal = long-tail prompts, the "
                         "regime where preemption pays)")
    ap.add_argument("--deadline-slack", type=float, default=None,
                    help="stamp generated requests with the absolute "
                         "deadline arrival + SLACK * max_new clock units "
                         "(decode-proportional: slot occupancy is decode "
                         "length on the virtual clock); enables the SLO "
                         "block and gives EDF something to order by")
    ap.add_argument("--deadline-frac", type=float, default=1.0,
                    help="fraction of generated requests carrying a "
                         "deadline (rest are best-effort)")
    ap.add_argument("--trace-file", default=None,
                    help="JSONL trace for --arrival trace (see "
                         "repro.serving.workload.save_trace; traces carry "
                         "their own optional per-request deadlines)")
    ap.add_argument("--clock", default="virtual",
                    choices=("virtual", "wall"),
                    help="virtual: deterministic tick clock; wall: pace "
                         "arrivals in real time")
    ap.add_argument("--truncate-prompts", action="store_true", default=None,
                    help="warn + drop the tail of prompts longer than "
                         "max_len-1 instead of rejecting them (useful when "
                         "replaying traces recorded on a larger engine)")
    # fault tolerance (repro.serving.faults)
    ap.add_argument("--retry-budget", type=int, default=None,
                    help="recoveries per request before it is shed "
                         "(plan default 3)")
    ap.add_argument("--watchdog-ticks", type=int, default=None,
                    help="evict a slot after this many ticks without "
                         "progress (plan default 0 = watchdog off; "
                         "required to serve a fault plan with stall_slot)")
    ap.add_argument("--fault-spec", default=None, metavar="PATH",
                    help="inject faults from a FaultPlan JSON "
                         "(repro.serving.faults) and serve through the "
                         "crash-restartable driver; virtual clock only — "
                         "faults are tick-scheduled and restarts rewind "
                         "time")
    ap.add_argument("--checkpoint-dir", default=None, metavar="DIR",
                    help="journal engine state here every "
                         "--checkpoint-every ticks while serving under "
                         "--fault-spec (required when the fault plan "
                         "contains kill_engine)")
    ap.add_argument("--checkpoint-every", type=int, default=8,
                    help="ticks between engine checkpoints under "
                         "--checkpoint-dir (default 8)")
    # observability (repro.obs)
    ap.add_argument("--trace-out", default=None, metavar="PATH",
                    help="record a structured event trace (request "
                         "lifecycle spans + engine events on the virtual "
                         "clock) and write Chrome trace_event JSON here — "
                         "open it at https://ui.perfetto.dev; same-seed "
                         "virtual-clock runs write byte-identical files")
    ap.add_argument("--live-metrics", type=int, nargs="?", const=32,
                    default=None, metavar="N",
                    help="print a rolling serving line (p95 TTFT/TPOT, "
                         "SLO attainment, utilization over the last N "
                         "ticks) every N engine ticks (default N=32)")
    ap.add_argument("-v", "--verbose", action="store_true",
                    help="DEBUG logging: per-tick engine utilization lines")
    return ap


def _workload_profile(args) -> WorkloadProfile:
    """The CLI-described workload as a declarative profile (drives both
    the autotuner and the replay loop)."""
    kind = args.arrival if args.arrival != "batch" else "poisson"
    return WorkloadProfile(
        kind=kind, rate=args.rate, duration=args.duration,
        max_new_tokens=(args.max_new, args.max_new),
        prompt_dist=args.prompt_dist,
        deadline_slack=args.deadline_slack,
        deadline_frac=args.deadline_frac,
        trace_path=args.trace_file)


def resolve_plan(args, parser: argparse.ArgumentParser) -> ServingPlan:
    """Turn the parsed CLI into one validated plan.

    Precedence: ``--plan`` file or ``--autotune`` result as the base
    (plain CLI defaults otherwise), then every explicitly-given knob flag
    overrides its plan field — and the override set is recorded under
    ``provenance["cli_overrides"]`` so the served design point is fully
    accounted for."""
    overrides = {}
    for flag, field in _PLAN_FLAGS:
        v = getattr(args, flag)
        if v is not None:
            overrides[field] = v
    if args.no_bucketed_prefill:
        overrides["bucketed_prefill"] = False
    if args.no_overlap_prefill:
        overrides["overlap_prefill"] = False

    if args.plan and args.autotune:
        parser.error("--plan and --autotune are mutually exclusive")
    if args.plan:
        base = plan_io.load_plan(args.plan)
        source = f"file:{args.plan}"
    elif args.autotune:
        if not args.arch:
            parser.error("--autotune requires --arch")
        from repro.plan import planner

        base = planner.autotune(
            args.arch, _workload_profile(args), seed=args.seed,
            reduced=bool(args.reduced),
            max_len=args.max_len or _CLI_DEFAULT_MAX_LEN)
        source = "autotune"
    else:
        if not args.arch:
            parser.error("--arch is required (or pass --plan)")
        base = ServingPlan(arch=args.arch, reduced=bool(args.reduced),
                           max_len=_CLI_DEFAULT_MAX_LEN)
        source = "cli"
    # a typed flag only *overrides* when it changes the base plan's value
    # (e.g. --autotune requires --arch, which the autotuned plan already
    # carries; recording it would misstate the plan's provenance)
    overrides = {k: v for k, v in overrides.items()
                 if getattr(base, k) != v}
    # a max_len override invalidates an explicit bucket set pinned to the
    # old max_len-1 (resolved plans — e.g. BENCH-embedded ones — always
    # carry one): reset it to the new default rather than failing
    # validation, and record the reset like any other override
    new_len = overrides.get("max_len")
    if (new_len is not None and base.buckets is not None
            and base.buckets[-1] != new_len - 1):
        overrides["buckets"] = None
    # tile plans are scored at (arch, max_batch, max_len, hardware) —
    # overriding any of those would leave a stale kernel design half, so
    # recompute them; an explicit --hw-spec always recomputes (the whole
    # point of the flag is rescoring the kernel half for other silicon)
    from repro import hw

    try:
        hw_spec = hw.get_spec(args.hw_spec) if args.hw_spec else hw.DEFAULT
    except KeyError as e:
        parser.error(str(e))
    stale = {"arch", "max_batch", "max_len"} & set(overrides)
    if args.hw_spec or (base.tile_plans and stale):
        from repro.plan import planner

        tp = planner.tile_plans_for(
            overrides.get("arch", base.arch),
            overrides.get("max_batch", base.max_batch), hw_spec,
            max_len=overrides.get("max_len", base.max_len))
        if tp != dict(base.tile_plans):
            overrides["tile_plans"] = tp
    plan = dataclasses.replace(base, **overrides) if overrides else base
    prov = dict(plan.provenance)
    prov["source"] = source
    if overrides:
        prov["cli_overrides"] = dict(overrides)
    return dataclasses.replace(plan, provenance=prov).validate()


def _serve_fleet(args, parser, plan) -> None:
    """Serve through a multi-replica :class:`Router` fleet.

    Homogeneous: every replica runs the resolved plan.  The fleet shares
    one deterministic virtual clock, so this path is replay-exact — the
    same seed yields byte-identical fleet schedules."""
    from repro.plan.plan import FleetPlan
    from repro.serving.router import Router, drive_fleet

    n = int(args.replicas or 1)
    k = int(args.prefill_replicas or 0)
    if n < 1:
        parser.error("--replicas must be >= 1")
    if not 0 <= k < n:
        parser.error("--prefill-replicas must leave at least one decode "
                     "replica (need 0 <= K < --replicas)")
    if args.arrival == "batch":
        parser.error("the fleet router needs an arrival process "
                     "(--arrival poisson/mmpp/trace): requests are routed "
                     "on the shared replay clock")
    if args.clock != "virtual":
        parser.error("--replicas requires --clock virtual: the fleet "
                     "replicas share one deterministic clock")
    if args.fault_spec:
        parser.error("--fault-spec does not compose with --replicas: "
                     "fault injection drives a single engine")
    fleet = FleetPlan.replicated(
        plan, n, routing=args.routing or "round_robin", n_prefill=k,
        provenance={"source": "launch.serve"}).validate()
    print(f"fleet: {fleet.summary()}")

    cfg = reduced_config(plan.arch) if plan.reduced else get_config(plan.arch)
    tracers = None
    if args.trace_out:
        from repro.obs import Tracer

        tracers = [Tracer() for _ in range(n)]
    router = Router.from_plan(fleet, seed=args.seed, tracers=tracers)

    profile = _workload_profile(args)
    items = wl.profile_items(profile, vocab_size=cfg.vocab_size,
                             seed=args.seed)
    span = None if args.arrival == "trace" else args.duration
    shown = span if span is not None else max((it.t for it in items),
                                              default=0.0)
    print(f"replaying {len(items)} {args.arrival} arrivals over "
          f"{shown:g} virtual-clock units across {n} replicas "
          f"(offered {wl.offered_load(items, span):.2f} tok/unit)")
    clock = wl.VirtualClock()
    t0 = time.time()
    reqs = drive_fleet(router, items, clock)
    dt = time.time() - t0
    agg = router.fleet_aggregate()
    print(smetrics.format_summary(agg))
    for i, eng in enumerate(router.engines):
        role = "prefill" if i < k else "decode"
        s = eng.stats()
        print(f"  replica[{i}] ({role}): {len(router.assigned[i])} routed, "
              f"{s['ticks']} ticks, {s['prefill_calls']} prefill calls, "
              f"{s['host_syncs']} host syncs")
    if k:
        ts = router.transit_stats()
        print(f"transit: {ts['handoffs']} handoffs, {ts['delivered']} "
              f"delivered, {ts['bytes']} bytes over {ts['ticks']} transit "
              f"ticks (bytes/tick {ts['bytes_per_tick']})")
    census = router.conservation_census()
    if census["total"] != len(reqs):
        raise RuntimeError(f"request conservation violated: {census}")
    print(f"wall: {dt:.2f}s ({len(reqs)} requests conserved)")
    if tracers is not None:
        from repro.obs import dumps_trace_doc, merge_traces

        doc = dumps_trace_doc(merge_traces(tracers))
        with open(args.trace_out, "w") as f:
            f.write(doc)
        print(f"wrote merged fleet trace ({n} replicas) to "
              f"{args.trace_out} (open at https://ui.perfetto.dev)")


def main() -> None:
    parser = build_parser()
    args = parser.parse_args()

    logging.basicConfig(level=logging.INFO,
                        format="%(asctime)s %(name)s %(message)s")
    if args.verbose:  # scope DEBUG to our loggers; root DEBUG floods w/ jax
        logging.getLogger("repro").setLevel(logging.DEBUG)

    plan = resolve_plan(args, parser)
    if args.replicas is not None or args.prefill_replicas:
        _serve_fleet(args, parser, plan)
        return
    if args.routing:
        parser.error("--routing only applies to a fleet; pass --replicas N")
    fault_plan = None
    if args.fault_spec:
        from repro.serving import FaultPlan

        if args.arrival == "batch":
            parser.error("--fault-spec needs an arrival process "
                         "(--arrival poisson/mmpp/trace): faults are "
                         "scheduled on the replay clock")
        if args.clock != "virtual":
            parser.error("--fault-spec requires --clock virtual: faults "
                         "are tick-scheduled and restarts rewind time")
        fault_plan = FaultPlan.load(args.fault_spec)
        if fault_plan.needs_watchdog() and plan.watchdog_ticks <= 0:
            parser.error("the fault plan stalls slots but the watchdog is "
                         "off; pass --watchdog-ticks N (stalled slots only "
                         "recover by watchdog eviction)")
        if fault_plan.needs_checkpoints() and not args.checkpoint_dir:
            parser.error("the fault plan kills the engine; pass "
                         "--checkpoint-dir DIR so it can restart from a "
                         "checkpoint")
    print(f"plan: {plan.summary()}")
    if plan.tile_plans:
        from repro.plan.plan import tiles_summary
        print(f"kernel tiles: {tiles_summary(plan.tile_plans)}")
    if args.save_plan:
        plan_io.save_plan(plan.resolve(), args.save_plan)
        print(f"wrote plan to {args.save_plan}")

    cfg = reduced_config(plan.arch) if plan.reduced else get_config(plan.arch)
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    sharder = make_sharder(cfg, None, plan.shard_mode)
    tracer = None
    if args.trace_out:
        from repro.obs import Tracer

        tracer = Tracer()
    engine = ServingEngine.from_plan(plan, params, model=model,
                                     sharder=sharder, seed=args.seed,
                                     tracer=tracer)
    live = (engine.enable_live_metrics(args.live_metrics)
            if args.live_metrics else None)

    def _save_trace() -> None:
        if tracer is not None:
            tracer.save(args.trace_out)
            print(f"wrote {len(tracer)} trace events to {args.trace_out} "
                  f"(open at https://ui.perfetto.dev)")

    if args.arrival == "batch":
        rng = np.random.default_rng(args.seed)
        reqs = []
        for _ in range(args.requests):
            prompt = rng.integers(0, cfg.vocab_size,
                                  size=rng.integers(4, 12)).tolist()
            reqs.append(engine.submit(prompt, max_new_tokens=args.max_new))
        t0 = time.time()
        engine.run()
        dt = time.time() - t0
        total = sum(len(r.output) for r in reqs)
        print(f"served {len(reqs)} requests, {total} tokens in {dt:.2f}s "
              f"({total/dt:.1f} tok/s)")
        print(f"engine stats: {engine.stats()}")
        for r in reqs[:3]:
            print(f"  req {r.uid}: prompt[:6]={r.prompt[:6]} -> {r.output[:8]}")
        assert all(r.done for r in reqs)
        if live is not None:
            print(live.line())
        _save_trace()
        return

    profile = _workload_profile(args)
    items = wl.profile_items(profile, vocab_size=cfg.vocab_size,
                             seed=args.seed)
    # declared span for generated workloads; a trace only knows its arrivals
    span = None if args.arrival == "trace" else args.duration
    shown = span if span is not None else max((it.t for it in items),
                                              default=0.0)
    print(f"replaying {len(items)} {args.arrival} arrivals over "
          f"{shown:g} {args.clock}-clock units "
          f"(offered {wl.offered_load(items, span):.2f} tok/unit)")
    if args.clock == "wall":
        # warm the fused decode chunk + the prefill jit cache (one compile
        # per length *bucket* the workload will hit) so tick_seconds
        # measures steady-state serving, not XLA compiles
        for n in sorted({engine.bucket(len(it.prompt)) for it in items}):
            engine.submit([1] * n, max_new_tokens=2)
        engine.run()
        engine.reset_telemetry()
    clock = wl.WallClock() if args.clock == "wall" else wl.VirtualClock()
    on_tick = None
    if live is not None:
        period = args.live_metrics
        last_print = [0]

        def on_tick(tick: int) -> None:
            if tick - last_print[0] >= period:
                last_print[0] = tick
                print(live.line())
    t0 = time.time()
    report = None
    if fault_plan is not None:
        from repro.checkpoint import CheckpointManager
        from repro.serving import FaultInjector, drive_resilient

        manager = (CheckpointManager(args.checkpoint_dir)
                   if args.checkpoint_dir else None)
        report = drive_resilient(engine, items, clock,
                                 injector=FaultInjector(fault_plan),
                                 manager=manager,
                                 checkpoint_every=args.checkpoint_every,
                                 on_tick=on_tick)
        engine = report.engine   # a kill_engine fault swaps the instance
        reqs = report.requests
    else:
        reqs = wl.drive(engine, items, clock, on_tick=on_tick)
    dt = time.time() - t0
    # per-tick cost from busy time only: at low rates most of dt is idle
    # sleep between arrivals, which must not inflate the latency scaling
    tick_s = (clock.busy_seconds / max(1, engine.ticks)
              if args.clock == "wall" else 1.0)
    agg = smetrics.aggregate(reqs, ticks=engine.ticks,
                             util_history=engine.util_history,
                             tick_seconds=tick_s)
    print(smetrics.format_summary(agg))
    s = engine.stats()
    print(f"hot path: {s['host_syncs']} host syncs / {s['ticks']} ticks "
          f"({s['host_syncs'] / max(1, s['ticks']):.2f}/tick, "
          f"sync_every={engine.sync_every}), "
          f"{s['prefill_calls']} prefill calls over "
          f"{s['prefill_compiles']} compiled shapes, "
          f"{s['instant_admits']} instant admits")
    if s["preemptions"] or s["shed"]:
        print(f"scheduler: {s['preemptions']} preemptions / "
              f"{s['resumes']} resumes, {s['evicted_tokens']} tokens "
              f"evicted to host, {s['shed']} requests shed at submit")
    if report is not None:
        fs = engine.fault_stats()
        print(f"faults: {fs['injected']:.0f} injected, "
              f"{fs['quarantined']:.0f} quarantined "
              f"({fs['watchdog_evictions']:.0f} by watchdog), "
              f"{fs['retries']:.0f} retries, {fs['shed']:.0f} shed; "
              f"{report.n_restarts} engine restarts "
              f"({report.restart_ticks_lost} ticks replayed)")
        lost = report.lost_uids()
        if lost:
            raise RuntimeError(f"lost requests (neither done nor shed): "
                               f"{lost}")
        print(f"recovery: {len(report.completed)} completed, "
              f"{len(report.shed_uids)} shed, 0 lost")
    if args.clock == "wall":
        print(f"wall: {dt:.2f}s, {agg['tokens'] / dt:.1f} tok/s measured")
    _save_trace()


if __name__ == "__main__":
    main()
