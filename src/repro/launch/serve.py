"""Serving launcher: batched request serving through the continuous-
batching engine, optionally under an open-loop arrival process.

  # legacy closed-loop mode: submit N requests up front, drain
  PYTHONPATH=src python -m repro.launch.serve --arch rwkv6-1.6b --reduced \\
      --requests 8 --max-new 16

  # the paper's real-time scenario: Poisson arrivals, latency percentiles
  PYTHONPATH=src python -m repro.launch.serve --arch rwkv6-1.6b --reduced \\
      --arrival poisson --rate 0.5 --duration 64 --seed 0

  # deadline-driven overload: EDF admission with preemption, SLO report
  PYTHONPATH=src python -m repro.launch.serve --arch rwkv6-1.6b --reduced \\
      --arrival poisson --rate 2.0 --duration 64 --prompt-dist bimodal \\
      --policy edf --preempt --deadline-slack 3.0

``--arrival {poisson,mmpp,trace}`` replays a workload from
``repro.serving.workload`` and prints the TTFT/TPOT/queue-wait percentile
summary.  ``--clock virtual`` (default) is deterministic — the metrics are
a pure function of (workload, seed); ``--clock wall`` paces arrivals in
real time and additionally reports measured wall tokens/sec.

``--policy`` choices are generated from the scheduler registry
(``repro.serving.scheduler.SCHEDULERS``) so the CLI can never offer a
policy the engine does not implement; the benchmark smoke guard asserts
this stays true.  ``--deadline-slack S`` stamps every generated request
with the absolute deadline ``arrival + S * max_new`` clock units — the
decode-proportional SLO EDF orders by — and ``--deadline-frac`` leaves a
random fraction of traffic best-effort.
"""

from __future__ import annotations

import argparse
import logging
import time

import jax
import numpy as np

from repro.configs import get_config
from repro.dist.sharding import make_sharder
from repro.models.lm import build_model
from repro.serving import ServingEngine
from repro.serving import metrics as smetrics
from repro.serving import workload as wl
from repro.serving.sampler import SamplerConfig
from repro.serving.scheduler import POLICIES
from repro.testing import reduced_config


def build_parser() -> argparse.ArgumentParser:
    """The CLI surface, as a factory so tools (and the benchmark smoke
    guard) can introspect it without running a model."""
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--max-new", type=int, default=16)
    ap.add_argument("--max-batch", type=int, default=4)
    ap.add_argument("--max-len", type=int, default=64)
    ap.add_argument("--temperature", type=float, default=0.0)
    ap.add_argument("--seed", type=int, default=0,
                    help="workload + sampler seed")
    ap.add_argument("--sync-every", type=int, default=1,
                    help="decode ticks per host sync: the fused on-device "
                         "decode loop runs this many ticks between host "
                         "interventions (admission/retire)")
    ap.add_argument("--policy", default="fcfs", choices=POLICIES,
                    help="admission order: FCFS, shortest-prompt-first, or "
                         "earliest-deadline-first (choices come from the "
                         "scheduler registry)")
    ap.add_argument("--preempt", action="store_true",
                    help="allow the scheduler to evict a running request "
                         "to host memory when a strictly tighter deadline "
                         "waits (EDF only); evicted requests resume "
                         "bit-exactly once a slot frees")
    ap.add_argument("--no-bucketed-prefill", action="store_true",
                    help="legacy exact-length batch-1 prefill per request "
                         "(compiles per distinct prompt length) instead of "
                         "length-bucketed batched prefill")
    ap.add_argument("--no-overlap-prefill", action="store_true",
                    help="serialize admission with decode: block on the "
                         "prefill sample readback before launching the "
                         "decode chunk (the pre-overlap engine behaviour; "
                         "the schedule is identical either way)")
    # open-loop arrival process (the paper's asynchronous-serving scenario)
    ap.add_argument("--arrival", default="batch",
                    choices=("batch",) + wl.ARRIVAL_KINDS,
                    help="'batch' submits --requests up front (legacy); "
                         "poisson/mmpp/trace replay an arrival process")
    ap.add_argument("--rate", type=float, default=0.5,
                    help="arrival rate, requests per clock unit")
    ap.add_argument("--duration", type=float, default=64.0,
                    help="workload span in clock units")
    ap.add_argument("--prompt-dist", default="uniform",
                    choices=wl.PROMPT_DISTS,
                    help="prompt-length distribution for generated "
                         "workloads (bimodal = long-tail prompts, the "
                         "regime where preemption pays)")
    ap.add_argument("--deadline-slack", type=float, default=None,
                    help="stamp generated requests with the absolute "
                         "deadline arrival + SLACK * max_new clock units "
                         "(decode-proportional: slot occupancy is decode "
                         "length on the virtual clock); enables the SLO "
                         "block and gives EDF something to order by")
    ap.add_argument("--deadline-frac", type=float, default=1.0,
                    help="fraction of generated requests carrying a "
                         "deadline (rest are best-effort)")
    ap.add_argument("--trace-file", default=None,
                    help="JSONL trace for --arrival trace (see "
                         "repro.serving.workload.save_trace; traces carry "
                         "their own optional per-request deadlines)")
    ap.add_argument("--clock", default="virtual",
                    choices=("virtual", "wall"),
                    help="virtual: deterministic tick clock; wall: pace "
                         "arrivals in real time")
    ap.add_argument("--truncate-prompts", action="store_true",
                    help="warn + drop the tail of prompts longer than "
                         "max_len-1 instead of rejecting them (useful when "
                         "replaying traces recorded on a larger engine)")
    ap.add_argument("-v", "--verbose", action="store_true",
                    help="DEBUG logging: per-tick engine utilization lines")
    return ap


def main() -> None:
    args = build_parser().parse_args()

    logging.basicConfig(level=logging.INFO,
                        format="%(asctime)s %(name)s %(message)s")
    if args.verbose:  # scope DEBUG to our loggers; root DEBUG floods w/ jax
        logging.getLogger("repro").setLevel(logging.DEBUG)

    cfg = reduced_config(args.arch) if args.reduced else get_config(args.arch)
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    sharder = make_sharder(cfg, None, "decode")
    engine = ServingEngine(model, params, sharder,
                           max_batch=args.max_batch, max_len=args.max_len,
                           sampler=SamplerConfig(temperature=args.temperature),
                           seed=args.seed,
                           truncate_prompts=args.truncate_prompts,
                           sync_every=args.sync_every, policy=args.policy,
                           preempt=args.preempt,
                           bucketed_prefill=not args.no_bucketed_prefill,
                           overlap_prefill=not args.no_overlap_prefill)

    if args.arrival == "batch":
        rng = np.random.default_rng(args.seed)
        reqs = []
        for _ in range(args.requests):
            prompt = rng.integers(0, cfg.vocab_size,
                                  size=rng.integers(4, 12)).tolist()
            reqs.append(engine.submit(prompt, max_new_tokens=args.max_new))
        t0 = time.time()
        engine.run()
        dt = time.time() - t0
        total = sum(len(r.output) for r in reqs)
        print(f"served {len(reqs)} requests, {total} tokens in {dt:.2f}s "
              f"({total/dt:.1f} tok/s)")
        print(f"engine stats: {engine.stats()}")
        for r in reqs[:3]:
            print(f"  req {r.uid}: prompt[:6]={r.prompt[:6]} -> {r.output[:8]}")
        assert all(r.done for r in reqs)
        return

    items = wl.make_workload(
        args.arrival, rate=args.rate, duration=args.duration, seed=args.seed,
        vocab_size=cfg.vocab_size, max_new_tokens=(args.max_new, args.max_new),
        prompt_dist=args.prompt_dist, deadline_slack=args.deadline_slack,
        deadline_frac=args.deadline_frac, trace_path=args.trace_file)
    # declared span for generated workloads; a trace only knows its arrivals
    span = None if args.arrival == "trace" else args.duration
    shown = span if span is not None else max((it.t for it in items),
                                              default=0.0)
    print(f"replaying {len(items)} {args.arrival} arrivals over "
          f"{shown:g} {args.clock}-clock units "
          f"(offered {wl.offered_load(items, span):.2f} tok/unit)")
    if args.clock == "wall":
        # warm the fused decode chunk + the prefill jit cache (one compile
        # per length *bucket* the workload will hit) so tick_seconds
        # measures steady-state serving, not XLA compiles
        for n in sorted({engine.bucket(len(it.prompt)) for it in items}):
            engine.submit([1] * n, max_new_tokens=2)
        engine.run()
        engine.reset_telemetry()
    clock = wl.WallClock() if args.clock == "wall" else wl.VirtualClock()
    t0 = time.time()
    reqs = wl.drive(engine, items, clock)
    dt = time.time() - t0
    # per-tick cost from busy time only: at low rates most of dt is idle
    # sleep between arrivals, which must not inflate the latency scaling
    tick_s = (clock.busy_seconds / max(1, engine.ticks)
              if args.clock == "wall" else 1.0)
    agg = smetrics.aggregate(reqs, ticks=engine.ticks,
                             util_history=engine.util_history,
                             tick_seconds=tick_s)
    print(smetrics.format_summary(agg))
    s = engine.stats()
    print(f"hot path: {s['host_syncs']} host syncs / {s['ticks']} ticks "
          f"({s['host_syncs'] / max(1, s['ticks']):.2f}/tick, "
          f"sync_every={args.sync_every}), "
          f"{s['prefill_calls']} prefill calls over "
          f"{s['prefill_compiles']} compiled shapes, "
          f"{s['instant_admits']} instant admits")
    if s["preemptions"]:
        print(f"scheduler: {s['preemptions']} preemptions / "
              f"{s['resumes']} resumes, {s['evicted_tokens']} tokens "
              f"evicted to host")
    if args.clock == "wall":
        print(f"wall: {dt:.2f}s, {agg['tokens'] / dt:.1f} tok/s measured")


if __name__ == "__main__":
    main()
