"""Serving launcher: batched request serving through the continuous-
batching engine.

  PYTHONPATH=src python -m repro.launch.serve --arch rwkv6-1.6b --reduced \\
      --requests 8 --max-new 16
"""

from __future__ import annotations

import argparse
import logging
import time

import jax
import numpy as np

from repro.configs import get_config
from repro.dist.sharding import make_sharder
from repro.models.lm import build_model
from repro.serving import ServingEngine
from repro.serving.sampler import SamplerConfig
from repro.testing import reduced_config


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--max-new", type=int, default=16)
    ap.add_argument("--max-batch", type=int, default=4)
    ap.add_argument("--max-len", type=int, default=64)
    ap.add_argument("--temperature", type=float, default=0.0)
    ap.add_argument("-v", "--verbose", action="store_true",
                    help="DEBUG logging: per-tick engine utilization lines")
    args = ap.parse_args()

    logging.basicConfig(level=logging.INFO,
                        format="%(asctime)s %(name)s %(message)s")
    if args.verbose:  # scope DEBUG to our loggers; root DEBUG floods w/ jax
        logging.getLogger("repro").setLevel(logging.DEBUG)

    cfg = reduced_config(args.arch) if args.reduced else get_config(args.arch)
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    sharder = make_sharder(cfg, None, "decode")
    engine = ServingEngine(model, params, sharder,
                           max_batch=args.max_batch, max_len=args.max_len,
                           sampler=SamplerConfig(temperature=args.temperature))
    rng = np.random.default_rng(0)
    reqs = []
    for i in range(args.requests):
        prompt = rng.integers(0, cfg.vocab_size,
                              size=rng.integers(4, 12)).tolist()
        reqs.append(engine.submit(prompt, max_new_tokens=args.max_new))
    t0 = time.time()
    engine.run()
    dt = time.time() - t0
    total = sum(len(r.output) for r in reqs)
    print(f"served {len(reqs)} requests, {total} tokens in {dt:.2f}s "
          f"({total/dt:.1f} tok/s)")
    print(f"engine stats: {engine.stats()}")
    for r in reqs[:3]:
        print(f"  req {r.uid}: prompt[:6]={r.prompt[:6]} -> {r.output[:8]}")
    assert all(r.done for r in reqs)


if __name__ == "__main__":
    main()
