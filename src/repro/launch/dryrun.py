import os
os.environ.setdefault("XLA_FLAGS", "--xla_force_host_platform_device_count=512")

"""Multi-pod dry-run: lower + compile every (architecture x input shape x
mesh) cell with ShapeDtypeStruct stand-ins (no allocation), then derive the
three-term roofline from the compiled artifacts.

The two lines above MUST stay the first statements of this module: jax
locks the device count at first initialization, and the production meshes
need 512 placeholder host devices.

Per cell this produces:
  * the FULL artifact — the real train/serve step with scan-over-layers:
    its successful ``.lower().compile()`` is the pass/fail gate, and its
    ``memory_analysis()`` proves per-chip fit;
  * COST PIECES — the scanned period body (fwd+bwd for training), the
    embed/head stem, and the optimizer update, each compiled separately and
    scaled by its trip count, because XLA's cost model counts a while body
    exactly once (EXPERIMENTS.md §Methodology);
  * the collective inventory parsed from post-SPMD HLO (launch/hlo.py).

Usage:
  python -m repro.launch.dryrun --arch qwen2.5-14b --shape train_4k
  python -m repro.launch.dryrun --all [--multi-pod-only|--single-pod-only]
  python -m repro.launch.dryrun --all --out results/dryrun
"""

import argparse
import dataclasses
import json
import time
import traceback
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro import hw
from repro.configs import get_config, get_shape, grid
from repro.configs.base import ModelConfig, ShapeSpec
from repro.core.quant import serving_specs
from repro.dist.sharding import Sharder, make_sharder
from repro.launch.hlo import collective_summary, parse_collectives
from repro.launch.mesh import make_production_mesh, mesh_chips
from repro.launch.roofline import RooflineResult, model_flops
from repro.models import params as pspec
from repro.models.blocks import block_specs
from repro.models.inputs import input_specs
from repro.models.lm import LM, build_model
from repro.optim import AdamW, cosine_schedule
from repro.optim.adamw import TrainState, abstract_state
from repro.train.step import make_train_step

F32 = jnp.float32


# ---------------------------------------------------------------------------
# Artifact helpers
# ---------------------------------------------------------------------------


def _analyze(compiled) -> Dict[str, Any]:
    cost = compiled.cost_analysis() or {}
    if isinstance(cost, (list, tuple)):  # jax < 0.5 wraps the dict in a list
        cost = cost[0] if cost else {}
    colls = parse_collectives(compiled.as_text())
    mem = compiled.memory_analysis()
    out = {
        "flops": float(cost.get("flops", 0.0)),
        "bytes": float(cost.get("bytes accessed", 0.0)),
        "collectives": collective_summary(colls),
    }
    if mem is not None:
        out["memory"] = {
            "argument_bytes": int(mem.argument_size_in_bytes),
            "output_bytes": int(mem.output_size_in_bytes),
            "temp_bytes": int(mem.temp_size_in_bytes),
            "alias_bytes": int(mem.alias_size_in_bytes),
            "peak_est_bytes": int(mem.argument_size_in_bytes
                                  + mem.output_size_in_bytes
                                  + mem.temp_size_in_bytes
                                  - mem.alias_size_in_bytes),
        }
    return out


def _lower_compile(fn, args, in_shardings=None, out_shardings=None,
                   donate=(), mesh=None) -> Tuple[Any, Dict[str, Any]]:
    kwargs = {}
    if in_shardings is not None:
        kwargs["in_shardings"] = in_shardings
    if out_shardings is not None:
        kwargs["out_shardings"] = out_shardings
    if donate:
        kwargs["donate_argnums"] = donate
    jitted = jax.jit(fn, **kwargs)
    t0 = time.time()
    with mesh:
        lowered = jitted.lower(*args)
        compiled = lowered.compile()
    info = _analyze(compiled)
    info["compile_s"] = time.time() - t0
    return compiled, info


def _batch_shardings(sharder: Sharder, specs: Dict, axes: Dict):
    return {k: sharder.sharding(axes[k], specs[k].shape) for k in specs}


# ---------------------------------------------------------------------------
# Cell construction: full artifact + cost pieces per mode
# ---------------------------------------------------------------------------


def make_optimizer() -> AdamW:
    return AdamW(lr=cosine_schedule(3e-4, 200, 50_000))


def _abstract_x(cfg: ModelConfig, batch: int, seq: int):
    return jax.ShapeDtypeStruct((batch, seq, cfg.d_model), jnp.bfloat16)


def _positions(cfg: ModelConfig, batch: int, seq: int):
    if cfg.m_rope_sections:
        return jnp.broadcast_to(jnp.arange(seq, dtype=jnp.int32),
                                (batch, 3, seq))
    return jnp.broadcast_to(jnp.arange(seq, dtype=jnp.int32), (batch, seq))


def build_train_cell(model: LM, shape: ShapeSpec, mesh, sharder: Sharder,
                     pieces: bool):
    cfg = model.cfg
    specs = model.param_specs()
    opt = make_optimizer()
    step_fn = make_train_step(model, opt, sharder)

    state_abs = abstract_state(specs)
    psh = sharder.param_shardings(specs)
    rep = sharder.sharding((), ())
    mvsh = psh
    if cfg.zero1:
        # ZeRO-1: only the optimizer state shards over the data axis; the
        # update step re-gathers params (GSPMD inserts the all-gather).
        from repro.dist.sharding import make_rules
        zrules = dict(make_rules(cfg, "train"))
        zrules["embed"] = ("data",)
        mvsh = Sharder(mesh, zrules).param_shardings(specs)
    state_sh = TrainState(params=psh, m=mvsh, v=mvsh, step=rep)
    b_specs, b_axes = input_specs(cfg, shape)
    b_sh = _batch_shardings(sharder, b_specs, b_axes)

    _, full = _lower_compile(
        step_fn, (state_abs, b_specs), in_shardings=(state_sh, b_sh),
        out_shardings=(state_sh, None), donate=(0,), mesh=mesh)
    result = {"full": full}
    if not pieces:
        return result

    # ---- piece 1: one scanned period, fwd+bwd, x (n_periods * n_micro) ----
    B_micro = shape.global_batch // cfg.n_microbatches
    S = shape.seq_len
    period_specs = {f"p{i}": block_specs(cfg, kind, cross=cfg.is_encoder_decoder)
                    for i, kind in enumerate(cfg.layer_pattern)}
    pp_abs = pspec.tree_abstract(period_specs)
    pp_sh = sharder.param_shardings(period_specs)
    positions = _positions(cfg, B_micro, S)
    enc_abs = None
    if cfg.is_encoder_decoder:
        enc_abs = _abstract_x(cfg, B_micro, S // cfg.encoder_downsample)

    def period_loss(p_params, x, enc_out=None):
        y, _, aux = model.period_apply(
            p_params, x, positions=positions, mode="train", sharder=sharder,
            enc_out=enc_out)
        if cfg.shard_residual_seq:
            y = sharder.constrain(y, "batch", "res_seq", None)
        return jnp.sum(y.astype(F32)) * 1e-6 + aux

    if cfg.remat != "none":  # match the real scan body: bwd re-gathers
        policy = (jax.checkpoint_policies.dots_with_no_batch_dims_saveable
                  if cfg.remat == "dots" else None)
        period_loss = jax.checkpoint(period_loss, policy=policy)

    grad_args = (0, 1) if enc_abs is None else (0, 1, 2)
    period_fn = jax.value_and_grad(period_loss, argnums=grad_args)
    x_abs = _abstract_x(cfg, B_micro, S)
    x_sh = sharder.sharding(("batch", "seq", None), x_abs.shape)
    args = (pp_abs, x_abs) + ((enc_abs,) if enc_abs is not None else ())
    in_sh = (pp_sh, x_sh) + ((x_sh,) if enc_abs is not None else ())
    # grads carry the params' (FSDP) sharding -> reduce-scatter, not
    # all-reduce, exactly as the real scan accumulates them
    grad_sh = (pp_sh, x_sh) + ((x_sh,) if enc_abs is not None else ())
    _, piece = _lower_compile(period_fn, args, in_shardings=in_sh,
                              out_shardings=(rep, grad_sh), mesh=mesh)
    result["pieces"] = {"period": dict(
        piece, mult=cfg.n_periods * cfg.n_microbatches)}

    # ---- piece 2: stem (embed + head + loss) fwd+bwd, x n_micro ------------
    stem_names = [k for k in specs if k not in
                  ("blocks", "enc_blocks", "enc_final_norm")]
    stem_specs = {k: specs[k] for k in stem_names}
    tok_abs = jax.ShapeDtypeStruct((B_micro, S + 1), jnp.int32)
    tok_sh = sharder.sharding(("batch", "seq"), tok_abs.shape)

    def stem_loss(s_params, tokens, h_final):
        return model.stem_train(s_params, tokens, h_final, sharder)

    stem_fn = jax.value_and_grad(stem_loss, argnums=(0, 2))
    stem_sh = sharder.param_shardings(stem_specs)
    _, piece = _lower_compile(
        stem_fn, (pspec.tree_abstract(stem_specs), tok_abs, x_abs),
        in_shardings=(stem_sh, tok_sh, x_sh),
        out_shardings=(rep, (stem_sh, x_sh)), mesh=mesh)
    result["pieces"]["stem"] = dict(piece, mult=cfg.n_microbatches)

    # ---- piece 3: optimizer update, x 1 ------------------------------------
    def opt_fn(state, grads):
        from repro.optim.adamw import adamw_update
        new_state, _ = adamw_update(opt, state, grads)
        return new_state

    _, piece = _lower_compile(
        opt_fn, (state_abs, state_abs["params"]),
        in_shardings=(state_sh, psh), out_shardings=state_sh, mesh=mesh)
    result["pieces"]["optimizer"] = dict(piece, mult=1)

    # ---- encoder piece (whisper) -------------------------------------------
    if cfg.is_encoder_decoder:
        eb = {"p0": block_specs(cfg, "attn")}
        Se = S // cfg.encoder_downsample
        pos_e = _positions(cfg, B_micro, Se)

        def enc_loss(p_params, x):
            y, _, aux = model.period_apply(
                p_params, x, positions=pos_e, mode="train", sharder=sharder,
                causal=False)
            return jnp.sum(y.astype(F32)) * 1e-6 + aux

        enc_fn = jax.value_and_grad(enc_loss, argnums=(0, 1))
        xe_abs = _abstract_x(cfg, B_micro, Se)
        _, piece = _lower_compile(
            enc_fn, (pspec.tree_abstract(eb), xe_abs),
            in_shardings=(sharder.param_shardings(eb),
                          sharder.sharding(("batch", "seq", None),
                                           xe_abs.shape)),
            mesh=mesh)
        result["pieces"]["encoder"] = dict(
            piece, mult=cfg.n_encoder_layers * cfg.n_microbatches)
    return result


def build_serve_cell(model: LM, shape: ShapeSpec, mesh, sharder: Sharder,
                     pieces: bool, int8: bool = False):
    cfg = model.cfg
    specs = serving_specs(model.param_specs(), int8=int8)
    p_abs = pspec.tree_abstract(specs)
    psh = sharder.param_shardings(specs)
    B, S = shape.global_batch, shape.seq_len
    result: Dict[str, Any] = {}

    if shape.mode == "prefill":
        b_specs, b_axes = input_specs(cfg, shape)
        b_sh = _batch_shardings(sharder, b_specs, b_axes)

        def prefill_fn(params, batch):
            return model.prefill(params, batch, sharder, max_len=S)

        _, full = _lower_compile(prefill_fn, (p_abs, b_specs),
                                 in_shardings=(psh, b_sh), mesh=mesh)
        result["full"] = full
        if pieces:
            positions = _positions(cfg, B, S)
            period_specs = {f"p{i}": block_specs(cfg, kind, cross=cfg.is_encoder_decoder)
                            for i, kind in enumerate(cfg.layer_pattern)}
            enc_abs = (_abstract_x(cfg, B, S // cfg.encoder_downsample)
                       if cfg.is_encoder_decoder else None)

            def period_fwd(p_params, x, enc_out=None):
                y, cache, _ = model.period_apply(
                    p_params, x, positions=positions, mode="prefill",
                    sharder=sharder, enc_out=enc_out, max_len=S)
                return y, cache

            x_abs = _abstract_x(cfg, B, S)
            x_sh = sharder.sharding(("batch", "seq", None), x_abs.shape)
            args = (pspec.tree_abstract(period_specs), x_abs) + (
                (enc_abs,) if enc_abs is not None else ())
            in_sh = (sharder.param_shardings(period_specs), x_sh) + (
                (x_sh,) if enc_abs is not None else ())
            _, piece = _lower_compile(period_fwd, args, in_shardings=in_sh,
                                      mesh=mesh)
            result["pieces"] = {"period": dict(piece, mult=cfg.n_periods)}

            tok_abs = jax.ShapeDtypeStruct((B, S), jnp.int32)

            def stem_fwd(s_params, tokens, h_final):
                return model.stem_serve(s_params, tokens, h_final, sharder)

            stem_names = [k for k in specs if k not in
                          ("blocks", "enc_blocks", "enc_final_norm")]
            stem_specs = {k: specs[k] for k in stem_names}
            _, piece = _lower_compile(
                stem_fwd, (pspec.tree_abstract(stem_specs), tok_abs, x_abs),
                in_shardings=(sharder.param_shardings(stem_specs),
                              sharder.sharding(("batch", "seq"), (B, S)),
                              x_sh),
                mesh=mesh)
            result["pieces"]["stem"] = dict(piece, mult=1)
        return result

    # ---- decode -------------------------------------------------------------
    cache_specs = model.cache_specs(B, S)
    cache_abs = pspec.tree_abstract(cache_specs)
    cache_sh = sharder.param_shardings(cache_specs)
    tok_abs = jax.ShapeDtypeStruct((B,), jnp.int32)
    tok_sh = sharder.sharding(("batch",), (B,))

    def decode_fn(params, cache, tokens):
        return model.decode_step(params, cache, tokens, sharder)

    _, full = _lower_compile(
        decode_fn, (p_abs, cache_abs, tok_abs),
        in_shardings=(psh, cache_sh, tok_sh),
        out_shardings=(cache_sh, None), donate=(1,), mesh=mesh)
    result["full"] = full
    if pieces:
        period_specs = {f"p{i}": block_specs(cfg, kind, cross=cfg.is_encoder_decoder)
                        for i, kind in enumerate(cfg.layer_pattern)}
        period_specs = serving_specs(period_specs, int8=int8)
        pc_specs = model.period_cache_specs(B, S)
        lengths = jnp.full((B,), S - 1, jnp.int32)
        positions = (lengths[:, None] if not cfg.m_rope_sections
                     else jnp.broadcast_to(lengths[:, None, None], (B, 3, 1)))

        def period_step(p_params, x, p_cache):
            y, new_c, _ = model.period_apply(
                p_params, x, positions=positions, lengths=lengths,
                mode="decode", sharder=sharder, p_cache=p_cache)
            return y, new_c

        x_abs = _abstract_x(cfg, B, 1)
        x_sh = sharder.sharding(("batch", None, None), x_abs.shape)
        _, piece = _lower_compile(
            period_step,
            (pspec.tree_abstract(period_specs), x_abs,
             pspec.tree_abstract(pc_specs)),
            in_shardings=(sharder.param_shardings(period_specs), x_sh,
                          sharder.param_shardings(pc_specs)),
            donate=(2,), mesh=mesh)
        result["pieces"] = {"period": dict(piece, mult=cfg.n_periods)}

        def stem_step(s_params, tokens, h_final):
            return model.stem_serve(s_params, tokens, h_final, sharder,
                                    last_only=True)

        stem_names = [k for k in specs if k not in
                      ("blocks", "enc_blocks", "enc_final_norm")]
        stem_specs = {k: specs[k] for k in stem_names}
        tok2 = jax.ShapeDtypeStruct((B, 1), jnp.int32)
        _, piece = _lower_compile(
            stem_step, (pspec.tree_abstract(stem_specs), tok2, x_abs),
            in_shardings=(sharder.param_shardings(stem_specs),
                          sharder.sharding(("batch", None), (B, 1)), x_sh),
            mesh=mesh)
        result["pieces"]["stem"] = dict(piece, mult=1)
    return result


# ---------------------------------------------------------------------------
# Cell driver
# ---------------------------------------------------------------------------


def run_cell(arch: str, shape_name: str, multi_pod: bool,
             pieces: bool = True, int8: bool = False,
             kv_int8: bool = False,
             overrides: Optional[Dict[str, Any]] = None) -> Dict[str, Any]:
    cfg = get_config(arch)
    if kv_int8:
        cfg = dataclasses.replace(cfg, kv_cache_dtype="int8")
    if overrides:
        cfg = dataclasses.replace(cfg, **overrides)
    shape = get_shape(shape_name)
    mesh_name = "pod2x16x16" if multi_pod else "pod16x16"
    cell: Dict[str, Any] = {
        "arch": arch, "shape": shape_name, "mesh": mesh_name,
        "int8": int8, "kv_int8": kv_int8, "overrides": overrides or {},
    }
    runs, reason = cfg.runs_shape(shape)
    if not runs:
        cell.update(ok=None, skip=reason)
        return cell
    try:
        mesh = make_production_mesh(multi_pod=multi_pod)
        model = build_model(cfg)
        sharder = make_sharder(cfg, mesh, shape.mode)
        t0 = time.time()
        if shape.mode == "train":
            result = build_train_cell(model, shape, mesh, sharder, pieces)
        else:
            result = build_serve_cell(model, shape, mesh, sharder, pieces,
                                      int8=int8)
        cell.update(result)
        cell["ok"] = True
        cell["wall_s"] = time.time() - t0
        cell["chips"] = mesh_chips(mesh)
        if pieces and "pieces" in result:
            cell["roofline"] = summarize_roofline(model, shape, cell)
    except Exception as e:  # noqa: BLE001 — report, don't crash the sweep
        cell["ok"] = False
        cell["error"] = f"{type(e).__name__}: {e}"
        cell["traceback"] = traceback.format_exc()[-4000:]
    return cell


def summarize_roofline(model: LM, shape: ShapeSpec, cell: Dict) -> Dict:
    chips = cell["chips"]
    flops = bytes_ = coll = coll_op = 0.0
    for name, piece in cell["pieces"].items():
        m = piece["mult"]
        flops += piece["flops"] * m
        bytes_ += piece["bytes"] * m
        coll += piece["collectives"]["ici_bytes"] * m
        coll_op += piece["collectives"]["operand_bytes"] * m
    mf = model_flops(model, shape)
    rr = RooflineResult(
        arch=cell["arch"], shape=shape.name, mesh=cell["mesh"], chips=chips,
        flops_device=flops, bytes_device=bytes_,
        coll_ici_bytes_device=coll, coll_operand_bytes_device=coll_op,
        model_flops_total=mf).finalize()
    return rr.row()


# ---------------------------------------------------------------------------
# CLI
# ---------------------------------------------------------------------------


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--arch")
    ap.add_argument("--shape")
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--multi-pod", action="store_true",
                    help="use the 2x16x16 multi-pod mesh")
    ap.add_argument("--both-meshes", action="store_true")
    ap.add_argument("--no-pieces", action="store_true")
    ap.add_argument("--int8", action="store_true",
                    help="int8 weight storage for serve cells")
    ap.add_argument("--kv-int8", action="store_true",
                    help="int8 kv-cache storage")
    # §Perf levers
    ap.add_argument("--micro", type=int, default=0,
                    help="override n_microbatches")
    ap.add_argument("--no-tp", action="store_true",
                    help="replicate weights at train (pure DP)")
    ap.add_argument("--zero1", action="store_true",
                    help="shard only optimizer state over data")
    ap.add_argument("--shard-res", action="store_true",
                    help="shard the residual scan carry's seq dim")
    ap.add_argument("--sp", action="store_true",
                    help="Megatron-style sequence parallelism at train")
    ap.add_argument("--no-fsdp", action="store_true")
    ap.add_argument("--tag", default="",
                    help="suffix for the result file name")
    ap.add_argument("--out", default="results/dryrun")
    args = ap.parse_args()

    overrides: Dict[str, Any] = {}
    if args.micro:
        overrides["n_microbatches"] = args.micro
    if args.no_tp:
        overrides["train_tp"] = False
    if args.zero1:
        overrides["zero1"] = True
    if args.shard_res:
        overrides["shard_residual_seq"] = True
    if args.sp:
        overrides["seq_parallel"] = True
    if args.no_fsdp:
        overrides["fsdp"] = False

    os.makedirs(args.out, exist_ok=True)
    todo = []
    if args.all:
        for cfg, shape, _, _ in grid():
            todo.append((cfg.name, shape.name))
    else:
        todo.append((args.arch, args.shape))

    meshes = [args.multi_pod]
    if args.both_meshes:
        meshes = [False, True]

    for arch, shape_name in todo:
        for multi_pod in meshes:
            tag = f"{arch}_{shape_name}_{'multi' if multi_pod else 'single'}"
            if args.int8:
                tag += "_int8"
            if args.kv_int8:
                tag += "_kv8"
            if args.tag:
                tag += "_" + args.tag
            path = os.path.join(args.out, tag + ".json")
            if os.path.exists(path):
                print(f"[skip existing] {tag}")
                continue
            print(f"[dryrun] {tag} ...", flush=True)
            cell = run_cell(arch, shape_name, multi_pod,
                            pieces=not args.no_pieces and not multi_pod,
                            int8=args.int8, kv_int8=args.kv_int8,
                            overrides=overrides or None)
            # strip unserializable / huge fields
            with open(path, "w") as f:
                json.dump(cell, f, indent=1, default=str)
            status = cell.get("ok")
            extra = cell.get("error", "") or cell.get("skip", "")
            print(f"[dryrun] {tag}: ok={status} "
                  f"wall={cell.get('wall_s', 0):.1f}s {extra}", flush=True)


if __name__ == "__main__":
    main()
