"""Training launcher.

  PYTHONPATH=src python -m repro.launch.train --arch rwkv6-1.6b --reduced \\
      --steps 50 --checkpoint-dir /tmp/ckpt

Full-size configs target the production mesh (run under the dry-run's
XLA_FLAGS on a real pod slice); ``--reduced`` shrinks the architecture for
CPU-scale end-to-end runs (the "train a ~100M model for a few hundred
steps" driver uses this path — see examples/train_lm.py).
"""

from __future__ import annotations

import argparse
import logging

import jax

from repro.configs import get_config
from repro.configs.base import ShapeSpec
from repro.dist.sharding import make_sharder
from repro.launch.mesh import make_production_mesh, make_test_mesh
from repro.models.lm import build_model
from repro.testing import reduced_config
from repro.train.loop import TrainLoopConfig, train


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--checkpoint-dir", default=None)
    ap.add_argument("--checkpoint-every", type=int, default=50)
    ap.add_argument("--mesh", choices=["none", "test", "pod", "multipod"],
                    default="none")
    args = ap.parse_args()

    logging.basicConfig(level=logging.INFO,
                        format="%(asctime)s %(name)s %(message)s")
    cfg = reduced_config(args.arch) if args.reduced else get_config(args.arch)
    model = build_model(cfg)
    shape = ShapeSpec("cli_train", args.seq, args.batch, "train")
    mesh = None
    if args.mesh == "test":
        n = len(jax.devices())
        mesh = make_test_mesh((1, n), ("data", "model"))
    elif args.mesh == "pod":
        mesh = make_production_mesh()
    elif args.mesh == "multipod":
        mesh = make_production_mesh(multi_pod=True)
    sharder = make_sharder(cfg, mesh, "train")
    loop_cfg = TrainLoopConfig(
        total_steps=args.steps,
        checkpoint_every=args.checkpoint_every,
        checkpoint_dir=args.checkpoint_dir,
    )
    ctx = mesh if mesh is not None else _nullcontext()
    with ctx:
        state, history = train(model, shape, sharder, loop_cfg)
    print(f"final loss: {history[-1]['loss']:.4f} after {len(history)} steps")


class _nullcontext:
    def __enter__(self):
        return None

    def __exit__(self, *a):
        return False


if __name__ == "__main__":
    main()
