"""Roofline term computation (TPU v5e target).

    compute term    = HLO_FLOPs / (chips x peak_FLOP/s)
    memory term     = HLO_bytes / (chips x HBM_bw)
    collective term = collective_bytes / (chips x link_bw)

HLO_FLOPs / HLO_bytes are assembled from compiled cost pieces: XLA counts a
``while`` body once, so the dry-run lowers the scanned layer period (and
stem, and optimizer) separately and scales each piece by its trip count —
``total = sum_i piece_i x mult_i``.  ``cost_analysis`` numbers are
per-device; globals multiply by chip count, and the spec formulas divide it
back out, so the terms are per-device seconds either way.

``collective_bytes`` uses the ring-model ICI bytes per device
(launch/hlo.py); the term divides by the single-link bandwidth per the
assignment formula (a 1-link worst case; v5e has 4 usable links, so the
achievable term is up to 4x lower — both are recorded).

MODEL_FLOPS follows the PaLM convention: 6·N_matmul·tokens (+ exact
attention-window term), N counted from the *actual* parameter tree with
MoE experts scaled to the active top-k.
"""

from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional

import jax

from repro import hw
from repro.configs.base import ModelConfig, ShapeSpec
from repro.models import params as pspec


# ---------------------------------------------------------------------------
# MODEL_FLOPS from the real parameter tree
# ---------------------------------------------------------------------------


def matmul_param_count(model) -> float:
    """Matmul-visible params: >=2-D leaves; embedding gathers excluded;
    tied embeddings count once (as the lm_head matmul); MoE experts scaled
    by top_k / n_experts."""
    cfg: ModelConfig = model.cfg
    specs = model.param_specs()
    total = 0.0
    for path, spec in jax.tree_util.tree_flatten_with_path(
            specs, is_leaf=pspec.is_spec)[0]:
        name = jax.tree_util.keystr(path)
        if len(spec.shape) < 2:
            continue
        n = float(spec.size)
        if "embedding" in name:
            if not cfg.tie_embeddings:
                continue  # pure gather; untied head counted separately
            # tied: the table is also the head matmul -> count once
        if "/moe/" in name.replace("']['", "/") or "moe" in name and \
                any(w in name for w in ("w_up", "w_down", "w_gate")):
            if cfg.moe is not None and spec.shape and \
                    spec.shape[0] == cfg.n_periods and \
                    len(spec.shape) >= 3 and spec.shape[1] == cfg.moe.n_experts:
                n *= cfg.moe.top_k / cfg.moe.n_experts
        total += n
    return total


def attention_flops(cfg: ModelConfig, shape: ShapeSpec, fwd_only: bool) -> float:
    """Score+AV flops: 4 * S_visible * q_heads * head_dim per token/layer."""
    if not any(k in ("attn", "local", "swa_ssm") for k in cfg.layer_pattern):
        return 0.0
    H, hd = cfg.n_heads, cfg.head_dim_
    total = 0.0
    per_period = cfg.layer_pattern
    if shape.mode == "decode":
        cache = shape.seq_len
        for kind in per_period * cfg.n_periods:
            if kind == "attn":
                vis = cache
            elif kind in ("local", "swa_ssm"):
                vis = min(cfg.local_window, cache)
            else:
                continue
            total += 4.0 * vis * H * hd * shape.global_batch
    else:
        S = shape.seq_len
        for kind in per_period * cfg.n_periods:
            if kind == "attn":
                vis = S / 2.0  # causal average
            elif kind in ("local", "swa_ssm"):
                vis = min(cfg.local_window, S)
            else:
                continue
            total += 4.0 * vis * H * hd * shape.tokens
    if not fwd_only:
        total *= 3.0
    return total


def model_flops(model, shape: ShapeSpec) -> float:
    n_mm = matmul_param_count(model)
    if shape.mode == "train":
        tokens = shape.tokens
        return 6.0 * n_mm * tokens + attention_flops(model.cfg, shape, False)
    if shape.mode == "prefill":
        return 2.0 * n_mm * shape.tokens + attention_flops(model.cfg, shape, True)
    return 2.0 * n_mm * shape.global_batch + attention_flops(model.cfg, shape, True)


# ---------------------------------------------------------------------------
# Terms
# ---------------------------------------------------------------------------


@dataclasses.dataclass
class RooflineResult:
    arch: str
    shape: str
    mesh: str
    chips: int
    # per-device totals assembled from the cost pieces
    flops_device: float
    bytes_device: float
    coll_ici_bytes_device: float
    coll_operand_bytes_device: float
    # terms, seconds
    compute_s: float = 0.0
    memory_s: float = 0.0
    collective_s: float = 0.0
    collective_s_4link: float = 0.0
    dominant: str = ""
    model_flops_total: float = 0.0
    useful_ratio: float = 0.0
    step_s: float = 0.0          # max of terms = roofline step time
    roofline_frac: float = 0.0   # model-flops MFU at the roofline step time
    note: str = ""

    def finalize(self, spec: hw.HardwareSpec = hw.TPU_V5E) -> "RooflineResult":
        self.compute_s = self.flops_device / spec.peak_bf16_flops
        self.memory_s = self.bytes_device / spec.hbm_bw
        self.collective_s = self.coll_ici_bytes_device / spec.ici_link_bw
        self.collective_s_4link = self.collective_s / spec.ici_links
        terms = {"compute": self.compute_s, "memory": self.memory_s,
                 "collective": self.collective_s}
        self.dominant = max(terms, key=terms.get)
        self.step_s = max(terms.values())
        hlo_total = self.flops_device * self.chips
        self.useful_ratio = (self.model_flops_total / hlo_total
                             if hlo_total else 0.0)
        ideal_s = self.model_flops_total / (self.chips * spec.peak_bf16_flops)
        self.roofline_frac = ideal_s / self.step_s if self.step_s else 0.0
        return self

    def row(self) -> Dict:
        return dataclasses.asdict(self)
