"""Post-SPMD HLO text analysis: collective inventory and byte accounting.

``compiled.cost_analysis()`` reports FLOPs and HBM bytes but no collective
traffic, so we parse the optimized (per-device) HLO text and sum operand
sizes of every all-gather / all-reduce / reduce-scatter / all-to-all /
collective-permute.  Two numbers are derived per op:

  * ``operand_bytes`` — the literal operand size (spec definition),
  * ``ici_bytes``     — ring-algorithm bytes actually serialized on a
                         device's links (2(g-1)/g x for all-reduce, etc.),
    which is what the collective roofline term uses.
"""

from __future__ import annotations

import dataclasses
import re
from typing import Dict, List

import numpy as np

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "s32": 4, "u32": 4,
    "s64": 8, "u64": 8, "f8e4m3fn": 1, "f8e5m2": 1, "bf16": 2, "f16": 2,
    "f32": 4, "f64": 8, "c64": 8, "c128": 16,
}

_SHAPE_RE = re.compile(r"([a-z0-9]+)\[([0-9,]*)\]")
_COLL_RE = re.compile(
    r"=\s*(?P<result>\([^)]*\)|[a-z0-9]+\[[0-9,]*\][^\s]*)\s*"
    r"(?P<op>all-reduce|all-gather|reduce-scatter|all-to-all|"
    r"collective-permute|collective-broadcast)"
    r"(?P<suffix>-start)?\(")
_GROUPS_ITOA_RE = re.compile(r"replica_groups=\[(\d+),(\d+)\]")
_GROUPS_LIST_RE = re.compile(r"replica_groups=\{\{([0-9, ]*)\}")


@dataclasses.dataclass
class CollectiveOp:
    kind: str
    out_bytes: int        # per-device result bytes
    group_size: int
    operand_bytes: int    # per-device operand bytes
    ici_bytes: int        # ring-model bytes serialized per device
    line: str


def _shape_bytes(text: str) -> int:
    total = 0
    for dtype, dims in _SHAPE_RE.findall(text):
        if dtype not in _DTYPE_BYTES:
            continue
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        total += n * _DTYPE_BYTES[dtype]
    return total


def _group_size(line: str) -> int:
    m = _GROUPS_ITOA_RE.search(line)
    if m:
        return int(m.group(2))
    m = _GROUPS_LIST_RE.search(line)
    if m:
        entries = [e for e in m.group(1).split(",") if e.strip()]
        return max(1, len(entries))
    if "collective-permute" in line:
        return 2
    return 1


def parse_collectives(hlo_text: str) -> List[CollectiveOp]:
    ops: List[CollectiveOp] = []
    for line in hlo_text.splitlines():
        if "-done(" in line:
            continue  # async completion of a -start op already counted
        m = _COLL_RE.search(line)
        if not m:
            continue
        kind = m.group("op")
        out_bytes = _shape_bytes(m.group("result"))
        g = _group_size(line)
        if kind == "all-reduce":
            operand = out_bytes
            ici = int(2 * (g - 1) / g * out_bytes)
        elif kind == "all-gather":
            operand = out_bytes // max(1, g)
            ici = int((g - 1) / g * out_bytes)
        elif kind == "reduce-scatter":
            operand = out_bytes * g
            ici = int((g - 1) / g * operand)
        elif kind == "all-to-all":
            operand = out_bytes
            ici = int((g - 1) / g * out_bytes)
        else:  # collective-permute / broadcast
            operand = out_bytes
            ici = out_bytes
        ops.append(CollectiveOp(kind, out_bytes, g, operand, ici,
                                line.strip()[:200]))
    return ops


def collective_summary(ops: List[CollectiveOp]) -> Dict[str, float]:
    summary: Dict[str, float] = {
        "n_ops": len(ops),
        "operand_bytes": float(sum(o.operand_bytes for o in ops)),
        "ici_bytes": float(sum(o.ici_bytes for o in ops)),
    }
    by_kind: Dict[str, float] = {}
    for o in ops:
        by_kind[o.kind] = by_kind.get(o.kind, 0.0) + o.ici_bytes
    summary["by_kind"] = by_kind  # type: ignore[assignment]
    return summary
