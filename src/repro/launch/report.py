"""Assemble the EXPERIMENTS.md §Dry-run and §Roofline tables from
results/dryrun/*.json.

  PYTHONPATH=src python -m repro.launch.report [--dir results/dryrun]
"""

from __future__ import annotations

import argparse
import glob
import json
import os
from typing import Dict, List, Optional

ARCH_ORDER = [
    "qwen2.5-14b", "gemma2-9b", "gemma3-12b", "starcoder2-15b",
    "whisper-tiny", "rwkv6-1.6b", "qwen2-vl-2b", "granite-moe-1b-a400m",
    "qwen3-moe-30b-a3b", "hymba-1.5b",
]
SHAPE_ORDER = ["train_4k", "prefill_32k", "decode_32k", "long_500k"]


def load(directory: str) -> Dict[str, dict]:
    cells = {}
    for path in glob.glob(os.path.join(directory, "*.json")):
        with open(path) as f:
            d = json.load(f)
        key = (d["arch"], d["shape"], d["mesh"],
               d.get("int8", False), d.get("kv_int8", False))
        cells[key] = d
    return cells


def fmt_bytes(n: Optional[float]) -> str:
    if n is None:
        return "-"
    return f"{n/2**30:.2f}"


def dryrun_table(cells: Dict) -> List[str]:
    rows = ["| arch | shape | mesh | compile | per-chip args GiB | "
            "per-chip temp GiB | HLO flops/dev | collectives (ici GiB/dev) |",
            "|---|---|---|---|---|---|---|---|"]
    for arch in ARCH_ORDER:
        for shape in SHAPE_ORDER:
            for mesh in ("pod16x16", "pod2x16x16"):
                c = cells.get((arch, shape, mesh, False, False))
                if c is None:
                    rows.append(f"| {arch} | {shape} | {mesh} | MISSING |  |  |  |  |")
                    continue
                if c.get("skip"):
                    rows.append(f"| {arch} | {shape} | {mesh} | skip* |  |  |  |  |")
                    continue
                if not c.get("ok"):
                    err = str(c.get("error", ""))[:40]
                    rows.append(f"| {arch} | {shape} | {mesh} | **FAIL** {err} |  |  |  |  |")
                    continue
                m = c["full"].get("memory", {})
                coll = c["full"]["collectives"]
                rows.append(
                    f"| {arch} | {shape} | {mesh} | ok ({c['wall_s']:.0f}s) "
                    f"| {fmt_bytes(m.get('argument_bytes'))} "
                    f"| {fmt_bytes(m.get('temp_bytes'))} "
                    f"| {c['full']['flops']:.3g} "
                    f"| {coll['n_ops']} ops, {coll['ici_bytes']/2**30:.2f} |")
    return rows


def serve_mem_floor_s(arch: str, shape: str) -> Optional[float]:
    """Analytic per-device byte floor for serving cells: weight shard read
    once per step + cache shard read+write once (bf16 baseline)."""
    from repro.configs import get_config, get_shape
    from repro.models import params as pspec
    from repro.models.lm import build_model

    cfg = get_config(arch)
    sh = get_shape(shape)
    if sh.mode == "train":
        return None
    model = build_model(cfg)
    w_bytes = pspec.tree_size(model.param_specs()) * 2 / 16  # bf16, TP=16
    floor = w_bytes
    if sh.mode == "decode":
        cache = pspec.tree_bytes(
            model.cache_specs(sh.global_batch, sh.seq_len)) / 256
        floor += 2 * cache
    return floor / 819e9


def roofline_table(cells: Dict) -> List[str]:
    rows = ["| arch | shape | compute s | memory s | collective s (1-link) | "
            "dominant | MODEL_FLOPS | useful | roofline-frac | mem-floor s | note |",
            "|---|---|---|---|---|---|---|---|---|---|---|"]
    for arch in ARCH_ORDER:
        for shape in SHAPE_ORDER:
            c = cells.get((arch, shape, "pod16x16", False, False))
            if c is None:
                continue
            if c.get("skip"):
                rows.append(f"| {arch} | {shape} | — | — | — | — | — | — | — "
                            f"| — | skip: sub-quadratic rule |")
                continue
            r = c.get("roofline")
            if not c.get("ok") or not r:
                rows.append(f"| {arch} | {shape} | — | — | — | — | — | — | — "
                            f"| — | {str(c.get('error','no pieces'))[:40]} |")
                continue
            try:
                floor = serve_mem_floor_s(arch, shape)
            except Exception:  # noqa: BLE001
                floor = None
            floor_s = f"{floor:.4g}" if floor else "—"
            rows.append(
                f"| {arch} | {shape} | {r['compute_s']:.4g} "
                f"| {r['memory_s']:.4g} | {r['collective_s']:.4g} "
                f"| **{r['dominant']}** | {r['model_flops_total']:.3g} "
                f"| {r['useful_ratio']:.2f} | {r['roofline_frac']:.3f} "
                f"| {floor_s} | {note_for(c, r)} |")
    return rows


def note_for(c: dict, r: dict) -> str:
    dom = r["dominant"]
    if dom == "collective":
        return "cut collective: fewer/cheaper weight gathers or int8 wire"
    if dom == "memory":
        return "cut bytes: int8 weights / int8 KV / fusion"
    return "compute-bound: at the MXU roofline"


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--dir", default="results/dryrun")
    ap.add_argument("--out", default=None, help="write markdown to file")
    args = ap.parse_args()
    cells = load(args.dir)
    lines = ["## §Dry-run (generated by repro.launch.report)", ""]
    lines += dryrun_table(cells)
    lines += ["", "## §Roofline (single-pod, per-device seconds)", ""]
    lines += roofline_table(cells)
    text = "\n".join(lines)
    if args.out:
        with open(args.out, "w") as f:
            f.write(text + "\n")
    print(text)


if __name__ == "__main__":
    main()
