"""Production meshes.

Defined as functions (never module-level constants) so importing this
module never touches jax device state — smoke tests keep their single CPU
device; only the dry-run forces 512 host devices.
"""

from __future__ import annotations

from typing import Optional, Sequence

import jax
from jax.sharding import Mesh

try:  # jax >= 0.5: meshes carry explicit axis types; default all-Auto
    from jax.sharding import AxisType
except ImportError:  # older jax: every axis is implicitly Auto
    AxisType = None


def _make_mesh(shape, axes, devices) -> Mesh:
    if AxisType is not None:
        return jax.make_mesh(shape, axes,
                             axis_types=(AxisType.Auto,) * len(shape),
                             devices=devices)
    return jax.make_mesh(shape, axes, devices=devices)


def make_production_mesh(*, multi_pod: bool = False) -> Mesh:
    """16x16 = 256 chips per pod; 2 pods = 512 chips multi-pod."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    n = 1
    for s in shape:
        n *= s
    devices = jax.devices()[:n]
    if len(devices) < n:
        raise RuntimeError(
            f"need {n} devices for the production mesh, have "
            f"{len(devices)}; run under "
            f"XLA_FLAGS=--xla_force_host_platform_device_count=512 "
            f"(see repro.launch.dryrun)")
    return _make_mesh(shape, axes, devices)


def make_test_mesh(shape: Sequence[int] = (2, 4),
                   axes: Sequence[str] = ("data", "model")) -> Mesh:
    """Small mesh over however many host devices exist (tests)."""
    n = 1
    for s in shape:
        n *= s
    return _make_mesh(tuple(shape), tuple(axes), jax.devices()[:n])


def mesh_chips(mesh: Mesh) -> int:
    return mesh.devices.size
