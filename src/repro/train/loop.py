"""The training loop: data + step + checkpoint + fault tolerance.

Wires every substrate piece together; this is what
``python -m repro.launch.train`` runs and what ``examples/train_lm.py``
demonstrates end-to-end on CPU with a reduced config.
"""

from __future__ import annotations

import dataclasses
import logging
import time
from typing import Callable, Dict, Optional

import jax
import numpy as np

from repro.checkpoint import CheckpointManager
from repro.configs.base import ModelConfig, ShapeSpec
from repro.data import SyntheticLMData
from repro.dist.sharding import Sharder
from repro.models.lm import LM
from repro.optim import AdamW, cosine_schedule
from repro.optim.adamw import init_state
from repro.runtime import PreemptionGuard, StepWatchdog
from repro.train.step import make_train_step

log = logging.getLogger("repro.train")


@dataclasses.dataclass
class TrainLoopConfig:
    total_steps: int = 100
    checkpoint_every: int = 50
    checkpoint_dir: Optional[str] = None
    log_every: int = 10
    seed: int = 0
    watchdog_timeout_s: float = 3600.0
    async_checkpoint: bool = True


def train(model: LM, shape: ShapeSpec, sharder: Sharder,
          loop_cfg: TrainLoopConfig,
          opt: Optional[AdamW] = None,
          metrics_cb: Optional[Callable[[int, Dict], None]] = None):
    """Runs the loop; returns (state, history)."""
    cfg = model.cfg
    opt = opt or AdamW(lr=cosine_schedule(3e-4, 100, loop_cfg.total_steps))
    step_fn = jax.jit(make_train_step(model, opt, sharder), donate_argnums=0)
    data = SyntheticLMData(cfg, shape, seed=loop_cfg.seed)

    ckpt = (CheckpointManager(loop_cfg.checkpoint_dir)
            if loop_cfg.checkpoint_dir else None)
    state = None
    start_step = 0
    if ckpt and ckpt.latest_step() is not None:
        abstract = init_state(model.param_specs(), jax.random.PRNGKey(0))
        state = ckpt.restore(abstract)
        start_step = ckpt.manifest(ckpt.latest_step())["extra"]["data_step"]
        data.restore({"step": start_step, "seed": loop_cfg.seed})
        log.info("restored checkpoint at data step %d", start_step)
    if state is None:
        state = init_state(model.param_specs(),
                           jax.random.PRNGKey(loop_cfg.seed))

    history = []
    with PreemptionGuard() as guard, \
            StepWatchdog(loop_cfg.watchdog_timeout_s) as watchdog:
        for step in range(start_step, loop_cfg.total_steps):
            batch = {k: jax.numpy.asarray(v) for k, v in
                     data.batch_at(step).items()}
            t0 = time.time()
            state, metrics = step_fn(state, batch)
            metrics = {k: float(v) for k, v in metrics.items()}
            metrics["step_time_s"] = time.time() - t0
            watchdog.beat()
            history.append(metrics)
            if metrics_cb:
                metrics_cb(step, metrics)
            if step % loop_cfg.log_every == 0:
                log.info("step %d loss=%.4f grad_norm=%.3f %.2fs", step,
                         metrics["loss"], metrics.get("grad_norm", 0.0),
                         metrics["step_time_s"])
            stop = guard.should_stop
            if ckpt and (stop or (step + 1) % loop_cfg.checkpoint_every == 0
                         or step + 1 == loop_cfg.total_steps):
                ckpt.save(step + 1, state,
                          extra={"data_step": step + 1},
                          blocking=not loop_cfg.async_checkpoint)
            if stop:
                log.warning("preempted: exiting cleanly at step %d", step)
                break
    if ckpt:
        ckpt.wait()
    return state, history
