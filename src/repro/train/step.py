"""The jitted train step: microbatched grad accumulation + AdamW.

Gradient accumulation runs as a ``lax.scan`` over microbatches — required
to fit the 4k x 256 global batch of the large architectures under 16 GB of
HBM per chip (saved activations scale with the *micro*batch).  The roofline
analyzer accounts for the scan trip counts through the cost-piece
decomposition (launch/dryrun.py), never through the full artifact.
"""

from __future__ import annotations

from typing import Callable, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.dist.sharding import Sharder
from repro.models.lm import LM
from repro.optim.adamw import AdamW, TrainState, adamw_update

F32 = jnp.float32


def microbatch(batch: Dict[str, jax.Array], n_micro: int) -> Dict[str, jax.Array]:
    def split(x):
        b = x.shape[0]
        assert b % n_micro == 0, (b, n_micro)
        return x.reshape((n_micro, b // n_micro) + x.shape[1:])
    return jax.tree.map(split, batch)


def make_train_step(model: LM, opt: AdamW, sharder: Sharder,
                    grad_transform: Optional[Callable] = None
                    ) -> Callable[[TrainState, Dict], Tuple[TrainState, Dict]]:
    cfg = model.cfg

    def loss_fn(params, mb):
        return model.loss(params, mb, sharder)

    grad_fn = jax.value_and_grad(loss_fn, has_aux=True)

    def train_step(state: TrainState, batch: Dict[str, jax.Array]):
        n_micro = cfg.n_microbatches
        params = state["params"]
        if n_micro == 1:
            (loss, metrics), grads = grad_fn(params, batch)
        else:
            mbs = microbatch(batch, n_micro)

            def micro(carry, mb):
                g_acc, loss_acc = carry
                (loss, metrics), g = grad_fn(params, mb)
                g_acc = jax.tree.map(lambda a, b: a + b.astype(F32), g_acc, g)
                return (g_acc, loss_acc + loss), metrics

            g0 = jax.tree.map(lambda p: jnp.zeros(p.shape, F32), params)
            (grads, loss_sum), metric_hist = jax.lax.scan(
                micro, (g0, jnp.zeros((), F32)), mbs)
            grads = jax.tree.map(lambda g: g / n_micro, grads)
            loss = loss_sum / n_micro
            metrics = jax.tree.map(lambda m: m[-1], metric_hist)
        if grad_transform is not None:  # e.g. compressed DP all-reduce
            grads = grad_transform(grads)
        new_state, opt_metrics = adamw_update(opt, state, grads)
        metrics = dict(metrics)
        metrics.update(opt_metrics)
        metrics["loss"] = loss
        return new_state, metrics

    return train_step
