"""Reduced configurations and helpers for smoke tests and examples.

``reduced_config(arch_id)`` shrinks each assigned architecture to a
CPU-friendly size while preserving its *family structure* (layer pattern,
GQA ratios, MoE routing, SSM/RWKV state shapes, softcaps, M-RoPE splits),
so the smoke tests exercise the same code paths the full configs lower.
"""

from __future__ import annotations

import dataclasses

from repro.configs import get_config
from repro.configs.base import ModelConfig, MoEConfig, RWKVConfig, SSMConfig, ShapeSpec


def reduced_config(arch: str, **overrides) -> ModelConfig:
    cfg = get_config(arch)
    r: dict = dict(
        d_model=64,
        n_heads=4,
        n_kv_heads=2,
        head_dim=16,
        d_ff=128,
        vocab_size=503,          # deliberately unaligned: exercises padding
        vocab_pad_to=64,
        n_microbatches=1,
        remat="full",
        fsdp=False,
    )
    if cfg.local_window:
        r["local_window"] = 16
    if cfg.moe is not None:
        r["moe"] = MoEConfig(n_experts=8, top_k=2, capacity_factor=1.5,
                             group_size=16)
        r["d_ff"] = 32
    if cfg.rwkv is not None:
        r["rwkv"] = RWKVConfig(head_dim=16, chunk=8)
        r["n_heads"] = 4
        r["n_kv_heads"] = 4
    if cfg.ssm is not None:
        r["ssm"] = SSMConfig(d_state=4, expand=2, head_dim=16, conv_width=4,
                             chunk=8)
    # shrink the stack to two periods of a (possibly shortened) pattern
    pattern = cfg.layer_pattern
    if len(pattern) > 4:
        kinds = list(dict.fromkeys(pattern))  # unique, order-preserving
        pattern = tuple(kinds) * (4 // max(1, len(kinds)))
        pattern = pattern or cfg.layer_pattern[:4]
    r["layer_pattern"] = pattern
    r["n_layers"] = 2 * len(pattern)
    if cfg.is_encoder_decoder:
        r["n_encoder_layers"] = 2
    if cfg.m_rope_sections:
        r["m_rope_sections"] = (4, 2, 2)  # sums to head_dim // 2
    r.update(overrides)
    return dataclasses.replace(cfg, **r)


def smoke_shape(mode: str = "train", seq: int = 16, batch: int = 2) -> ShapeSpec:
    return ShapeSpec(f"smoke_{mode}", seq, batch, mode)
