"""Checkpointing: atomic, sharded, async-capable, reshard-on-restore.

Design (scaled-down Orbax semantics, zero dependencies):

  * **Atomicity** — a checkpoint is written into ``step_<k>.tmp`` and
    renamed to ``step_<k>`` only after every leaf and the manifest are
    durably on disk; a crash mid-save never corrupts the latest step.
  * **Sharded save** — each host writes only the addressable shards of
    every array (single-host: the whole array), one ``.npy`` per leaf,
    names derived from the pytree path.
  * **Reshard on restore** — restore takes the *target* sharding tree and
    ``device_put``s each loaded leaf to it, so a checkpoint taken on one
    mesh restores onto another (elastic restart after losing a pod).
  * **Async** — ``save(..., blocking=False)`` snapshots to host memory and
    writes on a background thread, overlapping I/O with the next steps.
  * **Retention** — keeps the newest ``keep`` checkpoints.
"""

from __future__ import annotations

import json
import os
import re
import shutil
import threading
from typing import Any, Dict, Optional

import jax
import numpy as np


def _leaf_name(path) -> str:
    s = jax.tree_util.keystr(path)
    return re.sub(r"[^A-Za-z0-9_.-]+", "_", s).strip("_")


class CheckpointManager:
    def __init__(self, directory: str, keep: int = 3):
        self.directory = directory
        self.keep = keep
        os.makedirs(directory, exist_ok=True)
        self._thread: Optional[threading.Thread] = None

    # ------------------------------------------------------------------ save
    def save(self, step: int, state: Any, extra: Optional[Dict] = None,
             blocking: bool = True) -> None:
        self.wait()  # one in-flight save at a time
        # snapshot to host memory (cheap on CPU; device->host on TPU)
        leaves, treedef = jax.tree_util.tree_flatten_with_path(state)
        host = [(path, np.asarray(x)) for path, x in leaves]

        def _write():
            final = os.path.join(self.directory, f"step_{step:010d}")
            tmp = final + ".tmp"
            if os.path.exists(tmp):
                shutil.rmtree(tmp)
            os.makedirs(tmp)
            names = []
            for path, arr in host:
                name = _leaf_name(path)
                names.append(name)
                np.save(os.path.join(tmp, name + ".npy"), arr)
            manifest = {
                "step": step,
                "leaves": names,
                "extra": extra or {},
            }
            with open(os.path.join(tmp, "manifest.json"), "w") as f:
                json.dump(manifest, f)
                f.flush()
                os.fsync(f.fileno())
            if os.path.exists(final):
                shutil.rmtree(final)
            os.rename(tmp, final)
            self._gc()

        if blocking:
            _write()
        else:
            self._thread = threading.Thread(target=_write, daemon=True)
            self._thread.start()

    def wait(self) -> None:
        if self._thread is not None:
            self._thread.join()
            self._thread = None

    def _gc(self) -> None:
        steps = self.all_steps()
        for s in steps[:-self.keep] if self.keep else []:
            shutil.rmtree(os.path.join(self.directory, f"step_{s:010d}"),
                          ignore_errors=True)

    # --------------------------------------------------------------- restore
    def all_steps(self):
        out = []
        for name in os.listdir(self.directory):
            m = re.fullmatch(r"step_(\d+)", name)
            if m:
                out.append(int(m.group(1)))
        return sorted(out)

    def latest_step(self) -> Optional[int]:
        steps = self.all_steps()
        return steps[-1] if steps else None

    def _validate_step(self, step: int, need_names=None) -> str:
        """Up-front integrity check for a checkpoint step: the directory,
        its manifest, and every leaf file the manifest (plus the caller's
        target structure) declares must exist *before* any leaf is
        loaded, so a missing or partially-written step surfaces as ONE
        clear error listing everything absent — never a raw
        ``FileNotFoundError`` halfway through a tree rebuild."""
        d = os.path.join(self.directory, f"step_{step:010d}")
        if not os.path.isdir(d):
            have = self.all_steps()
            raise FileNotFoundError(
                f"checkpoint step {step} not found under {self.directory}"
                + (f"; available steps: {have}" if have
                   else "; no steps saved yet"))
        mpath = os.path.join(d, "manifest.json")
        if not os.path.exists(mpath):
            raise FileNotFoundError(
                f"checkpoint step {step} at {d} has no manifest.json — "
                f"the save was interrupted before the atomic rename; "
                f"delete the directory and restore an older step")
        with open(mpath) as f:
            manifest = json.load(f)
        declared = list(manifest.get("leaves", []))
        missing = [n for n in declared
                   if not os.path.exists(os.path.join(d, n + ".npy"))]
        extra_needed = [n for n in (need_names or []) if n not in declared]
        problems = []
        if missing:
            problems.append(f"manifest-declared leaf files missing on "
                            f"disk: {missing}")
        if extra_needed:
            problems.append(f"target structure needs leaves the manifest "
                            f"never saved: {extra_needed}")
        if problems:
            raise FileNotFoundError(
                f"checkpoint step {step} at {d} is incomplete: "
                + "; ".join(problems))
        return d

    @staticmethod
    def _load_leaf(path: str, like) -> np.ndarray:
        arr = np.load(path)
        want = getattr(like, "dtype", None)
        if want is None or arr.dtype == want:
            return arr
        if arr.dtype.kind == "V" and arr.dtype.itemsize == want.itemsize:
            # extension dtypes (bfloat16, float8, ...) round-trip through
            # .npy as raw void records; a bit-view restores them exactly
            return arr.view(want)
        return arr.astype(want)

    def restore(self, state_like: Any, step: Optional[int] = None,
                shardings: Any = None) -> Any:
        """Restore into the structure of ``state_like`` (abstract or
        concrete).  ``shardings``: matching tree of NamedShardings (or
        None leaves) — arrays are device_put to them (resharding).

        The step is validated up front (directory + manifest + every
        needed leaf file) so a partial checkpoint fails with one error
        naming what is absent, before any state is touched."""
        if step is None:
            step = self.latest_step()
        if step is None:
            raise FileNotFoundError(f"no checkpoints under {self.directory}")
        leaves, treedef = jax.tree_util.tree_flatten_with_path(state_like)
        d = self._validate_step(step,
                                need_names=[_leaf_name(p) for p, _ in leaves])
        sh_leaves = (jax.tree_util.tree_leaves(
            shardings, is_leaf=lambda x: x is None)
            if shardings is not None else [None] * len(leaves))
        out = []
        for (path, like), sh in zip(leaves, sh_leaves):
            arr = self._load_leaf(os.path.join(d, _leaf_name(path) + ".npy"),
                                  like)
            out.append(jax.device_put(arr, sh) if sh is not None
                       else jax.numpy.asarray(arr))
        return jax.tree_util.tree_unflatten(
            jax.tree_util.tree_structure(state_like), out)

    def manifest(self, step: int) -> Dict:
        d = self._validate_step(step)
        with open(os.path.join(d, "manifest.json")) as f:
            return json.load(f)
