"""Mixed-precision storage: int8 symmetric quantization and blocked
floating point.

The paper's precision scheme (§3.3, §4.1): weights live on-chip in 8-bit,
multiplies run narrow, the reduction tree widens (16-bit first stage) and
accumulation is 32-bit.  On TPU this maps to int8 HBM/VMEM storage with
bf16 multiplies and f32 MXU accumulation.  Serving is memory-bound at
decode, so 8-bit storage directly halves the dominant roofline term —
the framework exposes it for:

  * weights (``quantize_tree`` over a served param tree),
  * the KV cache (``quantize_kv``/``dequantize_kv``),
  * gradient all-reduce compression (:mod:`repro.optim.compression`).

``blocked_fp`` emulates Brainwave's shared-exponent block floating point
(hv values share a 5-bit exponent) for the DeepBench accuracy comparison.
"""

from __future__ import annotations

from typing import Any, Optional, Tuple

import jax
import jax.numpy as jnp

F32 = jnp.float32
INT8_MAX = 127.0


def quantize_int8(x: jax.Array, axis: int = -1,
                  ) -> Tuple[jax.Array, jax.Array]:
    """Symmetric per-slice int8 quantization along ``axis``.

    Returns (q int8, scale f32) with x ~= q * scale (scale broadcastable)."""
    xf = x.astype(F32)
    amax = jnp.max(jnp.abs(xf), axis=axis, keepdims=True)
    scale = jnp.maximum(amax, 1e-8) / INT8_MAX
    q = jnp.clip(jnp.round(xf / scale), -INT8_MAX, INT8_MAX).astype(jnp.int8)
    return q, scale


def dequantize_int8(q: jax.Array, scale: jax.Array,
                    dtype=jnp.bfloat16) -> jax.Array:
    return (q.astype(F32) * scale.astype(F32)).astype(dtype)


# ---------------------------------------------------------------------------
# Weight-tree quantization for serving
# ---------------------------------------------------------------------------

# Eligibility: matmul weights with a reasonably wide output dim and enough
# input rows for stable per-channel scales.  Embedding tables stay wide
# (gather path, accuracy-sensitive); norm scales / biases are 1-D anyway.
_MIN_OUT_DIM = 256
_MIN_IN_DIM = 64


def should_quantize(path: str, shape, dtype) -> bool:
    if "embedding" in path:
        return False
    return (len(shape) >= 2 and shape[-1] >= _MIN_OUT_DIM
            and shape[-2] >= _MIN_IN_DIM
            and jnp.dtype(dtype) in (jnp.dtype(jnp.float32),
                                     jnp.dtype(jnp.bfloat16)))


def quantize_tree(params: Any) -> Any:
    """Quantize every eligible matmul weight to {q: int8, scale: f32};
    ineligible leaves are cast to bf16 and stay plain arrays.

    Reduction happens over the *input* (second-to-last) dim so each output
    channel has its own scale — the layout a W8A16 matvec kernel wants.
    ``repro.models.layers.wcast`` consumes either form."""
    def quant_leaf(path, x):
        name = jax.tree_util.keystr(path)
        if not should_quantize(name, x.shape, x.dtype):
            return x.astype(jnp.bfloat16) if jnp.issubdtype(
                x.dtype, jnp.floating) else x
        q, scale = quantize_int8(x, axis=-2)
        return {"q": q, "scale": scale.astype(F32)}
    return jax.tree_util.tree_map_with_path(quant_leaf, params)


def serving_specs(specs: Any, int8: bool = False) -> Any:
    """Transform a ParamSpec tree into its serving layout: bf16 storage, or
    {q: int8, scale: f32} dict-leaves for eligible weights when int8."""
    import dataclasses

    from repro.models import params as pspec
    is_spec = pspec.is_spec

    def conv(path, s):
        if not jnp.issubdtype(jnp.dtype(s.dtype), jnp.floating):
            return s
        name = jax.tree_util.keystr(path)
        bf = dataclasses.replace(s, dtype=jnp.bfloat16)
        if not int8 or not should_quantize(name, s.shape, s.dtype):
            return bf
        scale_shape = s.shape[:-2] + (1,) + s.shape[-1:]
        scale_axes = (tuple(s.axes[:-2]) + (None,) + tuple(s.axes[-1:])
                      if s.axes else (None,) * len(scale_shape))
        return {
            "q": dataclasses.replace(s, dtype=jnp.int8),
            "scale": pspec.ParamSpec(scale_shape, F32, scale_axes,
                                     init="ones"),
        }
    return jax.tree_util.tree_map_with_path(conv, specs, is_leaf=is_spec)


# ---------------------------------------------------------------------------
# KV-cache quantization
# ---------------------------------------------------------------------------


def quantize_kv(kv: jax.Array) -> Tuple[jax.Array, jax.Array]:
    """(..., hd) tensors: scale per leading index (per token, per head)."""
    return quantize_int8(kv, axis=-1)


def dequantize_kv(q: jax.Array, scale: jax.Array) -> jax.Array:
    return dequantize_int8(q, scale, jnp.bfloat16)


# ---------------------------------------------------------------------------
# Blocked floating point (Brainwave emulation, for the accuracy benchmark)
# ---------------------------------------------------------------------------


def blocked_fp(x: jax.Array, block: int = 16, mantissa_bits: int = 4,
               axis: int = -1) -> jax.Array:
    """Round to a shared-exponent block format along ``axis``.

    Each block of ``block`` values shares one exponent (max exponent in the
    block); each value keeps a sign and ``mantissa_bits`` of mantissa."""
    xf = x.astype(F32)
    moved = jnp.moveaxis(xf, axis, -1)
    pad = (-moved.shape[-1]) % block
    if pad:
        moved = jnp.concatenate(
            [moved, jnp.zeros(moved.shape[:-1] + (pad,), F32)], axis=-1)
    blocks = moved.reshape(moved.shape[:-1] + (-1, block))
    amax = jnp.max(jnp.abs(blocks), axis=-1, keepdims=True)
    # shared exponent = floor(log2(amax)); quantize mantissa to m bits
    exp = jnp.floor(jnp.log2(jnp.maximum(amax, 1e-30)))
    step = jnp.exp2(exp - (mantissa_bits - 1))
    q = jnp.round(blocks / step) * step
    q = q.reshape(moved.shape)
    if pad:
        q = q[..., :-pad]
    return jnp.moveaxis(q, -1, axis).astype(x.dtype)
