"""RNN cell implementations: the paper's loop-based fused form and the
BLAS-based baselines it argues against.

Three execution models of the *same* LSTM/GRU math (§3 of the paper):

  "blas"      — BasicLSTM style (Fig. 1a): eight separate gate GEMVs
                (W_h·h and W_x·x per gate), every intermediate materialized.
  "semifused" — CudnnLSTM style (Fig. 1b): one concatenated [Wx|Wh] GEMV
                over [x;h], elementwise tail fused by the compiler, but the
                H-sized gate pre-activations still round-trip memory.
  "fused"     — the paper's loop-based form: gate dot products, bias,
                nonlinearities, and the c/h update fused into one kernel so
                intermediates never leave registers.  On TPU this is the
                Pallas kernel (repro.kernels.fused_rnn); this module holds
                its jnp semantics (= the kernel's oracle) plus the serving
                drivers that scan the cell over time with weights pinned
                on-chip.

Weights layout (all implementations share it):
  LSTM: w_x (D, 4, H), w_h (H, 4, H), b (4, H)   gate order (i, j, f, o)
  GRU:  w_x (D, 3, H), w_h (H, 3, H), b_x/b_h (3, H)  gate order (r, z, n)
"""

from __future__ import annotations

import dataclasses
from functools import partial
from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.core.quant import blocked_fp, dequantize_int8, quantize_int8

F32 = jnp.float32


@dataclasses.dataclass(frozen=True)
class RNNCellConfig:
    cell: str                 # "lstm" | "gru"
    hidden: int               # H
    features: int = 0         # D (DeepBench: D == H)
    timesteps: int = 1        # T
    batch: int = 1            # real-time serving: batch of 1
    precision: str = "int8"   # "int8" | "bf16" | "f32" | "blocked_fp"

    @property
    def d(self) -> int:
        return self.features or self.hidden

    @property
    def n_gates(self) -> int:
        return 4 if self.cell == "lstm" else 3

    def flops_per_step(self) -> float:
        """MACs x2: the gate matvecs dominate (paper §4.2: 2N^2 per N)."""
        g = self.n_gates
        return 2.0 * g * self.hidden * (self.hidden + self.d) * self.batch

    def weight_bytes(self) -> float:
        itemsize = {"int8": 1, "bf16": 2, "f32": 4, "blocked_fp": 1}[
            self.precision]
        g = self.n_gates
        return g * self.hidden * (self.hidden + self.d) * itemsize


def init_weights(cfg: RNNCellConfig, key: jax.Array) -> Dict[str, jax.Array]:
    g, H, D = cfg.n_gates, cfg.hidden, cfg.d
    k1, k2, k3 = jax.random.split(key, 3)
    s = 1.0 / jnp.sqrt(H + D)
    w = {
        "w_x": jax.random.uniform(k1, (D, g, H), F32, -s, s),
        "w_h": jax.random.uniform(k2, (H, g, H), F32, -s, s),
        "b": jnp.zeros((g, H), F32),
    }
    if cfg.cell == "gru":
        w["b_h"] = jnp.zeros((g, H), F32)
    return w


# ---------------------------------------------------------------------------
# Single-step cell math — three execution models
# ---------------------------------------------------------------------------


def lstm_step_blas(w, x, h, c):
    """BasicLSTM: one GEMV per (gate x input) — 8 kernels + adds."""
    outs = []
    for g in range(4):
        zx = x @ w["w_x"][:, g, :]           # separate kernels, materialized
        zh = h @ w["w_h"][:, g, :]
        outs.append(zx + zh + w["b"][g])
    i, j, f, o = outs
    c_new = jax.nn.sigmoid(f) * c + jax.nn.sigmoid(i) * jnp.tanh(j)
    h_new = jax.nn.sigmoid(o) * jnp.tanh(c_new)
    return h_new, c_new


def lstm_step_fused(w, x, h, c):
    """Loop-based/fused semantics: concatenated weights, single contraction,
    elementwise tail in registers.  (= the Pallas kernel's oracle.)"""
    B = x.shape[0]
    H = w["w_h"].shape[0]
    xh = jnp.concatenate([x, h], axis=-1)                    # (B, D+H)
    w_cat = jnp.concatenate([w["w_x"], w["w_h"]], axis=0)    # (D+H, 4, H)
    z = jax.lax.dot_general(                                 # one GEMV
        xh, w_cat.reshape(-1, 4 * H), (((1,), (0,)), ((), ())),
        preferred_element_type=F32).reshape(B, 4, H) + w["b"]
    i, j, f, o = z[:, 0], z[:, 1], z[:, 2], z[:, 3]
    c_new = jax.nn.sigmoid(f) * c + jax.nn.sigmoid(i) * jnp.tanh(j)
    h_new = jax.nn.sigmoid(o) * jnp.tanh(c_new)
    return h_new, c_new


def gru_step_blas(w, x, h):
    zx = [x @ w["w_x"][:, g, :] + w["b"][g] for g in range(3)]
    zh = [h @ w["w_h"][:, g, :] + w["b_h"][g] for g in range(3)]
    r = jax.nn.sigmoid(zx[0] + zh[0])
    z = jax.nn.sigmoid(zx[1] + zh[1])
    n = jnp.tanh(zx[2] + r * zh[2])
    return (1 - z) * n + z * h


def gru_step_fused(w, x, h):
    B = x.shape[0]
    H = w["w_h"].shape[0]
    mm = lambda a, ww: jax.lax.dot_general(
        a, ww.reshape(ww.shape[0], 3 * H), (((1,), (0,)), ((), ())),
        preferred_element_type=F32).reshape(B, 3, H)
    zx = mm(x, w["w_x"]) + w["b"]
    zh = mm(h, w["w_h"]) + w["b_h"]
    r = jax.nn.sigmoid(zx[:, 0] + zh[:, 0])
    z = jax.nn.sigmoid(zx[:, 1] + zh[:, 1])
    n = jnp.tanh(zx[:, 2] + r * zh[:, 2])
    return (1 - z) * n + z * h


# ---------------------------------------------------------------------------
# Precision transforms
# ---------------------------------------------------------------------------


def quantize_weights(cfg: RNNCellConfig, w: Dict[str, jax.Array]) -> Dict:
    """Storage transform per cfg.precision (math still runs wide)."""
    if cfg.precision == "f32":
        return w
    if cfg.precision == "bf16":
        return {k: v.astype(jnp.bfloat16) for k, v in w.items()}
    if cfg.precision == "blocked_fp":
        return {k: (blocked_fp(v, block=16, mantissa_bits=4, axis=0)
                    if k.startswith("w_") else v) for k, v in w.items()}
    # int8: per-(gate, unit) symmetric scales over the contraction dim
    out = {}
    for k, v in w.items():
        if k.startswith("w_"):
            q, scale = quantize_int8(v, axis=0)
            out[k] = q
            out[k + "_scale"] = scale[0]                      # (g, H)
        else:
            out[k] = v
    return out


def dequantize_weights(w: Dict) -> Dict[str, jax.Array]:
    out = {}
    for k, v in w.items():
        if k.endswith("_scale"):
            continue
        if k + "_scale" in w:
            out[k] = v.astype(F32) * w[k + "_scale"][None]
        else:
            out[k] = v.astype(F32)
    return out


# ---------------------------------------------------------------------------
# Serving drivers: scan over time, weights stationary
# ---------------------------------------------------------------------------


def serve(cfg: RNNCellConfig, w: Dict, x_seq: jax.Array,
          impl: str = "fused",
          state: Optional[Tuple[jax.Array, ...]] = None,
          plan: Optional[Dict] = None) -> jax.Array:
    """Run the full T-step sequence.  x_seq: (T, B, D) -> y (T, B, H).

    ``impl``: "blas" | "semifused"/"fused" (jnp) | "kernel" (Pallas — see
    repro.kernels.fused_rnn.ops, dispatched there to keep this module
    importable without kernel deps).  ``plan`` is a ``tile_plans`` entry
    forwarded to the kernel path (bh / persistent geometry).
    """
    if impl == "kernel":
        from repro.kernels.fused_rnn import ops as kernel_ops
        return kernel_ops.serve(cfg, w, x_seq, state=state, plan=plan)
    wd = dequantize_weights(w) if cfg.precision in ("int8",) else \
        {k: v.astype(F32) for k, v in w.items()}
    B, H = x_seq.shape[1], cfg.hidden
    if state is None:
        h = jnp.zeros((B, H), F32)
        c = jnp.zeros((B, H), F32)
    else:
        h, c = state[0], (state[1] if len(state) > 1 else None)

    if cfg.cell == "lstm":
        step_fn = lstm_step_blas if impl == "blas" else lstm_step_fused

        def body(carry, x):
            h, c = carry
            h, c = step_fn(wd, x.astype(F32), h, c)
            return (h, c), h

        (_, _), ys = jax.lax.scan(body, (h, c), x_seq)
    else:
        step_fn = gru_step_blas if impl == "blas" else gru_step_fused

        def body(carry, x):
            h = step_fn(wd, x.astype(F32), carry)
            return h, h

        _, ys = jax.lax.scan(body, h, x_seq)
    return ys
