"""Design-space exploration for the fused RNN kernel.

The paper's central systems claim (§3.3, Table 7): exposing the loop
tiling/unrolling parameters (hv, hu, rv, ru) and searching them per problem
size yields consistent utilization across DeepBench, unlike a
fixed-geometry MVM engine (Brainwave's hv=400, rv=40, ru=6) that fragments
2-D.  On TPU the parameter space collapses to:

  rv  — lane vectorization: fixed at 128 by the MXU/VPU geometry,
  bh  — the H-tile (hv x hu analogue): the kernel's BlockSpec row count,
  ru  — reduction unrolling: subsumed by the MXU's internal systolic
         reduction over the contraction dim,

so the search is over ``bh`` under a VMEM-residency constraint, with an
analytic latency model built from the hardware constants in repro.hw.
``fragmentation`` reproduces Fig. 4's utilization comparison.

PR 9 widens the same :class:`Plan` record to the other three Pallas
kernels so ``ServingPlan.tile_plans`` can carry every kernel's BlockSpec
geometry: ``bq``/``bk`` for flash_attention (query/KV tile rows, searched
by :func:`best_attn_plan`) and ``bm``/``bn``/``bk`` for matmul_int8
(output/contraction tiles, :func:`best_matmul_plan`).  Fields a given
kernel does not use stay at their zero default and are stripped from the
serialized form by :func:`plan_dict`, so recurrent-cell plan dicts keep
the exact key set the committed BENCH trajectories embed.
"""

from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional, Tuple

from repro import hw
from repro.core.cells import RNNCellConfig

MXU = 128
SUBLANE = 8

# pipeline overhead per grid step (issue + reduction drain): the
# 2 + log2(lanes) + 1 cycles of paper §4.1, at ~1 GHz
_STEP_OVERHEAD_S = (2 + 7 + 1) / 0.94e9


@dataclasses.dataclass(frozen=True)
class Plan:
    bh: int                   # H-tile rows per grid step
    n_tiles: int              # grid steps (H / bh for the RNN kernels)
    vmem_bytes: int           # working set claimed by the BlockSpecs
    resident: bool            # working set fits the VMEM budget
    step_latency_s: float     # modeled per-timestep latency
    util: float               # useful MACs / padded MACs
    bound: str                # "compute" | "vmem" | "hbm" | "latency"
    # --- per-kernel tile fields (zero = unused by this kernel) ----------
    bq: int = 0               # flash_attention: query rows per grid step
    bk: int = 0               # flash_attention KV tile / matmul K tile
    bm: int = 0               # matmul_int8: output rows per grid step
    bn: int = 0               # matmul_int8: output cols per grid step
    persistent: bool = False  # fused decode keeps weights VMEM-resident
    #                           across the device loop (requires n_tiles=1)


# Plan fields stripped by plan_dict() when at their unused default, so a
# recurrent-cell plan serializes to the same key set as before PR 9.
_OPTIONAL_PLAN_FIELDS = ("bq", "bk", "bm", "bn", "persistent")


def plan_dict(plan: Plan) -> Dict[str, object]:
    """Compact JSON form of a Plan: optional tile fields at their unused
    defaults are dropped (``tile_plans`` entries embedded in committed
    BENCH cells predate them), and ``bh: 0`` likewise vanishes for the
    attention/matmul plans that have no H tile."""
    d = dataclasses.asdict(plan)
    for name in _OPTIONAL_PLAN_FIELDS:
        if not d[name]:
            del d[name]
    if not d["bh"]:
        del d["bh"]
    return d


def snap_tile(dim: int, tile: int) -> int:
    """Largest divisor of ``dim`` that is <= ``tile`` (always >= 1).

    The ops wrappers snap a requested tile to the nearest feasible
    BlockSpec geometry instead of asserting, so a plan autotuned for one
    shape degrades gracefully on a non-divisible one."""
    dim, tile = int(dim), int(tile)
    tile = max(1, min(tile, dim))
    while dim % tile:
        tile -= 1
    return tile


def _pad(n: int, m: int) -> int:
    return -(-n // m) * m


def tile_vmem_bytes(cfg: RNNCellConfig, bh: int, *,
                    max_batch: Optional[int] = None) -> int:
    """VMEM bytes claimed per grid step (weights + state + io).

    ``max_batch`` overrides ``cfg.batch``: the serving engine decodes
    ``max_batch`` slots per step, so the h/c state and io buffers scale
    with it even though the DeepBench cell configs say batch 1."""
    g, H, D = cfg.n_gates, cfg.hidden, cfg.d
    B = cfg.batch if max_batch is None else max_batch
    wbytes = 1 if cfg.precision in ("int8", "blocked_fp") else 2
    w_block = (D + H) * g * bh * wbytes
    n_tiles = H // bh
    weights = w_block * (1 if n_tiles == 1 else 2)   # double-buffer if streaming
    state = (2 * B * H + B * H) * 4                  # h double buffer + c
    io = B * (D + bh) * 2 * 2
    scales = 2 * g * bh * 4 + 2 * g * bh * 4
    return weights + state + io + scales


def plan_metrics(cfg: RNNCellConfig, bh: int,
                 spec: hw.HardwareSpec = hw.DEFAULT, *,
                 max_batch: Optional[int] = None) -> Plan:
    """Score one tile choice.  ``max_batch`` threads the *serving* batch
    dimension through the model: the engine runs a batched decode over
    ``max_batch`` slots, so both the compute bound (sublane-padded batch)
    and the VMEM working set (h/c state, io) must be scored at the batch
    the engine actually runs — a tile that is VMEM-resident at batch 1
    can spill at batch 64, flipping the best plan to smaller tiles."""
    g, H, D = cfg.n_gates, cfg.hidden, cfg.d
    B = cfg.batch if max_batch is None else max_batch
    R = D + H
    n_tiles = H // bh
    vmem = tile_vmem_bytes(cfg, bh, max_batch=max_batch)
    resident = vmem <= hw.vmem_budget(spec)

    # --- utilization: 1-D fragmentation on R only (Fig. 4b).  The batch-
    # padding penalty of the MXU is a *latency* effect (modeled below),
    # not a fragmentation effect — the paper's Fig. 4 compares tiling
    # geometries at fixed batch.
    true_macs = g * H * R
    padded_macs = g * _pad(H, MXU) * _pad(R, MXU)
    util = true_macs / padded_macs

    # --- per-step time: three bounds
    # (1) MXU compute with sublane-padded batch,
    # (2) VMEM weight streaming — a matvec reads every resident weight
    #     byte per step, so small-batch serving is VMEM-bandwidth-bound
    #     (the paper's §4.2 compute:memory-read ratio argument),
    # (3) HBM streaming when the weights don't fit VMEM.
    mul_peak = (spec.peak_int8_ops if cfg.precision in ("int8", "blocked_fp")
                else spec.peak_bf16_flops)
    compute_s = 2.0 * padded_macs * max(B, SUBLANE) / mul_peak
    vmem_s = cfg.weight_bytes() / spec.vmem_bw
    hbm_s = 0.0 if resident else cfg.weight_bytes() / spec.hbm_bw
    # fixed pipeline overhead per tile (grid step issue + reduction drain)
    overhead_s = n_tiles * _STEP_OVERHEAD_S
    slowest = max(compute_s, vmem_s, hbm_s)
    lat = slowest + overhead_s
    # explicit comparison (a dict keyed by the times would merge entries
    # whenever two bounds are numerically equal); ties break toward the
    # earlier term in compute > vmem > hbm order
    if slowest == compute_s:
        bound = "compute"
    elif slowest == vmem_s:
        bound = "vmem"
    else:
        bound = "hbm"
    if overhead_s > slowest:
        bound = "latency"
    return Plan(bh=bh, n_tiles=n_tiles, vmem_bytes=vmem, resident=resident,
                step_latency_s=lat, util=util, bound=bound)


def candidate_tiles(H: int) -> List[int]:
    c = []
    bh = SUBLANE
    while bh <= H:
        if H % bh == 0:
            c.append(bh)
        bh *= 2
    if H not in c and H % SUBLANE == 0:
        c.append(H)
    return c or [H]


def search(cfg: RNNCellConfig, spec: hw.HardwareSpec = hw.DEFAULT, *,
           max_batch: Optional[int] = None) -> List[Plan]:
    return [plan_metrics(cfg, bh, spec, max_batch=max_batch)
            for bh in candidate_tiles(cfg.hidden)]


def best_plan(cfg: RNNCellConfig, spec: hw.HardwareSpec = hw.DEFAULT, *,
              max_batch: Optional[int] = None) -> Plan:
    plans = [p for p in search(cfg, spec, max_batch=max_batch)
             if p.vmem_bytes <= hw.vmem_budget(spec)]
    if not plans:  # weights can never be resident; stream with big tiles
        plans = search(cfg, spec, max_batch=max_batch)
    return min(plans, key=lambda p: p.step_latency_s)


# ---------------------------------------------------------------------------
# flash_attention tile search (bq x bk)
# ---------------------------------------------------------------------------


def candidate_attn_tiles(seq_q: int, seq_kv: int) -> List[Tuple[int, int]]:
    """(bq, bk) grid: power-of-two divisors, bq from the sublane count up,
    bk from one lane row (128) up — the shapes the TPU tiles natively."""
    bqs = [t for t in (8, 16, 32, 64, 128, 256)
           if t <= seq_q and seq_q % t == 0] or [snap_tile(seq_q, 256)]
    bks = [t for t in (128, 256, 512, 1024)
           if t <= seq_kv and seq_kv % t == 0] or [snap_tile(seq_kv, 512)]
    return [(bq, bk) for bq in bqs for bk in bks]


def attn_tile_vmem_bytes(bq: int, bk: int, head_dim: int) -> int:
    """VMEM per flash grid step: q tile + double-buffered k/v tiles +
    f32 score block + f32 accumulator/softmax-state scratch + out tile."""
    q = bq * head_dim * 2
    kv = 2 * (2 * bk * head_dim * 2)      # k and v, double-buffered
    scores = bq * bk * 4
    acc = bq * head_dim * 4 + 2 * bq * 4  # acc + (m, l)
    out = bq * head_dim * 2
    return q + kv + scores + acc + out


def attn_plan_metrics(seq_q: int, seq_kv: int, head_dim: int,
                      bq: int, bk: int,
                      spec: hw.HardwareSpec = hw.DEFAULT, *,
                      n_heads: int = 1, batch: int = 1) -> Plan:
    """Score one flash_attention tile choice (QK^T + AV roofline)."""
    ntq, ntk = seq_q // bq, seq_kv // bk
    n_steps = batch * n_heads * ntq * ntk
    vmem = attn_tile_vmem_bytes(bq, bk, head_dim)
    resident = vmem <= hw.vmem_budget(spec)

    true_macs = 2 * seq_q * seq_kv * head_dim          # QK^T and AV
    padded_macs = (2 * ntq * ntk * _pad(bq, SUBLANE)
                   * _pad(bk, MXU) * _pad(head_dim, MXU))
    util = true_macs / padded_macs

    compute_s = 2.0 * padded_macs * batch * n_heads / spec.peak_bf16_flops
    # K/V stream once per query tile; q and out stream once
    kv_bytes = batch * n_heads * ntq * seq_kv * head_dim * 2 * 2
    qo_bytes = batch * n_heads * seq_q * head_dim * 2 * 2
    hbm_s = (kv_bytes + qo_bytes) / spec.hbm_bw
    overhead_s = n_steps * _STEP_OVERHEAD_S
    slowest = max(compute_s, hbm_s)
    bound = "compute" if slowest == compute_s else "hbm"
    if overhead_s > slowest:
        bound = "latency"
    return Plan(bh=0, n_tiles=n_steps, vmem_bytes=vmem, resident=resident,
                step_latency_s=slowest + overhead_s, util=util, bound=bound,
                bq=bq, bk=bk)


def best_attn_plan(seq_q: int, seq_kv: int, head_dim: int,
                   spec: hw.HardwareSpec = hw.DEFAULT, *,
                   n_heads: int = 1, batch: int = 1) -> Plan:
    plans = [attn_plan_metrics(seq_q, seq_kv, head_dim, bq, bk, spec,
                               n_heads=n_heads, batch=batch)
             for bq, bk in candidate_attn_tiles(seq_q, seq_kv)]
    feasible = [p for p in plans if p.resident] or plans
    return min(feasible, key=lambda p: p.step_latency_s)


# ---------------------------------------------------------------------------
# matmul_int8 tile search (bm x bn x bk)
# ---------------------------------------------------------------------------


def candidate_mm_tiles(M: int, N: int, K: int) -> List[Tuple[int, int, int]]:
    bms = [t for t in (8, 32, 64, 128, 256)
           if t <= M and M % t == 0] or [snap_tile(M, 256)]
    bns = [t for t in (128, 256, 512)
           if t <= N and N % t == 0] or [snap_tile(N, 256)]
    bks = [t for t in (128, 256, 512)
           if t <= K and K % t == 0] or [snap_tile(K, 512)]
    return [(bm, bn, bk) for bm in bms for bn in bns for bk in bks]


def matmul_tile_vmem_bytes(bm: int, bn: int, bk: int) -> int:
    """VMEM per matmul grid step: double-buffered x/w tiles + f32
    accumulator + out tile + per-column scale/bias row."""
    x = 2 * bm * bk * 2
    w = 2 * bk * bn * 1
    acc = bm * bn * 4
    out = bm * bn * 2
    scale = 2 * bn * 4
    return x + w + acc + out + scale


def matmul_plan_metrics(M: int, N: int, K: int,
                        bm: int, bn: int, bk: int,
                        spec: hw.HardwareSpec = hw.DEFAULT) -> Plan:
    """Score one W8A16 matmul tile choice.  The kernel widens int8
    weights to bf16 before the MXU dot, so compute runs at bf16 peak;
    the win from int8 is the halved weight stream."""
    ntm, ntn, ntk = M // bm, N // bn, K // bk
    n_steps = ntm * ntn * ntk
    vmem = matmul_tile_vmem_bytes(bm, bn, bk)
    resident = vmem <= hw.vmem_budget(spec)

    true_macs = M * N * K
    padded_macs = (n_steps * _pad(bm, SUBLANE)
                   * _pad(bn, MXU) * _pad(bk, MXU))
    util = true_macs / padded_macs

    compute_s = 2.0 * padded_macs / spec.peak_bf16_flops
    # weights stream once per m-tile, activations once per n-tile
    hbm_bytes = ntm * K * N * 1 + ntn * M * K * 2 + M * N * 2
    hbm_s = hbm_bytes / spec.hbm_bw
    overhead_s = n_steps * _STEP_OVERHEAD_S
    slowest = max(compute_s, hbm_s)
    bound = "compute" if slowest == compute_s else "hbm"
    if overhead_s > slowest:
        bound = "latency"
    return Plan(bh=0, n_tiles=n_steps, vmem_bytes=vmem, resident=resident,
                step_latency_s=slowest + overhead_s, util=util, bound=bound,
                bk=bk, bm=bm, bn=bn)


def best_matmul_plan(M: int, N: int, K: int,
                     spec: hw.HardwareSpec = hw.DEFAULT) -> Plan:
    plans = [matmul_plan_metrics(M, N, K, bm, bn, bk, spec)
             for bm, bn, bk in candidate_mm_tiles(M, N, K)]
    feasible = [p for p in plans if p.resident] or plans
    return min(feasible, key=lambda p: p.step_latency_s)


# ---------------------------------------------------------------------------
# Fig. 4: fragmentation of MVM-tiled vs loop-based designs
# ---------------------------------------------------------------------------


def utilization_loop(H: int, R: int, rv: int = MXU, ru: int = 1) -> float:
    """Loop-based design: 1-D fragmentation on the reduction dim only."""
    return R / _pad(R, rv * ru)


def utilization_mvm(H: int, R: int, hv: int = 400, rv: int = 40,
                    ru: int = 6) -> float:
    """Brainwave-style tiled MVM: 2-D fragmentation on H and R
    (hv/rv/ru defaults = BW's Stratix-10 configuration, Table 7)."""
    return (H / _pad(H, hv)) * (R / _pad(R, rv * ru))


def fragmentation(H: int, D: Optional[int] = None) -> dict:
    R = H + (D if D is not None else H)
    return {
        "H": H, "R": R,
        "util_loop": utilization_loop(H, R),
        "util_mvm_bw": utilization_mvm(H, R),
    }
