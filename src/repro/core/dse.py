"""Design-space exploration for the fused RNN kernel.

The paper's central systems claim (§3.3, Table 7): exposing the loop
tiling/unrolling parameters (hv, hu, rv, ru) and searching them per problem
size yields consistent utilization across DeepBench, unlike a
fixed-geometry MVM engine (Brainwave's hv=400, rv=40, ru=6) that fragments
2-D.  On TPU the parameter space collapses to:

  rv  — lane vectorization: fixed at 128 by the MXU/VPU geometry,
  bh  — the H-tile (hv x hu analogue): the kernel's BlockSpec row count,
  ru  — reduction unrolling: subsumed by the MXU's internal systolic
         reduction over the contraction dim,

so the search is over ``bh`` under a VMEM-residency constraint, with an
analytic latency model built from the hardware constants in repro.hw.
``fragmentation`` reproduces Fig. 4's utilization comparison.
"""

from __future__ import annotations

import dataclasses
from typing import List, Optional

from repro import hw
from repro.core.cells import RNNCellConfig

MXU = 128
SUBLANE = 8


@dataclasses.dataclass(frozen=True)
class Plan:
    bh: int                   # H-tile rows per grid step
    n_tiles: int              # H / bh
    vmem_bytes: int           # working set claimed by the BlockSpecs
    resident: bool            # weights stay in VMEM across time steps
    step_latency_s: float     # modeled per-timestep latency
    util: float               # useful MACs / padded MACs
    bound: str                # "compute" | "hbm" | "latency"


def _pad(n: int, m: int) -> int:
    return -(-n // m) * m


def tile_vmem_bytes(cfg: RNNCellConfig, bh: int, *,
                    max_batch: Optional[int] = None) -> int:
    """VMEM bytes claimed per grid step (weights + state + io).

    ``max_batch`` overrides ``cfg.batch``: the serving engine decodes
    ``max_batch`` slots per step, so the h/c state and io buffers scale
    with it even though the DeepBench cell configs say batch 1."""
    g, H, D = cfg.n_gates, cfg.hidden, cfg.d
    B = cfg.batch if max_batch is None else max_batch
    wbytes = 1 if cfg.precision in ("int8", "blocked_fp") else 2
    w_block = (D + H) * g * bh * wbytes
    n_tiles = H // bh
    weights = w_block * (1 if n_tiles == 1 else 2)   # double-buffer if streaming
    state = (2 * B * H + B * H) * 4                  # h double buffer + c
    io = B * (D + bh) * 2 * 2
    scales = 2 * g * bh * 4 + 2 * g * bh * 4
    return weights + state + io + scales


def plan_metrics(cfg: RNNCellConfig, bh: int,
                 spec: hw.HardwareSpec = hw.DEFAULT, *,
                 max_batch: Optional[int] = None) -> Plan:
    """Score one tile choice.  ``max_batch`` threads the *serving* batch
    dimension through the model: the engine runs a batched decode over
    ``max_batch`` slots, so both the compute bound (sublane-padded batch)
    and the VMEM working set (h/c state, io) must be scored at the batch
    the engine actually runs — a tile that is VMEM-resident at batch 1
    can spill at batch 64, flipping the best plan to smaller tiles."""
    g, H, D = cfg.n_gates, cfg.hidden, cfg.d
    B = cfg.batch if max_batch is None else max_batch
    R = D + H
    n_tiles = H // bh
    vmem = tile_vmem_bytes(cfg, bh, max_batch=max_batch)
    resident = vmem <= hw.vmem_budget(spec)

    # --- utilization: 1-D fragmentation on R only (Fig. 4b).  The batch-
    # padding penalty of the MXU is a *latency* effect (modeled below),
    # not a fragmentation effect — the paper's Fig. 4 compares tiling
    # geometries at fixed batch.
    true_macs = g * H * R
    padded_macs = g * _pad(H, MXU) * _pad(R, MXU)
    util = true_macs / padded_macs

    # --- per-step time: three bounds
    # (1) MXU compute with sublane-padded batch,
    # (2) VMEM weight streaming — a matvec reads every resident weight
    #     byte per step, so small-batch serving is VMEM-bandwidth-bound
    #     (the paper's §4.2 compute:memory-read ratio argument),
    # (3) HBM streaming when the weights don't fit VMEM.
    mul_peak = (spec.peak_int8_ops if cfg.precision in ("int8", "blocked_fp")
                else spec.peak_bf16_flops)
    compute_s = 2.0 * padded_macs * max(B, SUBLANE) / mul_peak
    vmem_s = cfg.weight_bytes() / spec.vmem_bw
    hbm_s = 0.0 if resident else cfg.weight_bytes() / spec.hbm_bw
    # fixed pipeline overhead per tile (grid step issue + reduction drain),
    # the 2 + log2(lanes) + 1 cycles of paper §4.1, at ~1 GHz
    overhead_s = n_tiles * (2 + 7 + 1) / 0.94e9
    slowest = max(compute_s, vmem_s, hbm_s)
    lat = slowest + overhead_s
    # explicit comparison (a dict keyed by the times would merge entries
    # whenever two bounds are numerically equal); ties break toward the
    # earlier term in compute > vmem > hbm order
    if slowest == compute_s:
        bound = "compute"
    elif slowest == vmem_s:
        bound = "vmem"
    else:
        bound = "hbm"
    if overhead_s > slowest:
        bound = "latency"
    return Plan(bh=bh, n_tiles=n_tiles, vmem_bytes=vmem, resident=resident,
                step_latency_s=lat, util=util, bound=bound)


def candidate_tiles(H: int) -> List[int]:
    c = []
    bh = SUBLANE
    while bh <= H:
        if H % bh == 0:
            c.append(bh)
        bh *= 2
    if H not in c and H % SUBLANE == 0:
        c.append(H)
    return c or [H]


def search(cfg: RNNCellConfig, spec: hw.HardwareSpec = hw.DEFAULT, *,
           max_batch: Optional[int] = None) -> List[Plan]:
    return [plan_metrics(cfg, bh, spec, max_batch=max_batch)
            for bh in candidate_tiles(cfg.hidden)]


def best_plan(cfg: RNNCellConfig, spec: hw.HardwareSpec = hw.DEFAULT, *,
              max_batch: Optional[int] = None) -> Plan:
    plans = [p for p in search(cfg, spec, max_batch=max_batch)
             if p.vmem_bytes <= hw.vmem_budget(spec)]
    if not plans:  # weights can never be resident; stream with big tiles
        plans = search(cfg, spec, max_batch=max_batch)
    return min(plans, key=lambda p: p.step_latency_s)


# ---------------------------------------------------------------------------
# Fig. 4: fragmentation of MVM-tiled vs loop-based designs
# ---------------------------------------------------------------------------


def utilization_loop(H: int, R: int, rv: int = MXU, ru: int = 1) -> float:
    """Loop-based design: 1-D fragmentation on the reduction dim only."""
    return R / _pad(R, rv * ru)


def utilization_mvm(H: int, R: int, hv: int = 400, rv: int = 40,
                    ru: int = 6) -> float:
    """Brainwave-style tiled MVM: 2-D fragmentation on H and R
    (hv/rv/ru defaults = BW's Stratix-10 configuration, Table 7)."""
    return (H / _pad(H, hv)) * (R / _pad(R, rv * ru))


def fragmentation(H: int, D: Optional[int] = None) -> dict:
    R = H + (D if D is not None else H)
    return {
        "H": H, "R": R,
        "util_loop": utilization_loop(H, R),
        "util_mvm_bw": utilization_mvm(H, R),
    }
