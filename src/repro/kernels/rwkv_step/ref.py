"""Oracle for the fused RWKV6 step kernel: the framework's own
``linear_attention_step`` scanned over tokens."""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models.recurrence import linear_attention_step

F32 = jnp.float32


def rwkv6_step_ref(r, k, v, w_log, u, state):
    def step(S, inputs):
        rt, kt, vt, wt = inputs
        y, S = linear_attention_step(S, rt, kt, vt, wt,
                                     convention="exclusive", u=u)
        return S, y.astype(jnp.bfloat16)

    state, ys = jax.lax.scan(step, state.astype(F32), (r, k, v, w_log))
    return ys, state
