"""Jit'd wrapper: model-layout adapter for the fused RWKV6 step kernel.

Consumes the rwkv block's projections ((B, T, d) flat) and drives the
kernel in the (T, B, H, K) layout; used by the serving path on TPU and
validated in interpret mode on CPU.  A ``tile_plans["rwkv"]`` entry sets
the head tile: its ``bh`` is in hidden units (the DSE cell model's H
rows), converted to whole heads here and snapped to a divisor of the
head count.
"""

from __future__ import annotations

from typing import Mapping, Optional

import jax
import jax.numpy as jnp

from repro.kernels.dispatch import interpret_mode, tile_arg
from repro.kernels.rwkv_step.rwkv_step import rwkv6_step


def head_tile(n_heads: int, head_dim: int,
              plan: Optional[Mapping[str, object]]) -> int:
    """Heads per grid step for a plan whose ``bh`` counts hidden units."""
    from repro.core.dse import snap_tile

    bh_units = tile_arg(plan, "bh", 0)
    if not bh_units:
        return n_heads
    return snap_tile(n_heads, max(1, bh_units // head_dim))


def serve_wkv(r, k, v, w_log, u, state, *, head_dim: int = 64,
              interpret=None, plan: Optional[Mapping[str, object]] = None):
    """r/k/v/w_log: (B, T, d); u: (d,); state: (B, H, hd, hd) f32."""
    if interpret is None:
        interpret = interpret_mode()
    B, T, d = r.shape
    H = d // head_dim
    to = lambda x: x.reshape(B, T, H, head_dim).transpose(1, 0, 2, 3)
    y, state = rwkv6_step(to(r), to(k), to(v), to(w_log),
                          u.reshape(H, head_dim), state,
                          bh=head_tile(H, head_dim, plan),
                          interpret=interpret)
    return y.transpose(1, 0, 2, 3).reshape(B, T, d), state
