"""Jit'd wrapper: model-layout adapter for the fused RWKV6 step kernel.

Consumes the rwkv block's projections ((B, T, d) flat) and drives the
kernel in the (T, B, H, K) layout; used by the serving path on TPU and
validated in interpret mode on CPU.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.kernels.rwkv_step.rwkv_step import rwkv6_step


def serve_wkv(r, k, v, w_log, u, state, *, head_dim: int = 64,
              interpret=None):
    """r/k/v/w_log: (B, T, d); u: (d,); state: (B, H, hd, hd) f32."""
    if interpret is None:
        interpret = jax.default_backend() != "tpu"
    B, T, d = r.shape
    H = d // head_dim
    to = lambda x: x.reshape(B, T, H, head_dim).transpose(1, 0, 2, 3)
    y, state = rwkv6_step(to(r), to(k), to(v), to(w_log),
                          u.reshape(H, head_dim), state,
                          interpret=interpret)
    return y.transpose(1, 0, 2, 3).reshape(B, T, d), state
