"""Pallas TPU kernel: fused RWKV6 serving step (the paper's LSTM-1
pattern on the modern recurrent cell).

One kernel evaluates, per (batch, head) tile, the whole wkv recurrence for
a token:

    y   = r . (S + (u * k) v^T)
    S' <- diag(w) S + k v^T

with the state S resident in VMEM across the grid and every intermediate
(outer product, bonus read) in registers — no (K, V)-sized tensor ever
round-trips HBM, which is exactly the paper's cross-kernel-fusion claim
applied to RWKV serving.  Multi-token serving loops this kernel over a
grid t-axis with the state carried in the output buffer (in/out aliased).

Layouts: r/k/w (T, B, H, K); v (T, B, H, V); u (H, K); state (B, H, K, V).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro._jax_compat import tpu_compiler_params

_CompilerParams = tpu_compiler_params()

F32 = jnp.float32


def _kernel(r_ref, k_ref, v_ref, w_ref, u_ref, s0_ref,
            y_ref, sT_ref, s_scr, *, bh: int):
    t = pl.program_id(0)
    hb = pl.program_id(1)
    T = pl.num_programs(0)
    sl = pl.ds(hb * bh, bh)                         # this tile's heads

    @pl.when(t == 0)
    def _init():
        s_scr[:, sl] = s0_ref[...].astype(F32)      # (B, bh, K, V)

    r = r_ref[0].astype(F32)                        # (B, bh, K)
    k = k_ref[0].astype(F32)
    w = w_ref[0].astype(F32)                        # log-decay, <= 0
    v = v_ref[0].astype(F32)                        # (B, bh, V)
    u = u_ref[...].astype(F32)                      # (bh, K)

    S = s_scr[:, sl]
    kv = k[..., None] * v[:, :, None, :]            # (B, bh, K, V)
    read = S + u[None, :, :, None] * kv
    y = jnp.sum(r[..., None] * read, axis=2)        # (B, bh, V)
    s_scr[:, sl] = jnp.exp(w)[..., None] * S + kv
    y_ref[0] = y.astype(y_ref.dtype)

    @pl.when(t == T - 1)
    def _final():
        sT_ref[...] = s_scr[:, sl]


@functools.partial(jax.jit, static_argnames=("bh", "interpret"))
def rwkv6_step(r, k, v, w_log, u, state, *, bh: int = 0,
               interpret: bool = False):
    """Serve T tokens through the fused recurrence.

    r/k/w_log: (T, B, H, K); v: (T, B, H, V); u: (H, K);
    state: (B, H, K, V) f32.  Returns (y (T, B, H, V) bf16, state').

    ``bh`` tiles the head axis (grid (T, H/bh), t-major): heads are
    independent, so any head split is bit-exact; 0 = all heads in one
    tile (the pre-DSE default).  The state scratch stays full-size and
    each tile owns its slice — tiles carry no cross-tile state."""
    T, B, H, K = r.shape
    V = v.shape[-1]
    bh = bh or H
    assert H % bh == 0, (H, bh)
    step = pl.BlockSpec((1, B, bh, K), lambda t, h: (t, 0, h, 0))
    stepv = pl.BlockSpec((1, B, bh, V), lambda t, h: (t, 0, h, 0))
    tile = pl.BlockSpec((B, bh, K, V), lambda t, h: (0, h, 0, 0))
    return pl.pallas_call(
        functools.partial(_kernel, bh=bh),
        grid=(T, H // bh),
        in_specs=[step, step, stepv, step,
                  pl.BlockSpec((bh, K), lambda t, h: (h, 0)), tile],
        out_specs=[stepv, tile],
        out_shape=[
            jax.ShapeDtypeStruct((T, B, H, V), jnp.bfloat16),
            jax.ShapeDtypeStruct((B, H, K, V), F32),
        ],
        scratch_shapes=[pltpu.VMEM((B, H, K, V), F32)],
        compiler_params=_CompilerParams(
            dimension_semantics=("arbitrary", "arbitrary")),
        interpret=interpret,
        name="rwkv6_step",
    )(r, k, v, w_log, u, state)
