"""Pallas TPU kernel: the paper's loop-based fused RNN cell.

The Plasticine mapping (paper §3.3/§4) translated to the TPU memory
hierarchy (DESIGN.md §Hardware-adaptation):

  Plasticine                         TPU (this kernel)
  ----------------------------------- -----------------------------------
  weights resident in PMU scratchpads  weight blocks resident in VMEM; the
                                       BlockSpec index map is constant in t,
                                       so Pallas fetches each block from HBM
                                       once and reuses it for all T steps
  per-element LSTM-1 dataflow          per-tile fused dataflow: gate dots,
                                       scale/bias, nonlinearities, c/h
                                       update in one kernel body (VREGs)
  hu x ru spatial unrolling            grid dimension over H-tiles (bh) and
                                       the MXU's 128-lane parallelism (rv)
  8-bit multiply, 16/32-bit reduce     int8 weight storage, bf16 multiply,
                                       f32 MXU accumulation
  recurrent state in registers         h/c carried across grid steps in a
                                       VMEM scratch accumulator; h is
                                       double-buffered by t parity so later
                                       H-tiles of step t still read h_{t-1}

Grid: (T, H/bh), executed sequentially ("arbitrary" semantics) — t-major,
tile-minor, which is exactly the paper's loop nest in Fig. 5.

Weight layout: w_x (D, G, H), w_h (H, G, H); gate order (i, j, f, o) for
LSTM, (r, z, n) for GRU.  Scales are per (gate, unit) as produced by
``repro.core.cells.quantize_weights``.
"""

from __future__ import annotations

import functools
from typing import Optional, Tuple

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro._jax_compat import tpu_compiler_params

_CompilerParams = tpu_compiler_params()

F32 = jnp.float32


def _gates_matmul(x, h_prev, wx_ref, wh_ref, sx_ref, sh_ref, G, bh):
    """(B,D)x(D,G*bh) + (B,H)x(H,G*bh) with int8->bf16 widening and f32
    accumulation; returns the two pre-activation halves (B, G, bh)."""
    B = x.shape[0]
    wx = wx_ref[...].reshape(wx_ref.shape[0], G * bh)
    wh = wh_ref[...].reshape(wh_ref.shape[0], G * bh)
    zx = jax.lax.dot_general(
        x.astype(jnp.bfloat16), wx.astype(jnp.bfloat16),
        (((1,), (0,)), ((), ())), preferred_element_type=F32)
    zh = jax.lax.dot_general(
        h_prev.astype(jnp.bfloat16), wh.astype(jnp.bfloat16),
        (((1,), (0,)), ((), ())), preferred_element_type=F32)
    zx = zx.reshape(B, G, bh) * sx_ref[...]
    zh = zh.reshape(B, G, bh) * sh_ref[...]
    return zx, zh


def _lstm_kernel(x_ref, wx_ref, wh_ref, sx_ref, sh_ref, b_ref,
                 h0_ref, c0_ref,
                 y_ref, hT_ref, cT_ref,
                 h_scr, c_scr, *, bh: int):
    t = pl.program_id(0)
    hb = pl.program_id(1)
    T = pl.num_programs(0)

    @pl.when((t == 0) & (hb == 0))
    def _init():
        h_scr[0] = h0_ref[...].astype(F32)
        h_scr[1] = h0_ref[...].astype(F32)
        c_scr[...] = c0_ref[...].astype(F32)

    cur = jax.lax.rem(t, 2)
    h_prev = h_scr[cur]                                    # (B, H)
    x = x_ref[0]                                           # (B, D)
    G = 4
    zx, zh = _gates_matmul(x, h_prev, wx_ref, wh_ref, sx_ref, sh_ref, G, bh)
    z = zx + zh + b_ref[...]
    i = jax.nn.sigmoid(z[:, 0])
    j = jnp.tanh(z[:, 1])
    f = jax.nn.sigmoid(z[:, 2])
    o = jax.nn.sigmoid(z[:, 3])

    sl = pl.ds(hb * bh, bh)
    c_old = c_scr[:, sl]
    c_new = f * c_old + i * j
    h_new = o * jnp.tanh(c_new)
    c_scr[:, sl] = c_new
    h_scr[1 - cur, :, sl] = h_new                          # next step's h
    y_ref[0] = h_new.astype(y_ref.dtype)

    @pl.when(t == T - 1)
    def _final():
        hT_ref[...] = h_new.astype(hT_ref.dtype)
        cT_ref[...] = c_new.astype(cT_ref.dtype)


def _gru_kernel(x_ref, wx_ref, wh_ref, sx_ref, sh_ref, bx_ref, bh_ref,
                h0_ref,
                y_ref, hT_ref,
                h_scr, *, bh: int):
    t = pl.program_id(0)
    hb = pl.program_id(1)
    T = pl.num_programs(0)

    @pl.when((t == 0) & (hb == 0))
    def _init():
        h_scr[0] = h0_ref[...].astype(F32)
        h_scr[1] = h0_ref[...].astype(F32)

    cur = jax.lax.rem(t, 2)
    h_prev = h_scr[cur]
    x = x_ref[0]
    G = 3
    zx, zh = _gates_matmul(x, h_prev, wx_ref, wh_ref, sx_ref, sh_ref, G, bh)
    zx = zx + bx_ref[...]
    zh = zh + bh_ref[...]
    r = jax.nn.sigmoid(zx[:, 0] + zh[:, 0])
    z = jax.nn.sigmoid(zx[:, 1] + zh[:, 1])
    n = jnp.tanh(zx[:, 2] + r * zh[:, 2])

    sl = pl.ds(hb * bh, bh)
    h_old = jax.lax.dynamic_slice_in_dim(h_prev, hb * bh, bh, axis=1)
    h_new = (1 - z) * n + z * h_old
    h_scr[1 - cur, :, sl] = h_new
    y_ref[0] = h_new.astype(y_ref.dtype)

    @pl.when(t == T - 1)
    def _final():
        hT_ref[...] = h_new.astype(hT_ref.dtype)


def _lstm_kernel_persistent(x_ref, wx_ref, wh_ref, sx_ref, sh_ref, b_ref,
                            h0_ref, c0_ref,
                            y_ref, hT_ref, cT_ref,
                            h_scr, c_scr, *, H: int):
    """Persistent-decode variant (Sparse Persistent RNNs): the whole
    weight matrices live in VMEM for the full device loop — grid is (T,)
    only, there is no H-tile streaming and no double-buffered h parity.
    Requires the DSE to certify the weights fit (tile_vmem_bytes at
    bh == H); math is bit-identical to the streaming kernel at bh == H."""
    t = pl.program_id(0)
    T = pl.num_programs(0)

    @pl.when(t == 0)
    def _init():
        h_scr[...] = h0_ref[...].astype(F32)
        c_scr[...] = c0_ref[...].astype(F32)

    x = x_ref[0]
    zx, zh = _gates_matmul(x, h_scr[...], wx_ref, wh_ref, sx_ref, sh_ref,
                           4, H)
    z = zx + zh + b_ref[...]
    i = jax.nn.sigmoid(z[:, 0])
    j = jnp.tanh(z[:, 1])
    f = jax.nn.sigmoid(z[:, 2])
    o = jax.nn.sigmoid(z[:, 3])

    c_new = f * c_scr[...] + i * j
    h_new = o * jnp.tanh(c_new)
    c_scr[...] = c_new
    h_scr[...] = h_new
    y_ref[0] = h_new.astype(y_ref.dtype)

    @pl.when(t == T - 1)
    def _final():
        hT_ref[...] = h_new.astype(hT_ref.dtype)
        cT_ref[...] = c_new.astype(cT_ref.dtype)


def _gru_kernel_persistent(x_ref, wx_ref, wh_ref, sx_ref, sh_ref, bx_ref,
                           bh_ref, h0_ref,
                           y_ref, hT_ref,
                           h_scr, *, H: int):
    t = pl.program_id(0)
    T = pl.num_programs(0)

    @pl.when(t == 0)
    def _init():
        h_scr[...] = h0_ref[...].astype(F32)

    x = x_ref[0]
    h_prev = h_scr[...]
    zx, zh = _gates_matmul(x, h_prev, wx_ref, wh_ref, sx_ref, sh_ref, 3, H)
    zx = zx + bx_ref[...]
    zh = zh + bh_ref[...]
    r = jax.nn.sigmoid(zx[:, 0] + zh[:, 0])
    z = jax.nn.sigmoid(zx[:, 1] + zh[:, 1])
    n = jnp.tanh(zx[:, 2] + r * zh[:, 2])

    h_new = (1 - z) * n + z * h_prev
    h_scr[...] = h_new
    y_ref[0] = h_new.astype(y_ref.dtype)

    @pl.when(t == T - 1)
    def _final():
        hT_ref[...] = h_new.astype(hT_ref.dtype)


def _specs_persistent(D: int, H: int, G: int, B: int):
    """Whole-array BlockSpecs over a (T,)-only grid: every weight index
    map is constant, so each operand is fetched exactly once and pinned
    in VMEM for the entire sync_every device loop."""
    return dict(
        x=pl.BlockSpec((1, B, D), lambda t: (t, 0, 0)),
        wx=pl.BlockSpec((D, G, H), lambda t: (0, 0, 0)),
        wh=pl.BlockSpec((H, G, H), lambda t: (0, 0, 0)),
        s=pl.BlockSpec((G, H), lambda t: (0, 0)),
        state=pl.BlockSpec((B, H), lambda t: (0, 0)),
        y=pl.BlockSpec((1, B, H), lambda t: (t, 0, 0)),
    )


def _specs(D: int, H: int, G: int, B: int, bh: int):
    """BlockSpecs shared by both cells.  Weight index maps are constant in
    t, so weight blocks are HBM-fetched once and stay VMEM-resident across
    all time steps (the paper's on-chip-weights requirement)."""
    return dict(
        x=pl.BlockSpec((1, B, D), lambda t, h: (t, 0, 0)),
        wx=pl.BlockSpec((D, G, bh), lambda t, h: (0, 0, h)),
        wh=pl.BlockSpec((H, G, bh), lambda t, h: (0, 0, h)),
        s=pl.BlockSpec((G, bh), lambda t, h: (0, h)),
        state=pl.BlockSpec((B, H), lambda t, h: (0, 0)),
        y=pl.BlockSpec((1, B, bh), lambda t, h: (t, 0, h)),
        out_state=pl.BlockSpec((B, bh), lambda t, h: (0, h)),
    )


@functools.partial(jax.jit, static_argnames=("bh", "interpret",
                                             "persistent"))
def fused_lstm(x_seq, w_x, w_h, s_x, s_h, b, h0, c0, *,
               bh: int = 256, interpret: bool = False,
               persistent: bool = False):
    """x_seq (T, B, D); w_x (D, 4, H) int8/bf16; s_* (4, H) f32; b (4, H);
    h0/c0 (B, H).  Returns (y (T, B, H) bf16, h_T (B, H) f32, c_T).

    ``bh`` is the H-tile (default 256 — the pre-DSE hardcoded geometry);
    ``persistent=True`` switches to the weights-resident variant (grid
    (T,) only, whole matrices pinned in VMEM — caller must have checked
    ``dse.tile_vmem_bytes(cfg, H)`` against the budget)."""
    T, B, D = x_seq.shape
    H = w_h.shape[0]
    if persistent:
        sp = _specs_persistent(D, H, 4, B)
        return pl.pallas_call(
            functools.partial(_lstm_kernel_persistent, H=H),
            grid=(T,),
            in_specs=[sp["x"], sp["wx"], sp["wh"], sp["s"], sp["s"],
                      sp["s"], sp["state"], sp["state"]],
            out_specs=[sp["y"], sp["state"], sp["state"]],
            out_shape=[
                jax.ShapeDtypeStruct((T, B, H), jnp.bfloat16),
                jax.ShapeDtypeStruct((B, H), F32),
                jax.ShapeDtypeStruct((B, H), F32),
            ],
            scratch_shapes=[
                pltpu.VMEM((B, H), F32),
                pltpu.VMEM((B, H), F32),
            ],
            compiler_params=_CompilerParams(
                dimension_semantics=("arbitrary",)),
            interpret=interpret,
            name="fused_lstm_persistent",
        )(x_seq, w_x, w_h, s_x, s_h, b, h0, c0)
    bh = min(bh, H)
    assert H % bh == 0, (H, bh)
    sp = _specs(D, H, 4, B, bh)
    return pl.pallas_call(
        functools.partial(_lstm_kernel, bh=bh),
        grid=(T, H // bh),
        in_specs=[sp["x"], sp["wx"], sp["wh"], sp["s"], sp["s"], sp["s"],
                  sp["state"], sp["state"]],
        out_specs=[sp["y"], sp["out_state"], sp["out_state"]],
        out_shape=[
            jax.ShapeDtypeStruct((T, B, H), jnp.bfloat16),
            jax.ShapeDtypeStruct((B, H), F32),
            jax.ShapeDtypeStruct((B, H), F32),
        ],
        scratch_shapes=[
            pltpu.VMEM((2, B, H), F32),
            pltpu.VMEM((B, H), F32),
        ],
        compiler_params=_CompilerParams(
            dimension_semantics=("arbitrary", "arbitrary")),
        interpret=interpret,
        name="fused_lstm",
    )(x_seq, w_x, w_h, s_x, s_h, b, h0, c0)


@functools.partial(jax.jit, static_argnames=("bh", "interpret",
                                             "persistent"))
def fused_gru(x_seq, w_x, w_h, s_x, s_h, b_x, b_h, h0, *,
              bh: int = 256, interpret: bool = False,
              persistent: bool = False):
    """x_seq (T, B, D); w_x (D, 3, H); s_* (3, H); b_* (3, H); h0 (B, H).
    Returns (y (T, B, H) bf16, h_T (B, H) f32).  See ``fused_lstm`` for
    the ``bh``/``persistent`` contract."""
    T, B, D = x_seq.shape
    H = w_h.shape[0]
    if persistent:
        sp = _specs_persistent(D, H, 3, B)
        return pl.pallas_call(
            functools.partial(_gru_kernel_persistent, H=H),
            grid=(T,),
            in_specs=[sp["x"], sp["wx"], sp["wh"], sp["s"], sp["s"],
                      sp["s"], sp["s"], sp["state"]],
            out_specs=[sp["y"], sp["state"]],
            out_shape=[
                jax.ShapeDtypeStruct((T, B, H), jnp.bfloat16),
                jax.ShapeDtypeStruct((B, H), F32),
            ],
            scratch_shapes=[pltpu.VMEM((B, H), F32)],
            compiler_params=_CompilerParams(
                dimension_semantics=("arbitrary",)),
            interpret=interpret,
            name="fused_gru_persistent",
        )(x_seq, w_x, w_h, s_x, s_h, b_x, b_h, h0)
    bh = min(bh, H)
    assert H % bh == 0, (H, bh)
    sp = _specs(D, H, 3, B, bh)
    return pl.pallas_call(
        functools.partial(_gru_kernel, bh=bh),
        grid=(T, H // bh),
        in_specs=[sp["x"], sp["wx"], sp["wh"], sp["s"], sp["s"], sp["s"],
                  sp["s"], sp["state"]],
        out_specs=[sp["y"], sp["out_state"]],
        out_shape=[
            jax.ShapeDtypeStruct((T, B, H), jnp.bfloat16),
            jax.ShapeDtypeStruct((B, H), F32),
        ],
        scratch_shapes=[pltpu.VMEM((2, B, H), F32)],
        compiler_params=_CompilerParams(
            dimension_semantics=("arbitrary", "arbitrary")),
        interpret=interpret,
        name="fused_gru",
    )(x_seq, w_x, w_h, s_x, s_h, b_x, b_h, h0)
