"""Jit'd wrappers dispatching RNNCellConfig workloads onto the fused
Pallas kernels (TPU) or their interpret-mode execution (CPU validation).

``serve`` is the entry point used by ``repro.core.cells.serve(...,
impl="kernel")`` and the DeepBench benchmark harness.  Block size bh comes
from the DSE (repro.core.dse) unless overridden.
"""

from __future__ import annotations

from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.kernels.fused_rnn.fused_rnn import fused_gru, fused_lstm

F32 = jnp.float32


def _on_tpu() -> bool:
    return jax.default_backend() == "tpu"


def _weights_for_kernel(cfg, w: Dict) -> Tuple:
    """Split quantized/unquantized weight dicts into kernel operands."""
    s_x = w.get("w_x_scale")
    s_h = w.get("w_h_scale")
    wx, wh = w["w_x"], w["w_h"]
    if s_x is None:
        wx = wx.astype(jnp.bfloat16)
        s_x = jnp.ones(w["b"].shape, F32)
    if s_h is None:
        wh = wh.astype(jnp.bfloat16)
        s_h = jnp.ones(w["b"].shape, F32)
    return wx, wh, s_x, s_h


def serve(cfg, w: Dict, x_seq: jax.Array, *, bh: int = 0,
          state: Optional[Tuple[jax.Array, ...]] = None,
          interpret: Optional[bool] = None) -> jax.Array:
    """Run T serving steps through the fused kernel.  x_seq (T, B, D)."""
    if interpret is None:
        interpret = not _on_tpu()
    if not bh:
        from repro.core.dse import best_plan
        bh = best_plan(cfg).bh
    T, B, D = x_seq.shape
    H = cfg.hidden
    wx, wh, s_x, s_h = _weights_for_kernel(cfg, w)
    if state is None:
        h0 = jnp.zeros((B, H), F32)
        c0 = jnp.zeros((B, H), F32)
    else:
        h0 = state[0]
        c0 = state[1] if len(state) > 1 else jnp.zeros((B, H), F32)
    if cfg.cell == "lstm":
        y, _, _ = fused_lstm(x_seq, wx, wh, s_x, s_h, w["b"], h0, c0,
                             bh=bh, interpret=interpret)
    else:
        y, _ = fused_gru(x_seq, wx, wh, s_x, s_h, w["b"],
                         w.get("b_h", jnp.zeros_like(w["b"])), h0,
                         bh=bh, interpret=interpret)
    return y
