"""Jit'd wrappers dispatching RNNCellConfig workloads onto the fused
Pallas kernels (TPU) or their interpret-mode execution (CPU validation).

``serve`` is the entry point used by ``repro.core.cells.serve(...,
impl="kernel")`` and the DeepBench benchmark harness.  Block size bh comes
from the DSE (repro.core.dse) unless overridden — scored at the batch
actually served, not the DeepBench cell's batch-1 default — or from a
``tile_plans`` entry passed as ``plan``.
"""

from __future__ import annotations

from typing import Dict, Mapping, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.kernels.dispatch import interpret_mode, tile_arg
from repro.kernels.fused_rnn.fused_rnn import fused_gru, fused_lstm

F32 = jnp.float32


def _weights_for_kernel(cfg, w: Dict) -> Tuple:
    """Split quantized/unquantized weight dicts into kernel operands."""
    s_x = w.get("w_x_scale")
    s_h = w.get("w_h_scale")
    wx, wh = w["w_x"], w["w_h"]
    if s_x is None:
        wx = wx.astype(jnp.bfloat16)
        s_x = jnp.ones(w["b"].shape, F32)
    if s_h is None:
        wh = wh.astype(jnp.bfloat16)
        s_h = jnp.ones(w["b"].shape, F32)
    return wx, wh, s_x, s_h


def default_bh(cfg, batch: int) -> int:
    """DSE-chosen H tile for serving ``batch`` lanes of this cell.

    The batch must reach ``best_plan`` — the VMEM working set scales
    with it, so scoring at the config's batch-1 default silently picks
    the single-lane tile (e.g. lstm H=4096 wants bh=128 at b=1 but the
    smaller batched tile once the state/io buffers claim their share)."""
    from repro.core.dse import best_plan
    return best_plan(cfg, max_batch=batch).bh


def serve(cfg, w: Dict, x_seq: jax.Array, *, bh: int = 0,
          state: Optional[Tuple[jax.Array, ...]] = None,
          interpret: Optional[bool] = None,
          plan: Optional[Mapping[str, object]] = None) -> jax.Array:
    """Run T serving steps through the fused kernel.  x_seq (T, B, D).

    ``plan`` is a ``tile_plans`` entry: ``bh`` overrides the tile (snapped
    to a divisor of H), ``persistent: true`` selects the weights-resident
    variant (whole-H tile, validated against the VMEM budget by
    ``ServingPlan.validate``)."""
    from repro.core.dse import snap_tile

    if interpret is None:
        interpret = interpret_mode()
    T, B, D = x_seq.shape
    H = cfg.hidden
    persistent = bool((plan or {}).get("persistent", False))
    bh = tile_arg(plan, "bh", bh or 0)
    if not bh:
        bh = H if persistent else default_bh(cfg, B)
    bh = H if persistent else snap_tile(H, bh)
    wx, wh, s_x, s_h = _weights_for_kernel(cfg, w)
    if state is None:
        h0 = jnp.zeros((B, H), F32)
        c0 = jnp.zeros((B, H), F32)
    else:
        h0 = state[0]
        c0 = state[1] if len(state) > 1 else jnp.zeros((B, H), F32)
    if cfg.cell == "lstm":
        y, _, _ = fused_lstm(x_seq, wx, wh, s_x, s_h, w["b"], h0, c0,
                             bh=bh, interpret=interpret,
                             persistent=persistent)
    else:
        y, _ = fused_gru(x_seq, wx, wh, s_x, s_h, w["b"],
                         w.get("b_h", jnp.zeros_like(w["b"])), h0,
                         bh=bh, interpret=interpret, persistent=persistent)
    return y
