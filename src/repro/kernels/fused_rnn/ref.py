"""Pure-jnp oracle for the fused RNN kernels.

Mathematically identical to the kernel: int8 weights widened through the
same per-(gate, unit) scales, bf16 multiplies, f32 accumulation, identical
gate order.  The kernel tests sweep shapes/dtypes and assert_allclose
against these functions.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

F32 = jnp.float32


def _widen(w, s):
    """int8/bf16 weight (R, G, H) x scale (G, H) -> effective f32 weight."""
    w = w.astype(F32)
    if s is not None:
        w = w * s[None]
    return w


def _z(x, h, w_x, w_h, s_x, s_h):
    """Pre-activations (B, G, H) with bf16 multiply / f32 accumulate to
    match the kernel's numerics."""
    zx = jnp.einsum("bd,dgh->bgh", x.astype(jnp.bfloat16),
                    w_x.astype(jnp.bfloat16), preferred_element_type=F32)
    zh = jnp.einsum("bd,dgh->bgh", h.astype(jnp.bfloat16),
                    w_h.astype(jnp.bfloat16), preferred_element_type=F32)
    if s_x is not None:
        zx = zx * s_x[None]
    if s_h is not None:
        zh = zh * s_h[None]
    return zx, zh


def fused_lstm_ref(x_seq, w_x, w_h, s_x, s_h, b, h0, c0):
    wxf = w_x.astype(jnp.bfloat16) if s_x is None else w_x
    whf = w_h.astype(jnp.bfloat16) if s_h is None else w_h

    def step(carry, x):
        h, c = carry
        zx, zh = _z(x, h, wxf, whf, s_x, s_h)
        z = zx + zh + b[None]
        i = jax.nn.sigmoid(z[:, 0])
        j = jnp.tanh(z[:, 1])
        f = jax.nn.sigmoid(z[:, 2])
        o = jax.nn.sigmoid(z[:, 3])
        c_new = f * c + i * j
        h_new = o * jnp.tanh(c_new)
        return (h_new, c_new), h_new.astype(jnp.bfloat16)

    (hT, cT), ys = jax.lax.scan(step, (h0.astype(F32), c0.astype(F32)), x_seq)
    return ys, hT, cT


def fused_gru_ref(x_seq, w_x, w_h, s_x, s_h, b_x, b_h, h0):
    def step(h, x):
        zx, zh = _z(x, h, w_x, w_h, s_x, s_h)
        zx = zx + b_x[None]
        zh = zh + b_h[None]
        r = jax.nn.sigmoid(zx[:, 0] + zh[:, 0])
        z = jax.nn.sigmoid(zx[:, 1] + zh[:, 1])
        n = jnp.tanh(zx[:, 2] + r * zh[:, 2])
        h_new = (1 - z) * n + z * h
        return h_new, h_new.astype(jnp.bfloat16)

    hT, ys = jax.lax.scan(step, h0.astype(F32), x_seq)
    return ys, hT
