"""Pallas TPU kernels for the perf-critical compute layers.

Each kernel package follows the required structure:
  <name>.py  — pl.pallas_call + explicit BlockSpec VMEM tiling
  ops.py     — jit'd model-layout wrapper (TPU dispatch / CPU interpret)
  ref.py     — pure-jnp oracle the tests assert_allclose against

fused_rnn/        the paper's core: fused LSTM/GRU cell, weights VMEM-resident
flash_attention/  fused attention forward (causal/window/softcap)
matmul_int8/      W8A16 matmul with fused dequant+bias+activation epilogue
rwkv_step/        fused RWKV6 serving recurrence (paper's pattern, modern cell)
"""
