"""Plan-driven kernel dispatch: one place that reads a ``tile_plans``
entry and decides which implementation a model call site runs.

A ``ServingPlan.tile_plans`` entry (one dict per kernel kind, produced
by ``planner.tile_plans_for`` / ``core.dse``) may carry an ``impl``
field:

  * ``"auto"`` (default) — use the Pallas kernel only on a TPU backend;
    everywhere else keep the pure-jnp reference path.  CPU runs (tests,
    the committed BENCH trajectories, the virtual-clock scheduler) stay
    byte-identical to a plan with no tile_plans at all.
  * ``"jnp"`` — force the reference path.
  * ``"pallas"`` — force the Pallas kernel; off-TPU it runs in
    interpret mode (the mode the parity tests and smoke probes use).

The tile fields themselves (``bh``/``bq``/``bk``/``bm``/``bn``,
``persistent``) are read by each kernel's ops wrapper via
:func:`tile_arg`; geometry is snapped to the actual shapes with
``core.dse.snap_tile`` at the call site.
"""

from __future__ import annotations

from typing import Mapping, Optional

import jax

VALID_IMPLS = ("auto", "jnp", "pallas")


def on_tpu() -> bool:
    return jax.default_backend() == "tpu"


def resolve_impl(entry: Optional[Mapping[str, object]]) -> str:
    """Collapse a tile-plan entry's ``impl`` field to "jnp" | "pallas"."""
    impl = str((entry or {}).get("impl", "auto"))
    if impl not in VALID_IMPLS:
        raise ValueError(f"tile plan impl {impl!r} not in {VALID_IMPLS}")
    if impl == "auto":
        return "pallas" if on_tpu() else "jnp"
    return impl


def pallas_active(entry: Optional[Mapping[str, object]]) -> bool:
    """True when this call site should run its Pallas kernel."""
    return entry is not None and resolve_impl(entry) == "pallas"


def interpret_mode() -> bool:
    """Pallas interpret flag for the current backend (True off-TPU)."""
    return not on_tpu()


def tile_arg(entry: Optional[Mapping[str, object]], name: str,
             default: int) -> int:
    """Read one tile field from a plan entry, falling back to the
    kernel's documented default when absent or zero."""
    val = int((entry or {}).get(name, 0) or 0)
    return val if val > 0 else default


__all__ = ["VALID_IMPLS", "on_tpu", "resolve_impl", "pallas_active",
           "interpret_mode", "tile_arg"]
