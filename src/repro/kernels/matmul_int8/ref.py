"""Pure-jnp oracle for the W8A16 matmul kernel."""

from __future__ import annotations

import jax
import jax.numpy as jnp

F32 = jnp.float32

_EPILOGUES = {
    "none": lambda x: x,
    "silu": jax.nn.silu,
    "gelu": jax.nn.gelu,
    "relu": jax.nn.relu,
}


def matmul_w8a16_ref(x, w_q, scale, bias=None, *, act: str = "none"):
    out = jax.lax.dot_general(
        x.astype(jnp.bfloat16), w_q.astype(jnp.bfloat16),
        (((1,), (0,)), ((), ())), preferred_element_type=F32)
    out = out * scale[None, :]
    if bias is not None:
        out = out + bias[None, :]
    return _EPILOGUES[act](out).astype(jnp.bfloat16)
