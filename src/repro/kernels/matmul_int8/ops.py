"""Jit'd wrapper for the W8A16 matmul: accepts the framework's quantized
leaf convention ({"q": int8 (K, N), "scale": f32 (1, N)}) directly.

Tile geometry (bm/bn/bk) comes from a ``tile_plans["matmul_int8"]``
entry when one is passed, snapped to the actual problem shape; the
hardcoded values are the documented defaults.
"""

from __future__ import annotations

from typing import Mapping, Optional

import jax
import jax.numpy as jnp

from repro.kernels.dispatch import interpret_mode, tile_arg
from repro.kernels.matmul_int8.matmul_int8 import matmul_w8a16

DEFAULT_BM = 256
DEFAULT_BN = 256
DEFAULT_BK = 512


def qdot(x, leaf, bias=None, *, act: str = "none", interpret=None,
         plan: Optional[Mapping[str, object]] = None):
    """x (..., K) @ quantized leaf -> (..., N)."""
    from repro.core.dse import snap_tile

    if interpret is None:
        interpret = interpret_mode()
    lead = x.shape[:-1]
    K = x.shape[-1]
    N = leaf["q"].shape[-1]
    x2 = x.reshape(-1, K).astype(jnp.bfloat16)
    M = x2.shape[0]
    bm = snap_tile(M, min(tile_arg(plan, "bm", DEFAULT_BM), M))
    bn = snap_tile(N, min(tile_arg(plan, "bn", DEFAULT_BN), N))
    bk = snap_tile(K, min(tile_arg(plan, "bk", DEFAULT_BK), K))
    out = matmul_w8a16(x2, leaf["q"], leaf["scale"].reshape(-1), bias,
                       act=act, bm=bm, bn=bn, bk=bk, interpret=interpret)
    return out.reshape(*lead, -1)
