"""Jit'd wrapper for the W8A16 matmul: accepts the framework's quantized
leaf convention ({"q": int8 (K, N), "scale": f32 (1, N)}) directly."""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.kernels.matmul_int8.matmul_int8 import matmul_w8a16


def qdot(x, leaf, bias=None, *, act: str = "none", interpret=None):
    """x (..., K) @ quantized leaf -> (..., N)."""
    if interpret is None:
        interpret = jax.default_backend() != "tpu"
    lead = x.shape[:-1]
    K = x.shape[-1]
    x2 = x.reshape(-1, K).astype(jnp.bfloat16)
    out = matmul_w8a16(x2, leaf["q"], leaf["scale"].reshape(-1), bias,
                       act=act, interpret=interpret)
    return out.reshape(*lead, -1)
