"""Pallas TPU W8A16 matmul: int8-stored weights, bf16 activations, f32 MXU
accumulation, fused per-channel dequant + bias + activation epilogue.

This is the serving-path workhorse the paper's precision scheme implies for
transformer decode: decode is HBM-bandwidth-bound on weight reads, so int8
storage halves the dominant roofline term while the multiply runs wide.
The epilogue fusion (scale, bias, silu/gelu) is the cross-kernel
optimization: no (M, N) intermediate round-trips HBM.

Grid (M/bm, N/bn, K/bk), f32 accumulator in VMEM scratch, epilogue at the
last K block.
"""

from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro._jax_compat import tpu_compiler_params

_CompilerParams = tpu_compiler_params()

F32 = jnp.float32

_EPILOGUES = {
    "none": lambda x: x,
    "silu": jax.nn.silu,
    "gelu": jax.nn.gelu,
    "relu": jax.nn.relu,
}


def _kernel(x_ref, w_ref, s_ref, b_ref, o_ref, acc_scr, *, act: str,
            has_bias: bool):
    ik = pl.program_id(2)
    nk = pl.num_programs(2)

    @pl.when(ik == 0)
    def _init():
        acc_scr[...] = jnp.zeros_like(acc_scr)

    x = x_ref[...]                                        # (bm, bk) bf16
    w = w_ref[...].astype(jnp.bfloat16)                   # (bk, bn) int8->bf16
    acc_scr[...] += jax.lax.dot_general(
        x, w, (((1,), (0,)), ((), ())), preferred_element_type=F32)

    @pl.when(ik == nk - 1)
    def _epilogue():
        out = acc_scr[...] * s_ref[...]                   # per-channel scale
        if has_bias:
            out = out + b_ref[...]
        out = _EPILOGUES[act](out)
        o_ref[...] = out.astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=(
    "act", "bm", "bn", "bk", "interpret"))
def matmul_w8a16(x, w_q, scale, bias=None, *, act: str = "none",
                 bm: int = 256, bn: int = 256, bk: int = 512,
                 interpret: bool = False):
    """x (M, K) bf16; w_q (K, N) int8; scale (N,) f32; bias (N,) f32 or None.
    Returns act(x @ (w_q * scale) + bias) as (M, N) bf16."""
    M, K = x.shape
    N = w_q.shape[1]
    bm, bn, bk = min(bm, M), min(bn, N), min(bk, K)
    assert M % bm == 0 and N % bn == 0 and K % bk == 0, (M, N, K, bm, bn, bk)
    has_bias = bias is not None
    if bias is None:
        bias = jnp.zeros((N,), F32)
    grid = (M // bm, N // bn, K // bk)
    return pl.pallas_call(
        functools.partial(_kernel, act=act, has_bias=has_bias),
        grid=grid,
        in_specs=[
            pl.BlockSpec((bm, bk), lambda im, jn, ik: (im, ik)),
            pl.BlockSpec((bk, bn), lambda im, jn, ik: (ik, jn)),
            pl.BlockSpec((1, bn), lambda im, jn, ik: (0, jn)),
            pl.BlockSpec((1, bn), lambda im, jn, ik: (0, jn)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda im, jn, ik: (im, jn)),
        out_shape=jax.ShapeDtypeStruct((M, N), jnp.bfloat16),
        scratch_shapes=[pltpu.VMEM((bm, bn), F32)],
        compiler_params=_CompilerParams(
            dimension_semantics=("parallel", "parallel", "arbitrary")),
        interpret=interpret,
        name="matmul_w8a16",
    )(x, w_q, scale.reshape(1, N), bias.reshape(1, N))
