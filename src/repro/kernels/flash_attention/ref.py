"""Pure-jnp oracle for the flash attention kernel (naive, materializes the
full score matrix — small test shapes only)."""

from __future__ import annotations

import math

import jax
import jax.numpy as jnp

F32 = jnp.float32


def attention_ref(q, k, v, *, causal: bool = True, window: int = 0,
                  softcap: float = 0.0):
    B, H, Sq, d = q.shape
    Skv = k.shape[2]
    s = jnp.einsum("bhqd,bhkd->bhqk", q.astype(F32), k.astype(F32))
    s = s / math.sqrt(d)
    if softcap > 0.0:
        s = softcap * jnp.tanh(s / softcap)
    q_pos = jnp.arange(Sq)[:, None]
    k_pos = jnp.arange(Skv)[None, :]
    mask = jnp.ones((Sq, Skv), bool)
    if causal:
        mask &= k_pos <= q_pos
    if window > 0:
        mask &= (q_pos - k_pos) < window
    s = jnp.where(mask, s, -1e30)
    p = jax.nn.softmax(s, axis=-1)
    return jnp.einsum("bhqk,bhkd->bhqd", p, v.astype(F32)).astype(q.dtype)
