"""Pallas TPU flash attention (forward) with causal / sliding-window
masking and gemma-style logit soft-capping.

Cross-kernel fusion in the paper's sense applied to attention: the QK^T
matmul, soft-cap, mask, online softmax, and the AV matmul live in one
kernel, so the (Sq, Skv) score matrix never exists in HBM — only a
(bq, bk) tile in VMEM.  This is the TPU-side replacement for the unrolled
jnp flash path in repro.models.attention (which the CPU-backend dry-run
lowers).

Grid: (B, H, Sq/bq, Skv/bk); the KV dim is the innermost ("arbitrary")
axis, with m/l/acc accumulators in VMEM scratch reinitialized at ik == 0
and the output written at the last KV block.  GQA is handled by the
caller (kv head index = h // group).
"""

from __future__ import annotations

import functools
import math
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro._jax_compat import tpu_compiler_params

_CompilerParams = tpu_compiler_params()

F32 = jnp.float32
NEG_INF = -1e30


def _kernel(q_ref, k_ref, v_ref,
            o_ref,
            m_scr, l_scr, acc_scr, *,
            scale: float, causal: bool, window: int, softcap: float,
            bq: int, bk: int):
    iq = pl.program_id(2)
    ik = pl.program_id(3)
    nk = pl.num_programs(3)

    @pl.when(ik == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    q = q_ref[0, 0]                                       # (bq, d)
    k = k_ref[0, 0]                                       # (bk, d)
    v = v_ref[0, 0]
    s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                            preferred_element_type=F32) * scale
    if softcap > 0.0:
        s = softcap * jnp.tanh(s / softcap)

    q_pos = iq * bq + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 0)
    k_pos = ik * bk + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 1)
    mask = jnp.ones((bq, bk), jnp.bool_)
    if causal:
        mask &= k_pos <= q_pos
    if window > 0:
        mask &= (q_pos - k_pos) < window
    s = jnp.where(mask, s, NEG_INF)

    m_prev = m_scr[...]
    m_new = jnp.maximum(m_prev, s.max(axis=1))
    alpha = jnp.exp(m_prev - m_new)
    p = jnp.exp(s - m_new[:, None])
    l_scr[...] = l_scr[...] * alpha + p.sum(axis=1)
    acc_scr[...] = acc_scr[...] * alpha[:, None] + jax.lax.dot_general(
        p.astype(v.dtype), v, (((1,), (0,)), ((), ())),
        preferred_element_type=F32)
    m_scr[...] = m_new

    @pl.when(ik == nk - 1)
    def _finish():
        l = jnp.maximum(l_scr[...], 1e-30)
        o_ref[0, 0] = (acc_scr[...] / l[:, None]).astype(o_ref.dtype)


def _kernel_pos(q_ref, k_ref, v_ref, qp_ref, kvp_ref,
                o_ref,
                m_scr, l_scr, acc_scr, *,
                scale: float, causal: bool, window: int, softcap: float):
    """Position-array masking variant: instead of assuming positions are
    the row/col iota, read per-token absolute positions (-1 = padding /
    empty cache slot) — what the model's right-padded bucketed prefill
    needs before the Pallas kernel can replace the unrolled jnp path."""
    ik = pl.program_id(3)
    nk = pl.num_programs(3)

    @pl.when(ik == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    q = q_ref[0, 0]                                       # (bq, d)
    k = k_ref[0, 0]                                       # (bk, d)
    v = v_ref[0, 0]
    s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                            preferred_element_type=F32) * scale
    if softcap > 0.0:
        s = softcap * jnp.tanh(s / softcap)

    qp = qp_ref[0]                                        # (bq, 1)
    kvp = kvp_ref[0]                                      # (1, bk)
    mask = kvp >= 0
    if causal:
        mask &= kvp <= qp
    if window > 0:
        mask &= (qp - kvp) < window
    s = jnp.where(mask, s, NEG_INF)

    m_prev = m_scr[...]
    m_new = jnp.maximum(m_prev, s.max(axis=1))
    alpha = jnp.exp(m_prev - m_new)
    p = jnp.exp(s - m_new[:, None])
    l_scr[...] = l_scr[...] * alpha + p.sum(axis=1)
    acc_scr[...] = acc_scr[...] * alpha[:, None] + jax.lax.dot_general(
        p.astype(v.dtype), v, (((1,), (0,)), ((), ())),
        preferred_element_type=F32)
    m_scr[...] = m_new

    @pl.when(ik == nk - 1)
    def _finish():
        l = jnp.maximum(l_scr[...], 1e-30)
        o_ref[0, 0] = (acc_scr[...] / l[:, None]).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=(
    "causal", "window", "softcap", "bq", "bk", "interpret"))
def flash_attention(q, k, v, q_pos=None, kv_pos=None, *,
                    causal: bool = True, window: int = 0,
                    softcap: float = 0.0, bq: int = 256, bk: int = 512,
                    interpret: bool = False):
    """q (B, H, Sq, d); k/v (B, H, Skv, d) — kv already head-expanded.
    Returns (B, H, Sq, d) in q.dtype.

    ``bq``/``bk`` are the BlockSpec tile rows — defaults are the pre-DSE
    hardcoded geometry; a ``tile_plans["attn"]`` entry overrides them via
    the ops wrapper.  When ``q_pos``/``kv_pos`` (B, S) int32 arrays are
    given, masking uses the per-token absolute positions (-1 masks the
    slot) instead of the tile iota."""
    B, H, Sq, d = q.shape
    Skv = k.shape[2]
    bq = min(bq, Sq)
    bk = min(bk, Skv)
    assert Sq % bq == 0 and Skv % bk == 0, (Sq, bq, Skv, bk)
    scale = 1.0 / math.sqrt(d)
    grid = (B, H, Sq // bq, Skv // bk)
    qkv_specs = [
        pl.BlockSpec((1, 1, bq, d), lambda b, h, iq, ik: (b, h, iq, 0)),
        pl.BlockSpec((1, 1, bk, d), lambda b, h, iq, ik: (b, h, ik, 0)),
        pl.BlockSpec((1, 1, bk, d), lambda b, h, iq, ik: (b, h, ik, 0)),
    ]
    common = dict(
        grid=grid,
        out_specs=pl.BlockSpec((1, 1, bq, d),
                               lambda b, h, iq, ik: (b, h, iq, 0)),
        out_shape=jax.ShapeDtypeStruct((B, H, Sq, d), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((bq,), F32),
            pltpu.VMEM((bq,), F32),
            pltpu.VMEM((bq, d), F32),
        ],
        compiler_params=_CompilerParams(
            dimension_semantics=("parallel", "parallel", "parallel",
                                 "arbitrary")),
        interpret=interpret,
    )
    if q_pos is None and kv_pos is None:
        return pl.pallas_call(
            functools.partial(_kernel, scale=scale, causal=causal,
                              window=window, softcap=softcap, bq=bq, bk=bk),
            in_specs=qkv_specs,
            name="flash_attention",
            **common,
        )(q, k, v)
    if q_pos is None:
        q_pos = jnp.broadcast_to(jnp.arange(Sq, dtype=jnp.int32), (B, Sq))
    if kv_pos is None:
        kv_pos = jnp.broadcast_to(jnp.arange(Skv, dtype=jnp.int32), (B, Skv))
    qp = q_pos.astype(jnp.int32)[:, :, None]              # (B, Sq, 1)
    kvp = kv_pos.astype(jnp.int32)[:, None, :]            # (B, 1, Skv)
    return pl.pallas_call(
        functools.partial(_kernel_pos, scale=scale, causal=causal,
                          window=window, softcap=softcap),
        in_specs=qkv_specs + [
            pl.BlockSpec((1, bq, 1), lambda b, h, iq, ik: (b, iq, 0)),
            pl.BlockSpec((1, 1, bk), lambda b, h, iq, ik: (b, 0, ik)),
        ],
        name="flash_attention_pos",
        **common,
    )(q, k, v, qp, kvp)
