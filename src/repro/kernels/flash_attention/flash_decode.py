"""Pallas TPU split-KV flash-decoding: one query token per sequence
against a (possibly ring-layout) KV cache.

The single-pass jnp decode path (``repro.models.attention.decode_attention``)
serializes the whole softmax over one KV stretch; for long contexts that
leaves the chip idle behind one block.  Flash-decoding instead grids over
KV *chunks* — every (batch, head, chunk) cell computes an independent
partial softmax (running max ``m``, normalizer ``l``, unnormalized
accumulator ``acc``) and a cheap log-sum-exp combine over the chunk axis
merges them outside the kernel.  All three grid axes are "parallel": no
cross-chunk carry exists, which is exactly what lets long-context decode
stop serializing.

Masking follows ``decode_attention``: slots with ``kv_pos < 0`` are empty
(ring cache holes / unfilled prefill slots), ``causal`` compares against
the query's absolute position, ``window`` bounds the lookback.  A chunk
whose every slot is masked yields ``m = NEG_INF`` and is annihilated by
the ``exp(m - M)`` combine weight.
"""

from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro._jax_compat import tpu_compiler_params

_CompilerParams = tpu_compiler_params()

F32 = jnp.float32
NEG_INF = -1e30


def _decode_kernel(q_ref, k_ref, v_ref, kvp_ref, qp_ref,
                   m_ref, l_ref, acc_ref, *,
                   scale: float, causal: bool, window: int, softcap: float):
    q = q_ref[0].astype(jnp.bfloat16)                     # (1, d)
    k = k_ref[0, 0].astype(jnp.bfloat16)                  # (bk, d)
    v = v_ref[0, 0].astype(jnp.bfloat16)
    s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                            preferred_element_type=F32) * scale  # (1, bk)
    if softcap > 0.0:
        s = softcap * jnp.tanh(s / softcap)

    kvp = kvp_ref[0]                                      # (1, bk)
    qp = qp_ref[0]                                        # (1, 1)
    mask = kvp >= 0
    if causal:
        mask &= kvp <= qp
    if window > 0:
        mask &= (qp - kvp) < window
    s = jnp.where(mask, s, NEG_INF)

    m = jnp.max(s, axis=1)                                # (1,)
    p = jnp.exp(s - m[:, None])                           # (1, bk)
    l = jnp.sum(p, axis=1)                                # (1,)
    acc = jax.lax.dot_general(p.astype(jnp.bfloat16), v,
                              (((1,), (0,)), ((), ())),
                              preferred_element_type=F32)  # (1, d)
    m_ref[...] = m.reshape(m_ref.shape)
    l_ref[...] = l.reshape(l_ref.shape)
    acc_ref[...] = acc.reshape(acc_ref.shape)


@functools.partial(jax.jit, static_argnames=(
    "causal", "window", "softcap", "bk", "interpret"))
def flash_decode(q, k, v, kv_pos, q_pos, *, causal: bool = True,
                 window: int = 0, softcap: float = 0.0, bk: int = 512,
                 interpret: bool = False):
    """q (B, H, d); k/v (B, H, S, d) — kv already head-expanded;
    kv_pos (B, S) absolute positions (-1 = empty slot); q_pos (B,).
    Returns (B, H, d) f32 (unnormalized-partials combined here).
    """
    B, H, d = q.shape
    S = k.shape[2]
    bk = min(bk, S)
    assert S % bk == 0, (S, bk)
    nk = S // bk
    scale = 1.0 / math.sqrt(d)
    kvp = kv_pos.astype(jnp.int32)[:, None, :]            # (B, 1, S)
    qp = q_pos.astype(jnp.int32)[:, None, None]           # (B, 1, 1)
    m_p, l_p, acc_p = pl.pallas_call(
        functools.partial(_decode_kernel, scale=scale, causal=causal,
                          window=window, softcap=softcap),
        grid=(B, H, nk),
        in_specs=[
            pl.BlockSpec((1, 1, d), lambda b, h, ik: (b, h, 0)),
            pl.BlockSpec((1, 1, bk, d), lambda b, h, ik: (b, h, ik, 0)),
            pl.BlockSpec((1, 1, bk, d), lambda b, h, ik: (b, h, ik, 0)),
            pl.BlockSpec((1, 1, bk), lambda b, h, ik: (b, 0, ik)),
            pl.BlockSpec((1, 1, 1), lambda b, h, ik: (b, 0, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, 1, 1), lambda b, h, ik: (b, h, ik)),
            pl.BlockSpec((1, 1, 1), lambda b, h, ik: (b, h, ik)),
            pl.BlockSpec((1, 1, 1, d), lambda b, h, ik: (b, h, ik, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((B, H, nk), F32),
            jax.ShapeDtypeStruct((B, H, nk), F32),
            jax.ShapeDtypeStruct((B, H, nk, d), F32),
        ],
        compiler_params=_CompilerParams(
            dimension_semantics=("parallel", "parallel", "parallel")),
        interpret=interpret,
        name="flash_decode",
    )(q, k, v, kvp, qp)

    # log-sum-exp combine over the chunk axis (cheap: (B, H, nk) scalars)
    m_g = jnp.max(m_p, axis=2)                            # (B, H)
    alpha = jnp.exp(m_p - m_g[:, :, None])                # (B, H, nk)
    l_g = jnp.sum(alpha * l_p, axis=2)                    # (B, H)
    out = jnp.sum(alpha[..., None] * acc_p, axis=2)       # (B, H, d)
    return out / jnp.maximum(l_g, 1e-30)[..., None]


__all__ = ["flash_decode"]
