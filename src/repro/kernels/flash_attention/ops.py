"""Jit'd wrapper: model-layout adapter for the flash attention kernel.

Accepts the model's (B, S, H, hd) layout with separate KV heads and
dispatches to the Pallas kernel (TPU) or interpret mode (CPU tests).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.kernels.flash_attention.flash_attention import flash_attention


def attention(q, k, v, *, causal: bool = True, window: int = 0,
              softcap: float = 0.0, interpret: bool = None):
    """q (B, S, H, hd); k/v (B, S, K, hd) -> (B, S, H, hd)."""
    if interpret is None:
        interpret = jax.default_backend() != "tpu"
    B, S, H, hd = q.shape
    K = k.shape[2]
    if K != H:
        rep = H // K
        k = jnp.repeat(k, rep, axis=2)
        v = jnp.repeat(v, rep, axis=2)
    qt = q.transpose(0, 2, 1, 3)
    kt = k.transpose(0, 2, 1, 3)
    vt = v.transpose(0, 2, 1, 3)
    bq = min(256, S)
    bk = min(512, S)
    out = flash_attention(qt, kt, vt, causal=causal, window=window,
                          softcap=softcap, bq=bq, bk=bk, interpret=interpret)
    return out.transpose(0, 2, 1, 3)
