"""Jit'd wrappers: model-layout adapters for the flash attention kernels.

Accept the model's (B, S, H, hd) layout with separate KV heads and
dispatch to the Pallas kernels (TPU) or interpret mode (CPU tests).
Tile geometry (bq/bk) comes from a ``tile_plans`` entry when one is
passed, snapped to the actual sequence lengths; the hardcoded values
are the documented defaults.
"""

from __future__ import annotations

from typing import Mapping, Optional

import jax
import jax.numpy as jnp

from repro.kernels.dispatch import interpret_mode, tile_arg
from repro.kernels.flash_attention.flash_attention import flash_attention
from repro.kernels.flash_attention.flash_decode import flash_decode

DEFAULT_BQ = 256
DEFAULT_BK = 512


def _expand_kv(k, v, H: int):
    K = k.shape[2]
    if K != H:
        rep = H // K
        k = jnp.repeat(k, rep, axis=2)
        v = jnp.repeat(v, rep, axis=2)
    return k, v


def attention(q, k, v, *, causal: bool = True, window: int = 0,
              softcap: float = 0.0, q_pos=None, kv_pos=None,
              bq: int = 0, bk: int = 0,
              interpret: Optional[bool] = None,
              plan: Optional[Mapping[str, object]] = None):
    """q (B, S, H, hd); k/v (B, S, K, hd) -> (B, S, H, hd).

    ``q_pos``/``kv_pos`` (B, S) enable position-array masking (padded
    prefill buckets); ``plan`` supplies bq/bk tile geometry."""
    from repro.core.dse import snap_tile

    if interpret is None:
        interpret = interpret_mode()
    B, S, H, hd = q.shape
    Skv = k.shape[1]
    k, v = _expand_kv(k, v, H)
    qt = q.transpose(0, 2, 1, 3)
    kt = k.transpose(0, 2, 1, 3)
    vt = v.transpose(0, 2, 1, 3)
    bq = snap_tile(S, min(tile_arg(plan, "bq", bq or DEFAULT_BQ), S))
    bk = snap_tile(Skv, min(tile_arg(plan, "bk", bk or DEFAULT_BK), Skv))
    out = flash_attention(qt, kt, vt, q_pos, kv_pos, causal=causal,
                          window=window, softcap=softcap, bq=bq, bk=bk,
                          interpret=interpret)
    return out.transpose(0, 2, 1, 3)


def decode(q, k_cache, v_cache, kv_pos, q_pos, *, causal: bool = True,
           window: int = 0, softcap: float = 0.0, bk: int = 0,
           interpret: Optional[bool] = None,
           plan: Optional[Mapping[str, object]] = None):
    """Split-KV flash-decoding adapter, mirroring the contract of
    ``repro.models.attention.decode_attention``: q (B, H, hd), caches
    (B, S, K, hd), kv_pos (B, S) with -1 holes, q_pos (B,).
    Returns (B, H, hd) bf16."""
    from repro.core.dse import snap_tile

    if interpret is None:
        interpret = interpret_mode()
    B, H, hd = q.shape
    S = k_cache.shape[1]
    k, v = _expand_kv(k_cache, v_cache, H)
    kt = k.transpose(0, 2, 1, 3)                          # (B, H, S, hd)
    vt = v.transpose(0, 2, 1, 3)
    bk = snap_tile(S, min(tile_arg(plan, "bk", bk or DEFAULT_BK), S))
    out = flash_decode(q, kt, vt, kv_pos, q_pos, causal=causal,
                       window=window, softcap=softcap, bk=bk,
                       interpret=interpret)
    return out.astype(jnp.bfloat16)
