"""`repro.obs`: observability for the serving stack.

Three layers, each usable on its own:

* :mod:`repro.obs.registry` — a typed counters/gauges/histograms registry
  (:class:`MetricsRegistry`) that owns every serving-stack counter, plus
  :class:`LiveMetrics`, a rolling window over the last N engine ticks
  (p95 TTFT/TPOT, SLO attainment, utilization) for live monitoring;
* :mod:`repro.obs.trace` — :class:`Tracer`, a structured event tracer on
  the deterministic virtual clock: per-request lifecycle spans
  (submit→admit→first-token→done, preempt/resume/shed) and per-tick
  engine events (decode chunk, prefill call + bucket, host sync,
  compile), exportable as Chrome ``trace_event`` JSON viewable in
  Perfetto — byte-identical across same-seed virtual-clock runs;
* :mod:`repro.obs.observe` — :func:`fit_profile`, which fits a
  :class:`repro.plan.WorkloadProfile` (arrival rate, prompt/decode
  length distributions, deadline slack) from a recorded trace, so
  :func:`repro.plan.planner.autotune` can replan from *observed*
  traffic instead of a synthetic probe
  (surfaced as ``WorkloadProfile.from_trace`` and
  ``planner.autotune_from_trace``).
"""

from repro.obs.registry import (  # noqa: F401
    Counter,
    Gauge,
    Histogram,
    LiveMetrics,
    MetricsRegistry,
)
from repro.obs.trace import (  # noqa: F401
    TraceEvent,
    Tracer,
    check_trace,
    dumps_trace_doc,
    merge_traces,
)
from repro.obs.observe import fit_profile  # noqa: F401
