"""Fit a :class:`repro.plan.WorkloadProfile` from observed traffic.

PR 5's planner can only search against a *declared* workload profile —
fine for benchmarks, wrong for production, where the profile the plan
was tuned on drifts away from the traffic actually arriving.  This
module closes that loop: :func:`fit_profile` reads a recorded
:class:`repro.obs.trace.Tracer` trace (live object, exported Chrome
JSON document, or file path) and fits the declarative workload half of
a serving cell from the ``submit`` events:

* **arrival rate** — submissions per observed tick of span (the
  maximum-likelihood Poisson rate for the observed count);
* **prompt lengths** — the observed ``[min, max]`` range (the uniform
  fit the workload generator draws from);
* **decode lengths** — the observed ``max_new`` range, with a long-tail
  split: observations above ``2 x p90`` are fitted as a separate
  ``heavy_decode`` mixture component (fraction, lo, hi), matching the
  generator's heavy-tail service-time model;
* **deadlines** — the median decode-proportional slack
  ``(deadline - t_submit) / max_new`` plus the fraction of requests
  carrying any deadline.

The fit is a pure function of the trace, so
``autotune(fit_profile(trace))`` — surfaced as
``WorkloadProfile.from_trace`` and ``planner.autotune_from_trace`` — is
as deterministic as the probe-based search, and the drifting-workload
cell in ``benchmarks/serving_load.py`` can demonstrate re-autotuning
from observed traffic beating a stale static plan on SLO attainment.
"""

from __future__ import annotations

import math
from typing import Dict, List, Mapping, Optional, Tuple, Union

from repro.obs.trace import TICK_US, Tracer, load_trace_doc

# heavy-decode split: observations above HEAVY_FACTOR x p90 of the
# max_new stream are a separate long-tail mixture component
HEAVY_FACTOR = 2.0

TraceLike = Union[Tracer, Mapping[str, object], str]


def _submit_records(trace: TraceLike) -> List[Dict[str, object]]:
    """The ``submit`` events of a trace as ``{t(tick), prompt_len,
    max_new, deadline}`` records, in submission order."""
    if isinstance(trace, Tracer):
        return [{"t": ev.ts // TICK_US, **dict(ev.args)}
                for ev in trace.events
                if ev.cat == "request" and ev.name == "submit"]
    doc = load_trace_doc(trace) if isinstance(trace, str) else trace
    return [{"t": ev["ts"] // TICK_US, **ev.get("args", {})}
            for ev in doc["traceEvents"]
            if ev.get("cat") == "request" and ev.get("name") == "submit"]


def _percentile(xs: List[float], q: float) -> float:
    from repro.serving.metrics import percentile

    return percentile(xs, q)


def _split_heavy(max_news: List[int]) -> Tuple[
        Tuple[int, int], Optional[Tuple[float, int, int]]]:
    """Split the observed decode-length stream into its base range and an
    optional heavy-tail mixture component (fraction, lo, hi)."""
    thr = HEAVY_FACTOR * _percentile([float(v) for v in max_news], 90)
    heavy = [v for v in max_news if v > thr]
    base = [v for v in max_news if v <= thr]
    if not heavy or not base:
        return (min(max_news), max(max_news)), None
    frac = len(heavy) / len(max_news)
    return ((min(base), max(base)), (frac, min(heavy), max(heavy)))


def fit_profile(trace: TraceLike, *,
                kind: str = "poisson",
                duration: Optional[float] = None):
    """Fit a :class:`repro.plan.WorkloadProfile` from a recorded trace.

    ``trace`` is a live :class:`~repro.obs.trace.Tracer`, an exported
    Chrome-trace document (dict), or a path to one.  ``duration``
    overrides the observed span (last submission tick + 1) when the
    caller knows the true recording window — e.g. a quiet tail after the
    last arrival, which would otherwise inflate the fitted rate.
    """
    from repro.plan.plan import WorkloadProfile

    subs = _submit_records(trace)
    if not subs:
        raise ValueError("trace contains no request submit events; "
                         "nothing to fit a workload profile from")
    span = duration if duration is not None \
        else float(max(s["t"] for s in subs) + 1)
    if span <= 0:
        raise ValueError(f"non-positive observed span {span}")

    prompts = [int(s["prompt_len"]) for s in subs]
    max_news = [int(s["max_new"]) for s in subs]
    base_range, heavy = _split_heavy(max_news)

    slacks = [(float(s["deadline"]) - s["t"]) / s["max_new"]
              for s in subs if s.get("deadline") is not None]
    deadline_slack = _percentile(slacks, 50) if slacks else None
    deadline_frac = len(slacks) / len(subs) if slacks else 1.0

    return WorkloadProfile(
        kind=kind,
        rate=len(subs) / span,
        duration=span,
        prompt_len=(min(prompts), max(prompts)),
        max_new_tokens=base_range,
        heavy_decode=heavy,
        deadline_slack=deadline_slack,
        deadline_frac=deadline_frac,
    )


def observed_span_ticks(trace: TraceLike) -> int:
    """Last submission tick + 1 — the span :func:`fit_profile` assumes
    when no explicit duration is given."""
    subs = _submit_records(trace)
    return int(max(s["t"] for s in subs)) + 1 if subs else 0


def summarize(trace: TraceLike) -> Dict[str, object]:
    """A quick human-readable summary of a trace's observed traffic (the
    fit's inputs — handy for logs and notebooks)."""
    subs = _submit_records(trace)
    if not subs:
        return {"submits": 0}
    max_news = [float(s["max_new"]) for s in subs]
    return {
        "submits": len(subs),
        "span_ticks": observed_span_ticks(trace),
        "rate": len(subs) / max(1, observed_span_ticks(trace)),
        "prompt_len_p50": _percentile(
            [float(s["prompt_len"]) for s in subs], 50),
        "max_new_p50": _percentile(max_news, 50),
        "max_new_max": max(max_news),
        "with_deadline": sum(1 for s in subs
                             if s.get("deadline") is not None),
    }


__all__ = ["fit_profile", "observed_span_ticks", "summarize",
           "HEAVY_FACTOR"]
