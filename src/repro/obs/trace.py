"""Structured event tracing on the deterministic virtual clock.

"Measuring scheduling efficiency of RNNs for NLP applications" (Thakker
et al.) makes the case that per-request *timeline* measurement — not
end-of-run aggregates — is what separates real scheduling wins from
aggregate mirages.  :class:`Tracer` records exactly that timeline for the
serving engine:

* **request lifecycle** (cat ``request``, one Perfetto track per request
  uid): a ``queued`` span (submit → admit), a ``run`` span (admit →
  completion; occupancy includes the prefill tick, matching the TTFT
  convention), and instant events ``submit`` / ``first_token`` /
  ``preempt`` / ``resume`` / ``shed``;
* **engine events** (cat ``engine``, one track): ``decode_chunk`` spans
  (the fused on-device multi-tick loop), ``prefill`` instants (bucket
  length, rows, admitted count), ``host_sync`` instants (blocking
  device→host readbacks), and ``compile`` instants (a prefill shape or
  the decode program built by XLA);
* **counter tracks** (ph ``C``): per-tick slot ``util`` and per-schedule
  ``queue_depth``, rendered as graphs in Perfetto.

Timestamps are engine *ticks* scaled by :data:`TICK_US` (one tick
renders as 1 ms), never wall time — so a trace is a pure function of
(workload, seed) and two same-seed virtual-clock runs serialize to
**byte-identical** files (:meth:`Tracer.dumps` is canonical JSON; the
``benchmarks/run.py --smoke`` guard ``_check_trace_schema`` enforces
this in tier-1 CI).  Open an exported file at https://ui.perfetto.dev
(or chrome://tracing) — it is standard Chrome ``trace_event`` JSON.

The schema (validated by :func:`check_trace`) is documented in
``benchmarks/README.md`` § Observability.
"""

from __future__ import annotations

import dataclasses
import json
from typing import Dict, List, Mapping, Optional

TRACE_SCHEMA = "repro.obs.trace/v1"
TICK_US = 1000          # one virtual-clock tick rendered as 1 ms
ENGINE_PID = 1          # the engine's event track
REQUEST_PID = 2         # one thread (track) per request uid

CATS = ("request", "engine")
PHASES = ("X", "i", "C", "M")
# "quarantine" / "fault" / "retry" events are emitted only when the fault
# layer actually fires (injected fault or watchdog eviction), so every
# no-fault trace stays byte-identical to the pre-fault-tolerance engine
REQUEST_SPANS = ("queued", "run", "quarantine")
REQUEST_INSTANTS = ("submit", "first_token", "preempt", "resume", "shed",
                    "fault", "retry")
ENGINE_SPANS = ("decode_chunk",)
ENGINE_INSTANTS = ("prefill", "host_sync", "compile", "fault")
ENGINE_COUNTERS = ("util", "queue_depth",
                   # fragmentation tracks, emitted by paged-layout engines
                   # only (dense traces carry the first two exactly as
                   # before — byte-stable)
                   "blocks_free", "bytes_resident", "padding_waste")


@dataclasses.dataclass(frozen=True)
class TraceEvent:
    """One Chrome ``trace_event``; ``ts``/``dur`` are in the scaled tick
    units (:data:`TICK_US`), already multiplied."""

    name: str
    cat: str
    ph: str                       # "X" span | "i" instant | "C" counter
    ts: int
    pid: int
    tid: int
    dur: Optional[int] = None     # spans only
    args: Mapping[str, object] = dataclasses.field(default_factory=dict)

    def to_json(self) -> Dict[str, object]:
        d: Dict[str, object] = {"name": self.name, "cat": self.cat,
                                "ph": self.ph, "ts": self.ts,
                                "pid": self.pid, "tid": self.tid}
        if self.dur is not None:
            d["dur"] = self.dur
        if self.ph == "i":
            d["s"] = "t"          # instant scope: thread
        if self.args:
            d["args"] = dict(self.args)
        return d


class Tracer:
    """Collects :class:`TraceEvent`\\ s from the serving engine.

    Attach one via ``ServingEngine.from_plan(..., tracer=Tracer())`` (or
    the kwargs constructor); the engine calls the ``request_*`` /
    engine-event hooks below at the host points where it learns each
    fact, stamped with the *tick* the fact logically happened at.  All
    hooks are cheap appends — tracing never syncs the device and never
    perturbs the schedule.
    """

    def __init__(self) -> None:
        self.events: List[TraceEvent] = []

    def __len__(self) -> int:
        return len(self.events)

    def reset(self) -> None:
        """Drop all recorded events (``engine.reset_telemetry()`` calls
        this so a post-warmup trace restarts empty at tick 0)."""
        self.events.clear()

    # ------------------------------------------------------------ low level
    def _add(self, name: str, cat: str, ph: str, tick: int, tid: int, *,
             dur_ticks: Optional[int] = None, **args) -> None:
        pid = ENGINE_PID if cat == "engine" else REQUEST_PID
        self.events.append(TraceEvent(
            name=name, cat=cat, ph=ph, ts=int(tick) * TICK_US,
            pid=pid, tid=tid,
            dur=None if dur_ticks is None else int(dur_ticks) * TICK_US,
            args={k: v for k, v in args.items() if v is not None}))

    # ------------------------------------------------------ request lifecycle
    def request_submit(self, req, tick: int) -> None:
        self._add("submit", "request", "i", tick, req.uid,
                  uid=req.uid, prompt_len=len(req.prompt),
                  max_new=req.max_new_tokens, deadline=req.deadline)

    def request_shed(self, req, tick: int) -> None:
        self._add("shed", "request", "i", tick, req.uid,
                  uid=req.uid, deadline=req.deadline)

    def request_preempt(self, req, tick: int, slot: int,
                        evicted_tokens: int) -> None:
        self._add("preempt", "request", "i", tick, req.uid,
                  uid=req.uid, slot=slot, evicted_tokens=evicted_tokens)

    def request_resume(self, req, tick: int, slot: int) -> None:
        self._add("resume", "request", "i", tick, req.uid,
                  uid=req.uid, slot=slot)

    def request_done(self, req, tick: int) -> None:
        """Emit the request's lifecycle spans at completion, when every
        stamp is known: the ``queued`` wait span and the ``run``
        occupancy span (admit → done+1, the TTFT convention's prefill-
        inclusive interval), plus the ``first_token`` instant."""
        self._add("queued", "request", "X", req.t_submit, req.uid,
                  dur_ticks=req.t_admit - req.t_submit, uid=req.uid,
                  prompt_len=len(req.prompt))
        self._add("run", "request", "X", req.t_admit, req.uid,
                  dur_ticks=tick + 1 - req.t_admit, uid=req.uid,
                  n_tokens=len(req.output), n_preempts=req.n_preempts,
                  deadline=req.deadline)
        self._add("first_token", "request", "i", req.t_first, req.uid,
                  uid=req.uid)

    # ------------------------------------------------------- fault lifecycle
    def request_fault(self, req, tick: int, kind: str,
                      slot: Optional[int]) -> None:
        """A fault hit this request (poisoned/dropped/stalled slot, failed
        prefill): the moment the engine pulled it out of service."""
        self._add("fault", "request", "i", tick, req.uid,
                  uid=req.uid, kind=kind, slot=slot)

    def request_retry(self, req, tick: int, retries: int) -> None:
        """The faulted request was rolled back to its last good snapshot
        (or to scratch) and re-queued, charged one retry."""
        self._add("retry", "request", "i", tick, req.uid,
                  uid=req.uid, retries=retries,
                  tokens_kept=len(req.output))

    def request_quarantine(self, req, t_fault: int, t_recovered: int) -> None:
        """Span from the fault to the request being back in a slot (or
        shed) — the per-request recovery time the chaos benchmark plots."""
        self._add("quarantine", "request", "X", t_fault, req.uid,
                  dur_ticks=t_recovered - t_fault, uid=req.uid,
                  retries=req.retries)

    def engine_fault(self, tick: int, kind: str, **args) -> None:
        """Engine-scope fault instant (kill/drop_readback/fail_prefill and
        the slot-fault injection points)."""
        self._add("fault", "engine", "i", tick, 0, kind=kind, **args)

    # ---------------------------------------------------------- engine events
    def decode_chunk(self, tick: int, n_ticks: int, n_slots: int) -> None:
        self._add("decode_chunk", "engine", "X", tick, 0,
                  dur_ticks=max(1, n_ticks), n_ticks=n_ticks,
                  n_slots=n_slots)

    def prefill(self, tick: int, bucket: int, rows: int, n_reqs: int,
                overlap: bool) -> None:
        self._add("prefill", "engine", "i", tick, 0, bucket=bucket,
                  rows=rows, n_reqs=n_reqs, overlap=overlap)

    def host_sync(self, tick: int) -> None:
        self._add("host_sync", "engine", "i", tick, 0)

    def compile(self, tick: int, what: str, rows: int, length: int) -> None:
        self._add("compile", "engine", "i", tick, 0, what=what,
                  rows=rows, length=length)

    def counter(self, tick: int, name: str, value: float) -> None:
        self._add(name, "engine", "C", tick, 0, **{name: value})

    # -------------------------------------------------------------- export
    def to_chrome(self) -> Dict[str, object]:
        """The Chrome ``trace_event`` document: metadata naming the two
        process tracks, then every recorded event in emission order."""
        meta = [
            TraceEvent("process_name", "engine", "M", 0, ENGINE_PID, 0,
                       args={"name": "serving engine"}),
            TraceEvent("process_name", "request", "M", 0, REQUEST_PID, 0,
                       args={"name": "requests"}),
        ]
        return {
            "traceEvents": [e.to_json() for e in meta + self.events],
            "displayTimeUnit": "ms",
            "otherData": {"schema": TRACE_SCHEMA, "tick_us": TICK_US},
        }

    def dumps(self) -> str:
        """Canonical serialization: sorted keys, fixed separators — two
        tracers with equal event sequences produce equal bytes."""
        return json.dumps(self.to_chrome(), sort_keys=True,
                          separators=(",", ":")) + "\n"

    def save(self, path: str) -> None:
        with open(path, "w") as f:
            f.write(self.dumps())


def load_trace_doc(path: str) -> Dict[str, object]:
    """Read an exported trace back (for :mod:`repro.obs.observe` and the
    schema guard)."""
    with open(path) as f:
        return json.load(f)


def merge_traces(tracers, labels=None) -> Dict[str, object]:
    """Merge per-replica tracers into one Chrome document with replica-
    tagged tracks: replica ``r``'s engine events land on pid ``2r+1``
    and its request tracks on pid ``2r+2``, each named by ``process_name``
    metadata (``"replica 0 engine"`` / ``"replica 0 requests"`` …) so a
    fleet run opens in Perfetto as one timeline with the replicas stacked.
    Event content is untouched — ticks already share the fleet's virtual
    clock — so the merged document passes :func:`check_trace` and, like a
    single tracer, serializes byte-identically across same-seed runs
    (:func:`dumps_trace_doc`)."""
    tracers = list(tracers)
    if labels is None:
        labels = [f"replica {r}" for r in range(len(tracers))]
    if len(labels) != len(tracers):
        raise ValueError(f"need one label per tracer: "
                         f"{len(labels)} labels for {len(tracers)} tracers")
    events: List[TraceEvent] = []
    for r, (tr, label) in enumerate(zip(tracers, labels)):
        e_pid, q_pid = 2 * r + 1, 2 * r + 2
        events.append(TraceEvent("process_name", "engine", "M", 0,
                                 e_pid, 0, args={"name": f"{label} engine"}))
        events.append(TraceEvent("process_name", "request", "M", 0,
                                 q_pid, 0,
                                 args={"name": f"{label} requests"}))
        for e in tr.events:
            events.append(dataclasses.replace(
                e, pid=e_pid if e.pid == ENGINE_PID else q_pid))
    return {
        "traceEvents": [e.to_json() for e in events],
        "displayTimeUnit": "ms",
        "otherData": {"schema": TRACE_SCHEMA, "tick_us": TICK_US,
                      "replicas": len(tracers)},
    }


def dumps_trace_doc(doc: Mapping[str, object]) -> str:
    """Canonical serialization for an assembled trace document (same
    byte contract as :meth:`Tracer.dumps`)."""
    return json.dumps(doc, sort_keys=True, separators=(",", ":")) + "\n"


def check_trace(doc: Mapping[str, object]) -> None:
    """Validate a Chrome-trace document against the documented schema;
    raises ``ValueError`` on the first violation.  This is the drift
    guard ``benchmarks/run.py --smoke`` runs in tier-1 CI."""
    for key in ("traceEvents", "displayTimeUnit", "otherData"):
        if key not in doc:
            raise ValueError(f"trace document missing {key!r}")
    other = doc["otherData"]
    if other.get("schema") != TRACE_SCHEMA:
        raise ValueError(f"trace schema {other.get('schema')!r} != "
                         f"{TRACE_SCHEMA!r}")
    known = {
        "request": {"X": set(REQUEST_SPANS), "i": set(REQUEST_INSTANTS)},
        "engine": {"X": set(ENGINE_SPANS), "i": set(ENGINE_INSTANTS),
                   "C": set(ENGINE_COUNTERS)},
    }
    for i, ev in enumerate(doc["traceEvents"]):
        where = f"traceEvents[{i}]"
        for key in ("name", "cat", "ph", "ts", "pid", "tid"):
            if key not in ev:
                raise ValueError(f"{where} missing {key!r}: {ev}")
        if ev["ph"] not in PHASES:
            raise ValueError(f"{where} unknown phase {ev['ph']!r}")
        if ev["ph"] == "M":
            continue
        if ev["cat"] not in CATS:
            raise ValueError(f"{where} unknown category {ev['cat']!r}")
        if not isinstance(ev["ts"], int) or ev["ts"] < 0:
            raise ValueError(f"{where} ts must be a non-negative int, "
                             f"got {ev['ts']!r}")
        if ev["ts"] % TICK_US:
            raise ValueError(f"{where} ts {ev['ts']} is not tick-aligned "
                             f"(TICK_US={TICK_US})")
        allowed = known[ev["cat"]].get(ev["ph"])
        if allowed is None or ev["name"] not in allowed:
            raise ValueError(f"{where} unknown event "
                             f"{ev['cat']}/{ev['ph']}/{ev['name']!r}")
        if ev["ph"] == "X":
            if not isinstance(ev.get("dur"), int) or ev["dur"] < 0:
                raise ValueError(f"{where} span needs int dur >= 0: {ev}")
        if ev["cat"] == "request" and ev["ph"] != "C" \
                and ev["tid"] != ev.get("args", {}).get("uid", ev["tid"]):
            raise ValueError(f"{where} request event tid/uid mismatch: {ev}")


__all__ = ["Tracer", "TraceEvent", "check_trace", "load_trace_doc",
           "TRACE_SCHEMA", "TICK_US"]
