"""Typed metrics registry + windowed live metrics for the serving stack.

Pre-registry, every serving counter was an ad-hoc integer attribute:
``ServingEngine`` carried ten of them, ``SlotManager`` and the scheduler
each grew their own ``stats()`` dicts, and ``reset_telemetry()`` had to
enumerate every attribute by hand — miss one and warmup counts leak into
measured stats.  :class:`MetricsRegistry` centralizes them:

* every counter/gauge/histogram is *registered* under a dotted name
  (``engine.host_syncs``, ``scheduler.submitted``, ``slots.snapshots``),
  so ``registry.reset()`` resets all of them by construction;
* :meth:`MetricsRegistry.view` renders a compat dict under caller-chosen
  key names — ``ServingEngine.stats()`` keeps its historical keys
  byte-for-byte, which is what keeps the committed ``BENCH_*.json``
  blocks stable across the migration;
* gauges can be *derived* (backed by a callable), so occupancy-style
  values (active slots, queue depth) are always live and never stale.

:class:`LiveMetrics` is the windowed half: a rolling view over the last
``window`` engine ticks — p95 TTFT/TPOT, SLO attainment, mean
utilization — computed with the same tick conventions as
:mod:`repro.serving.metrics` (it reuses ``request_metrics``), so a
window spanning the whole run reproduces the end-of-run aggregate
exactly (property-tested in ``tests/test_obs.py``).

Everything here is host-side, deterministic, and dependency-light (no
jax): observability must never perturb the virtual-clock schedule.
"""

from __future__ import annotations

import math
from collections import deque
from typing import Callable, Deque, Dict, List, Optional, Tuple


class Counter:
    """A monotonically increasing count (resettable)."""

    kind = "counter"

    def __init__(self, name: str, help: str = ""):
        self.name = name
        self.help = help
        self.value: int = 0

    def inc(self, n: int = 1) -> None:
        self.value += n

    def reset(self) -> None:
        self.value = 0


class Gauge:
    """A point-in-time value.  Backed either by :meth:`set` or by a
    callable (``fn``) for derived/occupancy-style values that must never
    go stale; derived gauges ignore :meth:`reset`."""

    kind = "gauge"

    def __init__(self, name: str, help: str = "",
                 fn: Optional[Callable[[], float]] = None):
        self.name = name
        self.help = help
        self._fn = fn
        self._value: float = 0.0

    def set(self, v: float) -> None:
        if self._fn is not None:
            raise ValueError(f"gauge {self.name!r} is derived (fn-backed); "
                             f"it cannot be set")
        self._value = v

    @property
    def value(self) -> float:
        return self._fn() if self._fn is not None else self._value

    def reset(self) -> None:
        if self._fn is None:
            self._value = 0.0


class Histogram:
    """A stream of observations with nearest-rank percentile summaries
    (same method as :mod:`repro.serving.metrics` — deterministic, no
    interpolation)."""

    kind = "histogram"

    def __init__(self, name: str, help: str = ""):
        self.name = name
        self.help = help
        self.values: List[float] = []

    def observe(self, v: float) -> None:
        self.values.append(float(v))

    @property
    def value(self) -> int:
        """Registered-value view: the observation count."""
        return len(self.values)

    def summary(self) -> Dict[str, float]:
        from repro.serving.metrics import percentile

        out = {f"p{q}": percentile(self.values, q) for q in (50, 95, 99)}
        out["mean"] = (float(sum(self.values) / len(self.values))
                       if self.values else math.nan)
        out["n"] = len(self.values)
        return out

    def reset(self) -> None:
        self.values = []


class MetricsRegistry:
    """Name → metric store with get-or-create registration.

    Registration is idempotent per (name, kind): asking for an existing
    name returns the existing metric, asking for it under a different
    kind is an error (two subsystems silently sharing a name under
    different semantics is exactly the drift this registry exists to
    prevent)."""

    def __init__(self) -> None:
        self._metrics: Dict[str, object] = {}

    def _register(self, cls, name: str, help: str, **kw):
        m = self._metrics.get(name)
        if m is not None:
            if not isinstance(m, cls):
                raise ValueError(f"metric {name!r} already registered as "
                                 f"{m.kind}, requested {cls.kind}")
            return m
        m = cls(name, help, **kw)
        self._metrics[name] = m
        return m

    def counter(self, name: str, help: str = "") -> Counter:
        return self._register(Counter, name, help)

    def gauge(self, name: str, help: str = "",
              fn: Optional[Callable[[], float]] = None) -> Gauge:
        return self._register(Gauge, name, help, fn=fn)

    def histogram(self, name: str, help: str = "") -> Histogram:
        return self._register(Histogram, name, help)

    def get(self, name: str):
        return self._metrics.get(name)

    def __getitem__(self, name: str):
        return self._metrics[name]

    def __contains__(self, name: str) -> bool:
        return name in self._metrics

    def names(self) -> List[str]:
        return sorted(self._metrics)

    def reset(self) -> None:
        """Reset every registered metric — the one-call telemetry reset:
        a counter added anywhere in the stack is covered by construction,
        so warmup runs can never leak counts into measured stats."""
        for m in self._metrics.values():
            m.reset()

    def snapshot(self) -> Dict[str, float]:
        """Flat name → value dict (sorted keys; histograms report their
        observation count — use :meth:`Histogram.summary` for shape)."""
        return {name: self._metrics[name].value for name in self.names()}

    def view(self, mapping: "Dict[str, str]") -> Dict[str, float]:
        """A compat dict: ``{out_key: metric_name}`` rendered in mapping
        order with the *caller's* key names — how ``stats()`` surfaces
        preserve their historical keys over the registry."""
        return {out: self._metrics[name].value
                for out, name in mapping.items()}


class LiveMetrics:
    """Rolling serving metrics over the last ``window`` engine ticks.

    The engine feeds it per tick (:meth:`observe_tick` with that tick's
    utilization) and per retired request (:meth:`observe_request` at the
    completion/shed tick); :meth:`snapshot` then answers "how is serving
    *right now*": p95 TTFT/TPOT over requests that finished inside the
    window, rolling SLO attainment, and mean utilization — the windowed
    analogue of :func:`repro.serving.metrics.aggregate`, sharing its
    tick conventions via ``request_metrics``.  With ``window`` at least
    the run length nothing is ever evicted and the snapshot equals the
    end-of-run aggregate.
    """

    def __init__(self, window: int = 64):
        if window < 1:
            raise ValueError(f"window must be >= 1, got {window}")
        self.window = int(window)
        self._util: Deque[float] = deque(maxlen=self.window)
        # (tick retired, per-request metrics or None, slo_met or None)
        self._reqs: Deque[Tuple[int, Optional[Dict[str, float]],
                                Optional[bool]]] = deque()
        self._tick = 0

    def reset(self) -> None:
        self._util.clear()
        self._reqs.clear()
        self._tick = 0

    # ------------------------------------------------------------- feeding
    def observe_tick(self, tick: int, util: float) -> None:
        """One engine tick's utilization; evicts request samples that
        retired before the window's left edge."""
        self._tick = max(self._tick, int(tick))
        self._util.append(float(util))
        edge = self._tick - self.window
        while self._reqs and self._reqs[0][0] <= edge:
            self._reqs.popleft()

    def observe_request(self, req, tick: int) -> None:
        """A request retired at ``tick`` — completed (latency samples +
        SLO verdict) or shed/unfinished-with-deadline (SLO miss, no
        latency samples)."""
        from repro.serving.metrics import request_metrics

        m = request_metrics(req)
        met: Optional[bool] = None
        if req.deadline is not None:
            met = (req.done and req.t_done is not None
                   and req.t_done + 1 <= req.deadline)
        self._reqs.append((int(tick), m, met))

    # ------------------------------------------------------------ reporting
    def snapshot(self) -> Dict[str, object]:
        from repro.serving.metrics import percentile

        per = [m for _, m, _ in self._reqs if m is not None]
        ttft = [m["ttft"] for m in per]
        tpot = [m["tpot"] for m in per if "tpot" in m]
        slo = [met for _, _, met in self._reqs if met is not None]
        util = list(self._util)
        out: Dict[str, object] = {
            "window": self.window,
            "tick": self._tick,
            "completed": len(per),
            "ttft_p95": percentile(ttft, 95),
            "tpot_p95": percentile(tpot, 95),
            "mean_util": (sum(util) / len(util)) if util else math.nan,
            "slo_attainment": (sum(slo) / len(slo)) if slo else None,
        }
        return out

    def line(self) -> str:
        """One monitoring line for the serve CLI (``--live-metrics``)."""
        s = self.snapshot()
        slo = (f" slo={s['slo_attainment']:.2f}"
               if s["slo_attainment"] is not None else "")
        return (f"[t={s['tick']:>6}] last {s['window']}t: "
                f"ttft_p95={s['ttft_p95']:6.1f}t "
                f"tpot_p95={s['tpot_p95']:5.2f}t "
                f"util={s['mean_util']:.2f} "
                f"done={s['completed']}" + slo)


__all__ = ["Counter", "Gauge", "Histogram", "MetricsRegistry",
           "LiveMetrics"]
