"""Gradient-communication compression.

Two pieces:

  * :func:`compressed_allreduce` — a shard_map collective that implements
    mean-all-reduce as f32 ``psum_scatter`` + **int8 all-gather**: each
    device averages its 1/n shard at full precision, quantizes it once,
    and the replication traffic (the (n-1)/n·bytes all-gather leg) moves
    int8 — a ~1.6x wire-byte reduction vs f32 ring all-reduce, visible in
    the lowered HLO (``all-gather ... s8``).  Deployment point: the
    cross-pod (DCN) gradient sync, where bandwidth is scarcest.
  * :func:`make_error_feedback` — error-feedback quantization wrapper
    (residual carried in f32) so repeated compression does not bias the
    optimizer; composes with the train step's ``grad_transform`` hook.
"""

from __future__ import annotations

import functools
from typing import Any, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, PartitionSpec as P

from repro._jax_compat import shard_map_compat
from repro.core.quant import quantize_int8

F32 = jnp.float32


def _compressed_mean_1d(x: jax.Array, axis_name: str, n: int) -> jax.Array:
    """Per-device body: f32 psum_scatter -> int8 quantize -> all_gather."""
    shard = jax.lax.psum_scatter(x, axis_name, scatter_dimension=0,
                                 tiled=True) / n
    q, scale = quantize_int8(shard.reshape(1, -1), axis=-1)
    q = jax.lax.all_gather(q[0], axis_name, tiled=True)
    scales = jax.lax.all_gather(scale.reshape(1), axis_name).reshape(n)
    # undo the scatter layout: segment i was quantized with scales[i]
    seg = q.reshape(n, -1).astype(F32) * scales[:, None]
    return seg.reshape(x.shape)


def compressed_allreduce(grads: Any, mesh: Mesh, axis: str = "data") -> Any:
    """Mean-all-reduce every leaf over ``axis`` with int8 replication
    traffic.  Leaves must be replicated over ``axis`` on entry (the usual
    DP layout) and divisible by the axis size when flattened."""
    n = mesh.shape[axis]

    def one(g):
        flat = g.astype(F32).reshape(-1)
        pad = (-flat.size) % n
        if pad:
            flat = jnp.concatenate([flat, jnp.zeros((pad,), F32)])
        out = _compressed_mean_1d(flat, axis, n)
        if pad:
            out = out[:-pad]
        return out.reshape(g.shape).astype(g.dtype)

    fn = shard_map_compat(lambda t: jax.tree.map(one, t), mesh,
                          in_specs=P(), out_specs=P())
    return fn(grads)


def make_error_feedback():
    """Returns (init_fn, apply_fn) for error-feedback int8 compression:
    apply(grads, residual) -> (compressed_grads, new_residual)."""

    def init(grads):
        return jax.tree.map(lambda g: jnp.zeros(g.shape, F32), grads)

    def apply(grads, residual):
        def one(g, r):
            x = g.astype(F32) + r
            q, scale = quantize_int8(x.reshape(1, -1), axis=-1)
            deq = (q.astype(F32) * scale).reshape(g.shape)
            return deq.astype(g.dtype), x - deq

        pairs = jax.tree.map(one, grads, residual)
        comp = jax.tree.map(lambda p: p[0], pairs,
                            is_leaf=lambda x: isinstance(x, tuple))
        res = jax.tree.map(lambda p: p[1], pairs,
                           is_leaf=lambda x: isinstance(x, tuple))
        return comp, res

    return init, apply
