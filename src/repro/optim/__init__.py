from repro.optim.adamw import AdamW, TrainState, adamw_update  # noqa: F401
from repro.optim.schedule import cosine_schedule  # noqa: F401
