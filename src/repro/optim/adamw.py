"""AdamW with global-norm clipping and spec-aware sharded state.

The optimizer state (m, v) mirrors the parameter ParamSpecs — same shapes,
same logical axes — so under FSDP the whole Adam state shards over
(data x model) and never reaches per-chip HBM limits (ZeRO-style, but
expressed declaratively through shardings rather than explicit gathers).
"""

from __future__ import annotations

import dataclasses
from typing import Any, Callable, Dict, Optional

import jax
import jax.numpy as jnp

from repro.models import params as pspec

F32 = jnp.float32


@dataclasses.dataclass(frozen=True)
class AdamW:
    lr: Callable[[jax.Array], jax.Array]
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    clip_norm: float = 1.0


# {"params", "m", "v", "step"} — a plain dict so it is a registered pytree.
TrainState = dict


def init_state(param_specs, key: jax.Array) -> TrainState:
    params = pspec.tree_init(param_specs, key)
    zeros = jax.tree.map(lambda p: jnp.zeros_like(p), params)
    return TrainState(params=params, m=zeros,
                      v=jax.tree.map(jnp.zeros_like, params),
                      step=jnp.zeros((), jnp.int32))


def abstract_state(param_specs) -> TrainState:
    ab = pspec.tree_abstract(param_specs)
    return TrainState(params=ab, m=ab, v=ab,
                      step=jax.ShapeDtypeStruct((), jnp.int32))


def state_axes(param_specs) -> TrainState:
    ax = pspec.tree_axes(param_specs)
    return TrainState(params=ax, m=ax, v=ax, step=None)


def global_norm(tree) -> jax.Array:
    leaves = [jnp.sum(jnp.square(x.astype(F32))) for x in jax.tree.leaves(tree)]
    return jnp.sqrt(jnp.sum(jnp.stack(leaves)))


def adamw_update(opt: AdamW, state: TrainState, grads) -> tuple:
    """Returns (new_state, metrics)."""
    step = state["step"] + 1
    gnorm = global_norm(grads)
    scale = jnp.minimum(1.0, opt.clip_norm / jnp.maximum(gnorm, 1e-9))
    lr = opt.lr(step)
    b1, b2 = opt.b1, opt.b2
    bc1 = 1 - b1 ** step.astype(F32)
    bc2 = 1 - b2 ** step.astype(F32)

    def upd(p, g, m, v):
        g = g.astype(F32) * scale
        m = b1 * m + (1 - b1) * g
        v = b2 * v + (1 - b2) * jnp.square(g)
        mhat = m / bc1
        vhat = v / bc2
        delta = mhat / (jnp.sqrt(vhat) + opt.eps)
        if p.ndim >= 2:  # decoupled weight decay on matrices only
            delta = delta + opt.weight_decay * p.astype(F32)
        return (p.astype(F32) - lr * delta).astype(p.dtype), m, v

    flat_p, treedef = jax.tree.flatten(state["params"])
    flat_g = treedef.flatten_up_to(grads)
    flat_m = treedef.flatten_up_to(state["m"])
    flat_v = treedef.flatten_up_to(state["v"])
    out = [upd(p, g, m, v) for p, g, m, v in
           zip(flat_p, flat_g, flat_m, flat_v)]
    new_p = treedef.unflatten([o[0] for o in out])
    new_m = treedef.unflatten([o[1] for o in out])
    new_v = treedef.unflatten([o[2] for o in out])
    metrics = {"grad_norm": gnorm, "lr": lr}
    return TrainState(params=new_p, m=new_m, v=new_v, step=step), metrics
