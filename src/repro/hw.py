"""Hardware model of the target platform.

The container executes on CPU; TPU v5e-class chips are the *target* for
which we lower, tile, and budget.  All roofline arithmetic in
:mod:`repro.launch.roofline` and all DSE cost models in :mod:`repro.core.dse`
read their constants from here so there is exactly one source of truth.

The constants mirror the assignment spec:
  * 197 TFLOP/s bf16 per chip (394 TOP/s int8),
  * 819 GB/s HBM bandwidth,
  * ~50 GB/s per ICI link,
and the memory hierarchy parameters used by the Pallas kernels
(HBM -> VMEM -> VREG), which replace the paper's
(DRAM -> scratchpad/PMU -> pipeline-register/PCU) hierarchy on Plasticine.
"""

from __future__ import annotations

import dataclasses


@dataclasses.dataclass(frozen=True)
class HardwareSpec:
    """A single accelerator chip plus its interconnect."""

    name: str
    # --- compute ---------------------------------------------------------
    peak_bf16_flops: float      # FLOP/s, MXU bf16 multiply / f32 accumulate
    peak_int8_ops: float        # OP/s, MXU int8 multiply / i32 accumulate
    # --- memory ----------------------------------------------------------
    hbm_bytes: float            # per-chip HBM capacity
    hbm_bw: float               # bytes/s HBM <-> VMEM
    vmem_bytes: float           # on-chip vector memory (the paper's scratchpad)
    vmem_bw: float              # bytes/s VMEM <-> VREG (approximate)
    # --- interconnect ----------------------------------------------------
    ici_link_bw: float          # bytes/s per ICI link (one direction)
    ici_links: int              # links per chip (2D torus on v5e)
    dcn_bw: float               # bytes/s per host for cross-pod (DCN) traffic
    # --- micro-architecture ----------------------------------------------
    mxu_dim: int = 128          # systolic array edge: matmul dims should be
                                # multiples of this for full utilization
    vreg_lanes: int = 8         # (8, 128) native vector registers
    vreg_sublanes: int = 128
    # --- energy model (approximate, for the paper's power analysis) ------
    pj_per_flop_bf16: float = 0.25     # pJ per bf16 FLOP, MXU
    pj_per_byte_hbm: float = 120.0     # pJ per byte moved HBM<->VMEM
    pj_per_byte_vmem: float = 6.0      # pJ per byte moved VMEM<->VREG
    pj_per_byte_ici: float = 40.0      # pJ per byte over ICI
    idle_watts: float = 70.0           # static power per chip

    @property
    def peak_flops(self) -> float:
        return self.peak_bf16_flops

    def matmul_time(self, flops: float, dtype_bits: int = 16) -> float:
        """Roofline compute time for `flops` at the given precision."""
        peak = self.peak_int8_ops if dtype_bits <= 8 else self.peak_bf16_flops
        return flops / peak

    def hbm_time(self, nbytes: float) -> float:
        return nbytes / self.hbm_bw

    def ici_time(self, nbytes: float) -> float:
        """Time to move `nbytes` off-chip over all links (best case)."""
        return nbytes / (self.ici_link_bw * self.ici_links)


# TPU v5e-class target.  VMEM capacity is the order-of-magnitude budget the
# Pallas BlockSpecs are sized against; roughly half is usable once the
# pipelining machinery double-buffers every operand.
TPU_V5E = HardwareSpec(
    name="tpu-v5e",
    peak_bf16_flops=197e12,
    peak_int8_ops=394e12,
    hbm_bytes=16e9,
    hbm_bw=819e9,
    vmem_bytes=64 * 2**20,
    vmem_bw=10e12,
    ici_link_bw=50e9,
    ici_links=4,
    dcn_bw=25e9,
)

# The paper's comparison targets, kept for the DeepBench benchmark tables
# (Section 5, Tables 4-6).  Only the fields used by the benchmark report are
# meaningful; others are order-of-magnitude placeholders.
PLASTICINE = HardwareSpec(
    name="plasticine-rnn-variant",
    peak_bf16_flops=12.5e12,     # peak 32-bit from Table 4; 8-bit peak = 49T
    peak_int8_ops=49e12,
    hbm_bytes=16e9,
    hbm_bw=100e9,
    vmem_bytes=int(384 * 84e3),  # 384 PMUs x 84 kB scratchpads (Table 3)
    vmem_bw=4e12,
    ici_link_bw=0.0,
    ici_links=0,
    dcn_bw=0.0,
)

DEFAULT = TPU_V5E

# name -> spec, for CLI flags (launch.serve --hw-spec) and plan provenance
SPECS = {spec.name: spec for spec in (TPU_V5E, PLASTICINE)}


def get_spec(name: str) -> HardwareSpec:
    if name not in SPECS:
        raise KeyError(f"unknown hardware spec {name!r}; "
                       f"known: {sorted(SPECS)}")
    return SPECS[name]


def vmem_budget(hw: HardwareSpec = DEFAULT, fraction: float = 0.5) -> int:
    """Usable VMEM once double buffering is accounted for."""
    return int(hw.vmem_bytes * fraction)
