"""Distribution substrate: logical-axis sharding rules and pipeline stages.

``repro.dist.sharding`` — the rules engine mapping logical tensor axes to
mesh axes (the multi-device analogue of the paper's per-problem-size
design-parameter search; see the module docstring).
``repro.dist.pipeline`` — GPipe-style pipeline parallelism over a mesh
axis via ``shard_map`` + ``ppermute``.
"""

from repro.dist.sharding import Sharder, make_rules, make_sharder

__all__ = ["Sharder", "make_rules", "make_sharder"]
