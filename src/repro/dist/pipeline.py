"""GPipe pipeline parallelism over a mesh axis via shard_map + ppermute.

Each device along the ``pipe`` axis owns one stage's parameters (the
stacked stage tree shards on its leading dim).  The batch splits into
microbatches; device 0 feeds one in per step, every device applies its
stage to whatever it holds, and a ``ppermute`` shifts activations one hop
down the pipe — the classic GPipe fill/steady/drain schedule, S + M - 1
steps for S stages and M microbatches.  The last device's outputs are
collected per microbatch and replicated with a ``psum`` (only the owning
device contributes), so the whole schedule is a pure differentiable
function: ``jax.grad`` through it yields the backward pipeline for free,
and the lowered HLO moves activations with ``collective-permute`` (asserted
by tests/test_pipeline.py).
"""

from __future__ import annotations

from typing import Callable, Optional, Sequence

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, PartitionSpec as P

from repro._jax_compat import shard_map_compat


def stack_stage_params(stages: Sequence) -> jax.Array:
    """Stack a list of per-stage param trees along a new leading (stage)
    dim, giving the pipeline-sharded layout ``pipeline_apply`` expects."""
    return jax.tree.map(lambda *xs: jnp.stack(xs), *stages)


def pipeline_apply(stage_fn: Callable, params, x: jax.Array, mesh: Mesh,
                   axis: str = "pipe",
                   n_microbatches: Optional[int] = None) -> jax.Array:
    """Apply ``stage_fn`` S times through an S-deep pipeline.

    ``params``: stage-stacked tree (leaves lead with the stage dim, which
    shards over ``axis``); ``x``: (B, ...) batch, replicated.  Equals the
    sequential composition of the stages exactly.
    """
    n_stages = mesh.shape[axis]
    batch = x.shape[0]
    n_micro = n_microbatches or (n_stages if batch % n_stages == 0 else 1)
    assert batch % n_micro == 0, (batch, n_micro)

    def schedule(p_block, xs):
        # p_block: this device's (1, ...) stage slice; xs: (M, mb, ...)
        p = jax.tree.map(lambda a: a[0], p_block)
        idx = jax.lax.axis_index(axis)
        fwd = [(i, (i + 1) % n_stages) for i in range(n_stages)]
        zero = jnp.zeros(xs.shape[1:], xs.dtype)
        h = zero
        out = jnp.zeros_like(xs)
        for t in range(n_stages + n_micro - 1):
            feed = xs[t] if t < n_micro else zero
            y = stage_fn(p, jnp.where(idx == 0, feed, h))
            j = t - (n_stages - 1)       # microbatch draining this step
            if 0 <= j < n_micro:
                out = out.at[j].set(jnp.where(idx == n_stages - 1, y, 0.0))
            h = jax.lax.ppermute(y, axis, fwd)
        # only the last stage wrote non-zeros -> psum replicates its rows
        return jax.lax.psum(out, axis)

    fn = shard_map_compat(schedule, mesh, in_specs=(P(axis), P()),
                          out_specs=P())
    xs = x.reshape(n_micro, batch // n_micro, *x.shape[1:])
    return fn(params, xs).reshape(batch, *x.shape[1:])
