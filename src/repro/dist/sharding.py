"""Sharding rules engine: logical axes -> mesh axes, per architecture x mode.

Why a *rules table* instead of hardcoded ``PartitionSpec``s
-----------------------------------------------------------
The paper's central claim is that a fixed-geometry kernel (Brainwave's
hv=400/rv=40/ru=6 MVM engine) fragments utilization across problem sizes,
while exposing the loop/tiling design parameters and re-searching them per
problem size keeps the hardware busy (§3.3, Table 7).  The multi-device
analogue of that design space is the *partitioning* of every tensor over
the mesh: a layout that keeps a 14B dense model's weights resident and
balanced is wrong for a 128-expert MoE, and a train-time layout (activations
seq-replicated, params FSDP-sharded) is wrong for single-token decode
(cache-dim sharding, no head-divisibility requirement).  So, exactly as the
kernel DSE picks ``bh`` per (cell, batch, precision), this module picks a
rules table per (architecture, mode):

  ``make_rules(cfg, mode)``  ->  {logical_axis: (mesh_axis, ...)}

and a :class:`Sharder` resolves every tensor against that table at trace
time.  Model code never names mesh axes — it annotates *logical* axes
(``"batch"``, ``"heads"``, ``"mlp"``, ``"experts"``, ...) via
``ParamSpec.axes`` and ``sharder.constrain``; swapping the table re-lays-out
the whole program (the same ``constrain`` call sites resolve differently for
"heads" vs "qseq" attention sharding, or train vs decode).

Resolution semantics
--------------------
* **Divisibility fallback** — ``resolve(axis, dim)`` walks the rule's mesh
  axes and drops *trailing* axes until the dimension divides the product of
  the remaining sizes; when nothing divides, the tensor axis is fully
  replicated (returns ``None``).  This is what lets one table serve every
  problem size: 48 heads shard 16-way, 40 heads silently fall back, decode's
  size-1 seq dims always replicate.
* **No mesh-axis reuse** — ``spec(axes, shape)`` never assigns one mesh axis
  to two tensor dims (GSPMD would reject it); earlier tensor dims win, e.g.
  in an expert weight ``("experts", "embed", "mlp")`` the experts take the
  model axis and the mlp dim stays unsharded.
* **Replicated no-op path** — ``Sharder(None, {})`` (mesh-less) makes
  ``constrain`` the identity and every sharding ``None``, so single-host
  smoke tests and CPU serving run the exact same model code.

The ZeRO-1 variant used by ``launch/dryrun.py`` is this table plus one
override (``rules["embed"] = ("data",)`` applied only to optimizer-state
shardings): optimizer state shards over data while params stay replicated,
and GSPMD inserts the re-gather in the update step.
"""

from __future__ import annotations

from typing import Dict, Optional, Sequence, Tuple

import jax
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.configs.base import ModelConfig
from repro.models.params import is_spec

# Logical axes that carry the batch (data-parallel) dimension.
_DATA_AXES: Tuple[str, ...] = ("pod", "data")
# Logical weight axes that shard over the model (tensor-parallel) axis.
_WEIGHT_AXES: Tuple[str, ...] = ("mlp", "vocab", "q_flat", "kv_flat",
                                 "ssm_inner", "experts", "rwkv_heads")


class Sharder:
    """Resolves logical tensor axes against a mesh through a rules table."""

    def __init__(self, mesh, rules: Dict[str, Tuple[str, ...]]):
        self.mesh = mesh
        self.rules = dict(rules)

    # ------------------------------------------------------------- resolve
    def resolve(self, axis: Optional[str], dim: int
                ) -> Optional[Tuple[str, ...]]:
        """Mesh axes for one tensor dim, with the divisibility fallback:
        trailing mesh axes are dropped until ``dim`` divides the shard
        count; ``None`` means fully replicated."""
        if self.mesh is None or axis is None:
            return None
        cand = [a for a in self.rules.get(axis, ()) if a in self.mesh.shape]
        while cand and dim % self._size(cand):
            cand.pop()
        return tuple(cand) or None

    def _size(self, axes: Sequence[str]) -> int:
        n = 1
        for a in axes:
            n *= self.mesh.shape[a]
        return n

    # ---------------------------------------------------------------- spec
    def spec(self, axes: Sequence[Optional[str]], shape: Sequence[int]) -> P:
        """PartitionSpec for a tensor; a mesh axis is never used twice
        within one spec (earlier tensor dims win)."""
        used: set = set()
        entries = []
        for axis, dim in zip(axes, shape):
            r = self.resolve(axis, dim)
            if r:
                r = [a for a in r if a not in used]
                while r and dim % self._size(r):
                    r.pop()
            if not r:
                entries.append(None)
                continue
            used.update(r)
            entries.append(r[0] if len(r) == 1 else tuple(r))
        return P(*entries)

    # ------------------------------------------------------------ shardings
    def sharding(self, axes: Sequence[Optional[str]], shape: Sequence[int]
                 ) -> Optional[NamedSharding]:
        """NamedSharding for one tensor (``None`` on the mesh-less path)."""
        if self.mesh is None:
            return None
        return NamedSharding(self.mesh, self.spec(axes, shape))

    def param_shardings(self, specs):
        """Sharding tree for a ``ParamSpec`` tree (``models.params``)."""
        def one(s):
            axes = s.axes if s.axes else (None,) * len(s.shape)
            return self.sharding(axes, s.shape)
        return jax.tree.map(one, specs, is_leaf=is_spec)

    # ------------------------------------------------------------ constrain
    def constrain(self, x: jax.Array, *axes: Optional[str]) -> jax.Array:
        """``with_sharding_constraint`` by logical axes; identity when no
        mesh is attached or nothing resolves (the replicated no-op path)."""
        if self.mesh is None or not self.rules:
            return x
        spec = self.spec(axes, x.shape)
        if all(e is None for e in spec):
            return x
        return jax.lax.with_sharding_constraint(
            x, NamedSharding(self.mesh, spec))


# ---------------------------------------------------------------------------
# Per-architecture, per-mode rules tables
# ---------------------------------------------------------------------------


def _heads_mode(cfg: ModelConfig) -> bool:
    """"heads" attention sharding: head dims shard over the model axis;
    "qseq": the query sequence shards instead (head counts like 40 or 25
    that don't divide the 16-way production axis).  "auto" decides by the
    production mesh's model-axis width."""
    if cfg.attention_sharding == "auto":
        return cfg.n_heads % 16 == 0
    return cfg.attention_sharding != "qseq"


def make_rules(cfg: ModelConfig, mode: str) -> Dict[str, Tuple[str, ...]]:
    """The rules table for one (architecture, mode) cell.

    ``mode``: "train" | "prefill" | "decode".  Covers every logical axis the
    ten configs annotate: dense (``heads``/``qseq``), MoE (``experts``,
    ``expert_group``), RWKV (``rwkv_heads``), SSM (``ssm_inner``),
    ``vocab``/``mlp`` weight dims and the ``batch``/seq activation dims.
    """
    if mode not in ("train", "prefill", "decode"):
        raise ValueError(f"unknown mode {mode!r}")

    rules: Dict[str, Tuple[str, ...]] = {
        # data parallelism
        "batch": _DATA_AXES,
        "expert_group": _DATA_AXES,   # MoE token groups ride the data axis
    }
    # tensor parallelism: weight dims over the model axis
    for ax in _WEIGHT_AXES:
        rules[ax] = ("model",)

    if mode == "train":
        if cfg.fsdp:
            # FSDP: the shared "embed" dim additionally shards over data,
            # so params + Adam state scale with the full chip count.
            rules["embed"] = ("data",)
        if not cfg.train_tp:
            # pure DP lever: replicate weights, batch spans every axis
            for ax in _WEIGHT_AXES:
                rules[ax] = ()
            rules["batch"] = _DATA_AXES + ("model",)
            rules["expert_group"] = _DATA_AXES + ("model",)
        if cfg.seq_parallel:
            # Megatron-SP: activations stay seq-sharded through the layer
            rules["seq"] = ("model",)
        if cfg.shard_residual_seq:
            rules["res_seq"] = ("model",)

    if mode in ("train", "prefill"):
        if _heads_mode(cfg):
            rules["heads"] = ("model",)
            rules["kv_heads"] = ("model",)
        else:
            rules["qseq"] = ("model",)
    else:
        # decode: the KV cache's sequence dim shards over the model axis
        # (flash-decode style: partial softmax + all-reduce), which needs
        # no head divisibility at all — heads/qseq stay replicated.
        rules["cache_seq"] = ("model",)
        rules["window"] = ("model",)

    if mode == "prefill":
        # prefill *produces* the decode cache: lay it out as decode reads it
        rules["cache_seq"] = ("model",)
        rules["window"] = ("model",)

    return rules


def make_sharder(cfg: ModelConfig, mesh, mode: str) -> Sharder:
    """Tie it together: the Sharder for one (architecture, mesh, mode)."""
    return Sharder(mesh, make_rules(cfg, mode))
