"""Version-compat shims for jax API renames, shared by every user.

The repo targets current jax but must run on older toolchains (the pinned
image ships 0.4.x); each rename is bridged exactly once here.
"""

from __future__ import annotations

import jax


def shard_map_compat(f, mesh, in_specs, out_specs):
    """``shard_map`` with the replication check off, across the top-level
    (>= 0.6, ``check_vma``) and experimental (older, ``check_rep``) APIs."""
    if hasattr(jax, "shard_map"):
        return jax.shard_map(f, mesh=mesh, in_specs=in_specs,
                             out_specs=out_specs, check_vma=False)
    from jax.experimental.shard_map import shard_map
    return shard_map(f, mesh, in_specs=in_specs, out_specs=out_specs,
                     check_rep=False)


def tpu_compiler_params():
    """The Pallas-TPU compiler-params dataclass: ``CompilerParams`` on
    current jax, ``TPUCompilerParams`` before the rename.  Raises at import
    time (not at first kernel call) when neither exists."""
    from jax.experimental.pallas import tpu as pltpu
    for name in ("CompilerParams", "TPUCompilerParams"):
        cls = getattr(pltpu, name, None)
        if cls is not None:
            return cls
    raise ImportError(
        "jax.experimental.pallas.tpu exposes neither CompilerParams nor "
        "TPUCompilerParams; unsupported jax version")
