"""StarCoder2-15B  [dense]  40L d_model=6144 48H (GQA kv=4) d_ff=24576
vocab=49152 — GQA, RoPE.  [arXiv:2402.19173; hf]

Classic 4x non-gated GELU MLP.  48 heads divide the 16-way model axis;
4 KV heads are replicated across it (flat kv projection dim 512 still
divides 16 for the weights).
"""

from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="starcoder2-15b",
    family="dense",
    n_layers=40,
    d_model=6144,
    n_heads=48,
    n_kv_heads=4,
    head_dim=128,
    d_ff=24576,
    vocab_size=49152,
    qkv_bias=True,
    rope_theta=1e5,
    layer_pattern=("attn",),
    mlp_gated=False,
    mlp_act="gelu",
    fsdp=True,
    remat="full",
    n_microbatches=8,
    attention_sharding="heads",
)
