"""RWKV6-1.6B (Finch)  [ssm]  24L d_model=2048 (attention-free) d_ff=7168
vocab=65536 — data-dependent decay.  [arXiv:2404.05892]

This is the architecture closest to the paper's own subject: a recurrent
cell whose serving step is a fused matvec + elementwise program.  The WKV
state update S_t = diag(w_t) S_{t-1} + k_t v_t^T is evaluated in the
TPU-friendly chunked form (repro.models.recurrence) for train/prefill and
as the paper-style fused single-step recurrence for decode.  O(1) state
makes every shape cell, including long_500k, runnable.
"""

from repro.configs.base import ModelConfig, RWKVConfig

CONFIG = ModelConfig(
    name="rwkv6-1.6b",
    family="rwkv",
    n_layers=24,
    d_model=2048,
    n_heads=32,                 # wkv heads = d_model / rwkv.head_dim
    n_kv_heads=32,
    head_dim=64,
    d_ff=7168,
    vocab_size=65536,
    layer_pattern=("rwkv",),
    rwkv=RWKVConfig(head_dim=64, chunk=128, ffn_mult=3.5),
    mlp_gated=False,            # rwkv channel-mix is its own 2-matrix block
    mlp_act="relu_sq",
    remat="full",
    n_microbatches=2,
    attention_sharding="heads",  # 32 wkv heads / 16
)
