"""Architecture registry.

``get_config(arch_id)`` resolves the public ``--arch`` ids (dashed, as given
in the assignment) to :class:`repro.configs.base.ModelConfig` instances.
``SHAPES`` / ``get_shape`` resolve the input-shape cells.  ``grid()``
enumerates the full (architecture x shape) assignment grid together with the
applicability rule for each cell.

The paper's own workload — DeepBench RNN serving — is configured via
``DEEPBENCH_TASKS`` (consumed by :mod:`repro.core` and the benchmarks); the
RNN cell is not an LM architecture and lives outside the LM shape grid.
"""

from __future__ import annotations

import dataclasses
from typing import Dict, Iterator, Optional, Tuple

from repro.configs.base import (
    ModelConfig,
    ShapeSpec,
    SHAPES,
    TRAIN_4K,
    PREFILL_32K,
    DECODE_32K,
    LONG_500K,
)
from repro.plan.plan import ServingPlan, WorkloadProfile

from repro.configs import (  # noqa: E402  (import the arch modules)
    qwen2_5_14b,
    gemma2_9b,
    gemma3_12b,
    starcoder2_15b,
    whisper_tiny,
    rwkv6_1_6b,
    qwen2_vl_2b,
    granite_moe_1b,
    qwen3_moe_30b,
    hymba_1_5b,
)

ARCHS: Dict[str, ModelConfig] = {
    m.CONFIG.name: m.CONFIG
    for m in (
        qwen2_5_14b,
        gemma2_9b,
        gemma3_12b,
        starcoder2_15b,
        whisper_tiny,
        rwkv6_1_6b,
        qwen2_vl_2b,
        granite_moe_1b,
        qwen3_moe_30b,
        hymba_1_5b,
    )
}


def get_config(arch: str) -> ModelConfig:
    if arch not in ARCHS:
        raise KeyError(f"unknown arch {arch!r}; known: {sorted(ARCHS)}")
    return ARCHS[arch]


def get_shape(name: str) -> ShapeSpec:
    if name not in SHAPES:
        raise KeyError(f"unknown shape {name!r}; known: {sorted(SHAPES)}")
    return SHAPES[name]


def grid() -> Iterator[Tuple[ModelConfig, ShapeSpec, bool, str]]:
    """All 40 (arch x shape) cells with (runs, skip_reason)."""
    for cfg in ARCHS.values():
        for shape in SHAPES.values():
            runs, reason = cfg.runs_shape(shape)
            yield cfg, shape, runs, reason


# ---------------------------------------------------------------------------
# The paper's own benchmark: Baidu DeepBench RNN inference tasks (Table 6).
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class DeepBenchTask:
    cell: str            # "lstm" | "gru"
    hidden: int          # H (== input features D in DeepBench)
    timesteps: int       # T
    # Paper-reported latencies in ms (Table 6) for comparison columns.
    ms_cpu: float = 0.0
    ms_v100: float = 0.0
    ms_brainwave: float = 0.0
    ms_plasticine: float = 0.0

    @property
    def name(self) -> str:
        return f"{self.cell}-h{self.hidden}-t{self.timesteps}"


DEEPBENCH_TASKS = (
    DeepBenchTask("lstm", 256, 150, 15.75, 1.69, 0.425, 0.0419),
    DeepBenchTask("lstm", 512, 25, 11.50, 0.60, 0.077, 0.0139),
    DeepBenchTask("lstm", 1024, 25, 107.65, 0.71, 0.074, 0.0292),
    DeepBenchTask("lstm", 1536, 50, 411.00, 4.38, 0.145, 0.1224),
    DeepBenchTask("lstm", 2048, 25, 429.36, 1.55, 0.074, 0.1060),
    DeepBenchTask("gru", 512, 1, 0.91, 0.39, 0.013, 0.0004),
    DeepBenchTask("gru", 1024, 1500, 3810.00, 33.77, 3.792, 1.4430),
    DeepBenchTask("gru", 1536, 375, 2730.00, 13.12, 0.951, 0.7463),
    DeepBenchTask("gru", 2048, 375, 5040.00, 17.70, 0.954, 1.2833),
    DeepBenchTask("gru", 2560, 375, 7590.00, 23.57, 0.993, 1.9733),
)


# ---------------------------------------------------------------------------
# Serving-load sweep: the asynchronous-arrival serving benchmark's grid.
# ---------------------------------------------------------------------------


class ServingLoadCell:
    """One cell of the serving-load benchmark (benchmarks/serving_load.py):
    a *design point* (:class:`repro.plan.ServingPlan`) serving a
    *workload* (:class:`repro.plan.WorkloadProfile`).  ``family`` tags the
    model class so the benchmark provably spans dense / MoE / RWKV; an
    optional ``tag`` marks derived cells (e.g. the autotuned variant).

    A cell *is* ``(family, plan, workload, tag)``.  The historical
    constructor signature — ``ServingLoadCell(arch, family, max_batch,
    rate, policy=..., prompt_dist=..., ...)`` — is accepted via a
    converter that assembles the plan and profile from those field names,
    and the historical attributes remain readable as properties, so every
    pre-existing cell keeps its exact name (and, on the virtual clock,
    its exact ``metrics`` block) while new cells can be built directly
    from a plan (``ServingLoadCell(family=..., plan=..., workload=...)``).
    """

    # the benchmark's historical per-cell constants, now recorded in the
    # cell's plan/profile instead of hardcoded in run_cell
    MAX_LEN = 64
    PROMPT_LEN = (4, 12)
    MAX_NEW = (6, 10)

    def __init__(self, arch: Optional[str] = None, family: str = "",
                 max_batch: Optional[int] = None,
                 rate: Optional[float] = None, *,
                 policy: str = "fcfs", preempt: bool = False,
                 cache_layout: str = "dense",
                 prompt_dist: str = "uniform",
                 heavy_decode: Optional[Tuple[float, int, int]] = None,
                 deadline_slack: Optional[float] = None,
                 duration: Optional[float] = None,
                 plan: Optional["ServingPlan"] = None,
                 workload: Optional["WorkloadProfile"] = None,
                 tag: str = ""):
        if plan is None:
            if arch is None or max_batch is None:
                raise ValueError("ServingLoadCell needs (arch, max_batch) "
                                 "or an explicit plan")
            plan = ServingPlan(arch=arch, max_batch=max_batch,
                               max_len=self.MAX_LEN, policy=policy,
                               preempt=preempt, cache_layout=cache_layout)
        if workload is None:
            if rate is None:
                raise ValueError("ServingLoadCell needs rate or an "
                                 "explicit workload profile")
            workload = WorkloadProfile(
                kind="poisson", rate=rate, duration=duration,
                prompt_len=self.PROMPT_LEN, max_new_tokens=self.MAX_NEW,
                prompt_dist=prompt_dist,
                prompt_len_long=plan.max_len - 1,
                heavy_decode=heavy_decode, deadline_slack=deadline_slack)
        self.family = family
        self.plan = plan
        self.workload = workload
        self.tag = tag

    # ----------------------------------------------- historical field names
    @property
    def arch(self) -> str:
        return self.plan.arch

    @property
    def max_batch(self) -> int:
        return self.plan.max_batch

    @property
    def policy(self) -> str:
        return self.plan.policy

    @property
    def preempt(self) -> bool:
        return self.plan.preempt

    @property
    def cache_layout(self) -> str:
        return self.plan.cache_layout

    @property
    def rate(self) -> float:
        return self.workload.rate

    @property
    def prompt_dist(self) -> str:
        return self.workload.prompt_dist

    @property
    def heavy_decode(self) -> Optional[Tuple[float, int, int]]:
        return self.workload.heavy_decode

    @property
    def deadline_slack(self) -> Optional[float]:
        return self.workload.deadline_slack

    @property
    def duration(self) -> Optional[float]:
        return self.workload.duration

    def with_duration(self, duration: float) -> "ServingLoadCell":
        """A copy with the workload span replaced (smoke runs)."""
        return ServingLoadCell(
            family=self.family, plan=self.plan, tag=self.tag,
            workload=dataclasses.replace(self.workload, duration=duration))

    def __eq__(self, other) -> bool:
        return (isinstance(other, ServingLoadCell)
                and (self.family, self.plan, self.workload, self.tag)
                == (other.family, other.plan, other.workload, other.tag))

    def __hash__(self) -> int:
        # plans carry dict fields (tile_plans/provenance), so hash the
        # stable identity subset; eq-equal cells agree on all of these
        return hash((self.family, self.tag, self.name))

    def __repr__(self) -> str:
        return (f"ServingLoadCell({self.name!r}, family={self.family!r}, "
                f"plan={self.plan.summary()!r})")

    @property
    def name(self) -> str:
        n = f"{self.arch}/b{self.max_batch}/r{self.rate:g}"
        if self.prompt_dist != "uniform":
            n += f"/{self.prompt_dist}"
        if self.heavy_decode is not None:
            n += "/heavy"
        if self.policy != "fcfs" or self.preempt:
            n += f"/{self.policy}" + ("+p" if self.preempt else "")
        if self.cache_layout != "dense":
            # "paged:16" -> "paged16" (cell names double as file-safe keys)
            n += "/" + self.cache_layout.replace(":", "")
        if self.tag:
            n += f"/{self.tag}"
        return n


# One under-loaded and one saturating rate per (arch, max_batch): the
# benchmark's requests average ~16 tokens (prompt 4-12 + 6-10 new), so
# rate 0.1 offers ~1.6 tok/unit — under even max_batch=2's 2-tokens/tick
# ceiling (empty-queue regime) — while rate 1.0 offers ~16, past
# max_batch=4's ceiling (queue-growth regime).
_SERVING_BASE_GRID: Tuple[ServingLoadCell, ...] = tuple(
    ServingLoadCell(arch, family, mb, rate)
    for arch, family in (("qwen2.5-14b", "dense"),
                         ("qwen3-moe-30b-a3b", "moe"),
                         ("rwkv6-1.6b", "rwkv"))
    for mb in (2, 4)
    for rate in (0.1, 1.0)
)

# Prompt-length-distribution sweep (ROADMAP "Next"): the saturating RWKV
# cell re-served under fixed / lognormal / bimodal prompt lengths.
_SERVING_PROMPT_DIST_GRID: Tuple[ServingLoadCell, ...] = tuple(
    ServingLoadCell("rwkv6-1.6b", "rwkv", 4, 1.0, prompt_dist=dist)
    for dist in ("fixed", "lognormal", "bimodal")
)

# Overload scenario: offered slot-ticks exceed capacity (rate 0.8 x mean
# ~9.3 decode ticks vs 4 slots ~ 1.9x overload) and 3% of requests are
# heavy-decode jobs that hog a slot for 32-48 ticks — the long-tail
# service mixture where scheduling policy decides the latency tail.
# Every request carries the decode-proportional deadline
# arrival + 3 * max_new ticks.  The same seeded workload runs under
# FCFS, EDF, and preemptive EDF, so the cells isolate exactly what the
# policy buys: EDF stops tight-deadline shorts from queueing behind
# heavies (p95 TTFT drops vs FCFS), and +preempt additionally evicts a
# running heavy to host the moment a tighter deadline arrives.
OVERLOAD_DEADLINE_SLACK = 3.0
OVERLOAD_HEAVY_DECODE = (0.03, 32, 48)
_SERVING_OVERLOAD_GRID: Tuple[ServingLoadCell, ...] = tuple(
    ServingLoadCell("rwkv6-1.6b", "rwkv", 4, 0.8, policy=policy,
                    preempt=preempt, heavy_decode=OVERLOAD_HEAVY_DECODE,
                    deadline_slack=OVERLOAD_DEADLINE_SLACK, duration=128.0)
    for policy, preempt in (("fcfs", False), ("edf", False), ("edf", True))
)

# Paged-layout cells (PR 7).  The first is a byte-exact *twin* of the
# committed dense qwen2.5-14b/b4/r1.0 base cell: same plan except
# cache_layout, so its committed ``metrics`` block must equal the dense
# twin's exactly (the bit-exactness contract, pinned by
# tests/test_serving_load.py).  The next two are the capacity story: the
# same saturating arrival rate under heavy-tail prompt distributions,
# served with *doubled* admission capacity (8 slots) — affordable because
# the paged pool bounds resident bytes by blocks actually covered instead
# of max_batch x max_len columns (benchmarks/fig4_fragmentation.py
# records the before/after byte trajectory).  On the virtual clock these
# are directly comparable to the b4 prompt-dist cells above: queue waits
# collapse because admission, not arithmetic, was the bottleneck.
PAGED_BLOCK = 16
_SERVING_PAGED_GRID: Tuple[ServingLoadCell, ...] = tuple(
    [ServingLoadCell("qwen2.5-14b", "dense", 4, 1.0,
                     cache_layout=f"paged:{PAGED_BLOCK}")]
    + [ServingLoadCell("qwen2.5-14b", "dense", 8, 1.0, prompt_dist=dist,
                       cache_layout=f"paged:{PAGED_BLOCK}")
       for dist in ("lognormal", "bimodal")]
)

SERVING_LOAD_SWEEP: Tuple[ServingLoadCell, ...] = (
    _SERVING_BASE_GRID + _SERVING_PROMPT_DIST_GRID + _SERVING_OVERLOAD_GRID
    + _SERVING_PAGED_GRID
)


# ---------------------------------------------------------------------------
# Fleet serving sweep: the multi-replica router benchmark's grid (PR 10).
# ---------------------------------------------------------------------------


class FleetLoadCell:
    """One cell of the *fleet* section of the serving-load benchmark: a
    :class:`repro.plan.FleetPlan` (N engine replicas behind the router,
    optionally disaggregated into prefill and decode roles) serving a
    :class:`repro.plan.WorkloadProfile` on one shared virtual clock.

    Fleet cells live under the separate ``fleet`` key of
    BENCH_serving.json — the single-replica ``cells`` grid above is the
    stable trajectory history and its document shape never changes."""

    def __init__(self, family: str, fleet: "FleetPlan",
                 workload: "WorkloadProfile", tag: str = ""):
        self.family = family
        self.fleet = fleet
        self.workload = workload
        self.tag = tag

    @property
    def name(self) -> str:
        ref = self.fleet.replicas[0]
        n = (f"fleet/{ref.arch}/x{self.fleet.n_replicas}"
             f"b{ref.max_batch}/{self.fleet.routing}")
        if self.fleet.n_prefill:
            n += f"/p{self.fleet.n_prefill}"
        n += f"/r{self.workload.rate:g}"
        if self.tag:
            n += f"/{self.tag}"
        return n

    def __eq__(self, other) -> bool:
        return (isinstance(other, FleetLoadCell)
                and (self.family, self.fleet, self.workload, self.tag)
                == (other.family, other.fleet, other.workload, other.tag))

    def __repr__(self) -> str:
        return (f"FleetLoadCell({self.name!r}, family={self.family!r}, "
                f"fleet={self.fleet.summary()!r})")


def _fleet_sweep() -> Tuple[FleetLoadCell, ...]:
    """The committed fleet grid.  Three scenarios:

    * ``twin`` — a 1-replica colocated fleet serving the committed
      rwkv6-1.6b/b2/r1.0 base cell's exact plan + workload: its metrics
      block must be byte-identical to that bare-engine cell
      (single-replica fleet == bare engine, pinned by
      tests/test_router.py);
    * ``capacity`` — the overload workload (deadlines + heavy-decode
      tail, ~2.8x one replica's slot-tick capacity) served by 1, 2, and
      4 colocated replicas under least_queue: the 1->2 step must buy
      >= 1.8x SLO-met served tokens and lift attainment to >= 0.95
      (ISSUE 10 acceptance);
    * ``disagg`` — a heavy-tail (bimodal prompts) deadline workload
      served by a 3-replica colocated edf+preempt fleet vs its
      disaggregated twin (1 prefill + 2 decode): disaggregation must
      improve p99 TTFT without regressing p99 TPOT.
    """
    from repro.plan.plan import FleetPlan

    base_b2 = ServingLoadCell("rwkv6-1.6b", "rwkv", 2, 1.0)
    twin = FleetLoadCell(
        "rwkv", FleetPlan.replicated(base_b2.plan, 1), base_b2.workload,
        tag="twin")

    # ~0.75 req/unit x ~9.3 mean slot-ticks ~= 7 offered slot-ticks per
    # tick: 1.75x one b4 replica (overload: the admission queue grows ~3
    # slot-ticks/tick, so past the first ~35 units every request blows
    # its arrival + 3*max_new deadline), 0.87x two replicas (inside SLO;
    # measured attainment 1.0), 0.44x four (headroom — the scaling
    # curve's flat end).  The 192-unit span gives the 1-replica backlog
    # time to compound, which is exactly the capacity story: ratio of
    # SLO-met served tokens 1 -> 2 replicas measured at ~2.7x.
    cap_plan = ServingPlan(arch="rwkv6-1.6b", max_batch=4,
                           max_len=ServingLoadCell.MAX_LEN)
    cap_workload = WorkloadProfile(
        kind="poisson", rate=0.75, duration=192.0,
        prompt_len=ServingLoadCell.PROMPT_LEN,
        max_new_tokens=ServingLoadCell.MAX_NEW,
        prompt_len_long=ServingLoadCell.MAX_LEN - 1,
        heavy_decode=OVERLOAD_HEAVY_DECODE,
        deadline_slack=OVERLOAD_DEADLINE_SLACK)
    capacity = tuple(
        FleetLoadCell("rwkv",
                      FleetPlan.replicated(cap_plan, n,
                                           routing="least_queue"),
                      cap_workload, tag="capacity")
        for n in (1, 2, 4))

    # Disaggregated twins: four replicas each way.  Colocated runs all
    # four as edf+preempt engines (the overload grid's best policy for
    # protecting TTFT); disaggregated dedicates one b4 replica to
    # admission/prefill and runs three b8 decode replicas — decode-only
    # engines never allocate prompt prefill buffers (bucketed length-64
    # activations), and an RNN/SSM slot is an O(1) state column, so the
    # freed memory hosts double the slots.  Under a ~1.4x-overloaded
    # heavy-tail mix the colocated fleet queues at admission (TTFT tail)
    # and preemption stretches its TPOT tail, while the prefill tier
    # admits instantly and hands decode to an unsaturated tier: p99 TTFT
    # ~10x better with p99 TPOT also better (the acceptance pair).
    dis_workload = WorkloadProfile(
        kind="poisson", rate=1.9, duration=128.0,
        prompt_len=ServingLoadCell.PROMPT_LEN,
        max_new_tokens=(6, 16),
        prompt_len_long=ServingLoadCell.MAX_LEN - 1,
        heavy_decode=(0.03, 32, 48),
        deadline_slack=OVERLOAD_DEADLINE_SLACK)
    colo_plan = ServingPlan(arch="rwkv6-1.6b", max_batch=4,
                            max_len=ServingLoadCell.MAX_LEN,
                            policy="edf", preempt=True)
    pre_plan = ServingPlan(arch="rwkv6-1.6b", max_batch=4,
                           max_len=ServingLoadCell.MAX_LEN)
    dec_plan = ServingPlan(arch="rwkv6-1.6b", max_batch=8,
                           max_len=ServingLoadCell.MAX_LEN)
    disagg = (
        FleetLoadCell("rwkv", FleetPlan.replicated(colo_plan, 4,
                                                   routing="least_queue"),
                      dis_workload, tag="colocated"),
        FleetLoadCell("rwkv",
                      FleetPlan(replicas=(pre_plan, dec_plan, dec_plan,
                                          dec_plan),
                                routing="least_queue", n_prefill=1),
                      dis_workload, tag="disagg"),
    )
    return (twin,) + capacity + disagg


FLEET_SERVING_SWEEP: Tuple[FleetLoadCell, ...] = _fleet_sweep()
