"""Architecture registry.

``get_config(arch_id)`` resolves the public ``--arch`` ids (dashed, as given
in the assignment) to :class:`repro.configs.base.ModelConfig` instances.
``SHAPES`` / ``get_shape`` resolve the input-shape cells.  ``grid()``
enumerates the full (architecture x shape) assignment grid together with the
applicability rule for each cell.

The paper's own workload — DeepBench RNN serving — is configured via
``DEEPBENCH_TASKS`` (consumed by :mod:`repro.core` and the benchmarks); the
RNN cell is not an LM architecture and lives outside the LM shape grid.
"""

from __future__ import annotations

import dataclasses
from typing import Dict, Iterator, Tuple

from repro.configs.base import (
    ModelConfig,
    ShapeSpec,
    SHAPES,
    TRAIN_4K,
    PREFILL_32K,
    DECODE_32K,
    LONG_500K,
)

from repro.configs import (  # noqa: E402  (import the arch modules)
    qwen2_5_14b,
    gemma2_9b,
    gemma3_12b,
    starcoder2_15b,
    whisper_tiny,
    rwkv6_1_6b,
    qwen2_vl_2b,
    granite_moe_1b,
    qwen3_moe_30b,
    hymba_1_5b,
)

ARCHS: Dict[str, ModelConfig] = {
    m.CONFIG.name: m.CONFIG
    for m in (
        qwen2_5_14b,
        gemma2_9b,
        gemma3_12b,
        starcoder2_15b,
        whisper_tiny,
        rwkv6_1_6b,
        qwen2_vl_2b,
        granite_moe_1b,
        qwen3_moe_30b,
        hymba_1_5b,
    )
}


def get_config(arch: str) -> ModelConfig:
    if arch not in ARCHS:
        raise KeyError(f"unknown arch {arch!r}; known: {sorted(ARCHS)}")
    return ARCHS[arch]


def get_shape(name: str) -> ShapeSpec:
    if name not in SHAPES:
        raise KeyError(f"unknown shape {name!r}; known: {sorted(SHAPES)}")
    return SHAPES[name]


def grid() -> Iterator[Tuple[ModelConfig, ShapeSpec, bool, str]]:
    """All 40 (arch x shape) cells with (runs, skip_reason)."""
    for cfg in ARCHS.values():
        for shape in SHAPES.values():
            runs, reason = cfg.runs_shape(shape)
            yield cfg, shape, runs, reason


# ---------------------------------------------------------------------------
# The paper's own benchmark: Baidu DeepBench RNN inference tasks (Table 6).
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class DeepBenchTask:
    cell: str            # "lstm" | "gru"
    hidden: int          # H (== input features D in DeepBench)
    timesteps: int       # T
    # Paper-reported latencies in ms (Table 6) for comparison columns.
    ms_cpu: float = 0.0
    ms_v100: float = 0.0
    ms_brainwave: float = 0.0
    ms_plasticine: float = 0.0

    @property
    def name(self) -> str:
        return f"{self.cell}-h{self.hidden}-t{self.timesteps}"


DEEPBENCH_TASKS = (
    DeepBenchTask("lstm", 256, 150, 15.75, 1.69, 0.425, 0.0419),
    DeepBenchTask("lstm", 512, 25, 11.50, 0.60, 0.077, 0.0139),
    DeepBenchTask("lstm", 1024, 25, 107.65, 0.71, 0.074, 0.0292),
    DeepBenchTask("lstm", 1536, 50, 411.00, 4.38, 0.145, 0.1224),
    DeepBenchTask("lstm", 2048, 25, 429.36, 1.55, 0.074, 0.1060),
    DeepBenchTask("gru", 512, 1, 0.91, 0.39, 0.013, 0.0004),
    DeepBenchTask("gru", 1024, 1500, 3810.00, 33.77, 3.792, 1.4430),
    DeepBenchTask("gru", 1536, 375, 2730.00, 13.12, 0.951, 0.7463),
    DeepBenchTask("gru", 2048, 375, 5040.00, 17.70, 0.954, 1.2833),
    DeepBenchTask("gru", 2560, 375, 7590.00, 23.57, 0.993, 1.9733),
)


# ---------------------------------------------------------------------------
# Serving-load sweep: the asynchronous-arrival serving benchmark's grid.
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class ServingLoadCell:
    """One cell of the serving-load benchmark (benchmarks/serving_load.py):
    an architecture served at ``max_batch`` slots under Poisson arrivals at
    ``rate`` requests per clock unit.  ``family`` tags the model class so
    the benchmark provably spans dense / MoE / RWKV."""

    arch: str
    family: str          # "dense" | "moe" | "rwkv"
    max_batch: int
    rate: float

    @property
    def name(self) -> str:
        return f"{self.arch}/b{self.max_batch}/r{self.rate:g}"


# One under-loaded and one saturating rate per (arch, max_batch): the
# benchmark's requests average ~16 tokens (prompt 4-12 + 6-10 new), so
# rate 0.1 offers ~1.6 tok/unit — under even max_batch=2's 2-tokens/tick
# ceiling (empty-queue regime) — while rate 1.0 offers ~16, past
# max_batch=4's ceiling (queue-growth regime).
SERVING_LOAD_SWEEP: Tuple[ServingLoadCell, ...] = tuple(
    ServingLoadCell(arch, family, mb, rate)
    for arch, family in (("qwen2.5-14b", "dense"),
                         ("qwen3-moe-30b-a3b", "moe"),
                         ("rwkv6-1.6b", "rwkv"))
    for mb in (2, 4)
    for rate in (0.1, 1.0)
)
