"""Architecture registry.

``get_config(arch_id)`` resolves the public ``--arch`` ids (dashed, as given
in the assignment) to :class:`repro.configs.base.ModelConfig` instances.
``SHAPES`` / ``get_shape`` resolve the input-shape cells.  ``grid()``
enumerates the full (architecture x shape) assignment grid together with the
applicability rule for each cell.

The paper's own workload — DeepBench RNN serving — is configured via
``DEEPBENCH_TASKS`` (consumed by :mod:`repro.core` and the benchmarks); the
RNN cell is not an LM architecture and lives outside the LM shape grid.
"""

from __future__ import annotations

import dataclasses
from typing import Dict, Iterator, Optional, Tuple

from repro.configs.base import (
    ModelConfig,
    ShapeSpec,
    SHAPES,
    TRAIN_4K,
    PREFILL_32K,
    DECODE_32K,
    LONG_500K,
)

from repro.configs import (  # noqa: E402  (import the arch modules)
    qwen2_5_14b,
    gemma2_9b,
    gemma3_12b,
    starcoder2_15b,
    whisper_tiny,
    rwkv6_1_6b,
    qwen2_vl_2b,
    granite_moe_1b,
    qwen3_moe_30b,
    hymba_1_5b,
)

ARCHS: Dict[str, ModelConfig] = {
    m.CONFIG.name: m.CONFIG
    for m in (
        qwen2_5_14b,
        gemma2_9b,
        gemma3_12b,
        starcoder2_15b,
        whisper_tiny,
        rwkv6_1_6b,
        qwen2_vl_2b,
        granite_moe_1b,
        qwen3_moe_30b,
        hymba_1_5b,
    )
}


def get_config(arch: str) -> ModelConfig:
    if arch not in ARCHS:
        raise KeyError(f"unknown arch {arch!r}; known: {sorted(ARCHS)}")
    return ARCHS[arch]


def get_shape(name: str) -> ShapeSpec:
    if name not in SHAPES:
        raise KeyError(f"unknown shape {name!r}; known: {sorted(SHAPES)}")
    return SHAPES[name]


def grid() -> Iterator[Tuple[ModelConfig, ShapeSpec, bool, str]]:
    """All 40 (arch x shape) cells with (runs, skip_reason)."""
    for cfg in ARCHS.values():
        for shape in SHAPES.values():
            runs, reason = cfg.runs_shape(shape)
            yield cfg, shape, runs, reason


# ---------------------------------------------------------------------------
# The paper's own benchmark: Baidu DeepBench RNN inference tasks (Table 6).
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class DeepBenchTask:
    cell: str            # "lstm" | "gru"
    hidden: int          # H (== input features D in DeepBench)
    timesteps: int       # T
    # Paper-reported latencies in ms (Table 6) for comparison columns.
    ms_cpu: float = 0.0
    ms_v100: float = 0.0
    ms_brainwave: float = 0.0
    ms_plasticine: float = 0.0

    @property
    def name(self) -> str:
        return f"{self.cell}-h{self.hidden}-t{self.timesteps}"


DEEPBENCH_TASKS = (
    DeepBenchTask("lstm", 256, 150, 15.75, 1.69, 0.425, 0.0419),
    DeepBenchTask("lstm", 512, 25, 11.50, 0.60, 0.077, 0.0139),
    DeepBenchTask("lstm", 1024, 25, 107.65, 0.71, 0.074, 0.0292),
    DeepBenchTask("lstm", 1536, 50, 411.00, 4.38, 0.145, 0.1224),
    DeepBenchTask("lstm", 2048, 25, 429.36, 1.55, 0.074, 0.1060),
    DeepBenchTask("gru", 512, 1, 0.91, 0.39, 0.013, 0.0004),
    DeepBenchTask("gru", 1024, 1500, 3810.00, 33.77, 3.792, 1.4430),
    DeepBenchTask("gru", 1536, 375, 2730.00, 13.12, 0.951, 0.7463),
    DeepBenchTask("gru", 2048, 375, 5040.00, 17.70, 0.954, 1.2833),
    DeepBenchTask("gru", 2560, 375, 7590.00, 23.57, 0.993, 1.9733),
)


# ---------------------------------------------------------------------------
# Serving-load sweep: the asynchronous-arrival serving benchmark's grid.
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class ServingLoadCell:
    """One cell of the serving-load benchmark (benchmarks/serving_load.py):
    an architecture served at ``max_batch`` slots under Poisson arrivals at
    ``rate`` requests per clock unit.  ``family`` tags the model class so
    the benchmark provably spans dense / MoE / RWKV.

    The scheduling dimensions (``policy`` / ``preempt`` /
    ``deadline_slack``) and the prompt-length distribution default to the
    original grid's values, and :attr:`name` only appends suffixes for
    non-default settings — so every pre-existing cell keeps its exact
    historical name (and, on the virtual clock, its exact ``metrics``
    block) while the overload / prompt-distribution cells appear as new
    rows in ``BENCH_serving.json``."""

    arch: str
    family: str          # "dense" | "moe" | "rwkv"
    max_batch: int
    rate: float
    policy: str = "fcfs"             # scheduler registry key
    preempt: bool = False            # EDF evict-to-host preemption
    prompt_dist: str = "uniform"     # workload.PROMPT_DISTS
    # (frac, lo, hi): seeded frac of requests decode lo..hi tokens — the
    # long-tail service-time mixture (slot occupancy = decode ticks)
    heavy_decode: Optional[Tuple[float, int, int]] = None
    deadline_slack: Optional[float] = None   # decode-proportional SLO
    duration: Optional[float] = None         # override the sweep default

    @property
    def name(self) -> str:
        n = f"{self.arch}/b{self.max_batch}/r{self.rate:g}"
        if self.prompt_dist != "uniform":
            n += f"/{self.prompt_dist}"
        if self.heavy_decode is not None:
            n += "/heavy"
        if self.policy != "fcfs" or self.preempt:
            n += f"/{self.policy}" + ("+p" if self.preempt else "")
        return n


# One under-loaded and one saturating rate per (arch, max_batch): the
# benchmark's requests average ~16 tokens (prompt 4-12 + 6-10 new), so
# rate 0.1 offers ~1.6 tok/unit — under even max_batch=2's 2-tokens/tick
# ceiling (empty-queue regime) — while rate 1.0 offers ~16, past
# max_batch=4's ceiling (queue-growth regime).
_SERVING_BASE_GRID: Tuple[ServingLoadCell, ...] = tuple(
    ServingLoadCell(arch, family, mb, rate)
    for arch, family in (("qwen2.5-14b", "dense"),
                         ("qwen3-moe-30b-a3b", "moe"),
                         ("rwkv6-1.6b", "rwkv"))
    for mb in (2, 4)
    for rate in (0.1, 1.0)
)

# Prompt-length-distribution sweep (ROADMAP "Next"): the saturating RWKV
# cell re-served under fixed / lognormal / bimodal prompt lengths.
_SERVING_PROMPT_DIST_GRID: Tuple[ServingLoadCell, ...] = tuple(
    ServingLoadCell("rwkv6-1.6b", "rwkv", 4, 1.0, prompt_dist=dist)
    for dist in ("fixed", "lognormal", "bimodal")
)

# Overload scenario: offered slot-ticks exceed capacity (rate 0.8 x mean
# ~9.3 decode ticks vs 4 slots ~ 1.9x overload) and 3% of requests are
# heavy-decode jobs that hog a slot for 32-48 ticks — the long-tail
# service mixture where scheduling policy decides the latency tail.
# Every request carries the decode-proportional deadline
# arrival + 3 * max_new ticks.  The same seeded workload runs under
# FCFS, EDF, and preemptive EDF, so the cells isolate exactly what the
# policy buys: EDF stops tight-deadline shorts from queueing behind
# heavies (p95 TTFT drops vs FCFS), and +preempt additionally evicts a
# running heavy to host the moment a tighter deadline arrives.
OVERLOAD_DEADLINE_SLACK = 3.0
OVERLOAD_HEAVY_DECODE = (0.03, 32, 48)
_SERVING_OVERLOAD_GRID: Tuple[ServingLoadCell, ...] = tuple(
    ServingLoadCell("rwkv6-1.6b", "rwkv", 4, 0.8, policy=policy,
                    preempt=preempt, heavy_decode=OVERLOAD_HEAVY_DECODE,
                    deadline_slack=OVERLOAD_DEADLINE_SLACK, duration=128.0)
    for policy, preempt in (("fcfs", False), ("edf", False), ("edf", True))
)

SERVING_LOAD_SWEEP: Tuple[ServingLoadCell, ...] = (
    _SERVING_BASE_GRID + _SERVING_PROMPT_DIST_GRID + _SERVING_OVERLOAD_GRID
)
