"""Qwen3-30B-A3B  [moe]  48L d_model=2048 32H (GQA kv=4) d_ff=768,
MoE 128 experts top-8.  [hf:Qwen/Qwen3-30B-A3B]

30.5B total / ~3.3B active params.  128 experts shard 8-per-device over the
model axis; expert weights additionally FSDP-shard over the data axis so
params + Adam state fit 16 GB/chip at train_4k.  QK-norm, head_dim 128.
"""

from repro.configs.base import ModelConfig, MoEConfig

CONFIG = ModelConfig(
    name="qwen3-moe-30b-a3b",
    family="moe",
    n_layers=48,
    d_model=2048,
    n_heads=32,
    n_kv_heads=4,
    head_dim=128,
    d_ff=768,
    vocab_size=151936,
    rope_theta=1e6,
    qk_norm=True,
    layer_pattern=("attn",),
    moe=MoEConfig(n_experts=128, top_k=8, capacity_factor=1.25, group_size=512),
    fsdp=True,
    remat="full",
    n_microbatches=8,
    attention_sharding="heads",
)
