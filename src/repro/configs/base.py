"""Configuration dataclasses for models, input shapes, and runs.

Every assigned architecture is a :class:`ModelConfig` in its own module under
``repro.configs``; the registry in ``repro.configs.__init__`` maps the public
``--arch`` ids onto them.  Shapes (the four assigned input-shape cells) are
:class:`ShapeSpec` instances shared by all LM-family architectures.
"""

from __future__ import annotations

import dataclasses
import math
from typing import Optional, Sequence, Tuple

# ---------------------------------------------------------------------------
# Input shapes
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class ShapeSpec:
    """One input-shape cell from the assignment grid."""

    name: str
    seq_len: int
    global_batch: int
    mode: str  # "train" | "prefill" | "decode"

    @property
    def tokens(self) -> int:
        return self.seq_len * self.global_batch


TRAIN_4K = ShapeSpec("train_4k", 4_096, 256, "train")
PREFILL_32K = ShapeSpec("prefill_32k", 32_768, 32, "prefill")
DECODE_32K = ShapeSpec("decode_32k", 32_768, 128, "decode")
LONG_500K = ShapeSpec("long_500k", 524_288, 1, "decode")

SHAPES = {s.name: s for s in (TRAIN_4K, PREFILL_32K, DECODE_32K, LONG_500K)}


# ---------------------------------------------------------------------------
# Model configuration
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class MoEConfig:
    n_experts: int
    top_k: int
    capacity_factor: float = 1.25
    # Token group size for GShard-style dispatch; capacity is computed per
    # group so the one-hot dispatch tensors stay bounded.
    group_size: int = 512
    router_aux_coef: float = 0.01


@dataclasses.dataclass(frozen=True)
class SSMConfig:
    """Mamba2/SSD-style selective state space head block (see DESIGN.md for
    the adaptation from Mamba1's per-(channel, state) decay to SSD's
    per-head scalar decay, which admits a TPU-friendly chunked form)."""

    d_state: int = 16
    expand: int = 2
    head_dim: int = 64
    conv_width: int = 4
    chunk: int = 128


@dataclasses.dataclass(frozen=True)
class RWKVConfig:
    head_dim: int = 64          # key/value dim per wkv head
    chunk: int = 128            # chunked-recurrence block length
    ffn_mult: float = 3.5


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    """A single architecture.

    ``layer_pattern`` gives one *period* of the layer stack; the stack is
    ``layer_pattern * (n_layers // len(layer_pattern))``.  Scanning over the
    layer stack happens at period granularity so heterogeneous stacks
    (gemma2 local/global alternation, gemma3 5:1, hymba) still admit stacked
    parameters.
    Entries: "attn" (global), "local" (sliding window), "swa_ssm"
    (parallel sliding-window attention + SSM heads, hymba), "rwkv".
    """

    name: str
    family: str                 # dense | moe | rwkv | hybrid | encdec | vlm
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab_size: int
    head_dim: int = 0           # 0 -> d_model // n_heads
    layer_pattern: Tuple[str, ...] = ("attn",)

    # --- attention flavour -------------------------------------------------
    qkv_bias: bool = False
    rope_theta: float = 10_000.0
    local_window: int = 0               # sliding-window size for "local"
    attn_softcap: float = 0.0           # gemma2 logit soft-capping
    final_softcap: float = 0.0          # gemma2 final-logit soft-capping
    qk_norm: bool = False               # gemma3 / qwen3 style
    m_rope_sections: Tuple[int, ...] = ()  # qwen2-vl M-RoPE (t, h, w) split

    # --- mlp flavour ---------------------------------------------------------
    mlp_gated: bool = True              # 3-matrix gated (llama-style) vs 2-matrix
    mlp_act: str = "silu"               # silu | gelu | relu_sq

    # --- mixture of experts -------------------------------------------------
    moe: Optional[MoEConfig] = None

    # --- recurrent families --------------------------------------------------
    ssm: Optional[SSMConfig] = None
    rwkv: Optional[RWKVConfig] = None

    # --- encoder/decoder (whisper) -------------------------------------------
    n_encoder_layers: int = 0
    encoder_downsample: int = 1         # conv-frontend stub stride

    # --- embedding / head ----------------------------------------------------
    tie_embeddings: bool = False
    scale_embeddings: bool = False      # gemma multiplies embeds by sqrt(d)
    vocab_pad_to: int = 256             # pad vocab so it shards over the mesh
    norm_eps: float = 1e-6

    # --- execution policy -----------------------------------------------------
    fsdp: bool = False                  # shard params over the data axis too
    remat: str = "full"                 # "none" | "full" | "dots"
    n_microbatches: int = 1             # grad-accumulation steps at train_4k
    attention_sharding: str = "auto"    # "heads" | "qseq" | "auto"
    # FLOPs-efficient attention block size chosen by the DSE when 0.
    attn_block: int = 0
    # --- perf-iteration levers (EXPERIMENTS.md §Perf) -------------------------
    train_tp: bool = True               # False: replicate weights; batch then
                                        # shards over (pod, data, model)
    zero1: bool = False                 # shard ONLY optimizer state over data
    shard_residual_seq: bool = False    # shard the scan carry's seq dim over
                                        # the model axis (sharded remat saves)
    seq_parallel: bool = False          # Megatron-SP: activations stay seq-
                                        # sharded over model through the whole
                                        # layer; attention gathers kv once

    # --- paper-technique hooks --------------------------------------------------
    # int8 weight storage for serving (the paper's mixed-precision scheme:
    # 8-bit storage/multiply, wider accumulate).
    serve_int8: bool = False
    kv_cache_dtype: str = "bf16"        # "bf16" | "int8"

    # ------------------------------------------------------------------ derived
    @property
    def head_dim_(self) -> int:
        return self.head_dim or self.d_model // self.n_heads

    @property
    def q_dim(self) -> int:
        return self.n_heads * self.head_dim_

    @property
    def kv_dim(self) -> int:
        return self.n_kv_heads * self.head_dim_

    @property
    def padded_vocab(self) -> int:
        p = self.vocab_pad_to
        return ((self.vocab_size + p - 1) // p) * p

    @property
    def period(self) -> int:
        return len(self.layer_pattern)

    @property
    def n_periods(self) -> int:
        assert self.n_layers % self.period == 0, (
            f"{self.name}: n_layers={self.n_layers} not divisible by "
            f"layer pattern period {self.period}")
        return self.n_layers // self.period

    @property
    def is_encoder_decoder(self) -> bool:
        return self.n_encoder_layers > 0

    @property
    def sub_quadratic(self) -> bool:
        """True when no layer needs a full-length KV cache *or* the config
        is explicitly long-context by construction.  Used by the shape-grid
        skip rule for ``long_500k`` (see DESIGN.md §Arch-applicability)."""
        kinds = set(self.layer_pattern)
        if kinds <= {"rwkv", "swa_ssm", "local"}:
            return True
        # Mostly-local stacks (gemma2/gemma3) are long-context by design:
        # global layers are a bounded fraction and the local layers cache
        # only their window.
        n_global = sum(1 for k in self.layer_pattern if k == "attn")
        return n_global < len(self.layer_pattern) and self.local_window > 0

    # ---------------------------------------------------------------- counting
    def param_count(self) -> int:
        """Exact parameter count (embedding included once if tied)."""
        d, L = self.d_model, self.n_layers
        total = self.padded_vocab * d  # embedding
        if not self.tie_embeddings:
            total += self.padded_vocab * d  # lm head
        for kind in self.layer_pattern * self.n_periods:
            total += self._block_params(kind)
        if self.is_encoder_decoder:
            # encoder self-attn blocks + decoder cross-attn additions
            total += self.n_encoder_layers * self._block_params("attn")
            total += L * self._attn_params()  # cross attention
        total += d  # final norm
        return total

    def _attn_params(self) -> int:
        d = self.d_model
        p = d * self.q_dim + 2 * d * self.kv_dim + self.q_dim * d
        if self.qkv_bias:
            p += self.q_dim + 2 * self.kv_dim
        return p

    def _mlp_params(self) -> int:
        n_mats = 3 if self.mlp_gated else 2
        return n_mats * self.d_model * self.d_ff

    def _block_params(self, kind: str) -> int:
        d = self.d_model
        norms = 2 * d
        if kind == "rwkv":
            a = self.rwkv or RWKVConfig()
            wkv = d * d * 4 + d * d  # r,k,v,g(+output) projections approx
            wkv += d * d             # w (decay) lora-ish projections
            ffn = 2 * d * int(d * a.ffn_mult)
            return wkv + ffn + norms
        if kind == "swa_ssm":
            s = self.ssm or SSMConfig()
            d_in = d * s.expand
            ssm = d * d_in * 2 + d_in * d  # in/out projections (x, z)
            ssm += d_in * (2 * s.d_state) + d_in  # B,C,dt projections-ish
            return self._attn_params() + ssm + self._mlp_params() + norms
        if self.moe is not None:
            router = d * self.moe.n_experts
            experts = self.moe.n_experts * 3 * d * self.d_ff
            return self._attn_params() + router + experts + norms
        return self._attn_params() + self._mlp_params() + norms

    def active_param_count(self) -> int:
        """Params touched per token (MoE: top_k experts only)."""
        if self.moe is None:
            return self.param_count()
        total = self.param_count()
        experts_all = self.n_layers * self.moe.n_experts * 3 * self.d_model * self.d_ff
        experts_active = self.n_layers * self.moe.top_k * 3 * self.d_model * self.d_ff
        return total - experts_all + experts_active

    def model_flops(self, shape: ShapeSpec) -> float:
        """MODEL_FLOPS per step: 6*N*D for training, 2*N*D for inference
        (N = active params, D = tokens processed in the step)."""
        n_active = self.active_param_count()
        if shape.mode == "train":
            return 6.0 * n_active * shape.tokens
        if shape.mode == "prefill":
            return 2.0 * n_active * shape.tokens
        # decode: one token per sequence in the batch
        return 2.0 * n_active * shape.global_batch

    def runs_shape(self, shape: ShapeSpec) -> Tuple[bool, str]:
        """Shape-grid applicability rule.  Returns (runs, reason)."""
        if shape.name == "long_500k" and not self.sub_quadratic:
            return False, ("skip: pure full-attention stack; 500k-token decode "
                           "needs sub-quadratic attention (DESIGN.md)")
        return True, ""


def mxu_pad(n: int, align: int = 128) -> int:
    return ((n + align - 1) // align) * align
