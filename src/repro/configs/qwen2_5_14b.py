"""Qwen2.5-14B  [dense]  48L d_model=5120 40H (GQA kv=8) d_ff=13824
vocab=152064 — GQA, QKV bias.  [hf:Qwen/Qwen2.5-0.5B; hf]

40 query heads do not divide the 16-way model axis, so attention activations
are sequence-sharded ("qseq") while the projection weights stay flat-sharded
(5120 / 1024 both divide 16).  14.8B params require FSDP at train_4k.
"""

from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="qwen2.5-14b",
    family="dense",
    n_layers=48,
    d_model=5120,
    n_heads=40,
    n_kv_heads=8,
    head_dim=128,
    d_ff=13824,
    vocab_size=152064,
    qkv_bias=True,
    rope_theta=1e6,
    layer_pattern=("attn",),
    fsdp=True,
    remat="full",
    n_microbatches=8,
    attention_sharding="qseq",
)
