"""Gemma2-9B  [dense]  42L d_model=3584 16H (GQA kv=8) d_ff=14336
vocab=256000 — local+global alternating, logit softcap.  [arXiv:2408.00118; hf]

head_dim is 256 (16 x 256 = 4096 > d_model, as in the release).  The layer
stack alternates (local sliding-window, global) pairs -> scan period 2,
21 periods.  Attention soft-capping 50.0, final-logit soft-capping 30.0.
Long-context eligible: local layers cache only their 4096-token window.
"""

from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="gemma2-9b",
    family="dense",
    n_layers=42,
    d_model=3584,
    n_heads=16,
    n_kv_heads=8,
    head_dim=256,
    d_ff=14336,
    vocab_size=256000,
    layer_pattern=("local", "attn"),
    local_window=4096,
    attn_softcap=50.0,
    final_softcap=30.0,
    tie_embeddings=True,
    scale_embeddings=True,
    mlp_act="gelu",
    fsdp=True,
    remat="full",
    n_microbatches=8,
    attention_sharding="heads",   # 16 heads / 16-way model axis
)
