"""Hymba-1.5B  [hybrid]  32L d_model=1600 25H (GQA kv=5) d_ff=5504
vocab=32001, ssm_state=16 — parallel attention + mamba heads.
[arXiv:2411.13676; hf]

Each hybrid layer runs sliding-window attention heads and SSM (Mamba-style)
heads in parallel on the same input and sums their (normed) outputs.  The
release's 3 full-attention layers are modelled as one global layer per
16-layer scan period (period = 1 "attn" + 15 "swa_ssm").  The SSM uses the
SSD scalar-per-head-decay form (see DESIGN.md §Hardware-adaptation) with
d_state=16.  Sub-quadratic: runs long_500k.

25 query heads !| 16 -> qseq attention sharding.
"""

from repro.configs.base import ModelConfig, SSMConfig

CONFIG = ModelConfig(
    name="hymba-1.5b",
    family="hybrid",
    n_layers=32,
    d_model=1600,
    n_heads=25,
    n_kv_heads=5,
    head_dim=64,
    d_ff=5504,
    vocab_size=32001,
    layer_pattern=("attn",) + ("swa_ssm",) * 15,
    local_window=1024,
    ssm=SSMConfig(d_state=16, expand=2, head_dim=64, conv_width=4, chunk=128),
    tie_embeddings=True,
    remat="full",
    n_microbatches=2,
    attention_sharding="qseq",
)
