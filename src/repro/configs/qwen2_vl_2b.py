"""Qwen2-VL-2B  [vlm]  28L d_model=1536 12H (GQA kv=2) d_ff=8960
vocab=151936 — M-RoPE, dynamic resolution.  [arXiv:2409.12191; hf]

Backbone only: the vision tower is a stub and ``input_specs()`` carries
precomputed 3D (temporal, height, width) M-RoPE position ids alongside the
token stream.  head_dim 128 is split (32, 48, 48) across the three position
streams (rotary pairs 16/24/24).
"""

from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="qwen2-vl-2b",
    family="vlm",
    n_layers=28,
    d_model=1536,
    n_heads=12,
    n_kv_heads=2,
    head_dim=128,
    d_ff=8960,
    vocab_size=151936,
    qkv_bias=True,
    rope_theta=1e6,
    m_rope_sections=(16, 24, 24),   # rotary-pair split of head_dim // 2
    layer_pattern=("attn",),
    tie_embeddings=True,
    remat="full",
    n_microbatches=2,
    attention_sharding="qseq",      # 12 heads !| 16
)
