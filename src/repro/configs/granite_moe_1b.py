"""Granite-3.0-1B-A400M  [moe]  24L d_model=1024 16H (GQA kv=8) d_ff=512,
MoE 32 experts top-8.  [hf:ibm-granite/granite-3.0-1b-a400m-base]

32 experts shard 2-per-device over the 16-way model axis (expert
parallelism); GShard-style dispatch/combine einsums produce the all-to-alls.
Tiny d_ff=512 makes dispatch overhead the dominant inefficiency — this cell
is a candidate for the sort-based dispatch hillclimb.
"""

from repro.configs.base import ModelConfig, MoEConfig

CONFIG = ModelConfig(
    name="granite-moe-1b-a400m",
    family="moe",
    n_layers=24,
    d_model=1024,
    n_heads=16,
    n_kv_heads=8,
    head_dim=64,
    d_ff=512,
    vocab_size=49155,
    layer_pattern=("attn",),
    moe=MoEConfig(n_experts=32, top_k=8, capacity_factor=1.25, group_size=512),
    tie_embeddings=True,
    remat="full",
    n_microbatches=2,
    attention_sharding="heads",
)
