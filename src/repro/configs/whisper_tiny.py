"""Whisper-tiny  [audio]  4L d_model=384 6H (kv=6) d_ff=1536 vocab=51865
— enc-dec, conv frontend (stub).  [arXiv:2212.04356]

The transformer backbone only: ``input_specs()`` provides precomputed frame
embeddings of shape (batch, seq // encoder_downsample, d_model) standing in
for the conv1d frontend (stride-2 stub).  4 encoder layers (bidirectional)
+ 4 decoder layers (causal self-attn + cross-attn).  6 heads do not divide
the model axis -> qseq attention sharding; the model is small enough that
most weights are effectively replicated.

Decode shapes exercise the *decoder* with a cached encoder output.
"""

from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="whisper-tiny",
    family="encdec",
    n_layers=4,                  # decoder layers
    n_encoder_layers=4,
    encoder_downsample=2,
    d_model=384,
    n_heads=6,
    n_kv_heads=6,
    head_dim=64,
    d_ff=1536,
    vocab_size=51865,
    layer_pattern=("attn",),
    mlp_gated=False,
    mlp_act="gelu",
    remat="none",
    n_microbatches=1,
    attention_sharding="qseq",
)
