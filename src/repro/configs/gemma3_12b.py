"""Gemma3-12B  [dense]  48L d_model=3840 16H (GQA kv=8) d_ff=15360
vocab=262144 — 5:1 local:global, 128k context.  [hf:google/gemma-3-1b-pt]

Scan period is the 6-layer (5 local + 1 global) superblock -> 8 periods.
QK-norm replaces gemma2's attention soft-capping.  1024-token local window
keeps the long_500k KV cache dominated by the 8 global layers.
"""

from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="gemma3-12b",
    family="dense",
    n_layers=48,
    d_model=3840,
    n_heads=16,
    n_kv_heads=8,
    head_dim=256,
    d_ff=15360,
    vocab_size=262144,
    rope_theta=1e6,
    layer_pattern=("local", "local", "local", "local", "local", "attn"),
    local_window=1024,
    qk_norm=True,
    tie_embeddings=True,
    scale_embeddings=True,
    mlp_act="gelu",
    fsdp=True,
    remat="full",
    n_microbatches=8,
    attention_sharding="heads",
)
