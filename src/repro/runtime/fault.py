"""Fault tolerance primitives for thousand-node runs.

  * :class:`PreemptionGuard` — converts SIGTERM/SIGINT (maintenance events,
    spot reclaims) into a cooperative flag the train loop polls; the loop
    checkpoints and exits cleanly instead of dying mid-step.
  * :class:`StepWatchdog` — a heartbeat monitor: if no step completes
    within ``timeout_s`` (hung collective, straggling host), it invokes
    ``on_stall`` (default: log + record), which a supervisor (the launcher
    script / k8s liveness probe) uses to restart the job from the latest
    checkpoint.  This is the standard straggler/hang mitigation for
    synchronous SPMD: detect-and-restart, since a synchronous step cannot
    outrun its slowest participant.
  * :func:`retry` — exponential backoff for transient infrastructure
    errors (checkpoint storage, compilation cache, DNS).
"""

from __future__ import annotations

import logging
import signal
import threading
import time
from typing import Callable, Optional

log = logging.getLogger("repro.runtime")


class PreemptionGuard:
    def __init__(self, signals=(signal.SIGTERM,)):
        self._flag = threading.Event()
        self._prev = {}
        self._signals = signals

    def __enter__(self) -> "PreemptionGuard":
        for s in self._signals:
            self._prev[s] = signal.signal(s, self._handler)
        return self

    def __exit__(self, *exc) -> None:
        for s, prev in self._prev.items():
            signal.signal(s, prev)

    def _handler(self, signum, frame) -> None:
        log.warning("preemption signal %s received; requesting clean stop",
                    signum)
        self._flag.set()

    @property
    def should_stop(self) -> bool:
        return self._flag.is_set()


class StepWatchdog:
    """Call ``beat()`` after every completed step; a background thread
    fires ``on_stall`` if beats stop arriving."""

    def __init__(self, timeout_s: float = 600.0,
                 on_stall: Optional[Callable[[float], None]] = None,
                 poll_s: float = 5.0):
        self.timeout_s = timeout_s
        self.on_stall = on_stall or self._default_on_stall
        self.poll_s = poll_s
        self._last = time.monotonic()
        self._stop = threading.Event()
        self._stalled = False
        self._thread: Optional[threading.Thread] = None

    def _default_on_stall(self, idle_s: float) -> None:
        log.error("watchdog: no step completed for %.0fs — likely hung "
                  "collective or straggler; supervisor should restart from "
                  "the latest checkpoint", idle_s)

    def beat(self) -> None:
        self._last = time.monotonic()
        self._stalled = False

    @property
    def stalled(self) -> bool:
        return self._stalled

    def _run(self) -> None:
        while not self._stop.wait(self.poll_s):
            idle = time.monotonic() - self._last
            if idle > self.timeout_s and not self._stalled:
                self._stalled = True
                self.on_stall(idle)

    def __enter__(self) -> "StepWatchdog":
        self._thread = threading.Thread(target=self._run, daemon=True)
        self._thread.start()
        return self

    def __exit__(self, *exc) -> None:
        self._stop.set()
        if self._thread:
            self._thread.join()


def retry(fn: Callable, *, tries: int = 5, base_delay_s: float = 0.5,
          exceptions=(OSError, IOError), on_retry=None):
    """Run fn() with exponential backoff on transient errors."""
    delay = base_delay_s
    for attempt in range(tries):
        try:
            return fn()
        except exceptions as e:  # noqa: PERF203
            if attempt == tries - 1:
                raise
            if on_retry:
                on_retry(attempt, e)
            log.warning("retry %d/%d after %s: %s", attempt + 1, tries,
                        type(e).__name__, e)
            time.sleep(delay)
            delay *= 2
