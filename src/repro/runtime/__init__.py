from repro.runtime.fault import PreemptionGuard, StepWatchdog, retry  # noqa: F401
