"""Token samplers (pure functions of logits + rng)."""

from __future__ import annotations

import dataclasses
from typing import Optional

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class SamplerConfig:
    temperature: float = 0.0      # 0 -> greedy
    top_k: int = 0                # 0 -> disabled


def sample(logits: jax.Array, key: jax.Array,
           cfg: SamplerConfig = SamplerConfig()) -> jax.Array:
    """logits (B, V) -> tokens (B,) int32.  Pure and trace-safe: the same
    function runs on host arrays and inside the engine's fused jitted
    decode tick, so on-device sampling is host sampling by construction."""
    if cfg.temperature <= 0.0:
        return jnp.argmax(logits, axis=-1).astype(jnp.int32)
    logits = logits / cfg.temperature
    if cfg.top_k > 0:
        kth = jax.lax.top_k(logits, cfg.top_k)[0][..., -1:]
        logits = jnp.where(logits < kth, -jnp.inf, logits)
    return jax.random.categorical(key, logits, axis=-1).astype(jnp.int32)


def split_and_sample(key: jax.Array, logits: jax.Array,
                     cfg: SamplerConfig = SamplerConfig()):
    """The serving engine's key convention: one split per sampling event,
    sample with the subkey, carry the split key forward.  Returns
    (new_key, tokens).  Shared by the host admission path and the fused
    on-device decode tick so both provably consume the same key stream."""
    key, sub = jax.random.split(key)
    return key, sample(logits, sub, cfg)
