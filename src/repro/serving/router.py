"""Multi-replica serving tier: a router over N plan-driven engines.

One level above :class:`~repro.serving.engine.ServingEngine`, this module
scales the serving stack past a single engine the same way the engine
scaled past a single request: a :class:`Router` owns N replicas — each
built ``from_plan`` with its own (possibly heterogeneous) design point —
and load-balances arriving requests across them behind a pluggable
routing-policy registry (:data:`ROUTER_POLICIES`, mirroring how
scheduling policies live behind ``scheduler.SCHEDULERS``).

Two placement modes, selected by ``FleetPlan.n_prefill``:

* **colocated** (``n_prefill=0``) — every replica admits, prefills and
  decodes; the router only chooses *where* each request lands.
* **disaggregated** (``n_prefill=k``) — the first ``k`` replicas run
  admission/prefill only: after a replica's step, every slot holding a
  prefilled request is snapshotted (``SlotManager.snapshot_many`` — the
  batched eviction transport from the preemption path), released, and
  shipped to a decode replica as a :class:`TransitJob`.  The transit is
  charged a modeled DCN latency per snapshot byte (``hw.dcn_bw`` against
  the modeled decode-tick wall time), so disaggregation has a real cost
  axis: for transformer-style KV the snapshot grows with the prompt,
  while RNN/SSM archs ship one O(1) recurrent state column — the paper's
  cheap case — and round to the 1-tick floor.  On delivery the request
  is re-submitted to the decode replica carrying ``req.saved``; the
  engine's resume path restores it without a model call, so the decode
  replica never re-prefills.

The whole tier runs single-process over fake devices on one shared
virtual clock: :func:`drive_fleet` grows :func:`~repro.serving.workload.
drive`'s arrival-bounded loop with transit events, and for a fleet of one
colocated replica it reduces *exactly* to ``drive()`` — same skips, same
budgets, same submission ticks — which is what makes the single-replica
fleet bit-identical to the bare engine (schedule, outputs, metrics), the
anchor the fleet property tests pin.

Cross-replica tick domains: each engine's tick counter lags the clock
while idle (exactly as under ``drive()``).  Colocated replicas never
exchange timestamps, so their compressed per-engine domains stay
internally consistent.  Disaggregated fleets *do* exchange timestamps (a
request's TTFT stamps land on the prefill replica, its completion on the
decode replica), so ``step_all`` first aligns every engine's idle tick
counter to the shared clock (``ServingEngine.align_clock``) — all stamps
then live in one coherent clock domain and cross-replica latency math is
meaningful.
"""

from __future__ import annotations

import dataclasses
import math
import time
from typing import Dict, List, Optional, Sequence, Tuple, Type

from repro.serving.engine import Request, ServingEngine
from repro.serving.slotstate import SlotSnapshot
from repro.serving.workload import VirtualClock, WorkloadItem

# ---------------------------------------------------------------------------
# routing policies
# ---------------------------------------------------------------------------


class RoutingPolicy:
    """Chooses which replica an event goes to.  ``choose`` receives the
    eligible engines (admission set for fresh requests, decode set for
    disaggregated hand-offs — the router keeps one policy instance per
    role so round-robin cursors don't interleave) and returns an index
    into that list.  Implementations must be deterministic: same call
    sequence, same choices — fleet schedules are seed-exact."""

    name = "?"

    def choose(self, engines: Sequence[ServingEngine]) -> int:
        raise NotImplementedError


class RoundRobin(RoutingPolicy):
    """Cycle through the eligible replicas in order — the baseline that
    needs no replica state at all."""

    name = "round_robin"

    def __init__(self):
        self._next = 0

    def choose(self, engines: Sequence[ServingEngine]) -> int:
        k = self._next % len(engines)
        self._next += 1
        return k


def _queue_depth(e: ServingEngine) -> int:
    return len(e.scheduler) + e.sm.n_active()


class LeastQueue(RoutingPolicy):
    """Join the shortest queue: pending + in-slot requests, ties to the
    lowest replica index.  The classic supermarket rule; reacts to
    heterogeneous replica capacity where round-robin cannot."""

    name = "least_queue"

    def choose(self, engines: Sequence[ServingEngine]) -> int:
        return min(range(len(engines)),
                   key=lambda k: (_queue_depth(engines[k]), k))


class SLOFeedback(RoutingPolicy):
    """Route by each replica's *observed* serving quality: prefer the
    replica with the lowest rolling p95 TTFT from its ``LiveMetrics``
    window (``Router.from_plan`` enables the window when this policy is
    selected).  A replica with no completed requests in its window scores
    a TTFT of 0 — cold replicas attract traffic until they have a track
    record — and ties fall back to least-queue, then lowest index, so
    the choice stays deterministic."""

    name = "slo_feedback"

    def choose(self, engines: Sequence[ServingEngine]) -> int:
        def score(k: int):
            e = engines[k]
            ttft = 0.0
            if e.live is not None:
                s = e.live.snapshot()
                v = s["ttft_p95"]
                if s["completed"] and not math.isnan(v):
                    ttft = float(v)
            return (ttft, _queue_depth(e), k)

        return min(range(len(engines)), key=score)


ROUTER_POLICIES: Dict[str, Type[RoutingPolicy]] = {
    p.name: p for p in (RoundRobin, LeastQueue, SLOFeedback)}
ROUTING_POLICIES = tuple(ROUTER_POLICIES)   # CLI choices, registry order


def make_routing_policy(name: str,
                        registry: Optional[Dict[str, Type[RoutingPolicy]]]
                        = None) -> RoutingPolicy:
    registry = ROUTER_POLICIES if registry is None else registry
    if name not in registry:
        raise ValueError(f"unknown routing policy {name!r} "
                         f"(known: {sorted(registry)})")
    return registry[name]()


# ---------------------------------------------------------------------------
# transit
# ---------------------------------------------------------------------------


@dataclasses.dataclass
class TransitJob:
    """One prefill→decode state hand-off in flight: the request, its slot
    snapshot, and when the modeled DCN transfer completes (absolute clock
    units)."""

    req: Request
    snap: SlotSnapshot
    src: int         # prefill replica index
    dst: int         # decode replica index
    due: float       # clock time the snapshot finishes arriving
    nbytes: int
    ticks: int       # charged transit latency in clock ticks


# ---------------------------------------------------------------------------
# the router
# ---------------------------------------------------------------------------


class Router:
    """Load balancer + transit broker over a fleet of serving engines.

    Owns the replica engines, the routing-policy instances (one for
    admission, one for disaggregated dispatch), and the in-flight
    :class:`TransitJob` queue.  Driven by :func:`drive_fleet`; the
    conservation invariant — every submitted request is in exactly one
    place (a replica's queue/slot/finished list, or one transit job) —
    is what the fleet property harness checks."""

    def __init__(self, fleet, engines: Sequence[ServingEngine]):
        from repro.plan.plan import FleetPlan

        if not isinstance(fleet, FleetPlan):
            raise TypeError(f"Router needs a FleetPlan, "
                            f"got {type(fleet).__name__}")
        fleet.validate()
        if len(engines) != len(fleet.replicas):
            raise ValueError(f"fleet names {len(fleet.replicas)} replicas "
                             f"but {len(engines)} engines were supplied")
        self.fleet = fleet
        self.engines: List[ServingEngine] = list(engines)
        self.policy = make_routing_policy(fleet.routing)
        self._dispatch = make_routing_policy(fleet.routing)
        self.requests: List[Request] = []        # arrival order
        self.assigned: List[List[Request]] = [[] for _ in self.engines]
        self.transits: List[TransitJob] = []     # sorted by due
        self.n_handoffs = 0
        self.n_delivered = 0
        self.transit_bytes_total = 0
        self.transit_ticks_total = 0
        self._bytes_per_tick: Optional[float] = None

    # ------------------------------------------------------------ construction
    @classmethod
    def from_plan(cls, fleet, *, seed: int = 0,
                  tracers: Optional[Sequence] = None,
                  _built=None) -> "Router":
        """Build the fleet from its plan.  Replica ``i`` gets engine seed
        ``seed + i`` (replica 0 keeps the caller's seed, so a one-replica
        fleet seeds identically to a bare engine).  Model/params builds
        are shared across replicas with the same ``(arch, reduced)``
        identity; pass ``_built`` (a ``{(arch, reduced): (model, params)}``
        dict) to reuse builds across fleets the way the benchmark sweeps
        do.  ``tracers`` optionally supplies one ``repro.obs.Tracer`` per
        replica (merge them with ``obs.trace.merge_traces``)."""
        fleet.validate()
        if tracers is not None and len(tracers) != len(fleet.replicas):
            raise ValueError(f"need one tracer per replica: got "
                             f"{len(tracers)} for {len(fleet.replicas)}")
        built = _built if _built is not None else {}
        engines = []
        for i, plan in enumerate(fleet.replicas):
            key = (plan.arch, plan.reduced)
            if key not in built:
                import jax

                from repro.configs import get_config
                from repro.models.lm import build_model
                from repro.testing import reduced_config

                cfg = (reduced_config(plan.arch) if plan.reduced
                       else get_config(plan.arch))
                model = build_model(cfg)
                built[key] = (model, model.init(jax.random.PRNGKey(0)))
            model, params = built[key]
            eng = ServingEngine.from_plan(
                plan, params, model=model, seed=seed + i,
                tracer=None if tracers is None else tracers[i])
            if fleet.routing == "slo_feedback":
                eng.enable_live_metrics()
            engines.append(eng)
        return cls(fleet, engines)

    # ------------------------------------------------------------ replica sets
    @property
    def n_prefill(self) -> int:
        return self.fleet.n_prefill

    def admit_set(self) -> List[int]:
        """Replica indices eligible for fresh submissions: the prefill
        replicas when disaggregated, everyone when colocated."""
        if self.n_prefill:
            return list(range(self.n_prefill))
        return list(range(len(self.engines)))

    def decode_set(self) -> List[int]:
        return list(range(self.n_prefill, len(self.engines)))

    def _route(self, policy: RoutingPolicy, idxs: Sequence[int]) -> int:
        cands = [self.engines[i] for i in idxs]
        return idxs[policy.choose(cands)]

    # -------------------------------------------------------------- admission
    def submit(self, item: WorkloadItem) -> Request:
        """Route one arrival to a replica and submit it there — the fleet
        analogue of ``engine.submit`` (same argument mapping as
        ``drive()``, so a one-replica fleet stamps identically)."""
        idx = self._route(self.policy, self.admit_set())
        req = self.engines[idx].submit(
            list(item.prompt), item.max_new_tokens, item.eos_id,
            deadline=item.deadline)
        self.requests.append(req)
        self.assigned[idx].append(req)
        return req

    # ---------------------------------------------------------------- driving
    def engines_have_work(self) -> bool:
        return any(e.has_work() for e in self.engines)

    def has_work(self) -> bool:
        return self.engines_have_work() or bool(self.transits)

    @property
    def ticks(self) -> int:
        return max(e.ticks for e in self.engines)

    def step_all(self, budget: Optional[int], now: Optional[float] = None
                 ) -> int:
        """One fleet scheduling round: step every replica with the tick
        budget (prefill replicas are capped at 1 tick — they exist to
        admit, not to decode) and return the widest per-replica tick
        advance, which is how far the shared clock moves.  In
        disaggregated mode the engines' idle tick counters are first
        aligned to the shared clock so cross-replica stamps cohere."""
        if self.n_prefill and now is not None:
            for e in self.engines:
                e.align_clock(int(now))
        delta = 0
        for i, e in enumerate(self.engines):
            cap = 1 if i < self.n_prefill else budget
            before = e.ticks
            e.step(max_ticks=cap)
            delta = max(delta, e.ticks - before)
        return delta

    # ----------------------------------------------------------- transit side
    @property
    def bytes_per_tick(self) -> float:
        """Modeled DCN transit bandwidth per virtual-clock tick:
        ``hw.dcn_bw × modeled_tick_seconds`` for the fleet's reference
        replica (the planner's roofline decode-tick wall time), unless
        the plan pins ``transit_bytes_per_tick`` directly.  A spec with
        no DCN (``dcn_bw <= 0``) yields ``inf`` — transits then take the
        1-tick floor."""
        if self._bytes_per_tick is None:
            if self.fleet.transit_bytes_per_tick is not None:
                self._bytes_per_tick = float(
                    self.fleet.transit_bytes_per_tick)
            else:
                from repro import hw
                from repro.plan.planner import modeled_tick_seconds

                spec = hw.get_spec(self.fleet.hw)
                ref = self.fleet.replicas[0]
                if spec.dcn_bw > 0:
                    self._bytes_per_tick = spec.dcn_bw * \
                        modeled_tick_seconds(ref.arch, ref.max_batch, spec)
                else:
                    self._bytes_per_tick = math.inf
        return self._bytes_per_tick

    def transit_ticks(self, nbytes: int) -> int:
        """Clock ticks charged to ship one snapshot: ceil over the
        modeled per-tick DCN bytes, floored at one tick (nothing arrives
        the instant it leaves).  O(1) RNN/SSM state columns round to the
        floor — the paper's cheap hand-off."""
        bpt = self.bytes_per_tick
        if not math.isfinite(bpt) or bpt <= 0:
            return 1
        return max(1, int(math.ceil(nbytes / bpt)))

    def collect_handoffs(self, now: float) -> int:
        """Sweep prefill replicas for finished prefill state: every
        occupied slot whose request already has its first token is
        snapshotted (one batched gather per replica), released, and put
        in transit to a policy-chosen decode replica.  Slots whose
        overlapped first token is still on device are left for the next
        sweep; requests that completed inside the prefill step finished
        there and never transit.  Compatibility is checked against the
        destination *before* the job is queued, so an impossible
        hand-off fails at the source with a field-naming error."""
        if not self.n_prefill:
            return 0
        moved = 0
        for src in range(self.n_prefill):
            eng = self.engines[src]
            ready = [(slot, req) for slot, req in eng.sm.running()
                     if len(req.output) >= 1]
            if not ready:
                continue
            snaps = eng.sm.snapshot_many([slot for slot, _ in ready])
            for (slot, req), snap in zip(ready, snaps):
                eng.sm.release(slot)
                dst = self._route(self._dispatch, self.decode_set())
                self.engines[dst].sm.check_snapshot_compat(snap)
                nbytes = snap.nbytes()
                ticks = self.transit_ticks(nbytes)
                self.transits.append(TransitJob(
                    req=req, snap=snap, src=src, dst=dst,
                    due=now + ticks, nbytes=nbytes, ticks=ticks))
                self.n_handoffs += 1
                self.transit_bytes_total += nbytes
                self.transit_ticks_total += ticks
                moved += 1
        if moved:
            self.transits.sort(key=lambda t: t.due)   # stable: FIFO on ties
        return moved

    def next_transit_due(self) -> float:
        return self.transits[0].due

    def deliver_due(self, now: float) -> int:
        """Deliver every transit whose modeled transfer has completed:
        re-check compatibility, attach the snapshot as ``req.saved`` and
        submit to the decode replica's scheduler — the engine's resume
        path restores it into a slot with no model call, no re-prefill."""
        n = 0
        while self.transits and self.transits[0].due <= now + 1e-9:
            job = self.transits.pop(0)
            dst = self.engines[job.dst]
            dst.sm.check_snapshot_compat(job.snap)
            job.req.saved = job.snap
            dst.scheduler.submit(job.req)
            self.n_delivered += 1
            n += 1
        return n

    # -------------------------------------------------------------- reporting
    def parts(self) -> List[Tuple[List[Request], int, List[float]]]:
        """Per-replica ``(requests, ticks, util_history)`` triples for
        :func:`repro.serving.metrics.aggregate_fleet` — requests are
        attributed to the replica that *admitted* them (for a one-replica
        fleet this is exactly the bare ``aggregate`` input)."""
        return [(list(self.assigned[i]), e.ticks, list(e.util_history))
                for i, e in enumerate(self.engines)]

    def fleet_aggregate(self, *, tick_seconds: float = 1.0
                        ) -> Dict[str, object]:
        from repro.serving.metrics import aggregate_fleet

        return aggregate_fleet(self.parts(), tick_seconds=tick_seconds)

    def transit_stats(self) -> Dict[str, object]:
        bpt = self.bytes_per_tick if self.n_handoffs else None
        return {
            "handoffs": int(self.n_handoffs),
            "delivered": int(self.n_delivered),
            "in_flight": len(self.transits),
            "bytes": int(self.transit_bytes_total),
            "ticks": int(self.transit_ticks_total),
            "bytes_per_tick": (float(bpt) if bpt is not None
                               and math.isfinite(bpt) else None),
        }

    def conservation_census(self) -> Dict[str, int]:
        """Where every submitted request currently lives — the property
        harness asserts the sum equals the number of arrivals, with no
        request counted twice."""
        queued = sum(len(e.scheduler) for e in self.engines)
        in_slot = sum(e.sm.n_active() for e in self.engines)
        finished = sum(len(e.finished) for e in self.engines)
        shed = sum(1 for r in self.requests if r.shed)
        return {"queued": queued, "in_slot": in_slot,
                "in_transit": len(self.transits), "finished": finished,
                "shed": shed,
                "total": queued + in_slot + len(self.transits)
                + finished + shed}


# ---------------------------------------------------------------------------
# the fleet drive loop
# ---------------------------------------------------------------------------


def drive_fleet(router: Router, items: Sequence[WorkloadItem],
                clock=None, max_ticks: int = 1_000_000,
                sync_every: Optional[int] = None,
                on_tick=None) -> List[Request]:
    """Replay a workload against a fleet on one shared clock — the
    fleet-aware growth of :func:`repro.serving.workload.drive`, event for
    event: idle skips jump to the next arrival *or* transit completion
    (whichever lands first), per-round tick budgets never step the fleet
    past either event, and the clock advances by the widest per-replica
    tick delta each round.  For a one-replica colocated fleet every
    branch degenerates to ``drive()``'s — same skips, budgets and
    submission ticks — so the single-replica fleet schedule is
    bit-identical to the bare engine's.

    Returns the submitted :class:`Request` objects in arrival order (all
    done or shed once the fleet drains), exactly like ``drive()``."""
    if clock is None:
        clock = VirtualClock()
    pending = sorted(items, key=lambda it: it.t)
    i = 0
    busy = 0.0
    for _ in range(max_ticks):
        if not router.engines_have_work():
            horizons = []
            if i < len(pending):
                horizons.append(pending[i].t)
            if router.transits:
                horizons.append(router.next_transit_due())
            if horizons:
                clock.skip_to(min(horizons))   # idle: jump to next event
        router.deliver_due(clock.now)
        while i < len(pending) and pending[i].t <= clock.now:
            router.submit(pending[i])
            i += 1
        if not router.has_work() and i >= len(pending):
            clock.busy_seconds = busy
            return list(router.requests)
        budget = sync_every
        if isinstance(clock, VirtualClock):
            # never step past the next arrival or transit completion
            horizons = []
            if i < len(pending):
                horizons.append(pending[i].t)
            if router.transits:
                horizons.append(router.next_transit_due())
            if horizons:
                gap = min(horizons) - clock.now
                due = max(1, math.ceil(gap / clock.tick_cost)) \
                    if gap > 0 else 1
                budget = due if budget is None else min(budget, due)
        t0 = time.perf_counter()
        delta = router.step_all(budget, now=clock.now)
        busy += time.perf_counter() - t0
        for _ in range(delta):
            clock.tick()
        router.collect_handoffs(clock.now)
        if on_tick is not None and delta:
            on_tick(router.ticks)
    raise RuntimeError(f"fleet workload did not drain within {max_ticks} "
                       f"rounds ({i}/{len(pending)} submitted, "
                       f"{len(router.transits)} transits in flight)")


__all__ = ["ROUTER_POLICIES", "ROUTING_POLICIES", "RoutingPolicy",
           "RoundRobin", "LeastQueue", "SLOFeedback",
           "make_routing_policy", "Router", "TransitJob", "drive_fleet"]
