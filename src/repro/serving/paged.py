"""Paged slot-state: a block-table cache manager behind the SlotManager seam.

The dense :class:`repro.serving.slotstate.SlotManager` commits
``max_batch x max_len`` cache columns up front, so HBM is provisioned for
the worst-case sequence in every slot — exactly the padding the paper
argues a spatial design should avoid by capturing design parameters in
general loop constructs and provisioning per problem size.  This module
replaces the backing store with a *pool of fixed-size blocks* plus a
per-slot block table (vLLM/sarathi-serve style), while keeping every
SlotManager signature and — crucially — every schedule and logit
bit-exact:

* **What gets paged.** Only the KV ring leaves (``k``/``v``/``pos`` and
  int8 scales), along their ring axis, as declared per leaf by
  :meth:`repro.models.lm.LM.cache_page_axes`.  Recurrent/SSM/conv state
  is O(1) per sequence — the cheap case the paper's RNN focus makes
  interesting — and stays one dense column per slot, as do cross-attn
  keys and the ``lengths`` vector.  Pool leaves group by ring length
  ``S`` (local-window rings saturate at ``S = local_window`` while full
  rings run to ``max_len``), one block table per (slot, group).

* **Bit-exactness by construction.**  ``.cache`` is a *property*: the
  getter materializes the same dense ``(periods, max_batch, S, ...)``
  view the dense manager owns (one ``jnp.take`` per pool leaf through
  the block table), and the setter re-pages the updated view into the
  pool.  The engine's fused decode program therefore consumes
  byte-identical shapes and — because every unallocated table entry
  points at a reserved *null block* holding the empty-ring pattern
  (``pos = -1``, zero k/v), and attention masks ``pos < 0`` entries to
  ``-1e30`` whose softmax weight underflows to exactly ``0.0`` — byte-
  identical logits.  Schedules, samples, and metrics follow.  (Dense
  caches hold *different* garbage at masked positions — prefill leaves
  token-0 copies there — which is why bit-exactness is asserted on
  logits/schedules and on :func:`canonicalize_cache`-masked columns,
  not on raw masked bytes.)

* **The null block self-heals.**  Writebacks scatter every slot's full
  ring view; uncovered ring positions land in the null block (possibly
  colliding across slots), so the writeback unconditionally rewrites the
  null block with the empty pattern afterwards.  This also makes
  restore-from-a-dense-snapshot safe: whatever garbage the snapshot
  carries in masked positions beyond the allocated prefix is dropped on
  the floor instead of corrupting the shared null block.

* **Allocation is host-side and deterministic.**  Blocks allocate
  lowest-id-first from a sorted free list; a slot's pages form a
  monotone prefix of its ring (ring writes go to ``length % S``, which
  stays below the covered prefix while ``length < S`` and wraps inside
  it afterwards).  ``ensure_chunk(budget)`` — called by the engine
  before each decode chunk — extends each occupied slot's coverage to
  ``length + budget + 1`` tokens, so a chunk never writes an uncovered
  position.  The pool is fully provisioned (``max_batch`` worst-case
  slots + one null block per group) so allocation can never fail and
  admission never depends on pool state: the *capacity* win is taken by
  the planner, which can admit a larger ``max_batch`` under the same
  HBM budget because *expected* resident bytes — what
  :meth:`bytes_resident` reports and ``benchmarks/fig4_fragmentation``
  plots — track tokens in flight, not ``max_batch x max_len``.

The per-chunk materialize/writeback is O(cache) of jnp ops outside jit —
fine for the virtual-clock harness this repo measures with; fusing the
block-table gather into the decode kernel itself is the ROADMAP
follow-up (flash-decoding page layout, SNIPPETS.md §3).
"""

from __future__ import annotations

import math
from typing import Any, Dict, List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.models.lm import LM
from repro.obs.registry import MetricsRegistry
from repro.serving.slotstate import SlotManager, SlotSnapshot

NULL_BLOCK = 0   # reserved block id per group: the shared empty pattern


class BlockPool:
    """Host-side bookkeeping for one ring-length group: a block table per
    slot plus a sorted free list over ``capacity`` block ids (id 0 is the
    reserved null block and is never allocated)."""

    def __init__(self, ring_len: int, block_size: int, max_batch: int):
        self.ring_len = ring_len
        self.block = min(block_size, ring_len)
        self.n_pages = -(-ring_len // self.block)        # ceil per slot
        self.capacity = 1 + max_batch * self.n_pages     # + null block
        self.table = np.zeros((max_batch, self.n_pages), np.int32)
        self.pages = np.zeros((max_batch,), np.int32)    # allocated prefix
        self.free_list: List[int] = list(range(1, self.capacity))

    def cover(self, slot: int, tokens: int) -> bool:
        """Extend ``slot``'s page prefix to cover ``tokens`` ring
        positions (capped at the ring length).  Returns True if the
        table changed.  Never shrinks; lowest free ids first."""
        need = -(-min(self.ring_len, max(0, tokens)) // self.block)
        have = int(self.pages[slot])
        if need <= have:
            return False
        for p in range(have, need):
            self.table[slot, p] = self.free_list.pop(0)
        self.pages[slot] = need
        return True

    def release(self, slot: int) -> List[int]:
        """Return all of ``slot``'s blocks to the free list; returns the
        freed ids so the manager can wipe their contents (the pool
        invariant is that free blocks always hold the empty pattern —
        allocation then never surfaces a previous owner's stale ring
        entries, whose ``pos >= 0`` values attention would treat as
        live)."""
        n = int(self.pages[slot])
        if n == 0:
            return []
        freed = [int(b) for b in self.table[slot, :n]]
        self.free_list.extend(freed)
        self.free_list.sort()
        self.table[slot, :n] = NULL_BLOCK
        self.pages[slot] = 0
        return freed

    def flat_index(self) -> np.ndarray:
        """Flat pool-position index mapping every (slot, ring position)
        through the block table: shape ``(max_batch * ring_len,)`` into a
        pool leaf viewed as ``(..., capacity * block, ...)``."""
        pos = np.arange(self.ring_len)
        off = pos % self.block
        page = pos // self.block
        return (self.table[:, page] * self.block + off[None, :]).reshape(-1)

    def check(self, occupied: Sequence[int]) -> None:
        """Pool invariants: no leak, no double-allocation, free-count
        conservation, null block never allocated, unoccupied slots own
        nothing.  Raises AssertionError with a specific message."""
        occ = set(occupied)
        allocated: List[int] = []
        for slot in range(self.table.shape[0]):
            n = int(self.pages[slot])
            row = self.table[slot]
            assert np.all(row[n:] == NULL_BLOCK), \
                f"slot {slot}: table entries beyond page count {n}: {row}"
            if slot not in occ:
                assert n == 0, f"unoccupied slot {slot} owns {n} blocks"
            allocated.extend(int(b) for b in row[:n])
        assert NULL_BLOCK not in allocated, "null block was allocated"
        assert len(set(allocated)) == len(allocated), \
            f"block double-allocated: {sorted(allocated)}"
        assert self.free_list == sorted(set(self.free_list)), \
            f"free list unsorted or duplicated: {self.free_list}"
        assert not (set(self.free_list) & set(allocated)), \
            "block both free and allocated"
        assert len(self.free_list) + len(allocated) == self.capacity - 1, \
            (f"block leak: {len(self.free_list)} free + {len(allocated)} "
             f"allocated != capacity-1 = {self.capacity - 1}")


class PagedSlotManager(SlotManager):
    """SlotManager with a block-pool backing store.

    Every public method keeps its base signature and semantics; the
    moving parts are the ``cache`` property (materialize/re-page), the
    allocation hooks (``ensure_chunk`` / prefill-insert / restore /
    release), and the fragmentation gauge backends."""

    def __init__(self, model: LM, max_batch: int, max_len: int, *,
                 block_size: int,
                 registry: Optional[MetricsRegistry] = None):
        if block_size < 1:
            raise ValueError(f"block_size must be >= 1, got {block_size}")
        self.block_size = block_size
        super().__init__(model, max_batch, max_len, registry=registry)

    # ----------------------------------------------------------- storage seam
    def _init_storage(self, model: LM, max_batch: int, max_len: int) -> None:
        template = model.init_cache(max_batch, max_len)
        self.axes = model.cache_batch_axes(template)
        self.page_axes = model.cache_page_axes(template)
        flat, self._treedef = jax.tree_util.tree_flatten_with_path(template)
        paxes = {tuple(p): ax for p, ax in jax.tree_util.tree_leaves_with_path(
            self.page_axes, is_leaf=lambda x: x is None)}
        baxes = {tuple(p): ax for p, ax in
                 jax.tree_util.tree_leaves_with_path(self.axes)}
        self._paths: List[Tuple] = []
        self._dense_leaves: Dict[Tuple, jax.Array] = {}
        self._pool_leaves: Dict[Tuple, jax.Array] = {}
        self._pool_group: Dict[Tuple, int] = {}      # path -> ring length
        self._null_pattern: Dict[Tuple, jax.Array] = {}
        self._pools: Dict[int, BlockPool] = {}       # ring length -> pool
        for path, leaf in flat:
            key = tuple(path)
            self._paths.append(key)
            lax_ = paxes[key]
            if lax_ is None:
                self._dense_leaves[key] = leaf
                continue
            if baxes[key] != 1 or lax_ != 2 or leaf.ndim < 3:
                raise ValueError(
                    f"pageable leaf {key} must carry slots on axis 1 and "
                    f"its ring on axis 2, got batch axis {baxes[key]}, "
                    f"page axis {lax_}, shape {leaf.shape}")
            s = int(leaf.shape[2])
            pool = self._pools.get(s)
            if pool is None:
                pool = self._pools[s] = BlockPool(s, self.block_size,
                                                  max_batch)
            # empty-ring pattern: one block's worth of the freshly
            # initialized leaf (pos = -1, zero k/v — uniform along the
            # ring, so any window of it is "empty")
            empty = leaf[:, 0, :pool.block]                 # (P, blk, tail)
            self._null_pattern[key] = empty
            reps = (1, pool.capacity) + (1,) * (empty.ndim - 2)
            self._pool_leaves[key] = jnp.tile(empty, reps)
            self._pool_group[key] = s
        self._flat_idx: Dict[int, jax.Array] = {}    # ring length -> index
        self._refresh_indices()

    def _refresh_indices(self) -> None:
        self._flat_idx = {s: jnp.asarray(pool.flat_index(), jnp.int32)
                          for s, pool in self._pools.items()}

    # ------------------------------------------------------- dense cache view
    @property
    def cache(self):
        """Materialize the dense ``(periods, max_batch, S, ...)`` view the
        engine and the base-class gather/scatter methods consume."""
        leaves = []
        for key in self._paths:
            pool_leaf = self._pool_leaves.get(key)
            if pool_leaf is None:
                leaves.append(self._dense_leaves[key])
                continue
            s = self._pool_group[key]
            idx = self._flat_idx[s]
            view = jnp.take(pool_leaf, idx, axis=1)
            shape = (pool_leaf.shape[0], self.max_batch, s) \
                + pool_leaf.shape[2:]
            leaves.append(view.reshape(shape))
        return jax.tree_util.tree_unflatten(self._treedef, leaves)

    @cache.setter
    def cache(self, new_cache) -> None:
        """Re-page a dense view into the pool.  Uncovered ring positions
        scatter into the null block (colliding writes carry equal values
        when the view came from :meth:`cache`, arbitrary ones when it
        came from a foreign snapshot) — so the null block is rewritten
        with the empty pattern afterwards, unconditionally."""
        flat, treedef = jax.tree_util.tree_flatten_with_path(new_cache)
        if len(flat) != len(self._paths):
            raise ValueError("cache pytree structure changed under the "
                             "paged manager")
        for path, leaf in flat:
            key = tuple(path)
            pool_leaf = self._pool_leaves.get(key)
            if pool_leaf is None:
                self._dense_leaves[key] = jnp.asarray(leaf).astype(
                    self._dense_leaves[key].dtype)
                continue
            s = self._pool_group[key]
            pool = self._pools[s]
            idx = self._flat_idx[s]
            flat_view = jnp.asarray(leaf).astype(pool_leaf.dtype).reshape(
                (pool_leaf.shape[0], self.max_batch * s)
                + pool_leaf.shape[2:])
            pool_leaf = pool_leaf.at[:, idx].set(flat_view)
            pool_leaf = pool_leaf.at[:, :pool.block].set(
                self._null_pattern[key])
            self._pool_leaves[key] = pool_leaf

    # ------------------------------------------------------------- allocation
    def _cover(self, slot: int, tokens: int) -> None:
        changed = False
        for pool in self._pools.values():
            changed |= pool.cover(slot, tokens)
        if changed:
            self._refresh_indices()

    def ensure_chunk(self, budget: int) -> None:
        # +1: an overlapped admission's first sampled token is not in
        # req.output yet, so the host length estimate can lag device
        # lengths by one
        for slot in self.occupied():
            self._cover(slot, self._slot_tokens(slot) + int(budget) + 1)

    def insert_from_prefill(self, slots: Sequence[int], rows: Sequence[int],
                            cacheN) -> None:
        for slot in slots:
            req = self.slots[slot]
            if req is None:
                raise ValueError(f"prefill insert into ungranted slot {slot}")
            self._cover(slot, min(self.max_len, len(req.prompt)))
        super().insert_from_prefill(slots, rows, cacheN)

    def restore(self, slot: int, snap: SlotSnapshot, req) -> None:
        # compat first: an alien snapshot must not touch the block tables
        # (the base-class check would fire only after _cover mutated them)
        self.check_snapshot_compat(snap)
        tokens = int(np.asarray(snap.cache_col["lengths"]).reshape(-1)[0])
        self._cover(slot, min(self.max_len, tokens))
        super().restore(slot, snap, req)

    def release(self, slot: int) -> None:
        super().release(slot)
        changed = False
        for s, pool in self._pools.items():
            freed = pool.release(slot)
            if not freed:
                continue
            changed = True
            self._wipe_blocks(s, freed)
        if changed:
            self._refresh_indices()

    def _wipe_blocks(self, ring_len: int, block_ids: Sequence[int]) -> None:
        """Reset freed blocks to the empty pattern, preserving the pool
        invariant that free blocks are always clean — a recycled block
        must not leak its previous owner's ring entries into the next
        owner's view."""
        pool = self._pools[ring_len]
        idx = jnp.asarray(np.concatenate(
            [np.arange(b * pool.block, (b + 1) * pool.block)
             for b in block_ids]), jnp.int32)
        for key, s in self._pool_group.items():
            if s != ring_len:
                continue
            empty = self._null_pattern[key]
            reps = (1, len(block_ids)) + (1,) * (empty.ndim - 2)
            self._pool_leaves[key] = self._pool_leaves[key].at[:, idx].set(
                jnp.tile(empty, reps))

    # -------------------------------------------------------------- integrity
    def check_invariants(self) -> None:
        """Assert every pool's block-accounting invariants (no leak, no
        double-free, free-count conservation) — the property harness and
        the smoke probe call this after every operation."""
        occ = self.occupied()
        for pool in self._pools.values():
            pool.check(occ)

    # ----------------------------------------------------------------- gauges
    def blocks_free(self) -> int:
        return sum(len(p.free_list) for p in self._pools.values())

    def bytes_resident(self) -> int:
        """Bytes committed to live state: allocated blocks + one null
        block and the block table per group + per-slot (recurrent/conv/
        cross-attn) columns of occupied slots.  This — not pool capacity
        — is what tracks tokens in flight and what the fragmentation
        trajectory plots."""
        total = self.n_active() * self._per_slot_bytes
        for s, pool in self._pools.items():
            tok_b = self._ring_token_bytes[s]
            n_alloc = int(pool.pages.sum())
            total += (n_alloc + 1) * pool.block * tok_b    # +1: null block
            total += 4 * pool.table.size                   # int32 table
        return total


def canonicalize_cache(cache, page_axes=None):
    """Zero every KV-ring entry whose ``pos`` marks it invalid, so dense
    and paged cache columns — which legitimately differ only in masked
    garbage (dense prefill leaves token-0 copies, paged leaves the null
    pattern) — compare bit-equal exactly when their *live* state is
    bit-equal.  Works on device or host pytrees; ``lengths`` and
    per-slot leaves pass through untouched."""
    def canon_entry(entry):
        if not (isinstance(entry, dict) and "pos" in entry):
            return dict(entry) if isinstance(entry, dict) else entry
        pos = np.asarray(entry["pos"])                   # (P, B, S)
        valid = pos >= 0
        out = {}
        for name, leaf in entry.items():
            arr = np.asarray(leaf)
            if name == "pos" or arr.shape[:3] != pos.shape:
                out[name] = arr
                continue
            mask = valid.reshape(valid.shape + (1,) * (arr.ndim - 3))
            out[name] = np.where(mask, arr, np.zeros_like(arr))
        return out

    blocks = {k: canon_entry(v) for k, v in cache["blocks"].items()}
    return {"blocks": blocks, "lengths": np.asarray(cache["lengths"])}


def paged_cache_bytes(model: LM, max_batch: int, max_len: int,
                      block_size: int, tokens_per_slot: float) -> int:
    """Planner-side model of paged resident bytes at steady state: what
    :meth:`PagedSlotManager.bytes_resident` would report with every slot
    occupied at ``tokens_per_slot`` resident tokens.  Mirrors the
    manager's accounting (per-slot state + allocated pages rounded up to
    block granularity + null block + table per ring group) without
    allocating any device memory — it walks ``cache_specs``."""
    specs = model.cache_specs(max_batch, max_len)
    paxes = {tuple(p): ax for p, ax in jax.tree_util.tree_leaves_with_path(
        model.cache_page_axes(specs), is_leaf=lambda x: x is None)}
    per_slot = 0
    ring_tok: Dict[int, int] = {}
    for path, spec in jax.tree_util.tree_leaves_with_path(specs):
        lax_ = paxes[tuple(path)]
        if lax_ is None:
            per_slot += spec.nbytes // max_batch
        else:
            s = int(spec.shape[lax_])
            ring_tok[s] = ring_tok.get(s, 0) + spec.nbytes // (max_batch * s)
    total = max_batch * per_slot
    for s, tok_b in ring_tok.items():
        block = min(block_size, s)
        n_pages = math.ceil(min(s, tokens_per_slot) / block)
        total += max_batch * n_pages * block * tok_b
        total += block * tok_b                             # null block
        total += 4 * max_batch * math.ceil(s / block)      # int32 table
    return total


__all__ = ["PagedSlotManager", "BlockPool", "canonicalize_cache",
           "paged_cache_bytes", "NULL_BLOCK"]
