"""Per-request latency metrics for the serving engine.

The engine stamps every request with *tick* timestamps (``t_submit`` /
``t_admit`` / ``t_first`` / ``t_done``) and keeps a per-tick utilization
history; this module turns a drained run into the serving numbers the
paper's real-time scenario is judged on:

* **queue-wait** — ticks between submission and admission to a slot (the
  scheduling delay the paper's §6 latency breakdown charges to batching);
* **TTFT** — time to first token, inclusive of the prefill tick: a request
  admitted on its submission tick has TTFT 1, not 0;
* **TPOT** — time per output token over the decode phase (first token
  excluded, so a one-token request has no TPOT sample);
* **tokens/sec** and mean utilization over the active span;
* **SLO attainment** — for requests carrying a ``deadline`` (absolute
  clock units): the fraction whose completion tick ended by the deadline
  (``(t_done + 1) * tick_seconds <= deadline``, consistent with TTFT
  counting the prefill tick as 1; on the virtual clock one tick is one
  clock unit and the scaling is a no-op);
* **preemption counters** — evictions, resumes, and how many requests
  were ever preempted (EDF ``--preempt``).

The ``slo`` block appears only when some request carries a deadline, and
the ``preemption`` block only when some request was actually preempted —
so aggregates of deadline-less FCFS/SPF runs are byte-identical to what
this module produced before either feature existed, which is what keeps
the committed ``BENCH_serving.json`` history comparable.

Everything is computed in ticks and scaled by ``tick_seconds`` at the end,
so the same aggregation serves both the deterministic virtual-clock mode
(``tick_seconds=1.0`` — "seconds" are tick units) and wall-clock runs
(``tick_seconds = measured wall time / ticks``).  Percentiles use the
nearest-rank method: exact, deterministic, no interpolation.
"""

from __future__ import annotations

import math
from typing import Dict, Optional, Sequence, Tuple

from repro.serving.engine import Request

PERCENTILES = (50, 95, 99)


def percentile(xs: Sequence[float], q: float) -> float:
    """Nearest-rank percentile (q in [0, 100]); NaN on empty input."""
    if not xs:
        return math.nan
    xs = sorted(xs)
    rank = max(1, math.ceil(q / 100.0 * len(xs)))
    return float(xs[min(rank, len(xs)) - 1])


def _summary(xs: Sequence[float]) -> Dict[str, float]:
    out = {f"p{q}": percentile(xs, q) for q in PERCENTILES}
    out["mean"] = float(sum(xs) / len(xs)) if xs else math.nan
    out["n"] = len(xs)
    return out


def request_metrics(req: Request) -> Optional[Dict[str, float]]:
    """Tick-domain latency numbers for one *completed* request (None if the
    request never finished — it carries no valid stamps to aggregate)."""
    if not req.done or req.t_done is None:
        return None
    out: Dict[str, float] = {
        "queue_wait": float(req.t_admit - req.t_submit),
        "ttft": float(req.t_first - req.t_submit + 1),
        "n_tokens": float(len(req.output)),
    }
    if len(req.output) > 1:
        out["tpot"] = (req.t_done - req.t_first) / (len(req.output) - 1)
    return out


def aggregate(reqs: Sequence[Request], *, ticks: int,
              util_history: Sequence[float] = (),
              tick_seconds: float = 1.0) -> Dict[str, object]:
    """Aggregate a drained run into the benchmark's metric dict.

    With ``tick_seconds=1.0`` (virtual clock) every field is a pure
    function of the workload and the engine seed — two identical runs
    produce an identical dict, which is what the regression trajectory
    (``BENCH_serving.json``) diffs against.
    """
    per = [m for m in (request_metrics(r) for r in reqs) if m is not None]
    tokens = int(sum(m["n_tokens"] for m in per))

    def scaled(key: str) -> Dict[str, float]:
        xs = [m[key] * tick_seconds for m in per if key in m]
        return _summary(xs)

    span = ticks * tick_seconds
    util = list(util_history)
    out: Dict[str, object] = {
        "completed": len(per),
        "submitted": len(reqs),
        "tokens": tokens,
        "ticks": int(ticks),
        "tick_seconds": tick_seconds,
        "queue_wait": scaled("queue_wait"),
        "ttft": scaled("ttft"),
        "tpot": scaled("tpot"),
        "tokens_per_sec": tokens / span if span > 0 else math.nan,
        "mean_util": (float(sum(util) / len(util)) if util else math.nan),
    }
    # deadline / preemption blocks: emitted only when the feature was in
    # play, so deadline-less runs aggregate to the historical dict exactly.
    # Deadlines are absolute *clock* units, so the tick-domain completion
    # is scaled by tick_seconds before the comparison (a no-op on the
    # virtual clock, where one tick is one clock unit).
    with_dl = [r for r in reqs if r.deadline is not None]
    if with_dl:
        met = sum(1 for r in with_dl
                  if r.done and r.t_done is not None
                  and (r.t_done + 1) * tick_seconds <= r.deadline)
        out["slo"] = {
            "n": len(with_dl),
            "met": met,
            "violations": len(with_dl) - met,
            "attainment": met / len(with_dl),
        }
        # admission control (plan.shed_late): requests rejected at submit
        # as provably late.  They count as violations above (never done);
        # the key appears only when shedding actually happened, so every
        # pre-shedding slo block stays byte-identical.
        n_shed = sum(1 for r in with_dl if getattr(r, "shed", False))
        if n_shed:
            out["slo"]["shed"] = n_shed
    n_preempts = sum(r.n_preempts for r in reqs)
    if n_preempts:
        out["preemption"] = {
            "preemptions": n_preempts,
            "resumes": sum(len(r.t_resumes) for r in reqs),
            "preempted_requests": sum(1 for r in reqs if r.n_preempts),
        }
    return out


def aggregate_fleet(parts: Sequence[Tuple[Sequence[Request], int,
                                          Sequence[float]]], *,
                    tick_seconds: float = 1.0) -> Dict[str, object]:
    """Merge per-replica runs into one fleet-level metrics block.

    ``parts`` is one ``(requests, ticks, util_history)`` triple per
    replica.  The merge pools the *raw per-request samples* and recomputes
    every percentile over the pooled population — never an average of
    per-replica percentiles, which has no distributional meaning (a p95
    averaged across a fast and a slow replica reports a latency no actual
    request experienced; see the skewed-fleet unit test).  The fleet span
    is the widest replica span — replicas share one virtual clock, so the
    busiest replica's tick count is the fleet's serving window and
    ``tokens_per_sec`` is true fleet throughput, not a per-replica mean.
    Utilization histories concatenate: mean_util weights each replica by
    the ticks it actually ran.

    For a single-replica fleet this is byte-identical to
    :func:`aggregate` on that replica's run — the reduction the fleet
    equivalence tests pin."""
    parts = list(parts)
    if not parts:
        raise ValueError("aggregate_fleet of an empty fleet")
    reqs = [r for rs, _, _ in parts for r in rs]
    ticks = max(int(t) for _, t, _ in parts)
    util = [u for _, _, us in parts for u in us]
    return aggregate(reqs, ticks=ticks, util_history=util,
                     tick_seconds=tick_seconds)


def scale_latencies(agg: Dict[str, object],
                    tick_seconds: float) -> Dict[str, object]:
    """Map a tick-domain aggregate to milliseconds with a measured wall
    cost per tick (e.g. from a warmed-up closed-loop calibration run).

    This is the bridge between the deterministic virtual-clock trajectory
    and real time: the tick-domain ``agg`` stays seed-exact, and this view
    is derived, host-noisy, and reported separately (the benchmark files
    keep it under their ``wall`` blocks)."""
    out: Dict[str, object] = {"tick_seconds": tick_seconds}
    for key in ("queue_wait", "ttft", "tpot"):
        s = agg[key]
        out[f"{key}_ms"] = {q: s[q] * tick_seconds * 1e3
                            for q in ("p50", "p95", "p99", "mean")}
    span_s = agg["ticks"] * tick_seconds
    out["tokens_per_sec"] = agg["tokens"] / span_s if span_s > 0 else math.nan
    return out


def format_summary(agg: Dict[str, object]) -> str:
    """Human-readable one-block summary for the serve CLI."""

    def line(name: str) -> str:
        s = agg[name]
        return (f"  {name:<10} p50={s['p50']:8.3f}  p95={s['p95']:8.3f}  "
                f"p99={s['p99']:8.3f}  mean={s['mean']:8.3f}  (n={s['n']})")

    lines = [
        f"completed {agg['completed']}/{agg['submitted']} requests, "
        f"{agg['tokens']} tokens in {agg['ticks']} ticks "
        f"({agg['tokens_per_sec']:.2f} tok/s, "
        f"mean util {agg['mean_util']:.2f})",
        line("queue_wait"), line("ttft"), line("tpot"),
    ]
    if "slo" in agg:
        s = agg["slo"]
        shed = f", {s['shed']} shed at submit" if "shed" in s else ""
        lines.append(f"  slo        {s['met']}/{s['n']} met "
                     f"({s['attainment']:.1%} attainment, "
                     f"{s['violations']} violations{shed})")
    if "preemption" in agg:
        p = agg["preemption"]
        lines.append(f"  preempt    {p['preemptions']} evictions / "
                     f"{p['resumes']} resumes over "
                     f"{p['preempted_requests']} requests")
    return "\n".join(lines)
