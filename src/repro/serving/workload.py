"""Serving-load workloads: arrival processes, traces, and the load driver.

The paper's headline scenario is real-time serving — batch-of-1 requests
arriving *asynchronously*, where queueing and utilization (not raw BLAS
throughput) decide the win over the V100/Brainwave baselines.  This module
generates those arrival patterns and replays them against the
continuous-batching :class:`~repro.serving.engine.ServingEngine`:

* :func:`poisson_arrivals` — memoryless arrivals at a fixed rate (the
  paper's serving experiment, and the standard open-loop load model);
* :func:`mmpp_arrivals` — a two-state Markov-modulated Poisson process
  (bursty traffic: a quiet state and a burst state with exponentially
  distributed dwell times), the classic model for flash-crowd load;
* :func:`load_trace` / :func:`save_trace` — replayable JSON trace files,
  so a production arrival log can be re-served bit-for-bit.

Time is *virtual* by default: one engine tick is one unit of a
:class:`VirtualClock`, so a workload run is a pure function of
``(workload, seed)`` — tests and the regression benchmark never depend on
wall time.  :class:`WallClock` swaps real time in for live measurement
(``launch/serve.py --clock wall``); the engine itself only ever sees tick
stamps, so its telemetry stays deterministic either way.
"""

from __future__ import annotations

import dataclasses
import json
import math
import time
from typing import List, Optional, Sequence, Tuple

import numpy as np

from repro.plan.plan import WorkloadProfile
from repro.serving.engine import Request, ServingEngine

ARRIVAL_KINDS = ("poisson", "mmpp", "trace")


@dataclasses.dataclass(frozen=True)
class WorkloadItem:
    """One request in an arrival schedule (times in clock units).

    ``deadline`` is an optional *absolute* completion deadline in the same
    clock units as ``t`` (so slack = deadline - t).  It feeds the EDF
    scheduler and the SLO-attainment metric; absent means no deadline —
    the request sorts last under EDF and contributes no SLO sample.  The
    JSONL trace schema mirrors this: the ``deadline`` field is optional
    and traces written before it existed load unchanged.
    """

    t: float
    prompt: Tuple[int, ...]
    max_new_tokens: int = 16
    eos_id: Optional[int] = None
    deadline: Optional[float] = None

    def to_json(self) -> dict:
        d = {"t": self.t, "prompt": list(self.prompt),
             "max_new_tokens": self.max_new_tokens}
        if self.eos_id is not None:
            d["eos_id"] = self.eos_id
        if self.deadline is not None:
            d["deadline"] = self.deadline
        return d

    @staticmethod
    def from_json(d: dict) -> "WorkloadItem":
        """Parse one trace record, naming the offending field on bad input
        (a malformed line in a multi-MB JSONL trace is otherwise a bare
        ``KeyError: 't'`` with no hint of where or what)."""
        if not isinstance(d, dict):
            raise ValueError(f"trace record must be a JSON object, "
                             f"got {type(d).__name__}")
        for field in ("t", "prompt"):
            if field not in d:
                raise ValueError(f"trace record missing required field "
                                 f"{field!r} (has: {sorted(d)})")
        unknown = set(d) - {"t", "prompt", "max_new_tokens", "eos_id",
                            "deadline"}
        if unknown:
            raise ValueError(f"trace record has unknown fields "
                             f"{sorted(unknown)}")
        try:
            t = float(d["t"])
        except (TypeError, ValueError):
            raise ValueError(f"field 't' must be a number, got {d['t']!r}")
        if not isinstance(d["prompt"], (list, tuple)):
            raise ValueError(f"field 'prompt' must be a list of token ids, "
                             f"got {type(d['prompt']).__name__}")
        try:
            prompt = tuple(int(x) for x in d["prompt"])
        except (TypeError, ValueError):
            raise ValueError(f"field 'prompt' must contain integer token "
                             f"ids, got {d['prompt']!r}")
        try:
            max_new = int(d.get("max_new_tokens", 16))
        except (TypeError, ValueError):
            raise ValueError(f"field 'max_new_tokens' must be an int, "
                             f"got {d['max_new_tokens']!r}")
        dl = d.get("deadline")
        try:
            dl = None if dl is None else float(dl)
        except (TypeError, ValueError):
            raise ValueError(f"field 'deadline' must be a number, "
                             f"got {dl!r}")
        return WorkloadItem(t, prompt, max_new, d.get("eos_id"), dl)


# ---------------------------------------------------------------------------
# Arrival processes
# ---------------------------------------------------------------------------


def poisson_arrivals(rate: float, duration: float,
                     rng: np.random.Generator) -> List[float]:
    """Arrival times of a homogeneous Poisson process on ``[0, duration)``
    (i.i.d. exponential inter-arrival gaps at ``rate`` per time unit)."""
    if rate <= 0:
        raise ValueError(f"rate must be > 0, got {rate}")
    times, t = [], 0.0
    while True:
        t += rng.exponential(1.0 / rate)
        if t >= duration:
            return times
        times.append(t)


def mmpp_arrivals(rates: Tuple[float, float], dwell: Tuple[float, float],
                  duration: float, rng: np.random.Generator) -> List[float]:
    """Two-state Markov-modulated Poisson process: the arrival rate
    switches between ``rates[0]`` (quiet) and ``rates[1]`` (burst), holding
    each state for an Exp(1/dwell[s]) time — bursty open-loop load."""
    if min(rates) <= 0 or min(dwell) <= 0:
        raise ValueError(f"rates/dwell must be > 0, got {rates}, {dwell}")
    times: List[float] = []
    t, state = 0.0, 0
    t_switch = rng.exponential(dwell[0])
    while t < duration:
        gap = rng.exponential(1.0 / rates[state])
        if t + gap >= t_switch:
            # state flips before the next arrival lands: restart the
            # (memoryless) arrival clock from the switch point
            t = t_switch
            state = 1 - state
            t_switch = t + rng.exponential(dwell[state])
            continue
        t += gap
        if t < duration:
            times.append(t)
    return times


PROMPT_DISTS = ("uniform", "fixed", "lognormal", "bimodal")

# bimodal long-mode weight: a long-TAIL mixture, rare enough that p95
# latencies reflect the short mode (the requests a deadline scheduler can
# actually help) while the occasional giant prompt still clogs slots
BIMODAL_LONG_FRAC = 0.08


def _prompt_length(rng: np.random.Generator, dist: str,
                   lo: int, hi: int, long_hi: int) -> int:
    """One prompt length draw under the named distribution.

    ``uniform`` draws exactly as the pre-distribution code did (same rng
    call sequence, so seeded default workloads are unchanged).  ``fixed``
    is the range midpoint every time.  ``lognormal`` has its median at
    the midpoint with a long right tail clipped to ``long_hi``.
    ``bimodal`` mixes the short uniform range with a long mode on
    ``[3*hi, long_hi]`` at ``BIMODAL_LONG_FRAC`` weight — the
    long-tail-prompt regime where preemptive scheduling pays."""
    if dist == "uniform":
        return int(rng.integers(lo, hi + 1))
    if dist == "fixed":
        return (lo + hi) // 2
    if dist == "lognormal":
        x = rng.lognormal(mean=math.log((lo + hi) / 2.0), sigma=0.6)
        return int(min(max(int(round(x)), lo), long_hi))
    if dist == "bimodal":
        if rng.uniform() >= BIMODAL_LONG_FRAC:
            return int(rng.integers(lo, hi + 1))
        return int(rng.integers(min(3 * hi, long_hi), long_hi + 1))
    raise ValueError(f"unknown prompt_dist {dist!r}; known: {PROMPT_DISTS}")


def synthesize(times: Sequence[float], rng: np.random.Generator, *,
               vocab_size: int, prompt_len: Tuple[int, int] = (4, 12),
               max_new_tokens: Tuple[int, int] = (8, 16),
               eos_id: Optional[int] = None,
               prompt_dist: str = "uniform",
               prompt_len_long: Optional[int] = None,
               heavy_decode: Optional[Tuple[float, int, int]] = None,
               deadline_slack: Optional[float] = None,
               deadline_frac: float = 1.0) -> List[WorkloadItem]:
    """Attach seeded random prompts/lengths to a list of arrival times.

    ``prompt_dist`` selects the prompt-length distribution (see
    :func:`_prompt_length`); ``prompt_len_long`` caps the long tail
    (default ``4 * prompt_len[1]``).  ``heavy_decode=(frac, lo, hi)``
    turns a seeded ``frac`` of requests into heavy-decode jobs with
    ``max_new_tokens`` drawn from ``[lo, hi]`` — on the virtual clock a
    request's slot-occupancy *is* its decode length, so this is the
    long-tail *service-time* mixture (the overload regime where
    preempting a slot-hogging job pays).  ``deadline_slack``, when set,
    stamps each request with the decode-proportional absolute deadline
    ``t + deadline_slack * max_new_tokens`` (finish within ``slack``
    times your own decode length — the SLO-scale convention, in the same
    tick units the engine serves in).  ``deadline_frac`` < 1 leaves a
    seeded random fraction of requests deadline-less (best-effort
    traffic mixed into the SLO stream)."""
    long_hi = prompt_len_long if prompt_len_long is not None \
        else 4 * prompt_len[1]
    items = []
    for t in times:
        n = _prompt_length(rng, prompt_dist, prompt_len[0], prompt_len[1],
                           long_hi)
        m = int(rng.integers(max_new_tokens[0], max_new_tokens[1] + 1))
        if heavy_decode is not None and rng.uniform() < heavy_decode[0]:
            m = int(rng.integers(heavy_decode[1], heavy_decode[2] + 1))
        prompt = tuple(int(x) for x in rng.integers(0, vocab_size, size=n))
        deadline = None
        if deadline_slack is not None:
            if deadline_frac >= 1.0 or rng.uniform() < deadline_frac:
                deadline = float(t) + deadline_slack * m
        items.append(WorkloadItem(float(t), prompt, m, eos_id, deadline))
    return items


def make_workload(kind: str, *, rate: float, duration: float, seed: int,
                  vocab_size: int,
                  prompt_len: Tuple[int, int] = (4, 12),
                  max_new_tokens: Tuple[int, int] = (8, 16),
                  burst_factor: float = 4.0,
                  dwell: Tuple[float, float] = (16.0, 4.0),
                  prompt_dist: str = "uniform",
                  prompt_len_long: Optional[int] = None,
                  heavy_decode: Optional[Tuple[float, int, int]] = None,
                  deadline_slack: Optional[float] = None,
                  deadline_frac: float = 1.0,
                  trace_path: Optional[str] = None) -> List[WorkloadItem]:
    """One-stop workload builder for the CLI and the benchmark.

    ``kind``: "poisson" | "mmpp" | "trace".  For "mmpp" the quiet rate is
    ``rate`` and the burst rate is ``rate * burst_factor``.  The result is
    a pure function of the arguments (seeded ``numpy`` generator); with
    the default ``prompt_dist``/deadline arguments the draw sequence is
    exactly the pre-deadline one, so historical seeds replay unchanged.
    ``prompt_dist`` / ``deadline_slack`` / ``deadline_frac`` are forwarded
    to :func:`synthesize` (deadlines stamp an absolute, service-
    proportional SLO per request; traces carry their own deadlines).
    """
    if kind == "trace":
        if not trace_path:
            raise ValueError("kind='trace' requires trace_path")
        return load_trace(trace_path)
    rng = np.random.default_rng(seed)
    if kind == "poisson":
        times = poisson_arrivals(rate, duration, rng)
    elif kind == "mmpp":
        times = mmpp_arrivals((rate, rate * burst_factor), dwell, duration,
                              rng)
    else:
        raise ValueError(f"unknown arrival kind {kind!r}; "
                         f"known: {ARRIVAL_KINDS}")
    return synthesize(times, rng, vocab_size=vocab_size,
                      prompt_len=prompt_len, max_new_tokens=max_new_tokens,
                      prompt_dist=prompt_dist, prompt_len_long=prompt_len_long,
                      heavy_decode=heavy_decode,
                      deadline_slack=deadline_slack,
                      deadline_frac=deadline_frac)


def profile_items(profile: "WorkloadProfile", *, vocab_size: int, seed: int,
                  duration: Optional[float] = None) -> List[WorkloadItem]:
    """Materialize a :class:`repro.plan.WorkloadProfile` into arrival
    items — the declarative half of a serving cell turned into the exact
    seeded draw sequence :func:`make_workload` has always produced, so a
    profile with historical field values replays historical workloads
    byte-for-byte.  ``duration`` fills in a profile whose own duration is
    None (the benchmark's fast/full switch)."""
    span = profile.duration if profile.duration is not None else duration
    if span is None and profile.kind != "trace":
        raise ValueError("workload profile has no duration and none was "
                         "provided")
    return make_workload(
        profile.kind, rate=profile.rate, duration=span, seed=seed,
        vocab_size=vocab_size, prompt_len=profile.prompt_len,
        max_new_tokens=profile.max_new_tokens,
        burst_factor=profile.burst_factor, dwell=profile.dwell,
        prompt_dist=profile.prompt_dist,
        prompt_len_long=profile.prompt_len_long,
        heavy_decode=profile.heavy_decode,
        deadline_slack=profile.deadline_slack,
        deadline_frac=profile.deadline_frac,
        trace_path=profile.trace_path)


# ---------------------------------------------------------------------------
# Trace files
# ---------------------------------------------------------------------------


def save_trace(path: str, items: Sequence[WorkloadItem]) -> None:
    """Write a workload as JSON lines (one request per line, sorted by t)."""
    with open(path, "w") as f:
        for it in sorted(items, key=lambda it: it.t):
            f.write(json.dumps(it.to_json()) + "\n")


def load_trace(path: str) -> List[WorkloadItem]:
    """Load a JSONL arrival trace; a malformed line (truncated JSON, bad
    field type, missing field) raises one ValueError naming the file,
    line number, and problem rather than a bare decode/KeyError."""
    items = []
    with open(path) as f:
        for lineno, line in enumerate(f, start=1):
            if not line.strip():
                continue
            try:
                d = json.loads(line)
            except json.JSONDecodeError as e:
                raise ValueError(
                    f"{path}:{lineno}: not valid JSON ({e.msg} at column "
                    f"{e.colno}) — truncated write?") from None
            try:
                items.append(WorkloadItem.from_json(d))
            except ValueError as e:
                raise ValueError(f"{path}:{lineno}: {e}") from None
    return sorted(items, key=lambda it: it.t)


# ---------------------------------------------------------------------------
# Clocks + driver
# ---------------------------------------------------------------------------


class VirtualClock:
    """Deterministic clock: one engine tick advances time by ``tick_cost``
    units, and idle gaps fast-forward to the next arrival instantly."""

    def __init__(self, tick_cost: float = 1.0):
        self.tick_cost = tick_cost
        self.now = 0.0
        self.busy_seconds = 0.0   # filled by drive()

    def tick(self) -> None:
        self.now += self.tick_cost

    def skip_to(self, t: float) -> None:
        self.now = max(self.now, t)


class WallClock:
    """Real time (seconds since construction); idle gaps are slept away."""

    def __init__(self):
        self._t0 = time.perf_counter()
        self.busy_seconds = 0.0   # filled by drive()

    @property
    def now(self) -> float:
        return time.perf_counter() - self._t0

    def tick(self) -> None:
        pass

    def skip_to(self, t: float) -> None:
        dt = t - self.now
        if dt > 0:
            time.sleep(dt)


def drive(engine: ServingEngine, items: Sequence[WorkloadItem],
          clock=None, max_ticks: int = 1_000_000,
          sync_every: Optional[int] = None,
          on_tick=None) -> List[Request]:
    """Replay a workload against an engine: submit each item when the clock
    reaches its arrival time, run the engine until fully drained.  Returns
    the Request objects (all done) in arrival order.

    Each ``engine.step()`` may run a multi-tick on-device chunk (the
    engine's ``sync_every``); the clock advances once per *engine tick*,
    and ``sync_every`` here caps the per-step tick budget on top of the
    engine's own setting.  On a :class:`VirtualClock` the budget is also
    bounded by the next pending arrival, so admission lands on exactly the
    tick a per-tick loop would use — tick stamps are then independent of
    ``sync_every`` (exact for the default ``tick_cost=1.0``).  On a
    :class:`WallClock` arrivals can be admitted up to a chunk late; that
    is the latency/throughput trade the knob exposes.

    Sets ``clock.busy_seconds`` to the wall time spent inside
    ``engine.step()`` (idle waits for arrivals excluded), so wall-clock
    callers can derive an honest per-tick cost even at low arrival rates.

    ``on_tick`` (optional) is called as ``on_tick(engine.ticks)`` after
    every step that advanced the clock — the hook the serve CLI's
    ``--live-metrics`` uses to print its rolling window without drive()
    knowing anything about observability.
    """
    if clock is None:
        clock = VirtualClock()
    pending = sorted(items, key=lambda it: it.t)
    reqs: List[Request] = []
    i = 0
    busy = 0.0
    for _ in range(max_ticks):
        if i < len(pending) and not engine.has_work():
            clock.skip_to(pending[i].t)  # idle: jump/sleep to next arrival
        while i < len(pending) and pending[i].t <= clock.now:
            it = pending[i]
            reqs.append(engine.submit(list(it.prompt), it.max_new_tokens,
                                      it.eos_id, deadline=it.deadline))
            i += 1
        if not engine.has_work() and i >= len(pending):
            clock.busy_seconds = busy
            return reqs
        budget = sync_every
        if i < len(pending) and isinstance(clock, VirtualClock):
            # never decode past the next arrival: ticks until it lands
            gap = pending[i].t - clock.now
            due = max(1, math.ceil(gap / clock.tick_cost)) if gap > 0 else 1
            budget = due if budget is None else min(budget, due)
        t0 = time.perf_counter()
        before = engine.ticks
        engine.step(max_ticks=budget)
        busy += time.perf_counter() - t0
        for _ in range(engine.ticks - before):
            clock.tick()
        if on_tick is not None and engine.ticks != before:
            on_tick(engine.ticks)
    raise RuntimeError(f"workload did not drain within {max_ticks} steps "
                       f"({i}/{len(pending)} submitted)")


def offered_load(items: Sequence[WorkloadItem],
                 duration: Optional[float] = None) -> float:
    """Offered tokens per clock unit (prompt + decode), for sizing sweeps.
    ``duration`` is the workload span; when omitted (e.g. a replayed trace
    with no declared span) the last arrival time stands in for it."""
    if not items:
        return 0.0
    span = duration if duration else max(it.t for it in items)
    if span <= 0:
        return math.inf
    toks = sum(len(it.prompt) + it.max_new_tokens for it in items)
    return toks / span
