"""Request schedulers: all admission/preemption *policy* in one place.

The paper's core claim is that serving RNNs well is a scheduling problem
— cross-kernel optimization over general loop constructs, not a pile of
BLAS calls — and "Measuring scheduling efficiency of RNNs for NLP
applications" shows the scheduling policy dominates RNN serving
efficiency.  The :class:`~repro.serving.engine.ServingEngine` therefore
keeps *mechanism* (prefill, the fused decode chunk, slot state) and
delegates every "who runs next" decision to a :class:`Scheduler`:

* which queued requests to admit when slots free (:meth:`Scheduler.pick`);
* which running requests to *preempt* to make room for more urgent
  arrivals (:meth:`Scheduler.victims`) — only :class:`EDF` preempts.

Policies
--------
``fcfs``
    First-come-first-served: admit in arrival order.  The baseline, and
    the order every virtual-clock trajectory in ``BENCH_serving.json``
    was recorded under — its schedules are bit-identical to the
    pre-refactor engine.
``spf``
    Shortest-prompt-first: admit the cheapest prefill first (FIFO among
    equal lengths).  Approximates shortest-job-first on the prefill cost.
``edf``
    Earliest-deadline-first over the optional per-request ``deadline``
    (clock units; see :mod:`repro.serving.workload`).  Requests without a
    deadline sort last (infinite deadline) and fall back to FIFO among
    themselves.  With ``preempt=True`` it is *preemptive*: when no slot
    is free and a queued request's deadline is strictly earlier than a
    running request's, the latest-deadline running request is evicted to
    host memory (see :mod:`repro.serving.slotstate`) and resumed —
    bit-exactly — once a slot frees.  Preemption pays under overload
    with long-tail prompts: a long, slack request no longer blocks a
    burst of tight-deadline arrivals for its whole decode.

The queue lives *in* the scheduler (the engine never touches ordering);
all state is host-side and deterministic, so a policy is a pure function
of the submission/completion sequence.  ``SCHEDULERS`` is the single
registry: the engine validates against it and the ``--policy`` CLI
choices are generated from it, so the two cannot drift (enforced by the
benchmark smoke guard).

Telemetry lives in a :class:`repro.obs.registry.MetricsRegistry` (the
engine passes its own in, so ``engine.reset_telemetry()`` covers the
scheduler counters too): ``scheduler.submitted`` / ``scheduler.picked``
/ ``scheduler.requeued`` counters, a derived ``scheduler.queue_depth``
gauge, and a ``scheduler.peak_queued`` high-water mark, surfaced with
stable keys via :meth:`Scheduler.stats`.  Subclasses implement
:meth:`Scheduler._select`; the public :meth:`Scheduler.pick` wraps it
with the bookkeeping so no policy can forget to count.
"""

from __future__ import annotations

import math
from collections import deque
from typing import TYPE_CHECKING, Dict, List, Optional, Sequence, Tuple, Type

from repro.obs.registry import MetricsRegistry

if TYPE_CHECKING:  # pragma: no cover - import cycle (engine imports us)
    from repro.serving.engine import Request


def _deadline(req: "Request") -> float:
    """EDF sort key: an absent deadline is infinitely late."""
    return math.inf if req.deadline is None else float(req.deadline)


class Scheduler:
    """Base policy: owns the pending queue, decides admission order.

    Subclasses override :meth:`pick` (and :meth:`victims` if preemptive).
    ``pick(n)`` must *remove* the returned requests from the queue; a
    request that could not be admitted after all (no capacity left in the
    same engine tick) is handed back via :meth:`requeue_front`.
    """

    name: str = "base"
    preemptive: bool = False

    def __init__(self, registry: Optional[MetricsRegistry] = None) -> None:
        self.queue: deque = deque()
        self.metrics = registry if registry is not None else MetricsRegistry()
        self._submitted = self.metrics.counter(
            "scheduler.submitted", "requests enqueued")
        self._picked = self.metrics.counter(
            "scheduler.picked", "requests handed to the engine for admission")
        self._requeued = self.metrics.counter(
            "scheduler.requeued", "requests handed back (no capacity / "
            "preemption victims)")
        self._peak = self.metrics.gauge(
            "scheduler.peak_queued", "high-water mark of the pending queue")
        self.metrics.gauge("scheduler.queue_depth",
                           "current pending-queue length",
                           fn=lambda: float(len(self.queue)))

    # ------------------------------------------------------------- queue ops
    def submit(self, req: "Request") -> None:
        """Enqueue a new request."""
        self.queue.append(req)
        self._submitted.inc()
        self._peak.set(max(self._peak.value, float(len(self.queue))))

    def requeue_front(self, req: "Request") -> None:
        """Hand back a request the engine could not place this tick (or a
        just-evicted victim): it keeps its original submission order
        (``uid``, assigned monotonically at submit) and goes to the queue
        front so FIFO-style policies retry it first."""
        self.queue.appendleft(req)
        self._requeued.inc()
        self._peak.set(max(self._peak.value, float(len(self.queue))))

    def __len__(self) -> int:
        return len(self.queue)

    def stats(self) -> Dict[str, float]:
        """Counter snapshot under stable keys (a registry view)."""
        return self.metrics.view({
            "submitted": "scheduler.submitted",
            "picked": "scheduler.picked",
            "requeued": "scheduler.requeued",
            "queue_depth": "scheduler.queue_depth",
            "peak_queued": "scheduler.peak_queued",
        })

    # --------------------------------------------------------------- policy
    def pick(self, n: int) -> List["Request"]:
        """Remove and return up to ``n`` requests to admit, in order.

        Wraps the subclass :meth:`_select` with counter bookkeeping, so
        every policy counts picks identically."""
        picked = self._select(n)
        self._picked.inc(len(picked))
        return picked

    def _select(self, n: int) -> List["Request"]:
        """Policy hook: remove and return up to ``n`` requests."""
        raise NotImplementedError

    def victims(self, running: Sequence[Tuple[int, "Request"]],
                n_free: int) -> List[int]:
        """Slots to evict so more urgent queued requests can run.

        ``running`` is ``[(slot, request), ...]``; ``n_free`` is how many
        slots are already free.  Non-preemptive policies never evict."""
        return []

    def _pop_indices(self, order: Sequence[int]) -> List["Request"]:
        picked = [self.queue[j] for j in order]
        for j in sorted(order, reverse=True):
            del self.queue[j]
        return picked


class FCFS(Scheduler):
    """First-come-first-served (arrival order)."""

    name = "fcfs"

    def _select(self, n: int) -> List["Request"]:
        n = min(n, len(self.queue))
        return [self.queue.popleft() for _ in range(n)]


class SPF(Scheduler):
    """Shortest-prompt-first (FIFO among equal prompt lengths)."""

    name = "spf"

    def _select(self, n: int) -> List["Request"]:
        n = min(n, len(self.queue))
        order = sorted(range(len(self.queue)),
                       key=lambda j: (len(self.queue[j].prompt), j))[:n]
        return self._pop_indices(order)


class EDF(Scheduler):
    """Earliest-deadline-first; optionally preemptive.

    Admission: queued requests sorted by (deadline, submission order) —
    deadline-less requests run last, FIFO among themselves.  Preemption
    (``preempt=True``): pairs the most urgent waiters against the
    latest-deadline runners and evicts a runner only when the waiter's
    deadline is *strictly* earlier — equal deadlines never thrash, and a
    deadline-less waiter never preempts anything.
    """

    name = "edf"

    def __init__(self, preempt: bool = False,
                 registry: Optional[MetricsRegistry] = None) -> None:
        super().__init__(registry)
        self.preemptive = bool(preempt)

    def _key(self, req: "Request") -> Tuple[float, int]:
        # uid is assigned monotonically at engine.submit, so it IS the
        # submission order — an evicted request keeps its original rank
        return (_deadline(req), req.uid)

    def _select(self, n: int) -> List["Request"]:
        n = min(n, len(self.queue))
        order = sorted(range(len(self.queue)),
                       key=lambda j: self._key(self.queue[j]))[:n]
        return self._pop_indices(order)

    def victims(self, running: Sequence[Tuple[int, "Request"]],
                n_free: int) -> List[int]:
        if not self.preemptive or not self.queue:
            return []
        waiting = sorted(self.queue, key=self._key)
        runners = sorted(running, key=lambda sr: self._key(sr[1]),
                         reverse=True)          # latest deadline first
        out: List[int] = []
        for w in waiting:
            if n_free > 0:        # a slot is free anyway: no eviction needed
                n_free -= 1
                continue
            if not runners:
                break
            slot, victim = runners[0]
            if _deadline(w) < _deadline(victim):
                out.append(slot)
                runners.pop(0)
            else:                 # waiters only get less urgent from here
                break
        return out


SCHEDULERS: Dict[str, Type[Scheduler]] = {
    FCFS.name: FCFS,
    SPF.name: SPF,
    EDF.name: EDF,
}

POLICIES: Tuple[str, ...] = tuple(SCHEDULERS)


def make_scheduler(policy: str, *, preempt: bool = False,
                   registry: Optional[MetricsRegistry] = None) -> Scheduler:
    """Instantiate a registered policy.  ``preempt`` is only meaningful
    for preemption-capable policies (EDF); requesting it elsewhere is an
    error rather than a silent no-op.  ``registry`` shares the caller's
    :class:`~repro.obs.registry.MetricsRegistry` (the engine passes its
    own, so one ``reset()`` covers scheduler counters too)."""
    cls = SCHEDULERS.get(policy)
    if cls is None:
        raise ValueError(f"unknown policy {policy!r}; known: {POLICIES}")
    if cls is EDF:
        return EDF(preempt=preempt, registry=registry)
    if preempt:
        raise ValueError(f"policy {policy!r} is non-preemptive; "
                         f"preempt=True requires one of: "
                         f"{[n for n, c in SCHEDULERS.items() if c is EDF]}")
    return cls(registry)
