"""Unified slot-state manager: the serving cache as an addressable store.

The engine's serving state is a cache pytree (stacked-period KV rings,
rwkv ``wkv``/shift states, ssd/conv states, per-slot ``lengths``) plus
host-side per-slot control vectors (next token, active mask, EOS id,
remaining budget).  Pre-refactor this knowledge was smeared through
``ServingEngine`` and only flowed one way (prefill rows scattered *into*
slots).  :class:`SlotManager` centralizes it behind a symmetric
gather/scatter API keyed on the batch-axis contract that
:meth:`repro.models.lm.LM.cache_batch_axes` declares for every cache
leaf — no layer-kind special cases, so any architecture the LM wrapper
serves is preemptable for free.

The symmetric half is what enables preemption: :meth:`snapshot` gathers
one slot's full device state into a host :class:`SlotSnapshot` (a single
``device_get``), and :meth:`restore` scatters it back into *any* free
slot later.  The round trip is bit-exact — device→host→device copies
preserve every dtype's bits, KV ring positions are absolute (slot-
independent), and recurrent states carry no slot identity — so under
greedy decoding an evicted request resumes the exact token trajectory it
would have produced uninterrupted, wherever and whenever it lands
(property-tested across rwkv/dense/hymba in ``tests/test_preemption.py``).
Stochastic sampling consumes one engine-global PRNG key per batch tick,
so there the guarantee is schedule-relative: the trajectory is unchanged
iff the request decodes in the same slot on the same ticks (e.g. an
evict + next-step resume into the same slot is a provable no-op; a
delayed or cross-slot resume re-rolls the randomness, which is sampling
noise, not state corruption).
"""

from __future__ import annotations

import dataclasses
from typing import Any, Dict, List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.models.lm import LM
from repro.obs.registry import MetricsRegistry


def _index(a, ax: int, idx):
    ix = [slice(None)] * a.ndim
    ix[ax] = idx
    return tuple(ix)


def gather_slots(cache, axes, slots: Sequence[int]):
    """Gather the given slot columns out of every cache leaf (device op).

    ``axes`` is the leaf→batch-axis pytree from ``LM.cache_batch_axes``;
    the result keeps a slot axis of size ``len(slots)`` in every leaf, so
    it scatters back with :func:`scatter_slots` unchanged."""
    idx = jnp.asarray(list(slots), jnp.int32)
    return jax.tree.map(lambda a, ax: jnp.take(a, idx, axis=ax),
                        cache, axes)


def scatter_slots(cache, axes, slots: Sequence[int], sub):
    """Scatter slot columns (one per entry of ``slots``) into the cache —
    the inverse of :func:`gather_slots`; one pytree op for the group."""
    idx = jnp.asarray(list(slots), jnp.int32)
    return jax.tree.map(
        lambda a, s, ax: a.at[_index(a, ax, idx)].set(
            jnp.asarray(s).astype(a.dtype)),
        cache, sub, axes)


@dataclasses.dataclass
class SlotSnapshot:
    """One slot's complete decode state, on host.

    ``cache_col`` is the host copy of every cache leaf's slot column
    (slot axis kept, size 1); ``next_token`` is the last sampled token —
    the decode input the slot would have consumed next.  Together with
    the request's own host state (``output``, ``max_new_tokens``,
    ``eos_id``) this is everything needed to resume bit-exactly."""

    cache_col: Any
    next_token: int

    def nbytes(self) -> int:
        return int(sum(np.asarray(leaf).nbytes
                       for leaf in jax.tree.leaves(self.cache_col)))


class SlotManager:
    """Owns the decode-slot state: cache pytree + host control mirrors.

    The engine asks it *where* things go (free/occupied slots), moves
    state through it (prefill insertion, snapshot/restore, post-chunk
    refresh), and never touches the cache layout directly.  Policy — who
    gets a slot — stays in :mod:`repro.serving.scheduler`."""

    def __init__(self, model: LM, max_batch: int, max_len: int,
                 registry: Optional[MetricsRegistry] = None):
        self.max_batch = max_batch
        self.max_len = max_len
        self._init_storage(model, max_batch, max_len)
        self._init_byte_accounting(model)
        self._init_col_specs(model)
        self.slots: List[Optional[object]] = [None] * max_batch
        # host mirrors of the per-slot device control vectors
        self.next_token = np.zeros((max_batch,), np.int32)
        self.active = np.zeros((max_batch,), bool)
        self.eos = np.full((max_batch,), -1, np.int32)
        self.remaining = np.zeros((max_batch,), np.int32)
        # telemetry: shared with the engine's registry when passed in,
        # so engine.reset_telemetry() covers slot counters too
        self.metrics = registry if registry is not None else MetricsRegistry()
        self._snapshots = self.metrics.counter(
            "slots.snapshots", "slot columns gathered to host (evictions)")
        self._restores = self.metrics.counter(
            "slots.restores", "snapshots scattered back into slots")
        self._snapshot_bytes = self.metrics.counter(
            "slots.snapshot_bytes", "host bytes held by eviction snapshots")
        self._prefill_inserts = self.metrics.counter(
            "slots.prefill_inserts", "prefill rows scattered into slots")
        self.metrics.gauge("slots.active", "occupied decode slots",
                           fn=lambda: float(self.n_active()))
        self.metrics.gauge("slots.free", "free decode slots",
                           fn=lambda: float(self.max_batch - self.n_active()))
        # fragmentation gauges — shared names across dense/paged layouts so
        # benchmarks sample one vocabulary; dense semantics: the whole
        # cache is committed up front, so bytes_resident is constant and
        # the waste is everything not covered by live tokens
        self.metrics.gauge(
            "slots.blocks_free", "free cache-pool blocks (0 under dense)",
            fn=lambda: float(self.blocks_free()))
        self.metrics.gauge(
            "slots.bytes_resident", "cache bytes committed to slot state",
            fn=lambda: float(self.bytes_resident()))
        self.metrics.gauge(
            "slots.padding_waste",
            "committed cache bytes not backing live tokens",
            fn=lambda: float(self.padding_waste()))

    # ----------------------------------------------------------- storage seam
    def _init_storage(self, model: LM, max_batch: int, max_len: int) -> None:
        """Allocate the backing store.  The dense layout owns the cache
        pytree directly; :class:`repro.serving.paged.PagedSlotManager`
        overrides this to build block pools instead and serves ``cache``
        as a materialized view property."""
        self.cache = model.init_cache(max_batch, max_len)
        self.axes = model.cache_batch_axes(self.cache)
        self.page_axes = model.cache_page_axes(self.cache)

    def ensure_chunk(self, budget: int) -> None:
        """Hook called by the engine before each decode chunk of up to
        ``budget`` ticks.  Dense layout: no-op (every slot's full column
        is pre-committed).  Paged layout: extends each active slot's block
        table to cover the chunk's ring writes."""

    # -------------------------------------------------------- byte accounting
    def _init_byte_accounting(self, model: LM) -> None:
        """Precompute per-token / per-slot byte factors from the dense
        leaf shapes: pageable leaves (KV rings) group by ring length S
        (local-window rings saturate before full-length ones), everything
        else is per-slot state.  Both layouts share these factors, so the
        dense and paged fragmentation gauges are directly comparable."""
        paxes = {tuple(p): ax for p, ax in jax.tree_util.tree_leaves_with_path(
            self.page_axes, is_leaf=lambda x: x is None)}
        self._ring_token_bytes: Dict[int, int] = {}   # ring length S -> bytes
        self._per_slot_bytes = 0
        self._dense_cache_bytes = 0
        for path, spec in jax.tree_util.tree_leaves_with_path(
                model.cache_specs(self.max_batch, self.max_len)):
            nbytes = spec.nbytes
            self._dense_cache_bytes += nbytes
            lax_ = paxes[tuple(path)]
            if lax_ is None:
                self._per_slot_bytes += nbytes // self.max_batch
            else:
                s = int(spec.shape[lax_])
                per_tok = nbytes // (self.max_batch * s)
                self._ring_token_bytes[s] = (
                    self._ring_token_bytes.get(s, 0) + per_tok)

    # ------------------------------------------------- snapshot compatibility
    def _init_col_specs(self, model: LM) -> None:
        """Precompute the expected per-slot snapshot column spec — leaf
        path → (shape with the slot axis collapsed to 1, dtype) — from the
        model's cache specs.  This is the compatibility contract a
        :class:`SlotSnapshot` must meet to be restorable here; it is
        independent of ``max_batch`` (the slot axis is normalized away)
        but pins architecture, ``max_len`` (ring lengths) and cache
        dtypes.  Shared by both layouts: the paged manager snapshots and
        restores through the same dense-view columns."""
        ax_by_path = {tuple(p): ax for p, ax in
                      jax.tree_util.tree_leaves_with_path(self.axes)}
        self._col_specs: Dict[str, Tuple[Tuple[int, ...], str]] = {}
        for path, spec in jax.tree_util.tree_leaves_with_path(
                model.cache_specs(self.max_batch, self.max_len)):
            ax = ax_by_path[tuple(path)]
            shape = list(spec.shape)
            shape[ax] = 1
            self._col_specs[jax.tree_util.keystr(path)] = (
                tuple(shape), str(np.dtype(spec.dtype)))

    def snapshot_compat_errors(self, snap: SlotSnapshot) -> List[str]:
        """Field-naming compatibility report for restoring ``snap`` into
        this manager.  Empty list ⇒ compatible.  Each entry names the
        offending cache leaf (pytree path) and how it diverges — missing
        leaf, extra leaf, shape or dtype mismatch — so a cross-engine
        transit between engines whose arch/max_len/cache spec differ
        fails with a readable diagnosis instead of a deep scatter error."""
        got: Dict[str, Tuple[Tuple[int, ...], str]] = {}
        for path, leaf in jax.tree_util.tree_leaves_with_path(snap.cache_col):
            a = np.asarray(leaf)
            got[jax.tree_util.keystr(path)] = (tuple(a.shape), str(a.dtype))
        want = self._col_specs
        errs: List[str] = []
        for name in sorted(set(want) - set(got)):
            errs.append(f"{name}: required by this engine's cache spec but "
                        f"missing from the snapshot (different architecture?)")
        for name in sorted(set(got) - set(want)):
            errs.append(f"{name}: present in the snapshot but not in this "
                        f"engine's cache spec (different architecture?)")
        for name in sorted(set(want) & set(got)):
            w_shape, w_dtype = want[name]
            g_shape, g_dtype = got[name]
            if g_shape != w_shape:
                errs.append(
                    f"{name}: slot-column shape {g_shape} != expected "
                    f"{w_shape} (origin engine's arch/max_len differs)")
            elif g_dtype != w_dtype:
                errs.append(f"{name}: dtype {g_dtype} != expected {w_dtype}")
        return errs

    def check_snapshot_compat(self, snap: SlotSnapshot) -> None:
        """Raise ``ValueError`` naming every incompatible cache leaf if
        ``snap`` cannot be restored into this manager.  The router calls
        this before every cross-engine transit; :meth:`restore` calls it
        unconditionally so a bad hand-off can never reach the scatter."""
        errs = self.snapshot_compat_errors(snap)
        if errs:
            raise ValueError(
                "snapshot incompatible with this engine's cache spec "
                f"({len(errs)} field(s)):\n  - " + "\n  - ".join(errs))

    def _slot_tokens(self, slot: int) -> int:
        """Host-side estimate of a slot's current sequence length (prompt
        + generated so far) — gauge precision, not scheduling truth."""
        req = self.slots[slot]
        if req is None:
            return 0
        return min(self.max_len, len(req.prompt) + len(req.output))

    def useful_bytes(self) -> int:
        """Bytes actually backing live tokens/state of occupied slots."""
        total = 0
        for slot in self.occupied():
            toks = self._slot_tokens(slot)
            total += self._per_slot_bytes
            total += sum(min(s, toks) * tok_b
                         for s, tok_b in self._ring_token_bytes.items())
        return total

    def tokens_in_flight(self) -> int:
        """Total sequence tokens currently resident across occupied slots."""
        return sum(self._slot_tokens(s) for s in self.occupied())

    # fragmentation gauge backends (paged overrides all three)
    def blocks_free(self) -> int:
        return 0

    def bytes_resident(self) -> int:
        return self._dense_cache_bytes

    def padding_waste(self) -> int:
        return self.bytes_resident() - self.useful_bytes()

    # ------------------------------------------------------------ occupancy
    def free(self) -> List[int]:
        return [i for i, r in enumerate(self.slots) if r is None]

    def occupied(self) -> List[int]:
        return [i for i, r in enumerate(self.slots) if r is not None]

    def running(self) -> List[Tuple[int, object]]:
        return [(i, r) for i, r in enumerate(self.slots) if r is not None]

    def n_active(self) -> int:
        return sum(r is not None for r in self.slots)

    # ------------------------------------------------------------- grant/free
    def grant(self, slot: int, req, next_token: Optional[int]) -> None:
        """Mark a slot occupied by ``req``.  ``next_token`` may be None
        when the first token is still on device (overlapped admission);
        the post-chunk refresh fills the host mirror."""
        if self.slots[slot] is not None:
            raise ValueError(f"grant into occupied slot {slot}")
        self.slots[slot] = req
        self.active[slot] = True
        self.eos[slot] = -1 if req.eos_id is None else req.eos_id
        self.remaining[slot] = req.max_new_tokens - len(req.output) - (
            1 if next_token is None else 0)
        if next_token is not None:
            self.next_token[slot] = next_token

    def release(self, slot: int) -> None:
        if self.slots[slot] is None:
            raise ValueError(f"release of already-free slot {slot}")
        self.slots[slot] = None
        self.active[slot] = False

    # ------------------------------------------------------- prefill insert
    def insert_from_prefill(self, slots: Sequence[int], rows: Sequence[int],
                            cacheN) -> None:
        """Scatter prefill-cache rows into engine slots (one pytree op for
        the whole admitted group): the write half of the gather/scatter
        pair, with the prefill batch rows as the source columns."""
        self._prefill_inserts.inc(len(list(slots)))
        sl = jnp.asarray(list(slots), jnp.int32)
        rw = jnp.asarray(list(rows), jnp.int32)
        self.cache = jax.tree.map(
            lambda big, small, ax: big.at[_index(big, ax, sl)].set(
                jnp.take(small, rw, axis=ax).astype(big.dtype)),
            self.cache, cacheN, self.axes)

    # ------------------------------------------------------ preempt / resume
    def snapshot(self, slot: int) -> SlotSnapshot:
        """Gather one slot's device state to host (one blocking
        ``device_get``) — the evict-to-host half of preemption."""
        return self.snapshot_many([slot])[0]

    def snapshot_many(self, slots: Sequence[int]) -> List[SlotSnapshot]:
        """Batched eviction gather: one ``gather_slots`` + one blocking
        ``device_get`` for all N victim columns, split into per-slot
        snapshots on host.  Bit-identical to N sequential
        :meth:`snapshot` calls (``jnp.take`` then a host ``np.take`` per
        slot preserves every leaf's bytes), at one device round-trip
        instead of N — a preemption burst costs one host sync.

        An empty victim list is a no-op (no device round-trip); duplicate
        or unoccupied victims are rejected — a duplicate would otherwise
        snapshot one slot twice and double-requeue its request."""
        slots = list(slots)
        if not slots:
            return []
        if len(set(slots)) != len(slots):
            raise ValueError(f"duplicate slots in snapshot_many: {slots}")
        for s in slots:
            if self.slots[s] is None:
                raise ValueError(f"snapshot of unoccupied slot {s}")
        cols = jax.device_get(gather_slots(self.cache, self.axes,
                                           list(slots)))
        out = []
        for k, slot in enumerate(slots):
            col = jax.tree.map(lambda a, ax, k=k: np.take(a, [k], axis=ax),
                               cols, self.axes)
            snap = SlotSnapshot(cache_col=col,
                                next_token=int(self.next_token[slot]))
            self._snapshots.inc()
            self._snapshot_bytes.inc(snap.nbytes())
            out.append(snap)
        return out

    def restore(self, slot: int, snap: SlotSnapshot, req) -> None:
        """Scatter a snapshot into a (not necessarily the same) free slot
        and re-arm the control mirrors — the resume half.  No model call,
        no sampler-key consumption: the request decodes its next tick as
        if it had never left."""
        if self.slots[slot] is not None:
            raise ValueError(f"restore into occupied slot {slot}")
        self.check_snapshot_compat(snap)
        self._restores.inc()
        self.cache = scatter_slots(self.cache, self.axes, [slot],
                                   snap.cache_col)
        self.slots[slot] = req
        self.active[slot] = True
        self.eos[slot] = -1 if req.eos_id is None else req.eos_id
        self.remaining[slot] = req.max_new_tokens - len(req.output)
        self.next_token[slot] = snap.next_token

    def scrub(self, slots: Sequence[int]) -> None:
        """Zero-wipe slot columns (fault quarantine): no poisoned value
        survives for the guard scan or the slot's next tenant.  Device-only
        (no host sync); works under both layouts — the paged manager's
        ``cache`` setter re-pages the wiped view and re-heals its null
        block, and the subsequent ``release`` wipes the freed blocks."""
        slots = list(slots)
        if not slots:
            return
        col = gather_slots(self.cache, self.axes, slots)
        self.cache = scatter_slots(self.cache, self.axes, slots,
                                   jax.tree.map(jnp.zeros_like, col))

    # ------------------------------------------------------ post-chunk sync
    def refresh_after_chunk(self, last_tokens: np.ndarray) -> None:
        """Re-derive the host mirrors from the authoritative slot table
        after a decode chunk's readback."""
        self.next_token = last_tokens.copy()
        self.active = np.array([r is not None for r in self.slots])
        self.remaining = np.array(
            [r.max_new_tokens - len(r.output) if r is not None else 0
             for r in self.slots], np.int32)

    def stats(self) -> Dict[str, int]:
        # historical keys preserved; extended counters live in .metrics
        return {"active": self.n_active(),
                "free": self.max_batch - self.n_active()}


def make_slot_manager(model: LM, max_batch: int, max_len: int, *,
                      layout: str = "dense",
                      registry: Optional[MetricsRegistry] = None
                      ) -> SlotManager:
    """Construct the slot manager for a ``ServingPlan.cache_layout``:
    ``"dense"`` → :class:`SlotManager`, ``"paged:<block_size>"`` →
    :class:`repro.serving.paged.PagedSlotManager` (imported lazily; it
    depends on this module)."""
    from repro.plan.plan import parse_cache_layout

    block = parse_cache_layout(layout)
    if block is None:
        return SlotManager(model, max_batch, max_len, registry=registry)
    from repro.serving.paged import PagedSlotManager

    return PagedSlotManager(model, max_batch, max_len, block_size=block,
                            registry=registry)
