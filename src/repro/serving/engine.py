"""Slot-based continuous-batching serving engine with an on-device hot path.

The batched decode step runs every tick over all occupied slots; requests
join by prefilling into a free slot and leave on EOS/length without
disturbing the others — the standard continuous-batching scheme
(Orca/vLLM) on a fixed-slot KV cache.

The engine is mechanism only; the serving stack is three explicit layers:

* :mod:`repro.serving.scheduler` owns *policy* — which queued request to
  admit (FCFS / SPF / EDF) and, for preemptive EDF, which running request
  to evict when a tighter deadline arrives;
* :mod:`repro.serving.slotstate` owns *state* — the cache pytree and the
  per-slot control mirrors, with a symmetric gather/scatter API so a
  slot's whole decode state can be evicted to host and later restored
  bit-exactly into any free slot (preempt → resume);
* this module owns *execution* — ``step()`` asks the scheduler, moves
  state through the slot manager, runs the prefill / fused-decode
  programs, and reports telemetry;
* :mod:`repro.plan` owns the *design point* — every constructor knob
  (capacity, bucket set, chunking, policy, sampling) lives in one frozen
  :class:`~repro.plan.ServingPlan`; build engines with
  :meth:`ServingEngine.from_plan` (the kwargs constructor is a shim that
  assembles a plan internally and behaves identically).

The steady-state hot path is the paper's thesis applied at the host level:
breaking the serving loop into per-kernel launches (decode, then a host
round-trip to sample, then a host read of the lengths) wastes the machine
on host↔device traffic exactly the way per-kernel launches waste it on
inter-kernel data movement.  So the decode tick is ONE fused jit program —
decode + sample + EOS/length done-mask + per-slot token writeback, with
the PRNG key carried as state — and up to ``sync_every`` ticks run
on-device between host syncs (a ``lax.while_loop`` that early-exits when
every slot is done, or when a slot frees while requests are queued so the
host can admit).  The host only intervenes to admit and retire.

Admission is bucketed batched prefill: prompts are right-padded to
power-of-two length buckets (capped at ``max_len - 1``) and all
same-bucket admissions prefill in one fixed-batch call, so the number of
prefill XLA compiles is bounded by the bucket count instead of the number
of distinct prompt lengths, and bursty (MMPP) arrival spikes amortize
into one program launch.  Slot insertion is one pytree scatter for the
whole admitted group.

With ``overlap_prefill=True`` (default) admission no longer serializes
with decode: the prefill program, the on-device first-token sample, the
slot scatter, and the decode chunk are all dispatched back-to-back with
no host sync in between, and the first tokens ride home on the chunk's
single readback.  The schedule (tick stamps, outputs, utilization) is
bit-identical to the synchronous path; only the blocking-readback count
drops.  Admission rounds that can finish at the prefill token (a request
with an ``eos_id``, or ``max_new_tokens == 1``) fall back to the
synchronous path, because instant retirement frees the slot for further
same-tick admissions and that decision needs the sampled token on host.

Virtual-clock semantics are unchanged: with the default ``sync_every=1``
(and for any ``sync_every`` under ``workload.drive``'s arrival-bounded
chunks) the tick-stamp schedule is bit-identical to the per-tick host
loop, so the fused path is a pure wall-clock optimization.
"""

from __future__ import annotations

import dataclasses
import itertools
import logging
from functools import partial
from typing import Dict, List, Optional, Set, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.dist.sharding import Sharder
from repro.models.lm import LM
from repro.obs.registry import LiveMetrics, MetricsRegistry
from repro.obs.trace import Tracer
from repro.plan.plan import MIN_BUCKET, ServingPlan
from repro.serving.sampler import SamplerConfig, split_and_sample
from repro.serving.scheduler import POLICIES, Scheduler, make_scheduler
from repro.serving.slotstate import SlotSnapshot, make_slot_manager

log = logging.getLogger("repro.serving")


@dataclasses.dataclass
class Request:
    uid: int
    prompt: List[int]
    max_new_tokens: int = 16
    eos_id: Optional[int] = None
    deadline: Optional[float] = None   # absolute, clock units (EDF + SLO)
    # filled by the engine
    output: List[int] = dataclasses.field(default_factory=list)
    done: bool = False
    shed: bool = False            # rejected at submit: provably past its
    #                               deadline (plan.shed_late admission ctl)
    truncated: bool = False       # prompt tail dropped (truncate_prompts)
    capped: bool = False          # cache can't hold max_new_tokens: the
    #                               output will stop short (length cut)
    # tick stamps (engine tick counter; see serving.metrics for semantics)
    t_submit: int = 0             # tick at submission
    t_admit: Optional[int] = None   # tick the prefill ran (slot granted)
    t_first: Optional[int] = None   # tick the first token was produced
    t_done: Optional[int] = None    # tick the request completed
    # preemption lifecycle (EDF --preempt): evict-to-host / resume stamps
    n_preempts: int = 0
    t_preempts: List[int] = dataclasses.field(default_factory=list)
    t_resumes: List[int] = dataclasses.field(default_factory=list)
    saved: Optional[SlotSnapshot] = dataclasses.field(
        default=None, repr=False)   # host state while evicted


def _is_reduced(cfg) -> bool:
    """Best-effort identity check for the kwargs shim: a config that
    differs from the registry entry of its own name is a reduced (or
    otherwise customized) variant.  Unknown names count as reduced —
    the flag only matters when ``from_plan`` has to rebuild the model."""
    try:
        from repro.configs import ARCHS

        return ARCHS.get(cfg.name) != cfg
    except Exception:  # pragma: no cover - configs import should not fail
        return True


@dataclasses.dataclass
class _PendingAdmit:
    """An overlapped admission group: first tokens still on device, host
    bookkeeping deferred to the decode chunk's readback."""

    reqs: List[Request]
    rows: List[int]
    slots: List[int]
    first: jax.Array            # (rows,) sampled prefill tokens, on device


def _decode_many(model: LM, sharder: Sharder, sampler: SamplerConfig,
                 max_len: int, k: int,
                 params, cache, tokens, key, active, eos, remaining,
                 limit, stop_on_free):
    """Up to ``min(k, limit)`` fused decode ticks on device, no host sync.

    Per tick: decode_step + sample + done-mask (EOS / cache-full /
    max_new_tokens) + per-slot token writeback, threading the PRNG key.
    Early-exits when no slot is active, or — when ``stop_on_free`` — after
    the first tick that frees a slot, so the host can admit a queued
    request at exactly the tick the per-tick loop would have.

    Returns (n_ticks, cache, key, toks (k,B), acts (k,B), dones (k,B));
    rows >= n_ticks of the buffers are zero.
    """
    B = tokens.shape[0]
    st = dict(i=jnp.int32(0), cache=cache, tokens=tokens, key=key,
              active=active, remaining=remaining,
              toks=jnp.zeros((k, B), jnp.int32),
              acts=jnp.zeros((k, B), bool),
              dones=jnp.zeros((k, B), bool),
              freed=jnp.bool_(False))

    def cond(st):
        return ((st["i"] < limit) & st["active"].any()
                & jnp.logical_not(stop_on_free & st["freed"]))

    def body(st):
        cache, logits = model.decode_step(params, st["cache"], st["tokens"],
                                          sharder)
        key, sampled = split_and_sample(st["key"], logits, sampler)
        active = st["active"]
        tokens = jnp.where(active, sampled, st["tokens"])
        remaining = st["remaining"] - active.astype(jnp.int32)
        hit_eos = (eos >= 0) & (sampled == eos)
        full = cache["lengths"] >= max_len - 1
        done_now = active & (hit_eos | full | (remaining <= 0))
        i = st["i"]
        return dict(
            i=i + 1, cache=cache, tokens=tokens, key=key,
            active=active & ~done_now, remaining=remaining,
            toks=st["toks"].at[i].set(tokens),
            acts=st["acts"].at[i].set(active),
            dones=st["dones"].at[i].set(done_now),
            freed=st["freed"] | done_now.any())

    st = jax.lax.while_loop(cond, body, st)
    return (st["i"], st["cache"], st["key"],
            st["toks"], st["acts"], st["dones"])


class ServingEngine:
    """Plan-driven construction: every design parameter lives in one
    :class:`repro.plan.ServingPlan` (``engine.plan``) — build with
    :meth:`from_plan`.  The historical kwargs constructor is kept as a
    thin shim that assembles a plan internally, so ``ServingEngine(model,
    params, sharder, max_batch=..., ...)`` keeps working with a
    bit-identical schedule to the equivalent ``from_plan`` engine."""

    def __init__(self, model: LM, params, sharder: Sharder, *,
                 max_batch: int = 4, max_len: int = 128,
                 sampler: SamplerConfig = SamplerConfig(), seed: int = 0,
                 truncate_prompts: bool = False, sync_every: int = 1,
                 policy: str = "fcfs", preempt: bool = False,
                 bucketed_prefill: bool = True,
                 overlap_prefill: bool = True,
                 shed_late: bool = False,
                 cache_layout: str = "dense",
                 plan: Optional[ServingPlan] = None,
                 tracer: Optional[Tracer] = None):
        if plan is None:   # kwargs shim: capture the knobs as a plan
            plan = ServingPlan(
                arch=model.cfg.name, reduced=_is_reduced(model.cfg),
                max_batch=max_batch, max_len=max_len,
                cache_layout=cache_layout,
                sync_every=sync_every, policy=policy, preempt=preempt,
                bucketed_prefill=bucketed_prefill,
                overlap_prefill=overlap_prefill, shed_late=shed_late,
                temperature=sampler.temperature, top_k=sampler.top_k,
                truncate_prompts=truncate_prompts,
                provenance={"source": "engine-kwargs"})
        plan.validate()
        self.plan = plan
        self.model = model
        self.params = params
        self.sharder = sharder
        self.max_batch = plan.max_batch
        self.max_len = plan.max_len
        self.sampler = SamplerConfig(temperature=plan.temperature,
                                     top_k=plan.top_k)
        self.truncate_prompts = plan.truncate_prompts
        self.sync_every = int(plan.sync_every)
        self.policy = plan.policy
        self.bucketed_prefill = plan.bucketed_prefill
        self.overlap_prefill = plan.overlap_prefill
        self.shed_late = plan.shed_late
        self._buckets = plan.resolved_buckets()
        # one registry for the whole stack: scheduler + slot-state counters
        # register into it, so reset_telemetry() covers them by construction
        self.metrics = MetricsRegistry()
        self.scheduler: Scheduler = make_scheduler(
            plan.policy, preempt=plan.preempt, registry=self.metrics)
        self.cache_layout = plan.cache_layout
        self._paged = plan.cache_layout != "dense"
        self.sm = make_slot_manager(model, self.max_batch, self.max_len,
                                    layout=plan.cache_layout,
                                    registry=self.metrics)
        c = self.metrics.counter
        self._c_completed = c("engine.completed",
                              "requests finished since construction")
        self._c_total_tokens = c("engine.total_tokens",
                                 "tokens generated (prefill + decode)")
        self._c_instant_admits = c("engine.instant_admits",
                                   "requests done at their prefill token")
        self._c_host_syncs = c("engine.host_syncs",
                               "blocking device->host readbacks")
        self._c_decode_chunks = c("engine.decode_chunks",
                                  "fused decode_many launches")
        self._c_prefill_calls = c("engine.prefill_calls",
                                  "prefill program launches")
        self._c_preemptions = c("engine.preemptions",
                                "slots evicted to host")
        self._c_resumes = c("engine.resumes",
                            "evicted requests restored to a slot")
        self._c_evicted_tokens = c("engine.evicted_tokens",
                                   "tokens already generated at eviction")
        self._c_shed = c("engine.shed",
                         "requests rejected at submit (admission control)")
        self.metrics.gauge("engine.ticks", "virtual-clock tick counter",
                           fn=lambda: float(self._tick))
        self.finished: List[Request] = []   # completed Requests, in order
        self.util_history: List[float] = []  # per-tick (active+instant)/max
        self.prefill_shapes: Set[Tuple[int, int]] = set()  # (rows, S) seen
        self.tracer = tracer          # optional structured event tracer
        self.live: Optional[LiveMetrics] = None   # enable_live_metrics()
        self._decode_compile_traced = False  # decode program built once
        self._pending: List[_PendingAdmit] = []  # overlapped admissions
        self._tick = 0
        self._uid = itertools.count()
        self._key = jax.random.PRNGKey(seed)
        self._decode_many = jax.jit(
            partial(_decode_many, model, sharder, self.sampler,
                    self.max_len, self.sync_every),
            donate_argnums=1)
        self._prefill = jax.jit(
            lambda p, b: model.prefill(p, b, sharder,
                                       max_len=self.max_len))

    @classmethod
    def from_plan(cls, plan: ServingPlan, params, *,
                  model: Optional[LM] = None,
                  sharder: Optional[Sharder] = None,
                  seed: int = 0,
                  tracer: Optional[Tracer] = None) -> "ServingEngine":
        """Build an engine from a :class:`repro.plan.ServingPlan` — the
        plan-centric constructor.  ``model``/``sharder`` default to what
        the plan's identity fields describe (``arch`` + ``reduced`` +
        ``shard_mode``); pass them explicitly to reuse an already-built
        model (the benchmark sweeps do)."""
        plan.validate()
        if model is None:
            from repro.configs import get_config
            from repro.models.lm import build_model
            from repro.testing import reduced_config

            cfg = (reduced_config(plan.arch) if plan.reduced
                   else get_config(plan.arch))
            model = build_model(cfg)
        if sharder is None:
            from repro.dist.sharding import make_sharder

            sharder = make_sharder(model.cfg, None, plan.shard_mode)
        return cls(model, params, sharder, seed=seed, plan=plan,
                   tracer=tracer)

    # ------------------------------------------------- back-compat accessors
    @property
    def cache(self):
        return self.sm.cache

    @property
    def slots(self) -> List[Optional[Request]]:
        return self.sm.slots

    @property
    def queue(self):
        return self.scheduler.queue

    # counters live in the registry; these read-only views keep the
    # historical attribute names (engine.completed, engine.shed, ...)
    @property
    def completed(self) -> int:
        return self._c_completed.value

    @property
    def total_tokens(self) -> int:
        return self._c_total_tokens.value

    @property
    def instant_admits(self) -> int:
        return self._c_instant_admits.value

    @property
    def host_syncs(self) -> int:
        return self._c_host_syncs.value

    @property
    def decode_chunks(self) -> int:
        return self._c_decode_chunks.value

    @property
    def prefill_calls(self) -> int:
        return self._c_prefill_calls.value

    @property
    def preemptions(self) -> int:
        return self._c_preemptions.value

    @property
    def resumes(self) -> int:
        return self._c_resumes.value

    @property
    def evicted_tokens(self) -> int:
        return self._c_evicted_tokens.value

    @property
    def shed(self) -> int:
        return self._c_shed.value

    def enable_live_metrics(self, window: int = 64) -> LiveMetrics:
        """Attach a rolling :class:`repro.obs.LiveMetrics` window (last
        ``window`` ticks); the engine feeds it every tick and every
        retired request.  Returns the window for polling (``snapshot()``
        / ``line()``)."""
        self.live = LiveMetrics(window)
        return self.live

    # ------------------------------------------------------------------ API
    def submit(self, prompt: List[int], max_new_tokens: int = 16,
               eos_id: Optional[int] = None,
               deadline: Optional[float] = None) -> Request:
        prompt = list(prompt)
        if not prompt:
            raise ValueError("empty prompt")
        if max_new_tokens < 1:
            raise ValueError(f"max_new_tokens must be >= 1, got "
                             f"{max_new_tokens}: the prefill always emits "
                             f"one token")
        limit = self.max_len - 1  # >= 1 cache slot left for generation
        truncated = False
        if len(prompt) > limit:
            if not self.truncate_prompts:
                raise ValueError(
                    f"prompt length {len(prompt)} exceeds max_len-1 = "
                    f"{limit}; raise max_len or construct the engine with "
                    f"truncate_prompts=True to drop the tail")
            log.warning("truncating prompt from %d to %d tokens "
                        "(max_len=%d)", len(prompt), limit, self.max_len)
            prompt, truncated = prompt[:limit], True
        req = Request(next(self._uid), prompt, max_new_tokens, eos_id,
                      deadline=deadline, truncated=truncated,
                      t_submit=self._tick)
        # the `full` stop in the decode loop cuts generation at max(2,
        # max_len - len(prompt)) tokens (prefill token + decodes until the
        # cache fills): flag requests whose max_new_tokens cannot fit
        # instead of cutting the output silently
        cap = max(2, self.max_len - len(prompt))
        if max_new_tokens > cap:
            req.capped = True
            log.warning("request %d: max_new_tokens=%d exceeds cache room "
                        "for a %d-token prompt (max_len=%d); output stops "
                        "at %d tokens", req.uid, max_new_tokens,
                        len(prompt), self.max_len, cap)
        if self.tracer is not None:
            # every submission is traced — shed traffic included, so
            # obs.observe.fit_profile sees the *offered* load, not just
            # what admission control let through
            self.tracer.request_submit(req, self._tick)
        if (self.shed_late and deadline is not None
                and self._provably_late(req)):
            # deadline-aware admission control: reject work that cannot
            # meet its SLO even if admitted this very tick, instead of
            # spending slot-ticks on a guaranteed violation
            req.shed = True
            self._c_shed.inc()
            if self.tracer is not None:
                self.tracer.request_shed(req, self._tick)
            if self.live is not None:
                self.live.observe_request(req, self._tick)
            log.debug("shed req %d at tick %d: deadline %.1f < earliest "
                      "completion", req.uid, self._tick, deadline)
            return req
        self.scheduler.submit(req)
        return req

    def _provably_late(self, req: Request) -> bool:
        """True when the request cannot meet its deadline even with a slot
        granted *now*: earliest completion is the prefill tick plus the
        remaining decode ticks.  The bound is strictly conservative — a
        request with an ``eos_id`` could retire at its prefill token, so
        only the prefill tick counts; without one the output length is
        exactly ``max_new_tokens`` (or the cache cap, whichever is
        smaller).  Completion-by-deadline uses the SLO convention
        ``t_done + 1 <= deadline``.

        The bound equates one engine tick with one deadline clock unit —
        exact on the virtual clock (the benchmark/SLO convention, where
        deadlines are tick-denominated by construction).  Under
        ``--clock wall`` ticks run at the hardware's pace, so the bound
        is a heuristic there, not a proof."""
        if req.eos_id is not None:
            min_decode = 0      # could instant-EOS at the prefill token
        else:
            cap = max(2, self.max_len - len(req.prompt))
            min_decode = min(req.max_new_tokens, cap) - 1
        earliest_end = self._tick + 1 + min_decode
        return req.deadline < earliest_end

    def has_work(self) -> bool:
        """True while any request is queued or occupying a slot."""
        return bool(len(self.scheduler)) or self.sm.n_active() > 0

    def run(self, max_steps: int = 10_000) -> None:
        for _ in range(max_steps):
            if not self.step():
                break

    # ------------------------------------------------------------- buckets
    def bucket(self, n: int) -> int:
        """Padded prefill length for an n-token prompt: the smallest
        bucket that fits it.  The bucket set comes from the plan
        (``plan.buckets``, defaulting to the historical pow2 set)."""
        if not self.bucketed_prefill:
            return n
        for b in self._buckets:
            if b >= n:
                return b
        return self._buckets[-1]

    @property
    def bucket_lengths(self) -> List[int]:
        """All bucket lengths this engine can emit (= its prefill compile
        ceiling in bucketed mode)."""
        return list(self._buckets)

    # ----------------------------------------------------------------- ticks
    def step(self, max_ticks: Optional[int] = None) -> bool:
        """One host intervention: ask the scheduler (preempt + admit), run
        up to ``min(sync_every, max_ticks)`` fused decode ticks on device
        with a single host sync at the end, report telemetry.  Returns
        False when idle."""
        budget = self.sync_every if max_ticks is None \
            else max(1, min(int(max_ticks), self.sync_every))
        n_instant = self._schedule()
        if self.tracer is not None:
            self.tracer.counter(self._tick, "queue_depth",
                                len(self.scheduler))
        active_idx = self.sm.occupied()
        if not active_idx:
            if n_instant:
                # prefill-only tick: every admit finished at its first
                # token.  Real work happened, so time still advances.
                self._observe_tick(self._tick, n_instant / self.max_batch)
                self._tick += 1
                return True
            return bool(len(self.scheduler))
        # if requests wait in the queue, break the chunk as soon as a slot
        # frees so admission happens at the same tick the per-tick loop
        # would have admitted at
        stop_on_free = bool(len(self.scheduler))
        if self.tracer is not None and not self._decode_compile_traced:
            # the fused decode program has fixed shapes: XLA builds it
            # exactly once, on the first chunk launch
            self.tracer.compile(self._tick, "decode", self.max_batch,
                                self.sync_every)
            self._decode_compile_traced = True
        # paged layout: extend every occupied slot's block coverage for
        # the chunk's ring writes before the program launches (dense: no-op)
        self.sm.ensure_chunk(budget)
        tokens_in = self._merge_pending_tokens()
        n, self.sm.cache, self._key, toks, acts, dones = self._decode_many(
            self.params, self.sm.cache, tokens_in, self._key,
            self.sm.active, self.sm.eos, self.sm.remaining,
            np.int32(budget), np.bool_(stop_on_free))
        self._c_decode_chunks.inc()
        # ---- the chunk's single blocking host<->device sync -------------
        # (overlapped admissions' first tokens ride home on the same pull)
        n, toks, acts, dones, firsts = jax.device_get(
            (n, toks, acts, dones, [p.first for p in self._pending]))
        n = int(n)
        self._c_host_syncs.inc()
        for p, fv in zip(self._pending, firsts):
            for req, row in zip(p.reqs, p.rows):
                req.output.append(int(fv[row]))
                self._c_total_tokens.inc()
        self._pending = []
        base = self._tick
        if self.tracer is not None:
            self.tracer.decode_chunk(base, n, len(active_idx))
        for j in range(n):
            n_active = 0
            for i in active_idx:
                req = self.sm.slots[i]
                if req is None or not acts[j, i]:
                    continue
                n_active += 1
                req.output.append(int(toks[j, i]))
                self._c_total_tokens.inc()
                if dones[j, i]:
                    self._finish(req, base + j)
                    self.sm.release(i)
            self._observe_tick(
                base + j,
                (n_active + (n_instant if j == 0 else 0)) / self.max_batch)
        self._tick += n
        if self.tracer is not None:
            self.tracer.host_sync(self._tick)
        # refresh the host mirrors from the authoritative slot table
        self.sm.refresh_after_chunk(toks[n - 1])
        log.debug("chunk of %d ticks -> tick %d: util=%.2f queued=%d "
                  "completed=%d total_tokens=%d syncs=%d", n, self._tick,
                  self.util_history[-1], len(self.scheduler), self.completed,
                  self.total_tokens, self.host_syncs)
        return True

    # ------------------------------------------------------------- internals
    def _finish(self, req: Request, tick: int) -> None:
        req.done = True
        req.t_done = tick
        self._c_completed.inc()
        self.finished.append(req)
        if self.tracer is not None:
            self.tracer.request_done(req, tick)
        if self.live is not None:
            self.live.observe_request(req, tick)

    def _observe_tick(self, tick: int, util: float) -> None:
        """One virtual-clock tick's utilization, fanned out to every
        observer: the aggregate history, the rolling live window, and the
        trace's counter track."""
        self.util_history.append(util)
        if self.live is not None:
            self.live.observe_tick(tick, util)
        if self.tracer is not None:
            self.tracer.counter(tick, "util", util)
            if self._paged:
                # fragmentation tracks, paged runs only — dense traces
                # stay byte-identical to the pre-paged engine
                self.tracer.counter(tick, "blocks_free",
                                    self.sm.blocks_free())
                self.tracer.counter(tick, "bytes_resident",
                                    self.sm.bytes_resident())
                self.tracer.counter(tick, "padding_waste",
                                    self.sm.padding_waste())

    def _merge_pending_tokens(self):
        """Decode-chunk input tokens: the host mirror, with overlapped
        admissions' first tokens merged in on device (they were sampled by
        the prefill program and never came to host)."""
        if not self._pending:
            return self.sm.next_token
        tokens = jnp.asarray(self.sm.next_token)
        for p in self._pending:
            tokens = tokens.at[jnp.asarray(p.slots, jnp.int32)].set(
                p.first[jnp.asarray(p.rows, jnp.int32)])
        return tokens

    # ----------------------------------------------------------- scheduling
    def preempt(self, slot: int) -> Request:
        """Evict the request in ``slot`` to host memory and requeue it
        (see :meth:`preempt_many` — this is the one-victim case).  Public
        for manual load shedding and the round-trip tests."""
        return self.preempt_many([slot])[0]

    def preempt_many(self, slots: List[int]) -> List[Request]:
        """Evict N running requests to host memory and requeue them, in
        ``slots`` order, with ONE batched device->host transfer.

        ``SlotManager.snapshot_many`` gathers every victim's cache column
        in a single ``gather_slots`` + ``device_get`` instead of N
        sequential snapshots, so a preemption burst (EDF under an arrival
        spike) costs one host sync, not one per victim.  Bookkeeping is
        per-victim and order-preserving — ``requeue_front`` runs in
        ``slots`` order exactly as N sequential :meth:`preempt` calls
        would, so the schedule is bit-identical to the sequential path.
        Once the scheduler grants a victim a slot again it resumes
        bit-exactly under greedy decoding (with stochastic sampling the
        engine-global key stream makes resumed tokens slot/tick-dependent
        — see slotstate's module docstring)."""
        if not slots:
            return []   # no victims: no gather, no host sync
        reqs: List[Request] = []
        for slot in slots:
            if self.sm.slots[slot] is None:
                raise ValueError(f"slot {slot} is empty")
            reqs.append(self.sm.slots[slot])
        snaps = self.sm.snapshot_many(slots)
        self._c_host_syncs.inc()
        if self.tracer is not None:
            self.tracer.host_sync(self._tick)
        for slot, req, snap in zip(slots, reqs, snaps):
            req.saved = snap
            req.n_preempts += 1
            req.t_preempts.append(self._tick)
            self._c_preemptions.inc()
            self._c_evicted_tokens.inc(len(req.output))
            self.sm.release(slot)
            self.scheduler.requeue_front(req)
            if self.tracer is not None:
                self.tracer.request_preempt(req, self._tick, slot,
                                            len(req.output))
            log.debug("preempted req %d from slot %d at tick %d "
                      "(%d tokens evicted to host)", req.uid, slot,
                      self._tick, len(req.output))
        return reqs

    def _schedule(self) -> int:
        """One scheduler consultation: preempt (if the policy does), then
        admit queued requests into free slots.  Returns how many admits
        finished at their prefill token."""
        if self.scheduler.preemptive and len(self.scheduler):
            victims = self.scheduler.victims(self.sm.running(),
                                             len(self.sm.free()))
            if victims:
                self.preempt_many(victims)
        return self._admit()

    def _admit(self) -> int:
        """Admit queued requests into free slots — evicted requests are
        restored from their host snapshots (no model call), fresh ones go
        through bucketed batched prefill.  Returns how many finished at
        their prefill token (max_new_tokens=1 / instant EOS) — those never
        occupy a slot, so further queued requests are retried in the same
        tick."""
        n_instant = 0
        while len(self.scheduler):
            free = self.sm.free()
            if not free:
                break
            picked = self.scheduler.pick(len(free))
            resumes = [r for r in picked if r.saved is not None]
            fresh = [r for r in picked if r.saved is None]
            for req in resumes:
                slot = free.pop(0)
                self.sm.restore(slot, req.saved, req)
                req.saved = None
                req.t_resumes.append(self._tick)
                self._c_resumes.inc()
                if self.tracer is not None:
                    self.tracer.request_resume(req, self._tick, slot)
                log.debug("resumed req %d into slot %d at tick %d",
                          req.uid, slot, self._tick)
            if not fresh:
                continue
            if self.bucketed_prefill:
                groups: Dict[int, List[Request]] = {}
                for req in fresh:
                    groups.setdefault(self.bucket(len(req.prompt)),
                                      []).append(req)
                grouped = sorted(groups.items())
            else:
                # legacy comparison path: one exact-length batch-1 prefill
                # per request (compile count grows with distinct lengths)
                grouped = [(len(r.prompt), [r]) for r in fresh]
            # instant retirement (EOS at the prefill token / one-token
            # budget) frees the slot for further same-tick admissions, and
            # that decision needs the sampled token on host: such rounds
            # take the synchronous path
            overlap = (self.overlap_prefill
                       and not any(r.eos_id is not None
                                   or r.max_new_tokens == 1 for r in fresh))
            for S, reqs in grouped:
                n_instant += self._prefill_group(S, reqs, free, overlap)
        return n_instant

    def _prefill_group(self, S: int, reqs: List[Request],
                       free: List[int], overlap: bool) -> int:
        """One padded batched prefill for same-bucket admissions: sample
        every first token in one call, scatter all granted slots in one
        pytree op.  Mutates ``free`` as slots are granted.

        ``overlap=True`` keeps the sampled first tokens on device and
        defers the host bookkeeping to the decode chunk's readback, so
        the prefill never blocks the chunk launch."""
        rows = self.max_batch if self.bucketed_prefill else len(reqs)
        tokens = np.zeros((rows, S), np.int32)
        lengths = np.ones((rows,), np.int32)   # dummy rows: 1 valid token
        for r_i, req in enumerate(reqs):
            tokens[r_i, :len(req.prompt)] = req.prompt
            lengths[r_i] = len(req.prompt)
        batch = {"tokens": jnp.asarray(tokens),
                 "lengths": jnp.asarray(lengths)}
        if self.model.cfg.m_rope_sections:
            batch["positions"] = jnp.broadcast_to(
                jnp.arange(S, dtype=jnp.int32), (rows, 3, S))
        if self.tracer is not None:
            if (rows, S) not in self.prefill_shapes:
                self.tracer.compile(self._tick, "prefill", rows, S)
            self.tracer.prefill(self._tick, S, rows, len(reqs), overlap)
        cacheN, logitsN = self._prefill(self.params, batch)
        self._c_prefill_calls.inc()
        self.prefill_shapes.add((rows, S))
        self._key, first = split_and_sample(self._key, logitsN, self.sampler)
        if overlap:
            grant_rows, grant_slots = [], []
            for r_i, req in enumerate(reqs):
                slot = free.pop(0)
                self.sm.grant(slot, req, None)
                req.t_admit = req.t_first = self._tick
                grant_rows.append(r_i)
                grant_slots.append(slot)
            self.sm.insert_from_prefill(grant_slots, grant_rows, cacheN)
            self._pending.append(_PendingAdmit(list(reqs), grant_rows,
                                               grant_slots, first))
            return 0
        first = np.asarray(first)
        self._c_host_syncs.inc()
        if self.tracer is not None:
            self.tracer.host_sync(self._tick)
        n_instant = 0
        grant_rows, grant_slots = [], []
        for r_i, req in enumerate(reqs):
            tok = int(first[r_i])
            req.output.append(tok)
            self._c_total_tokens.inc()
            req.t_admit = req.t_first = self._tick
            if ((req.eos_id is not None and tok == req.eos_id)
                    or len(req.output) >= req.max_new_tokens):
                # done at the prefill token: never occupies a slot
                self._finish(req, self._tick)
                n_instant += 1
                self._c_instant_admits.inc()
                continue
            slot = free.pop(0)
            self.sm.grant(slot, req, tok)
            grant_rows.append(r_i)
            grant_slots.append(slot)
        if grant_rows:
            self.sm.insert_from_prefill(grant_slots, grant_rows, cacheN)
        return n_instant

    # ------------------------------------------------------------- telemetry
    @property
    def ticks(self) -> int:
        return self._tick

    def reset_telemetry(self) -> None:
        """Zero the counters/histories (e.g. after a jit warmup run, so
        wall-clock tick timings exclude compile).  The engine must be
        drained; queued or in-flight requests would get skewed stamps.

        ``metrics.reset()`` covers every registered counter — engine,
        scheduler, and slot-state alike — by construction, so a counter
        added anywhere in the stack can never leak warmup counts.  Two
        things deliberately survive: ``prefill_shapes`` mirrors the jit
        cache, which a telemetry reset does not clear (so the reported
        ``prefill_compiles`` stays truthful about programs built), and an
        attached tracer restarts empty at tick 0 (warmup events would
        otherwise overlap the measured run's restarted clock)."""
        if self.has_work():
            raise RuntimeError("reset_telemetry() on a busy engine")
        self.metrics.reset()
        self.finished = []
        self.util_history = []
        self._tick = 0
        if self.live is not None:
            self.live.reset()
        if self.tracer is not None:
            self.tracer.reset()

    def stats(self) -> Dict[str, float]:
        util = self.util_history
        out: Dict[str, float] = {
            "active": self.sm.n_active(),
            "queued": len(self.scheduler),
        }
        out.update(self.metrics.view({
            "completed": "engine.completed",
            "total_tokens": "engine.total_tokens",
        }))
        out["ticks"] = self._tick
        out["mean_util"] = sum(util) / len(util) if util else 0.0
        out.update(self.metrics.view({
            "instant_admits": "engine.instant_admits",
            "host_syncs": "engine.host_syncs",
            "decode_chunks": "engine.decode_chunks",
            "prefill_calls": "engine.prefill_calls",
        }))
        out["prefill_compiles"] = len(self.prefill_shapes)
        out.update(self.metrics.view({
            "preemptions": "engine.preemptions",
            "resumes": "engine.resumes",
            "evicted_tokens": "engine.evicted_tokens",
            "shed": "engine.shed",
        }))
        return out


# re-exported for back-compat: the policy registry lives in scheduler.py
__all__ = ["Request", "ServingEngine", "POLICIES", "MIN_BUCKET"]
