"""Slot-based continuous-batching serving engine.

The batched decode step (one jit-compiled program, fixed max_batch) runs
every tick over all occupied slots; requests join by prefilling into a free
slot and leave on EOS/length without disturbing the others — the standard
continuous-batching scheme (Orca/vLLM) on a fixed-slot KV cache.  Slot
insertion is a pytree scatter into the batch axis of the stacked cache.

This engine is the transformer-serving analogue of the paper's real-time
RNN serving scenario (batch-of-1 requests arriving asynchronously) and is
exercised end-to-end by examples/serve_lm.py and the integration tests.
"""

from __future__ import annotations

import dataclasses
import itertools
import logging
from collections import deque
from typing import Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.dist.sharding import Sharder
from repro.models.lm import LM
from repro.serving.sampler import SamplerConfig, sample

log = logging.getLogger("repro.serving")


@dataclasses.dataclass
class Request:
    uid: int
    prompt: List[int]
    max_new_tokens: int = 16
    eos_id: Optional[int] = None
    # filled by the engine
    output: List[int] = dataclasses.field(default_factory=list)
    done: bool = False
    truncated: bool = False       # prompt tail dropped (truncate_prompts)
    capped: bool = False          # cache can't hold max_new_tokens: the
    #                               output will stop short (length cut)
    # tick stamps (engine tick counter; see serving.metrics for semantics)
    t_submit: int = 0             # tick at submission
    t_admit: Optional[int] = None   # tick the prefill ran (slot granted)
    t_first: Optional[int] = None   # tick the first token was produced
    t_done: Optional[int] = None    # tick the request completed


class ServingEngine:
    def __init__(self, model: LM, params, sharder: Sharder, *,
                 max_batch: int = 4, max_len: int = 128,
                 sampler: SamplerConfig = SamplerConfig(), seed: int = 0,
                 truncate_prompts: bool = False):
        self.model = model
        self.params = params
        self.sharder = sharder
        self.max_batch = max_batch
        self.max_len = max_len
        self.sampler = sampler
        self.truncate_prompts = truncate_prompts
        self.cache = model.init_cache(max_batch, max_len)
        self.slots: List[Optional[Request]] = [None] * max_batch
        self.next_token = np.zeros((max_batch,), np.int32)
        self.queue: deque[Request] = deque()
        self.completed = 0        # requests finished since construction
        self.total_tokens = 0     # tokens generated (prefill + decode)
        self.finished: List[Request] = []   # completed Requests, in order
        self.util_history: List[float] = []  # per-tick active/max_batch
        self._tick = 0
        self._uid = itertools.count()
        self._key = jax.random.PRNGKey(seed)
        self._decode = jax.jit(
            lambda p, c, t: model.decode_step(p, c, t, sharder),
            donate_argnums=1)
        self._prefill = jax.jit(
            lambda p, b: model.prefill(p, b, sharder, max_len=max_len))

    # ------------------------------------------------------------------ API
    def submit(self, prompt: List[int], max_new_tokens: int = 16,
               eos_id: Optional[int] = None) -> Request:
        prompt = list(prompt)
        if not prompt:
            raise ValueError("empty prompt")
        if max_new_tokens < 1:
            raise ValueError(f"max_new_tokens must be >= 1, got "
                             f"{max_new_tokens}: the prefill always emits "
                             f"one token")
        limit = self.max_len - 1  # >= 1 cache slot left for generation
        truncated = False
        if len(prompt) > limit:
            if not self.truncate_prompts:
                raise ValueError(
                    f"prompt length {len(prompt)} exceeds max_len-1 = "
                    f"{limit}; raise max_len or construct the engine with "
                    f"truncate_prompts=True to drop the tail")
            log.warning("truncating prompt from %d to %d tokens "
                        "(max_len=%d)", len(prompt), limit, self.max_len)
            prompt, truncated = prompt[:limit], True
        req = Request(next(self._uid), prompt, max_new_tokens, eos_id,
                      truncated=truncated, t_submit=self._tick)
        # the `full` stop in step() cuts generation at max(2, max_len -
        # len(prompt)) tokens (prefill token + decodes until the cache
        # fills): flag requests whose max_new_tokens cannot fit instead of
        # cutting the output silently
        cap = max(2, self.max_len - len(prompt))
        if max_new_tokens > cap:
            req.capped = True
            log.warning("request %d: max_new_tokens=%d exceeds cache room "
                        "for a %d-token prompt (max_len=%d); output stops "
                        "at %d tokens", req.uid, max_new_tokens,
                        len(prompt), self.max_len, cap)
        self.queue.append(req)
        return req

    def has_work(self) -> bool:
        """True while any request is queued or occupying a slot."""
        return bool(self.queue) or any(r is not None for r in self.slots)

    def run(self, max_ticks: int = 10_000) -> None:
        for _ in range(max_ticks):
            if not self.step():
                break

    # ----------------------------------------------------------------- ticks
    def step(self) -> bool:
        """One engine tick: admit pending requests, one batched decode.
        Returns False when idle."""
        n_instant = self._admit()
        active = [i for i, r in enumerate(self.slots) if r is not None]
        if not active:
            if n_instant:
                # prefill-only tick: every admit finished at its first
                # token.  Real work happened, so time still advances.
                self.util_history.append(min(1.0, n_instant / self.max_batch))
                self._tick += 1
                return True
            return bool(self.queue)
        tokens = jnp.asarray(self.next_token)
        self.cache, logits = self._decode(self.params, self.cache, tokens)
        self._key, sub = jax.random.split(self._key)
        sampled = np.asarray(sample(logits, sub, self.sampler))
        lengths = np.asarray(self.cache["lengths"])
        for i in active:
            req = self.slots[i]
            tok = int(sampled[i])
            req.output.append(tok)
            self.total_tokens += 1
            self.next_token[i] = tok
            hit_eos = req.eos_id is not None and tok == req.eos_id
            full = lengths[i] >= self.max_len - 1
            if hit_eos or full or len(req.output) >= req.max_new_tokens:
                self._finish(req)
                self.slots[i] = None
        self.util_history.append(
            min(1.0, (len(active) + n_instant) / self.max_batch))
        self._tick += 1
        log.debug("tick %d: util=%.2f (%d+%d/%d slots) queued=%d "
                  "completed=%d total_tokens=%d", self._tick,
                  self.util_history[-1], len(active), n_instant,
                  self.max_batch, len(self.queue), self.completed,
                  self.total_tokens)
        return True

    # ------------------------------------------------------------- internals
    def _finish(self, req: Request) -> None:
        req.done = True
        req.t_done = self._tick
        self.completed += 1
        self.finished.append(req)

    def _admit(self) -> int:
        """Admit queued requests into free slots; returns how many finished
        at their prefill token (max_new_tokens=1 / instant EOS) — those
        free their slot immediately, so the next queued request is retried
        into the same slot within this tick."""
        n_instant = 0
        for i in range(self.max_batch):
            while self.slots[i] is None and self.queue:
                req = self.queue.popleft()
                # submit() guarantees 1 <= len(prompt) <= max_len - 1: the
                # full prompt prefills (no silent tail loss) and at least
                # one cache slot is left for generation.
                prompt = req.prompt
                batch = {"tokens": jnp.asarray([prompt], jnp.int32)}
                if self.model.cfg.m_rope_sections:
                    S = len(prompt)
                    batch["positions"] = jnp.broadcast_to(
                        jnp.arange(S, dtype=jnp.int32), (1, 3, S))
                cache1, logits1 = self._prefill(self.params, batch)
                self._insert_slot(i, cache1)
                self._key, sub = jax.random.split(self._key)
                first = int(np.asarray(sample(logits1, sub, self.sampler))[0])
                req.output.append(first)
                self.total_tokens += 1
                req.t_admit = req.t_first = self._tick
                if ((req.eos_id is not None and first == req.eos_id)
                        or len(req.output) >= req.max_new_tokens):
                    # done at the prefill token: never occupies the slot
                    # for a decode tick
                    self._finish(req)
                    n_instant += 1
                    continue
                self.next_token[i] = first
                self.slots[i] = req
        return n_instant

    def _insert_slot(self, slot: int, cache1) -> None:
        """Scatter a batch-1 prefill cache into slot ``slot``."""
        def ins(big, small):
            return big.at[:, slot].set(small[:, 0].astype(big.dtype))
        self.cache["blocks"] = jax.tree.map(ins, self.cache["blocks"],
                                            cache1["blocks"])
        self.cache["lengths"] = self.cache["lengths"].at[slot].set(
            cache1["lengths"][0])

    # ------------------------------------------------------------- telemetry
    @property
    def ticks(self) -> int:
        return self._tick

    def reset_telemetry(self) -> None:
        """Zero the counters/histories (e.g. after a jit warmup run, so
        wall-clock tick timings exclude compile).  The engine must be
        drained; queued or in-flight requests would get skewed stamps."""
        if self.has_work():
            raise RuntimeError("reset_telemetry() on a busy engine")
        self.completed = 0
        self.total_tokens = 0
        self.finished = []
        self.util_history = []
        self._tick = 0

    def stats(self) -> Dict[str, float]:
        util = self.util_history
        return {
            "active": sum(r is not None for r in self.slots),
            "queued": len(self.queue),
            "completed": self.completed,
            "total_tokens": self.total_tokens,
            "ticks": self._tick,
            "mean_util": sum(util) / len(util) if util else 0.0,
        }
