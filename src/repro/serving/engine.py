"""Slot-based continuous-batching serving engine with an on-device hot path.

The batched decode step runs every tick over all occupied slots; requests
join by prefilling into a free slot and leave on EOS/length without
disturbing the others — the standard continuous-batching scheme
(Orca/vLLM) on a fixed-slot KV cache.

The engine is mechanism only; the serving stack is three explicit layers:

* :mod:`repro.serving.scheduler` owns *policy* — which queued request to
  admit (FCFS / SPF / EDF) and, for preemptive EDF, which running request
  to evict when a tighter deadline arrives;
* :mod:`repro.serving.slotstate` owns *state* — the cache pytree and the
  per-slot control mirrors, with a symmetric gather/scatter API so a
  slot's whole decode state can be evicted to host and later restored
  bit-exactly into any free slot (preempt → resume);
* this module owns *execution* — ``step()`` asks the scheduler, moves
  state through the slot manager, runs the prefill / fused-decode
  programs, and reports telemetry;
* :mod:`repro.plan` owns the *design point* — every constructor knob
  (capacity, bucket set, chunking, policy, sampling) lives in one frozen
  :class:`~repro.plan.ServingPlan`; build engines with
  :meth:`ServingEngine.from_plan` (the kwargs constructor is a shim that
  assembles a plan internally and behaves identically).

The steady-state hot path is the paper's thesis applied at the host level:
breaking the serving loop into per-kernel launches (decode, then a host
round-trip to sample, then a host read of the lengths) wastes the machine
on host↔device traffic exactly the way per-kernel launches waste it on
inter-kernel data movement.  So the decode tick is ONE fused jit program —
decode + sample + EOS/length done-mask + per-slot token writeback, with
the PRNG key carried as state — and up to ``sync_every`` ticks run
on-device between host syncs (a ``lax.while_loop`` that early-exits when
every slot is done, or when a slot frees while requests are queued so the
host can admit).  The host only intervenes to admit and retire.

Admission is bucketed batched prefill: prompts are right-padded to
power-of-two length buckets (capped at ``max_len - 1``) and all
same-bucket admissions prefill in one fixed-batch call, so the number of
prefill XLA compiles is bounded by the bucket count instead of the number
of distinct prompt lengths, and bursty (MMPP) arrival spikes amortize
into one program launch.  Slot insertion is one pytree scatter for the
whole admitted group.

With ``overlap_prefill=True`` (default) admission no longer serializes
with decode: the prefill program, the on-device first-token sample, the
slot scatter, and the decode chunk are all dispatched back-to-back with
no host sync in between, and the first tokens ride home on the chunk's
single readback.  The schedule (tick stamps, outputs, utilization) is
bit-identical to the synchronous path; only the blocking-readback count
drops.  Admission rounds that can finish at the prefill token (a request
with an ``eos_id``, or ``max_new_tokens == 1``) fall back to the
synchronous path, because instant retirement frees the slot for further
same-tick admissions and that decision needs the sampled token on host.

Virtual-clock semantics are unchanged: with the default ``sync_every=1``
(and for any ``sync_every`` under ``workload.drive``'s arrival-bounded
chunks) the tick-stamp schedule is bit-identical to the per-tick host
loop, so the fused path is a pure wall-clock optimization.
"""

from __future__ import annotations

import dataclasses
import logging
from functools import partial
from typing import Any, Dict, List, Optional, Set, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.dist.sharding import Sharder
from repro.models.lm import LM
from repro.obs.registry import LiveMetrics, MetricsRegistry
from repro.obs.trace import Tracer
from repro.plan.plan import MIN_BUCKET, ServingPlan
from repro.serving.sampler import SamplerConfig, split_and_sample
from repro.serving.scheduler import POLICIES, Scheduler, make_scheduler
from repro.serving.slotstate import SlotSnapshot, gather_slots, \
    make_slot_manager, scatter_slots

log = logging.getLogger("repro.serving")


class EngineKilled(RuntimeError):
    """Raised by ``step()`` when an attached fault injector schedules a
    ``kill_engine`` fault at the current tick — the process-crash stand-in
    for the crash-restart path.  ``faults.drive_resilient`` catches it,
    restores a fresh engine from the last checkpoint, and replays."""

    def __init__(self, tick: int):
        super().__init__(f"engine killed by fault injector at tick {tick}")
        self.tick = tick


@dataclasses.dataclass
class Request:
    uid: int
    prompt: List[int]
    max_new_tokens: int = 16
    eos_id: Optional[int] = None
    deadline: Optional[float] = None   # absolute, clock units (EDF + SLO)
    # filled by the engine
    output: List[int] = dataclasses.field(default_factory=list)
    done: bool = False
    shed: bool = False            # rejected at submit: provably past its
    #                               deadline (plan.shed_late admission ctl)
    truncated: bool = False       # prompt tail dropped (truncate_prompts)
    capped: bool = False          # cache can't hold max_new_tokens: the
    #                               output will stop short (length cut)
    retries: int = 0              # fault recoveries consumed (rollback /
    #                               re-prefill); shed past plan.retry_budget
    # tick stamps (engine tick counter; see serving.metrics for semantics)
    t_submit: int = 0             # tick at submission
    t_admit: Optional[int] = None   # tick the prefill ran (slot granted)
    t_first: Optional[int] = None   # tick the first token was produced
    t_done: Optional[int] = None    # tick the request completed
    # preemption lifecycle (EDF --preempt): evict-to-host / resume stamps
    n_preempts: int = 0
    t_preempts: List[int] = dataclasses.field(default_factory=list)
    t_resumes: List[int] = dataclasses.field(default_factory=list)
    saved: Optional[SlotSnapshot] = dataclasses.field(
        default=None, repr=False)   # host state while evicted


#: Request fields journaled by ``ServingEngine.checkpoint()`` — everything
#: except ``saved``, whose cache column travels in the array tree (the
#: paired ``next_token`` scalar rides as ``saved_next_token``).
_REQ_FIELDS = ("uid", "prompt", "max_new_tokens", "eos_id", "deadline",
               "output", "done", "shed", "truncated", "capped", "retries",
               "t_submit", "t_admit", "t_first", "t_done",
               "n_preempts", "t_preempts", "t_resumes")


def _req_to_json(req: "Request") -> Dict[str, Any]:
    d = {f: getattr(req, f) for f in _REQ_FIELDS}
    if req.saved is not None:
        d["saved_next_token"] = int(req.saved.next_token)
    return d


def _req_from_json(d: Dict[str, Any]) -> "Request":
    d = dict(d)
    d.pop("saved_next_token", None)
    return Request(**d)


def _is_reduced(cfg) -> bool:
    """Best-effort identity check for the kwargs shim: a config that
    differs from the registry entry of its own name is a reduced (or
    otherwise customized) variant.  Unknown names count as reduced —
    the flag only matters when ``from_plan`` has to rebuild the model."""
    try:
        from repro.configs import ARCHS

        return ARCHS.get(cfg.name) != cfg
    except Exception:  # pragma: no cover - configs import should not fail
        return True


@dataclasses.dataclass
class _PendingAdmit:
    """An overlapped admission group: first tokens still on device, host
    bookkeeping deferred to the decode chunk's readback."""

    reqs: List[Request]
    rows: List[int]
    slots: List[int]
    first: jax.Array            # (rows,) sampled prefill tokens, on device


def _decode_many(model: LM, sharder: Sharder, sampler: SamplerConfig,
                 max_len: int, k: int,
                 params, cache, tokens, key, active, eos, remaining,
                 limit, stop_on_free):
    """Up to ``min(k, limit)`` fused decode ticks on device, no host sync.

    Per tick: decode_step + sample + done-mask (EOS / cache-full /
    max_new_tokens) + per-slot token writeback, threading the PRNG key.
    Early-exits when no slot is active, or — when ``stop_on_free`` — after
    the first tick that frees a slot, so the host can admit a queued
    request at exactly the tick the per-tick loop would have.

    Returns (n_ticks, cache, key, toks (k,B), acts (k,B), dones (k,B));
    rows >= n_ticks of the buffers are zero.
    """
    B = tokens.shape[0]
    st = dict(i=jnp.int32(0), cache=cache, tokens=tokens, key=key,
              active=active, remaining=remaining,
              toks=jnp.zeros((k, B), jnp.int32),
              acts=jnp.zeros((k, B), bool),
              dones=jnp.zeros((k, B), bool),
              freed=jnp.bool_(False))

    def cond(st):
        return ((st["i"] < limit) & st["active"].any()
                & jnp.logical_not(stop_on_free & st["freed"]))

    def body(st):
        cache, logits = model.decode_step(params, st["cache"], st["tokens"],
                                          sharder)
        key, sampled = split_and_sample(st["key"], logits, sampler)
        active = st["active"]
        tokens = jnp.where(active, sampled, st["tokens"])
        remaining = st["remaining"] - active.astype(jnp.int32)
        hit_eos = (eos >= 0) & (sampled == eos)
        full = cache["lengths"] >= max_len - 1
        done_now = active & (hit_eos | full | (remaining <= 0))
        i = st["i"]
        return dict(
            i=i + 1, cache=cache, tokens=tokens, key=key,
            active=active & ~done_now, remaining=remaining,
            toks=st["toks"].at[i].set(tokens),
            acts=st["acts"].at[i].set(active),
            dones=st["dones"].at[i].set(done_now),
            freed=st["freed"] | done_now.any())

    st = jax.lax.while_loop(cond, body, st)
    return (st["i"], st["cache"], st["key"],
            st["toks"], st["acts"], st["dones"])


class ServingEngine:
    """Plan-driven construction: every design parameter lives in one
    :class:`repro.plan.ServingPlan` (``engine.plan``) — build with
    :meth:`from_plan`.  The historical kwargs constructor is kept as a
    thin shim that assembles a plan internally, so ``ServingEngine(model,
    params, sharder, max_batch=..., ...)`` keeps working with a
    bit-identical schedule to the equivalent ``from_plan`` engine."""

    def __init__(self, model: LM, params, sharder: Sharder, *,
                 max_batch: int = 4, max_len: int = 128,
                 sampler: SamplerConfig = SamplerConfig(), seed: int = 0,
                 truncate_prompts: bool = False, sync_every: int = 1,
                 policy: str = "fcfs", preempt: bool = False,
                 bucketed_prefill: bool = True,
                 overlap_prefill: bool = True,
                 shed_late: bool = False,
                 cache_layout: str = "dense",
                 plan: Optional[ServingPlan] = None,
                 tracer: Optional[Tracer] = None):
        if plan is None:   # kwargs shim: capture the knobs as a plan
            plan = ServingPlan(
                arch=model.cfg.name, reduced=_is_reduced(model.cfg),
                max_batch=max_batch, max_len=max_len,
                cache_layout=cache_layout,
                sync_every=sync_every, policy=policy, preempt=preempt,
                bucketed_prefill=bucketed_prefill,
                overlap_prefill=overlap_prefill, shed_late=shed_late,
                temperature=sampler.temperature, top_k=sampler.top_k,
                truncate_prompts=truncate_prompts,
                provenance={"source": "engine-kwargs"})
        plan.validate()
        if plan.tile_plans and hasattr(model, "with_tile_plans"):
            # thread the DSE-chosen kernel geometry into every block call
            # (both jit seams below close over this rebound model)
            model = model.with_tile_plans(plan.tile_plans)
        self.plan = plan
        self.model = model
        self.params = params
        self.sharder = sharder
        self.max_batch = plan.max_batch
        self.max_len = plan.max_len
        self.sampler = SamplerConfig(temperature=plan.temperature,
                                     top_k=plan.top_k)
        self.truncate_prompts = plan.truncate_prompts
        self.sync_every = int(plan.sync_every)
        self.policy = plan.policy
        self.bucketed_prefill = plan.bucketed_prefill
        self.overlap_prefill = plan.overlap_prefill
        self.shed_late = plan.shed_late
        self._buckets = plan.resolved_buckets()
        # one registry for the whole stack: scheduler + slot-state counters
        # register into it, so reset_telemetry() covers them by construction
        self.metrics = MetricsRegistry()
        self.scheduler: Scheduler = make_scheduler(
            plan.policy, preempt=plan.preempt, registry=self.metrics)
        self.cache_layout = plan.cache_layout
        self._paged = plan.cache_layout != "dense"
        self.sm = make_slot_manager(model, self.max_batch, self.max_len,
                                    layout=plan.cache_layout,
                                    registry=self.metrics)
        c = self.metrics.counter
        self._c_completed = c("engine.completed",
                              "requests finished since construction")
        self._c_total_tokens = c("engine.total_tokens",
                                 "tokens generated (prefill + decode)")
        self._c_instant_admits = c("engine.instant_admits",
                                   "requests done at their prefill token")
        self._c_host_syncs = c("engine.host_syncs",
                               "blocking device->host readbacks")
        self._c_decode_chunks = c("engine.decode_chunks",
                                  "fused decode_many launches")
        self._c_prefill_calls = c("engine.prefill_calls",
                                  "prefill program launches")
        self._c_preemptions = c("engine.preemptions",
                                "slots evicted to host")
        self._c_resumes = c("engine.resumes",
                            "evicted requests restored to a slot")
        self._c_evicted_tokens = c("engine.evicted_tokens",
                                   "tokens already generated at eviction")
        self._c_shed = c("engine.shed",
                         "requests rejected at submit (admission control)")
        # fault-tolerance counters: registered always (so reset_telemetry
        # covers them), but surfaced via fault_stats() rather than stats()
        # — no-fault runs keep their historical stats()/BENCH bytes
        self._c_f_injected = c("faults.injected",
                               "faults fired by the attached injector")
        self._c_f_quarantined = c("faults.quarantined",
                                  "slots quarantined (poison / dropped "
                                  "readback / watchdog)")
        self._c_f_retries = c("faults.retries",
                              "request rollbacks (re-queued from the last "
                              "good snapshot or re-prefilled)")
        self._c_f_shed = c("faults.shed",
                           "requests shed after exhausting retry_budget")
        self._c_f_watchdog = c("faults.watchdog_evictions",
                               "stuck slots evicted by the watchdog")
        self.metrics.gauge("engine.ticks", "virtual-clock tick counter",
                           fn=lambda: float(self._tick))
        self.finished: List[Request] = []   # completed Requests, in order
        self.util_history: List[float] = []  # per-tick (active+instant)/max
        self.prefill_shapes: Set[Tuple[int, int]] = set()  # (rows, S) seen
        self.tracer = tracer          # optional structured event tracer
        self.live: Optional[LiveMetrics] = None   # enable_live_metrics()
        self._decode_compile_traced = False  # decode program built once
        self._pending: List[_PendingAdmit] = []  # overlapped admissions
        self._tick = 0
        self._uid_next = 0   # plain int (not itertools.count): journaled
        #                      by checkpoint() so restored engines mint
        #                      identical uids for replayed submissions
        # ---- fault tolerance (inert unless an injector is attached or
        # ---- the plan enables the watchdog — see _fault_mode) ----------
        self.retry_budget = int(plan.retry_budget)
        self.watchdog_ticks = int(plan.watchdog_ticks)
        self._injector = None                   # faults.FaultInjector
        self.fault_events: List[Dict[str, Any]] = []
        self._awaiting: Dict[int, Dict[str, Any]] = {}  # uid -> open event
        self._recovery: Dict[int, Tuple[Optional[SlotSnapshot], int]] = {}
        self._stalled: Set[int] = set()         # slots frozen by stall_slot
        self._poison_outstanding: Set[int] = set()  # scribbled, not yet seen
        self._last_progress = np.zeros((self.max_batch,), np.int64)
        self._drop_readback = False             # armed: next chunk readback
        #                                         is discarded wholesale
        self._fail_prefill = False              # armed: next prefill call
        #                                         fails before launch
        self._prefill_blocked = False           # a prefill failed this tick
        self.restored_from: Optional[Dict[str, Any]] = None
        self._key = jax.random.PRNGKey(seed)
        self._decode_many = jax.jit(
            partial(_decode_many, model, sharder, self.sampler,
                    self.max_len, self.sync_every),
            donate_argnums=1)
        self._prefill = jax.jit(
            lambda p, b: model.prefill(p, b, sharder,
                                       max_len=self.max_len))

    @classmethod
    def from_plan(cls, plan: ServingPlan, params, *,
                  model: Optional[LM] = None,
                  sharder: Optional[Sharder] = None,
                  seed: int = 0,
                  tracer: Optional[Tracer] = None) -> "ServingEngine":
        """Build an engine from a :class:`repro.plan.ServingPlan` — the
        plan-centric constructor.  ``model``/``sharder`` default to what
        the plan's identity fields describe (``arch`` + ``reduced`` +
        ``shard_mode``); pass them explicitly to reuse an already-built
        model (the benchmark sweeps do)."""
        plan.validate()
        if model is None:
            from repro.configs import get_config
            from repro.models.lm import build_model
            from repro.testing import reduced_config

            cfg = (reduced_config(plan.arch) if plan.reduced
                   else get_config(plan.arch))
            model = build_model(cfg)
        if sharder is None:
            from repro.dist.sharding import make_sharder

            sharder = make_sharder(model.cfg, None, plan.shard_mode)
        return cls(model, params, sharder, seed=seed, plan=plan,
                   tracer=tracer)

    # ------------------------------------------------- back-compat accessors
    @property
    def cache(self):
        return self.sm.cache

    @property
    def slots(self) -> List[Optional[Request]]:
        return self.sm.slots

    @property
    def queue(self):
        return self.scheduler.queue

    # counters live in the registry; these read-only views keep the
    # historical attribute names (engine.completed, engine.shed, ...)
    @property
    def completed(self) -> int:
        return self._c_completed.value

    @property
    def total_tokens(self) -> int:
        return self._c_total_tokens.value

    @property
    def instant_admits(self) -> int:
        return self._c_instant_admits.value

    @property
    def host_syncs(self) -> int:
        return self._c_host_syncs.value

    @property
    def decode_chunks(self) -> int:
        return self._c_decode_chunks.value

    @property
    def prefill_calls(self) -> int:
        return self._c_prefill_calls.value

    @property
    def preemptions(self) -> int:
        return self._c_preemptions.value

    @property
    def resumes(self) -> int:
        return self._c_resumes.value

    @property
    def evicted_tokens(self) -> int:
        return self._c_evicted_tokens.value

    @property
    def shed(self) -> int:
        return self._c_shed.value

    def enable_live_metrics(self, window: int = 64) -> LiveMetrics:
        """Attach a rolling :class:`repro.obs.LiveMetrics` window (last
        ``window`` ticks); the engine feeds it every tick and every
        retired request.  Returns the window for polling (``snapshot()``
        / ``line()``)."""
        self.live = LiveMetrics(window)
        return self.live

    # ------------------------------------------------------------------ API
    def submit(self, prompt: List[int], max_new_tokens: int = 16,
               eos_id: Optional[int] = None,
               deadline: Optional[float] = None) -> Request:
        prompt = list(prompt)
        if not prompt:
            raise ValueError("empty prompt")
        if max_new_tokens < 1:
            raise ValueError(f"max_new_tokens must be >= 1, got "
                             f"{max_new_tokens}: the prefill always emits "
                             f"one token")
        limit = self.max_len - 1  # >= 1 cache slot left for generation
        truncated = False
        if len(prompt) > limit:
            if not self.truncate_prompts:
                raise ValueError(
                    f"prompt length {len(prompt)} exceeds max_len-1 = "
                    f"{limit}; raise max_len or construct the engine with "
                    f"truncate_prompts=True to drop the tail")
            log.warning("truncating prompt from %d to %d tokens "
                        "(max_len=%d)", len(prompt), limit, self.max_len)
            prompt, truncated = prompt[:limit], True
        req = Request(self._uid_next, prompt, max_new_tokens, eos_id,
                      deadline=deadline, truncated=truncated,
                      t_submit=self._tick)
        self._uid_next += 1
        # the `full` stop in the decode loop cuts generation at max(2,
        # max_len - len(prompt)) tokens (prefill token + decodes until the
        # cache fills): flag requests whose max_new_tokens cannot fit
        # instead of cutting the output silently
        cap = max(2, self.max_len - len(prompt))
        if max_new_tokens > cap:
            req.capped = True
            log.warning("request %d: max_new_tokens=%d exceeds cache room "
                        "for a %d-token prompt (max_len=%d); output stops "
                        "at %d tokens", req.uid, max_new_tokens,
                        len(prompt), self.max_len, cap)
        if self.tracer is not None:
            # every submission is traced — shed traffic included, so
            # obs.observe.fit_profile sees the *offered* load, not just
            # what admission control let through
            self.tracer.request_submit(req, self._tick)
        if (self.shed_late and deadline is not None
                and self._provably_late(req)):
            # deadline-aware admission control: reject work that cannot
            # meet its SLO even if admitted this very tick, instead of
            # spending slot-ticks on a guaranteed violation
            req.shed = True
            self._c_shed.inc()
            if self.tracer is not None:
                self.tracer.request_shed(req, self._tick)
            if self.live is not None:
                self.live.observe_request(req, self._tick)
            log.debug("shed req %d at tick %d: deadline %.1f < earliest "
                      "completion", req.uid, self._tick, deadline)
            return req
        self.scheduler.submit(req)
        return req

    def _provably_late(self, req: Request) -> bool:
        """True when the request cannot meet its deadline even with a slot
        granted *now*: earliest completion is the prefill tick plus the
        remaining decode ticks.  The bound is strictly conservative — a
        request with an ``eos_id`` could retire at its prefill token, so
        only the prefill tick counts; without one the output length is
        exactly ``max_new_tokens`` (or the cache cap, whichever is
        smaller).  Completion-by-deadline uses the SLO convention
        ``t_done + 1 <= deadline``.

        The bound equates one engine tick with one deadline clock unit —
        exact on the virtual clock (the benchmark/SLO convention, where
        deadlines are tick-denominated by construction).  Under
        ``--clock wall`` ticks run at the hardware's pace, so the bound
        is a heuristic there, not a proof."""
        if req.eos_id is not None:
            min_decode = 0      # could instant-EOS at the prefill token
        else:
            cap = max(2, self.max_len - len(req.prompt))
            min_decode = min(req.max_new_tokens, cap) - 1
        earliest_end = self._tick + 1 + min_decode
        return req.deadline < earliest_end

    def has_work(self) -> bool:
        """True while any request is queued or occupying a slot."""
        return bool(len(self.scheduler)) or self.sm.n_active() > 0

    def run(self, max_steps: int = 10_000) -> None:
        for _ in range(max_steps):
            if not self.step():
                break

    # ------------------------------------------------------------- buckets
    def bucket(self, n: int) -> int:
        """Padded prefill length for an n-token prompt: the smallest
        bucket that fits it.  The bucket set comes from the plan
        (``plan.buckets``, defaulting to the historical pow2 set)."""
        if not self.bucketed_prefill:
            return n
        for b in self._buckets:
            if b >= n:
                return b
        return self._buckets[-1]

    @property
    def bucket_lengths(self) -> List[int]:
        """All bucket lengths this engine can emit (= its prefill compile
        ceiling in bucketed mode)."""
        return list(self._buckets)

    # ----------------------------------------------------------------- ticks
    def step(self, max_ticks: Optional[int] = None) -> bool:
        """One host intervention: ask the scheduler (preempt + admit), run
        up to ``min(sync_every, max_ticks)`` fused decode ticks on device
        with a single host sync at the end, report telemetry.  Returns
        False when idle."""
        budget = self.sync_every if max_ticks is None \
            else max(1, min(int(max_ticks), self.sync_every))
        if self._injector is not None:
            self._apply_due_faults()   # may raise EngineKilled
        n_instant = self._schedule()
        if self.tracer is not None:
            self.tracer.counter(self._tick, "queue_depth",
                                len(self.scheduler))
        active_idx = self.sm.occupied()
        if not active_idx:
            if n_instant:
                # prefill-only tick: every admit finished at its first
                # token.  Real work happened, so time still advances.
                self._observe_tick(self._tick, n_instant / self.max_batch)
                self._tick += 1
                return True
            return bool(len(self.scheduler))
        # if requests wait in the queue, break the chunk as soon as a slot
        # frees so admission happens at the same tick the per-tick loop
        # would have admitted at
        stop_on_free = bool(len(self.scheduler))
        if self.tracer is not None and not self._decode_compile_traced:
            # the fused decode program has fixed shapes: XLA builds it
            # exactly once, on the first chunk launch
            self.tracer.compile(self._tick, "decode", self.max_batch,
                                self.sync_every)
            self._decode_compile_traced = True
        # paged layout: extend every occupied slot's block coverage for
        # the chunk's ring writes before the program launches (dense: no-op)
        self.sm.ensure_chunk(budget)
        tokens_in = self._merge_pending_tokens()
        n, self.sm.cache, self._key, toks, acts, dones = self._decode_many(
            self.params, self.sm.cache, tokens_in, self._key,
            self.sm.active, self.sm.eos, self.sm.remaining,
            np.int32(budget), np.bool_(stop_on_free))
        self._c_decode_chunks.inc()
        # ---- the chunk's single blocking host<->device sync -------------
        # (overlapped admissions' first tokens ride home on the same pull)
        n, toks, acts, dones, firsts = jax.device_get(
            (n, toks, acts, dones, [p.first for p in self._pending]))
        n = int(n)
        self._c_host_syncs.inc()
        # fault path: a dropped readback discards the whole chunk's tokens
        # (and the overlapped first tokens riding on it) — every slot that
        # decoded rolls back to its last recovery point
        dropped = self._drop_readback and n > 0
        self._drop_readback = False
        if not dropped:
            for p, fv in zip(self._pending, firsts):
                for req, row in zip(p.reqs, p.rows):
                    req.output.append(int(fv[row]))
                    self._c_total_tokens.inc()
        self._pending = []
        if dropped:
            bad = [i for i in active_idx if self.sm.slots[i] is not None
                   and self.sm.active[i]]
        elif self._injector is not None and n > 0:
            bad = self._scan_poisoned(active_idx)
        else:
            bad = []
        bad_set = set(bad)
        progressed: Set[int] = set()
        base = self._tick
        if self.tracer is not None:
            self.tracer.decode_chunk(base, n, len(active_idx))
        for j in range(n):
            n_active = 0
            for i in active_idx:
                req = self.sm.slots[i]
                if req is None or not acts[j, i] or i in bad_set:
                    continue
                n_active += 1
                progressed.add(i)
                req.output.append(int(toks[j, i]))
                self._c_total_tokens.inc()
                if dones[j, i]:
                    self._finish(req, base + j)
                    self.sm.release(i)
            self._observe_tick(
                base + j,
                (n_active + (n_instant if j == 0 else 0)) / self.max_batch)
        self._tick += n
        if self.tracer is not None:
            self.tracer.host_sync(self._tick)
        if n > 0:
            # refresh the host mirrors from the authoritative slot table
            self.sm.refresh_after_chunk(toks[n - 1])
        else:
            # fault mode only: every occupied slot is stalled, so the
            # fused loop ran zero ticks.  Time still advances one tick so
            # the watchdog can reach its threshold and evict.
            self._observe_tick(self._tick, n_instant / self.max_batch)
            self._tick += 1
        if self._fault_mode:
            self._fault_epilogue(bad, dropped, progressed)
        log.debug("chunk of %d ticks -> tick %d: util=%.2f queued=%d "
                  "completed=%d total_tokens=%d syncs=%d", n, self._tick,
                  self.util_history[-1], len(self.scheduler), self.completed,
                  self.total_tokens, self.host_syncs)
        return True

    # ------------------------------------------------------------- internals
    def _finish(self, req: Request, tick: int) -> None:
        req.done = True
        req.t_done = tick
        if self._fault_mode:
            self._recovery.pop(req.uid, None)
        self._c_completed.inc()
        self.finished.append(req)
        if self.tracer is not None:
            self.tracer.request_done(req, tick)
        if self.live is not None:
            self.live.observe_request(req, tick)

    def _observe_tick(self, tick: int, util: float) -> None:
        """One virtual-clock tick's utilization, fanned out to every
        observer: the aggregate history, the rolling live window, and the
        trace's counter track."""
        self.util_history.append(util)
        if self.live is not None:
            self.live.observe_tick(tick, util)
        if self.tracer is not None:
            self.tracer.counter(tick, "util", util)
            if self._paged:
                # fragmentation tracks, paged runs only — dense traces
                # stay byte-identical to the pre-paged engine
                self.tracer.counter(tick, "blocks_free",
                                    self.sm.blocks_free())
                self.tracer.counter(tick, "bytes_resident",
                                    self.sm.bytes_resident())
                self.tracer.counter(tick, "padding_waste",
                                    self.sm.padding_waste())

    # -------------------------------------------------------- fault tolerance
    @property
    def _fault_mode(self) -> bool:
        """True when any recovery machinery must run: an injector is
        attached or the plan's watchdog is enabled.  Everything in this
        section is gated on it, so plain engines keep a byte-identical
        schedule, telemetry, and trace."""
        return self._injector is not None or self.watchdog_ticks > 0

    def attach_injector(self, injector) -> None:
        """Attach a :class:`repro.serving.faults.FaultInjector`; its due
        faults are applied at the top of every :meth:`step`."""
        if injector.plan.needs_watchdog() and self.watchdog_ticks <= 0:
            raise ValueError(
                "fault plan contains stall_slot faults but the engine's "
                "watchdog is off; set plan.watchdog_ticks > 0 so stalled "
                "requests can be evicted and retried")
        self._injector = injector

    def fault_stats(self) -> Dict[str, float]:
        """Fault/recovery counter view — separate from :meth:`stats` so
        no-fault runs keep their historical stats() keys byte-for-byte."""
        return self.metrics.view({
            "injected": "faults.injected",
            "quarantined": "faults.quarantined",
            "retries": "faults.retries",
            "shed": "faults.shed",
            "watchdog_evictions": "faults.watchdog_evictions",
        })

    def _apply_due_faults(self) -> None:
        """Fire every fault the injector scheduled at or before the current
        tick.  Slot faults (poison/stall) stay armed while no slot is
        occupied — they need a victim — and fall back to the lowest
        occupied slot when their nominal target is empty, so a fault plan
        written against one workload stays meaningful on another."""
        for idx, spec in self._injector.due(self._tick):
            if spec.kind == "kill_engine":
                self._injector.fire(idx, self._tick)
                self._c_f_injected.inc()
                self.fault_events.append(
                    {"kind": "kill_engine", "tick": self._tick,
                     "uid": None, "slot": None, "recovered_at": None})
                if self.tracer is not None:
                    self.tracer.engine_fault(self._tick, "kill_engine")
                raise EngineKilled(self._tick)
            if spec.kind == "drop_readback":
                self._injector.fire(idx, self._tick)
                self._c_f_injected.inc()
                self._drop_readback = True
                if self.tracer is not None:
                    self.tracer.engine_fault(self._tick, "drop_readback")
            elif spec.kind == "fail_prefill":
                self._injector.fire(idx, self._tick)
                self._c_f_injected.inc()
                self._fail_prefill = True
            else:   # poison_slot / stall_slot need an occupied victim
                occ = self.sm.occupied()
                if not occ:
                    continue   # not fired: stays due for a later tick
                slot = (spec.slot if spec.slot in occ else occ[0])
                self._injector.fire(idx, self._tick)
                self._c_f_injected.inc()
                if self.tracer is not None:
                    self.tracer.engine_fault(self._tick, spec.kind,
                                             slot=slot)
                if spec.kind == "poison_slot":
                    self._poison(slot, spec)
                else:
                    self._stalled.add(slot)
                    self.sm.active[slot] = False

    def _poison(self, slot: int, spec) -> None:
        """Corrupt ``slot``'s cache column in place: overwrite every float
        leaf with NaN (``mode="nan"``) or seeded large-magnitude garbage
        salted with ±Inf (``mode="garbage"``) — both detectable by the
        non-finite guard scan after the next chunk."""
        col = jax.device_get(gather_slots(self.sm.cache, self.sm.axes,
                                          [slot]))
        rng = np.random.default_rng(spec.seed)

        def scribble(a):
            a = np.asarray(a)
            if not jnp.issubdtype(a.dtype, jnp.floating):
                return a
            if spec.mode == "nan":
                return np.full_like(a, np.nan)
            g = (rng.standard_normal(a.shape) * 1e30).astype(np.float32)
            g[rng.uniform(size=a.shape) < 0.25] = np.inf
            g.reshape(-1)[0] = -np.inf   # at least one non-finite value
            return g.astype(a.dtype)

        bad = jax.tree.map(scribble, col)
        self.sm.cache = scatter_slots(self.sm.cache, self.sm.axes, [slot],
                                      bad)
        self._poison_outstanding.add(slot)

    def _scan_poisoned(self, active_idx: List[int]) -> List[int]:
        """Per-slot non-finite guard over every float cache leaf, reduced
        on device to one (max_batch,) flag vector — runs only while a
        poison is outstanding, so fault-free chunks pay nothing."""
        self._poison_outstanding = {
            s for s in self._poison_outstanding
            if self.sm.slots[s] is not None}
        if not self._poison_outstanding:
            return []
        flags = np.zeros((self.max_batch,), bool)
        cache = self.sm.cache
        checks = []
        for leaf, ax in zip(jax.tree.leaves(cache),
                            jax.tree.leaves(self.sm.axes)):
            if not jnp.issubdtype(leaf.dtype, jnp.floating):
                continue
            red = tuple(d for d in range(leaf.ndim) if d != ax)
            checks.append(jnp.any(~jnp.isfinite(leaf), axis=red))
        for bad in jax.device_get(checks):
            flags |= np.asarray(bad)
        caught = [i for i in active_idx
                  if flags[i] and self.sm.slots[i] is not None]
        self._poison_outstanding -= set(caught)
        return caught

    def _quarantine(self, slot: int, tick: int, kind: str) -> None:
        """Pull a bad slot out of service: scrub the column (no residue
        for the next tenant), release the slot, roll the request back."""
        req = self.sm.slots[slot]
        self._c_f_quarantined.inc()
        if kind == "watchdog":
            self._c_f_watchdog.inc()
        self.sm.scrub([slot])
        self.sm.release(slot)
        self._stalled.discard(slot)
        self._poison_outstanding.discard(slot)
        self._rollback(req, tick, kind, slot)

    def _rollback(self, req: Request, tick: int, kind: str,
                  slot: Optional[int] = None) -> None:
        """Re-queue ``req`` from its last good recovery point (or from
        scratch when none exists), charging one retry; past the budget the
        request is shed — the engine never emits tokens it cannot vouch
        for.  Emits the fault event + trace instants."""
        event = {"kind": kind, "tick": tick, "uid": req.uid, "slot": slot,
                 "recovered_at": None}
        self.fault_events.append(event)
        self._awaiting[req.uid] = event
        if self.tracer is not None:
            self.tracer.request_fault(req, tick, kind, slot)
        req.retries += 1
        rp = self._recovery.get(req.uid)
        if req.retries > self.retry_budget:
            req.shed = True
            event["shed"] = True
            event["recovered_at"] = tick
            self._awaiting.pop(req.uid, None)
            self._recovery.pop(req.uid, None)
            self._c_f_shed.inc()
            if self.tracer is not None:
                self.tracer.request_quarantine(req, tick, tick)
                self.tracer.request_shed(req, tick)
            if self.live is not None:
                self.live.observe_request(req, tick)
            log.debug("shed req %d at tick %d: retry budget %d exhausted "
                      "(%s)", req.uid, tick, self.retry_budget, kind)
            return
        self._c_f_retries.inc()   # counts re-queues, not the shedding try
        if rp is not None:
            snap, n_out = rp
            del req.output[n_out:]
            req.saved = snap
        else:
            del req.output[:]
            req.saved = None
        self.scheduler.requeue_front(req)
        if self.tracer is not None:
            self.tracer.request_retry(req, tick, req.retries)
        log.debug("rolled back req %d at tick %d (%s, retry %d/%d, "
                  "%d tokens kept)", req.uid, tick, kind, req.retries,
                  self.retry_budget, len(req.output))

    def _mark_recovered(self, req: Request) -> None:
        """A rolled-back request made it back into a slot: close its open
        fault event and emit the quarantine span (fault tick -> now)."""
        event = self._awaiting.pop(req.uid, None)
        if event is None:
            return
        event["recovered_at"] = self._tick
        if self.tracer is not None:
            self.tracer.request_quarantine(req, event["tick"], self._tick)

    def _fault_epilogue(self, bad: List[int], dropped: bool,
                        progressed: Set[int]) -> None:
        """End-of-chunk fault bookkeeping: quarantine flagged slots, run
        the watchdog, re-assert stalls over the refreshed mirrors, and
        refresh every survivor's recovery point."""
        for i in progressed:
            self._last_progress[i] = self._tick
        for i in bad:
            if self.sm.slots[i] is not None:
                self._quarantine(i, self._tick,
                                 "drop_readback" if dropped else "poison")
        # refresh_after_chunk derived `active` from occupancy: re-freeze
        # slots the injector stalled (their request is wedged, not done)
        for i in list(self._stalled):
            if self.sm.slots[i] is None:
                self._stalled.discard(i)
            else:
                self.sm.active[i] = False
        if self.watchdog_ticks > 0:
            for i in self.sm.occupied():
                if self._tick - self._last_progress[i] >= self.watchdog_ticks:
                    self._quarantine(i, self._tick, "watchdog")
        self._refresh_recovery()

    def _refresh_recovery(self) -> None:
        """Snapshot every occupied slot as its request's last *good*
        recovery point (the guard scan / quarantine above already removed
        every slot known bad, so what remains is vouched-for state).

        Stalled slots are skipped: the fused chunk advances *every*
        lane's device state (only the token/remaining writebacks are
        masked by ``active``), so a wedged slot's column silently drifts
        from its frozen outputs — its recovery point must stay the last
        pre-stall snapshot or the watchdog rollback resumes from state
        the request never emitted tokens for."""
        occ = [i for i in self.sm.occupied() if i not in self._stalled]
        if not occ:
            return
        snaps = self.sm.snapshot_many(occ)
        self._c_host_syncs.inc()
        for slot, snap in zip(occ, snaps):
            req = self.sm.slots[slot]
            self._recovery[req.uid] = (snap, len(req.output))

    def _merge_pending_tokens(self):
        """Decode-chunk input tokens: the host mirror, with overlapped
        admissions' first tokens merged in on device (they were sampled by
        the prefill program and never came to host)."""
        if not self._pending:
            return self.sm.next_token
        tokens = jnp.asarray(self.sm.next_token)
        for p in self._pending:
            tokens = tokens.at[jnp.asarray(p.slots, jnp.int32)].set(
                p.first[jnp.asarray(p.rows, jnp.int32)])
        return tokens

    # ----------------------------------------------------------- scheduling
    def preempt(self, slot: int) -> Request:
        """Evict the request in ``slot`` to host memory and requeue it
        (see :meth:`preempt_many` — this is the one-victim case).  Public
        for manual load shedding and the round-trip tests."""
        return self.preempt_many([slot])[0]

    def preempt_many(self, slots: List[int]) -> List[Request]:
        """Evict N running requests to host memory and requeue them, in
        ``slots`` order, with ONE batched device->host transfer.

        ``SlotManager.snapshot_many`` gathers every victim's cache column
        in a single ``gather_slots`` + ``device_get`` instead of N
        sequential snapshots, so a preemption burst (EDF under an arrival
        spike) costs one host sync, not one per victim.  Bookkeeping is
        per-victim and order-preserving — ``requeue_front`` runs in
        ``slots`` order exactly as N sequential :meth:`preempt` calls
        would, so the schedule is bit-identical to the sequential path.
        Once the scheduler grants a victim a slot again it resumes
        bit-exactly under greedy decoding (with stochastic sampling the
        engine-global key stream makes resumed tokens slot/tick-dependent
        — see slotstate's module docstring)."""
        if not slots:
            return []   # no victims: no gather, no host sync
        reqs: List[Request] = []
        for slot in slots:
            if self.sm.slots[slot] is None:
                raise ValueError(f"slot {slot} is empty")
            reqs.append(self.sm.slots[slot])
        snaps = self.sm.snapshot_many(slots)
        self._c_host_syncs.inc()
        if self.tracer is not None:
            self.tracer.host_sync(self._tick)
        for slot, req, snap in zip(slots, reqs, snaps):
            req.saved = snap
            req.n_preempts += 1
            req.t_preempts.append(self._tick)
            self._c_preemptions.inc()
            self._c_evicted_tokens.inc(len(req.output))
            self.sm.release(slot)
            self.scheduler.requeue_front(req)
            if self.tracer is not None:
                self.tracer.request_preempt(req, self._tick, slot,
                                            len(req.output))
            log.debug("preempted req %d from slot %d at tick %d "
                      "(%d tokens evicted to host)", req.uid, slot,
                      self._tick, len(req.output))
        return reqs

    def _schedule(self) -> int:
        """One scheduler consultation: preempt (if the policy does), then
        admit queued requests into free slots.  Returns how many admits
        finished at their prefill token."""
        if self.scheduler.preemptive and len(self.scheduler):
            victims = self.scheduler.victims(self.sm.running(),
                                             len(self.sm.free()))
            if victims:
                self.preempt_many(victims)
        return self._admit()

    def _admit(self) -> int:
        """Admit queued requests into free slots — evicted requests are
        restored from their host snapshots (no model call), fresh ones go
        through bucketed batched prefill.  Returns how many finished at
        their prefill token (max_new_tokens=1 / instant EOS) — those never
        occupy a slot, so further queued requests are retried in the same
        tick."""
        n_instant = 0
        while len(self.scheduler):
            free = self.sm.free()
            if not free:
                break
            picked = self.scheduler.pick(len(free))
            resumes = [r for r in picked if r.saved is not None]
            fresh = [r for r in picked if r.saved is None]
            for req in resumes:
                slot = free.pop(0)
                self.sm.restore(slot, req.saved, req)
                req.saved = None
                req.t_resumes.append(self._tick)
                self._c_resumes.inc()
                if self._fault_mode:
                    self._last_progress[slot] = self._tick
                    self._mark_recovered(req)
                if self.tracer is not None:
                    self.tracer.request_resume(req, self._tick, slot)
                log.debug("resumed req %d into slot %d at tick %d",
                          req.uid, slot, self._tick)
            if not fresh:
                continue
            if self.bucketed_prefill:
                groups: Dict[int, List[Request]] = {}
                for req in fresh:
                    groups.setdefault(self.bucket(len(req.prompt)),
                                      []).append(req)
                grouped = sorted(groups.items())
            else:
                # legacy comparison path: one exact-length batch-1 prefill
                # per request (compile count grows with distinct lengths)
                grouped = [(len(r.prompt), [r]) for r in fresh]
            # instant retirement (EOS at the prefill token / one-token
            # budget) frees the slot for further same-tick admissions, and
            # that decision needs the sampled token on host: such rounds
            # take the synchronous path
            overlap = (self.overlap_prefill
                       and not any(r.eos_id is not None
                                   or r.max_new_tokens == 1 for r in fresh))
            for S, reqs in grouped:
                n_instant += self._prefill_group(S, reqs, free, overlap)
            if self._prefill_blocked:
                # a fault just failed the prefill call and requeued its
                # group; stop admitting this tick or we'd pick the same
                # requests again in an endless same-tick loop
                self._prefill_blocked = False
                break
        return n_instant

    def _prefill_group(self, S: int, reqs: List[Request],
                       free: List[int], overlap: bool) -> int:
        """One padded batched prefill for same-bucket admissions: sample
        every first token in one call, scatter all granted slots in one
        pytree op.  Mutates ``free`` as slots are granted.

        ``overlap=True`` keeps the sampled first tokens on device and
        defers the host bookkeeping to the decode chunk's readback, so
        the prefill never blocks the chunk launch."""
        if self._fail_prefill:
            # injected fault: the prefill call fails before launch.  The
            # whole group rolls back (fresh requests: re-prefill from
            # scratch, charged one retry) and admission stops this tick.
            self._fail_prefill = False
            self._prefill_blocked = True
            self.fault_events.append(
                {"kind": "fail_prefill", "tick": self._tick, "uid": None,
                 "slot": None, "recovered_at": None})
            if self.tracer is not None:
                self.tracer.engine_fault(self._tick, "fail_prefill",
                                         rows=len(reqs))
            for req in reqs:
                self._rollback(req, self._tick, "fail_prefill")
            return 0
        rows = self.max_batch if self.bucketed_prefill else len(reqs)
        tokens = np.zeros((rows, S), np.int32)
        lengths = np.ones((rows,), np.int32)   # dummy rows: 1 valid token
        for r_i, req in enumerate(reqs):
            tokens[r_i, :len(req.prompt)] = req.prompt
            lengths[r_i] = len(req.prompt)
        batch = {"tokens": jnp.asarray(tokens),
                 "lengths": jnp.asarray(lengths)}
        if self.model.cfg.m_rope_sections:
            batch["positions"] = jnp.broadcast_to(
                jnp.arange(S, dtype=jnp.int32), (rows, 3, S))
        if self.tracer is not None:
            if (rows, S) not in self.prefill_shapes:
                self.tracer.compile(self._tick, "prefill", rows, S)
            self.tracer.prefill(self._tick, S, rows, len(reqs), overlap)
        cacheN, logitsN = self._prefill(self.params, batch)
        self._c_prefill_calls.inc()
        self.prefill_shapes.add((rows, S))
        self._key, first = split_and_sample(self._key, logitsN, self.sampler)
        if overlap:
            grant_rows, grant_slots = [], []
            for r_i, req in enumerate(reqs):
                slot = free.pop(0)
                self.sm.grant(slot, req, None)
                req.t_admit = req.t_first = self._tick
                if self._fault_mode:
                    self._last_progress[slot] = self._tick
                    self._mark_recovered(req)
                grant_rows.append(r_i)
                grant_slots.append(slot)
            self.sm.insert_from_prefill(grant_slots, grant_rows, cacheN)
            self._pending.append(_PendingAdmit(list(reqs), grant_rows,
                                               grant_slots, first))
            return 0
        first = np.asarray(first)
        self._c_host_syncs.inc()
        if self.tracer is not None:
            self.tracer.host_sync(self._tick)
        n_instant = 0
        grant_rows, grant_slots = [], []
        for r_i, req in enumerate(reqs):
            tok = int(first[r_i])
            req.output.append(tok)
            self._c_total_tokens.inc()
            req.t_admit = req.t_first = self._tick
            if self._fault_mode:
                self._mark_recovered(req)
            if ((req.eos_id is not None and tok == req.eos_id)
                    or len(req.output) >= req.max_new_tokens):
                # done at the prefill token: never occupies a slot
                self._finish(req, self._tick)
                n_instant += 1
                self._c_instant_admits.inc()
                continue
            slot = free.pop(0)
            self.sm.grant(slot, req, tok)
            if self._fault_mode:
                self._last_progress[slot] = self._tick
            grant_rows.append(r_i)
            grant_slots.append(slot)
        if grant_rows:
            self.sm.insert_from_prefill(grant_slots, grant_rows, cacheN)
        return n_instant

    # ------------------------------------------------------- crash restart
    def all_requests(self) -> List[Request]:
        """Every request the engine is currently tracking: finished, slot
        resident, and queued (in that order).  Shed requests the caller
        already holds are final — they appear in no engine structure."""
        out: List[Request] = list(self.finished)
        out.extend(r for r in self.sm.slots if r is not None)
        out.extend(self.scheduler.queue)
        return out

    def checkpoint(self, manager, *, clock_now: Optional[float] = None,
                   blocking: bool = True) -> int:
        """Journal the complete engine state through a
        :class:`repro.checkpoint.CheckpointManager` step (named by the
        current tick): PRNG key + slot mirrors + every occupied slot's
        cache column + every evicted snapshot column as the array tree,
        and requests / queue order / tick / uid counter / fault state as
        JSON extra.  :meth:`restore` rebuilds an engine that replays the
        remaining schedule bit-identically.

        Must run between steps (no overlapped admissions in flight) — the
        driver checkpoints at chunk boundaries, where that always holds."""
        if self._pending:
            raise RuntimeError("checkpoint() with overlapped admissions "
                               "in flight; call between steps")
        from repro.plan import io as plan_io

        occ = self.sm.occupied()
        slot_cols: Dict[str, Any] = {}
        slots_json: Dict[str, Any] = {}
        if occ:
            snaps = self.sm.snapshot_many(occ)
            self._c_host_syncs.inc()
            for slot, snap in zip(occ, snaps):
                slot_cols[f"s{slot}"] = snap.cache_col
                slots_json[str(slot)] = _req_to_json(self.sm.slots[slot])
        saved_cols: Dict[str, Any] = {}
        queue_json: List[Dict[str, Any]] = []
        for req in self.scheduler.queue:
            queue_json.append(_req_to_json(req))
            if req.saved is not None:
                saved_cols[f"u{req.uid}"] = req.saved.cache_col
        state = {
            "key": self._key,
            "next_token": np.asarray(self.sm.next_token),
            "active": np.asarray(self.sm.active),
            "eos": np.asarray(self.sm.eos),
            "remaining": np.asarray(self.sm.remaining),
            "slot_cols": slot_cols,
            "saved_cols": saved_cols,
        }
        extra = {"engine": {
            "plan": plan_io.to_dict(self.plan.resolve()),
            "tick": self._tick,
            "uid_next": self._uid_next,
            "clock_now": clock_now,
            "slots": slots_json,
            "queue": queue_json,
            "finished": [_req_to_json(r) for r in self.finished],
            "stalled": sorted(self._stalled),
            "last_progress": [int(x) for x in self._last_progress],
            "util_history": list(self.util_history),
            "counters": {
                "total_tokens": self.total_tokens,
                "instant_admits": self.instant_admits,
                "shed": self.shed,
                "faults": {k: int(v) for k, v in self.fault_stats().items()},
            },
        }}
        manager.save(self._tick, state, extra=extra, blocking=blocking)
        return self._tick

    @classmethod
    def restore(cls, manager, params, *, model: Optional[LM] = None,
                sharder: Optional[Sharder] = None,
                step: Optional[int] = None,
                tracer: Optional[Tracer] = None) -> "ServingEngine":
        """Rebuild an engine from a :meth:`checkpoint` step (latest when
        ``step`` is None).  The restored engine's remaining schedule —
        tick stamps, outputs, uids minted for replayed submissions — is
        bit-identical to the uninterrupted engine's from the checkpoint
        tick, because every input to the deterministic loop (PRNG key,
        cache columns, mirrors, queue order, counters) is journaled."""
        if step is None:
            step = manager.latest_step()
            if step is None:
                raise FileNotFoundError(
                    f"no checkpoint steps under {manager.directory}")
        extra = manager.manifest(step).get("extra") or {}
        if "engine" not in extra:
            raise ValueError(
                f"checkpoint step {step} was not written by "
                f"ServingEngine.checkpoint(): no 'engine' extra")
        ex = extra["engine"]
        from repro.plan import io as plan_io

        plan = plan_io.from_dict(ex["plan"])
        eng = cls.from_plan(plan, params, model=model, sharder=sharder,
                            tracer=tracer)
        occ = sorted(int(k) for k in ex["slots"])
        saved_uids = [d["uid"] for d in ex["queue"]
                      if "saved_next_token" in d]
        template = {
            "key": eng._key,
            "next_token": np.asarray(eng.sm.next_token),
            "active": np.asarray(eng.sm.active),
            "eos": np.asarray(eng.sm.eos),
            "remaining": np.asarray(eng.sm.remaining),
            "slot_cols": {f"s{i}": gather_slots(eng.sm.cache, eng.sm.axes,
                                                [i]) for i in occ},
            "saved_cols": {f"u{u}": gather_slots(eng.sm.cache, eng.sm.axes,
                                                 [0]) for u in saved_uids},
        }
        st = manager.restore(template, step=step)
        # slot-resident requests first: the public restore path scatters
        # each journaled column back (covers dense and paged layouts)
        for i in occ:
            req = _req_from_json(ex["slots"][str(i)])
            snap = SlotSnapshot(st["slot_cols"][f"s{i}"],
                                int(st["next_token"][i]))
            eng.sm.restore(i, snap, req)
            eng._recovery[req.uid] = (snap, len(req.output))
        # then overwrite the mirrors wholesale: restore() above recomputed
        # remaining/active heuristically; the journaled arrays are exact
        # (stalled slots inactive, mid-flight remaining counts, ...)
        eng.sm.next_token[:] = st["next_token"]
        eng.sm.active[:] = st["active"]
        eng.sm.eos[:] = st["eos"]
        eng.sm.remaining[:] = st["remaining"]
        eng._key = jnp.asarray(st["key"])
        for d in ex["queue"]:
            nt = d.get("saved_next_token")
            req = _req_from_json(d)
            if nt is not None:
                req.saved = SlotSnapshot(st["saved_cols"][f"u{req.uid}"],
                                         int(nt))
            eng.scheduler.submit(req)
        for d in ex["finished"]:
            eng.finished.append(_req_from_json(d))
            eng._c_completed.inc()
        c = ex.get("counters", {})
        eng._c_total_tokens.inc(int(c.get("total_tokens", 0)))
        eng._c_instant_admits.inc(int(c.get("instant_admits", 0)))
        eng._c_shed.inc(int(c.get("shed", 0)))
        fc = c.get("faults", {})
        for ctr, key in ((eng._c_f_injected, "injected"),
                         (eng._c_f_quarantined, "quarantined"),
                         (eng._c_f_retries, "retries"),
                         (eng._c_f_shed, "shed"),
                         (eng._c_f_watchdog, "watchdog_evictions")):
            ctr.inc(int(fc.get(key, 0)))
        eng._tick = int(ex["tick"])
        eng._uid_next = int(ex["uid_next"])
        eng.util_history = list(ex.get("util_history", []))
        eng._stalled = set(int(s) for s in ex.get("stalled", []))
        eng._last_progress[:] = np.asarray(ex["last_progress"],
                                           dtype=np.int64)
        eng.restored_from = {"step": step, "clock_now": ex["clock_now"]}
        return eng

    # ------------------------------------------------------------- telemetry
    @property
    def ticks(self) -> int:
        return self._tick

    def align_clock(self, tick: int) -> None:
        """Advance the idle tick counter to a shared external clock
        (never rewinds).  Under a solo ``drive()`` the engine's tick
        domain may lag the clock while idle — harmless, since every stamp
        lives in the one engine's domain.  A disaggregated fleet exchanges
        stamps *across* engines (TTFT on the prefill replica, completion
        on the decode replica), so the router aligns every replica to the
        fleet clock before each round; see ``repro.serving.router``."""
        self._tick = max(self._tick, int(tick))

    def reset_telemetry(self) -> None:
        """Zero the counters/histories (e.g. after a jit warmup run, so
        wall-clock tick timings exclude compile).  The engine must be
        drained; queued or in-flight requests would get skewed stamps.

        ``metrics.reset()`` covers every registered counter — engine,
        scheduler, and slot-state alike — by construction, so a counter
        added anywhere in the stack can never leak warmup counts.  Two
        things deliberately survive: ``prefill_shapes`` mirrors the jit
        cache, which a telemetry reset does not clear (so the reported
        ``prefill_compiles`` stays truthful about programs built), and an
        attached tracer restarts empty at tick 0 (warmup events would
        otherwise overlap the measured run's restarted clock)."""
        if self.has_work():
            raise RuntimeError("reset_telemetry() on a busy engine")
        self.metrics.reset()
        self.finished = []
        self.util_history = []
        self._tick = 0
        if self.live is not None:
            self.live.reset()
        if self.tracer is not None:
            self.tracer.reset()

    def stats(self) -> Dict[str, float]:
        util = self.util_history
        out: Dict[str, float] = {
            "active": self.sm.n_active(),
            "queued": len(self.scheduler),
        }
        out.update(self.metrics.view({
            "completed": "engine.completed",
            "total_tokens": "engine.total_tokens",
        }))
        out["ticks"] = self._tick
        out["mean_util"] = sum(util) / len(util) if util else 0.0
        out.update(self.metrics.view({
            "instant_admits": "engine.instant_admits",
            "host_syncs": "engine.host_syncs",
            "decode_chunks": "engine.decode_chunks",
            "prefill_calls": "engine.prefill_calls",
        }))
        out["prefill_compiles"] = len(self.prefill_shapes)
        out.update(self.metrics.view({
            "preemptions": "engine.preemptions",
            "resumes": "engine.resumes",
            "evicted_tokens": "engine.evicted_tokens",
            "shed": "engine.shed",
        }))
        return out


# re-exported for back-compat: the policy registry lives in scheduler.py
__all__ = ["Request", "ServingEngine", "EngineKilled", "POLICIES",
           "MIN_BUCKET"]
