"""Slot-based continuous-batching serving engine.

The batched decode step (one jit-compiled program, fixed max_batch) runs
every tick over all occupied slots; requests join by prefilling into a free
slot and leave on EOS/length without disturbing the others — the standard
continuous-batching scheme (Orca/vLLM) on a fixed-slot KV cache.  Slot
insertion is a pytree scatter into the batch axis of the stacked cache.

This engine is the transformer-serving analogue of the paper's real-time
RNN serving scenario (batch-of-1 requests arriving asynchronously) and is
exercised end-to-end by examples/serve_lm.py and the integration tests.
"""

from __future__ import annotations

import dataclasses
import itertools
import logging
from collections import deque
from typing import Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.dist.sharding import Sharder
from repro.models.lm import LM
from repro.serving.sampler import SamplerConfig, sample

log = logging.getLogger("repro.serving")


@dataclasses.dataclass
class Request:
    uid: int
    prompt: List[int]
    max_new_tokens: int = 16
    eos_id: Optional[int] = None
    # filled by the engine
    output: List[int] = dataclasses.field(default_factory=list)
    done: bool = False


class ServingEngine:
    def __init__(self, model: LM, params, sharder: Sharder, *,
                 max_batch: int = 4, max_len: int = 128,
                 sampler: SamplerConfig = SamplerConfig(), seed: int = 0):
        self.model = model
        self.params = params
        self.sharder = sharder
        self.max_batch = max_batch
        self.max_len = max_len
        self.sampler = sampler
        self.cache = model.init_cache(max_batch, max_len)
        self.slots: List[Optional[Request]] = [None] * max_batch
        self.next_token = np.zeros((max_batch,), np.int32)
        self.queue: deque[Request] = deque()
        self.completed = 0        # requests finished since construction
        self.total_tokens = 0     # tokens generated (prefill + decode)
        self._tick = 0
        self._uid = itertools.count()
        self._key = jax.random.PRNGKey(seed)
        self._decode = jax.jit(
            lambda p, c, t: model.decode_step(p, c, t, sharder),
            donate_argnums=1)
        self._prefill = jax.jit(
            lambda p, b: model.prefill(p, b, sharder, max_len=max_len))

    # ------------------------------------------------------------------ API
    def submit(self, prompt: List[int], max_new_tokens: int = 16,
               eos_id: Optional[int] = None) -> Request:
        req = Request(next(self._uid), list(prompt), max_new_tokens, eos_id)
        self.queue.append(req)
        return req

    def run(self, max_ticks: int = 10_000) -> None:
        for _ in range(max_ticks):
            if not self.step():
                break

    # ----------------------------------------------------------------- ticks
    def step(self) -> bool:
        """One engine tick: admit pending requests, one batched decode.
        Returns False when idle."""
        self._admit()
        active = [i for i, r in enumerate(self.slots) if r is not None]
        if not active:
            return bool(self.queue)
        tokens = jnp.asarray(self.next_token)
        self.cache, logits = self._decode(self.params, self.cache, tokens)
        self._key, sub = jax.random.split(self._key)
        sampled = np.asarray(sample(logits, sub, self.sampler))
        lengths = np.asarray(self.cache["lengths"])
        for i in active:
            req = self.slots[i]
            tok = int(sampled[i])
            req.output.append(tok)
            self.total_tokens += 1
            self.next_token[i] = tok
            hit_eos = req.eos_id is not None and tok == req.eos_id
            full = lengths[i] >= self.max_len - 1
            if hit_eos or full or len(req.output) >= req.max_new_tokens:
                req.done = True
                self.completed += 1
                self.slots[i] = None
        self._tick += 1
        log.debug("tick %d: util=%.2f (%d/%d slots) queued=%d "
                  "completed=%d total_tokens=%d", self._tick,
                  len(active) / self.max_batch, len(active), self.max_batch,
                  len(self.queue), self.completed, self.total_tokens)
        return True

    # ------------------------------------------------------------- internals
    def _admit(self) -> None:
        for i in range(self.max_batch):
            if self.slots[i] is not None or not self.queue:
                continue
            req = self.queue.popleft()
            # keep at least one prompt token; decode stops at max_len anyway
            keep = max(1, self.max_len - req.max_new_tokens - 1)
            prompt = req.prompt[:keep]
            batch = {"tokens": jnp.asarray([prompt], jnp.int32)}
            if self.model.cfg.m_rope_sections:
                S = len(prompt)
                batch["positions"] = jnp.broadcast_to(
                    jnp.arange(S, dtype=jnp.int32), (1, 3, S))
            cache1, logits1 = self._prefill(self.params, batch)
            self._insert_slot(i, cache1)
            self._key, sub = jax.random.split(self._key)
            first = int(np.asarray(sample(logits1, sub, self.sampler))[0])
            req.output.append(first)
            self.total_tokens += 1
            self.next_token[i] = first
            self.slots[i] = req

    def _insert_slot(self, slot: int, cache1) -> None:
        """Scatter a batch-1 prefill cache into slot ``slot``."""
        def ins(big, small):
            return big.at[:, slot].set(small[:, 0].astype(big.dtype))
        self.cache["blocks"] = jax.tree.map(ins, self.cache["blocks"],
                                            cache1["blocks"])
        self.cache["lengths"] = self.cache["lengths"].at[slot].set(
            cache1["lengths"][0])

    # ------------------------------------------------------------- telemetry
    def stats(self) -> Dict[str, int]:
        return {
            "active": sum(r is not None for r in self.slots),
            "queued": len(self.queue),
            "completed": self.completed,
            "total_tokens": self.total_tokens,
        }
