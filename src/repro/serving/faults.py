"""Deterministic fault injection + the crash-restartable serving driver.

Serving at data-center scale means faults are routine, not exceptional:
cache state gets corrupted, readbacks get lost, prefill calls fail,
requests wedge, whole engines die.  This module makes every one of those
survivable — and, because every fault is *scheduled on the virtual
clock*, byte-reproducible: the same :class:`FaultPlan` against the same
seeded workload produces the same faults, the same recoveries, and the
same final schedule, so chaos runs diff like any other BENCH trajectory.

Three pieces:

* :class:`FaultSpec` / :class:`FaultPlan` — a JSON-round-trippable
  description of *which* faults fire *when* (mirroring
  :mod:`repro.plan.io`'s schema discipline): poison a slot's cache
  column (NaN or garbage scribble), drop a decode chunk's readback,
  fail a prefill call, stall a slot (the watchdog's trigger), or kill
  the engine at a chosen tick.
* :class:`FaultInjector` — the one-shot consumption ledger.  Each spec
  fires at the first host intervention at-or-after its tick and never
  again; the ledger survives engine restarts (the resilient driver
  re-attaches the *same* injector to the restored engine), so a kill
  fault cannot re-kill the engine it already killed.
* :func:`drive_resilient` — :func:`repro.serving.workload.drive` with a
  checkpoint cadence and a restart loop: it journals the engine through
  :class:`repro.checkpoint.CheckpointManager` every ``checkpoint_every``
  ticks, catches :class:`EngineKilled`, rebuilds the engine with
  :meth:`ServingEngine.restore`, rewinds the clock to the checkpoint,
  re-submits the arrivals the checkpoint had not seen, and keeps going.
  Because checkpoints capture the complete engine state between steps,
  the killed-and-restored run's schedule is bit-identical to an
  uninterrupted run — the crash costs wall time, never correctness
  (proven in ``tests/test_faults.py``).

The *recovery* half — the numeric guard that quarantines poisoned
slots, the bounded-retry/shed policy, the stuck-slot watchdog, and
``checkpoint()``/``restore()`` themselves — lives in
:class:`repro.serving.engine.ServingEngine`; this module only decides
when to hurt it.
"""

from __future__ import annotations

import dataclasses
import json
import math
import time
from typing import Dict, List, Mapping, Optional, Sequence, Tuple

from repro.serving.engine import EngineKilled, Request, ServingEngine
from repro.serving.workload import VirtualClock, WorkloadItem

FAULT_SCHEMA = "fault_plan/v1"

# every fault class the injector can schedule; the engine's recovery
# layer (engine._apply_due_faults and friends) must handle each one
FAULT_KINDS = (
    "poison_slot",     # scribble NaN/garbage into a slot's cache column
    "drop_readback",   # lose one decode chunk's device->host readback
    "fail_prefill",    # fail the next prefill call (requests retry)
    "stall_slot",      # wedge a slot: no progress until the watchdog fires
    "kill_engine",     # raise EngineKilled out of step() — crash-restart
)
POISON_MODES = ("nan", "garbage")


@dataclasses.dataclass(frozen=True)
class FaultSpec:
    """One scheduled fault.

    ``tick`` is the virtual-clock engine tick the fault becomes *due*; it
    fires at the first host intervention at or after that tick (slot
    faults wait, still one-shot, until the target can be hit: a poison
    or stall aimed at a free slot stays armed until any slot is
    occupied).  ``slot`` picks the victim for ``poison_slot`` /
    ``stall_slot`` — when that slot is free, the lowest occupied slot is
    hit instead, so the fault lands deterministically on real work.
    ``mode`` selects the poison pattern (``nan`` or ``garbage``: a
    seeded scribble of huge values and ±Inf — both trip the engine's
    finiteness guard; *finite* silent corruption is out of scope, the
    guard is a poison detector, not an ECC).  ``seed`` seeds the
    garbage pattern only."""

    kind: str
    tick: int
    slot: int = 0
    mode: str = "nan"
    seed: int = 0

    def validate(self) -> "FaultSpec":
        if self.kind not in FAULT_KINDS:
            raise ValueError(f"unknown fault kind {self.kind!r}; "
                             f"known: {FAULT_KINDS}")
        if self.tick < 0:
            raise ValueError(f"fault tick must be >= 0, got {self.tick}")
        if self.slot < 0:
            raise ValueError(f"fault slot must be >= 0, got {self.slot}")
        if self.mode not in POISON_MODES:
            raise ValueError(f"unknown poison mode {self.mode!r}; "
                             f"known: {POISON_MODES}")
        return self

    def to_json(self) -> Dict[str, object]:
        return {"kind": self.kind, "tick": int(self.tick),
                "slot": int(self.slot), "mode": self.mode,
                "seed": int(self.seed)}

    @staticmethod
    def from_json(d: Mapping[str, object]) -> "FaultSpec":
        known = {"kind", "tick", "slot", "mode", "seed"}
        unknown = set(d) - known
        if unknown:
            raise ValueError(f"unknown FaultSpec fields {sorted(unknown)}; "
                             f"known: {sorted(known)}")
        if "kind" not in d or "tick" not in d:
            raise ValueError(f"FaultSpec needs at least 'kind' and 'tick', "
                             f"got {sorted(d)}")
        return FaultSpec(kind=str(d["kind"]), tick=int(d["tick"]),
                         slot=int(d.get("slot", 0)),
                         mode=str(d.get("mode", "nan")),
                         seed=int(d.get("seed", 0))).validate()


@dataclasses.dataclass(frozen=True)
class FaultPlan:
    """A whole chaos scenario: the ordered fault schedule, JSON-round-
    trippable exactly like :class:`repro.plan.ServingPlan` (schema tag,
    ``from_dict(to_dict(p)) == p``), so a BENCH_chaos cell can embed the
    plan that produced it and any recorded storm can be replayed."""

    faults: Tuple[FaultSpec, ...] = ()

    def __post_init__(self):
        object.__setattr__(self, "faults", tuple(self.faults))

    def validate(self) -> "FaultPlan":
        for f in self.faults:
            f.validate()
        return self

    @property
    def kinds(self) -> Tuple[str, ...]:
        return tuple(sorted({f.kind for f in self.faults}))

    def needs_watchdog(self) -> bool:
        """Stall faults only recover when the engine's watchdog evicts
        the wedged slot — serving one without a watchdog would hang."""
        return any(f.kind == "stall_slot" for f in self.faults)

    def needs_checkpoints(self) -> bool:
        """Kill faults only recover through a checkpoint restore."""
        return any(f.kind == "kill_engine" for f in self.faults)

    def to_dict(self) -> Dict[str, object]:
        return {"schema": FAULT_SCHEMA,
                "faults": [f.to_json() for f in self.faults]}

    @staticmethod
    def from_dict(d: Mapping[str, object]) -> "FaultPlan":
        d = dict(d)
        schema = d.pop("schema", FAULT_SCHEMA)
        if schema != FAULT_SCHEMA:
            raise ValueError(f"unsupported fault-plan schema {schema!r}; "
                             f"this build reads {FAULT_SCHEMA!r}")
        unknown = set(d) - {"faults"}
        if unknown:
            raise ValueError(f"unknown FaultPlan fields {sorted(unknown)}")
        return FaultPlan(tuple(FaultSpec.from_json(f)
                               for f in d.get("faults", ()))).validate()

    def save(self, path: str) -> None:
        with open(path, "w") as f:
            json.dump(self.to_dict(), f, indent=1)
            f.write("\n")

    @staticmethod
    def load(path: str) -> "FaultPlan":
        with open(path) as f:
            return FaultPlan.from_dict(json.load(f))


class FaultInjector:
    """One-shot consumption ledger over a :class:`FaultPlan`.

    The engine polls :meth:`due` at each host intervention and calls
    :meth:`fire` for every spec it actually applied; a fired spec never
    fires again.  The ledger lives *outside* the engine on purpose:
    :func:`drive_resilient` re-attaches the same injector to a restored
    engine, so a consumed kill fault stays consumed across the restart
    it caused."""

    def __init__(self, plan: FaultPlan):
        self.plan = plan.validate()
        self._fired: set = set()
        self.log: List[Dict[str, object]] = []   # (spec, fired-at tick)

    def due(self, tick: int) -> List[Tuple[int, FaultSpec]]:
        """Unfired specs whose scheduled tick has arrived, with their
        plan indices (pass the index back to :meth:`fire`)."""
        return [(i, f) for i, f in enumerate(self.plan.faults)
                if i not in self._fired and f.tick <= tick]

    def fire(self, index: int, tick: int) -> None:
        if index in self._fired:
            raise ValueError(f"fault {index} already fired")
        self._fired.add(index)
        self.log.append({**self.plan.faults[index].to_json(),
                         "fired_at": int(tick)})

    def pending(self) -> int:
        return len(self.plan.faults) - len(self._fired)


@dataclasses.dataclass
class FaultReport:
    """What :func:`drive_resilient` hands back: the final per-uid request
    set (one entry per submitted uid — restored runs replace the dead
    engine's Request objects), restart/fault accounting, and the final
    engine for stats/metrics aggregation."""

    requests: List[Request]
    engine: ServingEngine
    n_restarts: int = 0
    restart_ticks_lost: int = 0   # sum of (kill tick - restore tick)
    fault_events: List[Dict[str, object]] = dataclasses.field(
        default_factory=list)

    @property
    def completed(self) -> List[Request]:
        return [r for r in self.requests if r.done]

    @property
    def shed_uids(self) -> List[int]:
        return [r.uid for r in self.requests if r.shed]

    def lost_uids(self) -> List[int]:
        """Requests that neither finished nor were accountably shed —
        the invariant the whole fault layer exists to keep empty."""
        return [r.uid for r in self.requests if not r.done and not r.shed]


def drive_resilient(engine: ServingEngine, items: Sequence[WorkloadItem],
                    clock: Optional[VirtualClock] = None, *,
                    injector: Optional[FaultInjector] = None,
                    manager=None, checkpoint_every: int = 8,
                    max_ticks: int = 1_000_000,
                    sync_every: Optional[int] = None,
                    on_tick=None) -> FaultReport:
    """Fault-aware workload replay: :func:`repro.serving.workload.drive`'s
    exact arrival-bounded loop, plus a checkpoint cadence and a
    kill-restart path.

    ``manager`` (a :class:`repro.checkpoint.CheckpointManager`) enables
    journaling: the engine state is checkpointed at tick 0 and then every
    ``checkpoint_every`` ticks, always *between* steps.  When a
    ``kill_engine`` fault raises :class:`EngineKilled`, the engine is
    rebuilt from the latest checkpoint, the clock rewinds to the
    checkpoint's instant, arrivals the checkpoint had not seen are
    re-submitted (same order, same uids — submission is deterministic),
    and the loop continues.  Requests are tracked per-uid, so the report
    always describes the *final* engine's view of every submitted uid.

    Restricted to :class:`VirtualClock` — faults are scheduled in ticks
    and the restart path rewinds time, neither of which a wall clock can
    honor."""
    if clock is None:
        clock = VirtualClock()
    if not isinstance(clock, VirtualClock):
        raise ValueError("drive_resilient requires a VirtualClock: faults "
                         "are tick-scheduled and restarts rewind the clock")
    if injector is not None:
        if injector.plan.needs_checkpoints() and manager is None:
            raise ValueError("the fault plan kills the engine but no "
                             "CheckpointManager was given: pass manager= "
                             "or the kill is unrecoverable")
        engine.attach_injector(injector)
    pending = sorted(items, key=lambda it: it.t)
    by_uid: Dict[int, Request] = {}
    i = 0
    busy = 0.0
    n_restarts = 0
    ticks_lost = 0
    next_ckpt = engine.ticks if manager is not None else None
    for _ in range(max_ticks):
        if i < len(pending) and not engine.has_work():
            clock.skip_to(pending[i].t)
        while i < len(pending) and pending[i].t <= clock.now:
            it = pending[i]
            req = engine.submit(list(it.prompt), it.max_new_tokens,
                                it.eos_id, deadline=it.deadline)
            by_uid[req.uid] = req
            i += 1
        # checkpoint AFTER the submission block: the journal then holds
        # every arrival with t <= clock_now, which is exactly what the
        # restart path's cursor rewind assumes
        if manager is not None and engine.ticks >= next_ckpt:
            engine.checkpoint(manager, clock_now=clock.now)
            next_ckpt = engine.ticks + max(1, int(checkpoint_every))
        if not engine.has_work() and i >= len(pending):
            if injector is not None and any(
                    k != "kill_engine" for _, s in injector.due(engine.ticks)
                    for k in [s.kind]):
                # drained with armed non-kill faults left: they can never
                # fire (nothing to hit) — record them as expired, loudly
                # in the log rather than silently vanishing
                for idx, spec in injector.due(engine.ticks):
                    if spec.kind != "kill_engine":
                        injector.fire(idx, engine.ticks)
                        injector.log[-1]["expired"] = True
            clock.busy_seconds = busy
            return FaultReport(
                requests=[by_uid[u] for u in sorted(by_uid)],
                engine=engine, n_restarts=n_restarts,
                restart_ticks_lost=ticks_lost,
                fault_events=list(engine.fault_events))
        budget = sync_every
        if i < len(pending):
            gap = pending[i].t - clock.now
            due = max(1, math.ceil(gap / clock.tick_cost)) if gap > 0 else 1
            budget = due if budget is None else min(budget, due)
        t0 = time.perf_counter()
        before = engine.ticks
        try:
            engine.step(max_ticks=budget)
        except EngineKilled as kill:
            busy += time.perf_counter() - t0
            n_restarts += 1
            dead = engine
            engine = ServingEngine.restore(
                manager, dead.params, model=dead.model,
                sharder=dead.sharder, tracer=dead.tracer)
            engine.fault_events.extend(dead.fault_events)
            # the kill fired after the last checkpoint, so the restored
            # counters do not include it — yet the restart it caused is
            # part of the surviving timeline (unlike other post-checkpoint
            # faults, which roll back and never re-fire)
            engine._c_f_injected.inc()
            if injector is not None:
                engine.attach_injector(injector)
            ticks_lost += max(0, kill.tick - engine.ticks)
            clock.now = float(engine.restored_from["clock_now"])
            # arrivals the checkpoint had already seen live inside the
            # restored engine; rewind the submission cursor to the rest.
            # Re-submission is deterministic (same order, same uid
            # counter state), so uids line up with the dead run's.
            i = 0
            while i < len(pending) and pending[i].t <= clock.now:
                i += 1
            for req in engine.all_requests():
                by_uid[req.uid] = req
            next_ckpt = engine.ticks + max(1, int(checkpoint_every))
            continue
        busy += time.perf_counter() - t0
        for _ in range(engine.ticks - before):
            clock.tick()
        if on_tick is not None and engine.ticks != before:
            on_tick(engine.ticks)
    raise RuntimeError(f"workload did not drain within {max_ticks} steps "
                       f"({i}/{len(pending)} submitted, "
                       f"{n_restarts} restarts)")


def make_storm(*, duration: int, seed: int = 0,
               kinds: Sequence[str] = FAULT_KINDS,
               n_faults: int = 4, max_batch: int = 4) -> FaultPlan:
    """A seeded fault storm: ``n_faults`` specs spread over ``duration``
    ticks, cycling through ``kinds`` (at most one ``kill_engine``, placed
    mid-run so there is state worth losing).  Pure function of the
    arguments — the chaos benchmark's cells are as replayable as the
    serving ones."""
    import numpy as np

    rng = np.random.default_rng(seed)
    kinds = tuple(kinds)
    for k in kinds:
        if k not in FAULT_KINDS:
            raise ValueError(f"unknown fault kind {k!r}; "
                             f"known: {FAULT_KINDS}")
    specs: List[FaultSpec] = []
    killed = False
    for j in range(n_faults):
        kind = kinds[j % len(kinds)]
        if kind == "kill_engine":
            if killed:
                kind = "poison_slot"
            killed = True
            tick = max(2, duration // 2)
        else:
            tick = int(rng.integers(1, max(2, duration)))
        specs.append(FaultSpec(
            kind=kind, tick=tick,
            slot=int(rng.integers(0, max_batch)),
            mode="garbage" if (kind == "poison_slot" and j % 2) else "nan",
            seed=seed + j))
    return FaultPlan(tuple(sorted(specs, key=lambda s: (s.tick, s.kind))))


__all__ = ["FAULT_KINDS", "FAULT_SCHEMA", "POISON_MODES", "FaultSpec",
           "FaultPlan", "FaultInjector", "FaultReport", "EngineKilled",
           "drive_resilient", "make_storm"]
