from repro.serving.engine import (  # noqa: F401
    EngineKilled,
    Request,
    ServingEngine,
)
from repro.serving.faults import (  # noqa: F401
    FAULT_KINDS,
    FaultInjector,
    FaultPlan,
    FaultReport,
    FaultSpec,
    drive_resilient,
    make_storm,
)
from repro.serving.metrics import (  # noqa: F401
    aggregate,
    aggregate_fleet,
    format_summary,
    scale_latencies,
)
from repro.serving.router import (  # noqa: F401
    ROUTER_POLICIES,
    ROUTING_POLICIES,
    Router,
    RoutingPolicy,
    TransitJob,
    drive_fleet,
    make_routing_policy,
)
from repro.serving.scheduler import (  # noqa: F401
    EDF,
    FCFS,
    POLICIES,
    SCHEDULERS,
    SPF,
    Scheduler,
    make_scheduler,
)
from repro.serving.paged import (  # noqa: F401
    PagedSlotManager,
    canonicalize_cache,
    paged_cache_bytes,
)
from repro.serving.slotstate import (  # noqa: F401
    SlotManager,
    SlotSnapshot,
    gather_slots,
    make_slot_manager,
    scatter_slots,
)
from repro.serving.workload import (  # noqa: F401
    VirtualClock,
    WallClock,
    WorkloadItem,
    drive,
    load_trace,
    make_workload,
    profile_items,
    save_trace,
)
from repro.plan.plan import (  # noqa: F401
    FleetPlan,
    ServingPlan,
    WorkloadProfile,
)
