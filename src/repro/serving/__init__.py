from repro.serving.engine import Request, ServingEngine  # noqa: F401
from repro.serving.metrics import aggregate, format_summary  # noqa: F401
from repro.serving.workload import (  # noqa: F401
    VirtualClock,
    WallClock,
    WorkloadItem,
    drive,
    load_trace,
    make_workload,
    save_trace,
)
