"""End-to-end serving driver (the paper's workload kind, on a transformer):
batched requests through the continuous-batching engine.

  PYTHONPATH=src python examples/serve_lm.py [--arch gemma3-12b]
      [--requests 12]
"""

import argparse
import time

import jax
import numpy as np

from repro.dist.sharding import Sharder
from repro.models.lm import build_model
from repro.serving import ServingEngine
from repro.serving.sampler import SamplerConfig
from repro.testing import reduced_config


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="gemma3-12b")
    ap.add_argument("--requests", type=int, default=12)
    ap.add_argument("--max-new", type=int, default=8)
    args = ap.parse_args()

    cfg = reduced_config(args.arch)
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    engine = ServingEngine(model, params, Sharder(None, {}),
                           max_batch=4, max_len=48,
                           sampler=SamplerConfig(temperature=0.8, top_k=20))
    rng = np.random.default_rng(0)
    reqs = [engine.submit(rng.integers(0, cfg.vocab_size,
                                       rng.integers(4, 16)).tolist(),
                          max_new_tokens=args.max_new)
            for _ in range(args.requests)]
    t0 = time.time()
    engine.run()
    dt = time.time() - t0
    done = sum(r.done for r in reqs)
    toks = sum(len(r.output) for r in reqs)
    print(f"{cfg.name}: {done}/{len(reqs)} requests, {toks} tokens, "
          f"{toks/dt:.1f} tok/s (CPU, reduced config)")


if __name__ == "__main__":
    main()
