"""End-to-end driver for the paper's own scenario: real-time RNN serving
over the DeepBench task list (batch-of-1 requests, strict latency).

  PYTHONPATH=src python examples/serve_rnn_deepbench.py [--tasks N] [--t N]

For each task: run the request through all three execution models, check
they agree, and report measured CPU step latency plus the modeled TPU-v5e
latency / effective TFLOPS next to the paper's reported numbers.
"""

import argparse
import time

import jax
import jax.numpy as jnp

from repro.configs import DEEPBENCH_TASKS
from repro.core.cells import RNNCellConfig, init_weights, quantize_weights, serve
from repro.core.dse import best_plan


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--tasks", type=int, default=4)
    ap.add_argument("--t", type=int, default=8, help="timesteps to run")
    args = ap.parse_args()

    print(f"{'task':20s} {'agree':>7s} {'cpu_us/step':>12s} "
          f"{'tpu_model_ms':>13s} {'eff_TFLOPS':>11s} {'paper_ms':>9s}")
    for task in DEEPBENCH_TASKS[:args.tasks]:
        cfg = RNNCellConfig(task.cell, task.hidden, timesteps=task.timesteps,
                            batch=1, precision="int8")
        w = quantize_weights(cfg, init_weights(cfg, jax.random.PRNGKey(0)))
        T = min(args.t, task.timesteps)
        x = jax.random.normal(jax.random.PRNGKey(1), (T, 1, cfg.d),
                              jnp.bfloat16)
        y_fused = serve(cfg, w, x, impl="kernel")
        y_blas = serve(cfg, w, x, impl="blas")
        agree = float(jnp.max(jnp.abs(
            y_fused.astype(jnp.float32) - y_blas))) < 5e-2

        fn = jax.jit(lambda xx: serve(cfg, w, xx, impl="fused"))
        jax.block_until_ready(fn(x))
        t0 = time.perf_counter()
        jax.block_until_ready(fn(x))
        cpu_us = (time.perf_counter() - t0) / T * 1e6

        plan = best_plan(cfg)
        tpu_ms = plan.step_latency_s * task.timesteps * 1e3
        eff = cfg.flops_per_step() * task.timesteps / (tpu_ms * 1e-3) / 1e12
        print(f"{task.name:20s} {str(agree):>7s} {cpu_us:12.1f} "
              f"{tpu_ms:13.4f} {eff:11.1f} {task.ms_plasticine:9.4f}")


if __name__ == "__main__":
    main()
