"""End-to-end training driver: data pipeline -> microbatched train step ->
checkpointing -> restart, on a reduced assigned architecture.

  PYTHONPATH=src python examples/train_lm.py [--arch rwkv6-1.6b]
      [--steps 40] [--big]

``--big`` switches to a ~100M-parameter configuration (slower on CPU; the
same code path the full configs lower on the production mesh).
"""

import argparse
import dataclasses
import logging
import tempfile

from repro.configs.base import ShapeSpec
from repro.dist.sharding import Sharder
from repro.models.lm import build_model
from repro.testing import reduced_config
from repro.train.loop import TrainLoopConfig, train


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="rwkv6-1.6b")
    ap.add_argument("--steps", type=int, default=40)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--seq", type=int, default=64)
    ap.add_argument("--big", action="store_true",
                    help="~100M params instead of the tiny smoke config")
    args = ap.parse_args()
    logging.basicConfig(level=logging.INFO, format="%(message)s")

    cfg = reduced_config(args.arch)
    if args.big:
        cfg = dataclasses.replace(
            cfg, d_model=512, d_ff=2048, n_heads=8, n_kv_heads=4,
            head_dim=64, vocab_size=32_000, n_layers=2 * cfg.period)
    model = build_model(cfg)
    print(f"{cfg.name}: {model.n_params()/1e6:.1f}M params")

    shape = ShapeSpec("example", args.seq, args.batch, "train")
    with tempfile.TemporaryDirectory() as d:
        loop = TrainLoopConfig(total_steps=args.steps,
                               checkpoint_every=max(10, args.steps // 2),
                               checkpoint_dir=d, log_every=5)
        state, history = train(model, shape, Sharder(None, {}), loop)
    print(f"loss: {history[0]['loss']:.4f} -> {history[-1]['loss']:.4f} "
          f"over {len(history)} steps")


if __name__ == "__main__":
    main()
