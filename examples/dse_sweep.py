"""Design-space exploration sweep (the paper's Table 7 workflow).

  PYTHONPATH=src python examples/dse_sweep.py [--cell lstm] [--hidden 1024]

Prints every candidate plan for one problem size, then the chosen plan for
each DeepBench task.
"""

import argparse

from repro import hw
from repro.configs import DEEPBENCH_TASKS
from repro.core import dse
from repro.core.cells import RNNCellConfig


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--cell", default="lstm")
    ap.add_argument("--hidden", type=int, default=1024)
    args = ap.parse_args()

    cfg = RNNCellConfig(args.cell, args.hidden, precision="int8")
    print(f"candidates for {args.cell} H={args.hidden} "
          f"(VMEM budget {hw.vmem_budget()//2**20} MiB):")
    for p in dse.search(cfg):
        mark = " <== best" if p == dse.best_plan(cfg) else ""
        print(f"  bh={p.bh:5d} tiles={p.n_tiles:3d} "
              f"vmem={p.vmem_bytes/2**20:7.2f}MiB resident={p.resident!s:5s} "
              f"lat={p.step_latency_s*1e6:8.3f}us bound={p.bound}{mark}")

    print("\nchosen plans per DeepBench task:")
    for t in DEEPBENCH_TASKS:
        c = RNNCellConfig(t.cell, t.hidden, timesteps=t.timesteps,
                          precision="int8")
        p = dse.best_plan(c)
        print(f"  {t.name:20s} bh={p.bh:5d} tiles={p.n_tiles:3d} "
              f"util={p.util:.3f} bound={p.bound:8s} "
              f"seq_latency={p.step_latency_s*t.timesteps*1e3:9.4f}ms")


if __name__ == "__main__":
    main()
