"""Quickstart: the three layers of the framework in ~60 lines.

  PYTHONPATH=src python examples/quickstart.py

1. The paper's core: serve a DeepBench-style LSTM through the fused Pallas
   kernel (interpret mode on CPU) and compare against the BLAS baseline.
2. The framework: one training step of an assigned architecture (reduced).
3. Serving: prefill + a few decode steps with the KV cache.
"""

import jax
import jax.numpy as jnp

from repro.core.cells import RNNCellConfig, init_weights, quantize_weights, serve
from repro.core.dse import best_plan
from repro.dist.sharding import Sharder
from repro.models.inputs import make_batch
from repro.models.lm import build_model
from repro.optim import AdamW, cosine_schedule
from repro.optim.adamw import init_state
from repro.testing import reduced_config, smoke_shape
from repro.train.step import make_train_step

# --- 1. the paper: fused RNN serving --------------------------------------
cfg = RNNCellConfig("lstm", hidden=256, timesteps=8, batch=1,
                    precision="int8")
weights = quantize_weights(cfg, init_weights(cfg, jax.random.PRNGKey(0)))
x_seq = jax.random.normal(jax.random.PRNGKey(1), (8, 1, 256), jnp.bfloat16)
y_kernel = serve(cfg, weights, x_seq, impl="kernel")   # Pallas (interpret)
y_blas = serve(cfg, weights, x_seq, impl="blas")       # paper's baseline
plan = best_plan(cfg)
print(f"[paper] fused-vs-blas max diff: "
      f"{float(jnp.max(jnp.abs(y_kernel.astype(jnp.float32) - y_blas))):.4f}")
print(f"[paper] DSE plan: bh={plan.bh}, resident={plan.resident}, "
      f"bound={plan.bound}, modeled step latency "
      f"{plan.step_latency_s*1e6:.2f}us")

# --- 2. one training step of an assigned architecture ---------------------
arch = reduced_config("gemma3-12b")
model = build_model(arch)
sharder = Sharder(None, {})
state = init_state(model.param_specs(), jax.random.PRNGKey(0))
opt = AdamW(lr=cosine_schedule(1e-3, 10, 100))
step = jax.jit(make_train_step(model, opt, sharder))
batch = {k: jnp.asarray(v) for k, v in
         make_batch(arch, smoke_shape("train", seq=16, batch=2)).items()}
state, metrics = step(state, batch)
print(f"[train] {arch.name}: loss={float(metrics['loss']):.3f} "
      f"grad_norm={float(metrics['grad_norm']):.3f}")

# --- 3. prefill + decode with the KV cache ---------------------------------
params = state["params"]
prompt = {"tokens": batch["tokens"][:, :8]}
cache, logits = model.prefill(params, prompt, sharder, max_len=16)
for _ in range(4):
    tok = jnp.argmax(logits, -1).astype(jnp.int32)
    cache, logits = model.decode_step(params, cache, tok, sharder)
print(f"[serve] decoded 4 tokens, cache length = "
      f"{int(cache['lengths'][0])}, logits finite = "
      f"{bool(jnp.all(jnp.isfinite(logits)))}")
