"""Paper Table 7: chosen loop tiling / vectorization parameters per task.

The DSE's selected plan per DeepBench size: bh (the hv*hu analogue),
tile count, VMEM residency, utilization, and the binding resource —
demonstrating the paper's point that per-size tuning keeps utilization
consistent where a fixed-geometry engine fragments.
"""

from __future__ import annotations

from typing import List

from benchmarks.common import Row
from repro.configs import DEEPBENCH_TASKS
from repro.core import dse
from repro.core.cells import RNNCellConfig


def run(fast: bool = True) -> List[Row]:
    rows: List[Row] = []
    for task in DEEPBENCH_TASKS:
        cfg = RNNCellConfig(task.cell, task.hidden, timesteps=task.timesteps,
                            precision="int8")
        plan = dse.best_plan(cfg)
        rows.append(Row(
            name=f"dse/{task.name}",
            us_per_call=plan.step_latency_s * 1e6,
            derived=(f"bh={plan.bh};tiles={plan.n_tiles};"
                     f"resident={plan.resident};util={plan.util:.3f};"
                     f"bound={plan.bound};"
                     f"vmem_kb={plan.vmem_bytes//1024}"),
        ))
    return rows
