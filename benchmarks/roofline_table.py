"""The assignment's §Roofline table: aggregates results/dryrun/*.json.

One row per (arch x shape) single-pod cell: the three terms, the dominant
bottleneck, MODEL_FLOPS/HLO_FLOPs, and the roofline fraction.  Also emits
the markdown table EXPERIMENTS.md embeds (via --write-md in
repro.launch.report).
"""

from __future__ import annotations

import glob
import json
import os
from typing import List

from benchmarks.common import Row

RESULTS = os.environ.get("REPRO_DRYRUN_DIR", "results/dryrun")


def load_cells(pattern: str = "*_single.json") -> List[dict]:
    cells = []
    for path in sorted(glob.glob(os.path.join(RESULTS, pattern))):
        with open(path) as f:
            cells.append(json.load(f))
    return cells


def run(fast: bool = True) -> List[Row]:
    rows: List[Row] = []
    for cell in load_cells():
        name = f"roofline/{cell['arch']}/{cell['shape']}"
        if cell.get("skip"):
            rows.append(Row(name, 0.0, "skip=" + cell["skip"][:60]))
            continue
        if not cell.get("ok"):
            rows.append(Row(name, 0.0, "ERROR=" +
                            str(cell.get("error", ""))[:80]))
            continue
        r = cell.get("roofline")
        if not r:
            rows.append(Row(name, 0.0, "no-pieces"))
            continue
        rows.append(Row(
            name=name,
            us_per_call=r["step_s"] * 1e6,
            derived=(f"compute_ms={r['compute_s']*1e3:.3f};"
                     f"memory_ms={r['memory_s']*1e3:.3f};"
                     f"collective_ms={r['collective_s']*1e3:.3f};"
                     f"dominant={r['dominant']};"
                     f"useful={r['useful_ratio']:.3f};"
                     f"roofline_frac={r['roofline_frac']:.4f}"),
        ))
    if not rows:
        rows.append(Row("roofline/none", 0.0,
                        f"no dry-run results under {RESULTS}"))
    return rows
