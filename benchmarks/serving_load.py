"""Serving-load benchmark: continuous batching under Poisson arrivals.

The paper's real-time scenario — asynchronous batch-of-1 arrivals — turned
into a regression-trackable benchmark: for every cell of
``repro.configs.SERVING_LOAD_SWEEP`` it replays a seeded Poisson workload
through the continuous-batching engine on a virtual clock and aggregates
per-request latency percentiles (queue-wait, TTFT, TPOT) plus tokens/sec
and mean slot utilization.  The grid has three sections:

* the base grid — dense / MoE / RWKV architecture x ``max_batch`` x
  arrival rate, unchanged since the harness landed (its cell names and
  ``metrics`` blocks are the stable perf-trajectory history);
* a prompt-length-distribution sweep (fixed / lognormal / bimodal) over
  the saturating RWKV cell;
* the *overload scenario*: the same seeded over-capacity workload with a
  3% heavy-decode tail and per-request deadlines, served under FCFS, EDF,
  and preemptive EDF — new cells whose ``slo`` / ``sched`` blocks track
  what scheduling policy buys (see repro.serving.scheduler).

  PYTHONPATH=src python -m benchmarks.serving_load [--full] [--seed N] \\
      [--out BENCH_serving.json]

The ``metrics`` block of every cell is computed on the virtual clock, so
it is a *pure function of (sweep, seed)*: two runs with the same seed are
byte-identical, which is what makes ``BENCH_serving.json`` diffable as the
repo's perf trajectory (see benchmarks/README.md).  Wall-clock numbers
(host-dependent, noisy) are reported separately under ``wall`` and are
excluded from the determinism contract.
"""

from __future__ import annotations

import argparse
import json
import os
import time
from typing import Dict, Iterator, List, Optional, Sequence

import jax
import numpy as np

from benchmarks.common import Row
from repro.configs import (
    FLEET_SERVING_SWEEP,
    FleetLoadCell,
    SERVING_LOAD_SWEEP,
    ServingLoadCell,
    get_config,
)
from repro.dist.sharding import make_sharder
from repro.models.lm import build_model
from repro.plan import WorkloadProfile, io as plan_io
from repro.serving import ServingEngine, drive, profile_items
from repro.serving import metrics as smetrics
from repro.testing import reduced_config

SCHEMA = "serving_load/v1"
DEFAULT_OUT = "BENCH_serving.json"


def _build(arch: str, reduced: bool):
    cfg = reduced_config(arch) if reduced else get_config(arch)
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    return cfg, model, params


def _calibrate_tick_seconds(engine: ServingEngine, vocab_size: int,
                            seed: int, n_requests: int = 6) -> float:
    """Measured wall cost of one engine tick, on an engine that is already
    warm (its decode chunk and prefill buckets compiled during the virtual
    run): a short closed-loop rerun, wall seconds / ticks.  Host-noisy —
    lives in the ``wall`` block, never in ``metrics``."""
    rng = np.random.default_rng(seed + 0x5EED)
    ticks_before = engine.ticks
    for _ in range(n_requests):
        n = int(rng.integers(4, 13))
        engine.submit([int(x) for x in rng.integers(0, vocab_size, n)],
                      max_new_tokens=8)
    t0 = time.perf_counter()
    engine.run()
    dt = time.perf_counter() - t0
    return dt / max(1, engine.ticks - ticks_before)


def run_cell(cell: ServingLoadCell, *, duration: float = 32.0, seed: int = 0,
             reduced: bool = True, trace_dir: Optional[str] = None,
             _built=None) -> Dict[str, object]:
    """One sweep cell: build (or reuse) the model, serve the cell's
    workload profile under the cell's *plan* on a virtual clock, return
    {identity, plan, metrics, wall}.

    Every cell embeds its resolved plan dict, so the committed trajectory
    records exactly which design point produced each number (and any cell
    can be re-served from its recorded plan alone — see
    benchmarks/README.md).  Cells with non-default scheduling dimensions
    additionally report a deterministic ``sched`` block; base-grid cells
    emit the historical document shape plus the ``plan`` key.

    ``trace_dir`` archives a per-cell structured event trace
    (``repro.obs.Tracer``, Chrome trace_event JSON, Perfetto-viewable)
    under ``<trace_dir>/<cell name with / -> _>.trace.json`` — the
    virtual clock makes the files byte-stable per seed, so they can be
    diffed like the ``metrics`` blocks."""
    import dataclasses

    cfg, model, params = _built or _build(cell.arch, reduced)
    # the embedded plan must record the model actually measured: a
    # full-size run flips the plan's `reduced` identity bit too
    plan = cell.plan if cell.plan.reduced == reduced else \
        dataclasses.replace(cell.plan, reduced=reduced)
    sharder = make_sharder(cfg, None, plan.shard_mode)
    tracer = None
    if trace_dir is not None:
        from repro.obs import Tracer

        tracer = Tracer()
    engine = ServingEngine.from_plan(plan, params, model=model,
                                     sharder=sharder, seed=seed,
                                     tracer=tracer)
    duration = cell.duration if cell.duration is not None else duration
    items = profile_items(cell.workload, vocab_size=cfg.vocab_size,
                          seed=seed, duration=duration)
    t0 = time.perf_counter()
    reqs = drive(engine, items)
    wall_s = time.perf_counter() - t0
    agg = smetrics.aggregate(reqs, ticks=engine.ticks,
                             util_history=engine.util_history)
    if tracer is not None:
        # archive before tick calibration, which replays extra requests
        # that are no part of the cell's workload
        os.makedirs(trace_dir, exist_ok=True)
        tracer.save(os.path.join(
            trace_dir, cell.name.replace("/", "_") + ".trace.json"))
    # wall-calibrated tick cost (engine is warm after the drive), mapping
    # the deterministic tick-domain latencies above to milliseconds
    tick_s = _calibrate_tick_seconds(engine, cfg.vocab_size, seed)
    out = {
        "name": cell.name,
        "arch": cell.arch,
        "family": cell.family,
        "max_batch": cell.max_batch,
        "rate": cell.rate,
        "duration": duration,
        "plan": plan_io.to_dict(plan.resolve()),  # the design point
        "metrics": agg,  # virtual-clock: deterministic for a fixed seed
        "wall": {  # host-dependent; excluded from the determinism contract
            "seconds": wall_s,
            "tokens_per_sec_wall": agg["tokens"] / wall_s if wall_s else 0.0,
            "calibrated": smetrics.scale_latencies(agg, tick_s),
        },
    }
    default_sched = (cell.policy == "fcfs" and not cell.preempt
                     and cell.prompt_dist == "uniform"
                     and cell.heavy_decode is None
                     and cell.deadline_slack is None)
    if not default_sched:
        s = engine.stats()
        out["sched"] = {  # deterministic, like metrics
            "policy": cell.policy,
            "preempt": cell.preempt,
            "prompt_dist": cell.prompt_dist,
            "heavy_decode": list(cell.heavy_decode)
            if cell.heavy_decode else None,
            "deadline_slack": cell.deadline_slack,
            "preemptions": int(s["preemptions"]),
            "resumes": int(s["resumes"]),
            "evicted_tokens": int(s["evicted_tokens"]),
            "shed": int(s["shed"]),
        }
    return out


def _slo_met_tokens(reqs) -> int:
    """Served tokens that landed inside their deadline (virtual clock, so
    tick_seconds == 1; same completion rule as the metrics ``slo`` block).
    The capacity-scaling acceptance metric: adding replicas must grow
    *useful* throughput, not just tokens."""
    return sum(len(r.output) for r in reqs
               if r.deadline is not None and r.t_done is not None
               and (r.t_done + 1) <= r.deadline)


def run_fleet_cell(cell: FleetLoadCell, *, duration: float = 32.0,
                   seed: int = 0, reduced: bool = True,
                   _built=None) -> Dict[str, object]:
    """One fleet cell: build the router fleet from the cell's FleetPlan,
    serve the cell's workload on one shared virtual clock, return
    {identity, fleet plan, pooled metrics, transit, wall}.

    The ``metrics`` block pools per-request samples across replicas
    (``metrics.aggregate_fleet``) and — like every single-replica cell —
    is a pure function of (cell, seed).  ``slo_met_tokens`` is the
    capacity-scaling acceptance metric; ``transit`` records the
    disaggregation hand-off economics (bytes, modeled DCN ticks)."""
    import dataclasses

    from repro.plan import io as fleet_io
    from repro.serving.router import Router, drive_fleet

    fleet = cell.fleet
    if fleet.replicas[0].reduced != reduced:
        fleet = dataclasses.replace(fleet, replicas=tuple(
            dataclasses.replace(p, reduced=reduced)
            for p in fleet.replicas))
    fleet.validate()
    cfg = _built[0] if _built else (
        reduced_config(fleet.replicas[0].arch) if reduced
        else get_config(fleet.replicas[0].arch))
    built = {(p.arch, p.reduced): _built[1:] for p in fleet.replicas} \
        if _built else None
    router = Router.from_plan(fleet, seed=seed, _built=built)
    duration = (cell.workload.duration
                if cell.workload.duration is not None else duration)
    items = profile_items(cell.workload, vocab_size=cfg.vocab_size,
                          seed=seed, duration=duration)
    t0 = time.perf_counter()
    reqs = drive_fleet(router, items)
    wall_s = time.perf_counter() - t0
    agg = router.fleet_aggregate()
    census = router.conservation_census()
    if census["total"] != len(reqs):   # keep the BENCH writer honest
        raise RuntimeError(f"fleet cell {cell.name}: request conservation "
                           f"violated: {census} vs {len(reqs)} submitted")
    return {
        "name": cell.name,
        "family": cell.family,
        "n_replicas": fleet.n_replicas,
        "n_prefill": fleet.n_prefill,
        "routing": fleet.routing,
        "rate": cell.workload.rate,
        "duration": duration,
        "fleet": fleet_io.fleet_to_dict(fleet.resolve()),
        "metrics": agg,  # pooled across replicas; deterministic per seed
        "slo_met_tokens": _slo_met_tokens(reqs),
        "transit": router.transit_stats(),
        "wall": {  # host-dependent; excluded from the determinism contract
            "seconds": wall_s,
            "tokens_per_sec_wall": agg["tokens"] / wall_s if wall_s else 0.0,
        },
    }


def autotuned_overload_cell(seed: int = 0) -> ServingLoadCell:
    """The planner's acceptance cell: autotune the committed overload /
    heavy-decode workload (the FCFS cell's profile) and serve it under
    the winning plan, tagged ``auto`` — the serving-level analogue of the
    paper's per-problem-size search, recorded in the trajectory next to
    the hand-picked design points it competes with."""
    from repro.plan import planner

    base = next(c for c in SERVING_LOAD_SWEEP
                if c.deadline_slack is not None and c.policy == "fcfs")
    plan = planner.autotune(base.arch, base.workload, seed=seed,
                            max_len=base.plan.max_len)
    return ServingLoadCell(family=base.family, plan=plan,
                           workload=base.workload, tag="auto")


# The drifting-workload scenario (observed-traffic re-autotune): a plan
# tuned on calm, *deadline-free* traffic keeps serving after the traffic
# drifts to a heavier, deadline-carrying, heavy-tailed mix.  Calm
# traffic is sparse enough (~1 request per 33 ticks, mean decode ~8
# ticks) that requests almost never overlap, so every batch size probes
# identically and the autotuner keeps the cheapest feasible design
# point: 2 slots.  The drifted mix offers ~8.3 slot-ticks/tick — 4x the
# stale capacity — so the stale plan queues unboundedly and misses most
# deadlines, while the replan sees the real rate, the heavy decode
# tail, and the deadlines in the trace, and re-provisions (8 slots,
# deadline-aware policy probed).
_DRIFT_ARCH = "rwkv6-1.6b"
_DRIFT_CALM = WorkloadProfile(
    kind="poisson", rate=0.03, duration=96.0,
    prompt_len=ServingLoadCell.PROMPT_LEN,
    max_new_tokens=ServingLoadCell.MAX_NEW,
    prompt_len_long=ServingLoadCell.MAX_LEN - 1)
_DRIFT_WORKLOAD = WorkloadProfile(
    kind="poisson", rate=0.9, duration=96.0,
    prompt_len=ServingLoadCell.PROMPT_LEN,
    max_new_tokens=ServingLoadCell.MAX_NEW,
    prompt_len_long=ServingLoadCell.MAX_LEN - 1,
    heavy_decode=(0.05, 24, 40), deadline_slack=3.0)


def drifting_workload_cells(seed: int = 0) -> List[ServingLoadCell]:
    """The observability acceptance scenario: two cells serving the same
    drifted workload, under (a) the *stale* plan — autotuned for the calm
    pre-drift profile — and (b) the *replanned* design point, autotuned
    from a structured trace recorded while the stale plan served the
    drifted traffic (``planner.autotune_from_trace``).  The replan sees
    the real arrival rate, the heavy decode tail, and the deadlines the
    stale declaration never mentioned, so it beats the stale plan on SLO
    attainment (asserted in tests/test_serving_load.py).  Deterministic
    for a fixed seed, like every other cell."""
    from repro.obs import Tracer
    from repro.plan import planner

    stale = planner.autotune(_DRIFT_ARCH, _DRIFT_CALM, seed=seed,
                             max_len=ServingLoadCell.MAX_LEN)
    # record the drifted traffic under the stale plan (the "production"
    # run an operator would have a trace of)
    cfg, model, params = _build(_DRIFT_ARCH, reduced=True)
    sharder = make_sharder(cfg, None, stale.shard_mode)
    tracer = Tracer()
    engine = ServingEngine.from_plan(stale, params, model=model,
                                     sharder=sharder, seed=seed,
                                     tracer=tracer)
    items = profile_items(_DRIFT_WORKLOAD, vocab_size=cfg.vocab_size,
                          seed=seed)
    drive(engine, items)
    replan = planner.autotune_from_trace(
        _DRIFT_ARCH, tracer, seed=seed, max_len=ServingLoadCell.MAX_LEN,
        duration=_DRIFT_WORKLOAD.duration)   # the known recording window
    return [
        ServingLoadCell(family="rwkv", plan=stale,
                        workload=_DRIFT_WORKLOAD, tag="drift-stale"),
        ServingLoadCell(family="rwkv", plan=replan,
                        workload=_DRIFT_WORKLOAD, tag="drift-replan"),
    ]


def sweep(fast: bool = True, *, seed: int = 0, reduced: bool = True,
          cells: Optional[Sequence[ServingLoadCell]] = None,
          duration: Optional[float] = None,
          autotune: bool = False,
          trace_dir: Optional[str] = None) -> Dict[str, object]:
    """The full sweep -> the BENCH_serving.json document.  With
    ``autotune=True`` (the real, BENCH-writing runs) the overload
    scenario additionally gets its autotuned cell appended, plus the
    drifting-workload pair (stale plan vs replan-from-observed-trace).
    ``trace_dir`` archives one trace file per cell."""
    cells = list(cells if cells is not None else SERVING_LOAD_SWEEP)
    fleet_cells: List[FleetLoadCell] = []
    if autotune:
        cells.append(autotuned_overload_cell(seed))
        cells.extend(drifting_workload_cells(seed))
        # the fleet grid rides the BENCH-writing runs only, under its own
        # document key: the single-replica `cells` history never reshapes
        fleet_cells = list(FLEET_SERVING_SWEEP)
    duration = duration if duration is not None else (32.0 if fast else 256.0)
    built: Dict[str, tuple] = {}  # one model build per arch, many cells
    out_cells: List[Dict[str, object]] = []
    for cell in cells:
        if cell.arch not in built:
            built[cell.arch] = _build(cell.arch, reduced)
        out_cells.append(run_cell(cell, duration=duration, seed=seed,
                                  reduced=reduced, trace_dir=trace_dir,
                                  _built=built[cell.arch]))
    out_fleet: List[Dict[str, object]] = []
    for fcell in fleet_cells:
        arch = fcell.fleet.replicas[0].arch
        if arch not in built:
            built[arch] = _build(arch, reduced)
        out_fleet.append(run_fleet_cell(fcell, duration=duration, seed=seed,
                                        reduced=reduced, _built=built[arch]))
    doc = {
        "schema": SCHEMA,
        "seed": seed,
        "mode": "fast" if fast else "full",
        "reduced": reduced,
        "duration": duration,
        "families": sorted({c.family for c in cells}),
        "cells": out_cells,
    }
    if out_fleet:
        doc["fleet"] = out_fleet
    return doc


def deterministic_view(doc: Dict[str, object]) -> Dict[str, object]:
    """The seed-determined subset of a sweep document (drops wall timings);
    two same-seed runs must agree on this exactly."""
    out = {
        **{k: v for k, v in doc.items() if k not in ("cells", "fleet")},
        "cells": [{k: v for k, v in c.items() if k != "wall"}
                  for c in doc["cells"]],
    }
    if "fleet" in doc:
        out["fleet"] = [{k: v for k, v in c.items() if k != "wall"}
                        for c in doc["fleet"]]
    return out


def write(doc: Dict[str, object], path: str = DEFAULT_OUT) -> None:
    with open(path, "w") as f:
        json.dump(doc, f, indent=1)
        f.write("\n")


def _check_policy_registry() -> None:
    """Fail loudly if the scheduler registry and the serve CLI's --policy
    choices drift apart (the smoke runs in tier-1 CI, so a policy added to
    one surface but not the other breaks the build, not production)."""
    from repro.launch.serve import build_parser
    from repro.serving.scheduler import SCHEDULERS

    choices = None
    for action in build_parser()._actions:
        if "--policy" in action.option_strings:
            choices = set(action.choices or ())
    if choices is None:
        raise RuntimeError("launch/serve.py no longer exposes --policy")
    if choices != set(SCHEDULERS):
        raise RuntimeError(
            f"--policy CLI choices {sorted(choices)} drifted from the "
            f"scheduler registry {sorted(SCHEDULERS)}; update "
            f"launch/serve.py or repro/serving/scheduler.py")
    swept = {(c.policy, c.preempt) for c in SERVING_LOAD_SWEEP}
    missing = set(SCHEDULERS) - {p for p, _ in swept}
    if missing - {"spf"}:   # spf is covered by decode_hotpath's tests
        raise RuntimeError(f"policies {sorted(missing)} are registered but "
                           f"never exercised by SERVING_LOAD_SWEEP")


def _check_plan_surface() -> None:
    """CI guard for the plan subsystem: the plan JSON schema must match
    the dataclass fields, and a tiny autotune run must return a plan that
    passes ``ServingPlan.validate()`` and round-trips through JSON —
    loudly, in tier-1, so the trajectory files can never embed a plan the
    code cannot read back."""
    from repro.plan import ServingPlan, WorkloadProfile, planner

    plan_io.check_schema()
    tiny = planner.autotune(
        "rwkv6-1.6b", WorkloadProfile(rate=0.5, duration=6.0),
        max_batches=(2,), sync_everys=(1, 2), probe_duration=6.0)
    tiny.validate()   # autotune validates too; fail loudly if that rots
    rt = plan_io.from_dict(plan_io.to_dict(tiny))
    if rt != tiny:
        raise RuntimeError("autotuned plan does not round-trip through "
                           "JSON; fix repro.plan.io coercions")
    if not isinstance(rt, ServingPlan) or rt.arch != "rwkv6-1.6b":
        raise RuntimeError("autotune returned a malformed plan")


def _check_trace_schema() -> None:
    """CI guard for the observability subsystem: serve a tiny workload
    with a tracer attached, twice with the same seed, and require (a) the
    exported documents to be byte-identical — the determinism contract
    that makes trace files diffable artifacts — (b) the schema validator
    to accept them, and (c) ``fit_profile`` to read a workload profile
    back out.  Loud in tier-1, so the trace schema, the engine's hook
    points, and the observed-traffic fit can never silently drift."""
    from repro.obs import Tracer, check_trace, fit_profile

    tiny = WorkloadProfile(kind="poisson", rate=0.5, duration=8.0,
                           deadline_slack=3.0)
    cfg, model, params = _build("rwkv6-1.6b", reduced=True)
    sharder = make_sharder(cfg, None, "decode")

    def one_run() -> Tracer:
        tracer = Tracer()
        engine = ServingEngine(model, params, sharder, max_batch=2,
                               max_len=32, tracer=tracer)
        drive(engine, profile_items(tiny, vocab_size=cfg.vocab_size,
                                    seed=0))
        return tracer

    a, b = one_run(), one_run()
    if a.dumps() != b.dumps():
        raise RuntimeError("same-seed virtual-clock runs emitted "
                           "different trace bytes; repro.obs.trace has "
                           "lost determinism")
    check_trace(a.to_chrome())   # raises ValueError on schema drift
    prof = fit_profile(a, duration=tiny.duration)
    if not (0 < prof.rate < 10 and prof.prompt_len[0] >= 1):
        raise RuntimeError(f"fit_profile returned an implausible profile "
                           f"from the smoke trace: {prof}")


def _check_paged_surface() -> None:
    """CI guard for the paged cache layout: the serve CLI must expose
    ``--cache-layout``, ``parse_cache_layout`` must accept both spellings,
    the sweep must exercise a paged cell, and a tiny dense-vs-paged probe
    on a hybrid (attention + SSM) arch must produce identical schedules
    and metrics with clean pool invariants — loudly, in tier-1, so the
    bit-exactness contract can never silently rot."""
    from repro.launch.serve import build_parser
    from repro.plan.plan import parse_cache_layout

    if not any("--cache-layout" in a.option_strings
               for a in build_parser()._actions):
        raise RuntimeError("launch/serve.py no longer exposes "
                           "--cache-layout")
    if parse_cache_layout("paged:16") != 16 \
            or parse_cache_layout("dense") is not None:
        raise RuntimeError("repro.plan.parse_cache_layout drifted from "
                           "the dense / paged:<block_size> grammar")
    if not any(c.cache_layout != "dense" for c in SERVING_LOAD_SWEEP):
        raise RuntimeError("SERVING_LOAD_SWEEP no longer exercises a "
                           "paged cell; the layout has no trajectory "
                           "coverage")

    tiny = WorkloadProfile(kind="poisson", rate=0.6, duration=8.0)
    cfg, model, params = _build("hymba-1.5b", reduced=True)
    sharder = make_sharder(cfg, None, "decode")

    def one_run(layout: str):
        engine = ServingEngine(model, params, sharder, max_batch=2,
                               max_len=32, cache_layout=layout)
        reqs = drive(engine, profile_items(tiny, vocab_size=cfg.vocab_size,
                                           seed=0))
        agg = smetrics.aggregate(reqs, ticks=engine.ticks,
                                 util_history=engine.util_history)
        return engine, [(r.uid, tuple(r.output)) for r in reqs], agg

    _, sched_d, agg_d = one_run("dense")
    eng_p, sched_p, agg_p = one_run("paged:8")
    if sched_d != sched_p:
        raise RuntimeError("dense and paged:8 schedules diverged on the "
                           "hymba smoke probe; the paged manager broke "
                           "the bit-exactness contract")
    if json.dumps(agg_d, sort_keys=True) != json.dumps(agg_p, sort_keys=True):
        raise RuntimeError("dense and paged:8 metrics diverged on the "
                           "hymba smoke probe despite equal schedules")
    eng_p.sm.check_invariants()   # raises on any pool-accounting breach


def _check_router_surface() -> None:
    """CI guard for the multi-replica router: the serve CLI's --routing
    choices must match the router's policy registry, the FleetPlan JSON
    schema must round-trip (io.check_schema grew a fleet probe), and a
    tiny 2-replica live probe must serve a seeded workload with clean
    request conservation and a deterministic pooled metrics block —
    loudly, in tier-1, so the fleet surfaces can never silently drift."""
    from repro.launch.serve import build_parser
    from repro.plan.plan import FleetPlan, ServingPlan
    from repro.serving.router import ROUTER_POLICIES, Router, drive_fleet

    choices = None
    for action in build_parser()._actions:
        if "--routing" in action.option_strings:
            choices = set(action.choices or ())
    if choices is None:
        raise RuntimeError("launch/serve.py no longer exposes --routing")
    if choices != set(ROUTER_POLICIES):
        raise RuntimeError(
            f"--routing CLI choices {sorted(choices)} drifted from the "
            f"router registry {sorted(ROUTER_POLICIES)}; update "
            f"launch/serve.py or repro/serving/router.py")
    plan_io.check_schema()   # includes the fleet_plan/v1 probe

    tiny = WorkloadProfile(kind="poisson", rate=0.8, duration=8.0)
    cfg, model, params = _build("rwkv6-1.6b", reduced=True)
    fleet = FleetPlan.replicated(
        ServingPlan(arch="rwkv6-1.6b", max_batch=2, max_len=32), 2,
        routing="least_queue").validate()
    built = {("rwkv6-1.6b", True): (model, params)}

    def one_run():
        router = Router.from_plan(fleet, seed=0, _built=built)
        reqs = drive_fleet(router, profile_items(
            tiny, vocab_size=cfg.vocab_size, seed=0))
        return router, reqs

    ra, reqs_a = one_run()
    rb, reqs_b = one_run()
    census = ra.conservation_census()
    if census["total"] != len(reqs_a) or census["finished"] != len(reqs_a):
        raise RuntimeError(f"fleet smoke probe lost requests: {census}")
    a = json.dumps(ra.fleet_aggregate(), sort_keys=True)
    b = json.dumps(rb.fleet_aggregate(), sort_keys=True)
    if a != b:
        raise RuntimeError("same-seed fleet runs produced different pooled "
                           "metrics; the router has lost determinism")
    sched = [[(r.uid, tuple(r.output)) for r in rs]
             for rs in (reqs_a, reqs_b)]
    if sched[0] != sched[1]:
        raise RuntimeError("same-seed fleet runs produced different "
                           "schedules; the router has lost determinism")


def run(fast: bool = True, smoke: bool = False) -> Iterator[Row]:
    """benchmarks.run harness entry: emit one CSV row per cell and refresh
    BENCH_serving.json in the working directory.  ``smoke`` runs one tiny
    base cell plus the overload scenario (every policy in it, preemption
    included), checks the plan JSON schema, validates the trace schema +
    byte-determinism, probes the paged cache layout against dense, and
    autotunes one tiny cell — and does NOT touch
    BENCH_serving.json; it proves the scripts, the scheduler registry,
    the plan subsystem, and the observability layer still work (the
    tier-1 CI guard)."""
    if smoke:
        _check_policy_registry()
        _check_plan_surface()
        _check_trace_schema()
        _check_paged_surface()
        _check_router_surface()
        base = [c for c in SERVING_LOAD_SWEEP
                if c.family == "rwkv" and c.max_batch == 2
                and c.policy == "fcfs" and c.prompt_dist == "uniform"
                and c.heavy_decode is None and c.deadline_slack is None][-1:]
        overload = [c.with_duration(8.0)
                    for c in SERVING_LOAD_SWEEP
                    if c.deadline_slack is not None]
        if not base or not overload:  # keep the CI guard loud on reshapes
            raise RuntimeError("smoke filter matched no SERVING_LOAD_SWEEP "
                               "cell; update the filter")
        doc = sweep(fast=True, cells=base + overload, duration=8.0)
    else:
        doc = sweep(fast=fast, autotune=True)
        write(doc)
    for c in doc["cells"]:
        m, w = c["metrics"], c["wall"]
        us_per_tok = w["seconds"] / m["tokens"] * 1e6 if m["tokens"] else 0.0
        slo = (f" slo={m['slo']['attainment']:.2f}" if "slo" in m else "")
        yield Row(
            f"serving_load/{c['name']}",
            us_per_tok,
            f"ttft_p99={m['ttft']['p99']:.0f}t"
            f" tpot_p99={m['tpot']['p99']:.2f}t"
            f" qwait_p99={m['queue_wait']['p99']:.0f}t"
            f" tok_per_tick={m['tokens_per_sec']:.2f}"
            f" util={m['mean_util']:.2f}" + slo)
    for c in doc.get("fleet", ()):
        m, w = c["metrics"], c["wall"]
        us_per_tok = w["seconds"] / m["tokens"] * 1e6 if m["tokens"] else 0.0
        slo = (f" slo={m['slo']['attainment']:.2f}" if "slo" in m else "")
        yield Row(
            f"serving_load/{c['name']}",
            us_per_tok,
            f"ttft_p99={m['ttft']['p99']:.0f}t"
            f" tpot_p99={m['tpot']['p99']:.2f}t"
            f" slo_met_tok={c['slo_met_tokens']}"
            f" handoffs={c['transit']['handoffs']}" + slo)


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--full", action="store_true",
                    help="longer workloads (256 clock units vs 32)")
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--out", default=DEFAULT_OUT)
    ap.add_argument("--full-size", action="store_true",
                    help="full-size configs (default: reduced, CPU-friendly)")
    ap.add_argument("--trace-dir", default=None, metavar="DIR",
                    help="archive one Perfetto-viewable trace file per "
                         "cell (repro.obs structured event traces; "
                         "byte-stable per seed)")
    args = ap.parse_args()
    # both BENCH-writing entries (this and benchmarks.run) include the
    # autotuned overload cell, so the committed document shape is the same
    # whichever path regenerated it
    doc = sweep(fast=not args.full, seed=args.seed,
                reduced=not args.full_size, autotune=True,
                trace_dir=args.trace_dir)
    write(doc, args.out)
    print(f"wrote {args.out}: {len(doc['cells'])} cells "
          f"+ {len(doc.get('fleet', ()))} fleet cells, "
          f"families={doc['families']}")
    for c in doc["cells"]:
        m = c["metrics"]
        slo = (f"  slo {m['slo']['attainment']:.2f}" if "slo" in m else "")
        print(f"  {c['name']:>36}"
              f" ttft p50/p95 = {m['ttft']['p50']:5.1f}/{m['ttft']['p95']:5.1f}t"
              f"  tpot p50/p99 = {m['tpot']['p50']:4.2f}/{m['tpot']['p99']:4.2f}t"
              f"  {m['tokens_per_sec']:5.2f} tok/tick"
              f"  util {m['mean_util']:.2f}" + slo)
    for c in doc.get("fleet", ()):
        m = c["metrics"]
        slo = (f"  slo {m['slo']['attainment']:.2f}" if "slo" in m else "")
        print(f"  {c['name']:>36}"
              f" ttft p50/p99 = {m['ttft']['p50']:5.1f}/{m['ttft']['p99']:5.1f}t"
              f"  tpot p50/p99 = {m['tpot']['p50']:4.2f}/{m['tpot']['p99']:4.2f}t"
              f"  slo-met tok {c['slo_met_tokens']:4d}"
              f"  handoffs {c['transit']['handoffs']}" + slo)


if __name__ == "__main__":
    main()
