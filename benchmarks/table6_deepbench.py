"""Paper Table 6: DeepBench RNN inference latency / effective TFLOPS.

Per task we report:
  * measured CPU-JAX per-step latency of the BLAS-based vs loop-based-fused
    execution models (the paper's §3 comparison, on this host),
  * the *modeled* TPU-v5e latency of the fused Pallas kernel from the DSE
    cost model (no TPU in this container; the model is the same roofline
    arithmetic the §Roofline analysis uses),
  * the paper's reported Plasticine/Brainwave/V100 numbers for context.

derived column: full-sequence modeled latency (ms) on TPU + effective
TFLOPS at that latency + the paper-reported baselines.
"""

from __future__ import annotations

from typing import List

import jax
import jax.numpy as jnp

from benchmarks.common import Row, time_jax
from repro.configs import DEEPBENCH_TASKS
from repro.core import dse
from repro.core.cells import RNNCellConfig, init_weights, quantize_weights, serve


def run(fast: bool = True, smoke: bool = False) -> List[Row]:
    # smoke (tier-1 CI): two small tasks, 2 measured steps — just proves
    # the measured path (both execution models + the DSE) still runs
    tasks = DEEPBENCH_TASKS[:2] if smoke else DEEPBENCH_TASKS
    rows: List[Row] = []
    for task in tasks:
        cfg = RNNCellConfig(task.cell, task.hidden,
                            timesteps=task.timesteps, batch=1,
                            precision="int8")
        w = quantize_weights(cfg, init_weights(cfg, jax.random.PRNGKey(0)))
        t_meas = min(task.timesteps, 2 if smoke else (8 if fast else
                                                      task.timesteps))
        x = jax.random.normal(jax.random.PRNGKey(1), (t_meas, 1, cfg.d),
                              jnp.bfloat16)

        fused = jax.jit(lambda xx, ww=w, cc=cfg: serve(cc, ww, xx, "fused"))
        blas = jax.jit(lambda xx, ww=w, cc=cfg: serve(cc, ww, xx, "blas"))
        us_fused = time_jax(fused, x) / t_meas
        us_blas = time_jax(blas, x) / t_meas

        plan = dse.best_plan(cfg)
        tpu_ms = plan.step_latency_s * task.timesteps * 1e3
        flops = cfg.flops_per_step() * task.timesteps
        eff_tflops = flops / (tpu_ms * 1e-3) / 1e12
        rows.append(Row(
            name=f"deepbench/{task.name}/cpu_fused_step",
            us_per_call=us_fused,
            derived=(f"blas_step_us={us_blas:.1f};"
                     f"fused_speedup={us_blas/us_fused:.2f}x;"
                     f"tpu_model_ms={tpu_ms:.4f};"
                     f"tpu_eff_tflops={eff_tflops:.2f};"
                     f"paper_plasticine_ms={task.ms_plasticine};"
                     f"paper_bw_ms={task.ms_brainwave};"
                     f"paper_v100_ms={task.ms_v100}"),
        ))
    return rows
