"""Collective-volume trajectory: DCN/ICI traffic per (arch, shape) cell.

For each cell of a small serving-relevant grid — the three tier-1-pinned
serving archs x {decode_32k, prefill_32k} — this suite compiles the cell
against the 2x16x16 multi-pod production mesh (512 fake host devices,
one subprocess per cell because jax locks the device count at first
initialization) and records the compiled program's *collective* traffic:
op counts by kind, operand bytes, and modeled ICI bytes, plus peak
memory and compile wall time.  The deterministic part (everything except
wall timings) is committed as ``BENCH_collectives.json`` — the repo's
collective-volume trajectory.

The planner consumes this file: ``repro.plan.planner.load_collectives``
reads it and ``planner.autotune_fleet`` uses the recorded prefill/decode
evidence when scoring ``shard_mode`` per fleet replica (a prefill
replica only gets the prefill sharding when the trajectory actually
recorded a prefill cell for that arch).

  PYTHONPATH=src python -m benchmarks.collectives [--out BENCH_collectives.json]
"""

from __future__ import annotations

import argparse
import json
import os
import subprocess
import sys
from typing import Dict, Iterator, List, Optional, Sequence, Tuple

from benchmarks.common import Row

SCHEMA = "collectives/v1"
DEFAULT_OUT = "BENCH_collectives.json"
MESH = "pod2x16x16"

# The serving archs tier-1 pins (dense attention, RWKV, hybrid SSM) —
# the same trio the chaos and paged tier2 grids sweep — at the two
# serving shapes the fleet planner distinguishes: one decode step and
# the 32k prefill.
GRID: Tuple[Tuple[str, str], ...] = tuple(
    (arch, shape)
    for arch in ("rwkv6-1.6b", "qwen2.5-14b", "hymba-1.5b")
    for shape in ("decode_32k", "prefill_32k")
)

# One subprocess per cell: jax locks the fake-device count at first
# initialization, so the 512-device mesh cannot share a process with
# anything else (same pattern as tests/test_dryrun_tier2.py).
CELL = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"
import json, sys
from repro.launch.dryrun import run_cell

cell = run_cell(sys.argv[1], sys.argv[2], multi_pod=True, pieces=False)
cell.pop("traceback", None)
out = {k: cell.get(k) for k in ("arch", "shape", "mesh", "ok", "skip",
                                "error", "chips", "wall_s")}
full = cell.get("full") or {}
out["flops"] = full.get("flops")
out["bytes"] = full.get("bytes")
out["collectives"] = full.get("collectives")
out["memory"] = full.get("memory")
out["compile_s"] = full.get("compile_s")
print("CELL_JSON=" + json.dumps(out))
"""


def run_grid_cell(arch: str, shape: str,
                  timeout: float = 3600.0) -> Dict[str, object]:
    """Compile one (arch, shape) cell in a subprocess and return its
    record: the deterministic collective/memory summary at the top level,
    host-noisy timings under ``wall``."""
    r = subprocess.run(
        [sys.executable, "-c", CELL, arch, shape],
        capture_output=True, text=True, timeout=timeout,
        env={**os.environ, "PYTHONPATH": "src", "JAX_PLATFORMS": "cpu"},
        cwd=os.path.dirname(os.path.dirname(os.path.abspath(__file__))))
    if r.returncode != 0:
        raise RuntimeError(f"collectives cell {arch}/{shape} failed:\n"
                           + r.stderr[-3000:])
    line = next(l for l in r.stdout.splitlines()
                if l.startswith("CELL_JSON="))
    raw = json.loads(line[len("CELL_JSON="):])
    cell: Dict[str, object] = {
        "arch": raw["arch"],
        "shape": raw["shape"],
        "mesh": raw["mesh"],
        "chips": raw.get("chips"),
        "ok": raw["ok"],
    }
    if raw.get("skip"):
        cell["skip"] = raw["skip"]
        return cell
    if not raw["ok"]:
        raise RuntimeError(f"collectives cell {arch}/{shape} did not "
                           f"compile: {raw.get('error')}")
    cell.update(
        flops=raw["flops"],
        bytes=raw["bytes"],
        collectives=raw["collectives"],
        memory=raw["memory"],
        wall={  # host-dependent; excluded from the determinism contract
            "compile_s": raw["compile_s"],
            "total_s": raw["wall_s"],
        },
    )
    return cell


def sweep(grid: Sequence[Tuple[str, str]] = GRID) -> Dict[str, object]:
    cells: List[Dict[str, object]] = []
    for arch, shape in grid:
        cells.append(run_grid_cell(arch, shape))
    return {
        "schema": SCHEMA,
        "mesh": MESH,
        "cells": cells,
    }


def deterministic_view(doc: Dict[str, object]) -> Dict[str, object]:
    """The compile-determined subset (drops wall timings); two runs on
    the same jax/XLA build must agree on this exactly."""
    return {
        **{k: v for k, v in doc.items() if k != "cells"},
        "cells": [{k: v for k, v in c.items() if k != "wall"}
                  for c in doc["cells"]],
    }


def write(doc: Dict[str, object], path: str = DEFAULT_OUT) -> None:
    with open(path, "w") as f:
        json.dump(doc, f, indent=1)
        f.write("\n")


def _check_collectives_surface() -> None:
    """CI guard for the collective-volume trajectory: the committed
    BENCH_collectives.json must parse through the planner's reader, cover
    the grid this suite sweeps, and actually steer
    ``planner.fleet_shard_modes`` — loudly, in tier-1, so the planner can
    never silently consult a file the suite no longer writes."""
    from repro.plan import planner

    colls = planner.load_collectives()
    if not colls:
        raise RuntimeError(f"{planner.BENCH_COLLECTIVES} is missing or "
                           f"empty; regenerate it with "
                           f"`python -m benchmarks.collectives`")
    missing = [(a, s) for a, s in GRID if (a, s) not in colls]
    if missing:
        raise RuntimeError(f"BENCH_collectives.json lost grid cells "
                           f"{missing}; regenerate it")
    for key, block in colls.items():
        for field in ("n_ops", "operand_bytes", "ici_bytes", "by_kind"):
            if field not in block:
                raise RuntimeError(f"collectives block {key} lost field "
                                   f"{field!r}; the dryrun summary and "
                                   f"this trajectory drifted")
    # with prefill evidence on record, a disaggregated fleet's prefill
    # replica gets the prefill sharding; without it, the planner must
    # fall back to decode (never invent an unmeasured mode)
    modes, record = planner.fleet_shard_modes("rwkv6-1.6b", 3, 1, colls)
    if modes[0] != "prefill" or modes[1:] != ["decode", "decode"]:
        raise RuntimeError(f"fleet_shard_modes ignored the recorded "
                           f"prefill evidence: {modes}")
    modes, _ = planner.fleet_shard_modes("no-such-arch", 2, 1, colls)
    if modes != ["decode", "decode"]:
        raise RuntimeError(f"fleet_shard_modes invented a shard mode "
                           f"without trajectory evidence: {modes}")
    if record.get("source") != "BENCH_collectives.json":
        raise RuntimeError("fleet_shard_modes provenance lost its source "
                           "tag")


def _rows(doc: Dict[str, object]) -> Iterator[Row]:
    for c in doc["cells"]:
        if c.get("skip"):
            continue
        coll = c["collectives"]
        wall = c.get("wall", {})
        yield Row(
            f"collectives/{c['arch']}/{c['shape']}",
            float(wall.get("compile_s", 0.0)) * 1e6,
            f"n_ops={coll['n_ops']}"
            f" ici_gb={coll['ici_bytes'] / 1e9:.3f}"
            f" operand_gb={coll['operand_bytes'] / 1e9:.3f}"
            f" kinds={'+'.join(sorted(coll['by_kind']))}")


def run(fast: bool = True, smoke: bool = False) -> Iterator[Row]:
    """benchmarks.run harness entry.  ``smoke`` validates the committed
    trajectory against the planner's reader (no compiles, no writes);
    the real run re-sweeps the grid and refreshes BENCH_collectives.json."""
    if smoke:
        _check_collectives_surface()
        with open(DEFAULT_OUT) as f:
            yield from _rows(json.load(f))
        return
    doc = sweep()
    write(doc)
    yield from _rows(doc)


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out", default=DEFAULT_OUT)
    args = ap.parse_args()
    doc = sweep()
    write(doc, args.out)
    print(f"wrote {args.out}: {len(doc['cells'])} cells")
    for row in _rows(doc):
        print(row.csv())


if __name__ == "__main__":
    main()
