"""Decode hot-path microbenchmark: host-sync cost vs on-device chunking.

The paper's thesis at host granularity: per-tick host round-trips (sample
on host, read lengths, relaunch) are the serving analogue of per-kernel
data movement.  This benchmark measures exactly that lever on the
continuous-batching engine — for a grid of ``sync_every`` (decode ticks
per host sync) and prefill config (bucketed batched vs legacy exact-length
batch-1) it runs a warmed-up closed-loop workload and reports:

* ``syncs_per_tick`` — blocking host↔device readbacks per engine tick
  (deterministic: a pure function of the schedule);
* ``s_per_tick`` / ``tokens_per_sec`` — measured wall numbers
  (host-noisy);
* ``prefill_compiles`` — distinct prefill programs XLA built for the
  mixed-length arrivals (deterministic; ≤ bucket count in bucketed mode).

  PYTHONPATH=src python -m benchmarks.decode_hotpath [--arch rwkv6-1.6b]
      [--out BENCH_decode_hotpath.json]

The committed ``BENCH_decode_hotpath.json`` is part of the perf
trajectory: ``deterministic`` blocks must be byte-stable for a fixed
seed; ``wall`` blocks are machine-dependent context.
"""

from __future__ import annotations

import argparse
import json
import time
from typing import Dict, Iterator, List, Sequence

import jax
import numpy as np

from benchmarks.common import Row
from repro.configs import get_config
from repro.dist.sharding import make_sharder
from repro.models.lm import build_model
from repro.plan import ServingPlan, io as plan_io
from repro.serving import ServingEngine
from repro.testing import reduced_config

SCHEMA = "decode_hotpath/v1"
DEFAULT_OUT = "BENCH_decode_hotpath.json"
SYNC_EVERYS = (1, 2, 4, 8)


def _workload(vocab_size: int, n_requests: int, seed: int):
    """Seeded mixed-length closed-loop prompts (pure function of seed)."""
    rng = np.random.default_rng(seed)
    out = []
    for _ in range(n_requests):
        n = int(rng.integers(3, 21))
        out.append([int(x) for x in rng.integers(0, vocab_size, n)])
    return out


def run_config(model, params, sharder, vocab_size: int, *,
               sync_every: int, bucketed: bool, n_requests: int = 8,
               max_new: int = 32, max_batch: int = 4, max_len: int = 64,
               seed: int = 0, reduced: bool = True) -> Dict[str, object]:
    """Measure one (sync_every, bucketed) point: warm the jit caches with
    one full closed-loop pass, reset telemetry, then time a second pass.
    The point is expressed as a :class:`ServingPlan` (embedded in the
    output cell), so the trajectory records the design point."""
    plan = ServingPlan(arch=model.cfg.name, reduced=reduced,
                       max_batch=max_batch,
                       max_len=max_len, sync_every=sync_every,
                       bucketed_prefill=bucketed,
                       provenance={"source": "decode_hotpath grid"})
    engine = ServingEngine.from_plan(plan, params, model=model,
                                     sharder=sharder, seed=seed)
    prompts = _workload(vocab_size, n_requests, seed)
    for warm in (True, False):
        if warm:
            for p in prompts:
                engine.submit(list(p), max_new_tokens=max_new)
            engine.run()
            engine.reset_telemetry()
            continue
        for p in prompts:
            engine.submit(list(p), max_new_tokens=max_new)
        t0 = time.perf_counter()
        engine.run()
        dt = time.perf_counter() - t0
    s = engine.stats()
    ticks = max(1, int(s["ticks"]))
    return {
        "sync_every": sync_every,
        "bucketed_prefill": bucketed,
        "n_requests": n_requests,
        "max_new": max_new,
        "max_batch": max_batch,
        "plan": plan_io.to_dict(engine.plan.resolve()),
        "deterministic": {  # pure function of (workload seed, config)
            "ticks": int(s["ticks"]),
            "tokens": int(s["total_tokens"]),
            "host_syncs": int(s["host_syncs"]),
            "decode_chunks": int(s["decode_chunks"]),
            "prefill_calls": int(s["prefill_calls"]),
            "prefill_compiles": int(s["prefill_compiles"]),
            "syncs_per_tick": s["host_syncs"] / ticks,
        },
        "wall": {  # host-dependent; excluded from determinism
            "seconds": dt,
            "s_per_tick": dt / ticks,
            "tokens_per_sec": s["total_tokens"] / dt if dt else 0.0,
        },
    }


def measure(arch: str = "rwkv6-1.6b", *, reduced: bool = True, seed: int = 0,
            sync_everys: Sequence[int] = SYNC_EVERYS,
            bucket_configs: Sequence[bool] = (True, False),
            n_requests: int = 8, max_new: int = 32) -> Dict[str, object]:
    cfg = reduced_config(arch) if reduced else get_config(arch)
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    sharder = make_sharder(cfg, None, "decode")
    cells: List[Dict[str, object]] = []
    for bucketed in bucket_configs:
        for se in sync_everys:
            cells.append(run_config(model, params, sharder, cfg.vocab_size,
                                    sync_every=se, bucketed=bucketed,
                                    n_requests=n_requests, max_new=max_new,
                                    seed=seed, reduced=reduced))
    return {"schema": SCHEMA, "arch": arch, "reduced": reduced, "seed": seed,
            "cells": cells}


def write(doc: Dict[str, object], path: str = DEFAULT_OUT) -> None:
    with open(path, "w") as f:
        json.dump(doc, f, indent=1)
        f.write("\n")


def _rows(doc: Dict[str, object]) -> Iterator[Row]:
    for c in doc["cells"]:
        d, w = c["deterministic"], c["wall"]
        name = (f"decode_hotpath/{doc['arch']}/"
                f"{'bucketed' if c['bucketed_prefill'] else 'batch1'}"
                f"/sync{c['sync_every']}")
        yield Row(
            name,
            w["s_per_tick"] * 1e6,
            f"syncs_per_tick={d['syncs_per_tick']:.3f}"
            f" tok_per_s={w['tokens_per_sec']:.1f}"
            f" ticks={d['ticks']}"
            f" prefill_compiles={d['prefill_compiles']}")


def run(fast: bool = True, smoke: bool = False) -> Iterator[Row]:
    """benchmarks.run harness entry.  ``smoke`` runs a 2-point grid and
    does NOT refresh BENCH_decode_hotpath.json."""
    if smoke:
        doc = measure(sync_everys=(1, 4), bucket_configs=(True,),
                      n_requests=4, max_new=8)
    else:
        doc = measure(n_requests=8 if fast else 16,
                      max_new=32 if fast else 64)
        write(doc)
    yield from _rows(doc)


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--arch", default="rwkv6-1.6b")
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--out", default=DEFAULT_OUT)
    ap.add_argument("--full-size", action="store_true",
                    help="full-size config (default: reduced, CPU-friendly)")
    args = ap.parse_args()
    doc = measure(args.arch, reduced=not args.full_size, seed=args.seed)
    write(doc, args.out)
    print(f"wrote {args.out}: {len(doc['cells'])} cells")
    for row in _rows(doc):
        print(" ", row.csv())


if __name__ == "__main__":
    main()
