"""Benchmark harness: one module per paper table/figure.

  PYTHONPATH=src python -m benchmarks.run [--full] [--only NAME]

Prints ``name,us_per_call,derived`` CSV.
"""

from __future__ import annotations

import argparse
import sys
import traceback

from benchmarks import (
    energy,
    fig4_fragmentation,
    roofline_table,
    serving_load,
    table6_deepbench,
    table7_dse,
)

SUITES = {
    "table6_deepbench": table6_deepbench,
    "table7_dse": table7_dse,
    "fig4_fragmentation": fig4_fragmentation,
    "energy": energy,
    "roofline_table": roofline_table,
    "serving_load": serving_load,
}


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--full", action="store_true",
                    help="full timesteps for measured benchmarks")
    ap.add_argument("--only", default=None)
    args = ap.parse_args()

    print("name,us_per_call,derived")
    failed = []
    for name, mod in SUITES.items():
        if args.only and args.only != name:
            continue
        try:
            for row in mod.run(fast=not args.full):
                print(row.csv(), flush=True)
        except Exception as e:  # noqa: BLE001 — keep the harness running
            failed.append(name)
            print(f"{name}/ERROR,0.0,{type(e).__name__}: {e}", flush=True)
            traceback.print_exc(file=sys.stderr)
    if failed:
        sys.exit(1)


if __name__ == "__main__":
    main()
