"""Benchmark harness: one module per paper table/figure.

  PYTHONPATH=src python -m benchmarks.run [--full] [--smoke] [--only NAME]

Prints ``name,us_per_call,derived`` CSV.

``--smoke`` runs every suite in a tiny configuration (a couple of cells,
short sequences) and never rewrites the committed BENCH_*.json trajectory
files — it exists so tier-1 CI can prove the benchmark scripts still run
between the real (weekly / manual) sweeps.  The serving_load smoke
additionally guards the plan subsystem: it autotunes one tiny cell and
fails loudly if the result fails ``ServingPlan.validate()`` or the plan
JSON schema drifts from the dataclass fields (see
``serving_load._check_plan_surface``).
"""

from __future__ import annotations

import argparse
import inspect
import sys
import traceback

from benchmarks import (
    chaos,
    collectives,
    decode_hotpath,
    energy,
    fig4_fragmentation,
    kernel_tiles,
    roofline_table,
    serving_load,
    table6_deepbench,
    table7_dse,
)

SUITES = {
    "table6_deepbench": table6_deepbench,
    "table7_dse": table7_dse,
    "fig4_fragmentation": fig4_fragmentation,
    "energy": energy,
    "roofline_table": roofline_table,
    "kernel_tiles": kernel_tiles,
    "serving_load": serving_load,
    "decode_hotpath": decode_hotpath,
    "chaos": chaos,
    "collectives": collectives,
}


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--full", action="store_true",
                    help="full timesteps for measured benchmarks")
    ap.add_argument("--smoke", action="store_true",
                    help="tiny configs, no BENCH_*.json writes (CI guard)")
    ap.add_argument("--only", default=None)
    args = ap.parse_args()

    print("name,us_per_call,derived")
    failed = []
    for name, mod in SUITES.items():
        if args.only and args.only != name:
            continue
        kwargs = {"fast": not args.full}
        if args.smoke and "smoke" in inspect.signature(mod.run).parameters:
            kwargs["smoke"] = True
        try:
            for row in mod.run(**kwargs):
                print(row.csv(), flush=True)
        except Exception as e:  # noqa: BLE001 — keep the harness running
            failed.append(name)
            print(f"{name}/ERROR,0.0,{type(e).__name__}: {e}", flush=True)
            traceback.print_exc(file=sys.stderr)
    if failed:
        sys.exit(1)


if __name__ == "__main__":
    main()
