"""Kernel tile sweep: the DSE cost model driving real BlockSpec geometry.

For every Pallas kernel in ``repro.kernels`` this sweeps the kernel's
candidate tile grid through the analytic cost model in ``repro.core.dse``
(the paper's §3.3 loop-tiling search, extended in PR 9 from the fused RNN
cell to flash attention and the W8A16 matmul) and records, per shape:

* the *naive* tile — the smallest legal BlockSpec geometry in the
  candidate grid, i.e. what you get with no tuning at all (maximum grid
  steps, maximum per-step overhead);
* the *chosen* tile — the cost-model argmin under the VMEM-residency
  constraint (exactly what ``planner.tile_plans_for`` embeds in a
  ``ServingPlan`` and what the ops wrappers turn into BlockSpecs);
* the modeled speedup of chosen over naive.  The sweep **fails loudly**
  if the chosen tile ever models slower than the naive one — the
  committed file is the proof the search earns its keep per kernel.

Every number is a pure function of the hardware constants in ``repro.hw``
(no RNG, no wall clock), so ``BENCH_kernels.json`` is byte-stable across
runs and diffable as part of the perf trajectory.  The ``backend`` column
records what produced each row: ``modeled`` here; a hardware sweep on a
real TPU would append ``tpu`` rows next to them (same schema) rather than
replacing the modeled trajectory.

  PYTHONPATH=src python -m benchmarks.kernel_tiles [--out BENCH_kernels.json]

``--smoke`` (via benchmarks.run) instead runs ``_check_kernel_surface``:
an end-to-end probe that a non-default ``tile_plans`` entry provably
changes the *lowered program* of a tiny rwkv decode step while leaving
its logits bit-identical in interpret mode, plus plan-validation and CLI
surface guards.  It never writes BENCH_kernels.json.
"""

from __future__ import annotations

import argparse
import json
from typing import Dict, Iterator, List

from benchmarks.common import Row
from repro import hw
from repro.core import dse
from repro.core.cells import RNNCellConfig

SCHEMA = "kernel_tiles/v1"
DEFAULT_OUT = "BENCH_kernels.json"
BACKEND = "modeled"

# fused_rnn sweep points: DeepBench serving sizes (paper Table 6) at the
# two batch regimes the engine actually runs (interactive b=1, saturated
# b=64 — PR 5's batch-aware DSE point)
_RNN_POINTS = (
    ("lstm", 1024, 1), ("lstm", 2048, 1), ("lstm", 2048, 64),
    ("gru", 2048, 1), ("gru", 2560, 64),
)
# rwkv decode: the wkv cell at rwkv6-1.6b width, modeled as the 3-gate
# cell exactly as planner.tile_plans_for does
_RWKV_POINTS = (("rwkv6-width", 2048, 1), ("rwkv6-width", 2048, 8))
# flash attention: (seq_q, seq_kv, head_dim, n_heads, batch)
_ATTN_POINTS = (
    ("prefill-2k", 2048, 2048, 128, 8, 1),
    ("prefill-8k", 8192, 8192, 128, 8, 1),
    ("window-4k", 4096, 1024, 128, 8, 4),
)
# W8A16 matmul: (M, N, K) — decode-batch GEMV-ish and prefill GEMM
_MM_POINTS = (
    ("decode-b8", 8, 8192, 2048),
    ("prefill-256", 256, 8192, 2048),
    ("logits-256", 256, 50264, 2048),
)


def _cell(kernel: str, name: str, shape: Dict[str, int],
          naive: dse.Plan, chosen: dse.Plan) -> Dict[str, object]:
    if chosen.step_latency_s > naive.step_latency_s:
        raise RuntimeError(
            f"kernel_tiles/{kernel}/{name}: DSE-chosen tile "
            f"{dse.plan_dict(chosen)} models SLOWER than the naive tile "
            f"{dse.plan_dict(naive)}; the tile search regressed")
    return {
        "kernel": kernel,
        "name": name,
        "backend": BACKEND,
        "shape": shape,
        "naive": dse.plan_dict(naive),
        "chosen": dse.plan_dict(chosen),
        "speedup": naive.step_latency_s / chosen.step_latency_s,
    }


def sweep(spec: hw.HardwareSpec = hw.DEFAULT) -> Dict[str, object]:
    """The full modeled sweep -> the BENCH_kernels.json document."""
    cells: List[Dict[str, object]] = []

    for cell_kind, H, batch in _RNN_POINTS:
        cfg = RNNCellConfig(cell_kind, hidden=H, features=H,
                            precision="bf16")
        tiles = dse.candidate_tiles(H)
        naive = dse.plan_metrics(cfg, tiles[0], spec, max_batch=batch)
        chosen = dse.best_plan(cfg, spec, max_batch=batch)
        cells.append(_cell("fused_rnn", f"{cell_kind}-h{H}-b{batch}",
                           {"hidden": H, "batch": batch}, naive, chosen))

    for name, H, batch in _RWKV_POINTS:
        cfg = RNNCellConfig("gru", hidden=H, features=H, precision="bf16")
        tiles = dse.candidate_tiles(H)
        naive = dse.plan_metrics(cfg, tiles[0], spec, max_batch=batch)
        chosen = dse.best_plan(cfg, spec, max_batch=batch)
        cells.append(_cell("rwkv_step", f"{name}-b{batch}",
                           {"hidden": H, "batch": batch}, naive, chosen))

    for name, sq, skv, hd, heads, batch in _ATTN_POINTS:
        bq0, bk0 = dse.candidate_attn_tiles(sq, skv)[0]
        naive = dse.attn_plan_metrics(sq, skv, hd, bq0, bk0, spec,
                                      n_heads=heads, batch=batch)
        chosen = dse.best_attn_plan(sq, skv, hd, spec,
                                    n_heads=heads, batch=batch)
        cells.append(_cell(
            "flash_attention", name,
            {"seq_q": sq, "seq_kv": skv, "head_dim": hd,
             "n_heads": heads, "batch": batch}, naive, chosen))

    for name, M, N, K in _MM_POINTS:
        bm0, bn0, bk0 = dse.candidate_mm_tiles(M, N, K)[0]
        naive = dse.matmul_plan_metrics(M, N, K, bm0, bn0, bk0, spec)
        chosen = dse.best_matmul_plan(M, N, K, spec)
        cells.append(_cell("matmul_int8", name,
                           {"M": M, "N": N, "K": K}, naive, chosen))

    return {"schema": SCHEMA, "hw": spec.name, "backend": BACKEND,
            "cells": cells}


def write(doc: Dict[str, object], path: str = DEFAULT_OUT) -> None:
    with open(path, "w") as f:
        json.dump(doc, f, indent=1)
        f.write("\n")


def _rows(doc: Dict[str, object]) -> Iterator[Row]:
    for c in doc["cells"]:
        tiles = ";".join(f"{f}={c['chosen'][f]}"
                         for f in ("bh", "bq", "bk", "bm", "bn")
                         if c["chosen"].get(f))
        yield Row(
            name=f"kernel_tiles/{c['kernel']}/{c['name']}",
            us_per_call=c["chosen"]["step_latency_s"] * 1e6,
            derived=(f"backend={c['backend']};{tiles};"
                     f"bound={c['chosen']['bound']};"
                     f"speedup_vs_naive={c['speedup']:.2f}"),
        )


# ---------------------------------------------------------------------------
# Smoke guard: the tile plan provably reaches the compiled program
# ---------------------------------------------------------------------------


def _check_kernel_surface() -> None:
    """CI guard that closes the kernel loop end-to-end, in tier-1:

    1. A non-default ``tile_plans`` entry must *change the lowered
       program* of the model's decode step (the plan demonstrably reaches
       the hardware, not just the metadata), while the logits stay
       bit-identical in interpret mode — tile choices that only re-block
       independent work (the rwkv head tile) must never change a single
       bit of the math.
    2. ``ServingPlan.validate`` must reject malformed tile plans, so a
       bad entry can never reach a BlockSpec.
    3. ``launch/serve.py`` must expose ``--hw-spec`` (the rescore-for-
       other-silicon path), and ``planner.tile_plans_for`` output must
       validate for every layer-kind family it emits.
    """
    import jax
    import numpy as np

    from repro.dist.sharding import make_sharder
    from repro.models.lm import build_model
    from repro.plan import ServingPlan
    from repro.plan.planner import tile_plans_for
    from repro.testing import reduced_config

    # --- 1: lowered-program + bit-exactness probe
    cfg = reduced_config("rwkv6-1.6b")
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    sharder = make_sharder(cfg, None, "decode")
    prompts = jax.numpy.asarray([[3, 5, 7, 9]], jax.numpy.int32)
    cache, _ = model.prefill(params, {"tokens": prompts}, sharder,
                             max_len=16)
    tokens = jax.numpy.asarray([11], jax.numpy.int32)
    hd = cfg.rwkv.head_dim

    def lower_and_run(entry):
        m = model.with_tile_plans({"rwkv": entry} if entry else {})
        fn = jax.jit(lambda p, c, t: m.decode_step(p, c, t, sharder))
        text = fn.lower(params, cache, tokens).as_text()
        _, logits = fn(params, cache, tokens)
        return text, np.asarray(logits)

    # both pallas, differing only in the head tile: grids (T, 1) vs (T, H)
    text_a, logits_a = lower_and_run({"impl": "pallas"})
    text_b, logits_b = lower_and_run({"impl": "pallas", "bh": hd})
    text_jnp, _ = lower_and_run(None)
    if text_a == text_b:
        raise RuntimeError(
            "tile_plans bh change did not alter the lowered decode "
            "program; the plan no longer reaches the kernel grid")
    if text_a == text_jnp:
        raise RuntimeError(
            "impl=pallas lowered identically to the jnp path; kernel "
            "dispatch is disconnected from tile_plans")
    if not (logits_a == logits_b).all():
        raise RuntimeError(
            "rwkv head-tile change perturbed decode logits; the head "
            "split must be bit-exact (independent per-head math)")

    # --- 2: validation rejects malformed plans
    for bad in ({"bogus_kernel": {"bh": 8}},
                {"rwkv": {"bh": -8}},
                {"rwkv": {"persistent": True}}):
        try:
            ServingPlan(arch="rwkv6-1.6b", tile_plans=bad).validate()
        except ValueError:
            pass
        else:
            raise RuntimeError(
                f"ServingPlan.validate accepted malformed tile_plans "
                f"{bad}")

    # --- 3: CLI + planner surfaces
    from repro.launch.serve import build_parser
    if not any("--hw-spec" in a.option_strings
               for a in build_parser()._actions):
        raise RuntimeError("launch/serve.py no longer exposes --hw-spec")
    for arch in ("rwkv6-1.6b", "gemma2-9b", "hymba-1.5b"):
        tp = tile_plans_for(arch, 8, hw.DEFAULT, max_len=1024)
        if not tp:
            raise RuntimeError(f"tile_plans_for({arch}) emitted nothing")
        ServingPlan(arch=arch, tile_plans=tp).validate()


def run(fast: bool = True, smoke: bool = False) -> Iterator[Row]:
    """benchmarks.run entry: emit one row per (kernel, shape) cell and
    refresh BENCH_kernels.json; ``smoke`` runs the kernel-surface guard
    and never writes the file."""
    if smoke:
        _check_kernel_surface()
        doc = sweep()         # still modeled + asserted, just not written
    else:
        doc = sweep()
        write(doc)
    yield from _rows(doc)


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--out", default=DEFAULT_OUT)
    args = ap.parse_args()
    doc = sweep()
    write(doc, args.out)
    for row in _rows(doc):
        print(row.csv())


if __name__ == "__main__":
    main()
