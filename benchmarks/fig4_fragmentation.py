"""Paper Fig. 4: compute fragmentation, MVM-tiled vs loop-based designs.

Utilization = useful MACs / issued MACs for (a) a Brainwave-geometry tiled
MVM engine (2-D fragmentation on H and R) and (b) the loop-based design
(1-D fragmentation on R only), across hidden sizes.
"""

from __future__ import annotations

from typing import List

from benchmarks.common import Row
from repro.core import dse


def run(fast: bool = True) -> List[Row]:
    rows: List[Row] = []
    ratios = []
    for H in (256, 512, 1024, 1536, 2048, 2560, 2816):
        f = dse.fragmentation(H)
        ratios.append(f["util_loop"] / f["util_mvm_bw"])
        rows.append(Row(
            name=f"fragmentation/H{H}",
            us_per_call=0.0,
            derived=(f"util_loop={f['util_loop']:.3f};"
                     f"util_mvm_bw={f['util_mvm_bw']:.3f};"
                     f"advantage={ratios[-1]:.2f}x"),
        ))
    geo = 1.0
    for r in ratios:
        geo *= r
    geo **= 1.0 / len(ratios)
    rows.append(Row("fragmentation/geomean_advantage", 0.0,
                    f"advantage={geo:.2f}x"))
    return rows
