"""Paper Fig. 4 revisited: fragmentation, compute *and* memory.

Part A — the paper's own figure: compute fragmentation, MVM-tiled vs
loop-based designs.  Utilization = useful MACs / issued MACs for (a) a
Brainwave-geometry tiled MVM engine (2-D fragmentation on H and R) and
(b) the loop-based design (1-D fragmentation on R only), across hidden
sizes.

Part B — the serving-tier analogue of the same argument (PR 7): *memory*
fragmentation.  The dense slot-state layout pads every slot's cache to
``max_batch x max_len`` columns, so resident bytes are a worst-case
constant regardless of what the traffic actually holds; the paged layout
(``repro.serving.paged``) provisions blocks per covered tokens, so
resident bytes track the work in flight.  For each committed heavy-tail
serving cell (lognormal / bimodal prompt distributions and the
heavy-decode overload mix) this benchmark serves the *same seeded
workload* under both layouts and records the trajectory — tokens in
flight vs bytes resident vs padding waste, sampled on the virtual clock —
into ``BENCH_fragmentation.json``.  Both runs are deterministic (bytes
come from ``ParamSpec`` accounting, the clock is virtual), so the
committed document is byte-diffable like ``BENCH_serving.json``, and the
benchmark *asserts* the two contracts on every cell: identical
tokens-in-flight trajectories (the schedules are bit-exact) and paged
``bytes_resident <= dense`` at every sample.

  PYTHONPATH=src python -m benchmarks.fig4_fragmentation [--out PATH]
"""

from __future__ import annotations

import argparse
import dataclasses
import json
from typing import Dict, Iterator, List, Optional, Sequence

from benchmarks.common import Row
from repro.core import dse

SCHEMA = "fragmentation/v1"
DEFAULT_OUT = "BENCH_fragmentation.json"
# block size used when paging a committed dense cell for comparison (the
# sweep's own paged cells keep their recorded block size)
TRAJECTORY_BLOCK = 16


def compute_rows() -> List[Row]:
    """Part A: the paper's compute-fragmentation figure (unchanged)."""
    rows: List[Row] = []
    ratios = []
    for H in (256, 512, 1024, 1536, 2048, 2560, 2816):
        f = dse.fragmentation(H)
        ratios.append(f["util_loop"] / f["util_mvm_bw"])
        rows.append(Row(
            name=f"fragmentation/H{H}",
            us_per_call=0.0,
            derived=(f"util_loop={f['util_loop']:.3f};"
                     f"util_mvm_bw={f['util_mvm_bw']:.3f};"
                     f"advantage={ratios[-1]:.2f}x"),
        ))
    geo = 1.0
    for r in ratios:
        geo *= r
    geo **= 1.0 / len(ratios)
    rows.append(Row("fragmentation/geomean_advantage", 0.0,
                    f"advantage={geo:.2f}x"))
    return rows


# ---------------------------------------------------------------------------
# Part B: memory-fragmentation trajectories, dense vs paged.
# ---------------------------------------------------------------------------


def memory_cells() -> List["ServingLoadCell"]:  # noqa: F821 (doc name)
    """The committed heavy-tail cells this benchmark trajectories: the
    prompt-distribution sweep's lognormal/bimodal cells (both the rwkv b4
    originals and the paged qwen b8 capacity cells) plus the heavy-decode
    overload mix under FCFS."""
    from repro.configs import SERVING_LOAD_SWEEP

    tails = [c for c in SERVING_LOAD_SWEEP
             if c.prompt_dist in ("lognormal", "bimodal")
             and c.heavy_decode is None]
    heavy = [c for c in SERVING_LOAD_SWEEP
             if c.heavy_decode is not None and c.policy == "fcfs"]
    return tails + heavy


def _trajectory(plan, workload, *, seed: int, duration: float,
                _built) -> Dict[str, object]:
    """Serve ``workload`` under ``plan`` on the virtual clock, sampling
    the slot manager's fragmentation gauges after every engine step.
    Pure function of (plan, workload, seed) — every field is an int."""
    from repro.dist.sharding import make_sharder
    from repro.serving import ServingEngine, drive, profile_items

    cfg, model, params = _built
    sharder = make_sharder(cfg, None, plan.shard_mode)
    engine = ServingEngine.from_plan(plan, params, model=model,
                                     sharder=sharder, seed=seed)
    items = profile_items(workload, vocab_size=cfg.vocab_size, seed=seed,
                          duration=duration)
    ticks: List[int] = []
    tokens: List[int] = []
    resident: List[int] = []
    waste: List[int] = []

    def sample(t: int) -> None:
        ticks.append(int(t))
        tokens.append(int(engine.sm.tokens_in_flight()))
        resident.append(int(engine.sm.bytes_resident()))
        waste.append(int(engine.sm.padding_waste()))

    drive(engine, items, on_tick=sample)
    n = max(1, len(resident))
    return {
        "cache_layout": plan.cache_layout,
        "ticks": ticks,
        "tokens_in_flight": tokens,
        "bytes_resident": resident,
        "padding_waste": waste,
        "peak_bytes": max(resident, default=0),
        "mean_bytes": int(round(sum(resident) / n)),
    }


def run_memory_cell(cell, *, seed: int = 0, duration: float = 32.0,
                    reduced: bool = True, _built=None) -> Dict[str, object]:
    """One before/after pair: the cell's workload served dense and paged.
    Raises if the tokens-in-flight trajectories differ (the schedules are
    contractually bit-exact) or if paged bytes ever exceed dense (the
    acceptance criterion this benchmark exists to pin)."""
    from benchmarks.serving_load import _build
    from repro.plan.plan import parse_cache_layout

    built = _built or _build(cell.arch, reduced)
    block = parse_cache_layout(cell.plan.cache_layout) or TRAJECTORY_BLOCK
    dense_plan = dataclasses.replace(cell.plan, cache_layout="dense")
    paged_plan = dataclasses.replace(cell.plan,
                                     cache_layout=f"paged:{block}")
    duration = cell.duration if cell.duration is not None else duration
    dense = _trajectory(dense_plan, cell.workload, seed=seed,
                        duration=duration, _built=built)
    paged = _trajectory(paged_plan, cell.workload, seed=seed,
                        duration=duration, _built=built)
    if dense["tokens_in_flight"] != paged["tokens_in_flight"]:
        raise RuntimeError(
            f"{cell.name}: dense and paged tokens-in-flight trajectories "
            f"diverged — the paged manager broke the bit-exact schedule "
            f"contract")
    over = [t for t, (p, d) in enumerate(zip(paged["bytes_resident"],
                                             dense["bytes_resident"]))
            if p > d]
    if over:
        raise RuntimeError(
            f"{cell.name}: paged bytes_resident exceeds dense at sample(s) "
            f"{over[:5]} — paging must never cost more memory than the "
            f"worst-case dense columns")
    return {
        "name": cell.name,
        "arch": cell.arch,
        "family": cell.family,
        "max_batch": cell.max_batch,
        "prompt_dist": cell.prompt_dist,
        "heavy_decode": list(cell.heavy_decode) if cell.heavy_decode
        else None,
        "duration": duration,
        "block_size": block,
        "dense": dense,
        "paged": paged,
        # headline: bytes the paged layout leaves free at the dense peak
        "peak_saving_bytes": dense["peak_bytes"] - paged["peak_bytes"],
    }


def memory_sweep(cells: Optional[Sequence] = None, *, seed: int = 0,
                 duration: float = 32.0,
                 reduced: bool = True) -> Dict[str, object]:
    """The full Part-B document (everything in it is deterministic for a
    fixed seed — commit it, diff it)."""
    from benchmarks.serving_load import _build

    cells = list(cells if cells is not None else memory_cells())
    built: Dict[str, tuple] = {}
    out = []
    for cell in cells:
        if cell.arch not in built:
            built[cell.arch] = _build(cell.arch, reduced)
        out.append(run_memory_cell(cell, seed=seed, duration=duration,
                                   reduced=reduced,
                                   _built=built[cell.arch]))
    return {
        "schema": SCHEMA,
        "seed": seed,
        "reduced": reduced,
        "cells": out,
    }


def write(doc: Dict[str, object], path: str = DEFAULT_OUT) -> None:
    with open(path, "w") as f:
        json.dump(doc, f, indent=1)
        f.write("\n")


def _memory_rows(doc: Dict[str, object]) -> Iterator[Row]:
    for c in doc["cells"]:
        d, p = c["dense"], c["paged"]
        saving = (1.0 - c["paged"]["peak_bytes"] / d["peak_bytes"]) \
            if d["peak_bytes"] else 0.0
        yield Row(
            f"fragmentation/mem/{c['name']}",
            0.0,
            f"dense_peak={d['peak_bytes']}B;"
            f"paged_peak={p['peak_bytes']}B;"
            f"paged_mean={p['mean_bytes']}B;"
            f"peak_saving={saving:.2f}",
        )


def run(fast: bool = True, smoke: bool = False) -> Iterator[Row]:
    """benchmarks.run harness entry.  ``smoke`` trajectories one tiny
    heavy-tail cell (shrunk workload, no BENCH write) so tier-1 CI proves
    the dense≡paged schedule contract and the bytes bound still hold;
    real runs sweep every committed heavy-tail cell and refresh
    ``BENCH_fragmentation.json``."""
    yield from compute_rows()
    if smoke:
        cell = next(c for c in memory_cells()
                    if c.family == "rwkv" and c.prompt_dist == "lognormal")
        doc = memory_sweep([cell.with_duration(8.0)])
    else:
        doc = memory_sweep()
        write(doc)
    yield from _memory_rows(doc)


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--out", default=DEFAULT_OUT)
    args = ap.parse_args()
    doc = memory_sweep(seed=args.seed)
    write(doc, args.out)
    print(f"wrote {args.out}: {len(doc['cells'])} cells")
    for c in doc["cells"]:
        print(f"  {c['name']:>40}  dense peak {c['dense']['peak_bytes']:>9}B"
              f"  paged peak {c['paged']['peak_bytes']:>9}B"
              f"  saved {c['peak_saving_bytes']:>9}B")


if __name__ == "__main__":
    main()
