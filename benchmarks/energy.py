"""Paper §5.3 analogue: modeled energy per inference.

Energy model from repro.hw: pJ/FLOP for MXU work, pJ/byte for each level
of the memory hierarchy, plus static power x latency.  Compares the fused
(VMEM-resident weights) execution against a BLAS-style execution whose
intermediates round-trip HBM — the paper's energy-efficiency argument in
numbers.
"""

from __future__ import annotations

from typing import List

from benchmarks.common import Row
from repro import hw
from repro.configs import DEEPBENCH_TASKS
from repro.core import dse
from repro.core.cells import RNNCellConfig


def energy_joules(cfg: RNNCellConfig, fused: bool,
                  spec: hw.HardwareSpec = hw.TPU_V5E) -> float:
    g, H, D, T = cfg.n_gates, cfg.hidden, cfg.d, cfg.timesteps
    flops = 2.0 * g * H * (H + D) * T
    e = flops * spec.pj_per_flop_bf16 * 1e-12
    w_bytes = cfg.weight_bytes()
    plan = dse.best_plan(cfg, spec)
    if fused and plan.resident:
        hbm_bytes = w_bytes + T * (D + H) * 2          # weights once + io
        vmem_bytes = T * w_bytes                       # re-read per step
    else:
        # BLAS-style: gate pre-activations (g*H) round-trip HBM each step,
        # weights re-streamed when not resident
        hbm_bytes = T * (w_bytes + 3 * g * H * 4 + (D + H) * 2)
        vmem_bytes = T * w_bytes
    e += hbm_bytes * spec.pj_per_byte_hbm * 1e-12
    e += vmem_bytes * spec.pj_per_byte_vmem * 1e-12
    e += spec.idle_watts * plan.step_latency_s * T
    return e


def run(fast: bool = True) -> List[Row]:
    rows: List[Row] = []
    for task in DEEPBENCH_TASKS:
        cfg = RNNCellConfig(task.cell, task.hidden, timesteps=task.timesteps,
                            precision="int8")
        ef = energy_joules(cfg, fused=True)
        eb = energy_joules(cfg, fused=False)
        rows.append(Row(
            name=f"energy/{task.name}",
            us_per_call=0.0,
            derived=(f"fused_mj={ef*1e3:.3f};blas_mj={eb*1e3:.3f};"
                     f"saving={eb/ef:.2f}x"),
        ))
    return rows
