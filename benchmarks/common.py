"""Shared benchmark utilities: timing, CSV rows."""

from __future__ import annotations

import dataclasses
import time
from typing import Callable, List

import jax


@dataclasses.dataclass
class Row:
    name: str
    us_per_call: float
    derived: str

    def csv(self) -> str:
        return f"{self.name},{self.us_per_call:.2f},{self.derived}"


def time_jax(fn: Callable, *args, warmup: int = 2, iters: int = 5) -> float:
    """Median wall-time per call in microseconds (blocks on outputs)."""
    for _ in range(warmup):
        jax.block_until_ready(fn(*args))
    times = []
    for _ in range(iters):
        t0 = time.perf_counter()
        jax.block_until_ready(fn(*args))
        times.append(time.perf_counter() - t0)
    times.sort()
    return times[len(times) // 2] * 1e6
