"""Chaos benchmark: serving under deterministic fault storms.

Fault tolerance turned into a regression-trackable benchmark: every cell
replays a seeded Poisson workload (with per-request deadlines, so the
SLO block is live) through the crash-restartable driver
(:func:`repro.serving.faults.drive_resilient`) while a seeded fault
storm (:func:`repro.serving.faults.make_storm`) poisons cache columns,
drops readbacks, fails prefills, stalls slots, and kills the engine
mid-run.  Two cell sections:

* a *severity sweep* on the RWKV arch — the same workload under storms
  of 2 / 4 / 8 faults, tracking how SLO attainment degrades as fault
  pressure rises (gracefully: shed requests are accounted, completed
  requests keep their token-for-token outputs);
* an *arch x layout grid* — rwkv6 (pure recurrent), qwen2.5 (dense
  attention), hymba (hybrid) under dense and ``paged:8`` cache layouts
  at fixed storm severity, proving recovery (scrub / rollback /
  watchdog eviction / checkpoint restart) is layout- and cache-family-
  agnostic.  MoE archs are excluded on purpose: expert routing shares
  capacity across the batch, so a poisoned lane can contaminate its
  co-tenants' outputs (see benchmarks/README.md, "Fault model").

Every cell embeds its resolved :class:`~repro.plan.ServingPlan` *and*
its :class:`~repro.serving.faults.FaultPlan`, so any recorded storm can
be replayed; the ``metrics`` and ``faults`` blocks are computed on the
virtual clock and are a pure function of (cell, seed) — byte-identical
across runs, diffable like every other BENCH trajectory.  The hard
invariant, enforced at run time: ``lost`` is zero in every cell (each
submitted request completes or is accountably shed — faults may cost
latency and SLO, never requests).

The *no-fault twin* guards the other direction: it re-serves a
committed ``BENCH_serving.json`` cell through the ordinary driver and
raises if its ``{plan, metrics}`` differ from the committed bytes —
proving the fault machinery, merely by existing, perturbs nothing.

  PYTHONPATH=src python -m benchmarks.chaos [--full] [--seed N] \\
      [--out BENCH_chaos.json]
"""

from __future__ import annotations

import argparse
import json
import os
import shutil
import tempfile
import time
from typing import Dict, Iterator, List, Optional, Tuple

from benchmarks.common import Row
from benchmarks.serving_load import _build
from repro.checkpoint import CheckpointManager
from repro.dist.sharding import make_sharder
from repro.plan import WorkloadProfile, io as plan_io
from repro.plan.plan import ServingPlan
from repro.serving import (FaultInjector, ServingEngine, VirtualClock,
                           drive_resilient, profile_items)
from repro.serving import metrics as smetrics
from repro.serving.faults import make_storm

SCHEMA = "chaos/v1"
DEFAULT_OUT = "BENCH_chaos.json"

# (family tag, arch) — non-MoE on purpose, see module docstring
CHAOS_ARCHS = (("rwkv", "rwkv6-1.6b"),
               ("dense", "qwen2.5-14b"),
               ("hybrid", "hymba-1.5b"))
SEVERITIES = (2, 4, 8)          # storm sizes for the severity sweep
GRID_SEVERITY = 4               # storm size for the arch x layout grid
LAYOUTS = ("dense", "paged:8")
MAX_BATCH = 4
MAX_LEN = 64

# the committed serving cell the no-fault twin re-serves byte-for-byte
TWIN_CELL = "rwkv6-1.6b/b4/r1"
_SERVING_DOC = os.path.join(os.path.dirname(os.path.abspath(__file__)),
                            os.pardir, "BENCH_serving.json")


def _chaos_plan(arch: str, layout: str, *, reduced: bool) -> ServingPlan:
    return ServingPlan(arch=arch, reduced=reduced, max_batch=MAX_BATCH,
                       max_len=MAX_LEN, cache_layout=layout,
                       retry_budget=3, watchdog_ticks=4,
                       provenance={"source": "benchmarks.chaos"}).resolve()


def _workload(duration: float) -> WorkloadProfile:
    return WorkloadProfile(kind="poisson", rate=0.8, duration=duration,
                           prompt_len=(4, 12), max_new_tokens=(6, 10),
                           deadline_slack=1.5)


def _recovery_ticks(events: List[Dict]) -> Dict[str, float]:
    """Mean ticks-to-recover per fault class, over recovered (non-shed)
    request faults.  kill_engine recovers via restart, not via a
    per-request event, so it reports under ``restarts`` instead."""
    spans: Dict[str, List[int]] = {}
    for e in events:
        if e.get("recovered_at") is None or e.get("shed") or \
                e["kind"] == "kill_engine":
            continue
        spans.setdefault(e["kind"], []).append(
            int(e["recovered_at"]) - int(e["tick"]))
    return {k: sum(v) / len(v) for k, v in sorted(spans.items())}


def run_cell(family: str, arch: str, layout: str, n_faults: int, *,
             duration: float = 32.0, seed: int = 0, reduced: bool = True,
             _built=None) -> Dict[str, object]:
    """One chaos cell: serve the deadline-carrying workload under a
    seeded ``n_faults``-spec storm through the crash-restartable driver.
    Raises RuntimeError if any request is lost — the invariant this
    benchmark exists to track."""
    cfg, model, params = _built or _build(arch, reduced)
    plan = _chaos_plan(arch, layout, reduced=reduced)
    storm = make_storm(duration=int(duration), seed=seed + n_faults,
                       n_faults=n_faults, max_batch=MAX_BATCH)
    sharder = make_sharder(cfg, None, plan.shard_mode)
    engine = ServingEngine.from_plan(plan, params, model=model,
                                     sharder=sharder, seed=seed)
    items = profile_items(_workload(duration), vocab_size=cfg.vocab_size,
                          seed=seed)
    ckpt_dir = tempfile.mkdtemp(prefix="chaos_ckpt_")
    t0 = time.perf_counter()
    try:
        rep = drive_resilient(engine, items, VirtualClock(),
                              injector=FaultInjector(storm),
                              manager=CheckpointManager(ckpt_dir),
                              checkpoint_every=8)
    finally:
        shutil.rmtree(ckpt_dir, ignore_errors=True)
    wall_s = time.perf_counter() - t0
    lost = rep.lost_uids()
    if lost:
        raise RuntimeError(f"chaos cell {arch}/{layout}/storm{n_faults} "
                           f"LOST requests {lost}: the zero-loss "
                           f"invariant is broken")
    if layout != "dense":
        rep.engine.sm.check_invariants()
    agg = smetrics.aggregate(rep.requests, ticks=rep.engine.ticks,
                             util_history=rep.engine.util_history)
    fs = rep.engine.fault_stats()
    return {
        "name": f"{arch}/{layout}/storm{n_faults}",
        "arch": arch,
        "family": family,
        "layout": layout,
        "plan": plan_io.to_dict(plan),
        "fault_plan": storm.to_dict(),   # replayable, like the plan
        "metrics": agg,   # virtual-clock: deterministic for a fixed seed
        "faults": {       # deterministic, same contract as metrics
            "injected": int(fs["injected"]),
            "quarantined": int(fs["quarantined"]),
            "retries": int(fs["retries"]),
            "shed": int(fs["shed"]),
            "watchdog_evictions": int(fs["watchdog_evictions"]),
            "restarts": rep.n_restarts,
            "restart_ticks_lost": rep.restart_ticks_lost,
            "lost": 0,    # enforced above; recorded so diffs say so
            "mean_ticks_to_recover": _recovery_ticks(rep.fault_events),
        },
        "wall": {"seconds": wall_s},   # host-dependent, not deterministic
    }


def check_no_fault_twin(*, reduced: bool = True) -> Dict[str, object]:
    """Re-serve the committed ``TWIN_CELL`` of BENCH_serving.json through
    the ordinary (fault-free) path and fail loudly unless its ``plan``
    and ``metrics`` blocks match the committed bytes — the guard that
    the fault machinery cannot perturb a no-fault run."""
    from benchmarks import serving_load
    from repro.configs import SERVING_LOAD_SWEEP

    with open(_SERVING_DOC) as f:
        committed_doc = json.load(f)
    committed = next(c for c in committed_doc["cells"]
                     if c["name"] == TWIN_CELL)
    cell = next(c for c in SERVING_LOAD_SWEEP if c.name == TWIN_CELL)
    fresh = serving_load.run_cell(cell,
                                  duration=committed_doc["duration"],
                                  seed=committed_doc["seed"],
                                  reduced=reduced)
    for block in ("plan", "metrics"):
        a = json.dumps(committed[block], sort_keys=True)
        b = json.dumps(fresh[block], sort_keys=True)
        if a != b:
            raise RuntimeError(
                f"no-fault twin diverged from committed BENCH_serving "
                f"cell {TWIN_CELL} in its {block!r} block — the fault "
                f"machinery perturbed the fault-free path")
    return {"cell": TWIN_CELL, "matches": True}


def sweep(fast: bool = True, *, seed: int = 0,
          reduced: bool = True) -> Dict[str, object]:
    """The full chaos sweep -> the BENCH_chaos.json document: severity
    sweep + arch x layout grid + the no-fault twin verdict."""
    duration = 32.0 if fast else 128.0
    built: Dict[str, tuple] = {}
    cells: List[Dict[str, object]] = []
    specs: List[Tuple[str, str, str, int]] = []
    for n in SEVERITIES:
        specs.append(("rwkv", "rwkv6-1.6b", "dense", n))
    for family, arch in CHAOS_ARCHS:
        for layout in LAYOUTS:
            if (arch, layout) == ("rwkv6-1.6b", "dense"):
                continue   # the severity sweep already covers it
            specs.append((family, arch, layout, GRID_SEVERITY))
    for family, arch, layout, n in specs:
        if arch not in built:
            built[arch] = _build(arch, reduced)
        cells.append(run_cell(family, arch, layout, n, duration=duration,
                              seed=seed, reduced=reduced,
                              _built=built[arch]))
    return {
        "schema": SCHEMA,
        "seed": seed,
        "mode": "fast" if fast else "full",
        "reduced": reduced,
        "duration": duration,
        "no_fault_twin": check_no_fault_twin(reduced=reduced),
        "cells": cells,
    }


def deterministic_view(doc: Dict[str, object]) -> Dict[str, object]:
    """The seed-determined subset (drops wall timings); two same-seed
    runs must agree on this exactly."""
    return {
        **{k: v for k, v in doc.items() if k != "cells"},
        "cells": [{k: v for k, v in c.items() if k != "wall"}
                  for c in doc["cells"]],
    }


def write(doc: Dict[str, object], path: str = DEFAULT_OUT) -> None:
    with open(path, "w") as f:
        json.dump(doc, f, indent=1)
        f.write("\n")


def _check_fault_surface() -> None:
    """CI guard for the fault subsystem (tier-1, via ``run.py --smoke``):

    * the FaultSpec/FaultPlan JSON grammar round-trips and rejects junk;
    * the serve CLI still exposes the fault/recovery flags;
    * a tiny seeded poison-recover probe is byte-deterministic across two
      runs AND token-identical to the same workload served fault-free —
      the recovery-is-clean contract, proven loudly on every CI run.
    """
    from repro.launch.serve import build_parser
    from repro.serving import FaultPlan, FaultSpec, drive

    # grammar
    plan = FaultPlan((FaultSpec("poison_slot", tick=3, mode="garbage",
                                seed=1),
                      FaultSpec("kill_engine", tick=9)))
    if FaultPlan.from_dict(json.loads(json.dumps(plan.to_dict()))) != plan:
        raise RuntimeError("FaultPlan no longer round-trips through JSON")
    for bad in ({"faults": [{"kind": "melt_tpu", "tick": 1}]},
                {"faults": [], "extra": 1},
                {"schema": "fault_plan/v9", "faults": []}):
        try:
            FaultPlan.from_dict(bad)
        except ValueError:
            pass
        else:
            raise RuntimeError(f"FaultPlan.from_dict accepted junk {bad}")

    # CLI surface
    flags = {o for a in build_parser()._actions for o in a.option_strings}
    needed = {"--fault-spec", "--checkpoint-dir", "--checkpoint-every",
              "--retry-budget", "--watchdog-ticks"}
    if not needed <= flags:
        raise RuntimeError(f"launch/serve.py no longer exposes "
                           f"{sorted(needed - flags)}")

    # poison-recover probe: deterministic AND clean
    cfg, model, params = _build("rwkv6-1.6b", reduced=True)
    sharder = make_sharder(cfg, None, "decode")
    items = profile_items(_workload(8.0), vocab_size=cfg.vocab_size, seed=0)
    probe = FaultPlan((FaultSpec("poison_slot", tick=3, mode="nan"),))

    def one_run():
        eng = ServingEngine.from_plan(
            _chaos_plan("rwkv6-1.6b", "dense", reduced=True), params,
            model=model, sharder=sharder)
        rep = drive_resilient(eng, items, VirtualClock(),
                              injector=FaultInjector(probe))
        if rep.lost_uids() or rep.shed_uids:
            raise RuntimeError("poison-recover probe lost/shed a request")
        return json.dumps({"out": [(r.uid, r.output) for r in rep.requests],
                           "events": rep.fault_events,
                           "stats": eng.fault_stats()}, sort_keys=True)

    a, b = one_run(), one_run()
    if a != b:
        raise RuntimeError("same-seed chaos probe runs emitted different "
                           "bytes; fault injection lost determinism")
    clean = ServingEngine.from_plan(
        _chaos_plan("rwkv6-1.6b", "dense", reduced=True), params,
        model=model, sharder=sharder)
    base = {r.uid: r.output for r in drive(clean, items, VirtualClock())}
    got = {u: o for u, o in json.loads(a)["out"]}
    if {int(k): v for k, v in got.items()} != base:
        raise RuntimeError("poison-recover probe outputs differ from the "
                           "fault-free run; recovery is not clean")


def run(fast: bool = True, smoke: bool = False) -> Iterator[Row]:
    """benchmarks.run harness entry.  ``smoke`` checks the fault-plan
    grammar, the CLI flags, and the poison-recover determinism/cleanness
    probe, then serves one tiny storm cell — and never touches
    BENCH_chaos.json (the tier-1 CI guard)."""
    if smoke:
        _check_fault_surface()
        built = _build("rwkv6-1.6b", reduced=True)
        doc = {"cells": [run_cell("rwkv", "rwkv6-1.6b", "dense", 3,
                                  duration=10.0, _built=built)]}
    else:
        doc = sweep(fast=fast)
        write(doc)
    for c in doc["cells"]:
        m, f = c["metrics"], c["faults"]
        us_per_tok = (c["wall"]["seconds"] / m["tokens"] * 1e6
                      if m["tokens"] else 0.0)
        slo = (f" slo={m['slo']['attainment']:.2f}" if "slo" in m else "")
        yield Row(
            f"chaos/{c['name']}",
            us_per_tok,
            f"injected={f['injected']} quarantined={f['quarantined']}"
            f" retries={f['retries']} shed={f['shed']}"
            f" restarts={f['restarts']} lost=0" + slo)


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--full", action="store_true",
                    help="longer workloads (128 clock units vs 32)")
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--out", default=DEFAULT_OUT)
    ap.add_argument("--full-size", action="store_true",
                    help="full-size configs (default: reduced)")
    args = ap.parse_args()
    doc = sweep(fast=not args.full, seed=args.seed,
                reduced=not args.full_size)
    write(doc, args.out)
    print(f"wrote {args.out}: {len(doc['cells'])} cells "
          f"(no-fault twin: {doc['no_fault_twin']})")
    for c in doc["cells"]:
        f, m = c["faults"], c["metrics"]
        slo = (f"  slo {m['slo']['attainment']:.2f}" if "slo" in m else "")
        rec = ", ".join(f"{k}={v:.1f}t"
                        for k, v in f["mean_ticks_to_recover"].items())
        print(f"  {c['name']:>28}  inj {f['injected']}  quar "
              f"{f['quarantined']}  shed {f['shed']}  restarts "
              f"{f['restarts']}{slo}  recover[{rec}]")


if __name__ == "__main__":
    main()
