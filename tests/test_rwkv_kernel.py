"""Fused RWKV6 step kernel vs oracle — shape sweep + consistency with the
model's chunked train-time form."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels.rwkv_step.ref import rwkv6_step_ref
from repro.kernels.rwkv_step.rwkv_step import rwkv6_step
from repro.models.recurrence import chunked_linear_attention

SWEEP = [
    (1, 2, 8, 8, 3),     # B, H, K, V, T
    (2, 4, 16, 16, 5),
    (1, 8, 64, 64, 2),
]


def _inputs(B, H, K, V, T, seed=0):
    rng = np.random.default_rng(seed)
    mk = lambda *s: jnp.asarray(rng.standard_normal(s), jnp.float32)
    r, k, w = mk(T, B, H, K), mk(T, B, H, K), -jnp.abs(mk(T, B, H, K))
    v = mk(T, B, H, V)
    u = mk(H, K)
    s0 = mk(B, H, K, V)
    return r, k, v, w, u, s0


@pytest.mark.parametrize("B,H,K,V,T", SWEEP)
def test_kernel_vs_ref(B, H, K, V, T):
    r, k, v, w, u, s0 = _inputs(B, H, K, V, T)
    y, sT = rwkv6_step(r, k, v, w, u, s0, interpret=True)
    y_ref, sT_ref = rwkv6_step_ref(r, k, v, w, u, s0)
    np.testing.assert_allclose(np.asarray(y, np.float32),
                               np.asarray(y_ref, np.float32),
                               atol=2e-2, rtol=2e-2)
    np.testing.assert_allclose(np.asarray(sT), np.asarray(sT_ref),
                               atol=1e-4, rtol=1e-4)


@pytest.mark.parametrize("bh", [1, 2, 4])
def test_head_tile_is_bit_exact(bh):
    """PR 9: the grid's head axis (H // bh programs) only re-blocks
    independent per-head recurrences — every head tile must produce the
    exact same bits as the whole-H run."""
    B, H, K, V, T = 2, 4, 16, 16, 5
    r, k, v, w, u, s0 = _inputs(B, H, K, V, T, seed=1)
    y_full, sT_full = rwkv6_step(r, k, v, w, u, s0, bh=H, interpret=True)
    y, sT = rwkv6_step(r, k, v, w, u, s0, bh=bh, interpret=True)
    assert (np.asarray(y) == np.asarray(y_full)).all()
    assert (np.asarray(sT) == np.asarray(sT_full)).all()


def test_kernel_matches_chunked_train_form():
    """Serving through the fused kernel == the chunked parallel form used
    at train/prefill (the same invariant the LM consistency test checks,
    here at kernel granularity)."""
    B, H, K, V, T = 1, 2, 8, 8, 12
    r, k, v, w, u, s0 = _inputs(B, H, K, V, T, seed=3)
    y_k, sT_k = rwkv6_step(r, k, v, w, u, s0, interpret=True)
    tbh = lambda x: x.transpose(1, 2, 0, 3)          # (T,B,H,·) -> (B,H,T,·)
    y_c, sT_c = chunked_linear_attention(
        tbh(r), tbh(k), tbh(v), tbh(w), chunk=4, convention="exclusive",
        u=u, initial_state=s0)
    np.testing.assert_allclose(
        np.asarray(y_k, np.float32).transpose(1, 2, 0, 3),
        np.asarray(y_c, np.float32), atol=2e-2, rtol=2e-2)
    np.testing.assert_allclose(np.asarray(sT_k), np.asarray(sT_c),
                               atol=1e-3, rtol=1e-3)
