"""The on-device decode hot path: fused sample-in-step equivalence,
multi-tick chunks, bucketed batched prefill, and the sync/compile-count
contracts of ISSUE 3 (engine side; the model-side masking equivalence is
in test_bucketed_prefill_* below)."""

import math

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.dist.sharding import Sharder
from repro.models.lm import build_model
from repro.serving import ServingEngine, VirtualClock, drive, make_workload
from repro.serving.sampler import SamplerConfig, sample, split_and_sample
from repro.testing import reduced_config

NOSH = Sharder(None, {})


@pytest.fixture(scope="module")
def setup():
    cfg = reduced_config("rwkv6-1.6b")
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    return cfg, model, params


def _engine(setup, **kw):
    cfg, model, params = setup
    kw.setdefault("max_batch", 2)
    kw.setdefault("max_len", 32)
    return ServingEngine(model, params, NOSH, **kw)


# --------------------------------------------------- fused sample-in-step


@pytest.mark.parametrize("sampler", [
    SamplerConfig(),                                  # greedy
    SamplerConfig(temperature=0.8, top_k=5),          # stochastic
])
def test_fused_sample_matches_host_sampler(setup, sampler):
    """The engine's on-device sampling consumes the same key stream and
    produces the same tokens as the host-side prefill/decode/sample
    sequence replayed manually with model calls + split_and_sample."""
    cfg, model, params = setup
    prompt = [5, 9, 3, 7, 2]
    eng = _engine(setup, max_batch=1, seed=11, sampler=sampler)
    r = eng.submit(list(prompt), max_new_tokens=5)
    eng.run()
    assert r.done and len(r.output) == 5

    # manual replay: identical batch layout (bucketed, batch = max_batch)
    key = jax.random.PRNGKey(11)
    S = eng.bucket(len(prompt))
    toks = np.zeros((1, S), np.int32)
    toks[0, :len(prompt)] = prompt
    batch = {"tokens": jnp.asarray(toks),
             "lengths": jnp.asarray([len(prompt)], jnp.int32)}
    cache, logits = model.prefill(params, batch, NOSH, max_len=32)
    key, tok = split_and_sample(key, logits, sampler)
    out = [int(tok[0])]
    for _ in range(4):
        cache, logits = model.decode_step(params, cache, tok, NOSH)
        key, tok = split_and_sample(key, logits, sampler)
        out.append(int(tok[0]))
    assert r.output == out


def test_sample_helper_matches_sample(setup):
    """split_and_sample is literally split + sample with the same key."""
    logits = jax.random.normal(jax.random.PRNGKey(3), (2, 17))
    for cfg in (SamplerConfig(), SamplerConfig(temperature=1.1, top_k=4)):
        key = jax.random.PRNGKey(5)
        k2, sub = jax.random.split(key)
        new_key, toks = split_and_sample(key, logits, cfg)
        assert (np.asarray(toks) == np.asarray(sample(logits, sub, cfg))).all()
        assert (np.asarray(new_key) == np.asarray(k2)).all()


# --------------------------------------------------- decode_many == k x step


def _run_closed_loop(setup, sync_every, prompts, max_new, sampler):
    eng = _engine(setup, seed=123, sync_every=sync_every, sampler=sampler)
    reqs = [eng.submit(list(p), max_new_tokens=m) for p, m in
            zip(prompts, max_new)]
    eng.run()
    return ([(r.output, r.t_submit, r.t_admit, r.t_first, r.t_done)
             for r in reqs], eng.util_history, eng.ticks)


def test_decode_many_equals_k_steps_closed_loop(setup):
    """A sync_every=8 engine produces the same tokens, tick stamps, and
    per-tick util history as sync_every=1 on a closed-loop workload: tick
    attribution inside a chunk is exact, and the chunk breaks at a freed
    slot whenever the queue is non-empty."""
    prompts = [[1, 2, 3], [4, 5, 6, 7, 8], [9, 10], [11, 12, 13, 14],
               [15, 16, 17]]
    max_new = [6, 3, 9, 4, 7]
    sampler = SamplerConfig(temperature=0.7, top_k=7)
    a = _run_closed_loop(setup, 1, prompts, max_new, sampler)
    b = _run_closed_loop(setup, 8, prompts, max_new, sampler)
    assert a == b


def test_decode_many_equals_k_steps_open_loop(setup):
    """Under drive() on a virtual clock, arrival-bounded chunks make the
    whole schedule independent of sync_every — the fused multi-tick loop
    is a pure wall-clock optimization."""
    cfg = setup[0]

    def one(sync_every):
        eng = _engine(setup, seed=9, sync_every=sync_every)
        items = make_workload("mmpp", rate=0.4, duration=16.0, seed=4,
                              vocab_size=cfg.vocab_size, prompt_len=(2, 6),
                              max_new_tokens=(2, 8))
        reqs = drive(eng, items, VirtualClock())
        return ([(r.output, r.t_submit, r.t_admit, r.t_first, r.t_done)
                 for r in reqs], eng.util_history, eng.stats()["mean_util"])

    assert one(1) == one(4)


def test_sync_count_bound(setup):
    """The acceptance contract: steady-state decode performs <= 1 host
    sync per sync_every ticks (plus one per prefill launch)."""
    k = 8
    eng = _engine(setup, max_batch=4, sync_every=k)
    reqs = [eng.submit([1 + i, 2, 3], max_new_tokens=24) for i in range(4)]
    eng.run()
    assert all(r.done for r in reqs)
    s = eng.stats()
    assert s["host_syncs"] <= s["prefill_calls"] + math.ceil(s["ticks"] / k)
    # all four same-bucket admissions prefilled in ONE batched call
    assert s["prefill_calls"] == 1
    assert s["decode_chunks"] == math.ceil(s["ticks"] / k)


# --------------------------------------------------- bucketed prefill


@pytest.mark.parametrize("arch", ["rwkv6-1.6b", "qwen2.5-14b", "hymba-1.5b"])
def test_bucketed_prefill_matches_sequential(arch):
    """One right-padded batched prefill == per-prompt exact-length batch-1
    prefills: logits at the true last token, cache lengths, and the next
    decode step from the scattered rows (attention masking + identity-
    masked recurrent/ssd state + ring-window cache layout)."""
    cfg = reduced_config(arch)
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    rng = np.random.default_rng(0)
    lens = [3, 5, 9]
    S, ML = 16, 24
    toks = np.zeros((len(lens), S), np.int32)
    prompts = []
    for i, L in enumerate(lens):
        p = rng.integers(0, cfg.vocab_size, L)
        prompts.append(p)
        toks[i, :L] = p
    batch = {"tokens": jnp.asarray(toks),
             "lengths": jnp.asarray(lens, jnp.int32)}
    cacheB, logitsB = model.prefill(params, batch, NOSH, max_len=ML)
    for i, p in enumerate(prompts):
        c1, l1 = model.prefill(params, {"tokens": jnp.asarray([p], jnp.int32)},
                               NOSH, max_len=ML)
        assert int(cacheB["lengths"][i]) == len(p)
        scale = float(jnp.max(jnp.abs(l1))) + 1e-9
        rel = float(jnp.max(jnp.abs(logitsB[i] - l1[0]))) / scale
        assert rel < 2e-2, f"{arch} len={len(p)}: prefill rel err {rel}"
        # continue decoding from the padded batch's cache row
        row = {"blocks": jax.tree.map(lambda a: a[:, i:i + 1],
                                      cacheB["blocks"]),
               "lengths": cacheB["lengths"][i:i + 1]}
        t = jnp.argmax(l1, axis=-1).astype(jnp.int32)
        _, dB = model.decode_step(params, row, t, NOSH)
        _, d1 = model.decode_step(params, c1, t, NOSH)
        rel = float(jnp.max(jnp.abs(dB - d1))) / scale
        assert rel < 2e-2, f"{arch} len={len(p)}: decode rel err {rel}"


def test_engine_bucketed_matches_batch1(setup):
    """End-to-end: the bucketed engine serves a mixed-length greedy
    workload with the same outputs and stamps as the legacy exact-length
    batch-1 admission path."""
    prompts = [[1, 2, 3], [4, 5, 6, 7, 8, 9, 10], [11, 12],
               [13, 14, 15, 16, 17, 18]]

    def serve(bucketed):
        eng = _engine(setup, bucketed_prefill=bucketed)
        reqs = [eng.submit(list(p), max_new_tokens=4) for p in prompts]
        eng.run()
        return [(r.output, r.t_admit, r.t_done) for r in reqs]

    assert serve(True) == serve(False)


def test_prefill_recompile_bound(setup):
    """Mixed-length arrivals trigger at most n_buckets prefill compiles in
    bucketed mode; the legacy path compiles per distinct length."""
    cfg = setup[0]
    rng = np.random.default_rng(7)
    lengths = [int(rng.integers(2, 21)) for _ in range(12)]

    def serve(bucketed):
        eng = _engine(setup, max_len=32, bucketed_prefill=bucketed)
        for L in lengths:
            eng.submit([int(x) for x in rng.integers(0, cfg.vocab_size, L)],
                       max_new_tokens=2)
            eng.step()   # interleave admits so groups vary
        eng.run()
        return eng

    eng = serve(True)
    # max_len=32 -> buckets (8, 16, 31)
    assert eng.bucket_lengths == [8, 16, 31]
    assert eng.stats()["prefill_compiles"] <= len(eng.bucket_lengths)
    cache_size = getattr(eng._prefill, "_cache_size", None)
    if cache_size is not None:   # cross-check against the real jit cache
        assert cache_size() <= len(eng.bucket_lengths)
    legacy = serve(False)
    assert legacy.stats()["prefill_compiles"] == len(set(lengths))


def test_overlap_prefill_schedule_identical_fewer_syncs(setup):
    """Overlapped admission (prefill + first-token sample + slot scatter +
    decode chunk dispatched with no host sync in between) produces the
    bit-identical schedule of the synchronous path — same tokens, stamps,
    util — while performing strictly fewer blocking readbacks."""
    cfg = setup[0]

    def serve(overlap):
        eng = _engine(setup, max_batch=4, seed=3, overlap_prefill=overlap,
                      sampler=SamplerConfig(temperature=0.9, top_k=6))
        items = make_workload("poisson", rate=0.9, duration=24.0, seed=5,
                              vocab_size=cfg.vocab_size, prompt_len=(2, 14),
                              max_new_tokens=(2, 8))
        reqs = drive(eng, items, VirtualClock())
        sched = [(r.output, r.t_submit, r.t_admit, r.t_first, r.t_done)
                 for r in reqs]
        return sched, eng.util_history, eng.stats()

    sched_o, util_o, stats_o = serve(True)
    sched_s, util_s, stats_s = serve(False)
    assert sched_o == sched_s
    assert util_o == util_s
    assert stats_o["prefill_calls"] == stats_s["prefill_calls"]
    assert stats_o["host_syncs"] < stats_s["host_syncs"]
    # sync path blocks once per prefill launch on top of the chunk syncs
    assert (stats_s["host_syncs"] - stats_o["host_syncs"]
            == stats_s["prefill_calls"])


def test_overlap_falls_back_for_instant_finish_rounds(setup):
    """Admission rounds that may retire at the prefill token (eos_id set,
    or max_new_tokens == 1) take the synchronous path so instant admits
    still free slots for same-tick retries; outputs are unaffected."""
    eng = _engine(setup, max_batch=1)
    reqs = [eng.submit([1, 2, 3 + i], max_new_tokens=1) for i in range(3)]
    eng.run()
    assert all(r.done and len(r.output) == 1 for r in reqs)
    assert eng.stats()["instant_admits"] == 3
    assert [r.t_done for r in reqs] == [0, 0, 0]   # same-tick slot reuse


def test_spf_policy_admits_shortest_first(setup):
    """policy='spf' admits the shortest queued prompt when a slot frees;
    FCFS admits in arrival order."""
    long1, long2, short = [1] * 10, [2] * 8, [3, 4]

    def order(policy):
        eng = _engine(setup, max_batch=1, policy=policy)
        a = eng.submit(list(long1), max_new_tokens=3)   # occupies the slot
        b = eng.submit(list(long2), max_new_tokens=3)   # queued first
        c = eng.submit(list(short), max_new_tokens=3)   # queued second
        eng.run()
        assert all(r.done for r in (a, b, c))
        return (b.t_admit, c.t_admit)

    b_f, c_f = order("fcfs")
    assert b_f < c_f                  # arrival order
    b_s, c_s = order("spf")
    assert c_s < b_s                  # shortest first

    with pytest.raises(ValueError, match="policy"):
        _engine(setup, policy="lifo")
