"""End-to-end behaviour tests for the paper's system.

1.  The paper's pipeline: DeepBench-style RNN serving through the fused
    kernel path vs the BLAS baseline — same outputs, and the DSE picks a
    resident plan for on-chip-fit sizes.
2.  The framework pipeline: data -> train steps (loss goes down) ->
    checkpoint -> serve the trained weights through the engine.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import hw
from repro.core import dse
from repro.core.cells import RNNCellConfig, init_weights, quantize_weights, serve
from repro.models.lm import build_model
from repro.serving import ServingEngine
from repro.testing import reduced_config, smoke_shape
from repro.train.loop import TrainLoopConfig, train


def test_deepbench_style_serving_kernel_vs_blas(key):
    cfg = RNNCellConfig("lstm", 256, timesteps=10, batch=1, precision="int8")
    w = quantize_weights(cfg, init_weights(cfg, key))
    x = jax.random.normal(jax.random.fold_in(key, 1), (10, 1, 256),
                          jnp.bfloat16)
    y_kernel = serve(cfg, w, x, impl="kernel")
    y_blas = serve(cfg, w, x, impl="blas")
    np.testing.assert_allclose(np.asarray(y_kernel, np.float32),
                               np.asarray(y_blas, np.float32),
                               atol=3e-2, rtol=3e-2)
    plan = dse.best_plan(cfg)
    assert plan.resident  # H=256 int8 weights trivially fit VMEM
    assert plan.vmem_bytes < hw.vmem_budget()


@pytest.mark.slow
def test_train_then_serve_pipeline(tmp_path, nosharder):
    # hymba starts far from the unigram entropy (norm-fused init), so a
    # dozen steps reliably reduce the loss even on synthetic data
    arch = "hymba-1.5b"
    model = build_model(reduced_config(arch))
    shape = smoke_shape("train", seq=32, batch=4)
    loop_cfg = TrainLoopConfig(total_steps=12, checkpoint_every=6,
                               checkpoint_dir=str(tmp_path / "ck"),
                               log_every=100, async_checkpoint=False)
    state, history = train(model, shape, nosharder, loop_cfg)
    losses = [h["loss"] for h in history]
    assert losses[-1] < losses[0], (losses[0], losses[-1])

    engine = ServingEngine(model, state["params"], nosharder,
                           max_batch=2, max_len=48)
    reqs = [engine.submit([1, 2, 3, 4], max_new_tokens=4) for _ in range(3)]
    engine.run()
    assert all(r.done and len(r.output) == 4 for r in reqs)
