"""Shared fixtures.  NOTE: no XLA_FLAGS here — unit/smoke tests must see
the single real CPU device; only the dry-run subprocess tests force fake
device counts (in their own subprocess env)."""

import jax
import pytest

try:
    import hypothesis  # noqa: F401
except ImportError:  # no extra deps in the image: install the replay stub
    from repro import _hypothesis_stub
    _hypothesis_stub.install()

from repro.dist.sharding import Sharder


@pytest.fixture(scope="session")
def nosharder() -> Sharder:
    return Sharder(None, {})


@pytest.fixture(scope="session")
def key():
    return jax.random.PRNGKey(0)
