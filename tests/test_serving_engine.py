"""Continuous-batching engine: correctness of slot multiplexing."""

import jax
import numpy as np
import pytest

from repro.models.lm import build_model
from repro.serving import ServingEngine
from repro.serving.sampler import SamplerConfig
from repro.testing import reduced_config


@pytest.fixture(scope="module")
def setup():
    cfg = reduced_config("rwkv6-1.6b")
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    from repro.dist.sharding import Sharder
    return cfg, model, params, Sharder(None, {})


def _engine(setup, **kw):
    cfg, model, params, sharder = setup
    return ServingEngine(model, params, sharder, max_batch=2, max_len=32,
                         **kw)


def test_all_requests_complete(setup):
    eng = _engine(setup)
    reqs = [eng.submit([1, 2, 3, 4 + i], max_new_tokens=5) for i in range(5)]
    eng.run()
    assert all(r.done for r in reqs)
    assert all(len(r.output) == 5 for r in reqs)


def test_batched_equals_sequential(setup):
    """Greedy decoding of a request must not depend on its co-tenants."""
    cfg, model, params, sharder = setup
    prompt = [5, 9, 3, 7]
    solo = ServingEngine(model, params, sharder, max_batch=1, max_len=32)
    r_solo = solo.submit(list(prompt), max_new_tokens=6)
    solo.run()

    multi = ServingEngine(model, params, sharder, max_batch=2, max_len=32)
    r_a = multi.submit(list(prompt), max_new_tokens=6)
    r_b = multi.submit([2, 4, 6, 8, 10], max_new_tokens=6)
    multi.run()
    assert r_a.output == r_solo.output


def test_slot_reuse_after_completion(setup):
    eng = _engine(setup)
    first = [eng.submit([1, 2, 3], max_new_tokens=3) for _ in range(2)]
    eng.run()
    second = eng.submit([4, 5, 6], max_new_tokens=3)
    eng.run()
    assert second.done and len(second.output) == 3


def test_max_len_truncates(setup):
    eng = _engine(setup)
    r = eng.submit(list(range(1, 20)), max_new_tokens=100)
    eng.run()
    assert r.done
    assert len(r.output) <= 32
