"""Continuous-batching engine: correctness of slot multiplexing."""

import jax
import numpy as np
import pytest

from repro.models.lm import build_model
from repro.serving import ServingEngine
from repro.serving.sampler import SamplerConfig
from repro.testing import reduced_config


@pytest.fixture(scope="module")
def setup():
    cfg = reduced_config("rwkv6-1.6b")
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    from repro.dist.sharding import Sharder
    return cfg, model, params, Sharder(None, {})


def _engine(setup, **kw):
    cfg, model, params, sharder = setup
    return ServingEngine(model, params, sharder, max_batch=2, max_len=32,
                         **kw)


def test_all_requests_complete(setup):
    eng = _engine(setup)
    reqs = [eng.submit([1, 2, 3, 4 + i], max_new_tokens=5) for i in range(5)]
    eng.run()
    assert all(r.done for r in reqs)
    assert all(len(r.output) == 5 for r in reqs)


def test_batched_equals_sequential(setup):
    """Greedy decoding of a request must not depend on its co-tenants."""
    cfg, model, params, sharder = setup
    prompt = [5, 9, 3, 7]
    solo = ServingEngine(model, params, sharder, max_batch=1, max_len=32)
    r_solo = solo.submit(list(prompt), max_new_tokens=6)
    solo.run()

    multi = ServingEngine(model, params, sharder, max_batch=2, max_len=32)
    r_a = multi.submit(list(prompt), max_new_tokens=6)
    r_b = multi.submit([2, 4, 6, 8, 10], max_new_tokens=6)
    multi.run()
    assert r_a.output == r_solo.output


def test_slot_reuse_after_completion(setup):
    eng = _engine(setup)
    first = [eng.submit([1, 2, 3], max_new_tokens=3) for _ in range(2)]
    eng.run()
    second = eng.submit([4, 5, 6], max_new_tokens=3)
    eng.run()
    assert second.done and len(second.output) == 3


def test_max_len_truncates(setup):
    eng = _engine(setup)
    r = eng.submit(list(range(1, 20)), max_new_tokens=100)
    eng.run()
    assert r.done
    assert len(r.output) <= 32


# ------------------------------------------------- prompt-capacity boundary
# Regression tests for the old silent truncation: _admit used to drop the
# prompt tail to max_len - max_new_tokens - 1 tokens with no signal.


def test_prompt_at_capacity_accepted_and_fully_used(setup):
    """A prompt of exactly max_len - 1 tokens is admitted whole: its first
    greedy token matches the same prompt on a roomier engine, so the tail
    provably reached the model."""
    cfg, model, params, sharder = setup
    prompt = [(7 * i) % cfg.vocab_size for i in range(31)]   # max_len - 1
    tight = ServingEngine(model, params, sharder, max_batch=1, max_len=32)
    r_tight = tight.submit(list(prompt), max_new_tokens=4)
    tight.run()
    roomy = ServingEngine(model, params, sharder, max_batch=1, max_len=64)
    r_roomy = roomy.submit(list(prompt), max_new_tokens=4)
    roomy.run()
    assert r_tight.done and not r_tight.truncated
    assert r_tight.output[0] == r_roomy.output[0]
    # 4 requested tokens can't follow a 31-token prompt in a 32-slot
    # cache: flagged at submit, not silently cut at the end of the run
    assert r_tight.capped and len(r_tight.output) == 2
    assert not r_roomy.capped and len(r_roomy.output) == 4


def test_prompt_past_capacity_rejected(setup):
    eng = _engine(setup)   # max_len = 32
    with pytest.raises(ValueError, match="max_len"):
        eng.submit(list(range(32)), max_new_tokens=4)
    with pytest.raises(ValueError, match="empty"):
        eng.submit([], max_new_tokens=4)
    with pytest.raises(ValueError, match="max_new_tokens"):
        eng.submit([1, 2], max_new_tokens=0)
    # the engine stays serviceable after a rejected submit
    ok = eng.submit(list(range(31)), max_new_tokens=2)
    eng.run()
    assert ok.done


def test_prompt_past_capacity_opt_in_truncation(setup, caplog):
    cfg, model, params, sharder = setup
    eng = ServingEngine(model, params, sharder, max_batch=1, max_len=32,
                        truncate_prompts=True)
    with caplog.at_level("WARNING", logger="repro.serving"):
        r = eng.submit(list(range(40)), max_new_tokens=2)
    assert r.truncated and len(r.prompt) == 31
    assert any("truncating prompt" in m for m in caplog.messages)
    eng.run()
    assert r.done
