"""Property tests for the chunked affine recurrence (hypothesis-driven):
the chunked closed form must agree with the step recurrence for arbitrary
decays/inputs, any chunk size, both conventions."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.models.recurrence import (
    chunked_linear_attention,
    linear_attention_step,
)

F32 = jnp.float32


def _step_reference(q, k, v, log_decay, convention, u=None, s0=None):
    B, H, T, K = q.shape
    V = v.shape[-1]
    S = np.zeros((B, H, K, V), np.float64) if s0 is None else \
        np.asarray(s0, np.float64).copy()
    q, k, v = (np.asarray(x, np.float64) for x in (q, k, v))
    d = np.exp(np.broadcast_to(np.asarray(log_decay, np.float64),
                               (B, H, T, K)))
    ys = np.zeros((B, H, T, V))
    for t in range(T):
        kv = k[:, :, t, :, None] * v[:, :, t, None, :]
        if convention == "exclusive":
            read = S + (u[None, :, :, None] * kv if u is not None else 0.0)
            S = d[:, :, t, :, None] * S + kv
        else:
            S = d[:, :, t, :, None] * S + kv
            read = S
        ys[:, :, t] = np.einsum("bhk,bhkv->bhv", q[:, :, t], read)
    return ys, S


@settings(max_examples=20, deadline=None)
@given(
    T=st.integers(2, 17),
    chunk=st.sampled_from([2, 4, 8]),
    convention=st.sampled_from(["exclusive", "inclusive"]),
    scalar_decay=st.booleans(),
    with_u=st.booleans(),
    seed=st.integers(0, 2**16),
)
def test_chunked_matches_step(T, chunk, convention, scalar_decay, with_u,
                              seed):
    B, H, K, V = 1, 2, 4, 3
    rng = np.random.default_rng(seed)
    q = rng.standard_normal((B, H, T, K)).astype(np.float32)
    k = rng.standard_normal((B, H, T, K)).astype(np.float32)
    v = rng.standard_normal((B, H, T, V)).astype(np.float32)
    ld_shape = (B, H, T, 1) if scalar_decay else (B, H, T, K)
    log_decay = -np.abs(rng.standard_normal(ld_shape)).astype(np.float32) * 2
    u = (rng.standard_normal((H, K)).astype(np.float32)
         if with_u and convention == "exclusive" else None)
    s0 = rng.standard_normal((B, H, K, V)).astype(np.float32)

    y, S = chunked_linear_attention(
        jnp.asarray(q), jnp.asarray(k), jnp.asarray(v), jnp.asarray(log_decay),
        chunk=chunk, convention=convention,
        u=None if u is None else jnp.asarray(u),
        initial_state=jnp.asarray(s0))
    y_ref, S_ref = _step_reference(q, k, v, log_decay, convention, u, s0)
    np.testing.assert_allclose(np.asarray(y), y_ref, atol=2e-4, rtol=2e-3)
    np.testing.assert_allclose(np.asarray(S), S_ref, atol=2e-4, rtol=2e-3)


def test_single_step_matches_reference():
    B, H, K, V = 2, 3, 4, 5
    rng = np.random.default_rng(0)
    s0 = rng.standard_normal((B, H, K, V)).astype(np.float32)
    q = rng.standard_normal((B, H, K)).astype(np.float32)
    k = rng.standard_normal((B, H, K)).astype(np.float32)
    v = rng.standard_normal((B, H, V)).astype(np.float32)
    ld = -np.abs(rng.standard_normal((B, H, K))).astype(np.float32)
    u = rng.standard_normal((H, K)).astype(np.float32)
    for conv, uu in [("exclusive", u), ("inclusive", None)]:
        y, S = linear_attention_step(
            jnp.asarray(s0), jnp.asarray(q), jnp.asarray(k), jnp.asarray(v),
            jnp.asarray(ld), convention=conv,
            u=None if uu is None else jnp.asarray(uu))
        y_ref, S_ref = _step_reference(
            q[:, :, None], k[:, :, None], v[:, :, None], ld[:, :, None],
            conv, uu, s0)
        np.testing.assert_allclose(np.asarray(y), y_ref[:, :, 0], atol=1e-5,
                                   rtol=1e-4)
        np.testing.assert_allclose(np.asarray(S), S_ref, atol=1e-5, rtol=1e-4)


def test_extreme_decay_is_stable():
    """Channels that decay to ~zero within a chunk must not produce NaN/inf
    (the clamped-log path)."""
    B, H, T, K, V = 1, 1, 16, 4, 4
    rng = np.random.default_rng(1)
    q = jnp.asarray(rng.standard_normal((B, H, T, K)), F32)
    k = jnp.asarray(rng.standard_normal((B, H, T, K)), F32)
    v = jnp.asarray(rng.standard_normal((B, H, T, V)), F32)
    log_decay = jnp.full((B, H, T, K), -50.0, F32)  # instant forgetting
    y, S = chunked_linear_attention(q, k, v, log_decay, chunk=8,
                                    convention="inclusive")
    assert bool(jnp.all(jnp.isfinite(y)))
    assert bool(jnp.all(jnp.isfinite(S)))
