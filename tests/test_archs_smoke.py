"""Per-architecture smoke tests (assignment deliverable f).

Each assigned architecture instantiates a REDUCED config of the same
family and runs one forward/train step on CPU, asserting output shapes and
the absence of NaNs.  The FULL configs are exercised only through the
dry-run (ShapeDtypeStructs, no allocation).
"""

import jax
import jax.numpy as jnp
import pytest

from repro.configs import ARCHS
from repro.models import lm as lm_lib
from repro.models.inputs import make_batch
from repro.optim import AdamW, cosine_schedule
from repro.optim.adamw import init_state
from repro.testing import reduced_config, smoke_shape
from repro.train.step import make_train_step

ALL_ARCHS = sorted(ARCHS)


@pytest.mark.parametrize("arch", ALL_ARCHS)
def test_forward_loss_shapes_and_finite(arch, nosharder):
    cfg = reduced_config(arch)
    model = lm_lib.build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    shape = smoke_shape("train", seq=16, batch=2)
    batch = make_batch(cfg, shape)
    loss, metrics = model.loss(params, batch, nosharder)
    assert loss.shape == ()
    assert jnp.isfinite(loss), f"{arch}: loss not finite"
    for k, v in metrics.items():
        assert jnp.all(jnp.isfinite(v)), f"{arch}: metric {k} not finite"


@pytest.mark.parametrize("arch", ALL_ARCHS)
def test_one_train_step_updates_params(arch, nosharder):
    cfg = reduced_config(arch, n_microbatches=2)
    model = lm_lib.build_model(cfg)
    opt = AdamW(lr=cosine_schedule(1e-3, 2, 100))
    step = make_train_step(model, opt, nosharder)
    state = init_state(model.param_specs(), jax.random.PRNGKey(1))
    batch = {k: jnp.asarray(v) for k, v in
             make_batch(cfg, smoke_shape("train", seq=16, batch=4)).items()}
    new_state, metrics = jax.jit(step)(state, batch)
    assert int(new_state["step"]) == 1
    assert jnp.isfinite(metrics["loss"])
    assert jnp.isfinite(metrics["grad_norm"]) and metrics["grad_norm"] > 0
    # at least one parameter must actually move
    moved = jax.tree.map(
        lambda a, b: float(jnp.max(jnp.abs(a.astype(jnp.float32)
                                           - b.astype(jnp.float32)))),
        state["params"], new_state["params"])
    assert max(jax.tree.leaves(moved)) > 0


@pytest.mark.parametrize("arch", ALL_ARCHS)
def test_prefill_decode_shapes(arch, nosharder):
    cfg = reduced_config(arch)
    model = lm_lib.build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    B, S = 2, 12
    batch = make_batch(cfg, smoke_shape("prefill", seq=S, batch=B))
    cache, logits = model.prefill(params, batch, nosharder, max_len=S + 4)
    assert logits.shape == (B, cfg.padded_vocab)
    assert jnp.all(jnp.isfinite(logits))
    tok = jnp.argmax(logits, -1).astype(jnp.int32)
    cache, logits2 = model.decode_step(params, cache, tok, nosharder)
    assert logits2.shape == (B, cfg.padded_vocab)
    assert jnp.all(jnp.isfinite(logits2))
    assert int(cache["lengths"][0]) == S + 1
