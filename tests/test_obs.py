"""repro.obs: metrics registry, structured tracing, live metrics, and the
observed-traffic workload fit.

Pure units first (no model build: registry semantics, tracer event
schema, fit_profile estimators on synthetic traces), then engine
integration on the shared reduced model (trace byte-determinism across
same-seed runs, windowed-live == end-of-run-aggregate, and the
one-call ``reset_telemetry`` covering scheduler + slot counters)."""

import json

import jax
import pytest

from repro.dist.sharding import Sharder
from repro.models.lm import build_model
from repro.obs import (LiveMetrics, MetricsRegistry, Tracer, check_trace,
                       fit_profile)
from repro.obs.observe import observed_span_ticks, summarize
from repro.obs.trace import TICK_US, TRACE_SCHEMA
from repro.serving import ServingEngine, VirtualClock, drive
from repro.serving.engine import Request
from repro.serving.workload import profile_items
from repro.plan.plan import WorkloadProfile
from repro.testing import reduced_config


# ---------------------------------------------------------------------------
# registry units (no model)
# ---------------------------------------------------------------------------


def test_registry_counter_gauge_histogram_roundtrip():
    reg = MetricsRegistry()
    c = reg.counter("a.count")
    c.inc()
    c.inc(4)
    g = reg.gauge("a.level")
    g.set(2.5)
    h = reg.histogram("a.lat")
    for v in (1.0, 2.0, 3.0, 10.0):
        h.observe(v)
    assert reg.snapshot() == {"a.count": 5, "a.lat": 4, "a.level": 2.5}
    assert h.summary()["p50"] == 2.0 and h.summary()["n"] == 4
    reg.reset()
    assert reg.snapshot() == {"a.count": 0, "a.lat": 0, "a.level": 0.0}


def test_registry_get_or_create_and_kind_clash():
    reg = MetricsRegistry()
    assert reg.counter("x") is reg.counter("x")   # idempotent
    with pytest.raises(ValueError, match="already registered"):
        reg.gauge("x")
    assert "x" in reg and reg["x"].kind == "counter"


def test_derived_gauge_is_live_and_unsettable():
    reg = MetricsRegistry()
    state = {"v": 1.0}
    g = reg.gauge("d", fn=lambda: state["v"])
    assert g.value == 1.0
    state["v"] = 7.0
    assert g.value == 7.0
    with pytest.raises(ValueError, match="derived"):
        g.set(0.0)
    reg.reset()                     # derived gauges ignore reset
    assert g.value == 7.0


def test_registry_view_preserves_caller_key_order():
    reg = MetricsRegistry()
    reg.counter("m.b").inc(2)
    reg.counter("m.a").inc(1)
    view = reg.view({"bee": "m.b", "ay": "m.a"})
    assert list(view) == ["bee", "ay"] and view == {"bee": 2, "ay": 1}


# ---------------------------------------------------------------------------
# tracer units (synthetic requests, no model)
# ---------------------------------------------------------------------------


def _fake_done_request(uid=0, t_submit=0, t_admit=1, t_first=1, t_done=4,
                       n_tokens=4, deadline=None):
    r = Request(uid, [1, 2, 3], max_new_tokens=n_tokens, deadline=deadline,
                t_submit=t_submit)
    r.t_admit, r.t_first, r.t_done = t_admit, t_first, t_done
    r.output = list(range(n_tokens))
    r.done = True
    return r


def test_tracer_lifecycle_events_validate_and_roundtrip(tmp_path):
    tr = Tracer()
    req = _fake_done_request(uid=3, deadline=9.0)
    tr.request_submit(req, 0)
    tr.prefill(1, bucket=4, rows=2, n_reqs=1, overlap=True)
    tr.compile(1, "prefill", rows=2, length=4)
    tr.decode_chunk(1, n_ticks=3, n_slots=1)
    tr.host_sync(4)
    tr.counter(2, "util", 0.5)
    tr.counter(2, "queue_depth", 0)
    tr.request_done(req, 4)
    doc = tr.to_chrome()
    check_trace(doc)
    assert doc["otherData"]["schema"] == TRACE_SCHEMA
    # ticks scale to TICK_US in the export
    sub = next(e for e in doc["traceEvents"] if e["name"] == "submit")
    assert sub["ts"] == 0 and sub["args"]["deadline"] == 9.0
    run = next(e for e in doc["traceEvents"] if e["name"] == "run")
    assert run["ts"] == 1 * TICK_US and run["dur"] == 4 * TICK_US
    # canonical file round-trips through json and still validates
    p = tmp_path / "t.json"
    tr.save(str(p))
    check_trace(json.loads(p.read_text()))
    assert p.read_text() == tr.dumps()


def test_check_trace_rejects_schema_drift():
    tr = Tracer()
    tr.host_sync(1)
    doc = tr.to_chrome()
    bad = dict(doc)
    bad["otherData"] = {"schema": "nope", "tick_us": TICK_US}
    with pytest.raises(ValueError, match="schema"):
        check_trace(bad)
    tr2 = Tracer()
    tr2._add("mystery", "engine", "i", 0, 0)
    with pytest.raises(ValueError, match="unknown event"):
        check_trace(tr2.to_chrome())
    # non-tick-aligned timestamp
    from repro.obs.trace import TraceEvent
    tr3 = Tracer()
    tr3.events.append(TraceEvent("host_sync", "engine", "i", 1, 1, 0))
    with pytest.raises(ValueError, match="tick-aligned"):
        check_trace(tr3.to_chrome())


def test_tracer_reset_empties_event_log():
    tr = Tracer()
    tr.host_sync(0)
    assert len(tr) == 1
    tr.reset()
    assert len(tr) == 0 and tr.dumps() == Tracer().dumps()


# ---------------------------------------------------------------------------
# fit_profile units (synthetic traces)
# ---------------------------------------------------------------------------


def _trace_with_submits(specs):
    """specs: (tick, prompt_len, max_new, deadline) tuples."""
    tr = Tracer()
    for uid, (t, plen, mnew, dl) in enumerate(specs):
        r = Request(uid, list(range(plen)), max_new_tokens=mnew,
                    deadline=dl, t_submit=t)
        tr.request_submit(r, t)
    return tr


def test_fit_profile_recovers_rate_ranges_and_slack():
    specs = [(t, 4 + t % 8, 6 + t % 5, float(t + 3 * (6 + t % 5)))
             for t in range(0, 40, 2)]                 # one every 2 ticks
    tr = _trace_with_submits(specs)
    p = fit_profile(tr)
    assert isinstance(p, WorkloadProfile)
    assert p.rate == pytest.approx(len(specs) / 39.0)  # span = last + 1
    assert p.prompt_len == (4, 10)   # t is even, so t%8 tops out at 6
    assert p.max_new_tokens == (6, 10)
    assert p.heavy_decode is None
    assert p.deadline_slack == pytest.approx(3.0)
    assert p.deadline_frac == 1.0
    assert observed_span_ticks(tr) == 39
    # the explicit recording window overrides the observed span
    assert fit_profile(tr, duration=100.0).rate \
        == pytest.approx(len(specs) / 100.0)


def test_fit_profile_splits_heavy_decode_tail():
    base = [(t, 8, 6 + t % 5, None) for t in range(40)]
    heavy = [(t, 8, 30 + t % 11, None) for t in range(0, 40, 10)]
    p = fit_profile(_trace_with_submits(base + heavy))
    assert p.max_new_tokens == (6, 10)
    frac, lo, hi = p.heavy_decode
    assert frac == pytest.approx(len(heavy) / (len(base) + len(heavy)))
    assert 30 <= lo <= hi <= 40
    # deadline-less traffic fits a deadline-less profile
    assert p.deadline_slack is None and not p.has_deadlines


def test_fit_profile_fits_workload_profile_from_trace_classmethod():
    tr = _trace_with_submits([(0, 4, 8, None), (4, 6, 8, None)])
    p = WorkloadProfile.from_trace(tr, duration=8.0)
    assert p.rate == pytest.approx(2 / 8.0)
    assert summarize(tr)["submits"] == 2


def test_fit_profile_empty_trace_raises():
    with pytest.raises(ValueError, match="no request submit"):
        fit_profile(Tracer())


# ---------------------------------------------------------------------------
# LiveMetrics units
# ---------------------------------------------------------------------------


def test_live_metrics_window_eviction():
    lm = LiveMetrics(window=4)
    lm.observe_request(_fake_done_request(t_done=0), 0)
    for t in range(8):
        lm.observe_tick(t, 1.0)
    s = lm.snapshot()
    # the request retired at tick 0 left the window (edge = 7 - 4 = 3)
    assert s["completed"] == 0 and s["tick"] == 7
    lm.observe_request(_fake_done_request(t_done=7), 7)
    assert lm.snapshot()["completed"] == 1
    with pytest.raises(ValueError, match="window"):
        LiveMetrics(window=0)


def test_live_metrics_slo_and_reset():
    lm = LiveMetrics(window=100)
    lm.observe_request(_fake_done_request(t_done=4, deadline=10.0), 4)  # met
    lm.observe_request(_fake_done_request(t_done=4, deadline=2.0), 4)  # miss
    lm.observe_request(_fake_done_request(t_done=4), 4)       # no deadline
    s = lm.snapshot()
    assert s["slo_attainment"] == pytest.approx(0.5)
    assert s["completed"] == 3
    assert "slo=" in lm.line()
    lm.reset()
    assert lm.snapshot()["completed"] == 0
    assert lm.snapshot()["slo_attainment"] is None


# ---------------------------------------------------------------------------
# engine integration (shared reduced model)
# ---------------------------------------------------------------------------


@pytest.fixture(scope="module")
def setup():
    cfg = reduced_config("rwkv6-1.6b")
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    return cfg, model, params, Sharder(None, {})


_PROFILE = WorkloadProfile(kind="poisson", rate=0.6, duration=24.0,
                           deadline_slack=3.0)


def _traced_run(setup, **kw):
    cfg, model, params, sharder = setup
    tracer = Tracer()
    eng = ServingEngine(model, params, sharder, max_batch=2, max_len=32,
                        tracer=tracer, **kw)
    live = eng.enable_live_metrics(window=100_000)
    items = profile_items(_PROFILE, vocab_size=cfg.vocab_size, seed=0)
    reqs = drive(eng, items, VirtualClock())
    return tracer, eng, live, reqs


def test_same_seed_traces_are_byte_identical_and_valid(setup):
    tr1, eng, _, _ = _traced_run(setup, policy="edf", preempt=True)
    tr2, _, _, _ = _traced_run(setup, policy="edf", preempt=True)
    assert tr1.dumps() == tr2.dumps()
    check_trace(tr1.to_chrome())
    names = {e.name for e in tr1.events}
    assert {"submit", "queued", "run", "first_token", "prefill",
            "decode_chunk", "host_sync", "compile", "util",
            "queue_depth"} <= names
    # every completed request emitted its full lifecycle
    dones = [e for e in tr1.events if e.name == "run"]
    assert len(dones) == eng.completed
    # span durations line up with the request stamps
    for ev in dones:
        req = next(r for r in eng.finished
                   if r.uid == ev.args["uid"])
        assert ev.ts == req.t_admit * TICK_US
        assert ev.dur == (req.t_done + 1 - req.t_admit) * TICK_US


def test_windowed_live_metrics_match_end_of_run_aggregate(setup):
    """The property the ISSUE names: a window at least the run length
    evicts nothing, so the live snapshot must equal the end-of-run
    aggregate exactly (same request_metrics conventions)."""
    from repro.serving import metrics as smetrics

    _, eng, live, reqs = _traced_run(setup)
    agg = smetrics.aggregate(reqs, ticks=eng.ticks,
                             util_history=eng.util_history)
    snap = live.snapshot()
    assert snap["completed"] == agg["completed"]
    assert snap["ttft_p95"] == agg["ttft"]["p95"]
    assert snap["tpot_p95"] == agg["tpot"]["p95"]
    assert snap["mean_util"] == pytest.approx(agg["mean_util"])
    assert snap["slo_attainment"] == pytest.approx(
        agg["slo"]["attainment"])


def test_reset_telemetry_covers_the_whole_registry(setup):
    """One reset call zeroes engine + scheduler + slot counters by
    construction, while prefill_compiles (the jit-cache mirror) survives
    — the satellite fix for the per-attribute reset drift."""
    eng = _traced_run(setup, policy="edf", preempt=True)[1]
    s = eng.stats()
    assert s["completed"] > 0 and s["prefill_compiles"] > 0
    reg = eng.metrics.snapshot()
    assert reg["scheduler.submitted"] > 0
    assert reg["slots.prefill_inserts"] > 0
    compiles_before = s["prefill_compiles"]
    eng.reset_telemetry()
    s2 = eng.stats()
    zeroed = {k: v for k, v in s2.items()
              if k not in ("prefill_compiles", "mean_util")}
    assert all(v == 0 for v in zeroed.values()), s2
    assert s2["prefill_compiles"] == compiles_before
    reg2 = eng.metrics.snapshot()
    assert reg2["scheduler.submitted"] == 0
    assert reg2["scheduler.picked"] == 0
    assert reg2["slots.prefill_inserts"] == 0
    assert reg2["slots.snapshots"] == 0
    assert eng.tracer is not None and len(eng.tracer) == 0
    assert eng.live.snapshot()["completed"] == 0


_FRAG_COUNTERS = {"blocks_free", "bytes_resident", "padding_waste"}


def test_dense_traces_carry_no_fragmentation_counters(setup):
    """Byte-stability half of the PR-7 gauge wiring: dense engines emit
    exactly the pre-paged event vocabulary, so every previously-committed
    trace file's bytes are untouched by the new counter tracks."""
    tr, _, _, _ = _traced_run(setup)
    assert not {e.name for e in tr.events} & _FRAG_COUNTERS
    check_trace(tr.to_chrome())


def test_paged_traces_add_fragmentation_counters_deterministically(setup):
    """Paged engines emit the three fragmentation counter tracks, the
    schema validator accepts them, and same-seed runs stay
    byte-identical (the determinism contract extends to the new
    tracks)."""
    tr1, eng, _, _ = _traced_run(setup, cache_layout="paged:8")
    tr2, _, _, _ = _traced_run(setup, cache_layout="paged:8")
    assert tr1.dumps() == tr2.dumps()
    check_trace(tr1.to_chrome())
    assert _FRAG_COUNTERS <= {e.name for e in tr1.events}
    # the tracks carry the gauge values the registry serves
    assert "slots.bytes_resident" in eng.metrics
    resident = [e.args["bytes_resident"] for e in tr1.events
                if e.name == "bytes_resident"]
    assert resident and all(v >= 0 for v in resident)


@pytest.mark.parametrize("layout", ("dense", "paged:8"))
def test_fragmentation_gauges_registered_and_consistent(setup, layout):
    """The three slots.* fragmentation gauges are registered in the
    engine's shared MetricsRegistry under both layouts and satisfy
    resident = useful + waste; dense resident is the constant worst-case
    commitment, paged resident tracks occupancy."""
    cfg, model, params, sharder = setup
    eng = ServingEngine(model, params, sharder, max_batch=2, max_len=32,
                        cache_layout=layout)
    snap = eng.metrics.snapshot()
    for name in ("slots.blocks_free", "slots.bytes_resident",
                 "slots.padding_waste"):
        assert name in snap
    empty_resident = eng.sm.bytes_resident()
    eng.submit([1, 2, 3, 4], max_new_tokens=4)
    eng.step()
    assert eng.sm.bytes_resident() == \
        eng.sm.useful_bytes() + eng.sm.padding_waste()
    if layout == "dense":
        assert eng.sm.bytes_resident() == empty_resident  # constant
    else:
        assert eng.sm.bytes_resident() > empty_resident   # tracks load
    eng.run()


def test_aggregate_metrics_block_untouched_by_gauges(setup):
    """The committed BENCH ``metrics`` blocks never mention the gauges:
    aggregate() output is a pure function of the request set, identical
    whether the serving engine was dense or paged."""
    from repro.serving import metrics as smetrics

    def one(layout):
        cfg, model, params, sharder = setup
        eng = ServingEngine(model, params, sharder, max_batch=2,
                            max_len=32, cache_layout=layout)
        items = profile_items(_PROFILE, vocab_size=cfg.vocab_size, seed=0)
        reqs = drive(eng, items, VirtualClock())
        return smetrics.aggregate(reqs, ticks=eng.ticks,
                                  util_history=eng.util_history)

    agg_d, agg_p = one("dense"), one("paged:8")
    assert agg_d == agg_p
    flat = json.dumps(agg_d)
    assert "blocks_free" not in flat and "bytes_resident" not in flat


def test_fit_profile_from_engine_trace_matches_offered_traffic(setup):
    tracer, _, _, reqs = _traced_run(setup)
    p = fit_profile(tracer, duration=_PROFILE.duration)
    assert p.rate == pytest.approx(len(reqs) / _PROFILE.duration)
    assert p.prompt_len[0] >= _PROFILE.prompt_len[0]
    assert p.prompt_len[1] <= _PROFILE.prompt_len[1]
    assert p.deadline_slack == pytest.approx(3.0, abs=0.35)
    assert p.deadline_frac == 1.0
