"""Fault-tolerance primitives."""

import os
import signal
import time

import pytest

from repro.runtime import PreemptionGuard, StepWatchdog, retry


def test_watchdog_detects_stall_and_recovers():
    events = []
    with StepWatchdog(timeout_s=0.2, poll_s=0.05,
                      on_stall=lambda idle: events.append(idle)) as wd:
        wd.beat()
        time.sleep(0.5)
        assert wd.stalled
        wd.beat()
        assert not wd.stalled
    assert events and events[0] >= 0.2


def test_watchdog_quiet_while_beating():
    events = []
    with StepWatchdog(timeout_s=0.5, poll_s=0.05,
                      on_stall=lambda idle: events.append(idle)) as wd:
        for _ in range(6):
            wd.beat()
            time.sleep(0.05)
    assert not events


def test_preemption_guard_sets_flag():
    with PreemptionGuard(signals=(signal.SIGUSR1,)) as guard:
        assert not guard.should_stop
        os.kill(os.getpid(), signal.SIGUSR1)
        time.sleep(0.05)
        assert guard.should_stop


def test_retry_succeeds_after_transient_failures():
    calls = {"n": 0}

    def flaky():
        calls["n"] += 1
        if calls["n"] < 3:
            raise OSError("transient")
        return "ok"

    assert retry(flaky, tries=5, base_delay_s=0.01) == "ok"
    assert calls["n"] == 3


def test_retry_raises_after_exhaustion():
    def always_fails():
        raise OSError("permanent")

    with pytest.raises(OSError):
        retry(always_fails, tries=2, base_delay_s=0.01)
