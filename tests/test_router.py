"""Fleet property harness for the multi-replica router (repro.serving.router).

The contracts under test:

* a single-replica colocated fleet is the bare engine, bit-exactly —
  same schedule, same outputs, same metrics JSON (``drive_fleet``
  reduces branch-for-branch to ``drive`` when there are no transits);
* fleets are deterministic: same seed, same fleet plan, same workload
  => byte-identical schedules and pooled metrics, colocated and
  disaggregated alike;
* requests are conserved under ANY routing/transit interleaving: every
  submitted request is queued, in a slot, in transit, or finished —
  and at drain, finished + shed == submitted;
* cross-engine snapshot hand-off fails loudly *by field name* when the
  engines' cache specs disagree (the compat-check helper);
* ``metrics.aggregate_fleet`` pools per-request samples — it must NOT
  average per-replica percentiles (the committed divergence case);
* the committed BENCH_serving.json fleet cells carry the acceptance
  numbers (capacity scaling, disagg-vs-colocated twin, byte-exact twin).
"""

import json
import os

import jax
import pytest
from hypothesis import given, settings
from hypothesis import strategies as st

from repro.models.lm import build_model
from repro.plan import io as plan_io
from repro.plan.plan import FleetPlan, ServingPlan, WorkloadProfile
from repro.serving import (
    Request,
    ServingEngine,
    SlotSnapshot,
    VirtualClock,
    aggregate,
    aggregate_fleet,
    drive,
    profile_items,
)
from repro.serving.router import (
    ROUTER_POLICIES,
    Router,
    drive_fleet,
    make_routing_policy,
)
from repro.testing import reduced_config

ARCH = "rwkv6-1.6b"
MAX_LEN = 32
BENCH = os.path.join(os.path.dirname(os.path.dirname(
    os.path.abspath(__file__))), "BENCH_serving.json")


@pytest.fixture(scope="module")
def built():
    cfg = reduced_config(ARCH)
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    return cfg, model, params


def _shared(built):
    cfg, model, params = built
    return {(ARCH, True): (model, params)}


def _plan(**kw) -> ServingPlan:
    kw.setdefault("arch", ARCH)
    kw.setdefault("max_batch", 2)
    kw.setdefault("max_len", MAX_LEN)
    return ServingPlan(**kw)


def _items(cfg, *, rate=0.8, duration=10.0, seed=0, **kw):
    prof = WorkloadProfile(kind="poisson", rate=rate, duration=duration,
                           **kw)
    return profile_items(prof, vocab_size=cfg.vocab_size, seed=seed)


def _schedule(reqs):
    return [(r.uid, tuple(r.output), r.t_submit, r.t_admit, r.t_first,
             r.t_done, r.shed) for r in reqs]


# ---------------------------------------------------------------------------
# single-replica fleet == bare engine, bit-exactly
# ---------------------------------------------------------------------------


def test_single_replica_fleet_is_bare_engine(built):
    cfg, model, params = built
    plan = _plan()
    items = _items(cfg)

    engine = ServingEngine.from_plan(plan, params, model=model, seed=0)
    bare = drive(engine, items, VirtualClock())
    bare_agg = aggregate(bare, ticks=engine.ticks,
                         util_history=engine.util_history)

    fleet = FleetPlan.replicated(plan, 1).validate()
    router = Router.from_plan(fleet, seed=0, _built=_shared(built))
    freqs = drive_fleet(router, items, VirtualClock())

    assert _schedule(freqs) == _schedule(bare)
    assert json.dumps(router.fleet_aggregate(), sort_keys=True) == \
        json.dumps(bare_agg, sort_keys=True)


# ---------------------------------------------------------------------------
# determinism: same seed => byte-identical fleet schedules
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("routing,n,n_prefill", [
    ("round_robin", 2, 0),
    ("least_queue", 2, 0),
    ("slo_feedback", 2, 0),
    ("least_queue", 3, 1),
])
def test_same_seed_fleets_byte_identical(built, routing, n, n_prefill):
    cfg, _, _ = built
    fleet = FleetPlan.replicated(_plan(), n, routing=routing,
                                 n_prefill=n_prefill).validate()

    def one_run():
        router = Router.from_plan(fleet, seed=3, _built=_shared(built))
        reqs = drive_fleet(router, _items(cfg, seed=5))
        return _schedule(reqs), json.dumps(router.fleet_aggregate(),
                                           sort_keys=True)

    sched_a, agg_a = one_run()
    sched_b, agg_b = one_run()
    assert sched_a == sched_b
    assert agg_a == agg_b


# ---------------------------------------------------------------------------
# property harness: request conservation under random interleavings
# ---------------------------------------------------------------------------


@settings(max_examples=8, deadline=None)
@given(seed=st.integers(0, 2**16),
       n=st.integers(1, 3),
       n_prefill=st.integers(0, 2),
       routing=st.sampled_from(sorted(ROUTER_POLICIES)),
       rate=st.sampled_from([0.4, 0.9, 1.4]))
def test_fleet_conserves_requests(built, seed, n, n_prefill, routing, rate):
    cfg, _, _ = built
    n_prefill = min(n_prefill, n - 1)
    fleet = FleetPlan.replicated(_plan(), n, routing=routing,
                                 n_prefill=n_prefill).validate()
    router = Router.from_plan(fleet, seed=seed, _built=_shared(built))
    items = _items(cfg, rate=rate, duration=8.0, seed=seed)
    reqs = drive_fleet(router, items)

    assert len(reqs) == len(items)
    census = router.conservation_census()
    assert census["total"] == len(items), census
    assert census["queued"] == census["in_slot"] == \
        census["in_transit"] == 0, census
    assert census["finished"] + census["shed"] == len(items), census
    for r in reqs:
        assert r.shed or r.done, \
            f"request {r.uid} neither finished nor shed"
    ts = router.transit_stats()
    assert ts["delivered"] == ts["handoffs"], ts
    assert ts["in_flight"] == 0, ts
    # admission-order attribution covers every request exactly once
    assert sorted(r.uid for rs in router.assigned for r in rs) == \
        sorted(r.uid for r in reqs)


# ---------------------------------------------------------------------------
# disaggregation: hand-offs actually move requests across engines
# ---------------------------------------------------------------------------


def test_disaggregated_fleet_hands_off_every_request(built):
    cfg, _, _ = built
    fleet = FleetPlan.replicated(_plan(), 3, n_prefill=1).validate()
    router = Router.from_plan(fleet, seed=0, _built=_shared(built))
    reqs = drive_fleet(router, _items(cfg, rate=1.0, duration=12.0))

    done = [r for r in reqs if not r.shed]
    ts = router.transit_stats()
    assert ts["handoffs"] == len(done) > 0
    assert ts["delivered"] == ts["handoffs"]
    assert ts["bytes"] > 0 and ts["ticks"] >= ts["handoffs"]
    for r in done:
        assert r.t_resumes, \
            f"request {r.uid} never resumed on a decode replica"
    # the prefill replica drains empty: every slot streamed out
    assert router.engines[0].sm.n_active() == 0
    assert len(router.engines[0].finished) == 0


def test_transit_cost_model(built):
    # an explicit bytes/tick override drives the ceil; the paper's
    # single-accelerator plasticine spec has no DCN (dcn_bw == 0), so
    # transits there take the 1-tick floor regardless of snapshot size
    fleet = FleetPlan.replicated(_plan(), 2, n_prefill=1,
                                 transit_bytes_per_tick=100.0).validate()
    router = Router.from_plan(fleet, seed=0, _built=_shared(built))
    assert router.transit_ticks(1) == 1
    assert router.transit_ticks(250) == 3
    plast = FleetPlan.replicated(
        _plan(), 2, n_prefill=1, hw="plasticine-rnn-variant").validate()
    router_p = Router.from_plan(plast, seed=0, _built=_shared(built))
    assert router_p.transit_ticks(10**9) == 1


# ---------------------------------------------------------------------------
# cross-engine snapshot compat fails loudly, by field name
# ---------------------------------------------------------------------------


def _live_snapshot(engine):
    req = engine.submit([1, 2, 3], max_new_tokens=8)
    for _ in range(8):
        engine.step()
        if any(r.uid == req.uid and len(r.output) >= 1
               for _, r in engine.sm.running()):
            break
    slot = next(s for s, r in engine.sm.running() if r.uid == req.uid)
    return engine.sm.snapshot_many([slot])[0], req


def test_rwkv_state_is_max_len_invariant(built):
    # the paper's cheap hand-off: RNN/SSM slot state is an O(1) column
    # with no sequence axis, so it restores into ANY max_len engine —
    # the compat check must agree (no spurious shape errors)
    cfg, model, params = built
    src = ServingEngine.from_plan(_plan(), params, model=model, seed=0)
    dst = ServingEngine.from_plan(_plan(max_len=64), params, model=model,
                                  seed=0)
    snap, _ = _live_snapshot(src)
    assert dst.sm.snapshot_compat_errors(snap) == []


def test_snapshot_compat_names_fields():
    # dense-attention KV caches DO carry max_len in their shape, so a
    # cross-max_len hand-off must fail loudly, naming each leaf
    cfg = reduced_config("qwen2.5-14b")
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))

    def eng(max_len):
        plan = ServingPlan(arch="qwen2.5-14b", max_batch=2,
                           max_len=max_len)
        return ServingEngine.from_plan(plan, params, model=model, seed=0)

    src, dst = eng(MAX_LEN), eng(64)
    snap, req = _live_snapshot(src)

    errors = dst.sm.snapshot_compat_errors(snap)
    assert errors, "incompatible snapshot reported no errors"
    assert all("shape" in e for e in errors)
    assert any("max_len differs" in e for e in errors)
    # every error names the offending cache leaf by its pytree path
    leaf_names = {e.split(":")[0] for e in errors}
    assert leaf_names and leaf_names <= set(dst.sm._col_specs)
    with pytest.raises(ValueError, match="snapshot incompatible"):
        dst.sm.check_snapshot_compat(snap)
    # restore re-checks unconditionally: a bad hand-off can never scatter
    with pytest.raises(ValueError, match="snapshot incompatible"):
        dst.sm.restore(0, snap, req)
    # the compatible engine accepts the same snapshot
    assert src.sm.snapshot_compat_errors(snap) == []

    # a snapshot whose pytree disagrees (different architecture) reports
    # missing and extra leaves, both sides named
    bogus = SlotSnapshot(cache_col={"bogus": snap.cache_col},
                         next_token=0)
    errs = src.sm.snapshot_compat_errors(bogus)
    assert any("missing from the snapshot" in e for e in errs)
    assert any("not in this engine's cache spec" in e for e in errs)


def test_fleet_plan_rejects_incompatible_disagg():
    a, b = _plan(), _plan(max_len=64)
    with pytest.raises(ValueError, match="max_len"):
        FleetPlan(replicas=(a, b), n_prefill=1).validate()
    # colocated fleets may mix freely (no snapshot ever crosses engines)
    FleetPlan(replicas=(a, b)).validate()
    with pytest.raises(ValueError, match="routing"):
        FleetPlan.replicated(a, 2, routing="bogus").validate()
    with pytest.raises(ValueError, match="n_prefill"):
        FleetPlan.replicated(a, 2, n_prefill=2).validate()
    with pytest.raises(ValueError, match="at least one replica"):
        FleetPlan(replicas=()).validate()
    with pytest.raises(ValueError, match="transit_bytes_per_tick"):
        FleetPlan.replicated(a, 2, transit_bytes_per_tick=0.0).validate()


def test_fleet_plan_round_trips_through_json(tmp_path):
    fleet = FleetPlan.replicated(
        _plan(max_batch=4), 3, routing="least_queue", n_prefill=1,
        transit_bytes_per_tick=1e6,
        provenance={"source": "test"}).validate()
    d = plan_io.fleet_to_dict(fleet)
    assert d["schema"] == plan_io.FLEET_SCHEMA
    assert plan_io.fleet_from_dict(json.loads(json.dumps(d))) == fleet
    path = tmp_path / "fleet.json"
    plan_io.save_fleet_plan(fleet, str(path))
    assert plan_io.load_fleet_plan(str(path)) == fleet


def test_routing_registry():
    assert set(ROUTER_POLICIES) == {"round_robin", "least_queue",
                                    "slo_feedback"}
    for name in ROUTER_POLICIES:
        assert make_routing_policy(name).name == name
    with pytest.raises(ValueError, match="unknown routing policy"):
        make_routing_policy("bogus")


# ---------------------------------------------------------------------------
# aggregate_fleet pools samples (never averages percentiles)
# ---------------------------------------------------------------------------


def _req(uid, t_submit, t_admit, t_first, t_done, n_out=4):
    return Request(uid=uid, prompt=[1, 2], max_new_tokens=n_out,
                   output=[7] * n_out, done=True, t_submit=t_submit,
                   t_admit=t_admit, t_first=t_first, t_done=t_done)


def test_aggregate_fleet_pools_samples_across_skewed_replicas():
    # replica A: 9 fast requests (ttft 2); replica B: 9 slow (ttft 101).
    # The pooled p95 sits in the slow half (101); the naive mean of
    # per-replica p95s reports 51.5 — a latency no request experienced.
    fast = [_req(i, 0, 1, 1, 5) for i in range(9)]
    slow = [_req(100 + i, 0, 100, 100, 104) for i in range(9)]
    pooled = aggregate_fleet([(fast, 200, [0.5]), (slow, 300, [1.0])])

    agg_fast = aggregate(fast, ticks=200, util_history=[0.5])
    agg_slow = aggregate(slow, ticks=300, util_history=[1.0])
    naive_p95 = (agg_fast["ttft"]["p95"] + agg_slow["ttft"]["p95"]) / 2

    assert pooled["ttft"]["p95"] == 101.0
    assert naive_p95 == pytest.approx(51.5)
    # pooling == aggregating the concatenated population, definitionally
    assert json.dumps(pooled, sort_keys=True) == json.dumps(
        aggregate(fast + slow, ticks=300, util_history=[0.5, 1.0]),
        sort_keys=True)
    assert pooled["submitted"] == 18
    assert pooled["ticks"] == 300        # widest replica span
    assert pooled["mean_util"] == pytest.approx(0.75)


def test_aggregate_fleet_single_replica_identity():
    reqs = [_req(i, 0, i, i, i + 4) for i in range(5)]
    assert json.dumps(aggregate_fleet([(reqs, 60, [0.25])]),
                      sort_keys=True) == \
        json.dumps(aggregate(reqs, ticks=60, util_history=[0.25]),
                   sort_keys=True)


def test_aggregate_fleet_empty_rejected():
    with pytest.raises(ValueError, match="empty fleet"):
        aggregate_fleet([])


# ---------------------------------------------------------------------------
# committed trajectory: the BENCH fleet cells carry the acceptance numbers
# ---------------------------------------------------------------------------


@pytest.fixture(scope="module")
def bench():
    with open(BENCH) as f:
        return json.load(f)


def test_bench_has_fleet_section(bench):
    assert "fleet" in bench, "BENCH_serving.json lost its fleet section"
    names = [c["name"] for c in bench["fleet"]]
    assert len(names) == len(set(names))
    for c in bench["fleet"]:
        fleet = plan_io.fleet_from_dict(c["fleet"])
        fleet.validate()
        assert fleet.n_replicas == c["n_replicas"]
        assert "wall" in c   # split out so deterministic_view drops it


def test_bench_twin_cell_matches_bare_cell(bench):
    twin = next(c for c in bench["fleet"] if c["name"].endswith("/twin"))
    bare = next(c for c in bench["cells"]
                if c["name"] == "rwkv6-1.6b/b2/r1")
    assert json.dumps(twin["metrics"], sort_keys=True) == \
        json.dumps(bare["metrics"], sort_keys=True), \
        "single-replica fleet drifted from the bare engine trajectory"


def test_bench_capacity_scaling_acceptance(bench):
    cells = sorted((c for c in bench["fleet"]
                    if c["name"].endswith("/capacity")),
                   key=lambda c: c["n_replicas"])
    assert [c["n_replicas"] for c in cells] == [1, 2, 4]
    one, two, four = cells
    # the capacity bar: >= 1.8x SLO-met served tokens going 1 -> 2
    # replicas under overload, with 2-replica attainment >= 0.95
    assert two["metrics"]["slo"]["attainment"] >= 0.95
    assert two["slo_met_tokens"] >= 1.8 * one["slo_met_tokens"], \
        (one["slo_met_tokens"], two["slo_met_tokens"])
    assert four["metrics"]["slo"]["attainment"] >= 0.95
    assert four["slo_met_tokens"] >= two["slo_met_tokens"]


def test_bench_disagg_beats_colocated_twin(bench):
    colo = next(c for c in bench["fleet"]
                if c["name"].endswith("/colocated"))
    dis = next(c for c in bench["fleet"] if c["name"].endswith("/disagg"))
    assert dis["n_prefill"] >= 1 and colo["n_prefill"] == 0
    # the heavy-tail cell: disaggregation improves p99 TTFT without
    # regressing p99 TPOT against its colocated twin
    assert dis["metrics"]["ttft"]["p99"] < colo["metrics"]["ttft"]["p99"]
    assert dis["metrics"]["tpot"]["p99"] <= colo["metrics"]["tpot"]["p99"]
    assert dis["transit"]["handoffs"] > 0
    assert dis["transit"]["delivered"] == dis["transit"]["handoffs"]
