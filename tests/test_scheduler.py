"""Scheduler policies: pure unit tests (no model).

The refactor contract: FCFS/SPF selection through the Scheduler interface
is order-identical to the pre-refactor engine-internal ``_pick``; EDF
orders by (deadline, submission), never thrashes on equal deadlines, and
only evicts when a strictly tighter deadline waits.  The registry and the
serve CLI must agree (also enforced by the benchmark smoke guard)."""

import math

import pytest

from repro.serving.engine import Request
from repro.serving.scheduler import (
    EDF,
    FCFS,
    POLICIES,
    SCHEDULERS,
    SPF,
    make_scheduler,
)


def _req(uid, prompt_len=4, deadline=None):
    return Request(uid, list(range(1, prompt_len + 1)), deadline=deadline)


def _fill(sched, reqs):
    for r in reqs:
        sched.submit(r)
    return sched


# ------------------------------------------------------------------ registry


def test_registry_and_cli_agree():
    assert set(POLICIES) == set(SCHEDULERS) == {"fcfs", "spf", "edf"}
    from repro.launch.serve import build_parser
    choices = None
    for action in build_parser()._actions:
        if "--policy" in action.option_strings:
            choices = set(action.choices)
    assert choices == set(SCHEDULERS)


def test_make_scheduler_validation():
    with pytest.raises(ValueError, match="policy"):
        make_scheduler("lifo")
    with pytest.raises(ValueError, match="non-preemptive"):
        make_scheduler("fcfs", preempt=True)
    assert not make_scheduler("edf").preemptive
    assert make_scheduler("edf", preempt=True).preemptive
    for name in SCHEDULERS:
        assert make_scheduler(name).name == name


# ------------------------------------------------------------- pick ordering


def test_fcfs_picks_in_arrival_order():
    s = _fill(FCFS(), [_req(i) for i in range(5)])
    assert [r.uid for r in s.pick(3)] == [0, 1, 2]
    assert [r.uid for r in s.pick(9)] == [3, 4]
    assert len(s) == 0


def test_spf_picks_shortest_prompt_fifo_among_equal():
    # pre-refactor semantics: sort by (len(prompt), queue position)
    reqs = [_req(0, 7), _req(1, 3), _req(2, 3), _req(3, 5)]
    s = _fill(SPF(), reqs)
    assert [r.uid for r in s.pick(3)] == [1, 2, 3]
    assert [r.uid for r in s.pick(1)] == [0]


def test_edf_orders_by_deadline_then_submission():
    reqs = [_req(0, deadline=30.0), _req(1, deadline=10.0),
            _req(2), _req(3, deadline=10.0), _req(4, deadline=5.0)]
    s = _fill(EDF(), reqs)
    # deadline order, FIFO among equal deadlines, deadline-less last
    assert [r.uid for r in s.pick(5)] == [4, 1, 3, 0, 2]


def test_requeue_front_precedes_queue():
    s = _fill(FCFS(), [_req(0), _req(1)])
    victim = _req(9)
    s.submit(victim)
    s.requeue_front(s.queue.pop())     # simulate eviction
    assert [r.uid for r in s.pick(3)] == [9, 0, 1]


# ------------------------------------------------------------------- victims


def test_non_preemptive_policies_never_evict():
    running = [(0, _req(10, deadline=100.0))]
    for name in SCHEDULERS:
        s = make_scheduler(name)
        s.submit(_req(0, deadline=1.0))
        assert s.victims(running, n_free=0) == []


def test_edf_victims_strictly_earlier_only():
    s = make_scheduler("edf", preempt=True)
    running = [(0, _req(10, deadline=50.0)), (1, _req(11, deadline=20.0))]
    # no waiter -> nothing to evict
    assert s.victims(running, n_free=0) == []
    # equal deadline never thrashes
    s.submit(_req(0, deadline=50.0))
    assert s.victims(running, n_free=0) == []
    # strictly earlier than the LATEST-deadline runner: evict slot 0
    s2 = make_scheduler("edf", preempt=True)
    s2.submit(_req(1, deadline=30.0))
    assert s2.victims(running, n_free=0) == [0]
    # but a free slot absorbs the waiter instead
    assert s2.victims(running, n_free=1) == []
    # deadline-less waiters (infinite deadline) never preempt anything
    s3 = make_scheduler("edf", preempt=True)
    s3.submit(_req(2))
    assert s3.victims(running, n_free=0) == []


def test_edf_victims_pair_most_urgent_with_latest():
    s = make_scheduler("edf", preempt=True)
    running = [(0, _req(10, deadline=100.0)), (1, _req(11, deadline=40.0)),
               (2, _req(12, deadline=60.0))]
    s.submit(_req(0, deadline=5.0))
    s.submit(_req(1, deadline=10.0))
    s.submit(_req(2, deadline=90.0))   # not urgent enough for slot 1
    # two urgent waiters evict the two latest-deadline runners, in order
    assert s.victims(running, n_free=0) == [0, 2]


def test_edf_deadline_key_is_inf_for_none():
    from repro.serving.scheduler import _deadline
    assert _deadline(_req(0)) == math.inf
    assert _deadline(_req(0, deadline=3.5)) == 3.5
