"""The paper's cell implementations: BLAS vs loop-based-fused equivalence,
precision transforms, DSE invariants."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro import hw
from repro.core import dse
from repro.core.cells import (
    RNNCellConfig,
    dequantize_weights,
    init_weights,
    quantize_weights,
    serve,
)
from repro.configs import DEEPBENCH_TASKS


@pytest.mark.parametrize("cell", ["lstm", "gru"])
@pytest.mark.parametrize("H,B,T", [(64, 1, 7), (128, 3, 5)])
def test_blas_equals_fused(cell, H, B, T, key):
    """Identical math, different execution models (paper Fig. 1 vs Fig. 3)."""
    cfg = RNNCellConfig(cell, H, timesteps=T, batch=B, precision="f32")
    w = init_weights(cfg, key)
    x = jax.random.normal(jax.random.fold_in(key, 7), (T, B, H))
    y_blas = serve(cfg, w, x, impl="blas")
    y_fused = serve(cfg, w, x, impl="fused")
    np.testing.assert_allclose(np.asarray(y_blas), np.asarray(y_fused),
                               atol=1e-5, rtol=1e-5)


@pytest.mark.parametrize("precision", ["int8", "bf16", "blocked_fp"])
def test_low_precision_close_to_f32(precision, key):
    cfg = RNNCellConfig("lstm", 128, timesteps=6, batch=1,
                        precision=precision)
    w = init_weights(cfg, key)
    wq = quantize_weights(cfg, w)
    x = jax.random.normal(jax.random.fold_in(key, 3), (6, 1, 128))
    y32 = serve(RNNCellConfig("lstm", 128, timesteps=6, precision="f32"),
                w, x, impl="fused")
    yq = serve(cfg, wq, x, impl="fused")
    # bounded-state cell: quantization error stays small through time
    assert float(jnp.max(jnp.abs(yq - y32))) < 0.05


def test_dequantize_roundtrip(key):
    cfg = RNNCellConfig("gru", 64, precision="int8")
    w = init_weights(cfg, key)
    wq = quantize_weights(cfg, w)
    wd = dequantize_weights(wq)
    for name in ("w_x", "w_h"):
        amax = float(jnp.max(jnp.abs(w[name])))
        assert float(jnp.max(jnp.abs(wd[name] - w[name]))) <= amax / 127 + 1e-6


# ---------------------------------------------------------------------------
# DSE
# ---------------------------------------------------------------------------


def test_dse_plans_respect_vmem():
    for task in DEEPBENCH_TASKS:
        cfg = RNNCellConfig(task.cell, task.hidden, timesteps=task.timesteps)
        plan = dse.best_plan(cfg)
        assert plan.bh >= 8 and cfg.hidden % plan.bh == 0
        assert plan.vmem_bytes <= hw.vmem_budget() or not plan.resident


def test_dse_utilization_beats_mvm_tiling():
    """Paper Fig. 4: loop-based 1-D fragmentation dominates BW's 2-D
    fragmentation on every DeepBench size."""
    for task in DEEPBENCH_TASKS:
        f = dse.fragmentation(task.hidden)
        assert f["util_loop"] >= f["util_mvm_bw"], f
    # and the gap is large for small problems (the paper's 30x case)
    small = dse.fragmentation(256)
    assert small["util_loop"] / small["util_mvm_bw"] > 1.5


@settings(max_examples=25, deadline=None)
@given(h_exp=st.integers(5, 12))
def test_dse_latency_monotone_in_hidden(h_exp):
    """Bigger problems are never modeled faster (sanity of the cost model)."""
    H = 2 ** h_exp
    small = dse.best_plan(RNNCellConfig("lstm", H))
    big = dse.best_plan(RNNCellConfig("lstm", 2 * H))
    assert big.step_latency_s >= small.step_latency_s * 0.99
