"""Pipeline parallelism: GPipe schedule == sequential stage application,
forward and backward, in a 4-fake-device subprocess."""

import os
import subprocess
import sys

import pytest

CODE = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
import jax, jax.numpy as jnp, numpy as np
from repro.launch.mesh import make_test_mesh
from repro.dist.pipeline import pipeline_apply, stack_stage_params

mesh = make_test_mesh((4,), ("pipe",))
S, B, D = 4, 8, 16
rng = np.random.default_rng(0)
stages = [{"w": jnp.asarray(rng.standard_normal((D, D)) / np.sqrt(D),
                            jnp.float32)} for _ in range(S)]
params = stack_stage_params(stages)
x = jnp.asarray(rng.standard_normal((B, D)), jnp.float32)

def stage_fn(p, h):
    return jnp.tanh(h @ p["w"])

# forward equivalence
y_pipe = pipeline_apply(stage_fn, params, x, mesh)
y_seq = x
for s in stages:
    y_seq = stage_fn(s, y_seq)
np.testing.assert_allclose(np.asarray(y_pipe), np.asarray(y_seq),
                           atol=1e-5, rtol=1e-5)

# backward equivalence (GPipe step is differentiable through shard_map)
def loss_pipe(p):
    return jnp.sum(pipeline_apply(stage_fn, p, x, mesh) ** 2)
def loss_seq(p):
    h = x
    for i in range(S):
        h = stage_fn(jax.tree.map(lambda a: a[i], p), h)
    return jnp.sum(h ** 2)
g_pipe = jax.grad(loss_pipe)(params)
g_seq = jax.grad(loss_seq)(params)
np.testing.assert_allclose(np.asarray(g_pipe["w"]), np.asarray(g_seq["w"]),
                           atol=1e-4, rtol=1e-4)

# the lowered HLO really moves activations via collective-permute
import sys; sys.path.insert(0, "src")
from repro.launch.hlo import parse_collectives
txt = jax.jit(loss_pipe).lower(params).compile().as_text()
kinds = {o.kind for o in parse_collectives(txt)}
assert "collective-permute" in kinds, kinds
print("OK")
"""


@pytest.mark.slow
def test_gpipe_matches_sequential():
    r = subprocess.run([sys.executable, "-c", CODE], capture_output=True,
                       text=True, timeout=900,
                       env={**os.environ, "PYTHONPATH": "src"}, cwd=".")
    assert r.returncode == 0, r.stderr[-3000:]
    assert "OK" in r.stdout
