"""Sharding rules engine: divisibility fallback, axis dedup, param trees."""

import subprocess
import sys

import jax
import jax.numpy as jnp
import pytest

from repro.configs import get_config
from repro.dist.sharding import Sharder, make_rules, make_sharder
from repro.models.params import ParamSpec


class FakeMesh:
    """Just enough Mesh surface for rule resolution tests."""
    def __init__(self, shape):
        self.shape = dict(shape)
        self.axis_names = tuple(shape)


def _sharder(rules, shape=(("data", 16), ("model", 16))):
    s = Sharder.__new__(Sharder)
    s.mesh = FakeMesh(shape)
    s.rules = dict(rules)
    return s


def test_divisibility_fallback_drops_trailing_axes():
    s = _sharder({"batch": ("pod", "data")},
                 shape=(("pod", 2), ("data", 16), ("model", 16)))
    assert s.resolve("batch", 256) == ("pod", "data")   # 256 % 32 == 0
    assert s.resolve("batch", 32) == ("pod", "data")
    assert s.resolve("batch", 2) == ("pod",)            # falls back to pod
    assert s.resolve("batch", 1) is None                # fully replicated


def test_heads_fallback_to_replication():
    s = _sharder({"heads": ("model",)})
    assert s.resolve("heads", 40) is None   # 40 !| 16 -> replicate
    assert s.resolve("heads", 48) == ("model",)


def test_spec_never_reuses_mesh_axis():
    s = _sharder({"experts": ("model",), "mlp": ("model",)})
    spec = s.spec(("experts", None, "mlp"), (32, 1024, 512))
    # experts takes "model"; mlp must NOT reuse it
    assert spec[0] == "model"
    assert spec[2] is None


def test_rules_tables_cover_all_archs():
    for arch in ("qwen2.5-14b", "gemma2-9b", "rwkv6-1.6b", "hymba-1.5b",
                 "qwen3-moe-30b-a3b"):
        cfg = get_config(arch)
        for mode in ("train", "prefill", "decode"):
            rules = make_rules(cfg, mode)
            assert "batch" in rules and "mlp" in rules


def test_mesh_sharder_constrain_is_noop_without_mesh(nosharder):
    x = jnp.ones((4, 8))
    assert nosharder.constrain(x, "batch", None) is x


DRYRUN_SMALL = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import jax
from repro.launch import dryrun as dr
from repro.launch.mesh import make_test_mesh
from repro.testing import reduced_config, smoke_shape
from repro.models.lm import build_model
from repro.dist.sharding import make_sharder

mesh = make_test_mesh((2, 4), ("data", "model"))
cfg = reduced_config("gemma3-12b", n_microbatches=2)
model = build_model(cfg)
for shape in [smoke_shape("train", 16, 4), smoke_shape("prefill", 16, 4),
              smoke_shape("decode", 16, 4)]:
    sharder = make_sharder(cfg, mesh, shape.mode)
    if shape.mode == "train":
        res = dr.build_train_cell(model, shape, mesh, sharder, pieces=True)
    else:
        res = dr.build_serve_cell(model, shape, mesh, sharder, pieces=True)
    assert res["full"]["flops"] > 0
    assert res["full"]["collectives"]["n_ops"] > 0, shape.mode
print("OK")
"""


@pytest.mark.slow
def test_dryrun_machinery_on_8_fake_devices():
    """The dry-run builder (lower+compile+cost pieces) runs end to end on a
    small mesh in a subprocess with 8 fake devices."""
    r = subprocess.run([sys.executable, "-c", DRYRUN_SMALL],
                       capture_output=True, text=True, timeout=900,
                       env={**__import__("os").environ,
                            "PYTHONPATH": "src"}, cwd=".")
    assert r.returncode == 0, r.stderr[-3000:]
    assert "OK" in r.stdout
