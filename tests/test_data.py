"""Data pipeline: determinism, restartability, host-sharding partition."""

import numpy as np
from hypothesis import given, settings, strategies as st

from repro.configs import get_config
from repro.data import SyntheticLMData
from repro.testing import reduced_config, smoke_shape


def _data(arch="qwen2.5-14b", **kw):
    return SyntheticLMData(reduced_config(arch), smoke_shape("train", 8, 8),
                           **kw)


def test_deterministic_across_instances():
    a = _data(seed=3).batch_at(17)
    b = _data(seed=3).batch_at(17)
    for k in a:
        np.testing.assert_array_equal(a[k], b[k])


def test_restart_resumes_identically():
    d1 = _data(seed=1)
    first = [next(d1) for _ in range(5)]
    state = d1.state()
    d2 = _data(seed=1)
    d2.restore(state)
    np.testing.assert_array_equal(next(d2)["tokens"], d1.batch_at(5)["tokens"])


@settings(max_examples=10, deadline=None)
@given(step=st.integers(0, 1000), seed=st.integers(0, 100))
def test_seed_and_step_change_data(step, seed):
    d = _data(seed=seed)
    t0 = d.batch_at(step)["tokens"]
    t1 = d.batch_at(step + 1)["tokens"]
    assert not np.array_equal(t0, t1)


def test_hosts_generate_disjoint_rows():
    """Different hosts must produce different (independent) shards."""
    h0 = SyntheticLMData(reduced_config("rwkv6-1.6b"),
                         smoke_shape("train", 8, 8), host_id=0, n_hosts=2)
    h1 = SyntheticLMData(reduced_config("rwkv6-1.6b"),
                         smoke_shape("train", 8, 8), host_id=1, n_hosts=2)
    assert h0.local_batch == 4
    assert not np.array_equal(h0.batch_at(0)["tokens"],
                              h1.batch_at(0)["tokens"])


def test_tokens_within_vocab():
    cfg = reduced_config("granite-moe-1b-a400m")
    d = SyntheticLMData(cfg, smoke_shape("train", 16, 4))
    t = d.batch_at(0)["tokens"]
    assert t.min() >= 0 and t.max() < cfg.vocab_size


def test_encdec_and_vlm_fields():
    dw = SyntheticLMData(reduced_config("whisper-tiny"),
                         smoke_shape("train", 16, 2))
    b = dw.batch_at(0)
    assert "frames" in b and b["frames"].shape[1] == 8
    dv = SyntheticLMData(reduced_config("qwen2-vl-2b"),
                         smoke_shape("train", 16, 2))
    assert "positions" in dv.batch_at(0)
