"""Int8 weight serving at the model level: quantize_tree'd params flow
through every architecture's decode path and stay close to bf16."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.quant import quantize_tree
from repro.models.lm import build_model
from repro.testing import reduced_config


@pytest.mark.parametrize("arch", ["gemma2-9b", "rwkv6-1.6b", "hymba-1.5b",
                                  "granite-moe-1b-a400m"])
def test_int8_params_decode_close_to_bf16(arch, nosharder):
    cfg = reduced_config(arch)
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    qparams = quantize_tree(params)
    rng = np.random.default_rng(0)
    tokens = jnp.asarray(rng.integers(0, cfg.vocab_size, (2, 8)), jnp.int32)

    cache, logits = model.prefill(params, {"tokens": tokens}, nosharder,
                                  max_len=12)
    qcache, qlogits = model.prefill(qparams, {"tokens": tokens}, nosharder,
                                    max_len=12)
    nxt = jnp.argmax(logits, -1).astype(jnp.int32)
    _, logits2 = model.decode_step(params, cache, nxt, nosharder)
    _, qlogits2 = model.decode_step(qparams, qcache, nxt, nosharder)

    for a, b in ((logits, qlogits), (logits2, qlogits2)):
        scale = float(jnp.max(jnp.abs(a))) + 1e-9
        rel = float(jnp.max(jnp.abs(a - b))) / scale
        assert rel < 0.15, f"{arch}: int8 rel err {rel:.3f}"
        assert bool(jnp.all(jnp.isfinite(b)))


def test_int8_kv_cache_decode(nosharder):
    import dataclasses
    cfg = dataclasses.replace(reduced_config("gemma2-9b"),
                              kv_cache_dtype="int8")
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    rng = np.random.default_rng(1)
    tokens = jnp.asarray(rng.integers(0, cfg.vocab_size, (2, 8)), jnp.int32)
    cache, logits = model.prefill(params, {"tokens": tokens}, nosharder,
                                  max_len=12)
    assert cache["blocks"]["p0"]["k"].dtype == jnp.int8
    nxt = jnp.argmax(logits, -1).astype(jnp.int32)
    cache, logits2 = model.decode_step(params, cache, nxt, nosharder)
    assert bool(jnp.all(jnp.isfinite(logits2)))

    # compare against the bf16-cache model: same weights, small drift
    cfg16 = dataclasses.replace(cfg, kv_cache_dtype="bf16")
    m16 = build_model(cfg16)
    c16, l16 = m16.prefill(params, {"tokens": tokens}, nosharder, max_len=12)
    _, l16b = m16.decode_step(params, c16, nxt, nosharder)
    scale = float(jnp.max(jnp.abs(l16b))) + 1e-9
    assert float(jnp.max(jnp.abs(l16b - logits2))) / scale < 0.1
