"""Sharded end-to-end decode: the continuous-batching engine under a
non-trivial Sharder on a small mesh produces the same tokens as the
``mesh=None`` replicated path, and the engine's load counters track work."""

import os
import subprocess
import sys

import pytest

from repro.dist.sharding import Sharder, make_rules
from repro.models.lm import build_model
from repro.serving import ServingEngine
from repro.testing import reduced_config


def test_engine_stats_counters_track_load():
    cfg = reduced_config("rwkv6-1.6b")
    model = build_model(cfg)
    import jax
    params = model.init(jax.random.PRNGKey(0))
    eng = ServingEngine(model, params, Sharder(None, {}), max_batch=2,
                        max_len=32)
    reqs = [eng.submit([1, 2, 3, 4 + i], max_new_tokens=4) for i in range(3)]
    eng.run()
    s = eng.stats()
    assert s["completed"] == 3
    assert s["total_tokens"] == sum(len(r.output) for r in reqs) == 12
    assert s["active"] == 0 and s["queued"] == 0


def test_decode_rules_shard_cache_not_heads():
    """Decode needs no head divisibility: the cache dim takes the model
    axis; train/prefill give it to heads (or qseq) instead."""
    cfg = reduced_config("gemma3-12b")
    dec = make_rules(cfg, "decode")
    assert dec["cache_seq"] == ("model",)
    assert "heads" not in dec and "qseq" not in dec
    pre = make_rules(cfg, "prefill")
    assert pre["heads"] == ("model",)


SHARDED_DECODE = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
import jax
import jax.numpy as jnp
import numpy as np
from repro.dist.sharding import Sharder, make_sharder
from repro.launch.mesh import make_test_mesh
from repro.models.lm import build_model
from repro.serving import ServingEngine
from repro.testing import reduced_config

cfg = reduced_config("rwkv6-1.6b")
model = build_model(cfg)
params = model.init(jax.random.PRNGKey(0))
nosh = Sharder(None, {})
mesh = make_test_mesh((2, 2), ("data", "model"))
sharder = make_sharder(cfg, mesh, "decode")
# the rules must actually resolve on this mesh (non-trivial sharding)
assert sharder.resolve("batch", 2) == ("data",)
assert sharder.resolve("mlp", cfg.d_ff) == ("model",)

# --- numerical equivalence, teacher-forced over prefill + 4 decode steps.
# bf16 reductions reorder under sharding (~1e-2 logit wobble on a ~3 logit
# scale), so compare logits with tolerance rather than argmax'd tokens.
batch = {"tokens": jnp.asarray([[5, 9, 3, 7], [2, 4, 6, 8]], jnp.int32)}
c_r, l_r = jax.jit(lambda p, b: model.prefill(p, b, nosh, max_len=16))(
    params, batch)
c_s, l_s = jax.jit(lambda p, b: model.prefill(p, b, sharder, max_len=16))(
    params, batch)
np.testing.assert_allclose(np.asarray(l_r, np.float32),
                           np.asarray(l_s, np.float32), atol=0.15)
dec_r = jax.jit(lambda p, c, t: model.decode_step(p, c, t, nosh))
dec_s = jax.jit(lambda p, c, t: model.decode_step(p, c, t, sharder))
toks = jnp.argmax(l_r, -1).astype(jnp.int32)
for _ in range(4):
    c_r, l_r = dec_r(params, c_r, toks)
    c_s, l_s = dec_s(params, c_s, toks)
    np.testing.assert_allclose(np.asarray(l_r, np.float32),
                               np.asarray(l_s, np.float32), atol=0.15)
    toks = jnp.argmax(l_r, -1).astype(jnp.int32)

# --- the engine end-to-end under the sharded Sharder: continuous batching
# completes every request and the counters track the work
prompts = [[5, 9, 3, 7], [2, 4, 6, 8, 10], [11, 1, 12], [3, 3, 3, 3, 3, 3]]
eng = ServingEngine(model, params, sharder, max_batch=2, max_len=32)
reqs = [eng.submit(list(p), max_new_tokens=6) for p in prompts]
eng.run()
assert all(r.done and len(r.output) == 6 for r in reqs)
stats = eng.stats()
assert stats["completed"] == len(prompts)
assert stats["total_tokens"] == sum(len(r.output) for r in reqs)
print("OK")
"""


@pytest.mark.slow
def test_sharded_decode_matches_replicated():
    """Decode under a (data, model) mesh Sharder matches the mesh=None
    replicated path numerically (teacher-forced), and the engine serves
    end-to-end under the sharded layout."""
    r = subprocess.run([sys.executable, "-c", SHARDED_DECODE],
                       capture_output=True, text=True, timeout=900,
                       env={**os.environ, "PYTHONPATH": "src"}, cwd=".")
    assert r.returncode == 0, r.stderr[-3000:]
    assert "OK" in r.stdout
