"""Property harness for the paged slot-state manager (PR 7).

The contract under test: :class:`repro.serving.paged.PagedSlotManager` is
a drop-in replacement for the dense :class:`SlotManager` — under ANY
interleaving of grant / release / preempt / resume / snapshot_many /
decode ops, the paged engine produces bit-identical schedules, logits
(via the tokens they argmax to), and live cache state, while its block
pools keep their accounting invariants (no leak, no double-allocation,
free-count conservation) after every operation.

Driven through the hypothesis stub (tests/conftest.py installs it when
the real package is absent): each property replays over deterministic
pseudo-random seeds, and a failing seed is reproducible from the
assertion traceback.  Three architectures pin the three cache families:
rwkv6 (pure recurrent — no pools at all), qwen2.5 (dense attention — KV
rings page), hymba (hybrid attention + SSM + conv — paged rings next to
per-slot state).
"""

import jax
import numpy as np
import pytest
from hypothesis import given, settings
from hypothesis import strategies as st

from repro.dist.sharding import Sharder
from repro.models.lm import build_model
from repro.serving import ServingEngine
from repro.serving.paged import BlockPool, PagedSlotManager, \
    canonicalize_cache
from repro.serving.slotstate import SlotManager, gather_slots, \
    make_slot_manager
from repro.testing import reduced_config

ARCHS = ("rwkv6-1.6b", "qwen2.5-14b", "hymba-1.5b")
MAX_LEN = 32
BLOCK = 8


@pytest.fixture(scope="module")
def built():
    cache = {}

    def get(arch):
        if arch not in cache:
            cfg = reduced_config(arch)
            model = build_model(cfg)
            params = model.init(jax.random.PRNGKey(0))
            cache[arch] = (cfg, model, params, Sharder(None, {}))
        return cache[arch]

    return get


def _assert_trees_equal(a, b, what: str) -> None:
    fa = jax.tree_util.tree_leaves_with_path(a)
    fb = jax.tree_util.tree_leaves_with_path(b)
    assert len(fa) == len(fb), f"{what}: leaf count differs"
    for (pa, la), (pb, lb) in zip(fa, fb):
        assert pa == pb, f"{what}: leaf order differs ({pa} vs {pb})"
        np.testing.assert_array_equal(
            np.asarray(la), np.asarray(lb),
            err_msg=f"{what}: leaf {jax.tree_util.keystr(pa)} differs")


def _occupied_columns(engine):
    """The live (occupied-slot) cache columns, canonicalized: masked ring
    entries zeroed so dense and paged — which legitimately differ only in
    masked garbage — compare bit-equal iff their live state does."""
    occ = engine.sm.occupied()
    if not occ:
        return occ, None
    cols = jax.device_get(gather_slots(engine.sm.cache, engine.sm.axes, occ))
    return occ, canonicalize_cache(cols)


def _compare_engines(dense, paged, what: str) -> None:
    assert dense.sm.occupied() == paged.sm.occupied(), \
        f"{what}: occupancy diverged"
    occ_d, cols_d = _occupied_columns(dense)
    occ_p, cols_p = _occupied_columns(paged)
    if cols_d is not None:
        _assert_trees_equal(cols_d, cols_p, what)
    np.testing.assert_array_equal(dense.sm.next_token, paged.sm.next_token,
                                  err_msg=f"{what}: next_token mirrors")
    paged.sm.check_invariants()


def _lockstep(built, arch: str, seed: int, *, n_ops: int = 24,
              max_batch: int = 3) -> None:
    """Drive a dense and a paged engine through one identical random op
    script, comparing live state after every op and pool invariants after
    every op; then drain both and compare the complete schedules."""
    cfg, model, params, sharder = built(arch)
    rng = np.random.default_rng(seed)

    def make(layout):
        return ServingEngine(model, params, sharder, max_batch=max_batch,
                             max_len=MAX_LEN, seed=11, cache_layout=layout)

    dense, paged = make("dense"), make(f"paged:{BLOCK}")
    reqs_d, reqs_p = [], []
    for op_i in range(n_ops):
        op = rng.choice(("submit", "step", "step", "preempt"))
        if op == "submit":
            n = int(rng.integers(1, 13))
            prompt = [int(t) for t in rng.integers(0, cfg.vocab_size, n)]
            max_new = int(rng.integers(1, 7))
            reqs_d.append(dense.submit(list(prompt), max_new_tokens=max_new))
            reqs_p.append(paged.submit(list(prompt), max_new_tokens=max_new))
        elif op == "step":
            dense.step()
            paged.step()
        else:
            occ = dense.sm.occupied()
            k = int(rng.integers(0, len(occ) + 1))
            victims = [int(s) for s in rng.choice(occ, size=k,
                                                  replace=False)] if k else []
            dense.preempt_many(list(victims))
            paged.preempt_many(list(victims))
        _compare_engines(dense, paged, f"{arch} seed={seed} op[{op_i}]={op}")
    dense.run()
    paged.run()
    _compare_engines(dense, paged, f"{arch} seed={seed} drained")
    sched_d = [(r.output, r.t_submit, r.t_admit, r.t_first, r.t_done,
                r.n_preempts) for r in reqs_d]
    sched_p = [(r.output, r.t_submit, r.t_admit, r.t_first, r.t_done,
                r.n_preempts) for r in reqs_p]
    assert sched_d == sched_p, f"{arch} seed={seed}: schedules diverged"
    assert dense.stats() == paged.stats(), \
        f"{arch} seed={seed}: stats diverged"


@pytest.mark.parametrize("arch", ARCHS)
@settings(max_examples=3, deadline=None)
@given(seed=st.integers(min_value=0, max_value=2**31 - 1))
def test_random_interleavings_bit_exact(built, arch, seed):
    """THE property: any grant/release/preempt/resume/decode interleaving
    leaves dense and paged engines bit-identical in schedule, live cache
    columns, and stats, with clean pool invariants throughout."""
    _lockstep(built, arch, seed)


# ---------------------------------------------------------------------------
# Manager-level edges: snapshot_many / restore / grant / release.
# ---------------------------------------------------------------------------


class _FakeReq:
    def __init__(self, prompt_len=4, max_new_tokens=4):
        self.prompt = [1] * prompt_len
        self.output = []
        self.max_new_tokens = max_new_tokens
        self.eos_id = None


LAYOUTS = ("dense", f"paged:{BLOCK}")


def _manager(built, arch, layout, max_batch=3):
    _, model, _, _ = built(arch)
    return make_slot_manager(model, max_batch, MAX_LEN, layout=layout)


@pytest.mark.parametrize("layout", LAYOUTS, ids=("dense", "paged"))
def test_layout_factory(built, layout):
    sm = _manager(built, "rwkv6-1.6b", layout)
    assert isinstance(sm, PagedSlotManager) == (layout != "dense")
    assert isinstance(sm, SlotManager)


@pytest.mark.parametrize("layout", LAYOUTS, ids=("dense", "paged"))
def test_snapshot_many_empty_is_noop(built, layout):
    sm = _manager(built, "qwen2.5-14b", layout)
    assert sm.snapshot_many([]) == []
    assert sm.metrics["slots.snapshots"].value == 0


@pytest.mark.parametrize("layout", LAYOUTS, ids=("dense", "paged"))
def test_snapshot_many_rejects_duplicates_and_unoccupied(built, layout):
    sm = _manager(built, "qwen2.5-14b", layout)
    sm.grant(0, _FakeReq(), next_token=5)
    with pytest.raises(ValueError, match="duplicate"):
        sm.snapshot_many([0, 0])
    with pytest.raises(ValueError, match="unoccupied"):
        sm.snapshot_many([0, 1])


@pytest.mark.parametrize("layout", LAYOUTS, ids=("dense", "paged"))
def test_grant_release_restore_occupancy_errors(built, layout):
    sm = _manager(built, "qwen2.5-14b", layout)
    req = _FakeReq()
    sm.grant(1, req, next_token=5)
    with pytest.raises(ValueError, match="occupied"):
        sm.grant(1, _FakeReq(), next_token=6)
    (snap,) = sm.snapshot_many([1])
    with pytest.raises(ValueError, match="occupied"):
        sm.restore(1, snap, req)
    sm.release(1)
    with pytest.raises(ValueError, match="already-free"):
        sm.release(1)
    sm.restore(1, snap, req)          # free again: restore is legal now
    assert sm.occupied() == [1]


@pytest.mark.parametrize("arch", ("qwen2.5-14b", "hymba-1.5b"))
def test_restore_into_different_slot_cross_layout(built, arch):
    """A dense snapshot restored into a *different-index* slot of a paged
    manager (and vice versa) carries bit-identical live state: snapshots
    are layout- and slot-portable."""
    cfg, model, params, sharder = built(arch)

    def run_and_snap(layout):
        eng = ServingEngine(model, params, sharder, max_batch=3,
                            max_len=MAX_LEN, seed=3, cache_layout=layout)
        req = eng.submit([7, 3, 9, 2, 8], max_new_tokens=8)
        for _ in range(3):
            eng.step()
        (snap,) = eng.sm.snapshot_many([0])
        eng.sm.release(0)
        return eng, req, snap

    eng_d, req_d, snap_d = run_and_snap("dense")
    eng_p, req_p, snap_p = run_and_snap(f"paged:{BLOCK}")
    # cross-restore, each into a different free slot index
    eng_p.sm.restore(2, snap_d, req_p)
    eng_d.sm.restore(1, snap_p, req_d)
    eng_p.sm.check_invariants()
    col_d = canonicalize_cache(jax.device_get(
        gather_slots(eng_d.sm.cache, eng_d.sm.axes, [1])))
    col_p = canonicalize_cache(jax.device_get(
        gather_slots(eng_p.sm.cache, eng_p.sm.axes, [2])))
    _assert_trees_equal(col_d, col_p, f"{arch} cross-layout restore")


@pytest.mark.parametrize("layout", LAYOUTS, ids=("dense", "paged"))
def test_snapshot_restore_roundtrip_bit_exact(built, layout):
    """snapshot -> release -> restore into another slot leaves the live
    column bit-identical to the original (same manager, either layout)."""
    cfg, model, params, sharder = built("hymba-1.5b")
    eng = ServingEngine(model, params, sharder, max_batch=3,
                        max_len=MAX_LEN, seed=5, cache_layout=layout)
    req = eng.submit([4, 8, 15, 16, 23, 42], max_new_tokens=8)
    for _ in range(4):
        eng.step()
    before = canonicalize_cache(jax.device_get(
        gather_slots(eng.sm.cache, eng.sm.axes, [0])))
    (snap,) = eng.sm.snapshot_many([0])
    eng.sm.release(0)
    eng.sm.restore(2, snap, req)
    after = canonicalize_cache(jax.device_get(
        gather_slots(eng.sm.cache, eng.sm.axes, [2])))
    _assert_trees_equal(before, after, f"{layout} roundtrip")


# ---------------------------------------------------------------------------
# BlockPool unit invariants.
# ---------------------------------------------------------------------------


def test_block_pool_cover_release_conservation():
    pool = BlockPool(ring_len=32, block_size=8, max_batch=3)
    assert pool.n_pages == 4 and pool.capacity == 13
    assert pool.cover(0, 9)           # 2 pages
    assert not pool.cover(0, 9)       # idempotent: no change
    assert not pool.cover(0, 3)       # never shrinks
    assert pool.cover(1, 32)          # full ring
    assert not pool.cover(1, 500)     # capped at the ring
    pool.check(occupied=[0, 1])
    assert len(pool.free_list) == 12 - 2 - 4
    freed = pool.release(0)
    assert len(freed) == 2 and pool.release(0) == []   # second release: noop
    pool.check(occupied=[1])
    assert len(pool.free_list) == 12 - 4
    pool.release(1)
    pool.check(occupied=[])
    assert pool.free_list == list(range(1, 13))        # full conservation


def test_block_pool_flat_index_routes_through_table():
    pool = BlockPool(ring_len=8, block_size=4, max_batch=2)
    pool.cover(1, 8)                   # slot 1 gets blocks, slot 0 none
    idx = pool.flat_index().reshape(2, 8)
    # slot 0 is unallocated: every position routes to the null block
    assert set(idx[0] // pool.block) == {0}
    # slot 1: positions map contiguously through its two allocated blocks
    b0, b1 = pool.table[1, 0], pool.table[1, 1]
    np.testing.assert_array_equal(
        idx[1], np.r_[b0 * 4 + np.arange(4), b1 * 4 + np.arange(4)])


@settings(max_examples=10, deadline=None)
@given(block=st.integers(min_value=1, max_value=40),
       ring=st.sampled_from((8, 24, 32)),
       seed=st.integers(min_value=0, max_value=10**6))
def test_block_pool_random_ops_keep_invariants(block, ring, seed):
    """Random cover/release sequences never leak, double-allocate, or
    break free-count conservation, at any block size (including blocks
    larger than the ring, which clamp)."""
    rng = np.random.default_rng(seed)
    pool = BlockPool(ring_len=ring, block_size=block, max_batch=4)
    occupied = set()
    for _ in range(50):
        slot = int(rng.integers(0, 4))
        if rng.random() < 0.65:
            pool.cover(slot, int(rng.integers(0, 2 * ring)))
            occupied.add(slot)
        else:
            pool.release(slot)
            occupied.discard(slot)
        pool.check(occupied=sorted(occupied))


# ---------------------------------------------------------------------------
# Fragmentation gauges: the memory claim behind the layout.
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("arch", ("qwen2.5-14b", "hymba-1.5b"))
def test_paged_bytes_resident_never_exceeds_dense(built, arch):
    cfg, model, params, sharder = built(arch)

    def engines():
        for lay in LAYOUTS:
            yield ServingEngine(model, params, sharder, max_batch=3,
                                max_len=MAX_LEN, seed=2, cache_layout=lay)

    dense, paged = engines()
    assert paged.sm.bytes_resident() <= dense.sm.bytes_resident()
    for i in range(3):
        prompt = [1 + i, 2, 3]
        dense.submit(list(prompt), max_new_tokens=6)
        paged.submit(list(prompt), max_new_tokens=6)
    while dense.step() | paged.step():
        assert paged.sm.bytes_resident() <= dense.sm.bytes_resident()
        assert paged.sm.padding_waste() <= dense.sm.padding_waste()
    # drained: paged drops to its floor (null blocks + tables only)
    assert paged.sm.tokens_in_flight() == 0
    assert paged.sm.blocks_free() == sum(
        p.capacity - 1 for p in paged.sm._pools.values())


# ---------------------------------------------------------------------------
# Fault interleavings (PR 8): inject/quarantine/retry under dense vs paged.
# The recovery layer (numeric guard, scrub, rollback, watchdog) routes
# through gather/scatter/release — exactly the ops the paged pools remap —
# so any fault interleaving must leave the two layouts bit-identical in
# surviving columns and outputs, with clean pool invariants throughout.
# ---------------------------------------------------------------------------


def _fault_lockstep(built, arch: str, seed: int, *, n_ops: int = 20,
                    max_batch: int = 3) -> None:
    from repro.plan.plan import ServingPlan
    from repro.serving import FaultInjector, FaultPlan, FaultSpec

    cfg, model, params, sharder = built(arch)
    rng = np.random.default_rng(seed)
    kinds = ("poison_slot", "stall_slot", "drop_readback", "fail_prefill")
    fplan = FaultPlan(tuple(
        FaultSpec(kind=kinds[int(rng.integers(0, len(kinds)))],
                  tick=int(rng.integers(1, n_ops)),
                  slot=int(rng.integers(0, max_batch)),
                  mode=("nan", "garbage")[int(rng.integers(0, 2))],
                  seed=seed + j)
        for j in range(3)))

    def make(layout):
        plan = ServingPlan(
            arch=arch, reduced=True, max_batch=max_batch, max_len=MAX_LEN,
            cache_layout=layout, retry_budget=2, watchdog_ticks=3,
            provenance={"source": "fault-lockstep"})
        eng = ServingEngine(model, params, sharder, seed=11, plan=plan)
        eng.attach_injector(FaultInjector(fplan))   # per-engine ledger
        return eng

    dense, paged = make("dense"), make(f"paged:{BLOCK}")
    reqs_d, reqs_p = [], []
    for op_i in range(n_ops):
        op = rng.choice(("submit", "step", "step"))
        if op == "submit":
            n = int(rng.integers(1, 13))
            prompt = [int(t) for t in rng.integers(0, cfg.vocab_size, n)]
            max_new = int(rng.integers(1, 7))
            reqs_d.append(dense.submit(list(prompt), max_new_tokens=max_new))
            reqs_p.append(paged.submit(list(prompt), max_new_tokens=max_new))
        else:
            dense.step()
            paged.step()
        _compare_engines(dense, paged,
                         f"{arch} seed={seed} op[{op_i}]={op}")
    dense.run()
    paged.run()
    _compare_engines(dense, paged, f"{arch} seed={seed} drained")
    out_d = [(r.output, r.done, r.shed, r.retries) for r in reqs_d]
    out_p = [(r.output, r.done, r.shed, r.retries) for r in reqs_p]
    assert out_d == out_p, f"{arch} seed={seed}: fault outcomes diverged"
    assert dense.fault_stats() == paged.fault_stats(), \
        f"{arch} seed={seed}: fault stats diverged"
    assert [e for e in dense.fault_events] == \
        [e for e in paged.fault_events], \
        f"{arch} seed={seed}: fault events diverged"


@pytest.mark.parametrize("arch", ARCHS)
@settings(max_examples=3, deadline=None)
@given(seed=st.integers(min_value=0, max_value=2**31 - 1))
def test_fault_interleavings_bit_exact(built, arch, seed):
    """Inject/quarantine/retry under any interleaving: dense and paged
    engines agree bit-for-bit on surviving cache columns, outputs,
    retries, shed set, fault events, and fault counters — and the paged
    pools keep their invariants through scrub/release recovery."""
    _fault_lockstep(built, arch, seed)
