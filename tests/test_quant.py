"""Property tests for the mixed-precision storage layer."""

import jax
import jax.numpy as jnp
import numpy as np
from hypothesis import given, settings, strategies as st

from repro.core.quant import (
    blocked_fp,
    dequantize_int8,
    quantize_int8,
    quantize_tree,
    serving_specs,
)
from repro.models.params import ParamSpec, tree_abstract


@settings(max_examples=30, deadline=None)
@given(
    rows=st.integers(2, 65),
    cols=st.integers(2, 65),
    axis=st.sampled_from([0, 1, -1]),
    scale_exp=st.integers(-8, 8),
    seed=st.integers(0, 2**16),
)
def test_int8_roundtrip_error_bound(rows, cols, axis, scale_exp, seed):
    """|x - deq(q(x))| <= amax / 127 per quantization slice, any scale."""
    rng = np.random.default_rng(seed)
    x = (rng.standard_normal((rows, cols)) * 2.0 ** scale_exp).astype(
        np.float32)
    q, scale = quantize_int8(jnp.asarray(x), axis=axis)
    deq = np.asarray(dequantize_int8(q, scale, jnp.float32))
    amax = np.max(np.abs(x), axis=axis, keepdims=True)
    bound = np.maximum(amax, 1e-8) / 127.0 * 0.5001 + 1e-8
    assert np.all(np.abs(deq - x) <= bound + 1e-6)


@settings(max_examples=20, deadline=None)
@given(block=st.sampled_from([4, 16, 32]), mant=st.integers(2, 6),
       seed=st.integers(0, 2**16))
def test_blocked_fp_error_scales_with_mantissa(block, mant, seed):
    rng = np.random.default_rng(seed)
    x = rng.standard_normal((8, 50)).astype(np.float32)
    y = np.asarray(blocked_fp(jnp.asarray(x), block=block,
                              mantissa_bits=mant, axis=-1))
    # error bounded by the block's shared-exponent quantization step
    xb = np.pad(x, ((0, 0), (0, (-x.shape[1]) % block)))
    blocks = xb.reshape(8, -1, block)
    amax = np.max(np.abs(blocks), axis=-1, keepdims=True)
    step = 2.0 ** (np.floor(np.log2(np.maximum(amax, 1e-30))) - (mant - 1))
    err = np.abs(blocks - np.pad(y, ((0, 0), (0, (-x.shape[1]) % block))
                                 ).reshape(8, -1, block))
    assert np.all(err <= step * 0.5001 + 1e-7)


def test_quantize_tree_and_serving_specs_align():
    """quantize_tree output structure == serving_specs(int8) abstract
    structure, so serving in_shardings line up."""
    specs = {
        "big": ParamSpec((128, 512), jnp.float32, ("embed", "mlp")),
        "norm": ParamSpec((512,), jnp.float32, (None,)),
        "embedding": ParamSpec((1024, 128), jnp.float32, ("vocab", "embed")),
    }
    params = {
        "big": jnp.ones((128, 512)),
        "norm": jnp.ones((512,)),
        "embedding": jnp.ones((1024, 128)),
    }
    q = quantize_tree(params)
    s = tree_abstract(serving_specs(specs, int8=True))
    assert jax.tree_util.tree_structure(q) == jax.tree_util.tree_structure(s)
    assert q["big"]["q"].dtype == jnp.int8
    assert q["norm"].dtype == jnp.bfloat16
    assert q["embedding"].dtype == jnp.bfloat16  # embeddings stay wide
    # shapes match the abstract serving tree
    chk = jax.tree.map(lambda a, b: a.shape == b.shape, q, s)
    assert all(jax.tree.leaves(chk))


def test_wcast_dequantizes_within_bound():
    from repro.models.layers import wcast
    rng = np.random.default_rng(0)
    w = jnp.asarray(rng.standard_normal((256, 300)), jnp.float32)
    q = quantize_tree({"w": w})["w"]
    deq = wcast(q, jnp.float32)
    amax = jnp.max(jnp.abs(w), axis=0)
    assert float(jnp.max(jnp.abs(deq - w) / (amax / 127.0 + 1e-9))) < 0.51
