"""Pallas kernel validation: shape/dtype sweeps against pure-jnp oracles,
interpret=True on CPU (assignment deliverable c)."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.cells import RNNCellConfig, init_weights, quantize_weights
from repro.core.quant import quantize_int8
from repro.kernels.flash_attention.flash_attention import flash_attention
from repro.kernels.flash_attention.ref import attention_ref
from repro.kernels.fused_rnn import ops as rnn_ops
from repro.kernels.fused_rnn import ref as rnn_ref
from repro.kernels.matmul_int8.matmul_int8 import matmul_w8a16
from repro.kernels.matmul_int8.ref import matmul_w8a16_ref


# ---------------------------------------------------------------------------
# fused RNN
# ---------------------------------------------------------------------------

RNN_SWEEP = [
    ("lstm", 128, 1, 4, "int8", 64),
    ("lstm", 256, 2, 3, "int8", 128),
    ("lstm", 256, 1, 3, "bf16", 256),
    ("lstm", 512, 4, 2, "int8", 128),
    ("gru", 128, 1, 4, "int8", 128),
    ("gru", 256, 2, 3, "bf16", 64),
    ("gru", 512, 1, 2, "int8", 512),
]


@pytest.mark.parametrize("cell,H,B,T,prec,bh", RNN_SWEEP)
def test_fused_rnn_vs_ref(cell, H, B, T, prec, bh):
    cfg = RNNCellConfig(cell, H, timesteps=T, batch=B, precision=prec)
    w = quantize_weights(cfg, init_weights(cfg, jax.random.PRNGKey(0)))
    x = jax.random.normal(jax.random.PRNGKey(1), (T, B, cfg.d), jnp.bfloat16)
    y = rnn_ops.serve(cfg, w, x, bh=bh, interpret=True)
    wx, wh, sx, sh = rnn_ops._weights_for_kernel(cfg, w)
    h0 = jnp.zeros((B, H))
    if cell == "lstm":
        y_ref, _, _ = rnn_ref.fused_lstm_ref(x, wx, wh, sx, sh, w["b"], h0, h0)
    else:
        y_ref, _ = rnn_ref.fused_gru_ref(
            x, wx, wh, sx, sh, w["b"], w.get("b_h", jnp.zeros_like(w["b"])), h0)
    np.testing.assert_allclose(np.asarray(y, np.float32),
                               np.asarray(y_ref, np.float32),
                               atol=2e-2, rtol=2e-2)


def test_fused_lstm_state_carry():
    """Final (h, c) outputs equal the oracle's final state."""
    cfg = RNNCellConfig("lstm", 128, timesteps=6, batch=2, precision="bf16")
    w = quantize_weights(cfg, init_weights(cfg, jax.random.PRNGKey(2)))
    x = jax.random.normal(jax.random.PRNGKey(3), (6, 2, 128), jnp.bfloat16)
    wx, wh, sx, sh = rnn_ops._weights_for_kernel(cfg, w)
    from repro.kernels.fused_rnn.fused_rnn import fused_lstm
    h0 = jnp.zeros((2, 128))
    y, hT, cT = fused_lstm(x, wx, wh, sx, sh, w["b"], h0, h0, bh=64,
                           interpret=True)
    y_ref, hT_ref, cT_ref = rnn_ref.fused_lstm_ref(x, wx, wh, sx, sh,
                                                   w["b"], h0, h0)
    np.testing.assert_allclose(np.asarray(hT), np.asarray(hT_ref),
                               atol=1e-2, rtol=1e-2)
    np.testing.assert_allclose(np.asarray(cT), np.asarray(cT_ref),
                               atol=1e-2, rtol=1e-2)


# ---------------------------------------------------------------------------
# flash attention
# ---------------------------------------------------------------------------

FLASH_SWEEP = [
    (1, 2, 256, 64, True, 0, 0.0, jnp.bfloat16),
    (2, 1, 256, 64, True, 64, 0.0, jnp.bfloat16),
    (1, 2, 512, 128, True, 0, 50.0, jnp.bfloat16),
    (1, 1, 256, 64, False, 0, 0.0, jnp.bfloat16),
    (1, 2, 256, 64, True, 0, 0.0, jnp.float32),
]


@pytest.mark.parametrize("B,H,S,d,causal,win,cap,dtype", FLASH_SWEEP)
def test_flash_attention_vs_ref(B, H, S, d, causal, win, cap, dtype):
    key = jax.random.PRNGKey(0)
    q = jax.random.normal(key, (B, H, S, d), dtype)
    k = jax.random.normal(jax.random.fold_in(key, 1), (B, H, S, d), dtype)
    v = jax.random.normal(jax.random.fold_in(key, 2), (B, H, S, d), dtype)
    out = flash_attention(q, k, v, causal=causal, window=win, softcap=cap,
                          bq=128, bk=128, interpret=True)
    ref = attention_ref(q, k, v, causal=causal, window=win, softcap=cap)
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(ref, np.float32),
                               atol=2e-2, rtol=2e-2)


# ---------------------------------------------------------------------------
# W8A16 matmul
# ---------------------------------------------------------------------------

MM_SWEEP = [
    (128, 256, 512, "none", None),
    (256, 512, 256, "silu", True),
    (128, 128, 128, "gelu", True),
    (512, 256, 128, "relu", None),
]


@pytest.mark.parametrize("M,K,N,act,with_bias", MM_SWEEP)
def test_matmul_w8a16_vs_ref(M, K, N, act, with_bias):
    key = jax.random.PRNGKey(0)
    x = jax.random.normal(key, (M, K), jnp.bfloat16)
    w = jax.random.normal(jax.random.fold_in(key, 1), (K, N)) / np.sqrt(K)
    wq, sc = quantize_int8(w, axis=0)
    b = (jax.random.normal(jax.random.fold_in(key, 2), (N,)) * 0.1
         if with_bias else None)
    out = matmul_w8a16(x, wq, sc[0], b, act=act, bm=128, bn=128, bk=128,
                       interpret=True)
    ref = matmul_w8a16_ref(x, wq, sc[0], b, act=act)
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(ref, np.float32),
                               atol=2e-2, rtol=2e-2)


# ---------------------------------------------------------------------------
# PR 9: plan-driven tiles, persistent fused decode, split-KV flash-decoding
# ---------------------------------------------------------------------------


def test_default_bh_is_batch_aware():
    """Regression pin: the serving tile must be scored at the *served*
    batch.  lstm H=4096 (bf16) wants bh=128 single-lane but the smaller
    bh=64 tile once 256 slots of state/io claim their VMEM share — the
    old code passed no max_batch and silently served the b=1 tile."""
    from repro.core.dse import best_plan
    cfg = RNNCellConfig("lstm", 4096, precision="bf16")
    assert rnn_ops.default_bh(cfg, 1) == best_plan(cfg, max_batch=1).bh == 128
    assert rnn_ops.default_bh(cfg, 256) == 64
    assert rnn_ops.default_bh(cfg, 256) != best_plan(cfg).bh


def test_fused_rnn_plan_tile_sweep():
    """serve() under every candidate tile (plus non-divisor plan tiles,
    which must snap) matches the bh=H run bitwise — tiling the H axis
    never changes a single output bit."""
    from repro.core.dse import candidate_tiles
    cfg = RNNCellConfig("gru", 64, timesteps=3, batch=2, precision="bf16")
    w = quantize_weights(cfg, init_weights(cfg, jax.random.PRNGKey(4)))
    x = jax.random.normal(jax.random.PRNGKey(5), (3, 2, cfg.d), jnp.bfloat16)
    base = np.asarray(rnn_ops.serve(cfg, w, x, bh=64, interpret=True))
    for bh in candidate_tiles(64) + [48, 100]:   # 48, 100 snap to 32, 64
        y = rnn_ops.serve(cfg, w, x, interpret=True, plan={"bh": bh})
        assert (np.asarray(y) == base).all(), bh


@pytest.mark.parametrize("cell", ["lstm", "gru"])
@pytest.mark.parametrize("prec", ["bf16", "int8"])
def test_fused_rnn_persistent_parity(cell, prec):
    """The persistent (weights-VMEM-resident) decode variant is the same
    math as the streaming kernel at bh=H — bitwise, plus tolerance vs the
    jnp oracle — and lowers to a different program (whole-weight constant
    BlockSpecs vs the streamed H tiles)."""
    cfg = RNNCellConfig(cell, 128, timesteps=5, batch=2, precision=prec)
    w = quantize_weights(cfg, init_weights(cfg, jax.random.PRNGKey(6)))
    x = jax.random.normal(jax.random.PRNGKey(7), (5, 2, cfg.d), jnp.bfloat16)
    y_stream = rnn_ops.serve(cfg, w, x, bh=128, interpret=True)
    y_pers = rnn_ops.serve(cfg, w, x, interpret=True,
                           plan={"persistent": True})
    assert (np.asarray(y_pers) == np.asarray(y_stream)).all()
    wx, wh, sx, sh = rnn_ops._weights_for_kernel(cfg, w)
    h0 = jnp.zeros((2, 128))
    if cell == "lstm":
        y_ref, _, _ = rnn_ref.fused_lstm_ref(x, wx, wh, sx, sh, w["b"],
                                             h0, h0)
    else:
        y_ref, _ = rnn_ref.fused_gru_ref(
            x, wx, wh, sx, sh, w["b"], w.get("b_h", jnp.zeros_like(w["b"])),
            h0)
    np.testing.assert_allclose(np.asarray(y_pers, np.float32),
                               np.asarray(y_ref, np.float32),
                               atol=2e-2, rtol=2e-2)


def test_fused_rnn_persistent_changes_lowering():
    cfg = RNNCellConfig("lstm", 128, timesteps=3, batch=1, precision="bf16")
    w = quantize_weights(cfg, init_weights(cfg, jax.random.PRNGKey(8)))
    x = jax.random.normal(jax.random.PRNGKey(9), (3, 1, cfg.d), jnp.bfloat16)

    def text(plan):
        fn = jax.jit(lambda xx: rnn_ops.serve(cfg, w, xx, interpret=True,
                                              plan=plan))
        return fn.lower(x).as_text()

    assert text({"persistent": True}) != text({"bh": 128})


def test_flash_attention_pos_matches_iota_path():
    """With explicit iota positions the position-array kernel must equal
    the iota-masking kernel bitwise — same masks, same math."""
    B, H, S, d = 1, 2, 256, 64
    key = jax.random.PRNGKey(0)
    q = jax.random.normal(key, (B, H, S, d), jnp.bfloat16)
    k = jax.random.normal(jax.random.fold_in(key, 1), (B, H, S, d),
                          jnp.bfloat16)
    v = jax.random.normal(jax.random.fold_in(key, 2), (B, H, S, d),
                          jnp.bfloat16)
    pos = jnp.broadcast_to(jnp.arange(S, dtype=jnp.int32), (B, S))
    for causal, win in ((True, 0), (True, 64), (False, 0)):
        base = flash_attention(q, k, v, causal=causal, window=win,
                               bq=128, bk=128, interpret=True)
        out = flash_attention(q, k, v, pos, pos, causal=causal, window=win,
                              bq=128, bk=128, interpret=True)
        assert (np.asarray(out) == np.asarray(base)).all(), (causal, win)


def test_flash_attention_pos_masks_padding():
    """-1 positions (right-padded bucketed prefill) mask those keys out:
    the valid prefix of the output must match the unpadded run."""
    B, H, S, d, n_valid = 1, 1, 256, 64, 200
    key = jax.random.PRNGKey(3)
    q = jax.random.normal(key, (B, H, S, d), jnp.bfloat16)
    k = jax.random.normal(jax.random.fold_in(key, 1), (B, H, S, d),
                          jnp.bfloat16)
    v = jax.random.normal(jax.random.fold_in(key, 2), (B, H, S, d),
                          jnp.bfloat16)
    pos = jnp.where(jnp.arange(S) < n_valid, jnp.arange(S), -1)
    pos = jnp.broadcast_to(pos.astype(jnp.int32), (B, S))
    out = flash_attention(q, k, v, pos, pos, causal=True,
                          bq=128, bk=128, interpret=True)
    ref = attention_ref(q[:, :, :n_valid], k[:, :, :n_valid],
                        v[:, :, :n_valid], causal=True)
    np.testing.assert_allclose(
        np.asarray(out[:, :, :n_valid], np.float32),
        np.asarray(ref, np.float32), atol=2e-2, rtol=2e-2)


def _decode_ref(q, kc, vc, kv_pos, q_pos, *, causal, window):
    """jnp oracle mirroring models.attention.decode_attention (without
    sharder/cfg): q (B,H,hd), caches (B,S,H,hd)."""
    s = jnp.einsum("bhd,bshd->bhs", q.astype(jnp.float32),
                   kc.astype(jnp.float32)) / np.sqrt(q.shape[-1])
    mask = kv_pos >= 0
    if causal:
        mask &= kv_pos <= q_pos[:, None]
    if window > 0:
        mask &= (q_pos[:, None] - kv_pos) < window
    s = jnp.where(mask[:, None, :], s, -1e30)
    p = jax.nn.softmax(s, axis=-1)
    return jnp.einsum("bhs,bshd->bhd", p, vc.astype(jnp.float32))


DECODE_SWEEP = [
    # (B, H, K, S, bk, causal, window, holes)
    (2, 2, 2, 256, 128, True, 0, False),
    (1, 4, 2, 256, 64, True, 64, False),     # GQA + sliding window
    (2, 2, 2, 256, 128, True, 0, True),      # ring-buffer holes (-1 slots)
    (1, 2, 2, 512, 512, False, 0, False),    # single chunk, non-causal
]


@pytest.mark.parametrize("B,H,K,S,bk,causal,window,holes", DECODE_SWEEP)
def test_flash_decode_vs_ref(B, H, K, S, bk, causal, window, holes):
    from repro.kernels.flash_attention import ops as flash_ops
    key = jax.random.PRNGKey(10)
    q = jax.random.normal(key, (B, H, 64), jnp.bfloat16)
    kc = jax.random.normal(jax.random.fold_in(key, 1), (B, S, K, 64),
                           jnp.bfloat16)
    vc = jax.random.normal(jax.random.fold_in(key, 2), (B, S, K, 64),
                           jnp.bfloat16)
    kv_pos = jnp.broadcast_to(jnp.arange(S, dtype=jnp.int32), (B, S))
    if holes:   # empty ring slots scattered through the cache
        kv_pos = jnp.where(jnp.arange(S) % 5 == 3, -1, kv_pos)
    q_pos = jnp.full((B,), S // 2, jnp.int32)
    out = flash_ops.decode(q, kc, vc, kv_pos, q_pos, causal=causal,
                           window=window, plan={"bk": bk}, interpret=True)
    ke, ve = flash_ops._expand_kv(kc, vc, H)
    ref = _decode_ref(q, ke, ve, kv_pos, q_pos, causal=causal,
                      window=window)
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(ref, np.float32),
                               atol=2e-2, rtol=2e-2)


def test_flash_decode_chunk_count_is_bit_exact():
    """Splitting the KV axis into more chunks only reorders the LSE
    combine across chunks of *identical* per-chunk partials — outputs
    must stay equal within bf16 rounding of the same math."""
    from repro.kernels.flash_attention import ops as flash_ops
    B, H, S = 1, 2, 512
    key = jax.random.PRNGKey(11)
    q = jax.random.normal(key, (B, H, 64), jnp.bfloat16)
    kc = jax.random.normal(jax.random.fold_in(key, 1), (B, S, H, 64),
                           jnp.bfloat16)
    vc = jax.random.normal(jax.random.fold_in(key, 2), (B, S, H, 64),
                           jnp.bfloat16)
    kv_pos = jnp.broadcast_to(jnp.arange(S, dtype=jnp.int32), (B, S))
    q_pos = jnp.full((B,), S - 1, jnp.int32)
    outs = [np.asarray(flash_ops.decode(q, kc, vc, kv_pos, q_pos,
                                        plan={"bk": bk}, interpret=True),
                       np.float32)
            for bk in (512, 256, 128)]
    for o in outs[1:]:
        np.testing.assert_allclose(o, outs[0], atol=2e-2, rtol=2e-2)


def test_attention_ops_snap_non_divisible_tiles():
    """A plan tuned for another shape degrades gracefully: bq/bk that do
    not divide the actual sequence snap to divisors instead of failing."""
    from repro.kernels.flash_attention import ops as flash_ops
    B, S, H, d = 1, 192, 2, 64           # 192 = 64*3: 128 does not divide
    key = jax.random.PRNGKey(12)
    q = jax.random.normal(key, (B, S, H, d), jnp.bfloat16)
    k = jax.random.normal(jax.random.fold_in(key, 1), (B, S, H, d),
                          jnp.bfloat16)
    v = jax.random.normal(jax.random.fold_in(key, 2), (B, S, H, d),
                          jnp.bfloat16)
    out = flash_ops.attention(q, k, v, causal=True, interpret=True,
                              plan={"bq": 128, "bk": 512})
    ref = attention_ref(q.transpose(0, 2, 1, 3), k.transpose(0, 2, 1, 3),
                        v.transpose(0, 2, 1, 3), causal=True)
    np.testing.assert_allclose(
        np.asarray(out.transpose(0, 2, 1, 3), np.float32),
        np.asarray(ref, np.float32), atol=2e-2, rtol=2e-2)


def test_qdot_plan_tiles():
    """qdot under a tile plan (including non-divisible bm/bn/bk, snapped)
    matches the plain ref."""
    from repro.kernels.matmul_int8 import ops as mm_ops
    key = jax.random.PRNGKey(13)
    M, K, N = 96, 256, 384
    x = jax.random.normal(key, (M, K), jnp.bfloat16)
    w = jax.random.normal(jax.random.fold_in(key, 1), (K, N)) / np.sqrt(K)
    wq, sc = quantize_int8(w, axis=0)
    leaf = {"q": wq, "scale": sc}
    ref = matmul_w8a16_ref(x, wq, sc[0], None)
    for plan in (None, {"bm": 256, "bn": 256, "bk": 512},
                 {"bm": 100, "bn": 130, "bk": 70}):
        out = mm_ops.qdot(x, leaf, interpret=True, plan=plan)
        np.testing.assert_allclose(np.asarray(out, np.float32),
                                   np.asarray(ref, np.float32),
                                   atol=2e-2, rtol=2e-2)
