"""Pallas kernel validation: shape/dtype sweeps against pure-jnp oracles,
interpret=True on CPU (assignment deliverable c)."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.cells import RNNCellConfig, init_weights, quantize_weights
from repro.core.quant import quantize_int8
from repro.kernels.flash_attention.flash_attention import flash_attention
from repro.kernels.flash_attention.ref import attention_ref
from repro.kernels.fused_rnn import ops as rnn_ops
from repro.kernels.fused_rnn import ref as rnn_ref
from repro.kernels.matmul_int8.matmul_int8 import matmul_w8a16
from repro.kernels.matmul_int8.ref import matmul_w8a16_ref


# ---------------------------------------------------------------------------
# fused RNN
# ---------------------------------------------------------------------------

RNN_SWEEP = [
    ("lstm", 128, 1, 4, "int8", 64),
    ("lstm", 256, 2, 3, "int8", 128),
    ("lstm", 256, 1, 3, "bf16", 256),
    ("lstm", 512, 4, 2, "int8", 128),
    ("gru", 128, 1, 4, "int8", 128),
    ("gru", 256, 2, 3, "bf16", 64),
    ("gru", 512, 1, 2, "int8", 512),
]


@pytest.mark.parametrize("cell,H,B,T,prec,bh", RNN_SWEEP)
def test_fused_rnn_vs_ref(cell, H, B, T, prec, bh):
    cfg = RNNCellConfig(cell, H, timesteps=T, batch=B, precision=prec)
    w = quantize_weights(cfg, init_weights(cfg, jax.random.PRNGKey(0)))
    x = jax.random.normal(jax.random.PRNGKey(1), (T, B, cfg.d), jnp.bfloat16)
    y = rnn_ops.serve(cfg, w, x, bh=bh, interpret=True)
    wx, wh, sx, sh = rnn_ops._weights_for_kernel(cfg, w)
    h0 = jnp.zeros((B, H))
    if cell == "lstm":
        y_ref, _, _ = rnn_ref.fused_lstm_ref(x, wx, wh, sx, sh, w["b"], h0, h0)
    else:
        y_ref, _ = rnn_ref.fused_gru_ref(
            x, wx, wh, sx, sh, w["b"], w.get("b_h", jnp.zeros_like(w["b"])), h0)
    np.testing.assert_allclose(np.asarray(y, np.float32),
                               np.asarray(y_ref, np.float32),
                               atol=2e-2, rtol=2e-2)


def test_fused_lstm_state_carry():
    """Final (h, c) outputs equal the oracle's final state."""
    cfg = RNNCellConfig("lstm", 128, timesteps=6, batch=2, precision="bf16")
    w = quantize_weights(cfg, init_weights(cfg, jax.random.PRNGKey(2)))
    x = jax.random.normal(jax.random.PRNGKey(3), (6, 2, 128), jnp.bfloat16)
    wx, wh, sx, sh = rnn_ops._weights_for_kernel(cfg, w)
    from repro.kernels.fused_rnn.fused_rnn import fused_lstm
    h0 = jnp.zeros((2, 128))
    y, hT, cT = fused_lstm(x, wx, wh, sx, sh, w["b"], h0, h0, bh=64,
                           interpret=True)
    y_ref, hT_ref, cT_ref = rnn_ref.fused_lstm_ref(x, wx, wh, sx, sh,
                                                   w["b"], h0, h0)
    np.testing.assert_allclose(np.asarray(hT), np.asarray(hT_ref),
                               atol=1e-2, rtol=1e-2)
    np.testing.assert_allclose(np.asarray(cT), np.asarray(cT_ref),
                               atol=1e-2, rtol=1e-2)


# ---------------------------------------------------------------------------
# flash attention
# ---------------------------------------------------------------------------

FLASH_SWEEP = [
    (1, 2, 256, 64, True, 0, 0.0, jnp.bfloat16),
    (2, 1, 256, 64, True, 64, 0.0, jnp.bfloat16),
    (1, 2, 512, 128, True, 0, 50.0, jnp.bfloat16),
    (1, 1, 256, 64, False, 0, 0.0, jnp.bfloat16),
    (1, 2, 256, 64, True, 0, 0.0, jnp.float32),
]


@pytest.mark.parametrize("B,H,S,d,causal,win,cap,dtype", FLASH_SWEEP)
def test_flash_attention_vs_ref(B, H, S, d, causal, win, cap, dtype):
    key = jax.random.PRNGKey(0)
    q = jax.random.normal(key, (B, H, S, d), dtype)
    k = jax.random.normal(jax.random.fold_in(key, 1), (B, H, S, d), dtype)
    v = jax.random.normal(jax.random.fold_in(key, 2), (B, H, S, d), dtype)
    out = flash_attention(q, k, v, causal=causal, window=win, softcap=cap,
                          bq=128, bk=128, interpret=True)
    ref = attention_ref(q, k, v, causal=causal, window=win, softcap=cap)
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(ref, np.float32),
                               atol=2e-2, rtol=2e-2)


# ---------------------------------------------------------------------------
# W8A16 matmul
# ---------------------------------------------------------------------------

MM_SWEEP = [
    (128, 256, 512, "none", None),
    (256, 512, 256, "silu", True),
    (128, 128, 128, "gelu", True),
    (512, 256, 128, "relu", None),
]


@pytest.mark.parametrize("M,K,N,act,with_bias", MM_SWEEP)
def test_matmul_w8a16_vs_ref(M, K, N, act, with_bias):
    key = jax.random.PRNGKey(0)
    x = jax.random.normal(key, (M, K), jnp.bfloat16)
    w = jax.random.normal(jax.random.fold_in(key, 1), (K, N)) / np.sqrt(K)
    wq, sc = quantize_int8(w, axis=0)
    b = (jax.random.normal(jax.random.fold_in(key, 2), (N,)) * 0.1
         if with_bias else None)
    out = matmul_w8a16(x, wq, sc[0], b, act=act, bm=128, bn=128, bk=128,
                       interpret=True)
    ref = matmul_w8a16_ref(x, wq, sc[0], b, act=act)
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(ref, np.float32),
                               atol=2e-2, rtol=2e-2)
