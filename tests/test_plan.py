"""repro.plan: ServingPlan round-trips, kwargs-shim equivalence, the
autotuner's determinism, deadline-aware shedding, batched eviction, and
the batch-aware kernel tile search."""

import json

import jax
import numpy as np
import pytest

from repro import hw
from repro.core import dse
from repro.core.cells import RNNCellConfig
from repro.dist.sharding import make_sharder
from repro.models.lm import build_model
from repro.plan import (
    ServingPlan,
    WorkloadProfile,
    default_buckets,
    from_dict,
    load_plan,
    save_plan,
    to_dict,
)
from repro.plan import io as plan_io
from repro.serving import ServingEngine, drive, profile_items
from repro.testing import reduced_config

ARCH = "rwkv6-1.6b"


@pytest.fixture(scope="module")
def built():
    cfg = reduced_config(ARCH)
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    sharder = make_sharder(cfg, None, "decode")
    return cfg, model, params, sharder


def _schedule(engine, n=6, max_new=5):
    reqs = [engine.submit([1 + i, 2, 3 + i], max_new_tokens=max_new)
            for i in range(n)]
    engine.run()
    return [(r.t_submit, r.t_admit, r.t_first, r.t_done, tuple(r.output))
            for r in reqs]


# ---------------------------------------------------------------------------
# Round trips
# ---------------------------------------------------------------------------


def test_plan_json_round_trip_identity(tmp_path):
    plan = ServingPlan(
        arch=ARCH, max_batch=8, max_len=64, buckets=(8, 16, 63),
        sync_every=4, policy="edf", preempt=True, shed_late=True,
        temperature=0.7, top_k=40,
        tile_plans={"rwkv": {"bh": 128, "resident": True}},
        provenance={"source": "test", "cli_overrides": {"policy": "edf"}})
    plan.validate()
    rt = from_dict(json.loads(json.dumps(to_dict(plan))))
    assert rt == plan
    # and through a file
    path = str(tmp_path / "plan.json")
    save_plan(plan, path)
    assert load_plan(path) == plan


def test_plan_default_resolves_to_historical_buckets():
    plan = ServingPlan(arch=ARCH, max_len=64)
    assert plan.resolved_buckets() == (8, 16, 32, 63)
    assert default_buckets(128) == (8, 16, 32, 64, 127)
    resolved = plan.resolve()
    assert resolved.buckets == (8, 16, 32, 63)
    assert from_dict(to_dict(resolved)) == resolved


def test_plan_validate_rejects_bad_values():
    good = ServingPlan(arch=ARCH, max_len=64)
    good.validate()
    bad = [
        dict(max_batch=0),
        dict(sync_every=0),
        dict(max_len=1),
        dict(policy="nope"),
        dict(policy="fcfs", preempt=True),       # non-preemptive policy
        dict(buckets=(16, 8, 63)),               # not increasing
        dict(buckets=(8, 16, 32)),               # does not end at max_len-1
        dict(temperature=-1.0),
        dict(cache_layout="sparse"),             # unknown layout
        dict(cache_layout="paged:0"),            # block must be >= 1
        dict(cache_layout="paged:65"),           # block exceeds max_len
    ]
    import dataclasses
    for kw in bad:
        with pytest.raises(ValueError):
            dataclasses.replace(good, **kw).validate()


def test_plan_schema_guard_passes():
    plan_io.check_schema()


def test_workload_profile_round_trip():
    wp = WorkloadProfile(kind="poisson", rate=0.8, duration=128.0,
                         max_new_tokens=(6, 10), heavy_decode=(0.03, 32, 48),
                         deadline_slack=3.0)
    assert WorkloadProfile.from_json(
        json.loads(json.dumps(wp.to_json()))) == wp


# ---------------------------------------------------------------------------
# Engine: kwargs shim == from_plan
# ---------------------------------------------------------------------------


def test_kwargs_shim_matches_from_plan_bit_exact(built):
    cfg, model, params, sharder = built
    kwargs = dict(max_batch=2, max_len=32, sync_every=2, policy="spf")
    e1 = ServingEngine(model, params, sharder, seed=7, **kwargs)
    plan = ServingPlan(arch=ARCH, max_len=32, max_batch=2, sync_every=2,
                       policy="spf")
    e2 = ServingEngine.from_plan(plan, params, model=model, sharder=sharder,
                                 seed=7)
    assert _schedule(e1) == _schedule(e2)
    # the shim records an equivalent plan (provenance aside)
    import dataclasses
    assert dataclasses.replace(e1.plan, provenance={}, reduced=True) == \
        dataclasses.replace(e2.plan, provenance={}, reduced=True)


def test_explicit_bucket_set_drives_prefill_shapes(built):
    cfg, model, params, sharder = built
    plan = ServingPlan(arch=ARCH, max_len=64, max_batch=2,
                       buckets=(16, 63))
    eng = ServingEngine.from_plan(plan, params, model=model,
                                  sharder=sharder, seed=0)
    assert eng.bucket_lengths == [16, 63]
    assert eng.bucket(3) == 16 and eng.bucket(17) == 63
    eng.submit([1, 2, 3], max_new_tokens=2)
    eng.run()
    assert eng.prefill_shapes == {(2, 16)}


# ---------------------------------------------------------------------------
# Autotuner
# ---------------------------------------------------------------------------


@pytest.mark.slow
def test_autotune_deterministic_and_valid():
    from repro.plan import planner

    wp = WorkloadProfile(rate=0.8, duration=10.0, max_new_tokens=(6, 10),
                         deadline_slack=2.0)
    kw = dict(seed=3, max_len=64, max_batches=(2, 4), sync_everys=(1, 2, 4),
              probe_duration=10.0)
    a = planner.autotune(ARCH, wp, hw.DEFAULT, **kw)
    b = planner.autotune(ARCH, wp, hw.DEFAULT, **kw)
    assert a == b
    a.validate()
    assert from_dict(json.loads(json.dumps(to_dict(a)))) == a
    assert a.provenance["autotune"]["hw"] == hw.DEFAULT.name
    assert len(a.provenance["autotune"]["probes"]) >= 4
    # the recurrent arch embeds a batch-aware kernel tile plan
    assert "rwkv" in a.tile_plans and a.tile_plans["rwkv"]["bh"] >= 8


def test_pick_sync_every_pins_preemptive_plans_to_one():
    from repro.plan import planner

    assert planner.pick_sync_every(ARCH, 4, hw.DEFAULT, (1, 2, 4, 8),
                                   preempt=True) == 1


def test_candidate_bucket_sets_fit_workload():
    from repro.plan import planner

    sets = planner.candidate_bucket_sets([4, 5, 6, 30], max_len=64)
    assert sets[0] is None                       # pow2 default always there
    for bs in sets[1:]:
        assert bs[-1] == 63 and list(bs) == sorted(set(bs))


# ---------------------------------------------------------------------------
# Cache-layout search (dense vs. paged)
# ---------------------------------------------------------------------------


def test_parse_cache_layout_grammar():
    from repro.plan.plan import parse_cache_layout

    assert parse_cache_layout("dense") is None
    assert parse_cache_layout("paged:16") == 16
    assert parse_cache_layout("paged:1") == 1
    for bad in ("sparse", "paged", "paged:", "paged:x", "paged:0",
                "paged:-4", "paged:016", "PAGED:16"):
        with pytest.raises(ValueError):
            parse_cache_layout(bad)


def test_candidate_cache_layouts_dense_first_deduped():
    from repro.plan import planner

    lays = planner.candidate_cache_layouts(64, (32, 8, 8, 100, 0))
    assert lays[0] == "dense"                    # tie-break winner
    assert lays[1:] == ["paged:8", "paged:32"]   # sorted, deduped, in-range


def test_cache_layout_bytes_paged_tracks_load():
    """For an attention arch, paged bytes are far below dense at light
    per-slot load and above dense at saturation (the per-page overhead
    charge) — so the layout search has a real trade-off, and dense wins
    once every ring would be fully allocated anyway."""
    from repro.plan import planner

    arch, mb, ml = "qwen2.5-14b", 4, 64
    dense = planner.cache_layout_bytes(arch, mb, ml, "dense", 8.0)
    light = planner.cache_layout_bytes(arch, mb, ml, "paged:8", 8.0)
    full = planner.cache_layout_bytes(arch, mb, ml, "paged:8", float(ml))
    assert light < dense < full
    # a pure-recurrent arch has nothing to page: both layouts cost the
    # per-slot state, so dense (enumerated first) wins the tie
    d = planner.cache_layout_bytes("rwkv6-1.6b", mb, ml, "dense", 8.0)
    p = planner.cache_layout_bytes("rwkv6-1.6b", mb, ml, "paged:8", 8.0)
    assert p == d


@pytest.mark.slow
def test_autotune_layout_choice_and_provenance():
    """The autotuner records the layout comparison in provenance and
    picks paged for an attention arch under a light-tailed workload
    (expected tokens far below max_len), dense for a pure-recurrent
    arch (nothing to page — tie goes to dense)."""
    from repro.plan import planner

    wp = WorkloadProfile(rate=0.3, duration=6.0, prompt_len=(2, 6),
                         max_new_tokens=(2, 4))
    kw = dict(seed=1, max_len=64, max_batches=(2,), sync_everys=(1,),
              probe_duration=6.0)
    qwen = planner.autotune("qwen2.5-14b", wp, hw.DEFAULT, **kw)
    assert qwen.cache_layout.startswith("paged:")
    prov = qwen.provenance["autotune"]
    assert prov["expected_tokens_per_slot"] <= 10.0
    recorded = {e["layout"]: e["modeled_bytes"] for e in
                prov["cache_layouts"]}
    assert qwen.cache_layout == min(recorded, key=recorded.get)
    assert recorded[qwen.cache_layout] < recorded["dense"]

    rwkv = planner.autotune(ARCH, wp, hw.DEFAULT, **kw)
    assert rwkv.cache_layout == "dense"


def test_expected_tokens_per_slot_p95():
    from repro.plan import planner
    from repro.serving.workload import WorkloadItem

    items = [WorkloadItem(t=0.0, prompt=[1] * p, max_new_tokens=4,
                          eos_id=None, deadline=None)
             for p in list(range(1, 20)) + [60]]
    t = planner.expected_tokens_per_slot(items, max_len=32)
    assert t == 23.0                     # p95 of prompt+4 capped at 32
    assert planner.expected_tokens_per_slot([], max_len=32) == 32.0


# ---------------------------------------------------------------------------
# Deadline-aware admission control (shed_late)
# ---------------------------------------------------------------------------


def test_shed_late_rejects_provably_late_only(built):
    cfg, model, params, sharder = built
    eng = ServingEngine(model, params, sharder, max_batch=2, max_len=32,
                        shed_late=True, policy="edf")
    # needs 8 ticks minimum; deadline 3 is provably late at tick 0
    late = eng.submit([1, 2, 3], max_new_tokens=8, deadline=3.0)
    assert late.shed and not late.done
    # deadline exactly at the earliest completion (tick 0 + 8) is feasible
    tight = eng.submit([1, 2, 3], max_new_tokens=8, deadline=8.0)
    assert not tight.shed
    # no deadline -> never shed
    free = eng.submit([1, 2, 3], max_new_tokens=8)
    assert not free.shed
    eng.run()
    assert tight.done and free.done and not late.done
    assert eng.stats()["shed"] == 1
    # the SLO block reports the shed count; shed requests count as misses
    from repro.serving import metrics as smetrics
    agg = smetrics.aggregate([late, tight, free], ticks=eng.ticks,
                             util_history=eng.util_history)
    assert agg["slo"]["shed"] == 1
    assert agg["slo"]["n"] == 2 and agg["slo"]["met"] == 1


def test_shed_disabled_by_default(built):
    cfg, model, params, sharder = built
    eng = ServingEngine(model, params, sharder, max_batch=2, max_len=32)
    r = eng.submit([1, 2, 3], max_new_tokens=8, deadline=1.0)
    assert not r.shed            # admission control is opt-in
    eng.run()
    assert r.done and eng.stats()["shed"] == 0


def test_shed_eos_requests_use_conservative_bound(built):
    cfg, model, params, sharder = built
    eng = ServingEngine(model, params, sharder, max_batch=2, max_len=32,
                        shed_late=True)
    # an eos_id request could retire at its prefill token, so only a
    # deadline earlier than one tick from now is provably late
    ok = eng.submit([1, 2, 3], max_new_tokens=8, eos_id=0, deadline=1.0)
    assert not ok.shed
    late = eng.submit([1, 2, 3], max_new_tokens=8, eos_id=0, deadline=0.5)
    assert late.shed


# ---------------------------------------------------------------------------
# Batched eviction
# ---------------------------------------------------------------------------


def test_snapshot_many_is_one_transfer_and_bit_exact(built, monkeypatch):
    cfg, model, params, sharder = built
    eng = ServingEngine(model, params, sharder, max_batch=3, max_len=32)
    for i in range(3):
        eng.submit([5 + i, 6, 7 + i], max_new_tokens=10)
    eng.step()
    eng.step()
    seq = [eng.sm.snapshot(i) for i in range(3)]

    calls = []
    real = jax.device_get

    def counting(x):
        calls.append(1)
        return real(x)

    monkeypatch.setattr(jax, "device_get", counting)
    batch = eng.sm.snapshot_many([0, 1, 2])
    assert len(calls) == 1                  # one transfer for all victims
    monkeypatch.undo()
    for s, b in zip(seq, batch):
        assert s.next_token == b.next_token
        for x, y in zip(jax.tree.leaves(s.cache_col),
                        jax.tree.leaves(b.cache_col)):
            assert np.asarray(x).dtype == np.asarray(y).dtype
            assert np.array_equal(np.asarray(x), np.asarray(y))


def test_preempt_many_matches_sequential_schedule(built):
    cfg, model, params, sharder = built

    def run(batched):
        eng = ServingEngine(model, params, sharder, max_batch=3, max_len=32,
                            seed=0)
        reqs = [eng.submit([5 + i, 6, 7], max_new_tokens=12)
                for i in range(3)]
        eng.step()
        if batched:
            eng.preempt_many([0, 2])
        else:
            # the pre-batching behavior: one snapshot per victim
            for slot in (0, 2):
                req = eng.sm.slots[slot]
                req.saved = eng.sm.snapshot(slot)
                req.n_preempts += 1
                req.t_preempts.append(eng.ticks)
                eng.metrics["engine.preemptions"].inc()
                eng.metrics["engine.evicted_tokens"].inc(len(req.output))
                eng.sm.release(slot)
                eng.scheduler.requeue_front(req)
        eng.run()
        assert all(r.done for r in reqs)
        return [(r.t_admit, r.t_done, tuple(r.output), r.n_preempts)
                for r in reqs]

    assert run(batched=True) == run(batched=False)


# ---------------------------------------------------------------------------
# dse: the serving batch dimension reaches the tile search
# ---------------------------------------------------------------------------


def test_tile_search_scores_serving_batch():
    cfg = RNNCellConfig("lstm", 4096, precision="bf16")
    # regression pin: at batch 1 the big 128-row tile is VMEM-resident;
    # at the serving batch the h/c state squeezes it out and the search
    # correctly drops to 64-row tiles
    assert dse.best_plan(cfg).bh == 128
    assert dse.best_plan(cfg, max_batch=256).bh == 64
    # vmem accounting actually moved
    assert dse.tile_vmem_bytes(cfg, 128, max_batch=256) > \
        dse.tile_vmem_bytes(cfg, 128)
    # default path unchanged (max_batch=None == cfg.batch)
    assert dse.plan_metrics(cfg, 128) == \
        dse.plan_metrics(cfg, 128, max_batch=cfg.batch)


def test_batched_decode_compute_bound_scales():
    cfg = RNNCellConfig("lstm", 1024, precision="bf16")
    p1 = dse.plan_metrics(cfg, 1024, max_batch=1)
    p256 = dse.plan_metrics(cfg, 1024, max_batch=256)
    assert p256.step_latency_s > p1.step_latency_s


# ---------------------------------------------------------------------------
# Benchmark surface
# ---------------------------------------------------------------------------


def test_serving_load_cell_converter_and_plan():
    from repro.configs import SERVING_LOAD_SWEEP, ServingLoadCell

    old = ServingLoadCell("rwkv6-1.6b", "rwkv", 2, 0.5)
    assert old.arch == "rwkv6-1.6b" and old.max_batch == 2
    assert old.rate == 0.5 and old.policy == "fcfs"
    assert old.plan.max_len == ServingLoadCell.MAX_LEN
    assert old.workload.max_new_tokens == ServingLoadCell.MAX_NEW
    # plan-first construction with a tag
    new = ServingLoadCell(family="rwkv", plan=old.plan,
                          workload=old.workload, tag="auto")
    assert new.name == old.name + "/auto"
    # every sweep cell carries a valid plan + workload
    for c in SERVING_LOAD_SWEEP:
        c.plan.validate()
        assert c.workload.rate > 0


@pytest.mark.slow
def test_run_cell_embeds_resolved_plan():
    from benchmarks import serving_load as sl
    from repro.configs import ServingLoadCell

    cell = ServingLoadCell("rwkv6-1.6b", "rwkv", 2, 0.5)
    out = sl.run_cell(cell, duration=8.0, seed=0)
    plan = plan_io.from_dict(out["plan"])
    plan.validate()
    assert plan.buckets is not None          # resolved: buckets explicit
    assert plan.arch == cell.arch and plan.max_batch == cell.max_batch
    # a cell re-run from its recorded plan reproduces the metrics
    recell = ServingLoadCell(family=cell.family, plan=plan,
                             workload=cell.workload)
    again = sl.run_cell(recell, duration=8.0, seed=0)
    assert again["metrics"] == out["metrics"]
