"""tier2: 512-fake-device dry-run smoke over the full (arch x shape) grid.

Every applicable cell of the assignment grid must lower + compile against
the 2x16x16 multi-pod production mesh (512 fake host devices) — the
full-scale analogue of the 8-device smoke in tests/test_sharding.py, and
the ROADMAP's "dry-run at 512 fake devices across the whole grid in CI"
item.  Each cell runs in its own subprocess because jax locks the device
count at first initialization (see repro.launch.dryrun).

Deselected by default (pytest.ini: ``-m "not tier2"``); the scheduled /
manually-dispatched job in .github/workflows/tier2.yml runs it with
``-m tier2``.  One cell can take minutes: full-size models, CPU XLA.
"""

import json
import os
import subprocess
import sys

import pytest

from repro.configs import grid

GRID = [(cfg.name, shape.name) for cfg, shape, runs, _ in grid() if runs]

CELL = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"
import json, sys
from repro.launch.dryrun import run_cell

cell = run_cell(sys.argv[1], sys.argv[2], multi_pod=True, pieces=False)
cell.pop("traceback", None)
print("CELL_JSON=" + json.dumps(
    {k: cell.get(k) for k in ("ok", "skip", "error", "chips", "wall_s")}))
"""


@pytest.mark.tier2
@pytest.mark.parametrize("arch,shape", GRID,
                         ids=[f"{a}-{s}" for a, s in GRID])
def test_dryrun_grid_cell_512_devices(arch, shape):
    r = subprocess.run(
        [sys.executable, "-c", CELL, arch, shape],
        capture_output=True, text=True, timeout=3600,
        env={**os.environ, "PYTHONPATH": "src",
             "JAX_PLATFORMS": "cpu"},
        cwd=os.path.dirname(os.path.dirname(os.path.abspath(__file__))))
    assert r.returncode == 0, r.stderr[-3000:]
    line = next(l for l in r.stdout.splitlines() if l.startswith("CELL_JSON="))
    cell = json.loads(line[len("CELL_JSON="):])
    assert cell["ok"] is True, cell
    assert cell["chips"] == 512
