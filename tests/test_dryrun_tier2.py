"""tier2: 512-fake-device dry-run smoke over the full (arch x shape) grid.

Every applicable cell of the assignment grid must lower + compile against
the 2x16x16 multi-pod production mesh (512 fake host devices) — the
full-scale analogue of the 8-device smoke in tests/test_sharding.py, and
the ROADMAP's "dry-run at 512 fake devices across the whole grid in CI"
item.  Each cell runs in its own subprocess because jax locks the device
count at first initialization (see repro.launch.dryrun).

Deselected by default (pytest.ini: ``-m "not tier2"``); the scheduled /
manually-dispatched job in .github/workflows/tier2.yml runs it with
``-m tier2``.  One cell can take minutes: full-size models, CPU XLA.
"""

import json
import os
import subprocess
import sys

import pytest

from repro.configs import grid

GRID = [(cfg.name, shape.name) for cfg, shape, runs, _ in grid() if runs]

CELL = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"
import json, sys
from repro.launch.dryrun import run_cell

cell = run_cell(sys.argv[1], sys.argv[2], multi_pod=True, pieces=False)
cell.pop("traceback", None)
print("CELL_JSON=" + json.dumps(
    {k: cell.get(k) for k in ("ok", "skip", "error", "chips", "wall_s")}))
"""


# ---------------------------------------------------------------------------
# tier2 paged-layout grid: dense ≡ paged across every serving-capable arch
# the tier-1 paged tests do NOT already cover, times block sizes.  tier-1
# pins rwkv6/qwen2.5/hymba (tests/test_paged_slotstate.py); this grid
# sweeps the rest — sliding-window rings (gemma2/gemma3 pool by *two* ring
# lengths), MoE routing, and starcoder2's GQA — under a longer open-loop
# workload, asserting bit-identical schedules + clean pool invariants.
# ---------------------------------------------------------------------------

PAGED_TIER2_GRID = [
    (arch, block)
    for arch in ("gemma2-9b", "gemma3-12b", "starcoder2-15b",
                 "granite-moe-1b-a400m", "qwen3-moe-30b-a3b")
    for block in (4, 16)
]


@pytest.mark.tier2
@pytest.mark.parametrize("arch,block", PAGED_TIER2_GRID,
                         ids=[f"{a}-b{b}" for a, b in PAGED_TIER2_GRID])
def test_paged_dense_equivalence_grid(arch, block):
    import jax

    from repro.dist.sharding import Sharder
    from repro.models.lm import build_model
    from repro.serving import ServingEngine, VirtualClock, drive, \
        make_workload
    from repro.testing import reduced_config

    cfg = reduced_config(arch)
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    sharder = Sharder(None, {})
    items = make_workload("poisson", rate=0.6, duration=24.0, seed=4,
                          vocab_size=cfg.vocab_size, prompt_len=(2, 12),
                          max_new_tokens=(2, 8))

    def serve(layout):
        eng = ServingEngine(model, params, sharder, max_batch=3,
                            max_len=32, seed=13, cache_layout=layout)
        reqs = drive(eng, [i for i in items], VirtualClock())
        return eng, [(r.uid, r.output, r.t_admit, r.t_first, r.t_done)
                     for r in reqs]

    eng_d, sched_d = serve("dense")
    eng_p, sched_p = serve(f"paged:{block}")
    assert sched_d == sched_p
    assert eng_d.stats() == eng_p.stats()
    eng_p.sm.check_invariants()


@pytest.mark.tier2
@pytest.mark.parametrize("arch,shape", GRID,
                         ids=[f"{a}-{s}" for a, s in GRID])
def test_dryrun_grid_cell_512_devices(arch, shape):
    r = subprocess.run(
        [sys.executable, "-c", CELL, arch, shape],
        capture_output=True, text=True, timeout=3600,
        env={**os.environ, "PYTHONPATH": "src",
             "JAX_PLATFORMS": "cpu"},
        cwd=os.path.dirname(os.path.dirname(os.path.abspath(__file__))))
    assert r.returncode == 0, r.stderr[-3000:]
    line = next(l for l in r.stdout.splitlines() if l.startswith("CELL_JSON="))
    cell = json.loads(line[len("CELL_JSON="):])
    assert cell["ok"] is True, cell
    assert cell["chips"] == 512


# ---------------------------------------------------------------------------
# tier2 chaos grid (PR 8): a seeded fault storm against every tier-1-
# pinned serving arch, dense and paged — each run must end with zero
# lost requests (every uid completes or is accountably shed) and clean
# pool invariants.  MoE archs are excluded on purpose: expert routing
# shares capacity across the batch, so a poisoned lane can contaminate
# co-tenants (see benchmarks/README.md, "Fault model & recovery").
# ---------------------------------------------------------------------------

CHAOS_GRID = [
    (arch, layout)
    for arch in ("rwkv6-1.6b", "qwen2.5-14b", "hymba-1.5b")
    for layout in ("dense", "paged:8")
]


@pytest.mark.tier2
@pytest.mark.parametrize("arch,layout", CHAOS_GRID,
                         ids=[f"{a}-{l}" for a, l in CHAOS_GRID])
def test_chaos_grid_zero_lost_requests(arch, layout, tmp_path):
    import jax

    from repro.checkpoint import CheckpointManager
    from repro.dist.sharding import Sharder
    from repro.models.lm import build_model
    from repro.plan.plan import ServingPlan
    from repro.serving import (FaultInjector, ServingEngine, VirtualClock,
                               drive_resilient, make_workload)
    from repro.serving.faults import make_storm
    from repro.testing import reduced_config

    cfg = reduced_config(arch)
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    plan = ServingPlan(arch=arch, reduced=True, max_batch=3, max_len=32,
                       cache_layout=layout, retry_budget=3,
                       watchdog_ticks=4,
                       provenance={"source": "tier2-chaos"}).resolve()
    items = make_workload("poisson", rate=0.6, duration=24.0, seed=4,
                          vocab_size=cfg.vocab_size, prompt_len=(2, 12),
                          max_new_tokens=(2, 8))
    storm = make_storm(duration=30, seed=17, n_faults=6, max_batch=3)
    eng = ServingEngine.from_plan(plan, params, model=model,
                                  sharder=Sharder(None, {}))
    rep = drive_resilient(eng, items, VirtualClock(),
                          injector=FaultInjector(storm),
                          manager=CheckpointManager(str(tmp_path)),
                          checkpoint_every=4)
    assert rep.lost_uids() == [], \
        f"{arch}/{layout}: lost requests {rep.lost_uids()}"
    assert len(rep.requests) == len(items)
    assert rep.engine.fault_stats()["injected"] >= 1
    if layout != "dense":
        rep.engine.sm.check_invariants()


# ---------------------------------------------------------------------------
# tier2 fleet grid: the multi-replica router over every tier-1-pinned
# serving arch x {2, 4} replicas x {colocated, disaggregated}.  Each cell
# drives a fleet of reduced in-process engines on one virtual clock and
# asserts the router's conservation invariant (every arrival finishes or
# is accountably shed; transits all deliver), plus run-to-run determinism
# of the pooled fleet metrics.  tier-1 pins the same properties for
# rwkv6 only (tests/test_router.py); this grid sweeps the archs whose
# slot state is NOT an O(1) column — dense-attention KV and the hybrid
# SSM — so prefill->decode snapshot transit is exercised across every
# cache pytree family.
# ---------------------------------------------------------------------------

FLEET_TIER2_GRID = [
    (arch, n, n_prefill)
    for arch in ("rwkv6-1.6b", "qwen2.5-14b", "hymba-1.5b")
    for n in (2, 4)
    for n_prefill in (0, 1)
]

_FLEET_BUILT = {}   # arch -> (cfg, model, params); shared across cells


def _fleet_built(arch):
    if arch not in _FLEET_BUILT:
        import jax

        from repro.models.lm import build_model
        from repro.testing import reduced_config

        cfg = reduced_config(arch)
        model = build_model(cfg)
        _FLEET_BUILT[arch] = (cfg, model,
                              model.init(jax.random.PRNGKey(0)))
    return _FLEET_BUILT[arch]


@pytest.mark.tier2
@pytest.mark.parametrize(
    "arch,n,n_prefill", FLEET_TIER2_GRID,
    ids=[f"{a}-x{n}-{'disagg' if k else 'colo'}"
         for a, n, k in FLEET_TIER2_GRID])
def test_fleet_grid_conservation(arch, n, n_prefill):
    from repro.plan.plan import FleetPlan, ServingPlan, WorkloadProfile
    from repro.serving import profile_items
    from repro.serving.router import Router, drive_fleet

    cfg, model, params = _fleet_built(arch)
    plan = ServingPlan(arch=arch, max_batch=2, max_len=32)
    fleet = FleetPlan.replicated(plan, n, routing="least_queue",
                                 n_prefill=n_prefill).validate()
    built = {(arch, True): (model, params)}
    items = profile_items(
        WorkloadProfile(kind="poisson", rate=1.2, duration=16.0),
        vocab_size=cfg.vocab_size, seed=7)

    router = Router.from_plan(fleet, seed=0, _built=built)
    reqs = drive_fleet(router, items)

    census = router.conservation_census()
    assert census["total"] == len(items), census
    assert census["finished"] + census["shed"] == len(items), census
    for r in reqs:
        assert r.shed or r.done, f"{arch}: request {r.uid} lost"
    ts = router.transit_stats()
    assert ts["delivered"] == ts["handoffs"] and ts["in_flight"] == 0, ts
    if n_prefill:
        assert ts["handoffs"] > 0, "disaggregated cell never handed off"
    agg = router.fleet_aggregate()
    assert agg["submitted"] == len(items)

    router2 = Router.from_plan(fleet, seed=0, _built=built)
    drive_fleet(router2, items)
    assert json.dumps(router2.fleet_aggregate(), sort_keys=True) == \
        json.dumps(agg, sort_keys=True), f"{arch}: fleet run not " \
        f"deterministic"
