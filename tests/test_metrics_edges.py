"""serving.metrics edge cases: empty/single-sample percentiles, zero
tick_seconds scaling, and all-shed SLO blocks — pure host-side units, no
model build."""

import math

import pytest

from repro.serving import metrics as smetrics
from repro.serving.engine import Request
from repro.serving.metrics import aggregate, percentile, scale_latencies


def _done(uid, t_submit=0, t_admit=1, t_first=1, t_done=4, n_tokens=4,
          deadline=None):
    r = Request(uid, [1, 2, 3], max_new_tokens=max(1, n_tokens),
                deadline=deadline, t_submit=t_submit)
    r.t_admit, r.t_first, r.t_done = t_admit, t_first, t_done
    r.output = list(range(n_tokens))
    r.done = True
    return r


def _shed(uid, deadline=1.0):
    r = Request(uid, [1, 2], deadline=deadline)
    r.shed = True
    return r


def test_percentile_empty_is_nan():
    for q in (0, 50, 95, 100):
        assert math.isnan(percentile([], q))


def test_percentile_single_sample_is_that_sample_at_every_rank():
    for q in (0, 1, 50, 95, 99, 100):
        assert percentile([7.5], q) == 7.5


def test_percentile_nearest_rank_two_samples():
    # nearest-rank: p50 of [1, 9] is the first sample, p51+ the second
    assert percentile([9.0, 1.0], 50) == 1.0
    assert percentile([9.0, 1.0], 51) == 9.0
    assert percentile([9.0, 1.0], 0) == 1.0     # rank clamps to 1
    assert percentile([9.0, 1.0], 100) == 9.0


def test_aggregate_empty_run_is_all_nan_but_well_formed():
    agg = aggregate([], ticks=0)
    assert agg["completed"] == 0 and agg["submitted"] == 0
    assert agg["tokens"] == 0
    assert math.isnan(agg["ttft"]["p95"])
    assert math.isnan(agg["mean_util"])
    assert math.isnan(agg["tokens_per_sec"])    # zero-tick span
    assert "slo" not in agg and "preemption" not in agg
    # and it still formats without raising
    assert "completed 0/0" in smetrics.format_summary(agg)


def test_aggregate_single_token_request_has_no_tpot_sample():
    agg = aggregate([_done(0, n_tokens=1, t_done=1)], ticks=2)
    assert agg["tpot"]["n"] == 0 and math.isnan(agg["tpot"]["p95"])
    assert agg["ttft"]["n"] == 1


def test_scale_latencies_zero_tick_seconds():
    """A degenerate calibration (0 measured seconds per tick) must not
    divide by zero: latencies scale to 0 ms and throughput is NaN."""
    agg = aggregate([_done(0)], ticks=5)
    out = scale_latencies(agg, 0.0)
    assert out["tick_seconds"] == 0.0
    assert out["ttft_ms"]["p50"] == 0.0
    assert math.isnan(out["tokens_per_sec"])


def test_scale_latencies_maps_ticks_to_ms():
    agg = aggregate([_done(0)], ticks=5)
    out = scale_latencies(agg, 0.002)
    assert out["ttft_ms"]["p50"] == pytest.approx(
        agg["ttft"]["p50"] * 2.0)   # 2 ms per tick
    assert out["tokens_per_sec"] == pytest.approx(
        agg["tokens"] / (5 * 0.002))


def test_slo_block_when_every_request_is_shed():
    """All-shed runs: nothing completes, every deadline counts as a
    violation, attainment is exactly 0, and the shed count appears."""
    reqs = [_shed(i) for i in range(3)]
    agg = aggregate(reqs, ticks=4)
    assert agg["completed"] == 0 and agg["submitted"] == 3
    slo = agg["slo"]
    assert slo == {"n": 3, "met": 0, "violations": 3, "attainment": 0.0,
                   "shed": 3}
    assert "3 shed at submit" in smetrics.format_summary(agg)


def test_slo_shed_key_absent_without_shedding():
    agg = aggregate([_done(0, deadline=10.0)], ticks=5)
    assert "shed" not in agg["slo"] and agg["slo"]["attainment"] == 1.0
