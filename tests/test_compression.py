"""Gradient compression: wire-format and unbiasedness."""

import os
import subprocess
import sys

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.optim.compression import make_error_feedback


@settings(max_examples=10, deadline=None)
@given(seed=st.integers(0, 2**16), steps=st.integers(5, 40))
def test_error_feedback_is_unbiased_over_time(seed, steps):
    """Averaging EF-compressed copies of a constant gradient converges to
    the true gradient ~1/steps, unlike plain round-to-nearest."""
    rng = np.random.default_rng(seed)
    g = {"w": jnp.asarray(rng.standard_normal((33, 17)), jnp.float32)}
    init, apply = make_error_feedback()
    res = init(g)
    acc = jax.tree.map(jnp.zeros_like, g)
    for _ in range(steps):
        comp, res = apply(g, res)
        acc = jax.tree.map(lambda a, c: a + c, acc, comp)
    err_ef = float(jnp.max(jnp.abs(acc["w"] / steps - g["w"])))
    one_shot, _ = apply(g, init(g))
    err_once = float(jnp.max(jnp.abs(one_shot["w"] - g["w"]))) + 1e-12
    assert err_ef <= err_once + 1e-6
    assert err_ef < 0.05 * float(jnp.max(jnp.abs(g["w"])))


COMPRESSED_AR = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import jax, jax.numpy as jnp, numpy as np
from repro.launch.mesh import make_test_mesh
from repro.optim.compression import compressed_allreduce
from repro.launch.hlo import parse_collectives
mesh = make_test_mesh((8,), ("data",))
g = {"a": jnp.asarray(np.random.default_rng(0).standard_normal((64, 33)),
                      jnp.float32)}
out = compressed_allreduce(g, mesh, "data")
rel = float(jnp.max(jnp.abs(out["a"] - g["a"]))) / float(jnp.max(jnp.abs(g["a"])))
assert rel < 0.02, rel
txt = jax.jit(lambda t: compressed_allreduce(t, mesh, "data")).lower(g) \
        .compile().as_text()
ops = parse_collectives(txt)
assert any(o.kind == "all-gather" and "s8" in o.line for o in ops)
print("OK")
"""


@pytest.mark.slow
def test_compressed_allreduce_int8_wire_format():
    r = subprocess.run([sys.executable, "-c", COMPRESSED_AR],
                       capture_output=True, text=True, timeout=600,
                       env={**os.environ, "PYTHONPATH": "src"}, cwd=".")
    assert r.returncode == 0, r.stderr[-2000:]
    assert "OK" in r.stdout
